"""AOT lowering: jax (L2) + Pallas (L1) → HLO **text** artifacts + manifest.

Run once via ``make artifacts``; the rust runtime then loads
``artifacts/*.hlo.txt`` through ``HloModuleProto::from_text_file`` and is
self-contained.  HLO *text* (not ``.serialize()``) is the interchange: the
``xla`` crate's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-instruction-
id protos, while the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), F32)


def artifact_configs():
    """The artifact set: (name, fn, input_specs, output_shapes, kind, params).

    Shape families:
      * l16/d32 r4  — tests, examples, quickstart.
      * l24/d60 r5  — dense benchmark sweep (Figs. 5–6), paper's L=50
        scaled to keep interpret-mode runtime sane.
      * l{10..60}/d50 r3 — sparse benchmark sweep (Figs. 3–4), compression
        ratio 10 at I ∈ {100..600}.
    """
    cfgs = []

    # Smoke artifact for runtime self-tests.
    cfgs.append(
        dict(
            name="smoke_add",
            fn=model.smoke_add,
            inputs=[spec(4), spec(4)],
            kind="smoke",
            params={},
        )
    )

    # Mixed-precision matmul microbench artifacts (§IV-B).
    for size, tile in [(256, 128)]:
        cfgs.append(
            dict(
                name=f"mixed_matmul_{size}",
                fn=functools.partial(model.mixed_matmul, bm=tile, bn=tile, bk=tile),
                inputs=[spec(size, size), spec(size, size)],
                kind="mixed_matmul",
                params={"size": size, "tile": tile},
            )
        )

    def add_compress(l, m, n, d, k_tile=None, mixed=False, suffix=""):
        cfgs.append(
            dict(
                name=f"compress_block_l{l}m{m}n{n}_d{d}{suffix}",
                fn=functools.partial(
                    model.compress_block, k_tile=k_tile, mixed=mixed
                ),
                inputs=[spec(d, d, d), spec(l, d), spec(m, d), spec(n, d)],
                kind="compress_block" + suffix,
                params={"l": l, "m": m, "n": n, "d": d},
            )
        )

    def add_als(l, m, n, r, k_tile=None):
        cfgs.append(
            dict(
                name=f"als_sweep_l{l}m{m}n{n}_r{r}",
                fn=functools.partial(model.als_sweep, k_tile=k_tile),
                inputs=[spec(l, m, n), spec(m, r), spec(n, r)],
                kind="als_sweep",
                params={"l": l, "m": m, "n": n, "r": r},
            )
        )

    def add_mse(l, m, n, r):
        cfgs.append(
            dict(
                name=f"reconstruct_mse_l{l}m{m}n{n}_r{r}",
                fn=model.reconstruct_mse,
                inputs=[spec(l, m, n), spec(l, r), spec(m, r), spec(n, r)],
                kind="reconstruct_mse",
                params={"l": l, "m": m, "n": n, "r": r},
            )
        )

    # Family A: tests/examples.
    add_compress(16, 16, 16, 32, k_tile=16)
    add_compress(16, 16, 16, 32, k_tile=16, mixed=True, suffix="_mixed")
    add_als(16, 16, 16, 4, k_tile=16)
    add_mse(16, 16, 16, 4)

    # Family B: dense benchmark sweep.  (§Perf note: a single-step grid
    # variant (k_tile=None) measured identically in interpret mode, so the
    # k-streaming BlockSpec — which is what matters on real TPUs — stays.)
    add_compress(24, 24, 24, 60, k_tile=20)
    add_als(24, 24, 24, 5, k_tile=12)

    # Family C: sparse benchmark sweep (ratio-10 proxies).
    for i in (100, 200, 400, 600):
        l = i // 10
        add_compress(l, l, l, 50, k_tile=25)
        add_als(l, l, l, 3, k_tile=None)

    return cfgs


def lower_one(cfg, out_dir):
    lowered = jax.jit(cfg["fn"]).lower(*cfg["inputs"])
    text = to_hlo_text(lowered)
    fname = f"{cfg['name']}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    # Output shapes from the lowered signature.
    out_shapes = [list(o.shape) for o in lowered.out_info]
    return dict(
        file=fname,
        inputs=[list(s.shape) for s in cfg["inputs"]],
        outputs=out_shapes,
        kind=cfg["kind"],
        params=cfg["params"],
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"version": 1, "artifacts": {}}
    for cfg in artifact_configs():
        if only and cfg["name"] not in only:
            continue
        entry = lower_one(cfg, args.out)
        manifest["artifacts"][cfg["name"]] = entry
        print(f"lowered {cfg['name']}: in={entry['inputs']} out={entry['outputs']}")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
