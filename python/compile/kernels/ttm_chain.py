"""Pallas kernel: blocked TTM chain — the compression hot-spot (Fig. 2b).

Computes ``Y = T x1 U x2 V x3 W`` for one tensor block.  The grid streams
the block along its third (k) mode: each grid step loads a ``(d0, d1, tk)``
slab of ``T`` and the matching ``(n, tk)`` slice of ``W`` from HBM into
VMEM (expressed by the BlockSpec index maps), contracts modes 1 and 2 fully
and mode 3 partially, and accumulates into the output, which stays resident
in VMEM across steps.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles the
matricized block over CUDA threadblocks feeding tensor-core MMAs; here the
k-mode streaming schedule is the BlockSpec, and the three contractions are
``dot_general``s that map onto the 128×128 MXU.  ``interpret=True`` because
the CPU PJRT plugin cannot execute Mosaic custom-calls; the *structure*
(VMEM working set, MXU-shaped contractions) is what carries to real TPUs.

VMEM working set per step (f32): ``d0·d1·tk + n·tk + l·d1·tk(interm) +
l·m·n(acc)`` — e.g. d=100, tk=25, l=m=n=50: ~1.6 MB, well under 16 MB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(t_ref, u_ref, v_ref, w_ref, o_ref, *, mixed):
    t = t_ref[...]  # (d0, d1, tk)
    u = u_ref[...]  # (l, d0)
    v = v_ref[...]  # (m, d1)
    w = w_ref[...]  # (n, tk)

    if mixed:
        from .mixed_matmul import compensated_dot

        dot = compensated_dot
    else:
        def dot(x, y):
            return jax.lax.dot_general(
                x, y, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    d0, d1, tk = t.shape
    l = u.shape[0]
    m = v.shape[0]
    n = w.shape[0]

    # mode 1: (l, d0) @ (d0, d1*tk) -> (l, d1, tk)
    y1 = dot(u, t.reshape(d0, d1 * tk)).reshape(l, d1, tk)
    # mode 2: (m, d1) @ (d1, l*tk) -> (m, l, tk) -> (l, m, tk)
    y1t = jnp.transpose(y1, (1, 0, 2)).reshape(d1, l * tk)
    y2 = dot(v, y1t).reshape(m, l, tk).transpose(1, 0, 2)
    # mode 3 (partial over this k-slab): (n, tk) @ (tk, l*m) -> (l, m, n)
    y2t = jnp.transpose(y2, (2, 0, 1)).reshape(tk, l * m)
    y3 = dot(w, y2t).reshape(n, l, m).transpose(1, 2, 0)

    # Accumulate across the k-grid.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += y3


def ttm_chain(t, u, v, w, *, k_tile=None, mixed=False):
    """``Comp(T, U, V, W)`` as a Pallas call.

    Args:
      t: ``(d0, d1, d2)`` f32 block.
      u, v, w: ``(l, d0)``, ``(m, d1)``, ``(n, d2)`` f32 maps.
      k_tile: k-mode slab size (must divide d2); default whole d2.
      mixed: use the compensated bf16 dot (§IV-B) for every contraction.
    """
    d0, d1, d2 = t.shape
    l, m, n = u.shape[0], v.shape[0], w.shape[0]
    assert u.shape[1] == d0 and v.shape[1] == d1 and w.shape[1] == d2
    if k_tile is None:
        k_tile = d2
    assert d2 % k_tile == 0, f"k_tile {k_tile} must divide d2 {d2}"
    steps = d2 // k_tile

    return pl.pallas_call(
        functools.partial(_kernel, mixed=mixed),
        grid=(steps,),
        in_specs=[
            # Stream T and W along k; U, V stay resident.
            pl.BlockSpec((d0, d1, k_tile), lambda s: (0, 0, s)),
            pl.BlockSpec((l, d0), lambda s: (0, 0)),
            pl.BlockSpec((m, d1), lambda s: (0, 0)),
            pl.BlockSpec((n, k_tile), lambda s: (0, s)),
        ],
        out_specs=pl.BlockSpec((l, m, n), lambda s: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((l, m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(t, u, v, w)
