"""Layer-1 Pallas kernels (build-time only; lowered to HLO by aot.py).

All kernels run with ``interpret=True``: the CPU PJRT plugin the rust
runtime embeds cannot execute Mosaic custom-calls, so interpret-mode
lowering (plain HLO ops) is the portable interchange.  Real-TPU performance
is estimated analytically from the BlockSpecs — see DESIGN.md §Perf.
"""

from .mixed_matmul import compensated_dot, mixed_matmul
from .mttkrp import mttkrp1
from .ttm_chain import ttm_chain

__all__ = ["compensated_dot", "mixed_matmul", "mttkrp1", "ttm_chain"]
