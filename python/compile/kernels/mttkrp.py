"""Pallas kernel: mode-1 MTTKRP — the ALS hot-spot (Alg. 1 line 3).

``M = Y_(1) · (C ⊙ B)``: instead of materializing the Khatri-Rao product in
HBM, the grid streams over the k mode; each step loads the frontal slab
``Y[:, :, k-tile]`` and the matching rows of ``C``, forms the tiny
``(tk·J, R)`` Khatri-Rao panel *in VMEM*, and accumulates its GEMM with the
slab into the ``(I, R)`` output (VMEM-resident).  This is the TPU analogue
of the fused tensor-core MTTKRP the paper builds on [15].
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(y_ref, b_ref, c_ref, o_ref):
    y = y_ref[...]  # (I, J, tk)
    b = b_ref[...]  # (J, R)
    c = c_ref[...]  # (tk, R)
    i_dim, j_dim, tk = y.shape
    r = b.shape[1]

    # KR panel in VMEM: row (j + k·J) = c[k,:] * b[j,:]  (slow=c, fast=b).
    kr = (c[:, None, :] * b[None, :, :]).reshape(tk * j_dim, r)
    # Y slab matricized with columns (j + k·J): transpose to (I, tk, J)?
    # Column index of Y_(1) is j + k*J with our convention, so flatten k
    # slowest: (I, tk*J) needs rows of kr ordered (k, j) — matches reshape
    # above (k slow, j fast).
    y1 = jnp.transpose(y, (0, 2, 1)).reshape(i_dim, tk * j_dim)
    part = jax.lax.dot_general(
        y1, kr, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def mttkrp1(y, b, c, *, k_tile=None):
    """Mode-1 MTTKRP ``einsum('ijk,jr,kr->ir')`` as a Pallas call."""
    i_dim, j_dim, k_dim = y.shape
    r = b.shape[1]
    assert b.shape[0] == j_dim and c.shape == (k_dim, r)
    if k_tile is None:
        k_tile = k_dim
    assert k_dim % k_tile == 0
    steps = k_dim // k_tile

    return pl.pallas_call(
        _kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((i_dim, j_dim, k_tile), lambda s: (0, 0, s)),
            pl.BlockSpec((j_dim, r), lambda s: (0, 0)),
            pl.BlockSpec((k_tile, r), lambda s: (s, 0)),
        ],
        out_specs=pl.BlockSpec((i_dim, r), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((i_dim, r), jnp.float32),
        interpret=True,
    )(y, b, c)
