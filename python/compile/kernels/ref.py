"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has its semantics pinned by a function here;
``python/tests`` asserts ``allclose`` between kernel and oracle across a
hypothesis-driven sweep of shapes.  The rust crate's ``mixed``/``compress``
modules implement the same math (tested in rust against small closed forms),
so the chain rust ⇄ jnp ⇄ pallas is pinned at every joint.
"""

import jax.numpy as jnp


def comp_ref(t, u, v, w):
    """Eq. (3): ``Y = X x1 U x2 V x3 W`` — direct einsum."""
    return jnp.einsum("ijk,li,mj,nk->lmn", t, u, v, w)


def ttm1_ref(t, u):
    """Mode-1 tensor-times-matrix."""
    return jnp.einsum("ijk,li->ljk", t, u)


def khatri_rao_ref(slow, fast):
    """Column-wise Kronecker ``slow ⊙ fast``; row index = fast + slow*J.

    Matches the rust convention in ``linalg::products``: the *first*
    argument varies slowest.
    """
    k, r = slow.shape
    j, r2 = fast.shape
    assert r == r2
    return (slow[:, None, :] * fast[None, :, :]).reshape(k * j, r)


def mttkrp1_ref(y, b, c):
    """Mode-1 MTTKRP: ``Y_(1) · (C ⊙ B)``."""
    return jnp.einsum("ijk,jr,kr->ir", y, b, c)


def split_bf16(x):
    """First-order bf16 split: ``x = hi + lo`` with hi = bf16(x)."""
    hi = x.astype(jnp.bfloat16).astype(jnp.float32)
    lo = x - hi
    return hi, lo


def mixed_matmul_ref(a, b):
    """Eq. (5) restricted to two operands: compensated bf16 matmul.

    ``A·B ≈ hi(A)hi(B) + hi(A)lo(B) + lo(A)hi(B)`` with every operand fed
    through bf16 (as the MXU port would) and f32 accumulation.
    """
    a_hi, a_lo = split_bf16(a)
    b_hi, b_lo = split_bf16(b)
    # Residuals are re-quantized: hardware feeds them through the same port.
    a_lo = a_lo.astype(jnp.bfloat16).astype(jnp.float32)
    b_lo = b_lo.astype(jnp.bfloat16).astype(jnp.float32)

    def f(x, y):
        return jnp.dot(
            x.astype(jnp.bfloat16),
            y.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )

    return f(a_hi, b_hi) + f(a_hi, b_lo) + f(a_lo, b_hi)


def als_sweep_ref(y, b, c, ridge=1e-8):
    """One full ALS sweep (Alg. 1 line 3) on a dense proxy tensor."""

    def solve(mttkrp, g1, g2):
        gram = (g1.T @ g1) * (g2.T @ g2)
        damp = ridge * jnp.trace(gram) / gram.shape[0]
        gram = gram + damp * jnp.eye(gram.shape[0], dtype=gram.dtype)
        return jnp.linalg.solve(gram, mttkrp.T).T

    a = solve(jnp.einsum("ijk,jr,kr->ir", y, b, c), c, b)
    b = solve(jnp.einsum("ijk,ir,kr->jr", y, a, c), c, a)
    c = solve(jnp.einsum("ijk,ir,jr->kr", y, a, b), b, a)
    return a, b, c
