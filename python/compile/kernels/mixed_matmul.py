"""Pallas kernel: split-precision (compensated bf16) matmul — §IV-B.

GPU tensor cores take FP16 operands and accumulate in FP32; the TPU MXU
takes bf16 and accumulates in f32.  Feeding f32 data through either port
loses mantissa bits; the paper's Eq. (5) recovers first-order accuracy by
splitting each operand ``x = hi + lo`` (hi = 16-bit rounding, lo = residual)
and summing the three first-order product terms.

The kernel tiles ``(M, K) @ (K, N)`` over an ``(M/bm, N/bn, K/bk)`` grid:
each step loads an ``(bm, bk)`` A-tile and ``(bk, bn)`` B-tile into VMEM,
performs the three bf16 MXU dots, and accumulates into the f32 output tile
that stays VMEM-resident across the k-steps — the standard MXU matmul
schedule, with 3× the MMA issue rate of a plain bf16 matmul (the paper
reports the same 3-term overhead for tensor cores).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def compensated_dot(a, b):
    """Three-term compensated bf16 dot of f32 operands (used in-kernel)."""
    a_hi16 = a.astype(jnp.bfloat16)
    b_hi16 = b.astype(jnp.bfloat16)
    a_hi = a_hi16.astype(jnp.float32)
    b_hi = b_hi16.astype(jnp.float32)
    a_lo = (a - a_hi).astype(jnp.bfloat16)
    b_lo = (b - b_hi).astype(jnp.bfloat16)

    def mxu(x, y):
        return jax.lax.dot_general(
            x, y, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    return mxu(a_hi16, b_hi16) + mxu(a_hi16, b_lo) + mxu(a_lo, b_hi16)


def _kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += compensated_dot(a_ref[...], b_ref[...])


def mixed_matmul(a, b, *, bm=None, bn=None, bk=None):
    """Compensated bf16 matmul ``A (M,K) @ B (K,N) -> f32 (M,N)``.

    Tile sizes default to full dims (single program); pass MXU-shaped tiles
    (multiples of 128 on real hardware) to exercise the blocked schedule.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm = bm or m
    bn = bn or n
    bk = bk or k
    assert m % bm == 0 and n % bn == 0 and k % bk == 0

    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
