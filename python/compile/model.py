"""Layer-2 JAX graphs — the functions that get AOT-lowered to artifacts.

Each public function here is a pure jax function over fixed-shape f32
arrays, calling the Layer-1 Pallas kernels for its compute hot-spots.  It
is lowered ONCE by ``aot.py``; the rust runtime executes the resulting HLO
— Python never runs on the request path.
"""

import jax.numpy as jnp

from .kernels import mixed_matmul as _mixed_matmul_kernel
from .kernels import mttkrp1, ttm_chain


def smoke_add(x, y):
    """Tiny artifact used by the rust runtime's self-test."""
    return (x + y,)


def compress_block(t, u, v, w, *, k_tile=None, mixed=False):
    """One block's contribution to a proxy tensor (Eq. 3 / Fig. 2b).

    Inputs: ``t (d,d,d)``, ``u/v/w (L|M|N, d)``.  Output: ``(L, M, N)``.
    """
    return (ttm_chain(t, u, v, w, k_tile=k_tile, mixed=mixed),)


def mixed_matmul(a, b, *, bm=None, bn=None, bk=None):
    """Compensated bf16 matmul artifact (§IV-B) for the kernel microbench."""
    return (_mixed_matmul_kernel(a, b, bm=bm, bn=bn, bk=bk),)


def _solve_spd_unrolled(g, rhs):
    """Gauss-Jordan solve of an SPD ``R×R`` system, unrolled over R.

    ``jnp.linalg.solve`` lowers to a LAPACK *typed-FFI custom-call* that the
    rust runtime's xla_extension 0.5.1 cannot load, so the artifact path
    needs a solve made of plain HLO ops.  R ≤ 8 here, and the ridge-damped
    Gram is diagonally dominant enough that pivoting is unnecessary.
    """
    r = g.shape[0]
    aug = jnp.concatenate([g, rhs], axis=1)
    for i in range(r):
        row = aug[i] / aug[i, i]
        aug = aug.at[i].set(row)
        factors = aug[:, i].at[i].set(0.0)
        aug = aug - factors[:, None] * row[None, :]
    return aug[:, r:]


def _gram_solve(mttkrp, g1, g2, ridge):
    """Solve ``F · ((G1ᵀG1)*(G2ᵀG2)) = MTTKRP`` for F (Alg. 1 line 3)."""
    gram = (g1.T @ g1) * (g2.T @ g2)
    # Relative ridge + tiny absolute floor so an all-zero input (e.g. a
    # padded edge proxy) yields zeros instead of NaNs.
    damp = ridge * jnp.trace(gram) / gram.shape[0] + 1e-12
    gram = gram + damp * jnp.eye(gram.shape[0], dtype=gram.dtype)
    return _solve_spd_unrolled(gram, mttkrp.T).T


def als_sweep(y, b, c, *, ridge=1e-8, k_tile=None):
    """One fused ALS sweep over all three modes (Alg. 1 line 3).

    Takes only ``(y, b, c)``: the sweep recomputes ``a`` first, so an ``a``
    input would be dead code (XLA prunes the parameter, and then the AOT
    artifact's buffer count no longer matches the manifest).  The three
    MTTKRPs run through the Pallas kernel (mode 2/3 via transposes of ``y``
    — free at the HLO level).  Returns the updated ``(a, b, c)``.
    """
    a = _gram_solve(mttkrp1(y, b, c, k_tile=k_tile), c, b, ridge)
    yt2 = jnp.transpose(y, (1, 0, 2))  # J × I × K
    b = _gram_solve(mttkrp1(yt2, a, c, k_tile=k_tile), c, a, ridge)
    yt3 = jnp.transpose(y, (2, 0, 1))  # K × I × J
    c = _gram_solve(mttkrp1(yt3, a, b, k_tile=None), b, a, ridge)
    return a, b, c


def reconstruct_mse(y, a, b, c):
    """``mean((Y − [[A,B,C]])²)`` for a proxy-sized tensor."""
    model = jnp.einsum("ir,jr,kr->ijk", a, b, c)
    d = y - model
    return (jnp.mean(d * d),)
