"""L2 model graph tests: als_sweep convergence, reconstruct_mse, shapes."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def planted(rng, dims, r):
    a, b, c = rand(rng, dims[0], r), rand(rng, dims[1], r), rand(rng, dims[2], r)
    return jnp.einsum("ir,jr,kr->ijk", a, b, c), (a, b, c)


def test_als_sweep_matches_ref_one_step():
    rng = np.random.default_rng(10)
    y, _ = planted(rng, (6, 5, 4), 2)
    b0, c0 = rand(rng, 5, 2), rand(rng, 4, 2)
    got = model.als_sweep(y, b0, c0)
    want = ref.als_sweep_ref(y, b0, c0)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=5e-3, atol=5e-3)


def test_als_sweep_converges_on_planted():
    rng = np.random.default_rng(11)
    y, _ = planted(rng, (10, 10, 10), 3)
    b, c = rand(rng, 10, 3), rand(rng, 10, 3)
    for _ in range(60):
        a, b, c = model.als_sweep(y, b, c)
    rec = jnp.einsum("ir,jr,kr->ijk", a, b, c)
    err = float(jnp.linalg.norm(rec - y) / jnp.linalg.norm(y))
    assert err < 1e-3, err


def test_als_sweep_monotone_fit():
    rng = np.random.default_rng(12)
    y, _ = planted(rng, (8, 8, 8), 2)
    b, c = rand(rng, 8, 2), rand(rng, 8, 2)
    prev = float("inf")
    for i in range(15):
        a, b, c = model.als_sweep(y, b, c)
        resid = float(jnp.linalg.norm(y - jnp.einsum("ir,jr,kr->ijk", a, b, c)))
        assert resid < prev + 1e-3, (i, resid, prev)
        prev = resid


def test_reconstruct_mse_zero_for_exact():
    rng = np.random.default_rng(13)
    y, (a, b, c) = planted(rng, (6, 6, 6), 2)
    (mse,) = model.reconstruct_mse(y, a, b, c)
    assert float(mse) < 1e-10


@settings(max_examples=10, deadline=None)
@given(
    l=st.integers(2, 8),
    r=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_compress_block_shapes(l, r, seed):
    rng = np.random.default_rng(seed)
    d = 2 * l
    t = rand(rng, d, d, d)
    u, v, w = rand(rng, l, d), rand(rng, l, d), rand(rng, l, d)
    (y,) = model.compress_block(t, u, v, w)
    assert y.shape == (l, l, l)
    np.testing.assert_allclose(y, ref.comp_ref(t, u, v, w), rtol=3e-4, atol=3e-4)


def test_smoke_add():
    (out,) = model.smoke_add(jnp.ones(4), 2 * jnp.ones(4))
    np.testing.assert_allclose(out, 3 * np.ones(4))
