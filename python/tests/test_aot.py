"""AOT path tests: artifact configs are well-formed and one lowering
round-trips to parseable HLO text with the declared shapes."""

import json
import os
import tempfile

import pytest

from compile import aot


def test_artifact_configs_consistent():
    cfgs = aot.artifact_configs()
    names = [c["name"] for c in cfgs]
    assert len(names) == len(set(names)), "duplicate artifact names"
    kinds = {c["kind"] for c in cfgs}
    assert {"smoke", "compress_block", "als_sweep", "mixed_matmul"} <= kinds
    for c in cfgs:
        assert c["inputs"], c["name"]
        for s in c["inputs"]:
            assert all(d >= 1 for d in s.shape), c["name"]


def test_lower_smoke_artifact_to_text():
    cfgs = {c["name"]: c for c in aot.artifact_configs()}
    with tempfile.TemporaryDirectory() as d:
        entry = aot.lower_one(cfgs["smoke_add"], d)
        assert entry["inputs"] == [[4], [4]]
        assert entry["outputs"] == [[4]]
        text = open(os.path.join(d, entry["file"])).read()
        # HLO text essentials: a module header and an ENTRY computation.
        assert "HloModule" in text
        assert "ENTRY" in text
        assert "f32[4]" in text


def test_lower_als_sweep_has_no_custom_calls():
    # The rust runtime's xla_extension 0.5.1 cannot load typed-FFI
    # custom-calls (LAPACK solves); the unrolled Gauss-Jordan keeps the
    # artifact custom-call-free.
    cfgs = {c["name"]: c for c in aot.artifact_configs()}
    with tempfile.TemporaryDirectory() as d:
        entry = aot.lower_one(cfgs["als_sweep_l16m16n16_r4"], d)
        text = open(os.path.join(d, entry["file"])).read()
        assert "custom-call" not in text, "artifact contains a custom-call"
        assert entry["outputs"] == [[16, 4], [16, 4], [16, 4]]


def test_manifest_round_trip(tmp_path):
    cfgs = {c["name"]: c for c in aot.artifact_configs()}
    entry = aot.lower_one(cfgs["smoke_add"], str(tmp_path))
    manifest = {"version": 1, "artifacts": {"smoke_add": entry}}
    p = tmp_path / "manifest.json"
    p.write_text(json.dumps(manifest))
    loaded = json.loads(p.read_text())
    assert loaded["artifacts"]["smoke_add"]["kind"] == "smoke"


@pytest.mark.parametrize("name", ["compress_block_l16m16n16_d32", "mixed_matmul_256"])
def test_key_artifacts_custom_call_free(name):
    cfgs = {c["name"]: c for c in aot.artifact_configs()}
    with tempfile.TemporaryDirectory() as d:
        entry = aot.lower_one(cfgs[name], d)
        text = open(os.path.join(d, entry["file"])).read()
        assert "custom-call" not in text, f"{name} contains a custom-call"
