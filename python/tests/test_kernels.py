"""Kernel-vs-oracle correctness: the core L1 signal.

Each Pallas kernel is swept over shapes/dtypes with hypothesis and compared
against the pure-jnp oracle in ``compile.kernels.ref`` via assert_allclose.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mixed_matmul, mttkrp1, ttm_chain
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# ---------------------------------------------------------------- ttm_chain

@settings(max_examples=25, deadline=None)
@given(
    d0=st.integers(1, 8),
    d1=st.integers(1, 8),
    d2=st.integers(1, 8),
    l=st.integers(1, 6),
    m=st.integers(1, 6),
    n=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_ttm_chain_matches_ref(d0, d1, d2, l, m, n, seed):
    rng = np.random.default_rng(seed)
    t = rand(rng, d0, d1, d2)
    u = rand(rng, l, d0)
    v = rand(rng, m, d1)
    w = rand(rng, n, d2)
    got = ttm_chain(t, u, v, w)
    want = ref.comp_ref(t, u, v, w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("k_tile", [1, 2, 4, 8])
def test_ttm_chain_k_tiling_invariant(k_tile):
    rng = np.random.default_rng(0)
    t = rand(rng, 6, 5, 8)
    u, v, w = rand(rng, 3, 6), rand(rng, 4, 5), rand(rng, 2, 8)
    got = ttm_chain(t, u, v, w, k_tile=k_tile)
    want = ref.comp_ref(t, u, v, w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_ttm_chain_mixed_close_to_full():
    rng = np.random.default_rng(1)
    t = rand(rng, 8, 8, 8)
    u, v, w = rand(rng, 4, 8), rand(rng, 4, 8), rand(rng, 4, 8)
    full = ref.comp_ref(t, u, v, w)
    got = ttm_chain(t, u, v, w, mixed=True)
    # bf16-split keeps ~1e-3 relative accuracy through three contractions.
    err = float(jnp.linalg.norm(got - full) / jnp.linalg.norm(full))
    assert err < 5e-3, err


def test_ttm_chain_identity_is_noop():
    rng = np.random.default_rng(2)
    t = rand(rng, 5, 5, 5)
    eye = jnp.eye(5, dtype=jnp.float32)
    np.testing.assert_allclose(ttm_chain(t, eye, eye, eye), t, rtol=1e-5, atol=1e-5)


def test_ttm_chain_kronecker_identity():
    # Comp of a CP tensor compresses the factors: the identity Alg. 2 needs.
    rng = np.random.default_rng(3)
    a, b, c = rand(rng, 7, 2), rand(rng, 6, 2), rand(rng, 5, 2)
    t = jnp.einsum("ir,jr,kr->ijk", a, b, c)
    u, v, w = rand(rng, 3, 7), rand(rng, 3, 6), rand(rng, 3, 5)
    got = ttm_chain(t, u, v, w)
    want = jnp.einsum("ir,jr,kr->ijk", u @ a, v @ b, w @ c)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------- mixed_matmul

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 16),
    k=st.integers(1, 16),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_mixed_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = mixed_matmul(a, b)
    want = ref.mixed_matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_mixed_matmul_tiled_matches_untiled():
    rng = np.random.default_rng(4)
    a, b = rand(rng, 8, 12), rand(rng, 12, 16)
    got = mixed_matmul(a, b, bm=4, bn=8, bk=6)
    want = mixed_matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_mixed_matmul_beats_naive_bf16():
    rng = np.random.default_rng(5)
    a, b = rand(rng, 64, 64), rand(rng, 64, 64)
    exact = a @ b
    naive = jnp.dot(
        a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    comp = mixed_matmul(a, b)
    err_naive = float(jnp.linalg.norm(naive - exact))
    err_comp = float(jnp.linalg.norm(comp - exact))
    assert err_comp < err_naive / 5, (err_comp, err_naive)


# ------------------------------------------------------------------ mttkrp

@settings(max_examples=25, deadline=None)
@given(
    i=st.integers(1, 8),
    j=st.integers(1, 8),
    k=st.integers(1, 8),
    r=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_mttkrp_matches_ref(i, j, k, r, seed):
    rng = np.random.default_rng(seed)
    y = rand(rng, i, j, k)
    b = rand(rng, j, r)
    c = rand(rng, k, r)
    got = mttkrp1(y, b, c)
    want = ref.mttkrp1_ref(y, b, c)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("k_tile", [1, 3, 6])
def test_mttkrp_k_tiling_invariant(k_tile):
    rng = np.random.default_rng(6)
    y = rand(rng, 5, 4, 6)
    b, c = rand(rng, 4, 3), rand(rng, 6, 3)
    got = mttkrp1(y, b, c, k_tile=k_tile)
    want = ref.mttkrp1_ref(y, b, c)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_mttkrp_equals_unfold_times_kr():
    # Kernel == X_(1) @ khatri_rao(C, B) with the crate's row convention.
    rng = np.random.default_rng(7)
    y = rand(rng, 4, 3, 5)
    b, c = rand(rng, 3, 2), rand(rng, 5, 2)
    kr = ref.khatri_rao_ref(c, b)  # rows j + k*J
    x1 = jnp.transpose(y, (0, 2, 1)).reshape(4, 15)  # cols (k, j)? no:
    # column index j + k*J means k slow, j fast — matches (0,2,1) reshape.
    want = x1 @ kr
    got = mttkrp1(y, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
