//! Gene-expression analysis example (§V-C of the paper).
//!
//! Synthesizes an `individual × tissue × gene` tensor with planted
//! expression programs, decomposes it with the compressed pipeline, and
//! reports the paper's metrics (relative error, wall-clock) plus factor
//! congruence against the planted programs.
//!
//! ```sh
//! cargo run --release --example gene_analysis
//! ```

use exascale_tensor::apps::{run_gene_analysis, GeneConfig};
use exascale_tensor::util::logging;

fn main() -> anyhow::Result<()> {
    logging::init();
    let cfg = GeneConfig {
        individuals: 120,
        tissues: 30,
        genes: 800,
        programs: 5,
        gene_sparsity: 0.05,
        noise: 0.01,
        seed: 1,
        ..Default::default()
    };
    println!(
        "gene tensor: {} individuals × {} tissues × {} genes, {} planted programs",
        cfg.individuals, cfg.tissues, cfg.genes, cfg.programs
    );
    let report = run_gene_analysis(&cfg)?;
    println!("replicas           : {}", report.replicas);
    println!("relative error     : {:.3}%  (paper: 1.4%)", 100.0 * report.rel_error);
    println!("factor congruence  : {:.4}", report.factor_congruence);
    println!("decomposition time : {:.2} s (paper: 137 s at GTEx scale)", report.decompose_seconds);
    assert!(report.rel_error < 0.10, "gene analysis failed to recover programs");
    println!("gene_analysis OK");
    Ok(())
}
