//! Quickstart: decompose a synthetic low-rank tensor with the compressed
//! pipeline and verify the planted factors are recovered.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use exascale_tensor::coordinator::{Pipeline, PipelineConfig};
use exascale_tensor::cp::{model_congruence, CpModel};
use exascale_tensor::tensor::LowRankGenerator;

fn main() -> anyhow::Result<()> {
    // A rank-4 tensor of "size" 96³ — generated implicitly from planted
    // factors, as the paper's experiments do (the pipeline only ever reads
    // blocks, so the same code path handles sizes that don't fit in RAM).
    let (size, rank) = (96usize, 4usize);
    let gen = LowRankGenerator::new(size, size, size, rank, 2024);

    let cfg = PipelineConfig::builder()
        .reduced_dims(16, 16, 16) // proxy tensors are 16³
        .rank(rank)
        .block([32, 32, 32]) // streamed in 32³ blocks (Fig. 2)
        .seed(7)
        .build()?;

    let mut pipe = Pipeline::new(cfg);
    let result = pipe.run(&gen)?;

    println!("recovered rank-{rank} model from {size}³ tensor");
    println!("  sampled MSE       = {:.3e}", result.diagnostics.sampled_mse);
    println!("  sampled rel error = {:.3e}", result.diagnostics.rel_error);

    // We know the ground truth here — check factor congruence too.
    let (a, b, c) = gen.factors.clone();
    let truth = CpModel::new(a, b, c);
    let congruence = model_congruence(&truth, &result.model);
    println!("  factor congruence = {congruence:.4} (1.0 = perfect)");

    println!("\nper-stage timings:\n{}", pipe.metrics.report());
    assert!(result.diagnostics.rel_error < 0.05, "recovery failed");
    println!("quickstart OK");
    Ok(())
}
