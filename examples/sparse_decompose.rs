//! Sparse tensor decomposition example (the Figs. 3–4 scenario).
//!
//! Generates an implicit sparse low-rank tensor, decomposes it with both
//! the direct sparse ALS baseline and the compressed-sensing pipeline
//! (§IV-D), and compares time + error.
//!
//! ```sh
//! cargo run --release --example sparse_decompose
//! ```

use exascale_tensor::bench_harness::{bench_once, speedup};
use exascale_tensor::coordinator::{Pipeline, PipelineConfig, SensingConfig};
use exascale_tensor::cp::{als_decompose_sparse, AlsOptions};
use exascale_tensor::tensor::{DenseTensor, SparseLowRankGenerator, SparseTensor, TensorSource};
use exascale_tensor::util::logging;

fn main() -> anyhow::Result<()> {
    logging::init();
    let (size, rank, nnz_per_col) = (120usize, 3usize, 12usize);
    let gen = SparseLowRankGenerator::new(size, size, size, rank, nnz_per_col, 5);
    println!(
        "sparse tensor {size}³, rank {rank}, ~{} nnz",
        gen.nnz_estimate().unwrap_or(0)
    );

    // Baseline: direct sparse ALS on the materialized COO tensor.
    let (a, b, c) = gen.factors().clone();
    let dense = DenseTensor::from_cp_factors(&a, &b, &c);
    let coo = SparseTensor::from_dense(&dense, 0.0);
    let (base_meas, base) = bench_once("sparse-als", || {
        als_decompose_sparse(
            &coo,
            &AlsOptions {
                rank,
                max_iters: 150,
                tol: 1e-11,
                seed: 3,
                ..Default::default()
            },
        )
        .expect("sparse als")
    });
    let (base_model, _) = base;
    let base_err = base_model.to_tensor().rel_error(&dense);
    println!("[sparse-als baseline] {:.2}s rel_err {base_err:.2e}", base_meas.mean_s);

    // Compressed-sensing pipeline (§IV-D).
    let cfg = PipelineConfig::builder()
        .reduced_dims(20, 20, 20)
        .rank(rank)
        .block([40, 40, 40])
        .sensing(SensingConfig {
            alpha: 2.2,
            nnz_per_col: 16,
            lambda: 0.02,
        })
        .seed(9)
        .build()?;
    let mut pipe = Pipeline::new(cfg);
    let (sens_meas, result) = bench_once("sensing", || pipe.run(&gen).expect("sensing run"));
    println!(
        "[compressed-sensing]  {:.2}s rel_err {:.2e} (P={})",
        sens_meas.mean_s, result.diagnostics.rel_error, result.plan.replicas
    );
    println!(
        "speedup (baseline/sensing): {:.2}×",
        speedup(base_meas.mean_s, sens_meas.mean_s)
    );
    assert!(result.diagnostics.rel_error < 0.2, "sensing recovery failed");
    println!("sparse_decompose OK");
    Ok(())
}
