//! CP tensor layer example — the Table-I protocol on the tiny CNN.
//!
//! Trains the reference network, then compresses its second conv layer
//! with the three CP backends (Matlab-style hosvd-ALS, TensorLy-style
//! random-ALS, and our compressed pipeline), reporting accuracy before /
//! after / after-fine-tune and decomposition time.
//!
//! ```sh
//! cargo run --release --example cp_layer_compression
//! ```

use exascale_tensor::apps::nn::{evaluate, train, Network, SyntheticImages, TrainConfig};
use exascale_tensor::apps::{run_cp_layer_experiment, CpBackend};
use exascale_tensor::util::logging;

fn clone_net(reference: &Network, seed: u64) -> Network {
    let mut net = Network::new(18, 8, 16, 32, 3, seed);
    net.conv1.weight = reference.conv1.weight.clone();
    net.conv1.bias = reference.conv1.bias.clone();
    net.conv2.weight = reference.conv2.weight.clone();
    net.conv2.bias = reference.conv2.bias.clone();
    net.fc1.weight = reference.fc1.weight.clone();
    net.fc1.bias = reference.fc1.bias.clone();
    net.fc2.weight = reference.fc2.weight.clone();
    net.fc2.bias = reference.fc2.bias.clone();
    net
}

fn main() -> anyhow::Result<()> {
    logging::init();
    let gen = SyntheticImages::default();
    let train_ds = gen.generate(240, 1);
    let test_ds = gen.generate(90, 2);
    let seed = 42u64;

    println!("training reference CNN (conv 1→8→16, fc 32, 3 classes)…");
    let mut reference = Network::new(18, 8, 16, 32, 3, seed);
    let rep = train(&mut reference, &train_ds, &TrainConfig { epochs: 3, lr: 0.01, seed });
    println!(
        "  train losses {:?}  test acc {:.1}%",
        rep.epoch_losses
            .iter()
            .map(|l| (l * 100.0).round() / 100.0)
            .collect::<Vec<_>>(),
        100.0 * evaluate(&mut reference, &test_ds)
    );

    println!("\nTable I (conv2 weight tensor 16×8×9, CP rank 8):");
    println!(
        "{:<26} {:>8} {:>9} {:>9} {:>8} {:>8} {:>7}",
        "method", "acc pre", "acc drop", "acc ft", "time(s)", "rel err", "ratio"
    );
    for backend in [CpBackend::Hosvd, CpBackend::Random, CpBackend::Compressed] {
        let mut net = clone_net(&reference, seed);
        let r = run_cp_layer_experiment(&mut net, &train_ds, &test_ds, 8, backend, 1, seed)?;
        println!(
            "{:<26} {:>7.1}% {:>8.1}% {:>8.1}% {:>8.2} {:>8.4} {:>6.1}x",
            r.backend,
            100.0 * r.accuracy_before,
            100.0 * r.accuracy_after_decomp,
            100.0 * r.accuracy_after_finetune,
            r.decomp_seconds,
            r.reconstruction_error,
            r.compression_ratio,
        );
    }
    println!("cp_layer_compression OK");
    Ok(())
}
