//! End-to-end system driver — the EXPERIMENTS.md §E2E run.
//!
//! Exercises all three layers on a real (scaled) workload:
//!
//! 1. an implicit 360³ (≈47M virtual elements) rank-5 tensor is streamed
//!    through the block-compression stage;
//! 2. proxy decomposition runs on the **AOT XLA/Pallas artifacts** via the
//!    PJRT runtime (falling back to the rust backend with a warning if
//!    `make artifacts` has not been run);
//! 3. factors are recovered and verified against the planted truth;
//! 4. the same workload is repeated on the sequential rust baseline to
//!    report the paper-style speedup.
//!
//! ```sh
//! make artifacts && cargo run --release --example exascale_e2e
//! ```

use exascale_tensor::bench_harness::{bench_once, speedup};
use exascale_tensor::coordinator::{Backend, Pipeline, PipelineConfig};
use exascale_tensor::cp::{model_congruence, CpModel};
use exascale_tensor::runtime::{artifacts_dir, XlaBackend, XlaRuntime};
use exascale_tensor::tensor::LowRankGenerator;
use exascale_tensor::util::logging;

const SIZE: usize = 360;
const RANK: usize = 5;
const REDUCED: usize = 24;
const BLOCK: usize = 60;

fn build_pipeline(backend: Backend, rt: Option<&XlaRuntime>) -> anyhow::Result<Pipeline> {
    let cfg = PipelineConfig::builder()
        .reduced_dims(REDUCED, REDUCED, REDUCED)
        .rank(RANK)
        .block([BLOCK, BLOCK, BLOCK])
        .backend(backend)
        .als(100, 1e-10)
        .seed(11)
        .build()?;
    let mut pipe = Pipeline::new(cfg);
    if let Some(rt) = rt {
        // Single constructor for the whole XLA arm (ComputeBackend).
        let xla = XlaBackend::new(rt.clone(), [REDUCED; 3], BLOCK, RANK, 100, 1e-10, 4)?;
        pipe = pipe.with_compute(std::sync::Arc::new(xla));
    }
    Ok(pipe)
}

fn main() -> anyhow::Result<()> {
    logging::init();
    let gen = LowRankGenerator::new(SIZE, SIZE, SIZE, RANK, 99);
    println!(
        "== Exascale-Tensor end-to-end: {SIZE}³ = {:.1}M virtual elements, rank {RANK} ==",
        (SIZE * SIZE * SIZE) as f64 / 1e6
    );

    // Optimized arm: XLA artifacts if available.
    let rt = match XlaRuntime::load(artifacts_dir(), 2) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("WARNING: no artifacts ({e}); optimized arm uses rust backend");
            None
        }
    };
    let arm_name = if rt.is_some() { "xla-pallas" } else { "rust-parallel" };

    let mut opt_pipe = build_pipeline(
        if rt.is_some() { Backend::Xla } else { Backend::RustParallel },
        rt.as_ref(),
    )?;
    let (opt_meas, opt_result) = bench_once(arm_name, || opt_pipe.run(&gen).expect("optimized run"));

    let (a, b, c) = gen.factors.clone();
    let truth = CpModel::new(a, b, c);
    let congruence = model_congruence(&truth, &opt_result.model);
    println!("\n[{arm_name}] {:.2}s", opt_meas.mean_s);
    println!("  sampled MSE       = {:.3e}", opt_result.diagnostics.sampled_mse);
    println!("  sampled rel error = {:.3e}", opt_result.diagnostics.rel_error);
    println!("  factor congruence = {congruence:.4}");
    println!("  replicas          = {} (dropped {})", opt_result.plan.replicas, opt_result.diagnostics.dropped_replicas);
    println!("\nstage timings (optimized arm):\n{}", opt_pipe.metrics.report());

    // Baseline arm: sequential rust.
    let mut base_pipe = build_pipeline(Backend::RustSequential, None)?;
    let (base_meas, base_result) =
        bench_once("baseline-seq", || base_pipe.run(&gen).expect("baseline run"));
    println!("[baseline-seq] {:.2}s", base_meas.mean_s);
    println!("  sampled rel error = {:.3e}", base_result.diagnostics.rel_error);

    println!(
        "\nheadline: speedup = {:.2}× ({} vs sequential), rel error {:.2e}",
        speedup(base_meas.mean_s, opt_meas.mean_s),
        arm_name,
        opt_result.diagnostics.rel_error
    );
    assert!(opt_result.diagnostics.rel_error < 0.05, "recovery failed");
    assert!(congruence > 0.98, "factor recovery failed");
    println!("exascale_e2e OK");
    Ok(())
}
