//! Gene-expression analysis — the paper's second §V-C application.
//!
//! Gene data is modelled as an `individual × tissue × gene` tensor (Hore
//! et al. [11]); CP components then expose co-expression structure across
//! tissues.  Real GTEx-scale data is gated, so we synthesize a tensor with
//! the same statistical shape (DESIGN.md "Substitutions"): a few planted
//! expression programs (rank-1 components with sparse gene loadings and
//! smooth tissue profiles) plus measurement noise, at dims defaulting to
//! `200 × 40 × 2000` (16M entries — streamed, never fully materialized).

use crate::coordinator::{Pipeline, PipelineConfig};
use crate::cp::model_congruence;
use crate::linalg::Matrix;
use crate::tensor::{LowRankGenerator, TensorSource};
use crate::util::rng::Xoshiro256;
use crate::util::stats::Timer;
use anyhow::Result;

/// Gene-analysis experiment configuration.
#[derive(Clone, Debug)]
pub struct GeneConfig {
    pub individuals: usize,
    pub tissues: usize,
    pub genes: usize,
    /// Number of planted expression programs (CP rank).
    pub programs: usize,
    /// Fraction of genes participating in each program.
    pub gene_sparsity: f64,
    pub noise: f32,
    pub seed: u64,
    /// Worker threads for the pipeline.
    pub threads: usize,
}

impl Default for GeneConfig {
    fn default() -> Self {
        Self {
            individuals: 200,
            tissues: 40,
            genes: 2000,
            programs: 5,
            gene_sparsity: 0.05,
            // Measurement noise sets the achievable relative-error floor
            // (a CP model cannot fit i.i.d. noise); 0.01 puts the floor
            // near the paper's reported 1.4%.
            noise: 0.01,
            seed: 1,
            threads: crate::util::default_threads(),
        }
    }
}

/// Experiment outcome (paper reports relative error + wall-clock).
#[derive(Clone, Debug)]
pub struct GeneReport {
    pub rel_error: f64,
    pub factor_congruence: f64,
    pub decompose_seconds: f64,
    pub dims: [usize; 3],
    pub replicas: usize,
}

/// Builds the synthetic gene tensor source: individual loadings ~ N(0,1),
/// tissue profiles smooth (random walk), gene loadings sparse.
pub fn synthesize(cfg: &GeneConfig) -> LowRankGenerator {
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let r = cfg.programs;
    let individuals = Matrix::random_normal(cfg.individuals, r, &mut rng);
    // Smooth tissue profiles: zero-mean random walks (programs up- and
    // down-regulate across tissues). A common positive offset would make
    // the columns nearly parallel (pairwise cosine > 0.9) and the CP
    // recovery ill-posed — real expression programs are contrastive.
    let mut tissues = Matrix::zeros(cfg.tissues, r);
    for c in 0..r {
        let mut acc = 0.0f32;
        let mut col = Vec::with_capacity(cfg.tissues);
        for _ in 0..cfg.tissues {
            acc += rng.next_gaussian() as f32;
            col.push(acc);
        }
        let mean = col.iter().sum::<f32>() / cfg.tissues as f32;
        for (t, v) in col.into_iter().enumerate() {
            tissues.set(t, c, v - mean);
        }
    }
    tissues.normalize_cols();
    tissues.scale(3.0);
    // Sparse gene loadings.
    let mut genes = Matrix::zeros(cfg.genes, r);
    let nnz = ((cfg.genes as f64 * cfg.gene_sparsity) as usize).max(4);
    for c in 0..r {
        for row in rng.sample_indices(cfg.genes, nnz) {
            genes.set(row, c, rng.next_gaussian() as f32 * 2.0);
        }
    }
    LowRankGenerator::from_factors(individuals, tissues, genes, cfg.seed)
        .with_noise(cfg.noise)
}

/// Runs the compressed decomposition on the synthetic gene tensor.
pub fn run_gene_analysis(cfg: &GeneConfig) -> Result<GeneReport> {
    let gen = synthesize(cfg);
    let dims = TensorSource::dims(&gen);
    let r = cfg.programs;

    // Reduced dims scale with the tensor; tissues mode is small already.
    // The genes mode keeps ratio 10 (not 20): at ratio 20 the stacked
    // recovery sits right at the identifiability bound and the solve is
    // too ill-conditioned for the sparse gene loadings.
    let reduced = [
        (dims[0] / 8).max(r + 3).min(dims[0]),
        (dims[1] / 2).max(r + 3).min(dims[1]),
        (dims[2] / 10).max(r + 3).min(dims[2]),
    ];
    let pcfg = PipelineConfig::builder()
        .reduced_dims(reduced[0], reduced[1], reduced[2])
        .rank(r)
        .block([100, 40, 250])
        .als(120, 1e-10)
        .refine_sweeps(4)
        .threads(cfg.threads)
        .seed(cfg.seed ^ 0x6E6E)
        .build()?;
    let mut pipe = Pipeline::new(pcfg);
    let timer = Timer::start();
    let result = pipe.run(&gen)?;
    let secs = timer.elapsed_s();

    let (a, b, c) = gen.factors.clone();
    let truth = crate::cp::CpModel::new(a, b, c);
    Ok(GeneReport {
        rel_error: result.diagnostics.rel_error,
        factor_congruence: model_congruence(&truth, &result.model),
        decompose_seconds: secs,
        dims,
        replicas: result.plan.replicas,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> GeneConfig {
        GeneConfig {
            individuals: 60,
            tissues: 16,
            genes: 200,
            programs: 3,
            gene_sparsity: 0.1,
            noise: 0.01,
            seed: 2,
            threads: 4,
        }
    }

    #[test]
    fn synthesize_shapes_and_sparsity() {
        let cfg = small_cfg();
        let gen = synthesize(&cfg);
        assert_eq!(TensorSource::dims(&gen), [60, 16, 200]);
        let (_, _, genes) = &gen.factors;
        let nnz = genes.col(0).iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nnz, 20);
    }

    #[test]
    fn recovers_programs_on_small_instance() {
        let report = run_gene_analysis(&small_cfg()).unwrap();
        assert!(report.rel_error < 0.1, "rel error {}", report.rel_error);
        assert!(
            report.factor_congruence > 0.9,
            "congruence {}",
            report.factor_congruence
        );
    }
}
