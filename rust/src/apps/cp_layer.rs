//! CP tensor layer for neural networks — the Table-I experiment.
//!
//! Protocol (mirrors the paper's §V-C, scaled per DESIGN.md):
//!
//! 1. train the CNN on the synthetic image set;
//! 2. view `conv2`'s weights as the 3-way tensor `(out_ch, in_ch, k²)`
//!    [10]'s CP conv factorization, with the two spatial dims grouped;
//! 3. decompose it with one of three backends —
//!    * `Hosvd` direct ALS (Matlab Tensor Toolbox's `'nvecs'` init),
//!    * `Random` direct ALS (TensorLy's default init),
//!    * `Compressed` — **our** Exascale-Tensor pipeline;
//! 4. replace the layer with its rank-R reconstruction, measure accuracy,
//!    fine-tune briefly, measure again.  Report decomposition wall-clock.

use super::nn::{evaluate, train, Dataset, Network, TrainConfig};
use crate::coordinator::{Pipeline, PipelineConfig};
use crate::cp::{als_decompose, AlsOptions, CpModel, InitMethod};
use crate::linalg::Matrix;
use crate::tensor::{DenseTensor, InMemorySource};
use crate::util::stats::Timer;
use anyhow::Result;

/// Which CP backend decomposes the layer (the three Table-I columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpBackend {
    /// Matlab Tensor Toolbox stand-in: ALS with HOSVD init.
    Hosvd,
    /// TensorLy stand-in: ALS with random init.
    Random,
    /// Ours: the compressed Exascale-Tensor pipeline.
    Compressed,
}

impl CpBackend {
    pub fn label(&self) -> &'static str {
        match self {
            CpBackend::Hosvd => "Matlab (hosvd-ALS)",
            CpBackend::Random => "TensorLy (random-ALS)",
            CpBackend::Compressed => "Ours (Exascale-Tensor)",
        }
    }
}

/// One Table-I row.
#[derive(Clone, Debug)]
pub struct CpLayerReport {
    pub backend: &'static str,
    pub accuracy_before: f64,
    pub accuracy_after_decomp: f64,
    pub accuracy_after_finetune: f64,
    pub decomp_seconds: f64,
    pub reconstruction_error: f64,
    pub compression_ratio: f64,
}

/// Views conv weights `(out_ch × in_ch·k²)` as the 3-way tensor
/// `(out_ch, in_ch, k²)` — column-major `DenseTensor`.
pub fn conv_weight_tensor(w: &Matrix, in_ch: usize, k: usize) -> DenseTensor {
    let out_ch = w.rows();
    let kk = k * k;
    assert_eq!(w.cols(), in_ch * kk);
    DenseTensor::from_fn([out_ch, in_ch, kk], |o, c, s| w.get(o, c * kk + s))
}

/// Inverse of [`conv_weight_tensor`].
pub fn tensor_to_conv_weight(t: &DenseTensor) -> Matrix {
    let [out_ch, in_ch, kk] = t.dims();
    Matrix::from_fn(out_ch, in_ch * kk, |o, col| {
        t.get(o, col / kk, col % kk)
    })
}

/// Decomposes `w_tensor` at `rank` with the chosen backend; returns the
/// model and the wall-clock spent in the decomposition.
pub fn decompose_layer(
    w_tensor: &DenseTensor,
    rank: usize,
    backend: CpBackend,
    seed: u64,
) -> Result<(CpModel, f64)> {
    let timer = Timer::start();
    let model = match backend {
        CpBackend::Hosvd | CpBackend::Random => {
            let init = if backend == CpBackend::Hosvd {
                InitMethod::Hosvd
            } else {
                InitMethod::Random
            };
            let (model, _) = als_decompose(
                w_tensor,
                &AlsOptions {
                    rank,
                    max_iters: 300,
                    tol: 1e-10,
                    init,
                    seed,
                    ..Default::default()
                },
            )?;
            model
        }
        CpBackend::Compressed => {
            let dims = w_tensor.dims();
            // Reduced dims: 3/4 of each mode (conv weight tensors are small,
            // so anchors must leave informative rows on every mode).
            let red = |d: usize| ((3 * d) / 4).max(rank + 3).min(d);
            let cfg = PipelineConfig::builder()
                .reduced_dims(red(dims[0]), red(dims[1]), red(dims[2]))
                .rank(rank)
                // anchor rows default: (rank+2) clamped to min reduced dim
                .block([dims[0], dims[1], dims[2]])
                .corner(dims[0].min(dims[1]).min(dims[2]))
                .als(200, 1e-10)
                .seed(seed)
                .build()?;
            let src = InMemorySource::new(w_tensor.clone());
            let mut pipe = Pipeline::new(cfg);
            pipe.run(&src)?.model
        }
    };
    Ok((model, timer.elapsed_s()))
}

/// Full Table-I protocol for one backend.
#[allow(clippy::too_many_arguments)]
pub fn run_cp_layer_experiment(
    net: &mut Network,
    train_ds: &Dataset,
    test_ds: &Dataset,
    rank: usize,
    backend: CpBackend,
    finetune_epochs: usize,
    seed: u64,
) -> Result<CpLayerReport> {
    let accuracy_before = evaluate(net, test_ds);

    let w_tensor = conv_weight_tensor(&net.conv2.weight, net.conv2.in_ch, net.conv2.k);
    let (model, decomp_seconds) = decompose_layer(&w_tensor, rank, backend, seed)?;
    let recon = model.to_tensor();
    let reconstruction_error = recon.rel_error(&w_tensor);

    // Replace the layer with the rank-R reconstruction.
    net.conv2.weight = tensor_to_conv_weight(&recon);
    let accuracy_after_decomp = evaluate(net, test_ds);

    // Brief fine-tune (whole network; the paper fine-tunes end-to-end).
    train(
        net,
        train_ds,
        &TrainConfig {
            epochs: finetune_epochs,
            lr: 0.005,
            seed: seed ^ 0xF1,
        },
    );
    let accuracy_after_finetune = evaluate(net, test_ds);

    let dims = w_tensor.dims();
    let dense_params = (dims[0] * dims[1] * dims[2]) as f64;
    let cp_params = (rank * (dims[0] + dims[1] + dims[2])) as f64;

    Ok(CpLayerReport {
        backend: backend.label(),
        accuracy_before,
        accuracy_after_decomp,
        accuracy_after_finetune,
        decomp_seconds,
        reconstruction_error,
        compression_ratio: dense_params / cp_params,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::nn::SyntheticImages;

    #[test]
    fn weight_tensor_round_trip() {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(30);
        let w = Matrix::random_normal(8, 4 * 9, &mut rng);
        let t = conv_weight_tensor(&w, 4, 3);
        assert_eq!(t.dims(), [8, 4, 9]);
        assert_eq!(tensor_to_conv_weight(&t), w);
    }

    #[test]
    fn decompose_layer_all_backends_small() {
        // Low-rank planted weights: every backend should reconstruct well.
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(31);
        let a = Matrix::random_normal(16, 3, &mut rng);
        let b = Matrix::random_normal(8, 3, &mut rng);
        let c = Matrix::random_normal(9, 3, &mut rng);
        let w = DenseTensor::from_cp_factors(&a, &b, &c);
        for backend in [CpBackend::Hosvd, CpBackend::Random, CpBackend::Compressed] {
            let (model, secs) = decompose_layer(&w, 3, backend, 5).unwrap();
            let err = model.to_tensor().rel_error(&w);
            assert!(err < 0.05, "{backend:?}: err {err}");
            assert!(secs >= 0.0);
        }
    }

    #[test]
    #[ignore] // several seconds: full protocol exercised by the bench/example
    fn full_protocol_smoke() {
        let gen = SyntheticImages::default();
        let train_ds = gen.generate(120, 1);
        let test_ds = gen.generate(45, 2);
        let mut net = Network::new(18, 4, 16, 24, 3, 42);
        train(&mut net, &train_ds, &TrainConfig::default());
        let report = run_cp_layer_experiment(
            &mut net,
            &train_ds,
            &test_ds,
            6,
            CpBackend::Random,
            1,
            7,
        )
        .unwrap();
        assert!(report.accuracy_before > 0.8);
        assert!(report.accuracy_after_finetune >= report.accuracy_after_decomp - 0.1);
    }
}
