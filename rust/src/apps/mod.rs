//! Tensor-learning applications from §V-C of the paper: the CP tensor
//! layer for neural networks (Table I) and gene-expression analysis.

pub mod cp_layer;
pub mod gene;
pub mod nn;

pub use cp_layer::{run_cp_layer_experiment, CpBackend, CpLayerReport};
pub use gene::{run_gene_analysis, GeneConfig, GeneReport};
