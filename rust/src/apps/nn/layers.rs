//! CNN layers over `linalg::Matrix`: im2col conv, ReLU, max-pool, dense.
//!
//! The network processes one image at a time (batch = 1) — at 16×16 that
//! is plenty fast and keeps the backward passes simple and auditable.
//! The matrix work (im2col GEMMs, dense mat-vecs) dispatches through a
//! [`ComputeBackend`] handle held by [`Network`] — serial by default,
//! swappable via [`Network::with_backend`] for larger geometries.

use crate::linalg::backend::{serial_backend, BackendHandle, ComputeBackend};
use crate::linalg::{Matrix, Trans};
use crate::util::rng::Xoshiro256;

/// A 2-D convolution (valid padding, stride 1) via im2col.
///
/// Weights: `(out_ch, in_ch·kh·kw)` matrix; the CP-layer experiment views
/// it as the 3-way tensor `(out_ch, in_ch, kh·kw)`.
pub struct Conv2d {
    pub weight: Matrix, // out_ch × (in_ch·kh·kw)
    pub bias: Vec<f32>,
    pub in_ch: usize,
    pub out_ch: usize,
    pub k: usize,
    // cached for backward
    cols: Matrix,
    in_side: usize,
}

impl Conv2d {
    pub fn new(in_ch: usize, out_ch: usize, k: usize, rng: &mut Xoshiro256) -> Self {
        let fan_in = (in_ch * k * k) as f32;
        let mut weight = Matrix::random_normal(out_ch, in_ch * k * k, rng);
        weight.scale((2.0 / fan_in).sqrt()); // He init
        Self {
            weight,
            bias: vec![0.0; out_ch],
            in_ch,
            out_ch,
            k,
            cols: Matrix::zeros(0, 0),
            in_side: 0,
        }
    }

    pub fn out_side(&self, in_side: usize) -> usize {
        in_side - self.k + 1
    }

    /// im2col: column `p` holds the receptive field of output pixel `p`.
    fn im2col(&self, x: &[f32], in_side: usize) -> Matrix {
        let out_side = self.out_side(in_side);
        let krows = self.in_ch * self.k * self.k;
        let mut cols = Matrix::zeros(krows, out_side * out_side);
        for oy in 0..out_side {
            for ox in 0..out_side {
                let p = oy * out_side + ox;
                let mut rr = 0;
                for ch in 0..self.in_ch {
                    let plane = &x[ch * in_side * in_side..(ch + 1) * in_side * in_side];
                    for ky in 0..self.k {
                        for kx in 0..self.k {
                            cols.set(rr, p, plane[(oy + ky) * in_side + (ox + kx)]);
                            rr += 1;
                        }
                    }
                }
            }
        }
        cols
    }

    /// Forward: input `(in_ch · side²)` planes → `(out_ch · out²)` planes.
    pub fn forward(&mut self, x: &[f32], in_side: usize, be: &dyn ComputeBackend) -> Vec<f32> {
        let out_side = self.out_side(in_side);
        self.cols = self.im2col(x, in_side);
        self.in_side = in_side;
        let y = be.matmul(&self.weight, Trans::No, &self.cols, Trans::No);
        let mut out = vec![0.0f32; self.out_ch * out_side * out_side];
        for ch in 0..self.out_ch {
            for p in 0..out_side * out_side {
                out[ch * out_side * out_side + p] = y.get(ch, p) + self.bias[ch];
            }
        }
        out
    }

    /// Backward: given `dy` (out_ch · out²), updates weights with SGD and
    /// returns `dx` (in_ch · side²).
    pub fn backward(&mut self, dy: &[f32], lr: f32, be: &dyn ComputeBackend) -> Vec<f32> {
        let out_side = self.out_side(self.in_side);
        let np = out_side * out_side;
        let dy_m = Matrix::from_fn(self.out_ch, np, |ch, p| dy[ch * np + p]);
        // dW = dY · colsᵀ ; dcols = Wᵀ · dY
        let dw = be.matmul(&dy_m, Trans::No, &self.cols, Trans::Yes);
        let dcols = be.matmul(&self.weight, Trans::Yes, &dy_m, Trans::No);
        // col2im scatter
        let in_side = self.in_side;
        let mut dx = vec![0.0f32; self.in_ch * in_side * in_side];
        for oy in 0..out_side {
            for ox in 0..out_side {
                let p = oy * out_side + ox;
                let mut rr = 0;
                for ch in 0..self.in_ch {
                    for ky in 0..self.k {
                        for kx in 0..self.k {
                            dx[ch * in_side * in_side + (oy + ky) * in_side + (ox + kx)] +=
                                dcols.get(rr, p);
                            rr += 1;
                        }
                    }
                }
            }
        }
        // SGD update
        for ch in 0..self.out_ch {
            let mut db = 0.0;
            for p in 0..np {
                db += dy_m.get(ch, p);
            }
            self.bias[ch] -= lr * db;
        }
        for j in 0..self.weight.cols() {
            for i in 0..self.weight.rows() {
                let v = self.weight.get(i, j) - lr * dw.get(i, j);
                self.weight.set(i, j, v);
            }
        }
        dx
    }
}

/// Fully-connected layer.
pub struct Dense {
    pub weight: Matrix, // out × in
    pub bias: Vec<f32>,
    input: Vec<f32>,
}

impl Dense {
    pub fn new(inputs: usize, outputs: usize, rng: &mut Xoshiro256) -> Self {
        let mut weight = Matrix::random_normal(outputs, inputs, rng);
        weight.scale((2.0 / inputs as f32).sqrt());
        Self {
            weight,
            bias: vec![0.0; outputs],
            input: Vec::new(),
        }
    }

    pub fn forward(&mut self, x: &[f32], be: &dyn ComputeBackend) -> Vec<f32> {
        self.input = x.to_vec();
        let mut y = be.matvec(&self.weight, Trans::No, x);
        for (o, b) in y.iter_mut().zip(&self.bias) {
            *o += b;
        }
        y
    }

    pub fn backward(&mut self, dy: &[f32], lr: f32, be: &dyn ComputeBackend) -> Vec<f32> {
        let dx = be.matvec(&self.weight, Trans::Yes, dy);
        for (i, &g) in dy.iter().enumerate() {
            self.bias[i] -= lr * g;
            for (j, &xj) in self.input.iter().enumerate() {
                let v = self.weight.get(i, j) - lr * g * xj;
                self.weight.set(i, j, v);
            }
        }
        dx
    }
}

/// ReLU with mask caching.
#[derive(Default)]
pub struct Relu {
    mask: Vec<bool>,
}

impl Relu {
    pub fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        self.mask = x.iter().map(|&v| v > 0.0).collect();
        x.iter().map(|&v| v.max(0.0)).collect()
    }

    pub fn backward(&self, dy: &[f32]) -> Vec<f32> {
        dy.iter()
            .zip(&self.mask)
            .map(|(&g, &m)| if m { g } else { 0.0 })
            .collect()
    }
}

/// 2×2 max-pool with argmax caching. Input per-channel planes.
#[derive(Default)]
pub struct MaxPool2 {
    arg: Vec<usize>,
    in_len: usize,
}

impl MaxPool2 {
    pub fn forward(&mut self, x: &[f32], channels: usize, side: usize) -> Vec<f32> {
        let half = side / 2;
        let mut out = vec![0.0f32; channels * half * half];
        self.arg = vec![0; channels * half * half];
        self.in_len = x.len();
        for ch in 0..channels {
            let plane = &x[ch * side * side..(ch + 1) * side * side];
            for py in 0..half {
                for px in 0..half {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let idx = (2 * py + dy) * side + 2 * px + dx;
                            if plane[idx] > best {
                                best = plane[idx];
                                best_idx = idx;
                            }
                        }
                    }
                    let o = ch * half * half + py * half + px;
                    out[o] = best;
                    self.arg[o] = ch * side * side + best_idx;
                }
            }
        }
        out
    }

    pub fn backward(&self, dy: &[f32]) -> Vec<f32> {
        let mut dx = vec![0.0f32; self.in_len];
        for (o, &g) in dy.iter().enumerate() {
            dx[self.arg[o]] += g;
        }
        dx
    }
}

/// The Table-I CNN: conv(1→C1,3) → relu → pool → conv(C1→C2,3) → relu →
/// pool → dense → relu → dense(3).
pub struct Network {
    pub conv1: Conv2d,
    pub conv2: Conv2d,
    relu1: Relu,
    relu2: Relu,
    relu3: Relu,
    pool1: MaxPool2,
    pool2: MaxPool2,
    pub fc1: Dense,
    pub fc2: Dense,
    pub side: usize,
    /// Kernel dispatch for every layer; serial by default (the Table-I
    /// geometry is small), swappable via [`Network::with_backend`].
    backend: BackendHandle,
}

impl Network {
    /// Geometry: side → side−2 (conv3) → /2 (pool) → −2 (conv3) → /2
    /// (pool); all intermediate sides must be even, which requires
    /// `side ≡ 2 (mod 4)` — e.g. 18 → 16 → 8 → 6 → 3.
    pub fn new(side: usize, c1: usize, c2: usize, hidden: usize, classes: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        // geometry: side -conv3-> s1 -pool-> s1/2 -conv3-> s2 -pool-> s2/2
        let s1 = side - 2;
        assert!(s1 % 2 == 0, "side-2 must be even");
        let s1p = s1 / 2;
        let s2 = s1p - 2;
        assert!(s2 % 2 == 0, "pooled conv2 side must be even, got {s2}");
        let s2p = s2 / 2;
        let conv1 = Conv2d::new(1, c1, 3, &mut rng);
        let conv2 = Conv2d::new(c1, c2, 3, &mut rng);
        let fc1 = Dense::new(c2 * s2p * s2p, hidden, &mut rng);
        let fc2 = Dense::new(hidden, classes, &mut rng);
        Self {
            conv1,
            conv2,
            relu1: Relu::default(),
            relu2: Relu::default(),
            relu3: Relu::default(),
            pool1: MaxPool2::default(),
            pool2: MaxPool2::default(),
            fc1,
            fc2,
            side,
            backend: serial_backend(),
        }
    }

    /// Swaps the kernel dispatch backend for every layer.
    pub fn with_backend(mut self, backend: BackendHandle) -> Self {
        self.backend = backend;
        self
    }

    /// Forward to logits.
    pub fn forward(&mut self, img: &[f32]) -> Vec<f32> {
        let be = self.backend.clone();
        let be: &dyn ComputeBackend = &*be;
        let side = self.side;
        let s1 = side - 2;
        let x = self.conv1.forward(img, side, be);
        let x = self.relu1.forward(&x);
        let x = self.pool1.forward(&x, self.conv1.out_ch, s1);
        let s1p = s1 / 2;
        let x = self.conv2.forward(&x, s1p, be);
        let x = self.relu2.forward(&x);
        let s2 = s1p - 2;
        let x = self.pool2.forward(&x, self.conv2.out_ch, s2);
        let x = self.fc1.forward(&x, be);
        let x = self.relu3.forward(&x);
        self.fc2.forward(&x, be)
    }

    /// One SGD step on (img, label) with softmax cross-entropy.
    /// Returns the loss.
    pub fn train_step(&mut self, img: &[f32], label: usize, lr: f32) -> f32 {
        let logits = self.forward(img);
        let be = self.backend.clone();
        let be: &dyn ComputeBackend = &*be;
        let (loss, mut grad) = softmax_xent(&logits, label);
        grad = self.fc2.backward(&grad, lr, be);
        grad = self.relu3.backward(&grad);
        grad = self.fc1.backward(&grad, lr, be);
        grad = self.pool2.backward(&grad);
        grad = self.relu2.backward(&grad);
        grad = self.conv2.backward(&grad, lr, be);
        grad = self.pool1.backward(&grad);
        grad = self.relu1.backward(&grad);
        let _ = self.conv1.backward(&grad, lr, be);
        loss
    }

    pub fn predict(&mut self, img: &[f32]) -> usize {
        let logits = self.forward(img);
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Softmax cross-entropy: returns (loss, dlogits).
pub fn softmax_xent(logits: &[f32], label: usize) -> (f32, Vec<f32>) {
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let exps: Vec<f32> = logits.iter().map(|&x| (x - max).exp()).collect();
    let z: f32 = exps.iter().sum();
    let probs: Vec<f32> = exps.iter().map(|&e| e / z).collect();
    let loss = -probs[label].max(1e-12).ln();
    let grad = probs
        .iter()
        .enumerate()
        .map(|(i, &p)| p - if i == label { 1.0 } else { 0.0 })
        .collect();
    (loss, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::backend::SerialBackend;

    #[test]
    fn conv_identity_kernel_preserves_center() {
        let mut rng = Xoshiro256::seed_from_u64(20);
        let mut conv = Conv2d::new(1, 1, 3, &mut rng);
        // delta kernel at center
        for j in 0..9 {
            conv.weight.set(0, j, if j == 4 { 1.0 } else { 0.0 });
        }
        let img: Vec<f32> = (0..36).map(|i| i as f32).collect(); // 6×6
        let out = conv.forward(&img, 6, &SerialBackend);
        // out[p] = center pixel of field = img[(oy+1)*6 + ox+1]
        assert_eq!(out.len(), 16);
        assert_eq!(out[0], img[7]);
        assert_eq!(out[15], img[28]);
    }

    #[test]
    fn softmax_xent_gradient_checks() {
        let logits = vec![0.3f32, -0.7, 1.1];
        let (loss, grad) = softmax_xent(&logits, 2);
        assert!(loss > 0.0);
        // grad sums to 0 and is prob-1 at the label
        let s: f32 = grad.iter().sum();
        assert!(s.abs() < 1e-6);
        assert!(grad[2] < 0.0);
        // numeric check
        let eps = 1e-3;
        for i in 0..3 {
            let mut lp = logits.clone();
            lp[i] += eps;
            let (l2, _) = softmax_xent(&lp, 2);
            let num = (l2 - loss) / eps;
            assert!((num - grad[i]).abs() < 1e-2, "i={i} num={num} ana={}", grad[i]);
        }
    }

    #[test]
    fn maxpool_backward_routes_to_argmax() {
        let mut pool = MaxPool2::default();
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2×2 single channel
        let y = pool.forward(&x, 1, 2);
        assert_eq!(y, vec![4.0]);
        let dx = pool.backward(&[5.0]);
        assert_eq!(dx, vec![0.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn dense_backward_reduces_loss() {
        let mut rng = Xoshiro256::seed_from_u64(21);
        let mut d = Dense::new(4, 2, &mut rng);
        let x = vec![0.5, -1.0, 0.25, 2.0];
        for _ in 0..50 {
            let y = d.forward(&x, &SerialBackend);
            let (_, g) = softmax_xent(&y, 0);
            d.backward(&g, 0.1, &SerialBackend);
        }
        let y = d.forward(&x, &SerialBackend);
        assert!(y[0] > y[1], "did not learn: {y:?}");
    }

    #[test]
    fn conv_gradient_reduces_loss_single_pixel_task() {
        let mut rng = Xoshiro256::seed_from_u64(22);
        let mut conv = Conv2d::new(1, 2, 3, &mut rng);
        let img: Vec<f32> = (0..25).map(|i| (i % 5) as f32 / 5.0).collect();
        // learn to make channel 0 output sum big, channel 1 small
        for _ in 0..60 {
            let out = conv.forward(&img, 5, &SerialBackend);
            let np = 9;
            let mut dy = vec![0.0f32; 2 * np];
            for p in 0..np {
                dy[p] = -1.0; // increase ch0
                dy[np + p] = 1.0; // decrease ch1
            }
            conv.backward(&dy, 0.01, &SerialBackend);
        }
        let out = conv.forward(&img, 5, &SerialBackend);
        let s0: f32 = out[..9].iter().sum();
        let s1: f32 = out[9..].iter().sum();
        assert!(s0 > s1, "s0={s0} s1={s1}");
    }

    #[test]
    fn network_shapes_and_forward() {
        let mut net = Network::new(18, 4, 8, 16, 3, 23);
        let img = vec![0.1f32; 324];
        let logits = net.forward(&img);
        assert_eq!(logits.len(), 3);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}
