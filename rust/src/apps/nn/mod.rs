//! Tiny neural-network substrate for the CP-tensor-layer experiment
//! (Table I).
//!
//! The paper compresses ResNet-34 on CIFAR-10; we scale to a 2-conv-layer
//! CNN on a synthetic 16×16 3-class image set (DESIGN.md "Substitutions")
//! — small enough to train in seconds in pure rust, big enough that its
//! second conv layer's weight tensor `(64, 16, 3×3)` is a meaningful CP
//! compression target.
//!
//! Everything is implemented against the crate's `linalg::Matrix`:
//! im2col convolution, ReLU, 2×2 max-pool, dense layers, softmax
//! cross-entropy, and plain SGD.

pub mod data;
pub mod layers;
pub mod train;

pub use data::{Dataset, SyntheticImages};
pub use layers::{Conv2d, Dense, Network};
pub use train::{evaluate, train, TrainConfig, TrainReport};
