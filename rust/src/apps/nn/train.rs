//! Training loop + evaluation for the Table-I CNN.

use super::data::Dataset;
use super::layers::Network;
use crate::util::rng::Xoshiro256;

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 3,
            lr: 0.01,
            seed: 0,
        }
    }
}

/// Per-epoch record.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub epoch_losses: Vec<f32>,
    pub final_train_accuracy: f64,
}

/// Trains `net` on `ds` with shuffled single-sample SGD.
pub fn train(net: &mut Network, ds: &Dataset, cfg: &TrainConfig) -> TrainReport {
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let mut order: Vec<usize> = (0..ds.len()).collect();
    let mut report = TrainReport::default();
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0f32;
        for &i in &order {
            loss_sum += net.train_step(&ds.images[i], ds.labels[i], cfg.lr);
        }
        report.epoch_losses.push(loss_sum / ds.len() as f32);
    }
    report.final_train_accuracy = evaluate(net, ds);
    report
}

/// Classification accuracy on a dataset.
pub fn evaluate(net: &mut Network, ds: &Dataset) -> f64 {
    let mut correct = 0usize;
    for (img, &label) in ds.images.iter().zip(&ds.labels) {
        if net.predict(img) == label {
            correct += 1;
        }
    }
    correct as f64 / ds.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::nn::data::SyntheticImages;

    #[test]
    fn learns_the_synthetic_task() {
        let gen = SyntheticImages::default();
        let train_ds = gen.generate(180, 1);
        let test_ds = gen.generate(60, 2);
        let mut net = Network::new(18, 4, 8, 24, 3, 42);
        let report = train(
            &mut net,
            &train_ds,
            &TrainConfig {
                epochs: 3,
                lr: 0.01,
                seed: 3,
            },
        );
        // Loss should drop across epochs.
        assert!(
            report.epoch_losses.last().unwrap() < report.epoch_losses.first().unwrap(),
            "losses {:?}",
            report.epoch_losses
        );
        let acc = evaluate(&mut net, &test_ds);
        assert!(acc > 0.85, "test accuracy {acc}");
    }
}
