//! Synthetic 3-class image dataset (CIFAR stand-in, see DESIGN.md).
//!
//! Classes are oriented-texture patterns with random phase, frequency and
//! additive noise, so they are linearly non-trivial but learnable by a
//! small CNN in a few epochs:
//!
//! * class 0 — horizontal stripes
//! * class 1 — vertical stripes
//! * class 2 — checkerboard

use crate::util::rng::Xoshiro256;

/// A labelled image set. Images are `side × side`, single channel,
/// stored row-major per image.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub side: usize,
    pub images: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

/// Generator for the synthetic image set.
pub struct SyntheticImages {
    pub side: usize,
    pub noise: f32,
}

impl Default for SyntheticImages {
    fn default() -> Self {
        Self {
            side: 18, // Network geometry needs side ≡ 2 (mod 4)
            noise: 0.3,
        }
    }
}

impl SyntheticImages {
    /// Generates `n` images with balanced random classes.
    pub fn generate(&self, n: usize, seed: u64) -> Dataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let side = self.side;
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for idx in 0..n {
            let class = idx % 3;
            let freq = 1 + rng.next_below(2) as usize; // stripe width 1–2
            let phase = rng.next_below(4) as usize;
            let flip = if rng.next_f32() < 0.5 { 1.0 } else { -1.0 };
            let mut img = vec![0.0f32; side * side];
            for r in 0..side {
                for c in 0..side {
                    let v = match class {
                        0 => stripe(r + phase, freq),
                        1 => stripe(c + phase, freq),
                        _ => stripe(r + phase, freq) * stripe(c + phase, freq),
                    };
                    img[r * side + c] =
                        flip * v + self.noise * rng.next_gaussian() as f32;
                }
            }
            images.push(img);
            labels.push(class);
        }
        Dataset {
            side,
            images,
            labels,
            num_classes: 3,
        }
    }
}

#[inline]
fn stripe(x: usize, freq: usize) -> f32 {
    if (x / freq) % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_balanced_labels() {
        let ds = SyntheticImages::default().generate(99, 1);
        assert_eq!(ds.len(), 99);
        for c in 0..3 {
            let count = ds.labels.iter().filter(|&&l| l == c).count();
            assert_eq!(count, 33);
        }
    }

    #[test]
    fn images_have_unit_scale() {
        let ds = SyntheticImages::default().generate(30, 2);
        for img in &ds.images {
            assert_eq!(img.len(), 324);
            let max = img.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            assert!(max > 0.5 && max < 4.0, "max {max}");
        }
    }

    #[test]
    fn classes_are_distinguishable_by_template() {
        // Mean row-autocorrelation differs between stripes orientations —
        // cheap sanity that the classes carry signal.
        let ds = SyntheticImages {
            side: 16,
            noise: 0.0,
        }
        .generate(30, 3);
        for (img, &label) in ds.images.iter().zip(&ds.labels) {
            let mut row_var = 0.0f32; // variance along rows (vertical stripes → high)
            let mut col_var = 0.0f32;
            for r in 0..16 {
                let row: Vec<f32> = (0..16).map(|c| img[r * 16 + c]).collect();
                row_var += variance(&row);
                let col: Vec<f32> = (0..16).map(|c| img[c * 16 + r]).collect();
                col_var += variance(&col);
            }
            match label {
                0 => assert!(col_var > row_var, "horizontal stripes: {col_var} {row_var}"),
                1 => assert!(row_var > col_var, "vertical stripes"),
                _ => {}
            }
        }
    }

    fn variance(xs: &[f32]) -> f32 {
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SyntheticImages::default().generate(5, 7);
        let b = SyntheticImages::default().generate(5, 7);
        assert_eq!(a.images[3], b.images[3]);
    }
}
