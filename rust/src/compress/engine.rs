//! Out-of-core streaming engine: the producer/consumer block scheduler
//! behind every `compress_source*` entry point.
//!
//! The block grid is split into **shards** — contiguous runs of block
//! indices whose count depends only on the grid (never on thread counts) —
//! and every shard's contributions are accumulated in block-index order
//! into a shard-local accumulator, then folded into the global result in
//! shard-index order.  That fixed reduction tree makes the result **bitwise
//! identical** across compute-thread counts, I/O-thread counts, prefetch
//! depths, and sync-vs-prefetched execution, and it gives incremental
//! checkpointing a well-defined unit: the folded prefix of shards.
//!
//! Two execution modes share that reduction:
//!
//! * **Synchronous** (`prefetch: None`) — workers claim whole shards and
//!   read each block inline ([`TensorSource::block`]) before processing it.
//!   Zero queueing overhead; right for in-memory/implicit sources.
//! * **Prefetched** (`prefetch: Some`) — dedicated I/O producer threads
//!   stage upcoming blocks into a bounded queue
//!   ([`std::sync::mpsc::sync_channel`], double-buffering generalized to
//!   `depth` slots) while compute workers drain it; block reads overlap
//!   with the TTM chains, which is where file-backed sources win.  An
//!   ordered-commit step per shard (late blocks park in a small pending
//!   list) preserves the deterministic reduction.  Producers commit reads
//!   through a claim-order reorder buffer and claims are gated on a
//!   live-block budget, so at most `depth + io_threads + threads` blocks
//!   are ever resident at once — the exact bound the memory planner
//!   prices ([`StreamStats::max_live_blocks`] witnesses it).
//!
//! Stall time on both sides of the queue is counted ([`StreamStats`]) and
//! surfaced through `coordinator::metrics` by the pipeline.

use crate::tensor::{BlockRange, DenseTensor, TensorSource};
use crate::util::threadpool::ThreadPool;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Default shard count the block grid is partitioned into.  A constant —
/// NOT derived from the worker count — so the reduction tree (and thus the
/// bitwise result) is invariant across thread configurations, while still
/// exceeding any realistic pool size for load balancing.
pub const DEFAULT_SHARD_PARTS: usize = 32;

/// Prefetch policy for the staged I/O pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefetchConfig {
    /// Bounded-queue capacity in blocks (≥ 1): how far I/O may run ahead
    /// of compute.  The memory planner budgets `depth × block bytes`.
    pub depth: usize,
    /// Dedicated I/O producer threads.
    pub io_threads: usize,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        Self { depth: 4, io_threads: 2 }
    }
}

/// Execution options for [`stream_blocks`].
#[derive(Clone, Debug)]
pub struct StreamOptions {
    /// Compute worker threads.
    pub threads: usize,
    /// `None` → synchronous reads inside compute workers.
    pub prefetch: Option<PrefetchConfig>,
    /// Shard partition granularity (see [`DEFAULT_SHARD_PARTS`]).  Changing
    /// this changes the reduction tree, so checkpoints record it.
    pub shard_parts: usize,
}

impl Default for StreamOptions {
    fn default() -> Self {
        Self {
            threads: crate::util::default_threads(),
            prefetch: None,
            shard_parts: DEFAULT_SHARD_PARTS,
        }
    }
}

/// Counters from one streaming pass.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    /// Blocks actually read this pass (excludes resumed prefix).
    pub blocks_read: u64,
    /// Shards in the partition.
    pub shards: usize,
    /// Total time spent inside `TensorSource::block` (across threads).
    pub io_seconds: f64,
    /// Compute-side stall: time workers spent blocked in `recv` on an
    /// empty queue (prefetched mode only; includes the tail wait for the
    /// channel to close, excludes receiver-lock contention).
    pub io_stall_seconds: f64,
    /// Producer-side stall: time I/O threads blocked on the full queue
    /// (prefetched mode only; high values mean I/O is ahead of compute).
    pub send_stall_seconds: f64,
    /// Blocks skipped because a resumed checkpoint already covered them.
    pub resumed_blocks: u64,
    /// The progress callback requested an early stop.
    pub aborted: bool,
    /// Whether the prefetched pipeline ran.
    pub prefetched: bool,
    /// A source read failed irrecoverably (panic in `TensorSource::block`,
    /// e.g. an exhausted retry budget): the pass stopped early with the
    /// message recorded here.  The returned accumulator is still the
    /// intact folded prefix of [`StreamStats::shards_done`] shards, so the
    /// caller can checkpoint it before surfacing the failure.
    pub failure: Option<String>,
    /// Shards folded into the returned accumulator (== `shards` on a
    /// complete pass).
    pub shards_done: usize,
    /// Blocks covered by the folded prefix (includes the resumed prefix).
    pub blocks_done: u64,
    /// Peak number of blocks simultaneously live (claimed by a producer
    /// but not yet processed by a consumer) in prefetched mode — the
    /// live-block budget guarantees this never exceeds
    /// `depth + io_threads + threads`, which is exactly what the memory
    /// planner prices.  0 in synchronous mode (reads are inline, so at
    /// most one block per worker is ever live).
    pub max_live_blocks: usize,
}

/// A resumable prefix: the first `shards_done` shards' contributions are
/// already folded into `acc` (from an incremental checkpoint).
pub struct ResumeState<A> {
    pub shards_done: usize,
    pub blocks_done: usize,
    pub acc: A,
}

/// Incremental-progress callback: invoked (serialized, in prefix order)
/// whenever the folded shard prefix advances, with the partial accumulator,
/// folded shard count, and folded block count.  Returning `false` stops the
/// pass early — the engine then returns the folded prefix with
/// `stats.aborted = true` (the kill/resume test hook).
pub type ProgressFn<'a, A> = &'a (dyn Fn(&A, usize, usize) -> bool + Sync);

/// How one streaming pass consumes blocks into an accumulator.
///
/// `process` is called exactly once per block, **in block-index order
/// within each shard**, against that shard's private accumulator — the
/// engine guarantees this in both execution modes, which is what makes
/// results reproducible.  `Ctx` is per-worker scratch (pack buffers, GEMM
/// workspaces) that survives across blocks.
pub trait BlockConsumer: Sync {
    type Acc: Send;
    type Ctx;

    fn make_ctx(&self) -> Self::Ctx;
    fn zero_acc(&self) -> Self::Acc;
    fn process(&self, ctx: &mut Self::Ctx, blk: &BlockRange, t: DenseTensor, acc: &mut Self::Acc);
    /// Folds a completed shard accumulator into the running result.
    /// Called in strict shard-index order.
    fn merge(&self, into: &mut Self::Acc, from: Self::Acc);
}

/// Renders a caught panic payload as the failure message recorded in
/// [`StreamStats::failure`] (sources signal irrecoverable reads by
/// panicking with a formatted message — see `FileTensorSource::block`).
fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "source read panicked".to_string()
    }
}

/// In-order prefix folder over completed shards.
struct Folder<A> {
    next: usize,
    blocks_done: usize,
    parked: Vec<Option<A>>,
    acc: A,
}

/// Per-shard ordered-commit state for the prefetched mode.
///
/// The lock guarding this is held only for the cheap operations below —
/// claiming ownership, parking a block, handing the next parked block to
/// the owner.  The expensive `process` call runs **outside** the lock:
/// exactly one consumer owns a shard at a time (`busy`), so per-shard
/// ordering is preserved while different shards compute in parallel.
struct ShardState<A> {
    next_pos: usize,
    end: usize,
    acc: Option<A>,
    /// A consumer is currently processing this shard's in-order run.
    busy: bool,
    /// Blocks that arrived before their turn.  The reorder buffer delivers
    /// reads in claim order (ascending block order within a shard), so a
    /// block only parks here while another consumer owns the shard, and
    /// the total parked anywhere is capped by the live-block budget.
    pending: Vec<(usize, DenseTensor)>,
}

/// Live-block accounting for the prefetched mode's claim gate: a block is
/// "live" from position claim until a consumer finishes processing it
/// (being read, parked in the reorder buffer, queued in the channel,
/// parked in a shard's pending list, or in a worker's hands).  Claims
/// wait while `live` is at the cap, making the planner's
/// `depth + io_threads + threads` block budget an exact bound.
struct ClaimState {
    /// Next claim ticket (the reorder buffer sends in ticket order).
    seq: usize,
    live: usize,
    peak: usize,
}

/// In-claim-order send commit: producer reads finish out of order, so
/// completed reads park here keyed by their claim ticket and are released
/// into the channel strictly by ticket.  `draining` marks the one producer
/// currently sending (the channel send blocks on backpressure and must run
/// outside this lock).
struct Reorder {
    next_send: usize,
    parked: BTreeMap<usize, (usize, DenseTensor)>,
    draining: bool,
}

/// Streams `blocks` from `src` through `consumer`, returning the folded
/// accumulator and this pass's counters.  See the module docs for the
/// execution modes and determinism guarantees.
pub fn stream_blocks<C: BlockConsumer>(
    src: &dyn TensorSource,
    blocks: &[BlockRange],
    opts: &StreamOptions,
    consumer: &C,
    resume: Option<ResumeState<C::Acc>>,
    on_progress: Option<ProgressFn<'_, C::Acc>>,
) -> (C::Acc, StreamStats) {
    let shards = ThreadPool::partition(blocks.len(), opts.shard_parts.max(1));
    let nshards = shards.len();
    let (resume_shards, resume_blocks, acc0) = match resume {
        Some(r) => {
            assert!(
                r.shards_done <= nshards,
                "resume prefix {} exceeds shard count {nshards}",
                r.shards_done
            );
            (r.shards_done, r.blocks_done, r.acc)
        }
        None => (0, 0, consumer.zero_acc()),
    };
    let mut stats = StreamStats {
        shards: nshards,
        resumed_blocks: resume_blocks as u64,
        prefetched: opts.prefetch.is_some(),
        ..Default::default()
    };
    if blocks.is_empty() || resume_shards >= nshards {
        stats.shards_done = resume_shards.min(nshards);
        stats.blocks_done = resume_blocks as u64;
        return (acc0, stats);
    }
    debug_assert_eq!(
        resume_blocks,
        shards[..resume_shards].iter().map(|(a, b)| b - a).sum::<usize>(),
        "resume block count does not match the shard prefix"
    );

    let folder = Mutex::new(Folder {
        next: resume_shards,
        blocks_done: resume_blocks,
        parked: (0..nshards).map(|_| None).collect(),
        acc: acc0,
    });
    let fold_advanced = std::sync::Condvar::new();
    // Prefetched-mode live-block budget (unused in sync mode).
    let claim = Mutex::new(ClaimState { seq: 0, live: 0, peak: 0 });
    let claim_freed = std::sync::Condvar::new();
    let stop = AtomicBool::new(false);
    let failure: Mutex<Option<String>> = Mutex::new(None);
    // First source-read panic wins; later ones (other threads hitting the
    // same dying source) are dropped.  Sets `stop` and wakes any worker
    // throttled on the fold-prefix window so the pass winds down.
    let record_failure = |p: Box<dyn std::any::Any + Send>| {
        let msg = panic_message(p);
        let mut slot = failure.lock().unwrap();
        if slot.is_none() {
            *slot = Some(msg);
        }
        drop(slot);
        stop.store(true, Ordering::SeqCst);
        let _wake = folder.lock().unwrap();
        fold_advanced.notify_all();
    };
    let io_ns = AtomicU64::new(0);
    let recv_stall_ns = AtomicU64::new(0);
    let send_stall_ns = AtomicU64::new(0);
    let blocks_read = AtomicU64::new(0);

    // Folds `acc_s` (shard `s`, complete) and any now-contiguous parked
    // shards into the prefix, firing the progress callback on advance.
    let complete_shard = |s: usize, acc_s: C::Acc| {
        let mut f = folder.lock().unwrap();
        f.parked[s] = Some(acc_s);
        let mut advanced = false;
        while f.next < nshards {
            let idx = f.next;
            let Some(a) = f.parked[idx].take() else { break };
            consumer.merge(&mut f.acc, a);
            let (b0, b1) = shards[idx];
            f.blocks_done += b1 - b0;
            f.next += 1;
            advanced = true;
        }
        if advanced {
            if let Some(cb) = on_progress {
                if !cb(&f.acc, f.next, f.blocks_done) {
                    stop.store(true, Ordering::SeqCst);
                }
            }
            // Wake workers throttled on the fold-prefix window.
            fold_advanced.notify_all();
        }
    };

    match opts.prefetch {
        None => {
            // Synchronous mode: workers claim whole shards; reads happen
            // inline.  A claimed shard always runs to completion (stop is
            // only honored between shards) so parked accumulators stay
            // consistent with the shard partition.
            //
            // The fold-prefix window bounds live shard accumulators: a
            // worker may not start shard `s` until the folded prefix is
            // within `window` shards of it, so at most `window` accumulator
            // sets exist at once even if one early shard is slow (the
            // memory planner budgets exactly that bound).
            let window = opts.threads.max(2);
            let cursor = AtomicUsize::new(resume_shards);
            ThreadPool::run_workers(opts.threads, |_w| {
                let mut ctx = consumer.make_ctx();
                loop {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let s = cursor.fetch_add(1, Ordering::SeqCst);
                    if s >= nshards {
                        break;
                    }
                    {
                        let mut f = folder.lock().unwrap();
                        while !stop.load(Ordering::SeqCst) && s >= f.next + window {
                            f = fold_advanced.wait(f).unwrap();
                        }
                    }
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let (b0, b1) = shards[s];
                    let mut acc = consumer.zero_acc();
                    let mut failed = false;
                    for pos in b0..b1 {
                        let t0 = Instant::now();
                        let t = match catch_unwind(AssertUnwindSafe(|| src.block(&blocks[pos]))) {
                            Ok(t) => t,
                            Err(p) => {
                                record_failure(p);
                                failed = true;
                                break;
                            }
                        };
                        io_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        blocks_read.fetch_add(1, Ordering::Relaxed);
                        consumer.process(&mut ctx, &blocks[pos], t, &mut acc);
                    }
                    if failed {
                        // The shard is incomplete: folding it would corrupt
                        // the prefix, so abandon it and exit.  The folded
                        // prefix (shards before this one, once their owners
                        // finish) stays intact for checkpoint-then-fail.
                        break;
                    }
                    complete_shard(s, acc);
                }
            });
        }
        Some(pf) => {
            let depth = pf.depth.max(1);
            let io_threads = pf.io_threads.max(1);
            let consumers = opts.threads.max(1);
            // Fold-prefix window, as in sync mode: producers only claim
            // blocks of shards within `window` of the folded prefix, which
            // bounds live shard accumulators and parked raw blocks even if
            // one early shard is slow.  Claims round-robin **across** the
            // window's shards (per-shard cursors) rather than sweeping the
            // grid linearly — a shard's blocks must commit in order, so
            // shard-level interleaving is what lets `threads` consumers
            // compute concurrently instead of convoying behind one shard.
            let window = opts.threads.max(2);
            // Exact live-block cap the memory planner prices: queue slots,
            // one read per I/O thread, one block per consumer.
            let cap = depth + io_threads + consumers;
            let reorder = Mutex::new(Reorder {
                next_send: 0,
                parked: BTreeMap::new(),
                draining: false,
            });
            let (tx, rx) = mpsc::sync_channel::<(usize, DenseTensor)>(depth);
            let rx = Arc::new(Mutex::new(rx));
            let states: Vec<Mutex<ShardState<C::Acc>>> = shards
                .iter()
                .map(|&(a, b)| {
                    Mutex::new(ShardState {
                        next_pos: a,
                        end: b,
                        acc: None,
                        busy: false,
                        pending: Vec::new(),
                    })
                })
                .collect();
            // Per-shard claim cursors (positions are claimed ascending
            // within each shard; exhausted shards just overshoot).
            let shard_cursor: Vec<AtomicUsize> =
                shards.iter().map(|&(a, _)| AtomicUsize::new(a)).collect();
            // Spreads concurrent producers across the window's shards.
            let rr = AtomicUsize::new(0);
            let shard_of = |pos: usize| shards.partition_point(|&(_, end)| end <= pos);

            std::thread::scope(|scope| {
                for _ in 0..io_threads {
                    let tx = tx.clone();
                    let stop = &stop;
                    let io_ns = &io_ns;
                    let send_stall_ns = &send_stall_ns;
                    let blocks_read = &blocks_read;
                    let folder = &folder;
                    let fold_advanced = &fold_advanced;
                    let claim = &claim;
                    let claim_freed = &claim_freed;
                    let reorder = &reorder;
                    let shard_cursor = &shard_cursor;
                    let rr = &rr;
                    let shards = &shards;
                    let record_failure = &record_failure;
                    scope.spawn(move || 'producer: loop {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        // Claim the next block: wait out the live-block
                        // budget, then scan the current fold window
                        // round-robin for an unclaimed position.  The claim
                        // lock is held across the scan so ticket order ==
                        // claim order (within a shard, ascending block
                        // order).  When the whole window is claimed, wait
                        // for the prefix to advance (waiting is
                        // producer-only and safe — every in-window position
                        // was claimed by a non-waiting producer, so folds
                        // keep coming).
                        let claimed = 'claim: loop {
                            let mut c = claim.lock().unwrap();
                            while !stop.load(Ordering::SeqCst) && c.live >= cap {
                                c = claim_freed.wait(c).unwrap();
                            }
                            if stop.load(Ordering::SeqCst) {
                                break 'claim None;
                            }
                            let wstart = folder.lock().unwrap().next;
                            if wstart >= nshards {
                                break 'claim None;
                            }
                            let span = (wstart + window).min(nshards) - wstart;
                            let first = rr.fetch_add(1, Ordering::Relaxed) % span;
                            let mut found = None;
                            for k in 0..span {
                                let s = wstart + (first + k) % span;
                                let pos = shard_cursor[s].fetch_add(1, Ordering::SeqCst);
                                if pos < shards[s].1 {
                                    found = Some(pos);
                                    break;
                                }
                            }
                            if let Some(pos) = found {
                                c.live += 1;
                                c.peak = c.peak.max(c.live);
                                let seq = c.seq;
                                c.seq += 1;
                                break 'claim Some((pos, seq));
                            }
                            drop(c);
                            let mut f = folder.lock().unwrap();
                            while !stop.load(Ordering::SeqCst) && f.next == wstart {
                                f = fold_advanced.wait(f).unwrap();
                            }
                            if stop.load(Ordering::SeqCst) {
                                break 'claim None;
                            }
                        };
                        let Some((pos, seq)) = claimed else { break };
                        let t0 = Instant::now();
                        let t = match catch_unwind(AssertUnwindSafe(|| src.block(&blocks[pos]))) {
                            Ok(t) => t,
                            Err(p) => {
                                record_failure(p);
                                // Wake budget waiters so they observe stop
                                // and exit (this read's ticket will never
                                // commit).
                                let _g = claim.lock().unwrap();
                                claim_freed.notify_all();
                                break;
                            }
                        };
                        let read_done = Instant::now();
                        io_ns.fetch_add(
                            (read_done - t0).as_nanos() as u64,
                            Ordering::Relaxed,
                        );
                        blocks_read.fetch_add(1, Ordering::Relaxed);
                        // Commit the read in ticket order: park it, then —
                        // unless another producer is mid-send — drain every
                        // consecutive ticket into the channel.  The blocking
                        // send (backpressure from the bounded queue) runs
                        // outside the reorder lock; an Err means every
                        // consumer exited (abort).  Re-checking the head
                        // after each send, under the same lock that clears
                        // `draining`, means a ticket parked during the send
                        // cannot be stranded.
                        let mut ro = reorder.lock().unwrap();
                        ro.parked.insert(seq, (pos, t));
                        while !ro.draining {
                            let Some((&head, _)) = ro.parked.iter().next() else { break };
                            if head != ro.next_send {
                                break;
                            }
                            let (p, t) = ro.parked.remove(&head).unwrap();
                            ro.draining = true;
                            drop(ro);
                            let send_t0 = Instant::now();
                            let sent = tx.send((p, t)).is_ok();
                            send_stall_ns
                                .fetch_add(send_t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                            ro = reorder.lock().unwrap();
                            ro.next_send += 1;
                            ro.draining = false;
                            if !sent {
                                break 'producer;
                            }
                        }
                    });
                }
                // The scope's own sender must drop so the channel closes
                // once the last producer finishes.
                drop(tx);

                for _ in 0..consumers {
                    let rx = Arc::clone(&rx);
                    let states = &states;
                    let stop = &stop;
                    let recv_stall_ns = &recv_stall_ns;
                    let complete_shard = &complete_shard;
                    let shard_of = &shard_of;
                    let claim = &claim;
                    let claim_freed = &claim_freed;
                    scope.spawn(move || {
                        let mut ctx = consumer.make_ctx();
                        loop {
                            if stop.load(Ordering::SeqCst) {
                                break;
                            }
                            let msg = {
                                let guard = rx.lock().unwrap();
                                // Time only the recv itself (empty-queue
                                // starvation), not contention on the
                                // receiver lock — otherwise N-1 consumers
                                // would each double-count one consumer's
                                // wait and inflate the stall metric.
                                let t0 = Instant::now();
                                let m = guard.recv();
                                recv_stall_ns
                                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                                m
                            };
                            let Ok((pos, t)) = msg else { break };
                            let s = shard_of(pos);
                            // Become the shard's owner if this is the next
                            // in-order block and no one holds it; park
                            // otherwise.  The lock is held only for this.
                            let mut work = {
                                let mut st = states[s].lock().unwrap();
                                if st.busy || pos != st.next_pos {
                                    st.pending.push((pos, t));
                                    None
                                } else {
                                    st.busy = true;
                                    let acc =
                                        st.acc.take().unwrap_or_else(|| consumer.zero_acc());
                                    Some((pos, t, acc))
                                }
                            };
                            // Owner's in-order run: process WITHOUT the
                            // shard lock, re-locking briefly to commit and
                            // pick up parked successors.
                            while let Some((p, tensor, mut acc)) = work.take() {
                                consumer.process(&mut ctx, &blocks[p], tensor, &mut acc);
                                // The block is no longer live: free a
                                // budget slot for the producers.
                                {
                                    let mut c = claim.lock().unwrap();
                                    c.live -= 1;
                                    claim_freed.notify_one();
                                }
                                let mut st = states[s].lock().unwrap();
                                st.next_pos = p + 1;
                                let nxt = st.next_pos;
                                let parked =
                                    st.pending.iter().position(|(q, _)| *q == nxt);
                                if let Some(i) = parked {
                                    let (np, nt) = st.pending.swap_remove(i);
                                    work = Some((np, nt, acc));
                                } else if nxt == st.end {
                                    st.busy = false;
                                    drop(st);
                                    complete_shard(s, acc);
                                } else {
                                    st.acc = Some(acc);
                                    st.busy = false;
                                }
                            }
                        }
                        // Dropping our rx clone lets blocked producers
                        // observe the closed channel and exit on abort; a
                        // final wakeup frees any producer parked on the
                        // live-block budget so it can observe stop too.
                        let _g = claim.lock().unwrap();
                        claim_freed.notify_all();
                    });
                }
                // The scope's own receiver handle must drop too — otherwise
                // producers blocked in `send` after an abort would never see
                // the channel close (all consumers gone but the receiver
                // still alive here ⇒ deadlock).
                drop(rx);
            });
        }
    }

    let folder = folder.into_inner().unwrap();
    stats.max_live_blocks = claim.into_inner().unwrap().peak;
    stats.failure = failure.into_inner().unwrap();
    stats.aborted = stop.load(Ordering::SeqCst);
    assert!(
        stats.aborted || folder.next == nshards,
        "streaming pass ended with {} of {nshards} shards folded",
        folder.next
    );
    stats.shards_done = folder.next;
    stats.blocks_done = folder.blocks_done as u64;
    stats.blocks_read = blocks_read.load(Ordering::Relaxed);
    stats.io_seconds = io_ns.load(Ordering::Relaxed) as f64 * 1e-9;
    stats.io_stall_seconds = recv_stall_ns.load(Ordering::Relaxed) as f64 * 1e-9;
    stats.send_stall_seconds = send_stall_ns.load(Ordering::Relaxed) as f64 * 1e-9;
    (folder.acc, stats)
}

/// Computes one shard's raw accumulator exactly as the engine does: a
/// fresh `zero_acc` folded over blocks `b0..b1` in ascending block-index
/// order.  This is the worker-side seam of the shard-lease subsystem
/// (`serve/shard.rs`): a remote worker returns this accumulator verbatim
/// — NOT merged into another zero (`merge` is not guaranteed to be an
/// identity bit for bit, e.g. `0.0 + (-0.0)`) — and the coordinator folds
/// it in shard-index order, reproducing the single-process result
/// bitwise.  Panics from `TensorSource::block` propagate to the caller
/// (workers surface them as lease failures).
pub fn run_shard<C: BlockConsumer>(
    src: &dyn TensorSource,
    blocks: &[BlockRange],
    consumer: &C,
    b0: usize,
    b1: usize,
) -> C::Acc {
    assert!(b0 <= b1 && b1 <= blocks.len(), "shard range {b0}..{b1} out of bounds");
    let mut ctx = consumer.make_ctx();
    let mut acc = consumer.zero_acc();
    for pos in b0..b1 {
        let t = src.block(&blocks[pos]);
        consumer.process(&mut ctx, &blocks[pos], t, &mut acc);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{BlockSpec3, InMemorySource};
    use crate::util::rng::Xoshiro256;
    use std::sync::atomic::AtomicUsize;

    /// Toy consumer: accumulates `Σ block_sum·w(pos)` with a deliberately
    /// order-sensitive float recurrence, so any reordering of the per-shard
    /// fold or the shard merge changes the bits.
    struct SumConsumer;
    impl BlockConsumer for SumConsumer {
        type Acc = Vec<f32>;
        type Ctx = ();
        fn make_ctx(&self) {}
        fn zero_acc(&self) -> Vec<f32> {
            vec![0.0]
        }
        fn process(&self, _c: &mut (), blk: &BlockRange, t: DenseTensor, acc: &mut Vec<f32>) {
            let s: f32 = t.data().iter().sum();
            // Order-sensitive: multiply-accumulate with a pos-dependent
            // factor; float non-associativity exposes reorderings.
            acc[0] = acc[0] * 1.000_1 + s * (1.0 + blk.index as f32 * 0.01);
        }
        fn merge(&self, into: &mut Vec<f32>, from: Vec<f32>) {
            into[0] += from[0];
        }
    }

    fn setup(dims: [usize; 3], block: [usize; 3]) -> (InMemorySource, Vec<BlockRange>) {
        let mut rng = Xoshiro256::seed_from_u64(777);
        let t = DenseTensor::random_normal(dims, &mut rng);
        let blocks = BlockSpec3::new(dims, block).iter().collect();
        (InMemorySource::new(t), blocks)
    }

    fn run(src: &InMemorySource, blocks: &[BlockRange], opts: &StreamOptions) -> f32 {
        let (acc, stats) = stream_blocks(src, blocks, opts, &SumConsumer, None, None);
        assert!(!stats.aborted);
        assert_eq!(stats.blocks_read, blocks.len() as u64);
        acc[0]
    }

    #[test]
    fn bitwise_invariant_across_threads_and_prefetch() {
        let (src, blocks) = setup([12, 11, 10], [5, 4, 3]);
        let reference = run(
            &src,
            &blocks,
            &StreamOptions { threads: 1, prefetch: None, shard_parts: 8 },
        );
        for threads in [2, 4, 8] {
            let got = run(
                &src,
                &blocks,
                &StreamOptions { threads, prefetch: None, shard_parts: 8 },
            );
            assert_eq!(got.to_bits(), reference.to_bits(), "sync threads={threads}");
        }
        for (threads, depth, io) in [(1, 1, 1), (2, 2, 1), (4, 4, 2), (8, 3, 3)] {
            let got = run(
                &src,
                &blocks,
                &StreamOptions {
                    threads,
                    prefetch: Some(PrefetchConfig { depth, io_threads: io }),
                    shard_parts: 8,
                },
            );
            assert_eq!(
                got.to_bits(),
                reference.to_bits(),
                "prefetch threads={threads} depth={depth} io={io}"
            );
        }
    }

    #[test]
    fn progress_reports_monotonic_prefix_and_resume_matches() {
        let (src, blocks) = setup([10, 10, 10], [4, 4, 4]);
        let opts = StreamOptions { threads: 3, prefetch: None, shard_parts: 6 };
        let reference = run(&src, &blocks, &opts);

        // Abort after the prefix first advances, capturing the partial.
        // Single-threaded so shards complete strictly in order and the
        // captured prefix is deterministically one shard.
        let seq = StreamOptions { threads: 1, ..opts.clone() };
        let captured: Mutex<Option<(Vec<f32>, usize, usize)>> = Mutex::new(None);
        let abort_cb = |acc: &Vec<f32>, shards: usize, blks: usize| {
            let mut g = captured.lock().unwrap();
            if g.is_none() {
                *g = Some((acc.clone(), shards, blks));
                false
            } else {
                true
            }
        };
        let (_, stats) =
            stream_blocks(&src, &blocks, &seq, &SumConsumer, None, Some(&abort_cb));
        assert!(stats.aborted);
        let (partial, shards_done, blocks_done) = captured.into_inner().unwrap().unwrap();
        assert_eq!(shards_done, 1, "1-thread sync folds shard 0 first");

        // Resume from the captured prefix; result must match bitwise.
        let (acc, stats2) = stream_blocks(
            &src,
            &blocks,
            &opts,
            &SumConsumer,
            Some(ResumeState { shards_done, blocks_done, acc: partial }),
            None,
        );
        assert!(!stats2.aborted);
        assert_eq!(stats2.resumed_blocks, blocks_done as u64);
        assert_eq!(
            stats2.blocks_read as usize,
            blocks.len() - blocks_done,
            "resume must not re-read folded blocks"
        );
        assert_eq!(acc[0].to_bits(), reference.to_bits());
    }

    #[test]
    fn progress_prefix_is_monotone_and_complete() {
        let (src, blocks) = setup([9, 9, 9], [3, 3, 3]);
        let last = AtomicUsize::new(0);
        let calls = AtomicUsize::new(0);
        let cb = |_acc: &Vec<f32>, shards: usize, _blks: usize| {
            let prev = last.swap(shards, Ordering::SeqCst);
            assert!(shards > prev, "prefix must strictly advance");
            calls.fetch_add(1, Ordering::SeqCst);
            true
        };
        let opts = StreamOptions {
            threads: 4,
            prefetch: Some(PrefetchConfig { depth: 2, io_threads: 2 }),
            shard_parts: 5,
        };
        let (_, stats) = stream_blocks(&src, &blocks, &opts, &SumConsumer, None, Some(&cb));
        assert!(!stats.aborted);
        assert_eq!(last.load(Ordering::SeqCst), stats.shards);
        assert!(calls.load(Ordering::SeqCst) >= 1);
    }

    /// Source whose `block` panics at one block index: models a read whose
    /// retry budget is exhausted (`FileTensorSource::block` panics with the
    /// formatted error after `read_at` gives up).
    struct FailingSource {
        inner: InMemorySource,
        fail_at: usize,
    }
    impl TensorSource for FailingSource {
        fn dims(&self) -> [usize; 3] {
            self.inner.dims()
        }
        fn block(&self, r: &BlockRange) -> DenseTensor {
            if r.index == self.fail_at {
                panic!("simulated irrecoverable read at block {}", r.index);
            }
            self.inner.block(r)
        }
    }

    #[test]
    fn source_panic_is_captured_not_propagated() {
        let (src, blocks) = setup([10, 10, 10], [4, 4, 4]);
        let fail_at = blocks.len() - 1;
        let failing = FailingSource { inner: src, fail_at };
        for prefetch in [None, Some(PrefetchConfig { depth: 2, io_threads: 2 })] {
            let opts = StreamOptions { threads: 3, prefetch, shard_parts: 6 };
            let (_, stats) =
                stream_blocks(&failing, &blocks, &opts, &SumConsumer, None, None);
            assert!(stats.aborted, "failure must stop the pass");
            let msg = stats.failure.expect("failure message recorded");
            assert!(
                msg.contains("simulated irrecoverable read"),
                "panic payload surfaced: {msg}"
            );
            assert!(
                stats.shards_done < stats.shards,
                "failing final block means the last shard cannot fold"
            );
        }
    }

    #[test]
    fn run_shard_fold_matches_stream_blocks_bitwise() {
        // Computing every shard independently with `run_shard` and merging
        // in shard order must reproduce the engine's result bit for bit —
        // the invariant the shard-lease coordinator relies on when folding
        // worker partials.
        let (src, blocks) = setup([12, 11, 10], [5, 4, 3]);
        let opts = StreamOptions { threads: 3, prefetch: None, shard_parts: 8 };
        let reference = run(&src, &blocks, &opts);
        let shards = ThreadPool::partition(blocks.len(), 8);
        let mut acc = SumConsumer.zero_acc();
        for &(b0, b1) in &shards {
            let part = run_shard(&src, &blocks, &SumConsumer, b0, b1);
            SumConsumer.merge(&mut acc, part);
        }
        assert_eq!(acc[0].to_bits(), reference.to_bits());
    }

    #[test]
    fn prefetch_live_blocks_bounded_by_depth_io_threads() {
        // The live-block budget must hold exactly: never more than
        // depth + io_threads + threads blocks claimed-but-unprocessed,
        // which is precisely the planner's queue term.
        let (src, blocks) = setup([12, 12, 12], [3, 3, 3]);
        for (threads, depth, io) in [(1, 1, 1), (2, 3, 2), (4, 2, 3), (3, 5, 1)] {
            let opts = StreamOptions {
                threads,
                prefetch: Some(PrefetchConfig { depth, io_threads: io }),
                shard_parts: 8,
            };
            let (_, stats) = stream_blocks(&src, &blocks, &opts, &SumConsumer, None, None);
            assert!(!stats.aborted);
            assert!(stats.max_live_blocks >= 1, "at least one block was live");
            assert!(
                stats.max_live_blocks <= depth + io + threads,
                "live blocks {} exceeded the {}+{}+{} budget",
                stats.max_live_blocks,
                depth,
                io,
                threads
            );
        }
    }

    #[test]
    fn empty_grid_returns_zero_acc() {
        let (src, _) = setup([4, 4, 4], [4, 4, 4]);
        let (acc, stats) =
            stream_blocks(&src, &[], &StreamOptions::default(), &SumConsumer, None, None);
        assert_eq!(acc, vec![0.0]);
        assert_eq!(stats.blocks_read, 0);
    }

    #[test]
    fn single_shard_is_flat_block_order_fold() {
        // With one shard the engine must reduce exactly like a sequential
        // loop over blocks — the oracle for mutex-vs-shard comparisons.
        let (src, blocks) = setup([8, 8, 8], [3, 3, 3]);
        let mut expected = vec![0.0f32];
        for blk in &blocks {
            let t = src.block(blk);
            SumConsumer.process(&mut (), blk, t, &mut expected);
        }
        for threads in [1, 4] {
            let got = run(
                &src,
                &blocks,
                &StreamOptions { threads, prefetch: None, shard_parts: 1 },
            );
            assert_eq!(got.to_bits(), expected[0].to_bits());
        }
        let got = run(
            &src,
            &blocks,
            &StreamOptions {
                threads: 4,
                prefetch: Some(PrefetchConfig { depth: 3, io_threads: 2 }),
                shard_parts: 1,
            },
        );
        assert_eq!(got.to_bits(), expected[0].to_bits());
    }
}
