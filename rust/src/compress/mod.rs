//! The compression stage of Exascale-Tensor (§III "Compression", §IV-C
//! "Massive Parallel Compression", §IV-D "Efficient Decomposition").
//!
//! * [`comp`] — the mode-product chain `Comp(X, U, V, W)` (Eq. 3) for an
//!   in-memory tensor, with optional mixed-precision operands (§IV-B).
//! * [`maps`] — tiered replica compression-map source with `S` shared
//!   anchor rows (Alg. 2 line 1): a counter-based random-access generator
//!   behind a `Materialized` (stored matrices) and a `Procedural`
//!   (generate-on-slice, `O(panel)` memory) tier — bitwise identical.
//! * [`sparse_proj`] — sparse ±1 projection matrices for the
//!   compressed-sensing two-stage construction (§IV-D).
//! * [`engine`] — the out-of-core streaming engine: deterministic shard
//!   partition, shard-local accumulation with in-order prefix folding,
//!   optional prefetched I/O (bounded producer/consumer queue), and
//!   incremental-progress hooks for mid-compression checkpoints.
//! * [`stream`] — blocked, multi-threaded compression of a
//!   [`crate::tensor::TensorSource`] (Fig. 2) on top of the engine,
//!   generic over the block-compressor backend (pure rust vs AOT XLA
//!   kernel).

pub mod comp;
pub mod engine;
pub mod maps;
pub mod sparse_proj;
pub mod stream;

pub use comp::{
    comp_dense, comp_dense_with, ttm_mode1, ttm_mode1_with, ttm_mode2, ttm_mode2_with, ttm_mode3,
    ttm_mode3_with,
};
pub use engine::{
    run_shard, stream_blocks, BlockConsumer, PrefetchConfig, ProgressFn, ResumeState,
    StreamOptions, StreamStats, DEFAULT_SHARD_PARTS,
};
pub use maps::{CompressionMaps, MapSource, MapSpec, MapTier, ProceduralMaps, ReplicaMaps};
pub use sparse_proj::SparseSignMatrix;
pub use stream::{
    compress_shard, compress_shard_batched, compress_source, compress_source_batched,
    compress_source_batched_opts, compress_source_opts, compress_source_sparse,
    compress_source_sparse_opts, fold_shard_proxies, zero_shard_proxies, BlockCompressor,
    ProxyResume, RustCompressor,
};
