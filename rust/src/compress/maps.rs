//! Replica compression-matrix generation — Alg. 2 line 1 — as a **tiered
//! map source**.
//!
//! Each replica `p` gets Gaussian `U_p (L×I)`, `V_p (M×J)`, `W_p (N×K)`.
//! The first `S` **rows** of every `U_p` (and of `V_p`, `W_p`) are identical
//! across replicas — the PARACOMP anchor construction: since
//! `A_p = U_p·A·Π_p·Σ_p`, shared leading rows give every replica the same
//! leading `S×R` sub-block of `U·A` up to its own `Π_p Σ_p`, which is
//! exactly what lines 5–7 of Alg. 2 exploit to undo the per-replica
//! permutation and scaling; Alg. 2 line 5 divides the columns of *all
//! three* factor matrices by their anchor maxima, which requires anchors in
//! all three compression matrices.  (The paper's text says "columns"; for
//! `U_p ∈ R^{L×I}` the anchor must be on the compressed side, i.e. rows —
//! column anchors would not survive the product `U_p A`.)
//!
//! ## Tiers
//!
//! Storing the maps densely costs `P × (L·I + M·J + N·K)` floats — the
//! dominant term at exascale `I` (they dwarf the proxies).  But the maps
//! are *pure functions of the seed*: every entry is
//! [`MapSpec::entry`]`(p, mode, row, col)`, a counter-based hash
//! ([`crate::util::rng::counter_key`] → Box-Muller), so any `L×w` column
//! panel can be synthesized on demand, in any order, on any thread — the
//! generate-on-slice treatment that randomized-sketch CP methods
//! (Erichson et al., arXiv:1703.09074) use to avoid storing sketch
//! operators at all.
//!
//! * [`MapSource::Materialized`] — the panels are cut (`memcpy`) from
//!   matrices filled **by the same generator** at construction.  Right for
//!   small dims where `P·(L·I+…)` floats are cheap and reuse across blocks
//!   makes copying faster than re-hashing.
//! * [`MapSource::Procedural`] — nothing is stored but the [`MapSpec`];
//!   panels are synthesized into caller scratch at use sites.  Map memory
//!   collapses from `O(P·(L·I+M·J+N·K))` to `O(panel)`.
//!
//! Both tiers produce **bitwise-identical** panels (same entry function,
//! same f32 operations), so the whole pipeline — compression, recovery,
//! checkpoints — is tier-invariant, and a checkpoint written under one
//! tier resumes under the other.
//!
//! The original sequential-stream generator (per-replica xoshiro streams)
//! survives only as [`generate_stream_oracle`], the distributional oracle
//! for the statistical tests below.

use crate::linalg::Matrix;
use crate::util::rng::{counter_key, gaussian_from_key, Xoshiro256};
use std::sync::Arc;

/// Replica slot the shared anchor rows hash under: every replica sees the
/// same anchor entries because the replica index is collapsed to this
/// sentinel before keying.
const ANCHOR_REPLICA: u64 = u64::MAX;

/// The resolved storage tier of a [`MapSource`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapTier {
    /// Maps stored as dense matrices; panels are column-range memcpys.
    Materialized,
    /// Maps exist only as a seed; panels are synthesized on demand.
    Procedural,
}

impl MapTier {
    pub fn as_str(&self) -> &'static str {
        match self {
            MapTier::Materialized => "materialized",
            MapTier::Procedural => "procedural",
        }
    }
}

/// Everything needed to synthesize any map entry: the counter-based
/// generator's key space.  `Copy`-small — a procedural map source is just
/// this plus the kept-replica index list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MapSpec {
    pub dims: [usize; 3],
    pub reduced: [usize; 3],
    pub p_count: usize,
    pub anchor_rows: usize,
    pub seed: u64,
}

impl MapSpec {
    pub fn new(
        dims: [usize; 3],
        reduced: [usize; 3],
        p_count: usize,
        anchor_rows: usize,
        seed: u64,
    ) -> Self {
        let [l, m, n] = reduced;
        assert!(
            anchor_rows <= l && anchor_rows <= m && anchor_rows <= n,
            "anchor rows S={anchor_rows} exceed reduced dims {reduced:?}"
        );
        assert!(p_count >= 1, "need at least one replica");
        Self { dims, reduced, p_count, anchor_rows, seed }
    }

    /// Rows of a mode-`mode` map (`L`, `M`, or `N`).
    #[inline]
    pub fn rows(&self, mode: usize) -> usize {
        self.reduced[mode]
    }

    /// Columns of a mode-`mode` map (`I`, `J`, or `K`).
    #[inline]
    pub fn cols(&self, mode: usize) -> usize {
        self.dims[mode]
    }

    /// One map entry, random-access: `U_p[row, col]` for `mode = 0` (and
    /// `V_p`/`W_p` for modes 1/2).  Entries are `N(0, 1/dim)` — the same
    /// `1/√dim` variance normalization the sequential generator applied —
    /// and rows below `anchor_rows` are shared across replicas.
    #[inline]
    pub fn entry(&self, p: usize, mode: usize, row: usize, col: usize) -> f32 {
        debug_assert!(p < self.p_count, "replica {p} ≥ P={}", self.p_count);
        debug_assert!(row < self.rows(mode) && col < self.cols(mode));
        let rep = if row < self.anchor_rows { ANCHOR_REPLICA } else { p as u64 };
        let key = counter_key(self.seed, rep, mode as u64, row as u64, col as u64);
        gaussian_from_key(key) * (1.0 / (self.cols(mode) as f32).sqrt())
    }

    /// Synthesizes the column panel `[:, c0..c1)` of replica `p`'s
    /// mode-`mode` map into `out` (column-major `rows × (c1−c0)`), reusing
    /// `out`'s capacity.
    pub fn fill_panel(&self, p: usize, mode: usize, c0: usize, c1: usize, out: &mut Vec<f32>) {
        let rows = self.rows(mode);
        assert!(c0 <= c1 && c1 <= self.cols(mode), "panel [{c0},{c1}) out of range");
        out.clear();
        out.reserve(rows * (c1 - c0));
        for col in c0..c1 {
            for row in 0..rows {
                out.push(self.entry(p, mode, row, col));
            }
        }
    }

    /// Synthesizes the **stacked** column panel
    /// `[[U_{k0}]; …; [U_{k_last}]][:, c0..c1)` for the replicas in `kept`
    /// (column-major `(kept.len()·rows) × (c1−c0)`).
    pub fn fill_stacked_panel(
        &self,
        kept: &[usize],
        mode: usize,
        c0: usize,
        c1: usize,
        out: &mut Vec<f32>,
    ) {
        let rows = self.rows(mode);
        assert!(c0 <= c1 && c1 <= self.cols(mode), "panel [{c0},{c1}) out of range");
        out.clear();
        out.reserve(kept.len() * rows * (c1 - c0));
        for col in c0..c1 {
            for &p in kept {
                for row in 0..rows {
                    out.push(self.entry(p, mode, row, col));
                }
            }
        }
    }

    /// Materializes one replica's three maps (used by the materialized
    /// tier's constructor — and by it only, so both tiers share one entry
    /// function).
    fn materialize_replica(&self, p: usize) -> CompressionMaps {
        let gen = |mode: usize| {
            let (rows, cols) = (self.rows(mode), self.cols(mode));
            let mut data = Vec::with_capacity(rows * cols);
            for col in 0..cols {
                for row in 0..rows {
                    data.push(self.entry(p, mode, row, col));
                }
            }
            Matrix::from_vec(rows, cols, data)
        };
        CompressionMaps { u: gen(0), v: gen(1), w: gen(2) }
    }
}

/// One replica's compression matrices.
#[derive(Clone, Debug)]
pub struct CompressionMaps {
    pub u: Matrix, // L × I
    pub v: Matrix, // M × J
    pub w: Matrix, // N × K
}

impl CompressionMaps {
    /// The mode-`mode` map (`u`/`v`/`w`).
    #[inline]
    pub fn mode(&self, mode: usize) -> &Matrix {
        match mode {
            0 => &self.u,
            1 => &self.v,
            2 => &self.w,
            _ => panic!("mode {mode} out of range"),
        }
    }
}

/// The full set of `P` replicas with `S` shared anchor rows, **stored**.
///
/// Replicas are held behind [`Arc`] so [`ReplicaMaps::subset`] — replica
/// drop after failed proxy decompositions — is O(1) per kept replica
/// instead of a deep clone of every matrix.
#[derive(Clone, Debug)]
pub struct ReplicaMaps {
    pub replicas: Vec<Arc<CompressionMaps>>,
    pub anchor_rows: usize,
    pub dims: [usize; 3],
    pub reduced: [usize; 3],
}

impl ReplicaMaps {
    /// Generates `p_count` materialized replicas for compressing
    /// `dims = [I,J,K]` down to `reduced = [L,M,N]`, with
    /// `anchor_rows = S` shared leading rows per mode.  Filled from
    /// [`MapSpec::entry`], so the result is bitwise identical to what the
    /// procedural tier synthesizes for the same parameters.
    pub fn generate(
        dims: [usize; 3],
        reduced: [usize; 3],
        p_count: usize,
        anchor_rows: usize,
        seed: u64,
    ) -> Self {
        let spec = MapSpec::new(dims, reduced, p_count, anchor_rows, seed);
        let replicas = (0..p_count)
            .map(|p| Arc::new(spec.materialize_replica(p)))
            .collect();
        Self { replicas, anchor_rows, dims, reduced }
    }

    pub fn p_count(&self) -> usize {
        self.replicas.len()
    }

    /// Keeps only the replicas at `indices` (used after dropping replicas
    /// whose proxy decomposition failed to converge — Alg. 2's "drop it
    /// (them) in time").  O(1) per kept replica: only the `Arc`s clone.
    pub fn subset(&self, indices: &[usize]) -> ReplicaMaps {
        ReplicaMaps {
            replicas: indices.iter().map(|&i| Arc::clone(&self.replicas[i])).collect(),
            anchor_rows: self.anchor_rows,
            dims: self.dims,
            reduced: self.reduced,
        }
    }

    /// Stacked `[U_1; …; U_P]` — the LHS of the recovery least squares
    /// (Eq. 4) for mode 1.  Materializes `P·L × I`; production recovery
    /// streams panels instead (`coordinator::recovery::stacked_recover`) —
    /// this remains for tests and the vstack oracle.
    pub fn stacked_u(&self) -> Matrix {
        let refs: Vec<&Matrix> = self.replicas.iter().map(|r| &r.u).collect();
        Matrix::vstack(&refs)
    }

    /// Stacked `[V_1; …; V_P]`.
    pub fn stacked_v(&self) -> Matrix {
        let refs: Vec<&Matrix> = self.replicas.iter().map(|r| &r.v).collect();
        Matrix::vstack(&refs)
    }

    /// Stacked `[W_1; …; W_P]`.
    pub fn stacked_w(&self) -> Matrix {
        let refs: Vec<&Matrix> = self.replicas.iter().map(|r| &r.w).collect();
        Matrix::vstack(&refs)
    }
}

/// The procedural tier: a [`MapSpec`] plus the kept replica indices.
/// `kept[i]` is the *original* replica id of position `i`, so subsetting
/// preserves generation identity (a kept replica's entries never change).
#[derive(Clone, Debug)]
pub struct ProceduralMaps {
    pub spec: MapSpec,
    kept: Vec<usize>,
}

impl ProceduralMaps {
    /// Original replica id at position `p`.
    #[inline]
    pub fn replica_id(&self, p: usize) -> usize {
        self.kept[p]
    }
}

/// A tiered source of replica compression maps — the one interface every
/// consumer (streaming compression, stacked recovery, checkpoint resume)
/// goes through, so the tier choice is invisible to results.
#[derive(Clone, Debug)]
pub enum MapSource {
    Materialized(ReplicaMaps),
    Procedural(ProceduralMaps),
}

impl MapSource {
    /// Generates a map source in the given tier.  Both tiers describe the
    /// identical map family: the tier only decides whether panels are cut
    /// from stored matrices or synthesized on demand.
    pub fn generate(
        dims: [usize; 3],
        reduced: [usize; 3],
        p_count: usize,
        anchor_rows: usize,
        seed: u64,
        tier: MapTier,
    ) -> Self {
        match tier {
            MapTier::Materialized => MapSource::Materialized(ReplicaMaps::generate(
                dims, reduced, p_count, anchor_rows, seed,
            )),
            MapTier::Procedural => MapSource::Procedural(ProceduralMaps {
                spec: MapSpec::new(dims, reduced, p_count, anchor_rows, seed),
                kept: (0..p_count).collect(),
            }),
        }
    }

    pub fn tier(&self) -> MapTier {
        match self {
            MapSource::Materialized(_) => MapTier::Materialized,
            MapSource::Procedural(_) => MapTier::Procedural,
        }
    }

    pub fn p_count(&self) -> usize {
        match self {
            MapSource::Materialized(m) => m.p_count(),
            MapSource::Procedural(p) => p.kept.len(),
        }
    }

    pub fn dims(&self) -> [usize; 3] {
        match self {
            MapSource::Materialized(m) => m.dims,
            MapSource::Procedural(p) => p.spec.dims,
        }
    }

    pub fn reduced(&self) -> [usize; 3] {
        match self {
            MapSource::Materialized(m) => m.reduced,
            MapSource::Procedural(p) => p.spec.reduced,
        }
    }

    pub fn anchor_rows(&self) -> usize {
        match self {
            MapSource::Materialized(m) => m.anchor_rows,
            MapSource::Procedural(p) => p.spec.anchor_rows,
        }
    }

    /// The stored-tier maps, when this source is materialized (tests and
    /// the vstack recovery oracle).
    pub fn materialized(&self) -> Option<&ReplicaMaps> {
        match self {
            MapSource::Materialized(m) => Some(m),
            MapSource::Procedural(_) => None,
        }
    }

    /// Keeps only the replicas at `indices` — O(1) per kept replica in
    /// both tiers (`Arc` clone / index push).
    pub fn subset(&self, indices: &[usize]) -> MapSource {
        match self {
            MapSource::Materialized(m) => MapSource::Materialized(m.subset(indices)),
            MapSource::Procedural(p) => MapSource::Procedural(ProceduralMaps {
                spec: p.spec,
                kept: indices.iter().map(|&i| p.kept[i]).collect(),
            }),
        }
    }

    /// The column panel `[:, c0..c1)` of replica `p`'s mode-`mode` map,
    /// built in `buf` (recycled: pass the previous panel's
    /// [`Matrix::into_vec`] back in to make the hot path allocation-free).
    /// Materialized: one contiguous memcpy (column-major column range).
    /// Procedural: synthesized entry-wise.  Bitwise identical either way.
    pub fn panel(&self, p: usize, mode: usize, c0: usize, c1: usize, mut buf: Vec<f32>) -> Matrix {
        match self {
            MapSource::Materialized(m) => {
                let mat = m.replicas[p].mode(mode);
                let rows = mat.rows();
                assert!(c0 <= c1 && c1 <= mat.cols(), "panel [{c0},{c1}) out of range");
                buf.clear();
                buf.extend_from_slice(&mat.data()[c0 * rows..c1 * rows]);
                Matrix::from_vec(rows, c1 - c0, buf)
            }
            MapSource::Procedural(pm) => {
                let spec = &pm.spec;
                spec.fill_panel(pm.kept[p], mode, c0, c1, &mut buf);
                Matrix::from_vec(spec.rows(mode), c1 - c0, buf)
            }
        }
    }

    /// The stacked column panel `[[U_1]; …; [U_P]][:, c0..c1)` over the
    /// kept replicas — the `(P·L) × w` operand of the replica-batched
    /// mode-1 GEMM and of the streamed recovery solve.
    pub fn stacked_panel(&self, mode: usize, c0: usize, c1: usize, mut buf: Vec<f32>) -> Matrix {
        match self {
            MapSource::Materialized(m) => {
                let rows: usize = m.reduced[mode];
                let total = m.p_count() * rows;
                assert!(
                    c0 <= c1 && c1 <= m.dims[mode],
                    "panel [{c0},{c1}) out of range"
                );
                buf.clear();
                buf.reserve(total * (c1 - c0));
                for col in c0..c1 {
                    for rep in &m.replicas {
                        buf.extend_from_slice(rep.mode(mode).col(col));
                    }
                }
                Matrix::from_vec(total, c1 - c0, buf)
            }
            MapSource::Procedural(pm) => {
                let spec = &pm.spec;
                spec.fill_stacked_panel(&pm.kept, mode, c0, c1, &mut buf);
                Matrix::from_vec(pm.kept.len() * spec.rows(mode), c1 - c0, buf)
            }
        }
    }
}

impl From<ReplicaMaps> for MapSource {
    fn from(m: ReplicaMaps) -> Self {
        MapSource::Materialized(m)
    }
}

/// The **retired** sequential-stream generator (per-replica xoshiro
/// streams, anchors overwritten, `1/√dim` scale applied after) — kept only
/// as the distributional oracle for the statistical tests: the
/// counter-based generator must match its moments, anchor sharing, and
/// cross-replica independence, even though the individual values differ.
#[doc(hidden)]
pub fn generate_stream_oracle(
    dims: [usize; 3],
    reduced: [usize; 3],
    p_count: usize,
    anchor_rows: usize,
    seed: u64,
) -> Vec<CompressionMaps> {
    let [i, j, k] = dims;
    let [l, m, n] = reduced;
    assert!(anchor_rows <= l && anchor_rows <= m && anchor_rows <= n);
    let mut anchor_rng = Xoshiro256::seed_from_u64(seed ^ 0xA11C_0000);
    let anchor_u = Matrix::random_normal(anchor_rows, i, &mut anchor_rng);
    let anchor_v = Matrix::random_normal(anchor_rows, j, &mut anchor_rng);
    let anchor_w = Matrix::random_normal(anchor_rows, k, &mut anchor_rng);
    let overwrite_anchor = |mat: &mut Matrix, anchor: &Matrix| {
        for r in 0..anchor.rows() {
            for c in 0..anchor.cols() {
                mat.set(r, c, anchor.get(r, c));
            }
        }
    };
    let base = Xoshiro256::seed_from_u64(seed);
    (0..p_count)
        .map(|p| {
            let mut rng = base.stream(p as u64 + 1);
            let mut u = Matrix::random_normal(l, i, &mut rng);
            let mut v = Matrix::random_normal(m, j, &mut rng);
            let mut w = Matrix::random_normal(n, k, &mut rng);
            overwrite_anchor(&mut u, &anchor_u);
            overwrite_anchor(&mut v, &anchor_v);
            overwrite_anchor(&mut w, &anchor_w);
            u.scale(1.0 / (i as f32).sqrt());
            v.scale(1.0 / (j as f32).sqrt());
            w.scale(1.0 / (k as f32).sqrt());
            CompressionMaps { u, v, w }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_count() {
        let maps = ReplicaMaps::generate([40, 30, 20], [8, 6, 4], 7, 3, 1);
        assert_eq!(maps.p_count(), 7);
        for r in &maps.replicas {
            assert_eq!((r.u.rows(), r.u.cols()), (8, 40));
            assert_eq!((r.v.rows(), r.v.cols()), (6, 30));
            assert_eq!((r.w.rows(), r.w.cols()), (4, 20));
        }
    }

    #[test]
    fn anchor_rows_shared_rest_distinct() {
        let maps = ReplicaMaps::generate([20, 20, 20], [6, 6, 6], 4, 2, 2);
        let u0 = &maps.replicas[0].u;
        for p in 1..4 {
            let up = &maps.replicas[p].u;
            // first S rows identical
            for r in 0..2 {
                for c in 0..20 {
                    assert_eq!(u0.get(r, c), up.get(r, c), "anchor row {r} differs");
                }
            }
            // later rows differ
            let same = (0..20).filter(|&c| u0.get(3, c) == up.get(3, c)).count();
            assert!(same < 3, "non-anchor rows should differ");
        }
    }

    #[test]
    fn v_w_fully_distinct_across_replicas() {
        let maps = ReplicaMaps::generate([15, 15, 15], [5, 5, 5], 3, 2, 3);
        let v0 = &maps.replicas[0].v;
        let v1 = &maps.replicas[1].v;
        assert!(v0.sub(v1).max_abs() > 1e-6);
    }

    #[test]
    fn modes_distinct_within_replica() {
        // One replica's U/V/W must not repeat values (mode is keyed).
        let maps = ReplicaMaps::generate([12, 12, 12], [4, 4, 4], 1, 1, 5);
        let r = &maps.replicas[0];
        assert!(r.u.sub(&r.v).max_abs() > 1e-6);
        assert!(r.v.sub(&r.w).max_abs() > 1e-6);
    }

    #[test]
    fn stacked_shapes() {
        let maps = ReplicaMaps::generate([25, 24, 23], [5, 4, 3], 6, 2, 4);
        assert_eq!(maps.stacked_u().rows(), 30);
        assert_eq!(maps.stacked_u().cols(), 25);
        assert_eq!(maps.stacked_v().rows(), 24);
        assert_eq!(maps.stacked_w().rows(), 18);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = ReplicaMaps::generate([10, 10, 10], [4, 4, 4], 2, 1, 9);
        let b = ReplicaMaps::generate([10, 10, 10], [4, 4, 4], 2, 1, 9);
        assert_eq!(a.replicas[1].u.data(), b.replicas[1].u.data());
        let c = ReplicaMaps::generate([10, 10, 10], [4, 4, 4], 2, 1, 10);
        assert_ne!(c.replicas[1].u.data(), b.replicas[1].u.data());
    }

    #[test]
    #[should_panic(expected = "anchor rows")]
    fn anchor_larger_than_l_rejected() {
        let _ = ReplicaMaps::generate([10, 10, 10], [4, 4, 4], 2, 5, 1);
    }

    #[test]
    fn subset_is_shared_not_cloned() {
        let maps = ReplicaMaps::generate([16, 16, 16], [5, 5, 5], 4, 2, 11);
        let sub = maps.subset(&[0, 2, 3]);
        assert_eq!(sub.p_count(), 3);
        // O(1) subset: the kept replicas are the same allocations.
        assert!(Arc::ptr_eq(&maps.replicas[0], &sub.replicas[0]));
        assert!(Arc::ptr_eq(&maps.replicas[2], &sub.replicas[1]));
        assert!(Arc::ptr_eq(&maps.replicas[3], &sub.replicas[2]));
    }

    #[test]
    fn tiers_are_bitwise_identical() {
        let dims = [33, 21, 17];
        let reduced = [7, 6, 5];
        let mat = MapSource::generate(dims, reduced, 4, 3, 77, MapTier::Materialized);
        let proc_ = MapSource::generate(dims, reduced, 4, 3, 77, MapTier::Procedural);
        for mode in 0..3 {
            for p in 0..4 {
                // Whole-map panel and a strict interior panel.
                for (c0, c1) in [(0, dims[mode]), (3, dims[mode].min(11))] {
                    let a = mat.panel(p, mode, c0, c1, Vec::new());
                    let b = proc_.panel(p, mode, c0, c1, Vec::new());
                    assert_eq!(a.data(), b.data(), "p={p} mode={mode} [{c0},{c1})");
                }
            }
            let a = mat.stacked_panel(mode, 2, 9, Vec::new());
            let b = proc_.stacked_panel(mode, 2, 9, Vec::new());
            assert_eq!(a.data(), b.data(), "stacked mode={mode}");
        }
    }

    #[test]
    fn panels_agree_with_materialized_slices() {
        // A panel must equal the same column range of the stored matrix —
        // and the stacked panel must equal the vstack's column range.
        let src = MapSource::generate([19, 13, 11], [5, 4, 3], 3, 2, 21, MapTier::Materialized);
        let maps = src.materialized().unwrap();
        let pan = src.panel(1, 0, 4, 9, Vec::new());
        assert_eq!(pan.data(), maps.replicas[1].u.slice_cols(4, 9).data());
        let st = src.stacked_panel(0, 4, 9, Vec::new());
        assert_eq!(st.data(), maps.stacked_u().slice_cols(4, 9).data());
    }

    #[test]
    fn procedural_subset_preserves_generation_identity() {
        let full = MapSource::generate([14, 14, 14], [4, 4, 4], 5, 2, 31, MapTier::Procedural);
        let sub = full.subset(&[1, 4]);
        assert_eq!(sub.p_count(), 2);
        // Position 1 of the subset is original replica 4: identical panels.
        let a = full.panel(4, 2, 0, 14, Vec::new());
        let b = sub.panel(1, 2, 0, 14, Vec::new());
        assert_eq!(a.data(), b.data());
        // Subset-of-subset composes.
        let sub2 = sub.subset(&[1]);
        let c = sub2.panel(0, 2, 0, 14, Vec::new());
        assert_eq!(a.data(), c.data());
    }

    #[test]
    fn panel_assembly_is_order_invariant() {
        // Random access means assembling a map from panels in any split
        // must give the same bytes.
        let src = MapSource::generate([23, 9, 9], [6, 3, 3], 2, 1, 41, MapTier::Procedural);
        let whole = src.panel(1, 0, 0, 23, Vec::new());
        let mut pieced = vec![0.0f32; 6 * 23];
        for (c0, c1) in [(11, 23), (0, 5), (5, 11)] {
            let pan = src.panel(1, 0, c0, c1, Vec::new());
            pieced[c0 * 6..c1 * 6].copy_from_slice(pan.data());
        }
        assert_eq!(whole.data(), &pieced[..]);
    }

    #[test]
    fn counter_generator_matches_stream_oracle_statistics() {
        // The retired sequential generator is the distributional oracle:
        // same N(0, 1/dim) family, shared anchors, independent replicas.
        let dims = [200, 150, 100];
        let reduced = [12, 10, 8];
        let new = ReplicaMaps::generate(dims, reduced, 3, 2, 55);
        let old = generate_stream_oracle(dims, reduced, 3, 2, 55);
        let stats = |m: &Matrix| {
            let n = m.data().len() as f64;
            let mean: f64 = m.data().iter().map(|&x| x as f64).sum::<f64>() / n;
            let var: f64 =
                m.data().iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
            (mean, var)
        };
        for (mode, dim) in [(0usize, 200usize), (1, 150), (2, 100)] {
            let (nm, nv) = stats(new.replicas[0].mode(mode));
            let (om, ov) = stats(old[0].mode(mode));
            let sd = 1.0 / (dim as f64).sqrt();
            assert!(nm.abs() < 0.2 * sd, "mode {mode} mean {nm} vs sd {sd}");
            assert!(om.abs() < 0.2 * sd, "oracle mode {mode} mean {om} vs sd {sd}");
            assert!((nv / ov - 1.0).abs() < 0.25, "mode {mode} var {nv} vs oracle {ov}");
        }
        // Cross-replica correlation of non-anchor rows ≈ 0 in both.
        let corr = |a: &Matrix, b: &Matrix| {
            let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
            for c in 0..a.cols() {
                for r in 2..a.rows() {
                    let (x, y) = (a.get(r, c) as f64, b.get(r, c) as f64);
                    dot += x * y;
                    na += x * x;
                    nb += y * y;
                }
            }
            dot / (na.sqrt() * nb.sqrt())
        };
        assert!(corr(&new.replicas[0].u, &new.replicas[1].u).abs() < 0.08);
        assert!(corr(&old[0].u, &old[1].u).abs() < 0.08);
    }
}
