//! Replica compression-matrix generation — Alg. 2 line 1.
//!
//! Each replica `p` gets Gaussian `U_p (L×I)`, `V_p (M×J)`, `W_p (N×K)`.
//! The first `S` **rows** of every `U_p` (and of `V_p`, `W_p`) are identical
//! across replicas — the PARACOMP anchor construction: since
//! `A_p = U_p·A·Π_p·Σ_p`, shared leading rows give every replica the same
//! leading `S×R` sub-block of `U·A` up to its own `Π_p Σ_p`, which is
//! exactly what lines 5–7 of Alg. 2 exploit to undo the per-replica
//! permutation and scaling; Alg. 2 line 5 divides the columns of *all
//! three* factor matrices by their anchor maxima, which requires anchors in
//! all three compression matrices.  (The paper's text says "columns"; for
//! `U_p ∈ R^{L×I}` the anchor must be on the compressed side, i.e. rows —
//! column anchors would not survive the product `U_p A`.)

use crate::linalg::Matrix;
use crate::util::rng::Xoshiro256;

/// One replica's compression matrices.
#[derive(Clone, Debug)]
pub struct CompressionMaps {
    pub u: Matrix, // L × I
    pub v: Matrix, // M × J
    pub w: Matrix, // N × K
}

/// The full set of `P` replicas with `S` shared anchor rows in `U_p`.
#[derive(Clone, Debug)]
pub struct ReplicaMaps {
    pub replicas: Vec<CompressionMaps>,
    pub anchor_rows: usize,
    pub dims: [usize; 3],
    pub reduced: [usize; 3],
}

impl ReplicaMaps {
    /// Generates `p_count` replicas for compressing `dims = [I,J,K]` down to
    /// `reduced = [L,M,N]`, with `anchor_rows = S` shared leading rows of
    /// each `U_p`.  Entries are scaled `N(0, 1/√L)`-style so compressed
    /// magnitudes stay O(‖X‖) independent of the compression ratio.
    pub fn generate(
        dims: [usize; 3],
        reduced: [usize; 3],
        p_count: usize,
        anchor_rows: usize,
        seed: u64,
    ) -> Self {
        let [i, j, k] = dims;
        let [l, m, n] = reduced;
        assert!(
            anchor_rows <= l && anchor_rows <= m && anchor_rows <= n,
            "anchor rows S={anchor_rows} exceed reduced dims {reduced:?}"
        );
        assert!(p_count >= 1, "need at least one replica");
        let mut anchor_rng = Xoshiro256::seed_from_u64(seed ^ 0xA11C_0000);
        // Shared anchor blocks (S×dim), common to every replica, per mode.
        let anchor_u = Matrix::random_normal(anchor_rows, i, &mut anchor_rng);
        let anchor_v = Matrix::random_normal(anchor_rows, j, &mut anchor_rng);
        let anchor_w = Matrix::random_normal(anchor_rows, k, &mut anchor_rng);

        let overwrite_anchor = |mat: &mut Matrix, anchor: &Matrix| {
            for r in 0..anchor.rows() {
                for c in 0..anchor.cols() {
                    mat.set(r, c, anchor.get(r, c));
                }
            }
        };

        let base = Xoshiro256::seed_from_u64(seed);
        let mut replicas = Vec::with_capacity(p_count);
        for p in 0..p_count {
            let mut rng = base.stream(p as u64 + 1);
            let mut u = Matrix::random_normal(l, i, &mut rng);
            let mut v = Matrix::random_normal(m, j, &mut rng);
            let mut w = Matrix::random_normal(n, k, &mut rng);
            overwrite_anchor(&mut u, &anchor_u);
            overwrite_anchor(&mut v, &anchor_v);
            overwrite_anchor(&mut w, &anchor_w);
            // Variance normalization (1/√dim) keeps compressed magnitudes
            // O(‖X‖) independent of the compression ratio.
            u.scale(1.0 / (i as f32).sqrt());
            v.scale(1.0 / (j as f32).sqrt());
            w.scale(1.0 / (k as f32).sqrt());
            replicas.push(CompressionMaps { u, v, w });
        }
        Self {
            replicas,
            anchor_rows,
            dims,
            reduced,
        }
    }

    pub fn p_count(&self) -> usize {
        self.replicas.len()
    }

    /// Keeps only the replicas at `indices` (used after dropping replicas
    /// whose proxy decomposition failed to converge — Alg. 2's "drop it
    /// (them) in time").
    pub fn subset(&self, indices: &[usize]) -> ReplicaMaps {
        ReplicaMaps {
            replicas: indices.iter().map(|&i| self.replicas[i].clone()).collect(),
            anchor_rows: self.anchor_rows,
            dims: self.dims,
            reduced: self.reduced,
        }
    }

    /// Stacked `[U_1; …; U_P]` — the LHS of the recovery least squares
    /// (Eq. 4) for mode 1.
    pub fn stacked_u(&self) -> Matrix {
        let refs: Vec<&Matrix> = self.replicas.iter().map(|r| &r.u).collect();
        Matrix::vstack(&refs)
    }

    /// Stacked `[V_1; …; V_P]`.
    pub fn stacked_v(&self) -> Matrix {
        let refs: Vec<&Matrix> = self.replicas.iter().map(|r| &r.v).collect();
        Matrix::vstack(&refs)
    }

    /// Stacked `[W_1; …; W_P]`.
    pub fn stacked_w(&self) -> Matrix {
        let refs: Vec<&Matrix> = self.replicas.iter().map(|r| &r.w).collect();
        Matrix::vstack(&refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_count() {
        let maps = ReplicaMaps::generate([40, 30, 20], [8, 6, 4], 7, 3, 1);
        assert_eq!(maps.p_count(), 7);
        for r in &maps.replicas {
            assert_eq!((r.u.rows(), r.u.cols()), (8, 40));
            assert_eq!((r.v.rows(), r.v.cols()), (6, 30));
            assert_eq!((r.w.rows(), r.w.cols()), (4, 20));
        }
    }

    #[test]
    fn anchor_rows_shared_rest_distinct() {
        let maps = ReplicaMaps::generate([20, 20, 20], [6, 6, 6], 4, 2, 2);
        let u0 = &maps.replicas[0].u;
        for p in 1..4 {
            let up = &maps.replicas[p].u;
            // first S rows identical
            for r in 0..2 {
                for c in 0..20 {
                    assert_eq!(u0.get(r, c), up.get(r, c), "anchor row {r} differs");
                }
            }
            // later rows differ
            let same = (0..20).filter(|&c| u0.get(3, c) == up.get(3, c)).count();
            assert!(same < 3, "non-anchor rows should differ");
        }
    }

    #[test]
    fn v_w_fully_distinct_across_replicas() {
        let maps = ReplicaMaps::generate([15, 15, 15], [5, 5, 5], 3, 2, 3);
        let v0 = &maps.replicas[0].v;
        let v1 = &maps.replicas[1].v;
        assert!(v0.sub(v1).max_abs() > 1e-6);
    }

    #[test]
    fn stacked_shapes() {
        let maps = ReplicaMaps::generate([25, 24, 23], [5, 4, 3], 6, 2, 4);
        assert_eq!(maps.stacked_u().rows(), 30);
        assert_eq!(maps.stacked_u().cols(), 25);
        assert_eq!(maps.stacked_v().rows(), 24);
        assert_eq!(maps.stacked_w().rows(), 18);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = ReplicaMaps::generate([10, 10, 10], [4, 4, 4], 2, 1, 9);
        let b = ReplicaMaps::generate([10, 10, 10], [4, 4, 4], 2, 1, 9);
        assert_eq!(a.replicas[1].u.data(), b.replicas[1].u.data());
    }

    #[test]
    #[should_panic(expected = "anchor rows")]
    fn anchor_larger_than_l_rejected() {
        let _ = ReplicaMaps::generate([10, 10, 10], [4, 4, 4], 2, 5, 1);
    }
}
