//! Blocked, parallel streaming compression — Fig. 2 of the paper.
//!
//! The source tensor is read block-by-block (never materialized whole);
//! each block `T = X(i0:i1, j0:j1, k0:k1)` contributes
//! `Comp(T, U_p[:, i0:i1], V_p[:, j0:j1], W_p[:, k0:k1])` to every replica's
//! proxy tensor, and compression is linear so contributions just add.
//! Blocks are distributed over the worker pool ("the compressions of all
//! tensor blocks are independent"); per-replica accumulators are sharded to
//! avoid a single contended lock.
//!
//! The per-block TTM chain is pluggable ([`BlockCompressor`]): the pure-rust
//! backend below is the "Baseline"/"Parallel on CPU" arm of Figs. 5–7, and
//! `runtime::XlaCompressor` (the AOT Pallas kernel) is the "GPU tensor
//! cores" arm.

use super::comp::comp_dense_with;
use super::maps::ReplicaMaps;
use crate::linalg::backend::{ComputeBackend, SerialBackend};
use crate::linalg::Matrix;
use crate::mixed::MixedPrecision;
use crate::tensor::{BlockRange, BlockSpec3, DenseTensor, TensorSource};
use crate::util::threadpool::ThreadPool;
use std::sync::Mutex;

/// A backend that compresses one tensor block against matrix column-slices.
pub trait BlockCompressor: Sync {
    /// `Comp(T, U_blk, V_blk, W_blk)` where `T` is `di×dj×dk` and the
    /// matrices are `L×di`, `M×dj`, `N×dk` column-slices.
    fn compress_block(
        &self,
        t: &DenseTensor,
        u_blk: &Matrix,
        v_blk: &Matrix,
        w_blk: &Matrix,
    ) -> DenseTensor;

    /// Human-readable backend name (for metrics/logs).
    fn name(&self) -> &'static str;
}

/// Pure-rust blocked TTM chain with selectable precision.
///
/// Dispatches through the **serial** [`ComputeBackend`] reference: blocks
/// are already fanned out over the worker pool, so the per-block chain
/// must not nest another pool.
pub struct RustCompressor {
    pub precision: MixedPrecision,
}

impl BlockCompressor for RustCompressor {
    fn compress_block(
        &self,
        t: &DenseTensor,
        u_blk: &Matrix,
        v_blk: &Matrix,
        w_blk: &Matrix,
    ) -> DenseTensor {
        comp_dense_with(t, u_blk, v_blk, w_blk, self.precision, &SerialBackend)
    }

    fn name(&self) -> &'static str {
        match self.precision {
            MixedPrecision::Full => "rust-f32",
            MixedPrecision::F16 => "rust-f16-split",
            MixedPrecision::Bf16 => "rust-bf16-split",
        }
    }
}

/// Streams `src` through the block grid and returns one proxy tensor
/// `Y_p (L×M×N)` per replica.
///
/// `threads = 1` reproduces the sequential "Baseline"; more threads give the
/// "Parallel" arms.
pub fn compress_source(
    src: &dyn TensorSource,
    maps: &ReplicaMaps,
    block: [usize; 3],
    compressor: &dyn BlockCompressor,
    pool: &ThreadPool,
) -> Vec<DenseTensor> {
    let [l, m, n] = maps.reduced;
    let p_count = maps.p_count();
    let blocks = block_grid(maps.dims, block);

    // One accumulator per replica, each behind its own mutex; workers lock a
    // replica only for the cheap (L·M·N) add, not during the GEMMs.
    let accs: Vec<Mutex<DenseTensor>> = (0..p_count)
        .map(|_| Mutex::new(DenseTensor::zeros(l, m, n)))
        .collect();

    pool.for_each_chunk(blocks.len(), 1, |range| {
        for blk in &blocks[range] {
            let t = src.block(blk);
            for (p, rep) in maps.replicas.iter().enumerate() {
                // Column-slices of the compression matrices (cheap: we
                // transpose-slice via dedicated helper below).
                let u_blk = slice_cols(&rep.u, blk.i0, blk.i1);
                let v_blk = slice_cols(&rep.v, blk.j0, blk.j1);
                let w_blk = slice_cols(&rep.w, blk.k0, blk.k1);
                let contrib = compressor.compress_block(&t, &u_blk, &v_blk, &w_blk);
                let mut acc = accs[p].lock().unwrap();
                let acc_data = acc.data_mut();
                for (dst, &srcv) in acc_data.iter_mut().zip(contrib.data()) {
                    *dst += srcv;
                }
            }
        }
    });

    accs.into_iter()
        .map(|m| m.into_inner().unwrap())
        .collect()
}

/// Materializes the block grid once so the pool can chunk over indices
/// ([`ThreadPool::for_each_chunk`]) instead of hand-rolling one spawn per
/// block at every streaming call site.
fn block_grid(dims: [usize; 3], block: [usize; 3]) -> Vec<BlockRange> {
    BlockSpec3::new(dims, block).iter().collect()
}

/// `M[:, c0..c1]` — contiguous memcpy in column-major.
fn slice_cols(m: &Matrix, c0: usize, c1: usize) -> Matrix {
    m.slice_cols(c0, c1)
}

/// Replica-batched streaming compression (§Perf optimization).
///
/// The mode-1 product dominates each block's TTM chain (`L·d³` vs `M·L·d²`
/// and `N·L·M·d`), and it is the *same* `X_(1)` for every replica — so all
/// `P` mode-1 products fuse into one GEMM against the stacked
/// `[U_1; …; U_P] (P·L × d)`: fewer, larger GEMMs (better packing/cache
/// reuse in the blocked kernel).  Modes 2 and 3 remain per-replica (each
/// replica has its own `V_p`, `W_p`).  Only valid for the plain f32 rust
/// path; the mixed-precision and XLA backends use [`compress_source`].
pub fn compress_source_batched(
    src: &dyn TensorSource,
    maps: &ReplicaMaps,
    block: [usize; 3],
    pool: &ThreadPool,
) -> Vec<DenseTensor> {
    use crate::linalg::Trans;
    let [l, m, n] = maps.reduced;
    let p_count = maps.p_count();
    let blocks = block_grid(maps.dims, block);
    let u_stack = maps.stacked_u(); // (P·L) × I

    let accs: Vec<Mutex<DenseTensor>> = (0..p_count)
        .map(|_| Mutex::new(DenseTensor::zeros(l, m, n)))
        .collect();

    // Per-block contractions dispatch through the serial reference backend:
    // parallelism lives at block granularity (this chunked loop), so the
    // inner chain must not nest another pool.
    let be = SerialBackend;
    pool.for_each_chunk(blocks.len(), 1, |range| {
        for blk in &blocks[range] {
            let t = src.block(blk);
            let [di, dj, dk] = t.dims();
            // One batched mode-1 GEMM for all replicas:
            // X_(1) is a free view of the column-major block.
            let u_blk = u_stack.slice_cols(blk.i0, blk.i1); // (P·L) × di
            let x1 = Matrix::from_vec(di, dj * dk, t.data().to_vec());
            let mut y1_all = Matrix::zeros(p_count * l, dj * dk);
            be.gemm(1.0, &u_blk, Trans::No, &x1, Trans::No, 0.0, &mut y1_all);
            // Per replica, unfold-free chain (§Perf): in column-major,
            //   Y1 (l, dj, dk) viewed as (l·dj × dk) is contiguous →
            //   mode-3 is ONE gemm against W_blkᵀ;
            //   then each frontal slice of (l, dj, n) is a contiguous
            //   (l × dj) matrix → mode-2 is a batched GEMM of n small
            //   slices against V_blkᵀ (ComputeBackend::gemm_batch).
            for (p, rep) in maps.replicas.iter().enumerate() {
                let y1 = y1_all.slice_rows(p * l, (p + 1) * l); // l × dj·dk
                let v_blk = rep.v.slice_cols(blk.j0, blk.j1); // m × dj
                let w_blk = rep.w.slice_cols(blk.k0, blk.k1); // n × dk
                // mode 3: (l·dj × dk) @ (dk × n) → (l·dj × n)
                let y1_flat = Matrix::from_vec(l * dj, dk, y1.into_vec());
                let mut y13 = Matrix::zeros(l * dj, n);
                be.gemm(1.0, &y1_flat, Trans::No, &w_blk, Trans::Yes, 0.0, &mut y13);
                // mode 2, batched over output slices kn: (l × dj) @ (dj × m)
                let slices: Vec<Matrix> = (0..n)
                    .map(|kn| Matrix::from_vec(l, dj, y13.col(kn).to_vec()))
                    .collect();
                let mut outs: Vec<Matrix> = (0..n).map(|_| Matrix::zeros(l, m)).collect();
                be.gemm_batch(1.0, &slices, Trans::No, &v_blk, Trans::Yes, 0.0, &mut outs);
                let mut acc = accs[p].lock().unwrap();
                let acc_data = acc.data_mut();
                for (kn, out) in outs.iter().enumerate() {
                    for (dst, &s) in acc_data[kn * l * m..(kn + 1) * l * m]
                        .iter_mut()
                        .zip(out.data())
                    {
                        *dst += s;
                    }
                }
            }
        }
    });

    accs.into_iter()
        .map(|m| m.into_inner().unwrap())
        .collect()
}

/// First-stage **sparse** streaming compression for the compressed-sensing
/// construction (§IV-D): `Z = X ×₁U ×₂V ×₃W` with sparse ±1 maps, computed
/// block-wise in parallel.  `Z (αL×βM×γN)` is the intermediate the `P`
/// cheap dense second-stage compressions then act on.
pub fn compress_source_sparse(
    src: &dyn TensorSource,
    u: &crate::compress::SparseSignMatrix,
    v: &crate::compress::SparseSignMatrix,
    w: &crate::compress::SparseSignMatrix,
    block: [usize; 3],
    pool: &ThreadPool,
) -> DenseTensor {
    use crate::tensor::unfold::{refold_1, refold_2, refold_3, unfold_2, unfold_3};
    let dims = src.dims();
    assert_eq!(u.cols(), dims[0]);
    assert_eq!(v.cols(), dims[1]);
    assert_eq!(w.cols(), dims[2]);
    let (al, bm, gn) = (u.rows(), v.rows(), w.rows());
    let blocks = block_grid(dims, block);
    let acc = Mutex::new(DenseTensor::zeros(al, bm, gn));

    pool.for_each_chunk(blocks.len(), 1, |range| {
        for blk in &blocks[range] {
            let t = src.block(blk);
            let [di, dj, dk] = t.dims();
            // mode 1: sparse U slice (αL×di) · T_(1) (di × dj·dk).  The
            // ±1-sparse products are O(nnz) scalar kernels and stay
            // outside ComputeBackend deliberately — there is no dense
            // contraction here to dispatch.
            let u_blk = u.slice_cols(blk.i0, blk.i1);
            let t1 = Matrix::from_vec(di, dj * dk, t.data().to_vec());
            let y1 = refold_1(&u_blk.mul_dense(&t1), [al, dj, dk]);
            // mode 2
            let v_blk = v.slice_cols(blk.j0, blk.j1);
            let y2 = refold_2(&v_blk.mul_dense(&unfold_2(&y1)), [al, bm, dk]);
            // mode 3
            let w_blk = w.slice_cols(blk.k0, blk.k1);
            let y3 = refold_3(&w_blk.mul_dense(&unfold_3(&y2)), [al, bm, gn]);
            let mut a = acc.lock().unwrap();
            for (dst, &s) in a.data_mut().iter_mut().zip(y3.data()) {
                *dst += s;
            }
        }
    });
    acc.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::comp::comp_dense;
    use crate::tensor::{InMemorySource, LowRankGenerator};
    use crate::util::rng::Xoshiro256;

    fn full_comp(src: &DenseTensor, maps: &ReplicaMaps, p: usize) -> DenseTensor {
        let rep = &maps.replicas[p];
        comp_dense(src, &rep.u, &rep.v, &rep.w, MixedPrecision::Full)
    }

    #[test]
    fn blocked_equals_unblocked() {
        let mut rng = Xoshiro256::seed_from_u64(140);
        let t = DenseTensor::random_normal([12, 10, 8], &mut rng);
        let maps = ReplicaMaps::generate([12, 10, 8], [4, 3, 2], 3, 2, 141);
        let src = InMemorySource::new(t.clone());
        let pool = ThreadPool::new(4);
        let comp = RustCompressor {
            precision: MixedPrecision::Full,
        };
        let proxies = compress_source(&src, &maps, [5, 4, 3], &comp, &pool);
        assert_eq!(proxies.len(), 3);
        for p in 0..3 {
            let expected = full_comp(&t, &maps, p);
            let err = proxies[p].rel_error(&expected);
            assert!(err < 1e-4, "replica {p} err {err}");
        }
    }

    #[test]
    fn single_thread_matches_parallel() {
        let gen = LowRankGenerator::new(16, 16, 16, 2, 142);
        let maps = ReplicaMaps::generate([16, 16, 16], [5, 5, 5], 2, 2, 143);
        let comp = RustCompressor {
            precision: MixedPrecision::Full,
        };
        let seq = compress_source(&gen, &maps, [4, 4, 4], &comp, &ThreadPool::new(1));
        let par = compress_source(&gen, &maps, [4, 4, 4], &comp, &ThreadPool::new(8));
        for p in 0..2 {
            assert!(seq[p].rel_error(&par[p]) < 1e-5);
        }
    }

    #[test]
    fn block_size_invariance() {
        let gen = LowRankGenerator::new(9, 9, 9, 2, 144);
        let maps = ReplicaMaps::generate([9, 9, 9], [3, 3, 3], 2, 1, 145);
        let comp = RustCompressor {
            precision: MixedPrecision::Full,
        };
        let pool = ThreadPool::new(2);
        let a = compress_source(&gen, &maps, [9, 9, 9], &comp, &pool);
        let b = compress_source(&gen, &maps, [2, 3, 4], &comp, &pool);
        for p in 0..2 {
            assert!(a[p].rel_error(&b[p]) < 1e-4, "p={p} err {}", a[p].rel_error(&b[p]));
        }
    }

    #[test]
    fn batched_matches_unbatched() {
        let gen = LowRankGenerator::new(20, 18, 16, 2, 149);
        let maps = ReplicaMaps::generate([20, 18, 16], [6, 5, 4], 3, 2, 150);
        let pool = ThreadPool::new(2);
        let comp = RustCompressor { precision: MixedPrecision::Full };
        let plain = compress_source(&gen, &maps, [7, 6, 5], &comp, &pool);
        let batched = compress_source_batched(&gen, &maps, [7, 6, 5], &pool);
        for p in 0..3 {
            let err = batched[p].rel_error(&plain[p]);
            assert!(err < 1e-5, "replica {p} err {err}");
        }
    }

    #[test]
    fn sparse_stage_one_matches_dense_equivalent() {
        use crate::compress::SparseSignMatrix;
        let mut rng = Xoshiro256::seed_from_u64(148);
        let t = DenseTensor::random_normal([10, 9, 8], &mut rng);
        let src = InMemorySource::new(t.clone());
        let u = SparseSignMatrix::generate(6, 10, 2, 1);
        let v = SparseSignMatrix::generate(5, 9, 2, 2);
        let w = SparseSignMatrix::generate(4, 8, 2, 3);
        let pool = ThreadPool::new(3);
        let z = compress_source_sparse(&src, &u, &v, &w, [4, 3, 5], &pool);
        let z_ref = comp_dense(&t, &u.to_dense(), &v.to_dense(), &w.to_dense(), MixedPrecision::Full);
        assert!(z.rel_error(&z_ref) < 1e-4, "err {}", z.rel_error(&z_ref));
    }

    #[test]
    fn mixed_precision_backend_close() {
        let gen = LowRankGenerator::new(10, 10, 10, 2, 146);
        let maps = ReplicaMaps::generate([10, 10, 10], [4, 4, 4], 1, 1, 147);
        let pool = ThreadPool::new(2);
        let full = compress_source(
            &gen,
            &maps,
            [5, 5, 5],
            &RustCompressor {
                precision: MixedPrecision::Full,
            },
            &pool,
        );
        let mixed = compress_source(
            &gen,
            &maps,
            [5, 5, 5],
            &RustCompressor {
                precision: MixedPrecision::Bf16,
            },
            &pool,
        );
        let err = mixed[0].rel_error(&full[0]);
        assert!(err < 1e-2, "bf16 split err {err}");
        assert!(err > 0.0);
    }
}
