//! Blocked, parallel streaming compression — Fig. 2 of the paper.
//!
//! The source tensor is read block-by-block (never materialized whole);
//! each block `T = X(i0:i1, j0:j1, k0:k1)` contributes
//! `Comp(T, U_p[:, i0:i1], V_p[:, j0:j1], W_p[:, k0:k1])` to every replica's
//! proxy tensor, and compression is linear so contributions just add.
//!
//! Scheduling and accumulation live in [`super::engine`]: blocks stream
//! through deterministic shards with **shard-local accumulators** merged
//! once in shard order (no per-add mutex — the old `Mutex<DenseTensor>`
//! per-replica accumulators serialized every `L·M·N` add through one lock
//! per replica, and made results depend on thread scheduling).  With the
//! engine, every entry point below is bitwise-reproducible across thread
//! counts and prefetch settings, supports file-backed out-of-core sources
//! (prefetched reads), and reports incremental progress for mid-compression
//! checkpoints.
//!
//! The per-block TTM chain is pluggable ([`BlockCompressor`]): the pure-rust
//! backend below is the "Baseline"/"Parallel on CPU" arm of Figs. 5–7, and
//! `runtime::XlaCompressor` (the AOT Pallas kernel) is the "GPU tensor
//! cores" arm.

use super::comp::comp_dense_with;
use super::engine::{
    run_shard, stream_blocks, BlockConsumer, ProgressFn, ResumeState, StreamOptions, StreamStats,
};
use super::maps::MapSource;
use crate::linalg::backend::{ComputeBackend, SerialBackend};
use crate::linalg::{Matrix, Trans};
use crate::mixed::MixedPrecision;
use crate::tensor::{BlockRange, BlockSpec3, DenseTensor, TensorSource};
use crate::util::threadpool::ThreadPool;

/// A backend that compresses one tensor block against matrix column-slices.
pub trait BlockCompressor: Sync {
    /// `Comp(T, U_blk, V_blk, W_blk)` where `T` is `di×dj×dk` and the
    /// matrices are `L×di`, `M×dj`, `N×dk` column-slices.
    fn compress_block(
        &self,
        t: &DenseTensor,
        u_blk: &Matrix,
        v_blk: &Matrix,
        w_blk: &Matrix,
    ) -> DenseTensor;

    /// Human-readable backend name (for metrics/logs).
    fn name(&self) -> &'static str;
}

/// Pure-rust blocked TTM chain with selectable precision.
///
/// Dispatches through the **serial** [`ComputeBackend`] reference: blocks
/// are already fanned out over the worker pool, so the per-block chain
/// must not nest another pool.
pub struct RustCompressor {
    pub precision: MixedPrecision,
}

impl BlockCompressor for RustCompressor {
    fn compress_block(
        &self,
        t: &DenseTensor,
        u_blk: &Matrix,
        v_blk: &Matrix,
        w_blk: &Matrix,
    ) -> DenseTensor {
        comp_dense_with(t, u_blk, v_blk, w_blk, self.precision, &SerialBackend)
    }

    fn name(&self) -> &'static str {
        match self.precision {
            MixedPrecision::Full => "rust-f32",
            MixedPrecision::F16 => "rust-f16-split",
            MixedPrecision::Bf16 => "rust-bf16-split",
        }
    }
}

/// Resumable state for the proxy accumulators (one tensor per replica).
pub type ProxyResume = ResumeState<Vec<DenseTensor>>;

/// Materializes the block grid once so the scheduler can shard over
/// indices instead of hand-rolling one spawn per block at every call site.
fn block_grid(dims: [usize; 3], block: [usize; 3]) -> Vec<BlockRange> {
    BlockSpec3::new(dims, block).iter().collect()
}

#[inline]
fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

fn zero_proxies(maps: &MapSource) -> Vec<DenseTensor> {
    let [l, m, n] = maps.reduced();
    (0..maps.p_count()).map(|_| DenseTensor::zeros(l, m, n)).collect()
}

fn merge_proxies(into: &mut [DenseTensor], from: Vec<DenseTensor>) {
    for (a, b) in into.iter_mut().zip(from) {
        add_into(a.data_mut(), b.data());
    }
}

/// Per-worker scratch the per-block map panels are cut (materialized tier)
/// or synthesized (procedural tier) into — recycled across blocks so the
/// map path allocates nothing after warmup regardless of tier.
#[derive(Default)]
pub struct PanelScratch {
    u: Vec<f32>,
    v: Vec<f32>,
    w: Vec<f32>,
}

/// Per-replica compression through a pluggable [`BlockCompressor`].
struct CompressConsumer<'a> {
    maps: &'a MapSource,
    compressor: &'a dyn BlockCompressor,
}

impl BlockConsumer for CompressConsumer<'_> {
    type Acc = Vec<DenseTensor>;
    type Ctx = PanelScratch;

    fn make_ctx(&self) -> PanelScratch {
        PanelScratch::default()
    }

    fn zero_acc(&self) -> Vec<DenseTensor> {
        zero_proxies(self.maps)
    }

    fn process(
        &self,
        sc: &mut PanelScratch,
        blk: &BlockRange,
        t: DenseTensor,
        acc: &mut Vec<DenseTensor>,
    ) {
        for p in 0..self.maps.p_count() {
            // Per-block column panels of the compression maps, built in
            // recycled worker scratch (memcpy or generate-on-slice
            // depending on the tier — bitwise identical either way).
            let u_blk = self.maps.panel(p, 0, blk.i0, blk.i1, std::mem::take(&mut sc.u));
            let v_blk = self.maps.panel(p, 1, blk.j0, blk.j1, std::mem::take(&mut sc.v));
            let w_blk = self.maps.panel(p, 2, blk.k0, blk.k1, std::mem::take(&mut sc.w));
            let contrib = self.compressor.compress_block(&t, &u_blk, &v_blk, &w_blk);
            add_into(acc[p].data_mut(), contrib.data());
            sc.u = u_blk.into_vec();
            sc.v = v_blk.into_vec();
            sc.w = w_blk.into_vec();
        }
    }

    fn merge(&self, into: &mut Vec<DenseTensor>, from: Vec<DenseTensor>) {
        merge_proxies(into, from);
    }
}

/// Streams `src` through the block grid and returns one proxy tensor
/// `Y_p (L×M×N)` per replica.
///
/// `threads = 1` reproduces the sequential "Baseline"; more threads give the
/// "Parallel" arms (bitwise-identical results either way).
pub fn compress_source(
    src: &dyn TensorSource,
    maps: &MapSource,
    block: [usize; 3],
    compressor: &dyn BlockCompressor,
    pool: &ThreadPool,
) -> Vec<DenseTensor> {
    let opts = StreamOptions { threads: pool.size(), ..Default::default() };
    compress_source_opts(src, maps, block, compressor, &opts, None, None).0
}

/// [`compress_source`] with explicit scheduling options, optional resume
/// state, and an incremental-progress callback (checkpoint hook).
pub fn compress_source_opts(
    src: &dyn TensorSource,
    maps: &MapSource,
    block: [usize; 3],
    compressor: &dyn BlockCompressor,
    opts: &StreamOptions,
    resume: Option<ProxyResume>,
    on_progress: Option<ProgressFn<'_, Vec<DenseTensor>>>,
) -> (Vec<DenseTensor>, StreamStats) {
    let blocks = block_grid(maps.dims(), block);
    let consumer = CompressConsumer { maps, compressor };
    stream_blocks(src, &blocks, opts, &consumer, resume, on_progress)
}

/// Per-worker scratch for the replica-batched chain: every intermediate a
/// block needs — including the map panels of both tiers — recycled across
/// blocks so the hot loop allocates nothing but the accumulators
/// themselves (the old implementation copied each block into `x1` and
/// re-allocated `y1`/`y13`/`slices`/`outs` per block *per replica*).
#[derive(Default)]
pub struct BatchedScratch {
    y1_all: Vec<f32>,
    y1: Vec<f32>,
    y13: Vec<f32>,
    pool: Vec<Vec<f32>>,
    /// Stacked `[U_1; …; U_P]` column panel for the current block.
    u_stack: Vec<f32>,
    /// Per-replica `V_p` / `W_p` column panels (reused across replicas).
    v_blk: Vec<f32>,
    w_blk: Vec<f32>,
}

/// Re-sizes a recycled buffer without re-zeroing the retained prefix:
/// every consumer below fully overwrites what it takes (GEMM `beta = 0`
/// outputs, full repack/copy loops), so after warmup reuse is O(growth),
/// not an O(len) memset per block.
fn take_sized(slot: &mut Vec<f32>, len: usize) -> Vec<f32> {
    let mut v = std::mem::take(slot);
    v.resize(len, 0.0);
    v
}

fn pool_take(pool: &mut Vec<Vec<f32>>, len: usize) -> Vec<f32> {
    let mut v = pool.pop().unwrap_or_default();
    v.resize(len, 0.0);
    v
}

/// Replica-batched chain (§Perf optimization): one stacked mode-1 GEMM for
/// all replicas, then per-replica unfold-free modes 3 and 2.  The stacked
/// `[U_1; …; U_P]` operand is never held whole: each block takes only its
/// `(P·L) × di` column panel, cut or synthesized into worker scratch.
struct BatchedConsumer<'a> {
    maps: &'a MapSource,
}

impl BlockConsumer for BatchedConsumer<'_> {
    type Acc = Vec<DenseTensor>;
    type Ctx = BatchedScratch;

    fn make_ctx(&self) -> BatchedScratch {
        BatchedScratch::default()
    }

    fn zero_acc(&self) -> Vec<DenseTensor> {
        zero_proxies(self.maps)
    }

    fn process(
        &self,
        sc: &mut BatchedScratch,
        blk: &BlockRange,
        t: DenseTensor,
        acc: &mut Vec<DenseTensor>,
    ) {
        let [l, m, n] = self.maps.reduced();
        let p_count = self.maps.p_count();
        let [di, dj, dk] = t.dims();
        // Per-block contractions dispatch through the serial reference
        // backend: parallelism lives at block granularity in the engine, so
        // the inner chain must not nest another pool.
        let be = SerialBackend;

        // One batched mode-1 GEMM for all replicas.  `X_(1)` is a free
        // reinterpretation of the block's own column-major buffer — no copy.
        let u_blk = self
            .maps
            .stacked_panel(0, blk.i0, blk.i1, std::mem::take(&mut sc.u_stack)); // (P·L) × di
        let x1 = Matrix::from_vec(di, dj * dk, t.into_vec());
        let mut y1_all =
            Matrix::from_vec(p_count * l, dj * dk, take_sized(&mut sc.y1_all, p_count * l * dj * dk));
        be.gemm(1.0, &u_blk, Trans::No, &x1, Trans::No, 0.0, &mut y1_all);
        sc.pool.push(x1.into_vec()); // recycle the block buffer

        for p in 0..p_count {
            // m × dj and n × dk panels, recycled across replicas.
            let v_blk = self.maps.panel(p, 1, blk.j0, blk.j1, std::mem::take(&mut sc.v_blk));
            let w_blk = self.maps.panel(p, 2, blk.k0, blk.k1, std::mem::take(&mut sc.w_blk));
            // Rows p·l..(p+1)·l of Y1_all, repacked contiguously as the
            // (l·dj × dk) mode-3 operand (strided copy into reused scratch).
            let mut y1 = take_sized(&mut sc.y1, l * dj * dk);
            let all = y1_all.data();
            let rows_all = p_count * l;
            for c in 0..dj * dk {
                y1[c * l..(c + 1) * l]
                    .copy_from_slice(&all[c * rows_all + p * l..c * rows_all + (p + 1) * l]);
            }
            let y1_flat = Matrix::from_vec(l * dj, dk, y1);
            // mode 3: (l·dj × dk) @ (dk × n) → (l·dj × n)
            let mut y13 = Matrix::from_vec(l * dj, n, take_sized(&mut sc.y13, l * dj * n));
            be.gemm(1.0, &y1_flat, Trans::No, &w_blk, Trans::Yes, 0.0, &mut y13);
            // mode 2, batched over output slices kn: (l × dj) @ (dj × m)
            let mut slices = Vec::with_capacity(n);
            let mut outs = Vec::with_capacity(n);
            for kn in 0..n {
                let mut s = pool_take(&mut sc.pool, l * dj);
                s.copy_from_slice(y13.col(kn));
                slices.push(Matrix::from_vec(l, dj, s));
                outs.push(Matrix::from_vec(l, m, pool_take(&mut sc.pool, l * m)));
            }
            be.gemm_batch(1.0, &slices, Trans::No, &v_blk, Trans::Yes, 0.0, &mut outs);
            let acc_data = acc[p].data_mut();
            for (kn, out) in outs.iter().enumerate() {
                add_into(&mut acc_data[kn * l * m..(kn + 1) * l * m], out.data());
            }
            for s in slices {
                sc.pool.push(s.into_vec());
            }
            for o in outs {
                sc.pool.push(o.into_vec());
            }
            sc.y13 = y13.into_vec();
            sc.y1 = y1_flat.into_vec();
            sc.v_blk = v_blk.into_vec();
            sc.w_blk = w_blk.into_vec();
        }
        sc.y1_all = y1_all.into_vec();
        sc.u_stack = u_blk.into_vec();
        // The replica loop's takes/pushes balance, but the recycled block
        // buffer is a net +1 per block — cap the pool at one block's
        // working set (2n slice/out buffers + 1) so per-worker scratch
        // stays bounded over arbitrarily long streams.
        sc.pool.truncate(2 * n + 1);
    }

    fn merge(&self, into: &mut Vec<DenseTensor>, from: Vec<DenseTensor>) {
        merge_proxies(into, from);
    }
}

/// Replica-batched streaming compression (§Perf optimization).
///
/// The mode-1 product dominates each block's TTM chain (`L·d³` vs `M·L·d²`
/// and `N·L·M·d`), and it is the *same* `X_(1)` for every replica — so all
/// `P` mode-1 products fuse into one GEMM against the stacked
/// `[U_1; …; U_P] (P·L × d)`: fewer, larger GEMMs (better packing/cache
/// reuse in the blocked kernel).  Modes 2 and 3 remain per-replica (each
/// replica has its own `V_p`, `W_p`).  Only valid for the plain f32 rust
/// path; the mixed-precision and XLA backends use [`compress_source`].
pub fn compress_source_batched(
    src: &dyn TensorSource,
    maps: &MapSource,
    block: [usize; 3],
    pool: &ThreadPool,
) -> Vec<DenseTensor> {
    let opts = StreamOptions { threads: pool.size(), ..Default::default() };
    compress_source_batched_opts(src, maps, block, &opts, None, None).0
}

/// [`compress_source_batched`] with explicit scheduling options, resume
/// state, and progress callback.
pub fn compress_source_batched_opts(
    src: &dyn TensorSource,
    maps: &MapSource,
    block: [usize; 3],
    opts: &StreamOptions,
    resume: Option<ProxyResume>,
    on_progress: Option<ProgressFn<'_, Vec<DenseTensor>>>,
) -> (Vec<DenseTensor>, StreamStats) {
    let blocks = block_grid(maps.dims(), block);
    let consumer = BatchedConsumer { maps };
    stream_blocks(src, &blocks, opts, &consumer, resume, on_progress)
}

/// One shard's **raw** batched-path accumulator over blocks `b0..b1` of
/// the deterministic grid — the worker-side export of the shard-lease
/// subsystem (`serve/shard.rs`).  The returned proxies are exactly what
/// the engine would fold for this shard: a fresh zero accumulator folded
/// in ascending block order, with no extra merge.
pub fn compress_shard_batched(
    src: &dyn TensorSource,
    maps: &MapSource,
    block: [usize; 3],
    b0: usize,
    b1: usize,
) -> Vec<DenseTensor> {
    let blocks = block_grid(maps.dims(), block);
    run_shard(src, &blocks, &BatchedConsumer { maps }, b0, b1)
}

/// [`compress_shard_batched`] for the pluggable-compressor (plain) path.
pub fn compress_shard(
    src: &dyn TensorSource,
    maps: &MapSource,
    block: [usize; 3],
    compressor: &dyn BlockCompressor,
    b0: usize,
    b1: usize,
) -> Vec<DenseTensor> {
    let blocks = block_grid(maps.dims(), block);
    run_shard(src, &blocks, &CompressConsumer { maps, compressor }, b0, b1)
}

/// Zeroed proxy accumulators — the coordinator-side fold base for
/// [`fold_shard_proxies`] (identical to the engine's `zero_acc`).
pub fn zero_shard_proxies(maps: &MapSource) -> Vec<DenseTensor> {
    zero_proxies(maps)
}

/// Folds one completed shard accumulator into the running proxies — the
/// exact elementwise-add `merge` the engine applies, exposed so the
/// shard-lease coordinator reproduces the single-process reduction bit
/// for bit when folding worker partials in shard order.
pub fn fold_shard_proxies(into: &mut [DenseTensor], from: Vec<DenseTensor>) {
    merge_proxies(into, from);
}

/// First-stage **sparse** compression consumer (±1 maps; §IV-D).
struct SparseConsumer<'a> {
    u: &'a crate::compress::SparseSignMatrix,
    v: &'a crate::compress::SparseSignMatrix,
    w: &'a crate::compress::SparseSignMatrix,
}

impl BlockConsumer for SparseConsumer<'_> {
    type Acc = DenseTensor;
    type Ctx = ();

    fn make_ctx(&self) {}

    fn zero_acc(&self) -> DenseTensor {
        DenseTensor::zeros(self.u.rows(), self.v.rows(), self.w.rows())
    }

    fn process(&self, _ctx: &mut (), blk: &BlockRange, t: DenseTensor, acc: &mut DenseTensor) {
        use crate::tensor::unfold::{refold_1, refold_2, refold_3, unfold_2, unfold_3};
        let (al, bm, gn) = (self.u.rows(), self.v.rows(), self.w.rows());
        let [di, dj, dk] = t.dims();
        // mode 1: sparse U slice (αL×di) · T_(1) (di × dj·dk).  The
        // ±1-sparse products are O(nnz) scalar kernels and stay outside
        // ComputeBackend deliberately — there is no dense contraction here
        // to dispatch.  T_(1) reinterprets the block buffer (no copy).
        let u_blk = self.u.slice_cols(blk.i0, blk.i1);
        let t1 = Matrix::from_vec(di, dj * dk, t.into_vec());
        let y1 = refold_1(&u_blk.mul_dense(&t1), [al, dj, dk]);
        // mode 2
        let v_blk = self.v.slice_cols(blk.j0, blk.j1);
        let y2 = refold_2(&v_blk.mul_dense(&unfold_2(&y1)), [al, bm, dk]);
        // mode 3
        let w_blk = self.w.slice_cols(blk.k0, blk.k1);
        let y3 = refold_3(&w_blk.mul_dense(&unfold_3(&y2)), [al, bm, gn]);
        add_into(acc.data_mut(), y3.data());
    }

    fn merge(&self, into: &mut DenseTensor, from: DenseTensor) {
        add_into(into.data_mut(), from.data());
    }
}

/// First-stage **sparse** streaming compression for the compressed-sensing
/// construction (§IV-D): `Z = X ×₁U ×₂V ×₃W` with sparse ±1 maps, computed
/// block-wise in parallel.  `Z (αL×βM×γN)` is the intermediate the `P`
/// cheap dense second-stage compressions then act on.
pub fn compress_source_sparse(
    src: &dyn TensorSource,
    u: &crate::compress::SparseSignMatrix,
    v: &crate::compress::SparseSignMatrix,
    w: &crate::compress::SparseSignMatrix,
    block: [usize; 3],
    pool: &ThreadPool,
) -> DenseTensor {
    let opts = StreamOptions { threads: pool.size(), ..Default::default() };
    compress_source_sparse_opts(src, u, v, w, block, &opts).0
}

/// [`compress_source_sparse`] with explicit scheduling options.
pub fn compress_source_sparse_opts(
    src: &dyn TensorSource,
    u: &crate::compress::SparseSignMatrix,
    v: &crate::compress::SparseSignMatrix,
    w: &crate::compress::SparseSignMatrix,
    block: [usize; 3],
    opts: &StreamOptions,
) -> (DenseTensor, StreamStats) {
    let dims = src.dims();
    assert_eq!(u.cols(), dims[0]);
    assert_eq!(v.cols(), dims[1]);
    assert_eq!(w.cols(), dims[2]);
    let blocks = block_grid(dims, block);
    let consumer = SparseConsumer { u, v, w };
    stream_blocks(src, &blocks, opts, &consumer, None, None)
}

/// The retired per-add-mutex implementation, kept **only** as the
/// differential oracle for the shard-local engine (its accumulation order
/// is scheduling-dependent beyond one thread, which is exactly why it was
/// replaced).
#[doc(hidden)]
pub fn compress_source_locked(
    src: &dyn TensorSource,
    maps: &MapSource,
    block: [usize; 3],
    compressor: &dyn BlockCompressor,
    pool: &ThreadPool,
) -> Vec<DenseTensor> {
    use std::sync::Mutex;
    let [l, m, n] = maps.reduced();
    let accs: Vec<Mutex<DenseTensor>> = (0..maps.p_count())
        .map(|_| Mutex::new(DenseTensor::zeros(l, m, n)))
        .collect();
    let blocks = block_grid(maps.dims(), block);
    pool.for_each_chunk(blocks.len(), 1, |range| {
        for blk in &blocks[range] {
            let t = src.block(blk);
            for p in 0..maps.p_count() {
                let u_blk = maps.panel(p, 0, blk.i0, blk.i1, Vec::new());
                let v_blk = maps.panel(p, 1, blk.j0, blk.j1, Vec::new());
                let w_blk = maps.panel(p, 2, blk.k0, blk.k1, Vec::new());
                let contrib = compressor.compress_block(&t, &u_blk, &v_blk, &w_blk);
                let mut acc = accs[p].lock().unwrap();
                add_into(acc.data_mut(), contrib.data());
            }
        }
    });
    accs.into_iter().map(|m| m.into_inner().unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::comp::comp_dense;
    use crate::compress::engine::PrefetchConfig;
    use crate::compress::maps::MapTier;
    use crate::tensor::{InMemorySource, LowRankGenerator};
    use crate::util::rng::Xoshiro256;

    fn full_comp(src: &DenseTensor, maps: &MapSource, p: usize) -> DenseTensor {
        let rep = &maps.materialized().expect("test maps are materialized").replicas[p];
        comp_dense(src, &rep.u, &rep.v, &rep.w, MixedPrecision::Full)
    }

    #[test]
    fn blocked_equals_unblocked() {
        let mut rng = Xoshiro256::seed_from_u64(140);
        let t = DenseTensor::random_normal([12, 10, 8], &mut rng);
        let maps = MapSource::generate([12, 10, 8], [4, 3, 2], 3, 2, 141, MapTier::Materialized);
        let src = InMemorySource::new(t.clone());
        let pool = ThreadPool::new(4);
        let comp = RustCompressor {
            precision: MixedPrecision::Full,
        };
        let proxies = compress_source(&src, &maps, [5, 4, 3], &comp, &pool);
        assert_eq!(proxies.len(), 3);
        for p in 0..3 {
            let expected = full_comp(&t, &maps, p);
            let err = proxies[p].rel_error(&expected);
            assert!(err < 1e-4, "replica {p} err {err}");
        }
    }

    #[test]
    fn single_thread_matches_parallel_bitwise() {
        let gen = LowRankGenerator::new(16, 16, 16, 2, 142);
        let maps = MapSource::generate([16, 16, 16], [5, 5, 5], 2, 2, 143, MapTier::Materialized);
        let comp = RustCompressor {
            precision: MixedPrecision::Full,
        };
        let seq = compress_source(&gen, &maps, [4, 4, 4], &comp, &ThreadPool::new(1));
        let par = compress_source(&gen, &maps, [4, 4, 4], &comp, &ThreadPool::new(8));
        // The shard-local engine's fixed reduction tree makes thread counts
        // bitwise-invisible (the retired mutex path only promised ~1e-5).
        assert_eq!(seq, par);
    }

    #[test]
    fn prefetch_matches_sync_bitwise() {
        let gen = LowRankGenerator::new(14, 15, 16, 2, 151);
        let maps = MapSource::generate([14, 15, 16], [5, 5, 5], 2, 2, 152, MapTier::Materialized);
        let comp = RustCompressor {
            precision: MixedPrecision::Full,
        };
        let sync = compress_source_opts(
            &gen,
            &maps,
            [5, 6, 4],
            &comp,
            &StreamOptions { threads: 3, ..Default::default() },
            None,
            None,
        )
        .0;
        for (depth, io) in [(1, 1), (4, 2), (2, 3)] {
            let (pref, stats) = compress_source_opts(
                &gen,
                &maps,
                [5, 6, 4],
                &comp,
                &StreamOptions {
                    threads: 3,
                    prefetch: Some(PrefetchConfig { depth, io_threads: io }),
                    ..Default::default()
                },
                None,
                None,
            );
            assert!(stats.prefetched);
            assert_eq!(sync, pref, "depth={depth} io={io}");
        }
    }

    #[test]
    fn shard_local_matches_locked_oracle() {
        let gen = LowRankGenerator::new(15, 13, 11, 2, 153);
        let maps = MapSource::generate([15, 13, 11], [5, 4, 3], 2, 2, 154, MapTier::Materialized);
        let comp = RustCompressor {
            precision: MixedPrecision::Full,
        };
        // (a) Numerically: any shard partition vs the mutex path (fp
        // reassociation only — both sum the same per-block contributions).
        let locked = compress_source_locked(&gen, &maps, [4, 4, 4], &comp, &ThreadPool::new(1));
        let sharded = compress_source(&gen, &maps, [4, 4, 4], &comp, &ThreadPool::new(8));
        for p in 0..2 {
            let err = sharded[p].rel_error(&locked[p]);
            assert!(err < 1e-6, "replica {p} err {err}");
        }
        // (b) Bitwise: a single shard reduces in flat block order — exactly
        // the deterministic (1-thread) mutex fold — at every thread count
        // and in both execution modes.
        for threads in [1, 2, 8] {
            for prefetch in [None, Some(PrefetchConfig { depth: 2, io_threads: 2 })] {
                let got = compress_source_opts(
                    &gen,
                    &maps,
                    [4, 4, 4],
                    &comp,
                    &StreamOptions { threads, prefetch, shard_parts: 1 },
                    None,
                    None,
                )
                .0;
                assert_eq!(got, locked, "threads={threads} prefetch={prefetch:?}");
            }
        }
    }

    #[test]
    fn block_size_invariance() {
        let gen = LowRankGenerator::new(9, 9, 9, 2, 144);
        let maps = MapSource::generate([9, 9, 9], [3, 3, 3], 2, 1, 145, MapTier::Materialized);
        let comp = RustCompressor {
            precision: MixedPrecision::Full,
        };
        let pool = ThreadPool::new(2);
        let a = compress_source(&gen, &maps, [9, 9, 9], &comp, &pool);
        let b = compress_source(&gen, &maps, [2, 3, 4], &comp, &pool);
        for p in 0..2 {
            assert!(a[p].rel_error(&b[p]) < 1e-4, "p={p} err {}", a[p].rel_error(&b[p]));
        }
    }

    #[test]
    fn batched_matches_unbatched() {
        let gen = LowRankGenerator::new(20, 18, 16, 2, 149);
        let maps = MapSource::generate([20, 18, 16], [6, 5, 4], 3, 2, 150, MapTier::Materialized);
        let pool = ThreadPool::new(2);
        let comp = RustCompressor { precision: MixedPrecision::Full };
        let plain = compress_source(&gen, &maps, [7, 6, 5], &comp, &pool);
        let batched = compress_source_batched(&gen, &maps, [7, 6, 5], &pool);
        for p in 0..3 {
            let err = batched[p].rel_error(&plain[p]);
            assert!(err < 1e-5, "replica {p} err {err}");
        }
    }

    #[test]
    fn batched_bitwise_invariant_across_schedules() {
        let gen = LowRankGenerator::new(18, 14, 12, 2, 155);
        let maps = MapSource::generate([18, 14, 12], [6, 5, 4], 3, 2, 156, MapTier::Materialized);
        let reference = compress_source_batched(&gen, &maps, [5, 5, 5], &ThreadPool::new(1));
        let par = compress_source_batched(&gen, &maps, [5, 5, 5], &ThreadPool::new(8));
        assert_eq!(reference, par);
        let (pref, _) = compress_source_batched_opts(
            &gen,
            &maps,
            [5, 5, 5],
            &StreamOptions {
                threads: 4,
                prefetch: Some(PrefetchConfig { depth: 3, io_threads: 2 }),
                ..Default::default()
            },
            None,
            None,
        );
        assert_eq!(reference, pref);
    }

    #[test]
    fn shard_exports_fold_to_bitwise_identical_proxies() {
        // The shard-lease invariant end to end at this layer: computing
        // every shard with the public per-shard exports (as a remote
        // worker would) and folding them in shard order reproduces the
        // engine's proxies bit for bit, on both compression paths.
        let gen = LowRankGenerator::new(16, 14, 12, 2, 163);
        let maps = MapSource::generate([16, 14, 12], [5, 4, 4], 3, 2, 164, MapTier::Materialized);
        let block = [5, 5, 5];
        let nblocks = BlockSpec3::new([16, 14, 12], block).num_blocks();
        let shards = ThreadPool::partition(nblocks, 6);

        let reference = compress_source_batched(&gen, &maps, block, &ThreadPool::new(4));
        let mut folded = zero_shard_proxies(&maps);
        for &(b0, b1) in &shards {
            fold_shard_proxies(&mut folded, compress_shard_batched(&gen, &maps, block, b0, b1));
        }
        assert_eq!(folded, reference, "batched path");

        let comp = RustCompressor { precision: MixedPrecision::Full };
        let reference = compress_source(&gen, &maps, block, &comp, &ThreadPool::new(4));
        let mut folded = zero_shard_proxies(&maps);
        for &(b0, b1) in &shards {
            fold_shard_proxies(
                &mut folded,
                compress_shard(&gen, &maps, block, &comp, b0, b1),
            );
        }
        assert_eq!(folded, reference, "plain path");
    }

    #[test]
    fn procedural_tier_bitwise_matches_materialized() {
        // The whole point of the tiered source: same seed, either tier,
        // identical proxies — on the trait path and the batched path, at
        // several block shapes.
        let gen = LowRankGenerator::new(17, 15, 13, 2, 161);
        let mat = MapSource::generate([17, 15, 13], [5, 4, 4], 3, 2, 162, MapTier::Materialized);
        let proc_ = MapSource::generate([17, 15, 13], [5, 4, 4], 3, 2, 162, MapTier::Procedural);
        let comp = RustCompressor { precision: MixedPrecision::Full };
        let pool = ThreadPool::new(3);
        for block in [[17, 15, 13], [6, 5, 4], [4, 7, 3]] {
            let a = compress_source(&gen, &mat, block, &comp, &pool);
            let b = compress_source(&gen, &proc_, block, &comp, &pool);
            assert_eq!(a, b, "trait path, block {block:?}");
            let ab = compress_source_batched(&gen, &mat, block, &pool);
            let bb = compress_source_batched(&gen, &proc_, block, &pool);
            assert_eq!(ab, bb, "batched path, block {block:?}");
        }
    }

    #[test]
    fn sparse_stage_one_matches_dense_equivalent() {
        use crate::compress::SparseSignMatrix;
        let mut rng = Xoshiro256::seed_from_u64(148);
        let t = DenseTensor::random_normal([10, 9, 8], &mut rng);
        let src = InMemorySource::new(t.clone());
        let u = SparseSignMatrix::generate(6, 10, 2, 1);
        let v = SparseSignMatrix::generate(5, 9, 2, 2);
        let w = SparseSignMatrix::generate(4, 8, 2, 3);
        let pool = ThreadPool::new(3);
        let z = compress_source_sparse(&src, &u, &v, &w, [4, 3, 5], &pool);
        let z_ref = comp_dense(&t, &u.to_dense(), &v.to_dense(), &w.to_dense(), MixedPrecision::Full);
        assert!(z.rel_error(&z_ref) < 1e-4, "err {}", z.rel_error(&z_ref));
    }

    #[test]
    fn mixed_precision_backend_close() {
        let gen = LowRankGenerator::new(10, 10, 10, 2, 146);
        let maps = MapSource::generate([10, 10, 10], [4, 4, 4], 1, 1, 147, MapTier::Materialized);
        let pool = ThreadPool::new(2);
        let full = compress_source(
            &gen,
            &maps,
            [5, 5, 5],
            &RustCompressor {
                precision: MixedPrecision::Full,
            },
            &pool,
        );
        let mixed = compress_source(
            &gen,
            &maps,
            [5, 5, 5],
            &RustCompressor {
                precision: MixedPrecision::Bf16,
            },
            &pool,
        );
        let err = mixed[0].rel_error(&full[0]);
        assert!(err < 1e-2, "bf16 split err {err}");
        assert!(err > 0.0);
    }
}
