//! Sparse ±1 projection matrices for the compressed-sensing construction of
//! §IV-D: `U_p = U'_p · U` with `U (αL×I)` **sparse**.
//!
//! We use the sparse-embedding construction (Clarkson–Woodruff / Achlioptas
//! family): each column holds exactly `s` nonzeros at random rows with
//! values `±1/√s`.  This is a Johnson-Lindenstrauss map that is cheap to
//! apply (`O(nnz)` per vector) and RIP-friendly, which is what the L1
//! second-stage recovery needs.

use crate::linalg::Matrix;
use crate::util::rng::Xoshiro256;

/// Column-sparse sign matrix in CSC-like layout.
#[derive(Clone, Debug)]
pub struct SparseSignMatrix {
    rows: usize,
    cols: usize,
    /// per column: `s` (row, value) pairs
    entries: Vec<Vec<(u32, f32)>>,
}

impl SparseSignMatrix {
    /// `rows×cols` with `s` nonzeros per column, values `±1/√s`.
    pub fn generate(rows: usize, cols: usize, s: usize, seed: u64) -> Self {
        assert!(s >= 1 && s <= rows, "s={s} out of range 1..={rows}");
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let scale = 1.0 / (s as f32).sqrt();
        let entries = (0..cols)
            .map(|_| {
                rng.sample_indices(rows, s)
                    .into_iter()
                    .map(|r| (r as u32, rng.next_sign() * scale))
                    .collect()
            })
            .collect();
        Self {
            rows,
            cols,
            entries,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.entries.iter().map(|e| e.len()).sum()
    }

    /// Column slice `self[:, c0..c1]` (cheap: entries are per-column).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> SparseSignMatrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        SparseSignMatrix {
            rows: self.rows,
            cols: c1 - c0,
            entries: self.entries[c0..c1].to_vec(),
        }
    }

    /// Densifies (for validation and for the stacked recovery solve).
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for (c, col) in self.entries.iter().enumerate() {
            for &(r, v) in col {
                m.set(r as usize, c, v);
            }
        }
        m
    }

    /// `Y = self · X` for dense `X (cols × n)` — O(nnz · n).
    pub fn mul_dense(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows(), self.cols, "sparse mul: dim mismatch");
        let n = x.cols();
        let mut y = Matrix::zeros(self.rows, n);
        for (c, col_entries) in self.entries.iter().enumerate() {
            for j in 0..n {
                let xv = x.get(c, j);
                if xv == 0.0 {
                    continue;
                }
                for &(r, v) in col_entries {
                    y.add_assign_at(r as usize, j, v * xv);
                }
            }
        }
        y
    }

    /// Applies to a mode-unfolded tensor from the left along mode 1:
    /// `Y_(1) = self · X_(1)` — used by the first-stage streaming compress.
    pub fn mul_slice(&self, x_cols: &[f32], out: &mut [f32]) {
        // x_cols: one column of X_(1) (length = self.cols); out: length rows.
        debug_assert_eq!(x_cols.len(), self.cols);
        debug_assert_eq!(out.len(), self.rows);
        for (c, col_entries) in self.entries.iter().enumerate() {
            let xv = x_cols[c];
            if xv == 0.0 {
                continue;
            }
            for &(r, v) in col_entries {
                out[r as usize] += v * xv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, Trans};
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn structure_is_correct() {
        let m = SparseSignMatrix::generate(20, 50, 4, 1);
        assert_eq!(m.nnz(), 200);
        let d = m.to_dense();
        for c in 0..50 {
            let nnz = (0..20).filter(|&r| d.get(r, c) != 0.0).count();
            assert_eq!(nnz, 4, "column {c}");
            for r in 0..20 {
                let v = d.get(r, c);
                assert!(v == 0.0 || (v.abs() - 0.5).abs() < 1e-6); // 1/√4
            }
        }
    }

    #[test]
    fn mul_matches_dense() {
        prop::check("sparse-mul-dense", 20, |g| {
            let rows = g.int(2, 10);
            let cols = g.int(2, 12);
            let s = g.int(1, rows);
            let m = SparseSignMatrix::generate(rows, cols, s, g.int(0, 1 << 30) as u64);
            let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
            let x = Matrix::random_normal(cols, g.int(1, 5), &mut rng);
            let fast = m.mul_dense(&x);
            let slow = matmul(&m.to_dense(), Trans::No, &x, Trans::No);
            assert!(fast.rel_error(&slow) < 1e-5);
        });
    }

    #[test]
    fn norm_preserved_in_expectation() {
        // JL property sanity: ‖Ux‖ ≈ ‖x‖ on average for tall-enough U.
        let m = SparseSignMatrix::generate(256, 64, 8, 7);
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut ratios = Vec::new();
        for _ in 0..20 {
            let x = Matrix::random_normal(64, 1, &mut rng);
            let y = m.mul_dense(&x);
            ratios.push(y.frobenius_norm() / x.frobenius_norm());
        }
        let mean: f64 = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((mean - 1.0).abs() < 0.15, "mean norm ratio {mean}");
    }

    #[test]
    fn mul_slice_accumulates() {
        let m = SparseSignMatrix::generate(5, 8, 2, 3);
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 5];
        m.mul_slice(&x, &mut out);
        let xd = Matrix::from_vec(8, 1, x);
        let expect = m.mul_dense(&xd);
        for r in 0..5 {
            assert!((out[r] - expect.get(r, 0)).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn s_zero_rejected() {
        let _ = SparseSignMatrix::generate(4, 4, 0, 1);
    }
}
