//! `Comp(X, U, V, W)` — Eq. (3): the three-mode product
//! `Y = X ×₁ U ×₂ V ×₃ W` computed as a chain of matricized GEMMs.
//!
//! §IV-A's column-major layout means the mode-1 product is a single GEMM on
//! the raw buffer; modes 2 and 3 use the strided unfoldings.  Each GEMM can
//! run with mixed-precision operands (§IV-B) — that is the code path the
//! Pallas `ttm_chain` kernel replaces on the accelerator.

use crate::linalg::backend::{ComputeBackend, SerialBackend};
use crate::linalg::{Matrix, Trans};
use crate::mixed::{matmul_mixed_with, MixedPrecision};
use crate::tensor::unfold::{refold_1, refold_2, refold_3, unfold_2, unfold_3};
use crate::tensor::DenseTensor;

/// Mode-1 tensor-times-matrix: `Y = X ×₁ U`, `U (L×I)`, result `L×J×K`.
pub fn ttm_mode1(t: &DenseTensor, u: &Matrix, precision: MixedPrecision) -> DenseTensor {
    ttm_mode1_with(t, u, precision, &SerialBackend)
}

/// [`ttm_mode1`] dispatching its GEMM through `backend`.
pub fn ttm_mode1_with(
    t: &DenseTensor,
    u: &Matrix,
    precision: MixedPrecision,
    backend: &dyn ComputeBackend,
) -> DenseTensor {
    let [i, j, k] = t.dims();
    assert_eq!(u.cols(), i, "ttm1: U cols {} != I {}", u.cols(), i);
    // X_(1) is the raw buffer: (I × J·K).
    let x1 = Matrix::from_vec(i, j * k, t.data().to_vec());
    let y1 = mm(u, &x1, precision, backend);
    refold_1(&y1, [u.rows(), j, k])
}

/// Mode-2 TTM: `Y = X ×₂ V`, `V (M×J)`, result `I×M×K`.
pub fn ttm_mode2(t: &DenseTensor, v: &Matrix, precision: MixedPrecision) -> DenseTensor {
    ttm_mode2_with(t, v, precision, &SerialBackend)
}

/// [`ttm_mode2`] dispatching its GEMM through `backend`.
pub fn ttm_mode2_with(
    t: &DenseTensor,
    v: &Matrix,
    precision: MixedPrecision,
    backend: &dyn ComputeBackend,
) -> DenseTensor {
    let [i, j, k] = t.dims();
    assert_eq!(v.cols(), j, "ttm2: V cols {} != J {}", v.cols(), j);
    let x2 = unfold_2(t); // J × (I·K)
    let y2 = mm(v, &x2, precision, backend); // M × (I·K)
    refold_2(&y2, [i, v.rows(), k])
}

/// Mode-3 TTM: `Y = X ×₃ W`, `W (N×K)`, result `I×J×N`.
pub fn ttm_mode3(t: &DenseTensor, w: &Matrix, precision: MixedPrecision) -> DenseTensor {
    ttm_mode3_with(t, w, precision, &SerialBackend)
}

/// [`ttm_mode3`] dispatching its GEMM through `backend`.
pub fn ttm_mode3_with(
    t: &DenseTensor,
    w: &Matrix,
    precision: MixedPrecision,
    backend: &dyn ComputeBackend,
) -> DenseTensor {
    let [i, j, k] = t.dims();
    assert_eq!(w.cols(), k, "ttm3: W cols {} != K {}", w.cols(), k);
    let x3 = unfold_3(t); // K × (I·J)
    let y3 = mm(w, &x3, precision, backend); // N × (I·J)
    refold_3(&y3, [i, j, w.rows()])
}

#[inline]
fn mm(a: &Matrix, b: &Matrix, precision: MixedPrecision, backend: &dyn ComputeBackend) -> Matrix {
    match precision {
        MixedPrecision::Full => backend.matmul(a, Trans::No, b, Trans::No),
        p => matmul_mixed_with(a, b, p, backend),
    }
}

/// Full compression `Comp(X, U, V, W) = X ×₁U ×₂V ×₃W` (Eq. 3) on the
/// serial reference backend.
///
/// Order: smallest intermediate first would be optimal in general; here we
/// contract mode 1 first (free matricization), then 2, then 3 — for the
/// paper's shapes (`L=M=N ≪ I=J=K`) mode-1-first already shrinks the
/// intermediate by `L/I` immediately.
pub fn comp_dense(
    t: &DenseTensor,
    u: &Matrix,
    v: &Matrix,
    w: &Matrix,
    precision: MixedPrecision,
) -> DenseTensor {
    comp_dense_with(t, u, v, w, precision, &SerialBackend)
}

/// [`comp_dense`] dispatching every GEMM of the TTM chain through
/// `backend`.  The streaming compressor passes the serial reference here
/// (parallelism lives at block granularity); standalone callers can pass a
/// parallel backend to speed up a single large contraction.
pub fn comp_dense_with(
    t: &DenseTensor,
    u: &Matrix,
    v: &Matrix,
    w: &Matrix,
    precision: MixedPrecision,
    backend: &dyn ComputeBackend,
) -> DenseTensor {
    let y1 = ttm_mode1_with(t, u, precision, backend);
    let y2 = ttm_mode2_with(&y1, v, precision, backend);
    ttm_mode3_with(&y2, w, precision, backend)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    /// Direct elementwise reference of Eq. (3) — O(LMN·IJK), tiny sizes only.
    fn comp_reference(t: &DenseTensor, u: &Matrix, v: &Matrix, w: &Matrix) -> DenseTensor {
        let [i_dim, j_dim, k_dim] = t.dims();
        let (l, m, n) = (u.rows(), v.rows(), w.rows());
        DenseTensor::from_fn([l, m, n], |ll, mm, nn| {
            let mut s = 0.0f64;
            for k in 0..k_dim {
                for j in 0..j_dim {
                    for i in 0..i_dim {
                        s += u.get(ll, i) as f64
                            * v.get(mm, j) as f64
                            * w.get(nn, k) as f64
                            * t.get(i, j, k) as f64;
                    }
                }
            }
            s as f32
        })
    }

    #[test]
    fn matches_elementwise_reference() {
        let mut rng = Xoshiro256::seed_from_u64(130);
        let t = DenseTensor::random_normal([6, 5, 4], &mut rng);
        let u = Matrix::random_normal(3, 6, &mut rng);
        let v = Matrix::random_normal(2, 5, &mut rng);
        let w = Matrix::random_normal(2, 4, &mut rng);
        let fast = comp_dense(&t, &u, &v, &w, MixedPrecision::Full);
        let slow = comp_reference(&t, &u, &v, &w);
        assert!(fast.rel_error(&slow) < 1e-4, "err={}", fast.rel_error(&slow));
    }

    #[test]
    fn ttm_identity_is_noop() {
        let mut rng = Xoshiro256::seed_from_u64(131);
        let t = DenseTensor::random_normal([4, 4, 4], &mut rng);
        let i4 = Matrix::identity(4);
        assert!(ttm_mode1(&t, &i4, MixedPrecision::Full).rel_error(&t) < 1e-6);
        assert!(ttm_mode2(&t, &i4, MixedPrecision::Full).rel_error(&t) < 1e-6);
        assert!(ttm_mode3(&t, &i4, MixedPrecision::Full).rel_error(&t) < 1e-6);
    }

    #[test]
    fn mode_products_commute_across_modes() {
        // (X ×₁U) ×₂V == (X ×₂V) ×₁U — standard multilinear identity.
        prop::check("ttm-commute", 15, |g| {
            let dims = [g.int(2, 5), g.int(2, 5), g.int(2, 5)];
            let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
            let t = DenseTensor::random_normal(dims, &mut rng);
            let u = Matrix::random_normal(g.int(1, 4), dims[0], &mut rng);
            let v = Matrix::random_normal(g.int(1, 4), dims[1], &mut rng);
            let uv = ttm_mode2(&ttm_mode1(&t, &u, MixedPrecision::Full), &v, MixedPrecision::Full);
            let vu = ttm_mode1(&ttm_mode2(&t, &v, MixedPrecision::Full), &u, MixedPrecision::Full);
            assert!(uv.rel_error(&vu) < 1e-4);
        });
    }

    #[test]
    fn compression_of_cp_tensor_compresses_factors() {
        // Comp(Σ a∘b∘c, U, V, W) = Σ (Ua)∘(Vb)∘(Wc) — the Kronecker identity
        // behind the whole scheme (A_p = U_p·A·Π·Σ).
        let mut rng = Xoshiro256::seed_from_u64(132);
        let a = Matrix::random_normal(8, 2, &mut rng);
        let b = Matrix::random_normal(7, 2, &mut rng);
        let c = Matrix::random_normal(6, 2, &mut rng);
        let t = DenseTensor::from_cp_factors(&a, &b, &c);
        let u = Matrix::random_normal(3, 8, &mut rng);
        let v = Matrix::random_normal(3, 7, &mut rng);
        let w = Matrix::random_normal(3, 6, &mut rng);
        let y = comp_dense(&t, &u, &v, &w, MixedPrecision::Full);
        let ua = crate::linalg::matmul(&u, Trans::No, &a, Trans::No);
        let vb = crate::linalg::matmul(&v, Trans::No, &b, Trans::No);
        let wc = crate::linalg::matmul(&w, Trans::No, &c, Trans::No);
        let y_ref = DenseTensor::from_cp_factors(&ua, &vb, &wc);
        assert!(y.rel_error(&y_ref) < 1e-4, "err={}", y.rel_error(&y_ref));
    }

    #[test]
    fn compression_is_linear() {
        let mut rng = Xoshiro256::seed_from_u64(133);
        let t1 = DenseTensor::random_normal([5, 5, 5], &mut rng);
        let t2 = DenseTensor::random_normal([5, 5, 5], &mut rng);
        let sum = DenseTensor::from_fn([5, 5, 5], |i, j, k| t1.get(i, j, k) + t2.get(i, j, k));
        let u = Matrix::random_normal(3, 5, &mut rng);
        let v = Matrix::random_normal(3, 5, &mut rng);
        let w = Matrix::random_normal(3, 5, &mut rng);
        let y_sum = comp_dense(&sum, &u, &v, &w, MixedPrecision::Full);
        let y1 = comp_dense(&t1, &u, &v, &w, MixedPrecision::Full);
        let y2 = comp_dense(&t2, &u, &v, &w, MixedPrecision::Full);
        let y12 = DenseTensor::from_fn([3, 3, 3], |i, j, k| y1.get(i, j, k) + y2.get(i, j, k));
        assert!(y_sum.rel_error(&y12) < 1e-4);
    }

    #[test]
    fn mixed_precision_close_to_full() {
        let mut rng = Xoshiro256::seed_from_u64(134);
        let t = DenseTensor::random_normal([8, 8, 8], &mut rng);
        let u = Matrix::random_normal(4, 8, &mut rng);
        let v = Matrix::random_normal(4, 8, &mut rng);
        let w = Matrix::random_normal(4, 8, &mut rng);
        let full = comp_dense(&t, &u, &v, &w, MixedPrecision::Full);
        let f16 = comp_dense(&t, &u, &v, &w, MixedPrecision::F16);
        let bf16 = comp_dense(&t, &u, &v, &w, MixedPrecision::Bf16);
        assert!(f16.rel_error(&full) < 1e-4, "f16 err {}", f16.rel_error(&full));
        assert!(bf16.rel_error(&full) < 1e-3, "bf16 err {}", bf16.rel_error(&full));
    }

    use crate::linalg::Trans;
}
