//! `exatensor` — the Exascale-Tensor command-line coordinator (Layer 3).
//!
//! Subcommands:
//! * `decompose` — run the compressed CP pipeline on a synthetic implicit
//!   tensor or a tensor file.
//! * `gene`      — the gene-expression analysis application (§V-C).
//! * `cp-layer`  — the CP tensor-layer / CNN compression application
//!   (Table I).
//! * `artifacts` — list the AOT artifacts the runtime can execute.

use exascale_tensor::apps::{run_cp_layer_experiment, run_gene_analysis, CpBackend, GeneConfig};
use exascale_tensor::apps::nn::{train, Network, SyntheticImages, TrainConfig};
use exascale_tensor::coordinator::{Backend, Pipeline, PipelineConfig};
use exascale_tensor::runtime::artifacts_dir;
use exascale_tensor::tensor::{InMemorySource, LowRankGenerator};
use exascale_tensor::util::cli::Command;
use exascale_tensor::util::logging;

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().collect();
    let prog = args.first().map(|s| s.as_str()).unwrap_or("exatensor").to_string();
    let sub = args.get(1).map(|s| s.as_str()).unwrap_or("help").to_string();
    let rest: Vec<String> = args.iter().skip(2).cloned().collect();
    let code = match sub.as_str() {
        "decompose" => cmd_decompose(&prog, &rest),
        "gene" => cmd_gene(&prog, &rest),
        "cp-layer" => cmd_cp_layer(&prog, &rest),
        "artifacts" => cmd_artifacts(),
        _ => {
            print_help(&prog);
            if sub == "help" || sub == "--help" {
                0
            } else {
                eprintln!("unknown subcommand '{sub}'");
                2
            }
        }
    };
    std::process::exit(code);
}

fn print_help(prog: &str) {
    println!(
        "exatensor — compressed CP tensor decomposition (Exascale-Tensor)\n\n\
         USAGE: {prog} <decompose|gene|cp-layer|artifacts> [OPTIONS]\n\n\
         Run `{prog} <subcommand> --help` for options."
    );
}

fn decompose_cmd() -> Command {
    Command::new("decompose", "compressed CP decomposition of a tensor")
        .opt("size", "synthetic tensor side I=J=K", Some("200"))
        .opt("rank", "CP rank F", Some("5"))
        .opt("reduced", "proxy side L=M=N", Some("24"))
        .opt("block", "compression block side d", Some("60"))
        .opt("input", "EXT1 tensor file instead of synthetic", None)
        .opt("backend", "seq | par | xla", Some("par"))
        .opt("threads", "worker threads (0 = auto)", Some("0"))
        .opt("seed", "random seed", Some("0"))
        .switch("mixed", "mixed-precision (split bf16) compression")
        .switch("help", "show help")
}

fn cmd_decompose(prog: &str, args: &[String]) -> i32 {
    let cmd = decompose_cmd();
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") {
        println!("{}", cmd.usage(prog));
        return 0;
    }
    let run = || -> anyhow::Result<()> {
        let size = m.get_usize("size")?;
        let rank = m.get_usize("rank")?;
        let reduced = m.get_usize("reduced")?;
        let block = m.get_usize("block")?;
        let seed = m.get_u64("seed")?;
        let threads = match m.get_usize("threads")? {
            0 => exascale_tensor::util::default_threads(),
            t => t,
        };
        let backend = match m.get("backend").unwrap_or("par") {
            "seq" => Backend::RustSequential,
            "xla" => Backend::Xla,
            _ => Backend::RustParallel,
        };
        let cfg = PipelineConfig::builder()
            .reduced_dims(reduced, reduced, reduced)
            .rank(rank)
            .block([block, block, block])
            .backend(backend)
            .threads(threads)
            .mixed_precision(m.get_bool("mixed"))
            .seed(seed)
            .build()?;
        let mut pipe = Pipeline::new(cfg);
        if backend == Backend::Xla {
            // One constructor wires the whole XLA arm (fused compression +
            // ALS artifacts, CPU fallback kernels) from the run config.
            let xla = exascale_tensor::runtime::XlaBackend::from_config(pipe.config())?;
            pipe = pipe.with_compute(std::sync::Arc::new(xla));
        }

        let result = if let Some(path) = m.get("input") {
            let t = exascale_tensor::tensor::io::load_tensor(path)?;
            let src = InMemorySource::new(t);
            pipe.run(&src)?
        } else {
            let gen = LowRankGenerator::new(size, size, size, rank, seed);
            println!(
                "synthetic implicit tensor {size}³ = {} virtual elements (rank {rank})",
                size * size * size
            );
            pipe.run(&gen)?
        };
        println!(
            "plan: P={} block={:?} est bytes={}",
            result.plan.replicas, result.plan.block, result.plan.estimated_bytes
        );
        println!("sampled MSE      : {:.3e}", result.diagnostics.sampled_mse);
        println!("sampled rel error: {:.3e}", result.diagnostics.rel_error);
        println!("dropped replicas : {}", result.diagnostics.dropped_replicas);
        println!("\nstage timings:\n{}", pipe.metrics.report());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_gene(prog: &str, args: &[String]) -> i32 {
    let cmd = Command::new("gene", "gene-expression CP analysis (§V-C)")
        .opt("individuals", "individuals dim", Some("120"))
        .opt("tissues", "tissues dim", Some("30"))
        .opt("genes", "genes dim", Some("800"))
        .opt("programs", "planted expression programs (rank)", Some("5"))
        .opt("seed", "random seed", Some("1"))
        .switch("help", "show help");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") {
        println!("{}", cmd.usage(prog));
        return 0;
    }
    let run = || -> anyhow::Result<()> {
        let cfg = GeneConfig {
            individuals: m.get_usize("individuals")?,
            tissues: m.get_usize("tissues")?,
            genes: m.get_usize("genes")?,
            programs: m.get_usize("programs")?,
            seed: m.get_u64("seed")?,
            ..Default::default()
        };
        let report = run_gene_analysis(&cfg)?;
        println!("gene tensor {:?} (individual × tissue × gene)", report.dims);
        println!("replicas          : {}", report.replicas);
        println!("relative error    : {:.3}%", 100.0 * report.rel_error);
        println!("factor congruence : {:.4}", report.factor_congruence);
        println!("decomposition time: {:.2} s", report.decompose_seconds);
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Deep-copies a trained network's parameters into a fresh instance so each
/// Table-I backend starts from identical weights.
fn clone_network(reference: &Network, seed: u64) -> Network {
    let mut net = Network::new(18, 8, 16, 32, 3, seed);
    net.conv1.weight = reference.conv1.weight.clone();
    net.conv1.bias = reference.conv1.bias.clone();
    net.conv2.weight = reference.conv2.weight.clone();
    net.conv2.bias = reference.conv2.bias.clone();
    net.fc1.weight = reference.fc1.weight.clone();
    net.fc1.bias = reference.fc1.bias.clone();
    net.fc2.weight = reference.fc2.weight.clone();
    net.fc2.bias = reference.fc2.bias.clone();
    net
}

fn cmd_cp_layer(prog: &str, args: &[String]) -> i32 {
    let cmd = Command::new("cp-layer", "CP tensor layer CNN compression (Table I)")
        .opt("train", "training images", Some("240"))
        .opt("test", "test images", Some("90"))
        .opt("rank", "CP rank for the conv layer", Some("8"))
        .opt("epochs", "pre-training epochs", Some("3"))
        .opt("seed", "random seed", Some("42"))
        .switch("help", "show help");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") {
        println!("{}", cmd.usage(prog));
        return 0;
    }
    let run = || -> anyhow::Result<()> {
        let gen = SyntheticImages::default();
        let train_ds = gen.generate(m.get_usize("train")?, 1);
        let test_ds = gen.generate(m.get_usize("test")?, 2);
        let seed = m.get_u64("seed")?;
        let rank = m.get_usize("rank")?;
        println!("training reference CNN…");
        let mut reference = Network::new(18, 8, 16, 32, 3, seed);
        train(
            &mut reference,
            &train_ds,
            &TrainConfig {
                epochs: m.get_usize("epochs")?,
                lr: 0.01,
                seed,
            },
        );
        println!(
            "{:<26} {:>8} {:>10} {:>10} {:>9} {:>8}",
            "method", "acc pre", "acc drop", "acc tuned", "time", "rel err"
        );
        for backend in [CpBackend::Hosvd, CpBackend::Random, CpBackend::Compressed] {
            let mut net = clone_network(&reference, seed);
            let rep =
                run_cp_layer_experiment(&mut net, &train_ds, &test_ds, rank, backend, 1, seed)?;
            println!(
                "{:<26} {:>7.1}% {:>9.1}% {:>9.1}% {:>8.2}s {:>8.4}",
                rep.backend,
                100.0 * rep.accuracy_before,
                100.0 * rep.accuracy_after_decomp,
                100.0 * rep.accuracy_after_finetune,
                rep.decomp_seconds,
                rep.reconstruction_error
            );
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_artifacts() -> i32 {
    match exascale_tensor::runtime::Manifest::load(artifacts_dir()) {
        Ok(man) => {
            println!("{} artifacts in {}:", man.artifacts.len(), man.dir.display());
            for (name, spec) in &man.artifacts {
                println!(
                    "  {:<38} kind={:<18} in={:?} out={:?}",
                    name, spec.kind, spec.inputs, spec.outputs
                );
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#} (run `make artifacts`)");
            1
        }
    }
}
