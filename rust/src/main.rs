//! `exatensor` — the Exascale-Tensor command-line coordinator (Layer 3).
//!
//! Subcommands:
//! * `decompose`  — run the compressed CP pipeline on a synthetic implicit
//!   tensor or an `EXT1` tensor file (file inputs stream out-of-core
//!   through [`FileTensorSource`]; see `--memory-budget-mb`).
//! * `gen-tensor` — author an `EXT1` tensor file from the implicit
//!   low-rank generator, streamed slab-by-slab so the file may exceed RAM.
//! * `gene`       — the gene-expression analysis application (§V-C).
//! * `cp-layer`   — the CP tensor-layer / CNN compression application
//!   (Table I).
//! * `artifacts`  — list the AOT artifacts the runtime can execute.
//! * `serve`      — the multi-tenant decomposition daemon (job scheduler
//!   with memory-budget admission control, result cache, crash-safe job
//!   spool; line-delimited JSON protocol over TCP).
//! * `worker`     — join a running daemon as a shard-lease worker: pulls
//!   leased shard ranges of `--sharded` jobs, streams raw accumulators
//!   back, exits when the coordinator drains.
//! * `client`     — talk to a running daemon
//!   (`submit|status|result|cancel|metrics|shutdown`).

use exascale_tensor::apps::{run_cp_layer_experiment, run_gene_analysis, CpBackend, GeneConfig};
use exascale_tensor::apps::nn::{train, Network, SyntheticImages, TrainConfig};
use exascale_tensor::coordinator::{
    Backend, MapTierChoice, Pipeline, PipelineConfig, RecoverySolver,
};
use exascale_tensor::runtime::artifacts_dir;
use exascale_tensor::tensor::{
    save_tensor_streamed, FileTensorSource, LowRankGenerator, TensorSource,
};
use exascale_tensor::util::cli::Command;
use exascale_tensor::util::logging;

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().collect();
    let prog = args.first().map(|s| s.as_str()).unwrap_or("exatensor").to_string();
    let sub = args.get(1).map(|s| s.as_str()).unwrap_or("help").to_string();
    let rest: Vec<String> = args.iter().skip(2).cloned().collect();
    let code = match sub.as_str() {
        "decompose" => cmd_decompose(&prog, &rest),
        "gen-tensor" => cmd_gen_tensor(&prog, &rest),
        "gene" => cmd_gene(&prog, &rest),
        "cp-layer" => cmd_cp_layer(&prog, &rest),
        "artifacts" => cmd_artifacts(),
        "serve" => cmd_serve(&prog, &rest),
        "worker" => cmd_worker(&prog, &rest),
        "client" => cmd_client(&prog, &rest),
        _ => {
            print_help(&prog);
            if sub == "help" || sub == "--help" {
                0
            } else {
                eprintln!("unknown subcommand '{sub}'");
                2
            }
        }
    };
    std::process::exit(code);
}

fn print_help(prog: &str) {
    println!(
        "exatensor — compressed CP tensor decomposition (Exascale-Tensor)\n\n\
         USAGE: {prog} <decompose|gen-tensor|gene|cp-layer|artifacts|serve|worker|client> [OPTIONS]\n\n\
         Run `{prog} <subcommand> --help` for options."
    );
}

fn decompose_cmd() -> Command {
    Command::new("decompose", "compressed CP decomposition of a tensor")
        .opt("size", "synthetic tensor side I=J=K", Some("200"))
        .opt("rank", "CP rank F", Some("5"))
        .opt("reduced", "proxy side L=M=N", Some("24"))
        .opt("block", "compression block side d", Some("60"))
        .opt("input", "EXT1 tensor file instead of synthetic (streamed out-of-core)", None)
        .opt("backend", "seq | par | xla", Some("par"))
        .opt("threads", "worker threads (0 = auto)", Some("0"))
        .opt("memory-budget-mb", "planner byte budget in MiB (0 = unlimited)", Some("0"))
        .opt("prefetch-depth", "staged-block queue depth (auto | 0 = off | N)", Some("auto"))
        .opt("io-threads", "I/O producer threads when prefetching", Some("2"))
        .opt("checkpoint-dir", "directory for incremental + final checkpoints", None)
        .opt(
            "map-tier",
            "replica-map tier: auto | materialized | procedural (generate-on-slice)",
            Some("auto"),
        )
        .opt(
            "recovery-solver",
            "stacked-solve solver: auto | cholesky | iterative (matrix-free CG) | sketch",
            Some("auto"),
        )
        .opt("recovery-panel-cols", "streamed map-panel width in columns", Some("256"))
        .opt("seed", "random seed", Some("0"))
        .opt(
            "fault-plan",
            "chaos testing: arm a deterministic fault plan, e.g. \
             'seed=7;io_read:period=5,max=3' (sites: io_read io_write \
             checkpoint_commit worker_panic conn_stall)",
            None,
        )
        .switch("mixed", "mixed-precision (split bf16) compression")
        .switch("help", "show help")
}

fn cmd_decompose(prog: &str, args: &[String]) -> i32 {
    let cmd = decompose_cmd();
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") {
        println!("{}", cmd.usage(prog));
        return 0;
    }
    let run = || -> anyhow::Result<()> {
        if let Some(plan) = m.get("fault-plan") {
            exascale_tensor::util::fault::arm(exascale_tensor::util::fault::FaultPlan::parse(
                plan,
            )?);
        }
        let size = m.get_usize("size")?;
        let rank = m.get_usize("rank")?;
        let reduced = m.get_usize("reduced")?;
        let block = m.get_usize("block")?;
        let seed = m.get_u64("seed")?;
        let threads = match m.get_usize("threads")? {
            0 => exascale_tensor::util::default_threads(),
            t => t,
        };
        let backend = match m.get("backend").unwrap_or("par") {
            "seq" => Backend::RustSequential,
            "xla" => Backend::Xla,
            _ => Backend::RustParallel,
        };
        let mut builder = PipelineConfig::builder()
            .reduced_dims(reduced, reduced, reduced)
            .rank(rank)
            .block([block, block, block])
            .backend(backend)
            .threads(threads)
            .memory_budget(m.get_usize("memory-budget-mb")? * (1 << 20))
            .io_threads(m.get_usize("io-threads")?)
            .mixed_precision(m.get_bool("mixed"))
            .seed(seed);
        match m.get("prefetch-depth").unwrap_or("auto") {
            "auto" => {}
            d => {
                builder = builder.prefetch_depth(
                    d.parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("bad --prefetch-depth '{d}'"))?,
                )
            }
        }
        if let Some(dir) = m.get("checkpoint-dir") {
            builder = builder.checkpoint_dir(dir);
        }
        builder = builder.map_tier(MapTierChoice::parse(m.get("map-tier").unwrap_or("auto"))?);
        builder = builder
            .recovery_solver(RecoverySolver::parse(m.get("recovery-solver").unwrap_or("auto"))?)
            .recovery_panel_cols(m.get_usize("recovery-panel-cols")?);
        let cfg = builder.build()?;
        let mut pipe = Pipeline::new(cfg);
        if backend == Backend::Xla {
            // One constructor wires the whole XLA arm (fused compression +
            // ALS artifacts, CPU fallback kernels) from the run config.
            let xla = exascale_tensor::runtime::XlaBackend::from_config(pipe.config())?;
            pipe = pipe.with_compute(std::sync::Arc::new(xla));
        }

        let result = if let Some(path) = m.get("input") {
            // File inputs stream block-by-block: only the planner's working
            // set (not the tensor) must fit in memory.
            let src = FileTensorSource::open(path)?;
            println!(
                "file tensor {:?} ({} MiB on disk, streamed out-of-core)",
                src.dims(),
                src.payload_bytes() >> 20
            );
            pipe.run(&src)?
        } else {
            let gen = LowRankGenerator::new(size, size, size, rank, seed);
            println!(
                "synthetic implicit tensor {size}³ = {} virtual elements (rank {rank})",
                size * size * size
            );
            pipe.run(&gen)?
        };
        println!(
            "plan: P={} block={:?} est bytes={} out_of_core={} prefetch_depth={} \
             io_threads={} map_tier={} recovery_solver={}",
            result.plan.replicas,
            result.plan.block,
            result.plan.estimated_bytes,
            result.plan.out_of_core,
            result.plan.prefetch_depth,
            result.plan.io_threads,
            result.plan.map_tier.as_str(),
            result.plan.recovery_solver.as_str()
        );
        println!("sampled MSE      : {:.3e}", result.diagnostics.sampled_mse);
        println!("sampled rel error: {:.3e}", result.diagnostics.rel_error);
        println!("dropped replicas : {}", result.diagnostics.dropped_replicas);
        println!("\nstage timings:\n{}", pipe.metrics.report());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_gen_tensor(prog: &str, args: &[String]) -> i32 {
    let cmd = Command::new(
        "gen-tensor",
        "author an EXT1 tensor file from the implicit low-rank generator (streamed)",
    )
    .opt("size", "tensor side I=J=K", Some("200"))
    .opt("rank", "planted CP rank", Some("5"))
    .opt("noise", "additive N(0,σ²) noise sigma", Some("0"))
    .opt("slab", "frontal slices per write slab", Some("8"))
    .opt("out", "output path", Some("tensor.ext1"))
    .opt("seed", "random seed", Some("0"))
    .switch("help", "show help");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") {
        println!("{}", cmd.usage(prog));
        return 0;
    }
    let run = || -> anyhow::Result<()> {
        let size = m.get_usize("size")?;
        let rank = m.get_usize("rank")?;
        let out = m.get("out").unwrap_or("tensor.ext1");
        let sigma: f32 = m
            .get("noise")
            .unwrap_or("0")
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --noise"))?;
        let mut gen = LowRankGenerator::new(size, size, size, rank, m.get_u64("seed")?);
        if sigma > 0.0 {
            gen = gen.with_noise(sigma);
        }
        let t0 = std::time::Instant::now();
        save_tensor_streamed(&gen, out, m.get_usize("slab")?)?;
        let bytes = size * size * size * 4;
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "wrote {out}: {size}³ rank-{rank} tensor, {} MiB in {secs:.2}s ({:.1} MiB/s)",
            bytes >> 20,
            (bytes >> 20) as f64 / secs.max(1e-9)
        );
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_gene(prog: &str, args: &[String]) -> i32 {
    let cmd = Command::new("gene", "gene-expression CP analysis (§V-C)")
        .opt("individuals", "individuals dim", Some("120"))
        .opt("tissues", "tissues dim", Some("30"))
        .opt("genes", "genes dim", Some("800"))
        .opt("programs", "planted expression programs (rank)", Some("5"))
        .opt("seed", "random seed", Some("1"))
        .switch("help", "show help");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") {
        println!("{}", cmd.usage(prog));
        return 0;
    }
    let run = || -> anyhow::Result<()> {
        let cfg = GeneConfig {
            individuals: m.get_usize("individuals")?,
            tissues: m.get_usize("tissues")?,
            genes: m.get_usize("genes")?,
            programs: m.get_usize("programs")?,
            seed: m.get_u64("seed")?,
            ..Default::default()
        };
        let report = run_gene_analysis(&cfg)?;
        println!("gene tensor {:?} (individual × tissue × gene)", report.dims);
        println!("replicas          : {}", report.replicas);
        println!("relative error    : {:.3}%", 100.0 * report.rel_error);
        println!("factor congruence : {:.4}", report.factor_congruence);
        println!("decomposition time: {:.2} s", report.decompose_seconds);
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Deep-copies a trained network's parameters into a fresh instance so each
/// Table-I backend starts from identical weights.
fn clone_network(reference: &Network, seed: u64) -> Network {
    let mut net = Network::new(18, 8, 16, 32, 3, seed);
    net.conv1.weight = reference.conv1.weight.clone();
    net.conv1.bias = reference.conv1.bias.clone();
    net.conv2.weight = reference.conv2.weight.clone();
    net.conv2.bias = reference.conv2.bias.clone();
    net.fc1.weight = reference.fc1.weight.clone();
    net.fc1.bias = reference.fc1.bias.clone();
    net.fc2.weight = reference.fc2.weight.clone();
    net.fc2.bias = reference.fc2.bias.clone();
    net
}

fn cmd_cp_layer(prog: &str, args: &[String]) -> i32 {
    let cmd = Command::new("cp-layer", "CP tensor layer CNN compression (Table I)")
        .opt("train", "training images", Some("240"))
        .opt("test", "test images", Some("90"))
        .opt("rank", "CP rank for the conv layer", Some("8"))
        .opt("epochs", "pre-training epochs", Some("3"))
        .opt("seed", "random seed", Some("42"))
        .switch("help", "show help");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") {
        println!("{}", cmd.usage(prog));
        return 0;
    }
    let run = || -> anyhow::Result<()> {
        let gen = SyntheticImages::default();
        let train_ds = gen.generate(m.get_usize("train")?, 1);
        let test_ds = gen.generate(m.get_usize("test")?, 2);
        let seed = m.get_u64("seed")?;
        let rank = m.get_usize("rank")?;
        println!("training reference CNN…");
        let mut reference = Network::new(18, 8, 16, 32, 3, seed);
        train(
            &mut reference,
            &train_ds,
            &TrainConfig {
                epochs: m.get_usize("epochs")?,
                lr: 0.01,
                seed,
            },
        );
        println!(
            "{:<26} {:>8} {:>10} {:>10} {:>9} {:>8}",
            "method", "acc pre", "acc drop", "acc tuned", "time", "rel err"
        );
        for backend in [CpBackend::Hosvd, CpBackend::Random, CpBackend::Compressed] {
            let mut net = clone_network(&reference, seed);
            let rep =
                run_cp_layer_experiment(&mut net, &train_ds, &test_ds, rank, backend, 1, seed)?;
            println!(
                "{:<26} {:>7.1}% {:>9.1}% {:>9.1}% {:>8.2}s {:>8.4}",
                rep.backend,
                100.0 * rep.accuracy_before,
                100.0 * rep.accuracy_after_decomp,
                100.0 * rep.accuracy_after_finetune,
                rep.decomp_seconds,
                rep.reconstruction_error
            );
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn serve_cmd() -> Command {
    Command::new("serve", "multi-tenant decomposition daemon")
        .opt("addr", "bind address (port 0 = ephemeral)", Some("127.0.0.1:7077"))
        .opt("spool", "spool dir (job records, results, checkpoints)", Some("spool"))
        .opt(
            "memory-budget-mb",
            "global admission budget in MiB (0 = unlimited)",
            Some("0"),
        )
        .opt("workers", "concurrent jobs", Some("2"))
        .opt("cache-mb", "result-cache toggle in MiB (0 = off)", Some("64"))
        .opt(
            "store-mb",
            "artifact-store budget in MiB (proxy sets, shard accumulators, \
             cached factors; 0 = store off, no stage reuse)",
            Some("256"),
        )
        .opt(
            "starvation-rounds",
            "backfill admissions a blocked head job tolerates before the \
             scheduler reserves the budget for it",
            Some("8"),
        )
        .opt(
            "max-retries",
            "transient-failure requeues before a job is finally failed",
            Some("2"),
        )
        .opt(
            "poison-threshold",
            "panicking runs (daemon crashes included) before a job is quarantined",
            Some("2"),
        )
        .opt(
            "conn-timeout-ms",
            "per-request connection deadline in ms (reaps idle, half-open \
             and slow-loris peers; 0 = no deadline)",
            Some("30000"),
        )
        .opt(
            "max-conns",
            "concurrent connection bound (excess peers get a polite error; \
             0 = unbounded)",
            Some("256"),
        )
        .opt(
            "fault-plan",
            "chaos testing: arm a deterministic fault plan, e.g. \
             'seed=7;worker_panic:period=1,max=1,key=3'",
            None,
        )
        .opt(
            "lease-timeout-ms",
            "sharded jobs: worker lease deadline in ms (an expired lease's \
             unfinished shards are re-leased)",
            Some("5000"),
        )
        .opt(
            "lease-shards",
            "sharded jobs: contiguous shards per lease grant",
            Some("4"),
        )
        .opt(
            "batch-threshold-mb",
            "batch lane: jobs whose plan costs at most this coalesce into \
             shared ALS sweeps (0 = lane off)",
            Some("0"),
        )
        .opt("batch-max-jobs", "max jobs per coalesced sweep", Some("32"))
        .opt(
            "tenant-quota",
            "per-tenant concurrent-job cap enforced by the batch lane \
             (0 = unlimited)",
            Some("0"),
        )
        .switch("help", "show help")
}

fn cmd_serve(prog: &str, args: &[String]) -> i32 {
    let cmd = serve_cmd();
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") {
        println!("{}", cmd.usage(prog));
        return 0;
    }
    let run = || -> anyhow::Result<()> {
        if let Some(plan) = m.get("fault-plan") {
            exascale_tensor::util::fault::arm(exascale_tensor::util::fault::FaultPlan::parse(
                plan,
            )?);
        }
        let cfg = exascale_tensor::serve::ServerConfig {
            addr: m.req("addr")?.to_string(),
            spool_dir: m.req("spool")?.into(),
            scheduler: exascale_tensor::serve::SchedulerConfig {
                memory_budget: m.get_usize("memory-budget-mb")? * (1 << 20),
                workers: m.get_usize("workers")?,
                cache_bytes: m.get_usize("cache-mb")? * (1 << 20),
                store_bytes: m.get_usize("store-mb")? * (1 << 20),
                starvation_rounds: m.get_u64("starvation-rounds")?,
                max_retries: m.get_usize("max-retries")? as u32,
                poison_threshold: m.get_usize("poison-threshold")? as u32,
                batch_threshold_bytes: m.get_usize("batch-threshold-mb")? * (1 << 20),
                batch_max_jobs: m.get_usize("batch-max-jobs")?,
                tenant_quota: m.get_usize("tenant-quota")?,
                lease_timeout_ms: m.get_u64("lease-timeout-ms")?,
                lease_shards: m.get_usize("lease-shards")?,
                ..Default::default()
            },
            conn_timeout_ms: m.get_u64("conn-timeout-ms")?,
            max_conns: m.get_usize("max-conns")?,
        };
        let server = exascale_tensor::serve::Server::bind(&cfg)?;
        // The "listening" line is the readiness signal scripts wait for.
        println!("exatensor serve: listening on {} (spool {})", server.local_addr(),
                 cfg.spool_dir.display());
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        server.run()
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn worker_cmd() -> Command {
    Command::new("worker", "join a daemon as a shard-lease worker")
        .opt("addr", "coordinator address", Some("127.0.0.1:7077"))
        .opt("name", "worker name shown by LIST", Some("worker"))
        .opt("backoff-ms", "idle backoff when no lease is available", Some("50"))
        .opt(
            "fault-plan",
            "chaos testing: arm a deterministic fault plan, e.g. \
             'seed=7;worker_panic:period=1,max=1'",
            None,
        )
        .opt(
            "key",
            "fault key matched by worker_panic:…,key=K schedules, so a \
             plan kills exactly one worker of a fleet",
            Some("0"),
        )
        .switch("help", "show help")
}

fn cmd_worker(prog: &str, args: &[String]) -> i32 {
    let cmd = worker_cmd();
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") {
        println!("{}", cmd.usage(prog));
        return 0;
    }
    let run = || -> anyhow::Result<()> {
        if let Some(plan) = m.get("fault-plan") {
            exascale_tensor::util::fault::arm(exascale_tensor::util::fault::FaultPlan::parse(
                plan,
            )?);
        }
        let cfg = exascale_tensor::serve::WorkerConfig {
            addr: m.req("addr")?.to_string(),
            name: m.req("name")?.to_string(),
            backoff_ms: m.get_u64("backoff-ms")?,
            fault_key: m.get_u64("key")?,
        };
        let report = exascale_tensor::serve::run_worker(&cfg)?;
        println!(
            "worker {}: coordinator drained after {} leases, {} shards served",
            cfg.name, report.leases, report.shards
        );
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn client_cmd() -> Command {
    Command::new(
        "client",
        "talk to a running daemon: submit|status|result|cancel|list|metrics|shutdown",
    )
    .opt("addr", "daemon address", Some("127.0.0.1:7077"))
    .opt("id", "job id (status/result/cancel)", None)
    .opt("tenant", "owning tenant for fair-share accounting (submit)", None)
    .opt("size", "synthetic tensor side I=J=K", Some("200"))
    .opt("source-rank", "planted generator rank (default: --rank)", None)
    .opt("noise", "synthetic additive noise sigma", Some("0"))
    .opt("input", "EXT1 tensor file instead of synthetic", None)
    .opt("rank", "CP rank F", Some("5"))
    .opt("reduced", "proxy side L=M=N", Some("24"))
    .opt("block", "compression block side d", Some("60"))
    .opt("memory-budget-mb", "per-job planner budget in MiB (0 = daemon default)", Some("0"))
    .opt("threads", "per-job worker threads", Some("2"))
    .opt("priority", "higher runs first", Some("0"))
    .opt("map-tier", "replica-map tier: auto | materialized | procedural", Some("auto"))
    .opt(
        "recovery-solver",
        "stacked-solve solver: auto | cholesky | iterative | sketch",
        Some("auto"),
    )
    .opt("recovery-panel-cols", "streamed map-panel width in columns", Some("256"))
    .opt(
        "anchor-rows",
        "anchor rows S (default rank+2; pin it so a rank sweep shares one \
         Stage-1 artifact across ranks)",
        None,
    )
    .opt("replicas", "replica count P (default: planner's replica rule)", None)
    .opt("seed", "random seed", Some("0"))
    .opt("poll-ms", "--wait poll interval", Some("200"))
    .switch(
        "sharded",
        "run the compression stage across connected shard-lease workers \
         (results stay bitwise identical to a solo run)",
    )
    .switch(
        "no-cache",
        "bypass the daemon's artifact store for this job: no result-cache \
         fast path, no stage reuse, nothing published (cold-baseline runs)",
    )
    .switch("wait", "block until the submitted job is terminal")
    .switch("help", "show help")
}

fn cmd_client(prog: &str, args: &[String]) -> i32 {
    use exascale_tensor::serve::{protocol, JobSource, JobSpec, Request};
    let cmd = client_cmd();
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cmd.usage(prog));
            return 2;
        }
    };
    if m.get_bool("help") || m.positional.is_empty() {
        println!("{}", cmd.usage(prog));
        return i32::from(!m.get_bool("help"));
    }
    let run = || -> anyhow::Result<()> {
        let addr = m.req("addr")?;
        let verb = m.positional[0].as_str();
        let want_id = || -> anyhow::Result<String> { Ok(m.req("id")?.to_string()) };
        let req = match verb {
            "submit" => {
                let rank = m.get_usize("rank")?;
                let seed = m.get_u64("seed")?;
                let source = match m.get("input") {
                    Some(path) => JobSource::File { path: path.to_string() },
                    None => JobSource::Synthetic {
                        size: m.get_usize("size")?,
                        rank: match m.get("source-rank") {
                            Some(_) => m.get_usize("source-rank")?,
                            None => rank,
                        },
                        noise: m.get_f64("noise")?,
                        seed,
                    },
                };
                let reduced = m.get_usize("reduced")?;
                let block = m.get_usize("block")?;
                let mut builder = PipelineConfig::builder()
                    .reduced_dims(reduced, reduced, reduced)
                    .rank(rank)
                    .block([block, block, block])
                    .threads(m.get_usize("threads")?);
                if m.get("anchor-rows").is_some() {
                    builder = builder.anchor_rows(m.get_usize("anchor-rows")?);
                }
                if m.get("replicas").is_some() {
                    builder = builder.replicas(m.get_usize("replicas")?);
                }
                let config = builder
                    .memory_budget(m.get_usize("memory-budget-mb")? * (1 << 20))
                    .map_tier(MapTierChoice::parse(m.get("map-tier").unwrap_or("auto"))?)
                    .recovery_solver(RecoverySolver::parse(
                        m.get("recovery-solver").unwrap_or("auto"),
                    )?)
                    .recovery_panel_cols(m.get_usize("recovery-panel-cols")?)
                    .seed(seed)
                    .build()?;
                Request::Submit(JobSpec {
                    source,
                    config,
                    priority: m.get_f64("priority")? as i64,
                    tenant: m.get("tenant").unwrap_or("").to_string(),
                    sharded: m.get_bool("sharded"),
                    no_cache: m.get_bool("no-cache"),
                })
            }
            "status" => Request::Status(want_id()?),
            "result" => Request::Result(want_id()?),
            "cancel" => Request::Cancel(want_id()?),
            "list" => Request::List,
            "metrics" => Request::Metrics,
            "shutdown" => Request::Shutdown,
            other => anyhow::bail!("unknown client verb '{other}'"),
        };
        let resp = protocol::call(addr, &req)?;
        print!("{}", resp.to_string_pretty());
        if verb == "submit" && m.get_bool("wait") {
            let id = resp
                .get("job")
                .and_then(|j| j.get("id"))
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow::anyhow!("submit failed, nothing to wait for"))?
                .to_string();
            let poll = std::time::Duration::from_millis(m.get_u64("poll-ms")?);
            loop {
                std::thread::sleep(poll);
                let st = protocol::call_ok(addr, &Request::Status(id.clone()))?;
                let state = st
                    .get("job")
                    .and_then(|j| j.get("state"))
                    .and_then(|x| x.as_str())
                    .unwrap_or("?")
                    .to_string();
                if matches!(state.as_str(), "done" | "failed" | "cancelled") {
                    print!("{}", st.to_string_pretty());
                    if state != "done" {
                        anyhow::bail!("job {id} ended {state}");
                    }
                    break;
                }
            }
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_artifacts() -> i32 {
    match exascale_tensor::runtime::Manifest::load(artifacts_dir()) {
        Ok(man) => {
            println!("{} artifacts in {}:", man.artifacts.len(), man.dir.display());
            for (name, spec) in &man.artifacts {
                println!(
                    "  {:<38} kind={:<18} in={:?} out={:?}",
                    name, spec.kind, spec.inputs, spec.outputs
                );
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#} (run `make artifacts`)");
            1
        }
    }
}
