//! Tensor sources: how the pipeline reads (possibly enormous) input tensors.
//!
//! The paper's experiments generate tensors from planted CP factors with
//! dims up to 100,000³ — far beyond memory.  The key observation (which the
//! paper's own evaluation relies on) is that the algorithm only ever touches
//! the input **block-wise**, so a [`TensorSource`] that materializes any
//! requested block on demand reproduces the exact computation without ever
//! holding the full tensor.  `LowRankGenerator` is that implicit source;
//! `InMemorySource` wraps a real [`DenseTensor`] for small inputs and tests.

use super::block::BlockRange;
use super::dense::DenseTensor;
use crate::linalg::Matrix;
use crate::util::rng::{SplitMix64, Xoshiro256};

/// A readable third-order tensor, addressed by blocks.
///
/// Implementations must be `Sync`: the block-compression stage reads blocks
/// from many worker threads at once.
pub trait TensorSource: Sync {
    /// Tensor dimensions `[I, J, K]`.
    fn dims(&self) -> [usize; 3];

    /// Materializes the block `X(i0..i1, j0..j1, k0..k1)`.
    fn block(&self, r: &BlockRange) -> DenseTensor;

    /// Number of nonzeros if the source is sparse (None ⇒ dense).
    fn nnz_estimate(&self) -> Option<usize> {
        None
    }

    /// Convenience: materializes the leading `b×b×b` corner (the sampled
    /// tensor `B` of Alg. 2 line 10).
    fn corner(&self, b: usize) -> DenseTensor {
        let [i, j, k] = self.dims();
        let r = BlockRange {
            i0: 0,
            i1: b.min(i),
            j0: 0,
            j1: b.min(j),
            k0: 0,
            k1: b.min(k),
            index: 0,
        };
        self.block(&r)
    }
}

/// Implicit dense low-rank tensor `X = Σ_r a_r∘b_r∘c_r (+ σ·noise)`.
///
/// Blocks are computed on demand from factor row-slices; optional noise is
/// element-deterministic (counter-based hashing) so overlapping reads agree.
pub struct LowRankGenerator {
    pub factors: (Matrix, Matrix, Matrix),
    dims: [usize; 3],
    noise_sigma: f32,
    seed: u64,
}

impl LowRankGenerator {
    /// Plants rank-`rank` normal factors for an `i×j×k` tensor.
    pub fn new(i: usize, j: usize, k: usize, rank: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let a = Matrix::random_normal(i, rank, &mut rng);
        let b = Matrix::random_normal(j, rank, &mut rng);
        let c = Matrix::random_normal(k, rank, &mut rng);
        Self {
            factors: (a, b, c),
            dims: [i, j, k],
            noise_sigma: 0.0,
            seed,
        }
    }

    /// Uses caller-provided factors.
    pub fn from_factors(a: Matrix, b: Matrix, c: Matrix, seed: u64) -> Self {
        let dims = [a.rows(), b.rows(), c.rows()];
        assert_eq!(a.cols(), b.cols());
        assert_eq!(b.cols(), c.cols());
        Self {
            factors: (a, b, c),
            dims,
            noise_sigma: 0.0,
            seed,
        }
    }

    /// Adds i.i.d. `N(0, σ²)` noise (element-deterministic).
    pub fn with_noise(mut self, sigma: f32) -> Self {
        self.noise_sigma = sigma;
        self
    }

    pub fn rank(&self) -> usize {
        self.factors.0.cols()
    }

    /// Deterministic per-element noise: hash (seed, i, j, k) → N(0,1).
    #[inline]
    fn noise_at(&self, i: usize, j: usize, k: usize) -> f32 {
        // Two decorrelated uniforms via SplitMix64 streams → Box-Muller.
        let key = (i as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((j as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
            .wrapping_add((k as u64).wrapping_mul(0x1656_67B1_9E37_79F9))
            .wrapping_add(self.seed);
        let mut sm = SplitMix64::new(key);
        let u1 = (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u2 = (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let r = (-2.0 * (1.0 - u1).max(1e-300).ln()).sqrt();
        (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }
}

impl TensorSource for LowRankGenerator {
    fn dims(&self) -> [usize; 3] {
        self.dims
    }

    fn block(&self, r: &BlockRange) -> DenseTensor {
        let (a, b, c) = &self.factors;
        let a_blk = a.slice_rows(r.i0, r.i1);
        let b_blk = b.slice_rows(r.j0, r.j1);
        let c_blk = c.slice_rows(r.k0, r.k1);
        let mut t = DenseTensor::from_cp_factors(&a_blk, &b_blk, &c_blk);
        if self.noise_sigma > 0.0 {
            let [di, dj, _] = t.dims();
            let sigma = self.noise_sigma;
            let data = t.data_mut();
            for k in r.k0..r.k1 {
                for j in r.j0..r.j1 {
                    let base = (j - r.j0) * di + (k - r.k0) * di * dj;
                    for i in r.i0..r.i1 {
                        data[base + (i - r.i0)] += sigma * self.noise_at(i, j, k);
                    }
                }
            }
        }
        t
    }
}

/// Implicit **sparse** low-rank tensor: factor columns have exactly
/// `nnz_per_col` nonzeros (the paper's sparse-tensor generator: "the number
/// of non-zero elements in each mode matrix as one hundred of the
/// dimension").
pub struct SparseLowRankGenerator {
    inner: LowRankGenerator,
    nnz_per_col: usize,
}

impl SparseLowRankGenerator {
    pub fn new(i: usize, j: usize, k: usize, rank: usize, nnz_per_col: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x5157_u64);
        let sparse_factor = |dim: usize, rng: &mut Xoshiro256| {
            let mut m = Matrix::zeros(dim, rank);
            for c in 0..rank {
                let nnz = nnz_per_col.min(dim);
                let rows = rng.sample_indices(dim, nnz);
                for row in rows {
                    m.set(row, c, rng.next_gaussian() as f32);
                }
            }
            m
        };
        let a = sparse_factor(i, &mut rng);
        let b = sparse_factor(j, &mut rng);
        let c = sparse_factor(k, &mut rng);
        Self {
            inner: LowRankGenerator::from_factors(a, b, c, seed),
            nnz_per_col,
        }
    }

    pub fn factors(&self) -> &(Matrix, Matrix, Matrix) {
        &self.inner.factors
    }

    pub fn rank(&self) -> usize {
        self.inner.rank()
    }
}

impl TensorSource for SparseLowRankGenerator {
    fn dims(&self) -> [usize; 3] {
        self.inner.dims()
    }

    fn block(&self, r: &BlockRange) -> DenseTensor {
        self.inner.block(r)
    }

    fn nnz_estimate(&self) -> Option<usize> {
        // Union bound over rank-1 terms: each contributes nnz³ elements.
        Some(self.inner.rank() * self.nnz_per_col.pow(3))
    }
}

/// A fully materialized tensor as a source (small inputs, tests, apps).
pub struct InMemorySource {
    pub tensor: DenseTensor,
}

impl InMemorySource {
    pub fn new(tensor: DenseTensor) -> Self {
        Self { tensor }
    }
}

impl TensorSource for InMemorySource {
    fn dims(&self) -> [usize; 3] {
        self.tensor.dims()
    }

    fn block(&self, r: &BlockRange) -> DenseTensor {
        self.tensor.subtensor(r.i0, r.i1, r.j0, r.j1, r.k0, r.k1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::block::BlockSpec3;

    #[test]
    fn blocks_agree_with_full_materialization() {
        let gen = LowRankGenerator::new(12, 10, 8, 3, 99);
        let (a, b, c) = &gen.factors;
        let full = DenseTensor::from_cp_factors(a, b, c);
        let spec = BlockSpec3::new([12, 10, 8], [5, 4, 3]);
        for blk in spec.iter() {
            let t = gen.block(&blk);
            for k in 0..t.dims()[2] {
                for j in 0..t.dims()[1] {
                    for i in 0..t.dims()[0] {
                        let expected = full.get(blk.i0 + i, blk.j0 + j, blk.k0 + k);
                        assert!((t.get(i, j, k) - expected).abs() < 1e-5);
                    }
                }
            }
        }
    }

    #[test]
    fn noise_is_deterministic_across_overlapping_reads() {
        let gen = LowRankGenerator::new(8, 8, 8, 2, 7).with_noise(0.1);
        let r1 = BlockRange { i0: 0, i1: 8, j0: 0, j1: 8, k0: 0, k1: 8, index: 0 };
        let r2 = BlockRange { i0: 2, i1: 6, j0: 2, j1: 6, k0: 2, k1: 6, index: 0 };
        let big = gen.block(&r1);
        let small = gen.block(&r2);
        for k in 0..4 {
            for j in 0..4 {
                for i in 0..4 {
                    assert_eq!(small.get(i, j, k), big.get(i + 2, j + 2, k + 2));
                }
            }
        }
    }

    #[test]
    fn noise_changes_values() {
        let clean = LowRankGenerator::new(6, 6, 6, 2, 7);
        let noisy = LowRankGenerator::new(6, 6, 6, 2, 7).with_noise(0.5);
        let r = BlockRange { i0: 0, i1: 6, j0: 0, j1: 6, k0: 0, k1: 6, index: 0 };
        let a = clean.block(&r);
        let b = noisy.block(&r);
        assert!(a.rel_error(&b) > 1e-3);
    }

    #[test]
    fn corner_is_leading_block() {
        let gen = LowRankGenerator::new(10, 10, 10, 2, 3);
        let c = gen.corner(4);
        assert_eq!(c.dims(), [4, 4, 4]);
        let full_r = BlockRange { i0: 0, i1: 10, j0: 0, j1: 10, k0: 0, k1: 10, index: 0 };
        let full = gen.block(&full_r);
        assert_eq!(c.get(1, 2, 3), full.get(1, 2, 3));
    }

    #[test]
    fn sparse_generator_has_sparse_factors() {
        let gen = SparseLowRankGenerator::new(50, 50, 50, 3, 5, 13);
        let (a, _, _) = gen.factors();
        for c in 0..3 {
            let nnz = a.col(c).iter().filter(|&&x| x != 0.0).count();
            assert_eq!(nnz, 5);
        }
        assert_eq!(gen.nnz_estimate(), Some(3 * 125));
    }

    #[test]
    fn in_memory_source_round_trips() {
        let t = DenseTensor::from_fn([4, 5, 6], |i, j, k| (i + j + k) as f32);
        let src = InMemorySource::new(t.clone());
        assert_eq!(src.dims(), [4, 5, 6]);
        let r = BlockRange { i0: 1, i1: 3, j0: 0, j1: 5, k0: 2, k1: 4, index: 0 };
        let blk = src.block(&r);
        assert_eq!(blk.get(0, 0, 0), t.get(1, 0, 2));
        assert_eq!(blk.get(1, 4, 1), t.get(2, 4, 3));
    }
}
