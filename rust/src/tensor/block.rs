//! Block partitioning of a third-order tensor (Fig. 2 of the paper).
//!
//! The compression stage never sees the whole tensor: it iterates over
//! `d₁×d₂×d₃` blocks, compresses each against the matching column-slices of
//! the compression matrices, and accumulates into the proxy tensor.  Edge
//! blocks are allowed to be smaller (the paper assumes divisibility; we
//! don't).

/// Block-grid description for an `I×J×K` tensor with block dims `d`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSpec3 {
    pub dims: [usize; 3],
    pub block: [usize; 3],
}

/// One block's coordinates: half-open ranges per mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockRange {
    pub i0: usize,
    pub i1: usize,
    pub j0: usize,
    pub j1: usize,
    pub k0: usize,
    pub k1: usize,
    /// Linear block index (for worker-stream seeding / progress).
    pub index: usize,
}

impl BlockRange {
    pub fn shape(&self) -> [usize; 3] {
        [self.i1 - self.i0, self.j1 - self.j0, self.k1 - self.k0]
    }

    pub fn len(&self) -> usize {
        let s = self.shape();
        s[0] * s[1] * s[2]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl BlockSpec3 {
    pub fn new(dims: [usize; 3], block: [usize; 3]) -> Self {
        assert!(block.iter().all(|&b| b > 0), "block dims must be positive");
        Self { dims, block }
    }

    /// Number of blocks along each mode.
    pub fn grid(&self) -> [usize; 3] {
        [
            self.dims[0].div_ceil(self.block[0]),
            self.dims[1].div_ceil(self.block[1]),
            self.dims[2].div_ceil(self.block[2]),
        ]
    }

    pub fn num_blocks(&self) -> usize {
        let g = self.grid();
        g[0] * g[1] * g[2]
    }

    /// Block at grid coordinates `(bi, bj, bk)`.
    pub fn block_at(&self, bi: usize, bj: usize, bk: usize) -> BlockRange {
        let g = self.grid();
        assert!(bi < g[0] && bj < g[1] && bk < g[2], "block index out of grid");
        let i0 = bi * self.block[0];
        let j0 = bj * self.block[1];
        let k0 = bk * self.block[2];
        BlockRange {
            i0,
            i1: (i0 + self.block[0]).min(self.dims[0]),
            j0,
            j1: (j0 + self.block[1]).min(self.dims[1]),
            k0,
            k1: (k0 + self.block[2]).min(self.dims[2]),
            index: bi + bj * g[0] + bk * g[0] * g[1],
        }
    }

    /// Iterator over all blocks, mode-1-fastest order (matches the memory
    /// layout so streaming reads are as sequential as possible).
    pub fn iter(&self) -> BlockIter {
        BlockIter {
            spec: *self,
            next: 0,
        }
    }
}

/// Iterator produced by [`BlockSpec3::iter`].
pub struct BlockIter {
    spec: BlockSpec3,
    next: usize,
}

impl Iterator for BlockIter {
    type Item = BlockRange;

    fn next(&mut self) -> Option<BlockRange> {
        let g = self.spec.grid();
        let total = g[0] * g[1] * g[2];
        if self.next >= total {
            return None;
        }
        let idx = self.next;
        self.next += 1;
        let bi = idx % g[0];
        let bj = (idx / g[0]) % g[1];
        let bk = idx / (g[0] * g[1]);
        Some(self.spec.block_at(bi, bj, bk))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let remaining = self.spec.num_blocks() - self.next;
        (remaining, Some(remaining))
    }
}

impl ExactSizeIterator for BlockIter {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn exact_division() {
        let spec = BlockSpec3::new([100, 100, 100], [50, 50, 50]);
        assert_eq!(spec.grid(), [2, 2, 2]);
        assert_eq!(spec.num_blocks(), 8);
        let b = spec.block_at(1, 0, 1);
        assert_eq!((b.i0, b.i1), (50, 100));
        assert_eq!((b.k0, b.k1), (50, 100));
        assert_eq!(b.shape(), [50, 50, 50]);
    }

    #[test]
    fn ragged_edges() {
        let spec = BlockSpec3::new([10, 7, 5], [4, 4, 4]);
        assert_eq!(spec.grid(), [3, 2, 2]);
        let last = spec.block_at(2, 1, 1);
        assert_eq!(last.shape(), [2, 3, 1]);
    }

    #[test]
    fn iter_covers_exactly_once() {
        prop::check("blocks-partition", 30, |g| {
            let dims = [g.int(1, 12), g.int(1, 12), g.int(1, 12)];
            let block = [g.int(1, 5), g.int(1, 5), g.int(1, 5)];
            let spec = BlockSpec3::new(dims, block);
            let mut covered = vec![0u8; dims[0] * dims[1] * dims[2]];
            let mut count = 0;
            for b in spec.iter() {
                count += 1;
                for k in b.k0..b.k1 {
                    for j in b.j0..b.j1 {
                        for i in b.i0..b.i1 {
                            covered[i + j * dims[0] + k * dims[0] * dims[1]] += 1;
                        }
                    }
                }
            }
            assert_eq!(count, spec.num_blocks());
            assert!(covered.iter().all(|&c| c == 1), "cover counts {covered:?}");
        });
    }

    #[test]
    fn indices_unique_and_dense() {
        let spec = BlockSpec3::new([9, 9, 9], [4, 4, 4]);
        let mut seen: Vec<usize> = spec.iter().map(|b| b.index).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..spec.num_blocks()).collect::<Vec<_>>());
    }

    #[test]
    fn exact_size_iterator() {
        let spec = BlockSpec3::new([8, 8, 8], [3, 3, 3]);
        let it = spec.iter();
        assert_eq!(it.len(), 27);
    }

    #[test]
    #[should_panic(expected = "block dims must be positive")]
    fn zero_block_rejected() {
        let _ = BlockSpec3::new([4, 4, 4], [0, 2, 2]);
    }
}
