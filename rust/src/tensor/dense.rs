//! Dense third-order tensor, column-major.
//!
//! Layout: element `(i, j, k)` of an `I×J×K` tensor lives at
//! `i + j·I + k·I·J` — "column-major" in the sense of §IV-A: mode-1 fibers
//! are contiguous, so the mode-1 matricization `X_(1) (I × J·K)` is a free
//! reinterpretation of the same buffer.

use crate::linalg::Matrix;
use crate::util::rng::Xoshiro256;

/// Dense `I×J×K` tensor of `f32`, column-major.
#[derive(Clone, PartialEq, Debug)]
pub struct DenseTensor {
    dims: [usize; 3],
    data: Vec<f32>,
}

impl DenseTensor {
    pub fn zeros(i: usize, j: usize, k: usize) -> Self {
        Self {
            dims: [i, j, k],
            data: vec![0.0; i * j * k],
        }
    }

    /// Takes ownership of a column-major buffer.
    pub fn from_vec(dims: [usize; 3], data: Vec<f32>) -> Self {
        assert_eq!(data.len(), dims[0] * dims[1] * dims[2], "buffer size mismatch");
        Self { dims, data }
    }

    pub fn from_fn(dims: [usize; 3], mut f: impl FnMut(usize, usize, usize) -> f32) -> Self {
        let mut t = Self::zeros(dims[0], dims[1], dims[2]);
        for k in 0..dims[2] {
            for j in 0..dims[1] {
                for i in 0..dims[0] {
                    t.set(i, j, k, f(i, j, k));
                }
            }
        }
        t
    }

    /// i.i.d. standard-normal entries.
    pub fn random_normal(dims: [usize; 3], rng: &mut Xoshiro256) -> Self {
        let mut data = vec![0.0f32; dims[0] * dims[1] * dims[2]];
        rng.fill_gaussian_f32(&mut data);
        Self { dims, data }
    }

    /// Materializes `X = Σ_r a_r ∘ b_r ∘ c_r` from CP factors (Eq. 1).
    pub fn from_cp_factors(a: &Matrix, b: &Matrix, c: &Matrix) -> Self {
        let r = a.cols();
        assert_eq!(b.cols(), r);
        assert_eq!(c.cols(), r);
        let (i_dim, j_dim, k_dim) = (a.rows(), b.rows(), c.rows());
        let mut t = Self::zeros(i_dim, j_dim, k_dim);
        for rr in 0..r {
            let ac = a.col(rr);
            let bc = b.col(rr);
            let cc = c.col(rr);
            for (k, &cv) in cc.iter().enumerate() {
                for (j, &bv) in bc.iter().enumerate() {
                    let s = cv * bv;
                    if s == 0.0 {
                        continue;
                    }
                    let base = (j + k * j_dim) * i_dim;
                    for (i, &av) in ac.iter().enumerate() {
                        t.data[base + i] += av * s;
                    }
                }
            }
        }
        t
    }

    #[inline]
    pub fn dims(&self) -> [usize; 3] {
        self.dims
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    fn offset(&self, i: usize, j: usize, k: usize) -> usize {
        debug_assert!(i < self.dims[0] && j < self.dims[1] && k < self.dims[2]);
        i + j * self.dims[0] + k * self.dims[0] * self.dims[1]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize, k: usize) -> f32 {
        self.data[self.offset(i, j, k)]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, k: usize, v: f32) {
        let o = self.offset(i, j, k);
        self.data[o] = v;
    }

    #[inline]
    pub fn add_assign_at(&mut self, i: usize, j: usize, k: usize, v: f32) {
        let o = self.offset(i, j, k);
        self.data[o] += v;
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Frontal slice `X(:,:,k)` as an `I×J` matrix (memcpy: slice is
    /// contiguous in this layout).
    pub fn frontal_slice(&self, k: usize) -> Matrix {
        let (i_dim, j_dim) = (self.dims[0], self.dims[1]);
        let start = k * i_dim * j_dim;
        Matrix::from_vec(i_dim, j_dim, self.data[start..start + i_dim * j_dim].to_vec())
    }

    /// Extracts the sub-tensor `X(i0..i1, j0..j1, k0..k1)`.
    pub fn subtensor(&self, i0: usize, i1: usize, j0: usize, j1: usize, k0: usize, k1: usize) -> DenseTensor {
        assert!(i1 <= self.dims[0] && j1 <= self.dims[1] && k1 <= self.dims[2]);
        let mut out = DenseTensor::zeros(i1 - i0, j1 - j0, k1 - k0);
        for k in k0..k1 {
            for j in j0..j1 {
                // mode-1 fibers are contiguous: copy a run of length i1-i0
                let src = self.offset(i0, j, k);
                let dst = out.offset(0, j - j0, k - k0);
                out.data[dst..dst + (i1 - i0)].copy_from_slice(&self.data[src..src + (i1 - i0)]);
            }
        }
        out
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Mean squared error against another tensor of the same shape.
    pub fn mse(&self, other: &DenseTensor) -> f64 {
        assert_eq!(self.dims, other.dims);
        let n = self.data.len().max(1) as f64;
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / n
    }

    /// Relative Frobenius error `‖self − other‖ / ‖other‖`.
    pub fn rel_error(&self, other: &DenseTensor) -> f64 {
        assert_eq!(self.dims, other.dims);
        let denom = other.frobenius_norm();
        let diff: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>();
        if denom == 0.0 {
            diff.sqrt()
        } else {
            diff.sqrt() / denom
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_mode1_fibers_contiguous() {
        let t = DenseTensor::from_fn([2, 3, 2], |i, j, k| (i + 10 * j + 100 * k) as f32);
        // data[i + j*2 + k*6]
        assert_eq!(t.data()[0], 0.0); // (0,0,0)
        assert_eq!(t.data()[1], 1.0); // (1,0,0)
        assert_eq!(t.data()[2], 10.0); // (0,1,0)
        assert_eq!(t.data()[6], 100.0); // (0,0,1)
    }

    #[test]
    fn cp_factors_rank1() {
        let a = Matrix::from_rows(&[&[1.0], &[2.0]]);
        let b = Matrix::from_rows(&[&[3.0], &[4.0]]);
        let c = Matrix::from_rows(&[&[5.0], &[6.0]]);
        let t = DenseTensor::from_cp_factors(&a, &b, &c);
        assert_eq!(t.dims(), [2, 2, 2]);
        assert_eq!(t.get(0, 0, 0), 15.0);
        assert_eq!(t.get(1, 1, 1), 2.0 * 4.0 * 6.0);
    }

    #[test]
    fn cp_factors_additive_in_rank() {
        let mut rng = Xoshiro256::seed_from_u64(50);
        let a = Matrix::random_normal(3, 2, &mut rng);
        let b = Matrix::random_normal(4, 2, &mut rng);
        let c = Matrix::random_normal(5, 2, &mut rng);
        let full = DenseTensor::from_cp_factors(&a, &b, &c);
        let t1 = DenseTensor::from_cp_factors(
            &a.slice_cols(0, 1),
            &b.slice_cols(0, 1),
            &c.slice_cols(0, 1),
        );
        let t2 = DenseTensor::from_cp_factors(
            &a.slice_cols(1, 2),
            &b.slice_cols(1, 2),
            &c.slice_cols(1, 2),
        );
        for idx in 0..full.len() {
            assert!((full.data()[idx] - (t1.data()[idx] + t2.data()[idx])).abs() < 1e-5);
        }
    }

    #[test]
    fn frontal_slice_matches_get() {
        let t = DenseTensor::from_fn([3, 4, 2], |i, j, k| (i * 100 + j * 10 + k) as f32);
        let s = t.frontal_slice(1);
        for i in 0..3 {
            for j in 0..4 {
                assert_eq!(s.get(i, j), t.get(i, j, 1));
            }
        }
    }

    #[test]
    fn subtensor_extracts() {
        let t = DenseTensor::from_fn([4, 4, 4], |i, j, k| (i + 10 * j + 100 * k) as f32);
        let s = t.subtensor(1, 3, 2, 4, 0, 2);
        assert_eq!(s.dims(), [2, 2, 2]);
        assert_eq!(s.get(0, 0, 0), t.get(1, 2, 0));
        assert_eq!(s.get(1, 1, 1), t.get(2, 3, 1));
    }

    #[test]
    fn mse_and_rel_error() {
        let a = DenseTensor::from_fn([2, 2, 2], |_, _, _| 1.0);
        let b = DenseTensor::from_fn([2, 2, 2], |_, _, _| 2.0);
        assert!((a.mse(&b) - 1.0).abs() < 1e-12);
        assert!((a.rel_error(&b) - 0.5).abs() < 1e-6);
        assert_eq!(a.mse(&a), 0.0);
    }

    #[test]
    #[should_panic(expected = "buffer size mismatch")]
    fn from_vec_validates() {
        let _ = DenseTensor::from_vec([2, 2, 2], vec![0.0; 7]);
    }
}
