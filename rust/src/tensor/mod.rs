//! Third-order tensor substrate.
//!
//! Column-major dense tensors (paper §IV-A: mode-1 matricization is then a
//! free reinterpretation), COO sparse tensors, block views for the Fig. 2
//! streaming compression, stride-view unfoldings, and the implicit low-rank
//! generator that stands in for the paper's trillion/exascale inputs (see
//! DESIGN.md "Substitutions").

pub mod block;
pub mod dense;
pub mod generator;
pub mod io;
pub mod sparse;
pub mod unfold;

pub use block::{BlockIter, BlockRange, BlockSpec3};
pub use dense::DenseTensor;
pub use generator::{InMemorySource, LowRankGenerator, SparseLowRankGenerator, TensorSource};
pub use io::{save_tensor_streamed, FileTensorSource, StreamedTensorWriter};
pub use sparse::SparseTensor;
