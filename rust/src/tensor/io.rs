//! Binary tensor/matrix I/O and the file-backed block source.
//!
//! Simple self-describing little-endian format:
//! magic `EXT1`, u32 ndim, u64 dims…, f32 data (column-major).  Used by the
//! CLI to load real inputs, by the apps to persist decompositions, and —
//! since the out-of-core PR — as the on-disk layout behind
//! [`FileTensorSource`] (seek-based block reads, never materializing the
//! whole tensor) and [`StreamedTensorWriter`] (authoring larger-than-RAM
//! files slab by slab).

use super::block::BlockRange;
use super::dense::DenseTensor;
use super::generator::TensorSource;
use crate::linalg::Matrix;
use crate::util::fault::{self, TRANSIENT_MARKER};
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, ErrorKind, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

const MAGIC: &[u8; 4] = b"EXT1";

/// Transient read failures retried before giving up (so a fault schedule
/// with `period >= 2` can never exhaust a read's budget).
const IO_MAX_RETRIES: u32 = 4;
/// Capped exponential backoff between retries: 2, 4, 8, 16 ms.
const IO_BACKOFF_BASE_MS: u64 = 2;
const IO_BACKOFF_CAP_MS: u64 = 100;

/// Process-wide I/O failure telemetry.  `read_at` has no metrics handle (it
/// runs on source/producer threads deep under the engine), so the pipeline
/// snapshots these before/after a run and reports the deltas as its
/// `io_retries` / `io_gave_up` metrics.
pub static IO_RETRIES: AtomicU64 = AtomicU64::new(0);
pub static IO_GAVE_UP: AtomicU64 = AtomicU64::new(0);

/// Transient I/O errors are worth retrying: the syscall was interrupted or
/// the storage stack timed out.  Everything else (bad fd, truncation's
/// `UnexpectedEof`, permission) is permanent — retrying can't help.
fn transient_io(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        ErrorKind::Interrupted | ErrorKind::TimedOut | ErrorKind::WouldBlock
    )
}

fn write_header(w: &mut impl Write, dims: &[u64]) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&(dims.len() as u32).to_le_bytes())?;
    for &d in dims {
        w.write_all(&d.to_le_bytes())?;
    }
    Ok(())
}

/// Header byte size for an `ndim`-way file: magic + ndim + dims.
fn header_len(ndim: usize) -> u64 {
    (4 + 4 + 8 * ndim) as u64
}

/// Validates that the dims product fits `usize` (and the address space when
/// multiplied by 4 bytes/element) — a corrupt header must fail loudly here,
/// not by attempting a multi-exabyte allocation downstream.
fn checked_elems(dims: &[u64]) -> Result<usize> {
    let mut n: usize = 1;
    for &d in dims {
        let d: usize = d
            .try_into()
            .ok()
            .with_context(|| format!("dim {d} exceeds usize"))?;
        n = n
            .checked_mul(d)
            .with_context(|| format!("dims {dims:?} overflow usize"))?;
    }
    n.checked_mul(4)
        .with_context(|| format!("dims {dims:?}: byte size overflows usize"))?;
    Ok(n)
}

fn read_header(r: &mut impl Read) -> Result<Vec<u64>> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        bail!("not an EXT1 file (magic {magic:?})");
    }
    let mut nd = [0u8; 4];
    r.read_exact(&mut nd)?;
    let ndim = u32::from_le_bytes(nd) as usize;
    if ndim == 0 || ndim > 8 {
        bail!("implausible ndim {ndim}");
    }
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        dims.push(u64::from_le_bytes(b));
    }
    checked_elems(&dims)?;
    Ok(dims)
}

/// Bulk byte view of an `f32` slice.  Every bit pattern is a valid `f32`
/// and the payload is little-endian on all supported targets, so reads and
/// writes are single `memcpy`-sized calls instead of per-element loops.
fn as_bytes(data: &[f32]) -> &[u8] {
    // SAFETY: f32 has no invalid bit patterns, align(u8) ≤ align(f32), and
    // the length is exactly the element count times the element size.
    unsafe { std::slice::from_raw_parts(data.as_ptr().cast::<u8>(), data.len() * 4) }
}

fn as_bytes_mut(data: &mut [f32]) -> &mut [u8] {
    // SAFETY: see `as_bytes`; exclusive borrow guarantees no aliasing.
    unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr().cast::<u8>(), data.len() * 4) }
}

/// Fixes endianness in place after a raw little-endian read (no-op on
/// little-endian targets, i.e. everywhere we run).
fn fix_endianness(data: &mut [f32]) {
    if cfg!(target_endian = "big") {
        for x in data.iter_mut() {
            *x = f32::from_bits(x.to_bits().swap_bytes());
        }
    }
}

fn write_f32s(w: &mut impl Write, data: &[f32]) -> Result<()> {
    // Payload writes are not retried in place: `write_all` already resumes
    // interrupted syscalls, and a mid-stream failure leaves the file torn —
    // the recovery story is the caller's tmp+rename discipline plus the
    // checkpoint generation fallback, which this site exists to exercise.
    if fault::should_fault(fault::Site::IoWrite) {
        bail!("injected write fault {TRANSIENT_MARKER}");
    }
    if cfg!(target_endian = "big") {
        // Slow path for exotic targets: byte-swap through a bounce buffer.
        let mut buf = Vec::with_capacity(data.len() * 4);
        for &x in data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    } else {
        w.write_all(as_bytes(data))?;
    }
    Ok(())
}

fn read_f32s_into(r: &mut impl Read, out: &mut [f32]) -> Result<()> {
    r.read_exact(as_bytes_mut(out)).context("reading f32 payload")?;
    fix_endianness(out);
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; n];
    read_f32s_into(r, &mut out)?;
    Ok(out)
}

/// Checks the file length against the header: `n` payload elements after an
/// `ndim`-way header.  Catches truncated files and headers whose dims claim
/// more data than the file holds before any allocation is sized from them.
fn check_file_len(f: &File, ndim: usize, n: usize, what: &str) -> Result<()> {
    let actual = f.metadata().context("stat")?.len();
    let expected = header_len(ndim) + n as u64 * 4;
    if actual != expected {
        bail!("{what}: file is {actual} bytes, header implies {expected}");
    }
    Ok(())
}

/// Saves a dense tensor.
pub fn save_tensor(t: &DenseTensor, path: impl AsRef<Path>) -> Result<()> {
    let f = File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    let d = t.dims();
    write_header(&mut w, &[d[0] as u64, d[1] as u64, d[2] as u64])?;
    write_f32s(&mut w, t.data())?;
    Ok(())
}

/// Loads a dense tensor (fully materialized — use [`FileTensorSource`] for
/// inputs that must stay out of core).
pub fn load_tensor(path: impl AsRef<Path>) -> Result<DenseTensor> {
    let f = File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = BufReader::new(f);
    let dims = read_header(&mut r)?;
    if dims.len() != 3 {
        bail!("expected a 3-way tensor, found {} dims", dims.len());
    }
    let n = checked_elems(&dims)?;
    check_file_len(r.get_ref(), 3, n, "load_tensor")?;
    let data = read_f32s(&mut r, n)?;
    Ok(DenseTensor::from_vec(
        [dims[0] as usize, dims[1] as usize, dims[2] as usize],
        data,
    ))
}

/// Saves a matrix (2-way).
pub fn save_matrix(m: &Matrix, path: impl AsRef<Path>) -> Result<()> {
    let f = File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    write_header(&mut w, &[m.rows() as u64, m.cols() as u64])?;
    write_f32s(&mut w, m.data())?;
    Ok(())
}

/// Loads a matrix (2-way).
pub fn load_matrix(path: impl AsRef<Path>) -> Result<Matrix> {
    let f = File::open(path.as_ref())?;
    let mut r = BufReader::new(f);
    let dims = read_header(&mut r)?;
    if dims.len() != 2 {
        bail!("expected a matrix, found {} dims", dims.len());
    }
    let n = checked_elems(&dims)?;
    check_file_len(r.get_ref(), 2, n, "load_matrix")?;
    let data = read_f32s(&mut r, n)?;
    Ok(Matrix::from_vec(dims[0] as usize, dims[1] as usize, data))
}

/// A [`TensorSource`] backed by an `EXT1` file on disk: blocks are read with
/// positional (`pread`-style) strided reads, so the whole tensor never
/// resides in memory and many worker/producer threads can read concurrently
/// from one shared handle.
///
/// Reads are coalesced per the column-major layout: a block spanning the
/// full mode-1 extent reads one contiguous run per `(j-range, k)` plane,
/// and a block spanning modes 1 *and* 2 reads one run per frontal slice —
/// the `BlockSpec3` iteration order (mode-1 fastest) keeps those runs as
/// sequential on disk as the grid allows.  Bytes land directly in the
/// output tensor's buffer (no intermediate staging copy).
pub struct FileTensorSource {
    file: File,
    dims: [usize; 3],
    data_offset: u64,
    path: PathBuf,
    /// Non-unix targets have no positional read on a shared handle; they
    /// serialize seek+read pairs through this lock instead.
    #[cfg(not(unix))]
    seek_lock: std::sync::Mutex<()>,
}

impl FileTensorSource {
    /// Opens an `EXT1` 3-way tensor file for out-of-core block reads.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file =
            File::open(&path).with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(&file);
        let dims = read_header(&mut r)?;
        if dims.len() != 3 {
            bail!(
                "{}: expected a 3-way tensor, found {} dims",
                path.display(),
                dims.len()
            );
        }
        let n = checked_elems(&dims)?;
        check_file_len(&file, 3, n, "FileTensorSource")?;
        Ok(Self {
            file,
            dims: [dims[0] as usize, dims[1] as usize, dims[2] as usize],
            data_offset: header_len(3),
            path,
            #[cfg(not(unix))]
            seek_lock: std::sync::Mutex::new(()),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Total payload bytes on disk (the figure the memory planner compares
    /// against its budget to pick an out-of-core plan).
    pub fn payload_bytes(&self) -> usize {
        self.dims[0] * self.dims[1] * self.dims[2] * 4
    }

    /// Positional read of `out.len()` f32s starting at element `elem_off`.
    ///
    /// Transient failures (interrupted/timed-out syscalls, or the `io_read`
    /// fault site) are retried up to [`IO_MAX_RETRIES`] times with capped
    /// exponential backoff; each retry bumps [`IO_RETRIES`].  An exhausted
    /// budget bumps [`IO_GAVE_UP`] and surfaces a [`TRANSIENT_MARKER`]-tagged
    /// error so callers up the stack (engine → pipeline → scheduler) can
    /// classify the failure as retryable at job granularity.
    fn read_at(&self, elem_off: u64, out: &mut [f32]) -> Result<()> {
        let byte_off = self.data_offset + elem_off * 4;
        let mut attempt = 0u32;
        loop {
            match self.read_at_once(byte_off, out) {
                Ok(()) => {
                    fix_endianness(out);
                    return Ok(());
                }
                Err(e) if transient_io(&e) && attempt < IO_MAX_RETRIES => {
                    attempt += 1;
                    IO_RETRIES.fetch_add(1, Ordering::Relaxed);
                    let delay = (IO_BACKOFF_BASE_MS << (attempt - 1)).min(IO_BACKOFF_CAP_MS);
                    std::thread::sleep(std::time::Duration::from_millis(delay));
                }
                Err(e) => {
                    let transient = transient_io(&e);
                    if transient {
                        IO_GAVE_UP.fetch_add(1, Ordering::Relaxed);
                    }
                    let marker =
                        if transient { format!(" {TRANSIENT_MARKER}") } else { String::new() };
                    return Err(e).with_context(|| {
                        format!(
                            "read of {} bytes at {byte_off} failed after {attempt} retries{marker}",
                            out.len() * 4
                        )
                    });
                }
            }
        }
    }

    /// One read attempt: the raw positional syscall, preceded by the
    /// `io_read` fault probe (each attempt probes, so a retried read
    /// re-consults the schedule at a new counter position).
    fn read_at_once(&self, byte_off: u64, out: &mut [f32]) -> std::io::Result<()> {
        if fault::should_fault(fault::Site::IoRead) {
            return Err(std::io::Error::new(
                ErrorKind::Interrupted,
                "injected transient read fault",
            ));
        }
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.read_exact_at(as_bytes_mut(out), byte_off)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Seek, SeekFrom};
            let _g = self.seek_lock.lock().unwrap();
            let mut f = &self.file;
            f.seek(SeekFrom::Start(byte_off))?;
            f.read_exact(as_bytes_mut(out))
        }
    }
}

impl TensorSource for FileTensorSource {
    fn dims(&self) -> [usize; 3] {
        self.dims
    }

    fn block(&self, r: &BlockRange) -> DenseTensor {
        let [i_dim, j_dim, k_dim] = self.dims;
        assert!(
            r.i1 <= i_dim && r.j1 <= j_dim && r.k1 <= k_dim,
            "block {r:?} out of bounds for dims {:?}",
            self.dims
        );
        let [di, dj, dk] = r.shape();
        let mut out = vec![0.0f32; di * dj * dk];
        let plane = (i_dim * j_dim) as u64;
        let res: Result<()> = (|| {
            if di == i_dim && dj == j_dim {
                // Whole frontal slices: one contiguous run.
                let off = r.k0 as u64 * plane;
                self.read_at(off, &mut out)?;
            } else if di == i_dim {
                // Full mode-1 fibers: one run of di·dj per frontal slice.
                for (kk, k) in (r.k0..r.k1).enumerate() {
                    let off = k as u64 * plane + (r.j0 * i_dim) as u64;
                    let dst = kk * di * dj;
                    self.read_at(off, &mut out[dst..dst + di * dj])?;
                }
            } else {
                // General case: one run of di per (j, k) fiber.
                for (kk, k) in (r.k0..r.k1).enumerate() {
                    for (jj, j) in (r.j0..r.j1).enumerate() {
                        let off = k as u64 * plane + (j * i_dim + r.i0) as u64;
                        let dst = (kk * dj + jj) * di;
                        self.read_at(off, &mut out[dst..dst + di])?;
                    }
                }
            }
            Ok(())
        })();
        if let Err(e) = res {
            // TensorSource::block is infallible by contract; a read error on
            // an already-validated file is unrecoverable mid-stream.
            panic!("FileTensorSource: reading {}: {e:#}", self.path.display());
        }
        DenseTensor::from_vec([di, dj, dk], out)
    }
}

/// Sequential writer for `EXT1` tensor files too large to materialize:
/// accepts the column-major payload in slabs and verifies the element count
/// on [`StreamedTensorWriter::finish`].
pub struct StreamedTensorWriter {
    w: BufWriter<File>,
    total: usize,
    written: usize,
    path: PathBuf,
}

impl StreamedTensorWriter {
    /// Creates the file and writes the header; payload slabs follow.
    pub fn create(path: impl AsRef<Path>, dims: [usize; 3]) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let f = File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        write_header(&mut w, &[dims[0] as u64, dims[1] as u64, dims[2] as u64])?;
        Ok(Self {
            w,
            total: dims[0] * dims[1] * dims[2],
            written: 0,
            path,
        })
    }

    /// Appends the next slab of column-major payload.
    pub fn write_slab(&mut self, data: &[f32]) -> Result<()> {
        if self.written + data.len() > self.total {
            bail!(
                "{}: slab overruns payload ({} + {} > {})",
                self.path.display(),
                self.written,
                data.len(),
                self.total
            );
        }
        write_f32s(&mut self.w, data)?;
        self.written += data.len();
        Ok(())
    }

    /// Flushes and validates that exactly the declared payload was written.
    pub fn finish(mut self) -> Result<()> {
        if self.written != self.total {
            bail!(
                "{}: wrote {} of {} elements",
                self.path.display(),
                self.written,
                self.total
            );
        }
        self.w.flush()?;
        Ok(())
    }
}

/// Streams `src` to an `EXT1` file in slabs of `slab_planes` frontal slices
/// (each slab is contiguous in the column-major layout), so implicit
/// generators can author files far larger than resident memory.
pub fn save_tensor_streamed(
    src: &dyn TensorSource,
    path: impl AsRef<Path>,
    slab_planes: usize,
) -> Result<()> {
    let [i, j, k] = src.dims();
    let planes = slab_planes.max(1);
    let mut w = StreamedTensorWriter::create(path, [i, j, k])?;
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + planes).min(k);
        let slab = src.block(&BlockRange {
            i0: 0,
            i1: i,
            j0: 0,
            j1: j,
            k0,
            k1,
            index: 0,
        });
        w.write_slab(slab.data())?;
        k0 = k1;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::block::BlockSpec3;
    use crate::tensor::generator::{InMemorySource, LowRankGenerator};
    use crate::util::rng::Xoshiro256;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_io_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn tensor_round_trip() {
        let mut rng = Xoshiro256::seed_from_u64(70);
        let t = DenseTensor::random_normal([5, 6, 7], &mut rng);
        let path = tmp("tensor");
        save_tensor(&t, &path).unwrap();
        let loaded = load_tensor(&path).unwrap();
        assert_eq!(loaded, t);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn matrix_round_trip() {
        let mut rng = Xoshiro256::seed_from_u64(71);
        let m = Matrix::random_normal(9, 4, &mut rng);
        let path = tmp("matrix");
        save_matrix(&m, &path).unwrap();
        let loaded = load_matrix(&path).unwrap();
        assert_eq!(loaded, m);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_kind_rejected() {
        let mut rng = Xoshiro256::seed_from_u64(72);
        let m = Matrix::random_normal(3, 3, &mut rng);
        let path = tmp("kind");
        save_matrix(&m, &path).unwrap();
        assert!(load_tensor(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a tensor").unwrap();
        assert!(load_tensor(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_rejected() {
        assert!(load_tensor("/nonexistent/exatensor.bin").is_err());
    }

    #[test]
    fn huge_dims_header_rejected_without_allocating() {
        // Header claims ~u64::MAX elements; must bail on validation, not
        // attempt the allocation.
        let path = tmp("hugedims");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&3u32.to_le_bytes());
        for _ in 0..3 {
            bytes.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_tensor(&path).is_err());
        assert!(FileTensorSource::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_payload_rejected() {
        let mut rng = Xoshiro256::seed_from_u64(73);
        let t = DenseTensor::random_normal([4, 4, 4], &mut rng);
        let path = tmp("trunc");
        save_tensor(&t, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        assert!(load_tensor(&path).is_err());
        assert!(FileTensorSource::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// Builds a raw EXT1 header (magic + ndim + dims), optionally followed
    /// by `payload` f32s — for authoring deliberately corrupt files.
    fn raw_file(path: &std::path::Path, ndim: u32, dims: &[u64], payload_elems: usize) {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&ndim.to_le_bytes());
        for &d in dims {
            bytes.extend_from_slice(&d.to_le_bytes());
        }
        bytes.extend_from_slice(&vec![0u8; payload_elems * 4]);
        std::fs::write(path, &bytes).unwrap();
    }

    #[test]
    fn file_source_rejects_length_mismatch_long_and_short() {
        // Header says 2×2×2 = 8 elements; file carries 9 (trailing junk —
        // e.g. a half-finished rewrite) and then 7 (truncation).  Both are
        // length mismatches FileTensorSource must refuse at open.
        let path = tmp("len_long");
        raw_file(&path, 3, &[2, 2, 2], 9);
        let e = FileTensorSource::open(&path).unwrap_err().to_string();
        assert!(e.contains("header implies"), "unexpected error: {e}");
        assert!(load_tensor(&path).is_err());
        std::fs::remove_file(&path).ok();

        let path = tmp("len_short");
        raw_file(&path, 3, &[2, 2, 2], 7);
        assert!(FileTensorSource::open(&path).is_err());
        assert!(load_tensor(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_source_rejects_header_truncated_mid_dims() {
        // ndim claims 3 but only two dim words follow: read_header must
        // fail on the short read, not invent a dimension.
        let path = tmp("mid_dims");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(FileTensorSource::open(&path).is_err());
        assert!(load_tensor(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_source_rejects_implausible_ndim() {
        for ndim in [0u32, 9, u32::MAX] {
            let path = tmp(&format!("ndim_{ndim}"));
            raw_file(&path, ndim, &[], 0);
            let e = FileTensorSource::open(&path).unwrap_err().to_string();
            assert!(e.contains("ndim"), "ndim {ndim}: unexpected error {e}");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn file_source_rejects_dims_product_overflow() {
        // 2³¹ × 2³¹ × 1: the element count (2⁶²) fits usize on 64-bit
        // targets, but ×4 bytes overflows — checked_elems must catch the
        // byte-size overflow before any allocation is sized from it.
        let path = tmp("byte_overflow");
        raw_file(&path, 3, &[1 << 31, 1 << 31, 1], 0);
        assert!(FileTensorSource::open(&path).is_err());
        std::fs::remove_file(&path).ok();

        // 2³³ × 2³³ × 1: the element product itself overflows u64→usize
        // multiplication.
        let path = tmp("elem_overflow");
        raw_file(&path, 3, &[1 << 33, 1 << 33, 1], 0);
        assert!(FileTensorSource::open(&path).is_err());
        assert!(load_tensor(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_source_rejects_matrix_files() {
        let mut rng = Xoshiro256::seed_from_u64(77);
        let m = Matrix::random_normal(4, 4, &mut rng);
        let path = tmp("src_kind");
        save_matrix(&m, &path).unwrap();
        let e = FileTensorSource::open(&path).unwrap_err().to_string();
        assert!(e.contains("3-way"), "unexpected error: {e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_source_matches_in_memory_all_block_sizes() {
        let mut rng = Xoshiro256::seed_from_u64(74);
        let t = DenseTensor::random_normal([13, 9, 7], &mut rng);
        let path = tmp("filesrc");
        save_tensor(&t, &path).unwrap();
        let fsrc = FileTensorSource::open(&path).unwrap();
        assert_eq!(fsrc.dims(), [13, 9, 7]);
        assert_eq!(fsrc.payload_bytes(), 13 * 9 * 7 * 4);
        let msrc = InMemorySource::new(t);
        for block in [[13, 9, 7], [13, 9, 3], [13, 4, 2], [5, 3, 2], [1, 1, 1]] {
            let spec = BlockSpec3::new([13, 9, 7], block);
            for blk in spec.iter() {
                let a = fsrc.block(&blk);
                let b = msrc.block(&blk);
                assert_eq!(a, b, "block {blk:?} at block dims {block:?}");
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_source_concurrent_reads_agree() {
        let mut rng = Xoshiro256::seed_from_u64(75);
        let t = DenseTensor::random_normal([16, 16, 16], &mut rng);
        let path = tmp("filesrc_par");
        save_tensor(&t, &path).unwrap();
        let fsrc = FileTensorSource::open(&path).unwrap();
        let spec = BlockSpec3::new([16, 16, 16], [5, 6, 7]);
        let blocks: Vec<BlockRange> = spec.iter().collect();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let fsrc = &fsrc;
                let blocks = &blocks;
                let expected = &t;
                s.spawn(move || {
                    for blk in blocks {
                        let a = fsrc.block(blk);
                        let b =
                            expected.subtensor(blk.i0, blk.i1, blk.j0, blk.j1, blk.k0, blk.k1);
                        assert_eq!(a, b);
                    }
                });
            }
        });
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn streamed_writer_round_trips_generator() {
        let gen = LowRankGenerator::new(10, 8, 12, 2, 76);
        let path = tmp("streamed");
        save_tensor_streamed(&gen, &path, 5).unwrap();
        let loaded = load_tensor(&path).unwrap();
        let full = gen.block(&BlockRange {
            i0: 0,
            i1: 10,
            j0: 0,
            j1: 8,
            k0: 0,
            k1: 12,
            index: 0,
        });
        assert_eq!(loaded, full);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_source_retries_injected_transient_faults_bitwise() {
        use crate::util::fault::{arm_scoped, FaultPlan, Site, SiteSpec};
        let mut rng = Xoshiro256::seed_from_u64(78);
        let t = DenseTensor::random_normal([8, 8, 8], &mut rng);
        let path = tmp("retry");
        save_tensor(&t, &path).unwrap();
        let fsrc = FileTensorSource::open(&path).unwrap();
        let spec = BlockSpec3::new([8, 8, 8], [4, 4, 4]);
        // period 2 ⇒ a faulted attempt's immediate retry always succeeds;
        // bounded max keeps concurrently running tests unbothered (their
        // reads at worst retry once too).
        let g = arm_scoped(
            FaultPlan::new(11)
                .site(Site::IoRead, SiteSpec { period: 2, max: 6, ..Default::default() }),
        );
        let before = IO_RETRIES.load(Ordering::Relaxed);
        for blk in spec.iter() {
            let a = fsrc.block(&blk);
            let b = t.subtensor(blk.i0, blk.i1, blk.j0, blk.j1, blk.k0, blk.k1);
            assert_eq!(a, b, "retried read must be bitwise identical");
        }
        assert!(g.fired(Site::IoRead) >= 1, "plan must actually deliver faults");
        assert!(
            IO_RETRIES.load(Ordering::Relaxed) > before,
            "retries must be visible in telemetry"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn streamed_writer_validates_counts() {
        let path = tmp("streamed_bad");
        let mut w = StreamedTensorWriter::create(&path, [2, 2, 2]).unwrap();
        w.write_slab(&[0.0; 4]).unwrap();
        assert!(w.write_slab(&[0.0; 5]).is_err(), "overrun rejected");
        std::fs::remove_file(&path).ok();

        let mut w = StreamedTensorWriter::create(&path, [2, 2, 2]).unwrap();
        w.write_slab(&[0.0; 4]).unwrap();
        assert!(w.finish().is_err(), "short payload rejected");
        std::fs::remove_file(&path).ok();
    }
}
