//! Binary tensor/matrix I/O.
//!
//! Simple self-describing little-endian format:
//! magic `EXT1`, u32 ndim, u64 dims…, f32 data (column-major).  Used by the
//! CLI to load real inputs and by the apps to persist decompositions.

use super::dense::DenseTensor;
use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"EXT1";

fn write_header(w: &mut impl Write, dims: &[u64]) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&(dims.len() as u32).to_le_bytes())?;
    for &d in dims {
        w.write_all(&d.to_le_bytes())?;
    }
    Ok(())
}

fn read_header(r: &mut impl Read) -> Result<Vec<u64>> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        bail!("not an EXT1 file (magic {magic:?})");
    }
    let mut nd = [0u8; 4];
    r.read_exact(&mut nd)?;
    let ndim = u32::from_le_bytes(nd) as usize;
    if ndim == 0 || ndim > 8 {
        bail!("implausible ndim {ndim}");
    }
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        dims.push(u64::from_le_bytes(b));
    }
    Ok(dims)
}

fn write_f32s(w: &mut impl Write, data: &[f32]) -> Result<()> {
    // Bulk byte conversion; f32 is IEEE-754 LE on all supported targets.
    let mut buf = Vec::with_capacity(data.len() * 4);
    for &x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf).context("reading f32 payload")?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Saves a dense tensor.
pub fn save_tensor(t: &DenseTensor, path: impl AsRef<Path>) -> Result<()> {
    let f = File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    let d = t.dims();
    write_header(&mut w, &[d[0] as u64, d[1] as u64, d[2] as u64])?;
    write_f32s(&mut w, t.data())?;
    Ok(())
}

/// Loads a dense tensor.
pub fn load_tensor(path: impl AsRef<Path>) -> Result<DenseTensor> {
    let f = File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = BufReader::new(f);
    let dims = read_header(&mut r)?;
    if dims.len() != 3 {
        bail!("expected a 3-way tensor, found {} dims", dims.len());
    }
    let n = (dims[0] * dims[1] * dims[2]) as usize;
    let data = read_f32s(&mut r, n)?;
    Ok(DenseTensor::from_vec(
        [dims[0] as usize, dims[1] as usize, dims[2] as usize],
        data,
    ))
}

/// Saves a matrix (2-way).
pub fn save_matrix(m: &Matrix, path: impl AsRef<Path>) -> Result<()> {
    let f = File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    write_header(&mut w, &[m.rows() as u64, m.cols() as u64])?;
    write_f32s(&mut w, m.data())?;
    Ok(())
}

/// Loads a matrix (2-way).
pub fn load_matrix(path: impl AsRef<Path>) -> Result<Matrix> {
    let f = File::open(path.as_ref())?;
    let mut r = BufReader::new(f);
    let dims = read_header(&mut r)?;
    if dims.len() != 2 {
        bail!("expected a matrix, found {} dims", dims.len());
    }
    let n = (dims[0] * dims[1]) as usize;
    let data = read_f32s(&mut r, n)?;
    Ok(Matrix::from_vec(dims[0] as usize, dims[1] as usize, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_io_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn tensor_round_trip() {
        let mut rng = Xoshiro256::seed_from_u64(70);
        let t = DenseTensor::random_normal([5, 6, 7], &mut rng);
        let path = tmp("tensor");
        save_tensor(&t, &path).unwrap();
        let loaded = load_tensor(&path).unwrap();
        assert_eq!(loaded, t);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn matrix_round_trip() {
        let mut rng = Xoshiro256::seed_from_u64(71);
        let m = Matrix::random_normal(9, 4, &mut rng);
        let path = tmp("matrix");
        save_matrix(&m, &path).unwrap();
        let loaded = load_matrix(&path).unwrap();
        assert_eq!(loaded, m);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_kind_rejected() {
        let mut rng = Xoshiro256::seed_from_u64(72);
        let m = Matrix::random_normal(3, 3, &mut rng);
        let path = tmp("kind");
        save_matrix(&m, &path).unwrap();
        assert!(load_tensor(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a tensor").unwrap();
        assert!(load_tensor(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_rejected() {
        assert!(load_tensor("/nonexistent/exatensor.bin").is_err());
    }
}
