//! Mode-n matricizations (unfoldings) of a dense third-order tensor.
//!
//! Convention (matches Kolda & Bader and the Khatri-Rao convention in
//! `linalg::products`): for `X (I×J×K)`,
//!
//! * `X_(1)` is `I × (J·K)` with column `j + k·J`,
//! * `X_(2)` is `J × (I·K)` with column `i + k·I`,
//! * `X_(3)` is `K × (I·J)` with column `i + j·I`,
//!
//! so that `X_(1) = A (C ⊙ B)ᵀ`, `X_(2) = B (C ⊙ A)ᵀ`, `X_(3) = C (B ⊙ A)ᵀ`
//! with `khatri_rao(slow, fast)` pairing row `fast + slow·dim_fast`.
//!
//! §IV-A of the paper: with column-major storage, `unfold_1` is a pure
//! buffer reinterpretation (zero copy); modes 2 and 3 are strided gathers —
//! `refold` inverts each.

use super::dense::DenseTensor;
use crate::linalg::Matrix;

/// Mode-1 unfolding `X_(1) (I × J·K)`. Zero-copy reinterpretation.
pub fn unfold_1(t: &DenseTensor) -> Matrix {
    let [i, j, k] = t.dims();
    Matrix::from_vec(i, j * k, t.data().to_vec())
}

/// Mode-2 unfolding `X_(2) (J × I·K)`, column `i + k·I`.
pub fn unfold_2(t: &DenseTensor) -> Matrix {
    let [i_dim, j_dim, k_dim] = t.dims();
    let mut m = Matrix::zeros(j_dim, i_dim * k_dim);
    for k in 0..k_dim {
        for i in 0..i_dim {
            let col = i + k * i_dim;
            for j in 0..j_dim {
                m.set(j, col, t.get(i, j, k));
            }
        }
    }
    m
}

/// Mode-3 unfolding `X_(3) (K × I·J)`, column `i + j·I`.
pub fn unfold_3(t: &DenseTensor) -> Matrix {
    let [i_dim, j_dim, k_dim] = t.dims();
    let mut m = Matrix::zeros(k_dim, i_dim * j_dim);
    // X_(3)'s row k is exactly the frontal slice k flattened column-major.
    let slice_len = i_dim * j_dim;
    for k in 0..k_dim {
        let src = &t.data()[k * slice_len..(k + 1) * slice_len];
        for (col, &v) in src.iter().enumerate() {
            m.set(k, col, v);
        }
    }
    m
}

/// Inverse of [`unfold_1`].
pub fn refold_1(m: &Matrix, dims: [usize; 3]) -> DenseTensor {
    assert_eq!(m.rows(), dims[0]);
    assert_eq!(m.cols(), dims[1] * dims[2]);
    DenseTensor::from_vec(dims, m.data().to_vec())
}

/// Inverse of [`unfold_2`].
pub fn refold_2(m: &Matrix, dims: [usize; 3]) -> DenseTensor {
    let [i_dim, j_dim, k_dim] = dims;
    assert_eq!(m.rows(), j_dim);
    assert_eq!(m.cols(), i_dim * k_dim);
    let mut t = DenseTensor::zeros(i_dim, j_dim, k_dim);
    for k in 0..k_dim {
        for i in 0..i_dim {
            let col = i + k * i_dim;
            for j in 0..j_dim {
                t.set(i, j, k, m.get(j, col));
            }
        }
    }
    t
}

/// Inverse of [`unfold_3`].
pub fn refold_3(m: &Matrix, dims: [usize; 3]) -> DenseTensor {
    let [i_dim, j_dim, k_dim] = dims;
    assert_eq!(m.rows(), k_dim);
    assert_eq!(m.cols(), i_dim * j_dim);
    let mut t = DenseTensor::zeros(i_dim, j_dim, k_dim);
    for j in 0..j_dim {
        for i in 0..i_dim {
            let col = i + j * i_dim;
            for k in 0..k_dim {
                t.set(i, j, k, m.get(k, col));
            }
        }
    }
    t
}

/// Unfolds along `mode` ∈ {1, 2, 3}.
pub fn unfold(t: &DenseTensor, mode: usize) -> Matrix {
    match mode {
        1 => unfold_1(t),
        2 => unfold_2(t),
        3 => unfold_3(t),
        _ => panic!("mode must be 1, 2 or 3; got {mode}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::products::khatri_rao;
    use crate::linalg::{matmul, Trans};
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    fn test_tensor() -> DenseTensor {
        DenseTensor::from_fn([2, 3, 2], |i, j, k| (i + 10 * j + 100 * k) as f32)
    }

    #[test]
    fn unfold1_known() {
        let t = test_tensor();
        let m = unfold_1(&t);
        assert_eq!((m.rows(), m.cols()), (2, 6));
        // column j + k*J: col 0 = X(:,0,0), col 4 = X(:,1,1)
        assert_eq!(m.col(0), &[0.0, 1.0]);
        assert_eq!(m.col(4), &[110.0, 111.0]);
    }

    #[test]
    fn unfold2_known() {
        let t = test_tensor();
        let m = unfold_2(&t);
        assert_eq!((m.rows(), m.cols()), (3, 4));
        // col i + k*I: col 1 = X(1,:,0) = [1, 11, 21]
        assert_eq!(m.col(1), &[1.0, 11.0, 21.0]);
        // col 2 = X(0,:,1) = [100, 110, 120]
        assert_eq!(m.col(2), &[100.0, 110.0, 120.0]);
    }

    #[test]
    fn unfold3_known() {
        let t = test_tensor();
        let m = unfold_3(&t);
        assert_eq!((m.rows(), m.cols()), (2, 6));
        // col i + j*I: col 3 = X(1,1,:) = [11, 111]
        assert_eq!(m.col(3), &[11.0, 111.0]);
    }

    #[test]
    fn refold_inverts_unfold() {
        prop::check("unfold-refold", 20, |g| {
            let dims = [g.int(1, 5), g.int(1, 5), g.int(1, 5)];
            let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
            let t = DenseTensor::random_normal(dims, &mut rng);
            assert_eq!(refold_1(&unfold_1(&t), dims), t);
            assert_eq!(refold_2(&unfold_2(&t), dims), t);
            assert_eq!(refold_3(&unfold_3(&t), dims), t);
        });
    }

    #[test]
    fn unfoldings_satisfy_cp_identities() {
        // X from CP factors must satisfy X_(n) = F_n (KR)ᵀ for each mode.
        let mut rng = Xoshiro256::seed_from_u64(51);
        let a = Matrix::random_normal(3, 2, &mut rng);
        let b = Matrix::random_normal(4, 2, &mut rng);
        let c = Matrix::random_normal(5, 2, &mut rng);
        let t = DenseTensor::from_cp_factors(&a, &b, &c);

        let x1 = unfold_1(&t);
        let rhs1 = matmul(&a, Trans::No, &khatri_rao(&c, &b), Trans::Yes);
        assert!(x1.rel_error(&rhs1) < 1e-5, "mode1 err={}", x1.rel_error(&rhs1));

        let x2 = unfold_2(&t);
        let rhs2 = matmul(&b, Trans::No, &khatri_rao(&c, &a), Trans::Yes);
        assert!(x2.rel_error(&rhs2) < 1e-5, "mode2 err={}", x2.rel_error(&rhs2));

        let x3 = unfold_3(&t);
        let rhs3 = matmul(&c, Trans::No, &khatri_rao(&b, &a), Trans::Yes);
        assert!(x3.rel_error(&rhs3) < 1e-5, "mode3 err={}", x3.rel_error(&rhs3));
    }

    #[test]
    #[should_panic(expected = "mode must be")]
    fn bad_mode_panics() {
        let _ = unfold(&test_tensor(), 4);
    }
}
