//! COO sparse third-order tensor.
//!
//! Used by the sparse-decomposition experiments (Fig. 3/4) and the sparse
//! direct-ALS baseline: the baseline's MTTKRP iterates nonzeros instead of
//! dense fibers.

use super::dense::DenseTensor;
use crate::linalg::Matrix;

/// Coordinate-format sparse tensor: parallel arrays of indices and values.
#[derive(Clone, Debug, Default)]
pub struct SparseTensor {
    dims: [usize; 3],
    pub indices: Vec<[u32; 3]>,
    pub values: Vec<f32>,
}

impl SparseTensor {
    pub fn new(dims: [usize; 3]) -> Self {
        Self {
            dims,
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    pub fn dims(&self) -> [usize; 3] {
        self.dims
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn push(&mut self, i: usize, j: usize, k: usize, v: f32) {
        debug_assert!(i < self.dims[0] && j < self.dims[1] && k < self.dims[2]);
        if v != 0.0 {
            self.indices.push([i as u32, j as u32, k as u32]);
            self.values.push(v);
        }
    }

    /// Converts a dense tensor, dropping entries with `|x| ≤ threshold`.
    pub fn from_dense(t: &DenseTensor, threshold: f32) -> Self {
        let [i_dim, j_dim, k_dim] = t.dims();
        let mut s = Self::new(t.dims());
        for k in 0..k_dim {
            for j in 0..j_dim {
                for i in 0..i_dim {
                    let v = t.get(i, j, k);
                    if v.abs() > threshold {
                        s.push(i, j, k, v);
                    }
                }
            }
        }
        s
    }

    /// Builds the COO directly from **sparse** CP factors without ever
    /// densifying: iterates the nonzero index triples of each rank-1 term
    /// and accumulates collisions. `O(Σ_r nnz(a_r)·nnz(b_r)·nnz(c_r))`.
    pub fn from_sparse_factors(a: &Matrix, b: &Matrix, c: &Matrix) -> Self {
        let r = a.cols();
        assert_eq!(b.cols(), r);
        assert_eq!(c.cols(), r);
        let dims = [a.rows(), b.rows(), c.rows()];
        let nz = |m: &Matrix, col: usize| -> Vec<(usize, f32)> {
            m.col(col)
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(i, &v)| (i, v))
                .collect()
        };
        let mut acc: std::collections::HashMap<(u32, u32, u32), f32> =
            std::collections::HashMap::new();
        for rr in 0..r {
            let an = nz(a, rr);
            let bn = nz(b, rr);
            let cn = nz(c, rr);
            for &(i, av) in &an {
                for &(j, bv) in &bn {
                    let ab = av * bv;
                    for &(k, cv) in &cn {
                        *acc.entry((i as u32, j as u32, k as u32)).or_insert(0.0) += ab * cv;
                    }
                }
            }
        }
        let mut s = Self::new(dims);
        for ((i, j, k), v) in acc {
            if v != 0.0 {
                s.indices.push([i, j, k]);
                s.values.push(v);
            }
        }
        s
    }

    /// Densifies (tests / small tensors only).
    pub fn to_dense(&self) -> DenseTensor {
        let mut t = DenseTensor::zeros(self.dims[0], self.dims[1], self.dims[2]);
        for (idx, &v) in self.indices.iter().zip(&self.values) {
            t.add_assign_at(idx[0] as usize, idx[1] as usize, idx[2] as usize, v);
        }
        t
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.values
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Sparse MTTKRP for `mode` ∈ {1,2,3}: the workhorse of sparse ALS.
    ///
    /// * mode 1: `out[i, :] += v · (B[j, :] * C[k, :])`
    /// * mode 2: `out[j, :] += v · (A[i, :] * C[k, :])`
    /// * mode 3: `out[k, :] += v · (A[i, :] * B[j, :])`
    ///
    /// Rank-column-outer loop order: every operand of the inner scatter is
    /// a contiguous column slice (free in column-major storage), mirroring
    /// the fused dense kernel's factor-column walks — the row-outer form's
    /// strided per-entry `get`s (one `i + c·rows` multiply each) dominated
    /// at large `nnz`.
    pub fn mttkrp(&self, mode: usize, f1: &Matrix, f2: &Matrix) -> Matrix {
        let r = f1.cols();
        assert_eq!(f2.cols(), r);
        assert!((1..=3).contains(&mode), "mode must be 1, 2 or 3");
        let out_rows = self.dims[mode - 1];
        let mut out = Matrix::zeros(out_rows, r);
        for c in 0..r {
            let f1c = f1.col(c);
            let f2c = f2.col(c);
            let oc = out.col_mut(c);
            let entries = self.indices.iter().zip(&self.values);
            match mode {
                1 => {
                    for (idx, &v) in entries {
                        oc[idx[0] as usize] += v * f1c[idx[1] as usize] * f2c[idx[2] as usize];
                    }
                }
                2 => {
                    for (idx, &v) in entries {
                        oc[idx[1] as usize] += v * f1c[idx[0] as usize] * f2c[idx[2] as usize];
                    }
                }
                3 => {
                    for (idx, &v) in entries {
                        oc[idx[2] as usize] += v * f1c[idx[0] as usize] * f2c[idx[1] as usize];
                    }
                }
                _ => unreachable!(),
            }
        }
        out
    }

    /// Squared residual `‖X − [[A,B,C]]‖²` computed sparsely.
    /// Assumes coordinates are distinct (no COO duplicates):
    /// `‖X‖² − 2·Σ_nnz x·x̂ + ‖[[A,B,C]]‖²` where the model norm uses the
    /// Gram-Hadamard identity — O(nnz·R + R²) rather than O(IJK).
    pub fn residual_sq(&self, a: &Matrix, b: &Matrix, c: &Matrix) -> f64 {
        use crate::linalg::backend::{ComputeBackend, SerialBackend};
        use crate::linalg::products::hadamard;
        let r = a.cols();
        let x_sq: f64 = self.values.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let mut cross = 0.0f64;
        for (idx, &v) in self.indices.iter().zip(&self.values) {
            let (i, j, k) = (idx[0] as usize, idx[1] as usize, idx[2] as usize);
            let mut xhat = 0.0f64;
            for rr in 0..r {
                xhat += a.get(i, rr) as f64 * b.get(j, rr) as f64 * c.get(k, rr) as f64;
            }
            cross += v as f64 * xhat;
        }
        let g = hadamard(
            &hadamard(&SerialBackend.gram(a), &SerialBackend.gram(b)),
            &SerialBackend.gram(c),
        );
        let model_sq: f64 = g.data().iter().map(|&x| x as f64).sum();
        (x_sq - 2.0 * cross + model_sq).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::products::khatri_rao;
    use crate::linalg::{matmul, Trans};
    use crate::tensor::unfold::{unfold_1, unfold_2, unfold_3};
    use crate::util::rng::Xoshiro256;

    fn random_sparse(dims: [usize; 3], nnz: usize, seed: u64) -> SparseTensor {
        // Distinct coordinates: residual_sq assumes no duplicate entries
        // (COO duplicates would need pre-summing).
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let total = dims[0] * dims[1] * dims[2];
        let lin = rng.sample_indices(total, nnz.min(total));
        let mut s = SparseTensor::new(dims);
        for idx in lin {
            let i = idx % dims[0];
            let j = (idx / dims[0]) % dims[1];
            let k = idx / (dims[0] * dims[1]);
            s.push(i, j, k, rng.next_gaussian() as f32);
        }
        s
    }

    #[test]
    fn dense_round_trip() {
        let t = DenseTensor::from_fn([3, 3, 3], |i, j, k| {
            if (i + j + k) % 2 == 0 {
                (i + j + k) as f32
            } else {
                0.0
            }
        });
        let s = SparseTensor::from_dense(&t, 0.0);
        assert!(s.nnz() < 27);
        assert_eq!(s.to_dense(), t);
    }

    #[test]
    fn mttkrp_matches_dense_formula() {
        // sparse mttkrp(mode) == X_(mode) · KR
        let mut rng = Xoshiro256::seed_from_u64(60);
        let s = random_sparse([6, 5, 4], 25, 61);
        let dense = s.to_dense();
        let a = Matrix::random_normal(6, 3, &mut rng);
        let b = Matrix::random_normal(5, 3, &mut rng);
        let c = Matrix::random_normal(4, 3, &mut rng);

        let m1 = s.mttkrp(1, &b, &c);
        let ref1 = matmul(&unfold_1(&dense), Trans::No, &khatri_rao(&c, &b), Trans::No);
        assert!(m1.rel_error(&ref1) < 1e-4, "mode1 {}", m1.rel_error(&ref1));

        let m2 = s.mttkrp(2, &a, &c);
        let ref2 = matmul(&unfold_2(&dense), Trans::No, &khatri_rao(&c, &a), Trans::No);
        assert!(m2.rel_error(&ref2) < 1e-4, "mode2 {}", m2.rel_error(&ref2));

        let m3 = s.mttkrp(3, &a, &b);
        let ref3 = matmul(&unfold_3(&dense), Trans::No, &khatri_rao(&b, &a), Trans::No);
        assert!(m3.rel_error(&ref3) < 1e-4, "mode3 {}", m3.rel_error(&ref3));
    }

    #[test]
    fn residual_matches_dense() {
        let mut rng = Xoshiro256::seed_from_u64(62);
        let s = random_sparse([5, 5, 5], 20, 63);
        let a = Matrix::random_normal(5, 2, &mut rng);
        let b = Matrix::random_normal(5, 2, &mut rng);
        let c = Matrix::random_normal(5, 2, &mut rng);
        let model = DenseTensor::from_cp_factors(&a, &b, &c);
        let dense = s.to_dense();
        let expected: f64 = dense
            .data()
            .iter()
            .zip(model.data())
            .map(|(x, m)| {
                let d = (*x - *m) as f64;
                d * d
            })
            .sum();
        let got = s.residual_sq(&a, &b, &c);
        assert!(
            (got - expected).abs() / expected.max(1e-12) < 1e-3,
            "got {got} expected {expected}"
        );
    }

    #[test]
    fn from_sparse_factors_matches_dense() {
        let gen = crate::tensor::SparseLowRankGenerator::new(15, 15, 15, 2, 3, 70);
        let (a, b, c) = gen.factors();
        let direct = SparseTensor::from_sparse_factors(a, b, c);
        let dense = DenseTensor::from_cp_factors(a, b, c);
        assert!(direct.to_dense().rel_error(&dense) < 1e-5);
        assert!(direct.nnz() <= 2 * 27);
    }

    #[test]
    fn zero_values_dropped() {
        let mut s = SparseTensor::new([2, 2, 2]);
        s.push(0, 0, 0, 0.0);
        assert_eq!(s.nnz(), 0);
    }

    #[test]
    fn norm_matches_dense() {
        let s = random_sparse([4, 4, 4], 10, 64);
        let d = s.to_dense();
        assert!((s.frobenius_norm() - d.frobenius_norm()).abs() < 1e-6);
    }
}
