//! Streaming refinement sweeps — exact ALS updates against the *source*.
//!
//! Compressed recovery is unbiased but amplifies input noise through the
//! stacked pseudo-inverse (conditioning ∝ oversampling of Eq. 4).  A few
//! ALS sweeps computed directly against the original tensor remove that
//! amplification: for each mode, the MTTKRP is accumulated block-by-block
//! in a streaming pass over the source (never materializing the tensor),
//! and the Gram solves are the usual R×R ridge systems.  True Gauss-Seidel
//! ordering (re-stream after each mode update) is used — a simultaneous
//! "Jacobi" sweep reusing one pass for all three modes is cheaper but
//! oscillates in scale.  Cost: three passes over the tensor per sweep,
//! versus `P ≈ 15–30` passes for the compression stage, and it needs a
//! good initial model to land in the right basin — which is exactly what
//! the compressed pipeline provides.

use crate::cp::CpModel;
use crate::linalg::backend::{ComputeBackend, SerialBackend};
use crate::linalg::{ridge_solve, Matrix};
use crate::tensor::unfold::{unfold_2, unfold_3};
use crate::tensor::{BlockRange, BlockSpec3, TensorSource};
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::sync::Mutex;

/// Streams one mode's MTTKRP `X_(mode) · (slow ⊙ fast)` over the block
/// grid.
///
/// Per-block contractions dispatch through the serial [`ComputeBackend`]
/// reference — now the fused kernel, so no block ever materializes its
/// Khatri-Rao operand — and parallelism lives at block granularity via
/// [`ThreadPool::for_each_chunk`], so the inner kernel must not nest
/// another pool.
fn streaming_mttkrp(
    src: &dyn TensorSource,
    model: &CpModel,
    mode: usize,
    block: [usize; 3],
    pool: &ThreadPool,
) -> Matrix {
    let dims = src.dims();
    let r = model.rank();
    let out_rows = dims[mode - 1];
    let blocks: Vec<BlockRange> = BlockSpec3::new(dims, block).iter().collect();
    let acc = Mutex::new(Matrix::zeros(out_rows, r));
    let be = SerialBackend;

    pool.for_each_chunk(blocks.len(), 1, |range| {
        for blk in &blocks[range] {
            let t = src.block(blk);
            let [di, dj, dk] = t.dims();
            let a_blk = model.a.slice_rows(blk.i0, blk.i1);
            let b_blk = model.b.slice_rows(blk.j0, blk.j1);
            let c_blk = model.c.slice_rows(blk.k0, blk.k1);
            let (part, off, rows) = match mode {
                1 => {
                    let x1 = Matrix::from_vec(di, dj * dk, t.data().to_vec());
                    (be.mttkrp(1, &x1, &c_blk, &b_blk), blk.i0, di)
                }
                2 => (be.mttkrp(2, &unfold_2(&t), &c_blk, &a_blk), blk.j0, dj),
                3 => (be.mttkrp(3, &unfold_3(&t), &b_blk, &a_blk), blk.k0, dk),
                _ => unreachable!(),
            };
            let mut g = acc.lock().unwrap();
            for c in 0..r {
                let dst = &mut g.col_mut(c)[off..off + rows];
                for (d, &s) in dst.iter_mut().zip(part.col(c)) {
                    *d += s;
                }
            }
        }
    });
    acc.into_inner().unwrap()
}

/// Runs `sweeps` streaming Gauss-Seidel ALS sweeps starting from `model`.
pub fn refine(
    src: &dyn TensorSource,
    mut model: CpModel,
    block: [usize; 3],
    sweeps: usize,
    pool: &ThreadPool,
) -> Result<CpModel> {
    let ridge = 1e-8f32;
    let be = SerialBackend;
    let gram = |x: &Matrix, y: &Matrix| be.kr_gram(x, y);
    for _ in 0..sweeps {
        let m1 = streaming_mttkrp(src, &model, 1, block, pool);
        model.a = ridge_solve(&gram(&model.c, &model.b), &m1.transpose(), ridge)?.transpose();
        let m2 = streaming_mttkrp(src, &model, 2, block, pool);
        model.b = ridge_solve(&gram(&model.c, &model.a), &m2.transpose(), ridge)?.transpose();
        let m3 = streaming_mttkrp(src, &model, 3, block, pool);
        model.c = ridge_solve(&gram(&model.b, &model.a), &m3.transpose(), ridge)?.transpose();
    }
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::products::khatri_rao;
    use crate::linalg::{matmul, Trans};
    use crate::tensor::LowRankGenerator;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn streaming_mttkrp_matches_dense() {
        let gen = LowRankGenerator::new(18, 14, 10, 2, 600);
        let mut rng = Xoshiro256::seed_from_u64(601);
        let model = CpModel::new(
            Matrix::random_normal(18, 2, &mut rng),
            Matrix::random_normal(14, 2, &mut rng),
            Matrix::random_normal(10, 2, &mut rng),
        );
        let pool = ThreadPool::new(3);
        let full = gen.corner(100); // corner clamps to dims → full tensor

        let m1 = streaming_mttkrp(&gen, &model, 1, [7, 6, 4], &pool);
        let x1 = crate::tensor::unfold::unfold_1(&full);
        let r1 = matmul(&x1, Trans::No, &khatri_rao(&model.c, &model.b), Trans::No);
        assert!(m1.rel_error(&r1) < 1e-4, "m1 err {}", m1.rel_error(&r1));

        let m2 = streaming_mttkrp(&gen, &model, 2, [7, 6, 4], &pool);
        let x2 = unfold_2(&full);
        let r2 = matmul(&x2, Trans::No, &khatri_rao(&model.c, &model.a), Trans::No);
        assert!(m2.rel_error(&r2) < 1e-4);

        let m3 = streaming_mttkrp(&gen, &model, 3, [7, 6, 4], &pool);
        let x3 = unfold_3(&full);
        let r3 = matmul(&x3, Trans::No, &khatri_rao(&model.b, &model.a), Trans::No);
        assert!(m3.rel_error(&r3) < 1e-4);
    }

    #[test]
    fn refinement_improves_noisy_estimate() {
        let gen = LowRankGenerator::new(30, 30, 30, 2, 602);
        let (a, b, c) = gen.factors.clone();
        // Perturb the truth by 10% — stands in for compressed-recovery noise.
        let mut rng = Xoshiro256::seed_from_u64(603);
        let perturb = |m: &Matrix, rng: &mut Xoshiro256| {
            let noise = Matrix::random_normal(m.rows(), m.cols(), rng);
            let scale = 0.1 * m.frobenius_norm() as f32 / noise.frobenius_norm() as f32;
            let mut n = noise;
            n.scale(scale);
            m.add(&n)
        };
        let rough = CpModel::new(
            perturb(&a, &mut rng),
            perturb(&b, &mut rng),
            perturb(&c, &mut rng),
        );
        let truth = CpModel::new(a, b, c);
        let pool = ThreadPool::new(4);
        let before = rough.to_tensor().rel_error(&truth.to_tensor());
        let refined = refine(&gen, rough, [10, 10, 10], 2, &pool).unwrap();
        let after = refined.to_tensor().rel_error(&truth.to_tensor());
        assert!(after < before / 10.0, "before {before}, after {after}");
    }
}
