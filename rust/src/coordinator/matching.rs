//! Replica alignment — Alg. 2 lines 5–7.
//!
//! Each proxy decomposition `(A_p, B_p, C_p)` recovers the compressed
//! factors only up to a per-replica column permutation `Π_p` and scaling
//! `Σ_p`.  Because the compression matrices share their first `S` anchor
//! rows, the anchor sub-blocks `A_p(1:S,:)` are — up to `Π_p Σ_p` — the
//! same matrix for every replica, so:
//!
//! 1. **scale fix** (line 5): divide each column of `A_p` by its
//!    largest-|·| entry among the first `S` rows (and likewise `B_p`,
//!    `C_p`): the anchored scale is replica-independent, and using the
//!    *signed* max also resolves the sign ambiguity;
//! 2. **permutation fix** (lines 6–7): match columns to replica 1 by
//!    maximizing `Tr(A_1(1:S,:)ᵀ A_p(1:S,:) Π)` with the Hungarian
//!    algorithm.

use crate::cp::CpModel;
use crate::linalg::{hungarian_max, Matrix};
use anyhow::{bail, Result};

/// Outcome of aligning one replica.
#[derive(Clone, Debug)]
pub struct AlignmentReport {
    /// Hungarian objective normalized to [0,1]-ish (mean anchor cosine).
    pub match_score: f64,
    /// The permutation applied (candidate column for each reference column).
    pub permutation: Vec<usize>,
}

/// Divides each factor column by its signed anchor max — Alg. 2 line 5.
///
/// Errors if any anchor block column is entirely (near-)zero: that replica
/// failed to converge and should be dropped (the paper pads `P` by +10
/// exactly for this).
pub fn anchor_normalize(model: &mut CpModel, anchor_rows: usize) -> Result<()> {
    for (name, f) in [
        ("A", &mut model.a),
        ("B", &mut model.b),
        ("C", &mut model.c),
    ] {
        let s = anchor_rows.min(f.rows());
        for col in 0..f.cols() {
            // signed entry with the largest magnitude among the anchor rows
            let mut best = 0.0f32;
            for r in 0..s {
                let v = f.get(r, col);
                if v.abs() > best.abs() {
                    best = v;
                }
            }
            if best.abs() < 1e-20 {
                bail!("factor {name} column {col}: anchor block is zero");
            }
            for r in 0..f.rows() {
                let v = f.get(r, col) / best;
                f.set(r, col, v);
            }
        }
    }
    Ok(())
}

/// Aligns `candidate`'s columns to `reference` via the anchor blocks of the
/// first factor (Alg. 2 lines 6–7), permuting all three factor matrices.
pub fn align_to_reference(
    reference: &CpModel,
    candidate: &CpModel,
    anchor_rows: usize,
) -> Result<(CpModel, AlignmentReport)> {
    let r = reference.rank();
    if candidate.rank() != r {
        bail!("rank mismatch: {} vs {}", candidate.rank(), r);
    }
    let s = anchor_rows.min(reference.a.rows());
    let ref_anchor = reference.a.slice_rows(0, s);
    let cand_anchor = candidate.a.slice_rows(0, s);
    // Weight[i][j] = ⟨ref col i, cand col j⟩ over anchor rows; Hungarian
    // maximizes the trace of the permuted product.
    let weight = Matrix::from_fn(r, r, |i, j| {
        let mut dot = 0.0;
        for row in 0..s {
            dot += ref_anchor.get(row, i) * cand_anchor.get(row, j);
        }
        dot
    });
    let assignment = hungarian_max(&weight);
    let perm = assignment.col_of_row.clone();

    let aligned = CpModel {
        a: candidate.a.permute_cols(&perm),
        b: candidate.b.permute_cols(&perm),
        c: candidate.c.permute_cols(&perm),
    };
    // Normalized score: mean cosine between matched anchor columns.
    let mut score = 0.0f64;
    for i in 0..r {
        let j = perm[i];
        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
        for row in 0..s {
            let x = ref_anchor.get(row, i) as f64;
            let y = cand_anchor.get(row, j) as f64;
            dot += x * y;
            na += x * x;
            nb += y * y;
        }
        if na > 0.0 && nb > 0.0 {
            score += dot / (na.sqrt() * nb.sqrt());
        }
    }
    Ok((
        aligned,
        AlignmentReport {
            match_score: score / r as f64,
            permutation: perm,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn model(seed: u64, rows: usize, rank: usize) -> CpModel {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        CpModel::new(
            Matrix::random_normal(rows, rank, &mut rng),
            Matrix::random_normal(rows, rank, &mut rng),
            Matrix::random_normal(rows, rank, &mut rng),
        )
    }

    #[test]
    fn anchor_normalize_makes_anchor_max_one() {
        let mut m = model(200, 8, 3);
        anchor_normalize(&mut m, 4).unwrap();
        for f in [&m.a, &m.b, &m.c] {
            for col in 0..3 {
                let maxabs = (0..4).map(|r| f.get(r, col).abs()).fold(0.0f32, f32::max);
                assert!((maxabs - 1.0).abs() < 1e-5);
                // the signed max itself is +1
                let has_plus_one = (0..4).any(|r| (f.get(r, col) - 1.0).abs() < 1e-5);
                assert!(has_plus_one);
            }
        }
    }

    #[test]
    fn anchor_normalize_rejects_zero_anchor() {
        let mut m = model(201, 6, 2);
        for r in 0..3 {
            m.a.set(r, 0, 0.0);
        }
        assert!(anchor_normalize(&mut m, 3).is_err());
    }

    #[test]
    fn align_recovers_planted_permutation_and_sign() {
        let base = model(202, 10, 4);
        // Candidate = base with permuted columns and random signs/scales.
        let perm = [3usize, 1, 0, 2];
        let scales = [2.0f32, -1.5, 0.5, -3.0];
        // candidate col j = base col perm_inv… build directly:
        let mut cand = CpModel {
            a: Matrix::zeros(10, 4),
            b: Matrix::zeros(10, 4),
            c: Matrix::zeros(10, 4),
        };
        for (dst, (&src, &s)) in perm.iter().zip(scales.iter()).enumerate() {
            // place base column `src` at candidate column `dst`, scaled
            for row in 0..10 {
                cand.a.set(row, dst, base.a.get(row, src) * s);
                cand.b.set(row, dst, base.b.get(row, src) * s);
                cand.c.set(row, dst, base.c.get(row, src) * s);
            }
        }
        let mut reference = base.clone();
        let mut cand = cand;
        anchor_normalize(&mut reference, 5).unwrap();
        anchor_normalize(&mut cand, 5).unwrap();
        let (aligned, report) = align_to_reference(&reference, &cand, 5).unwrap();
        assert!(report.match_score > 0.999, "score {}", report.match_score);
        // aligned factors equal the normalized reference.
        assert!(aligned.a.rel_error(&reference.a) < 1e-4);
        assert!(aligned.b.rel_error(&reference.b) < 1e-4);
        assert!(aligned.c.rel_error(&reference.c) < 1e-4);
    }

    #[test]
    fn align_rank_mismatch_rejected() {
        let a = model(203, 6, 2);
        let b = model(204, 6, 3);
        assert!(align_to_reference(&a, &b, 3).is_err());
    }

    #[test]
    fn identity_alignment_for_identical_models() {
        let mut m = model(205, 8, 3);
        anchor_normalize(&mut m, 4).unwrap();
        let (aligned, report) = align_to_reference(&m, &m, 4).unwrap();
        assert_eq!(report.permutation, vec![0, 1, 2]);
        assert!(aligned.a.rel_error(&m.a) < 1e-6);
    }
}
