//! Layer-3 coordinator — the Exascale-Tensor pipeline (Alg. 2).
//!
//! This is the paper's *system* contribution: the orchestration that lets a
//! tensor far larger than memory be CP-decomposed by streaming blocks
//! through the compression stage, decomposing `P` small proxies in
//! parallel, undoing the per-replica permutation/scaling ambiguity with
//! anchor rows + the Hungarian algorithm, and recovering the original
//! factors with stacked least squares plus a sampled-subtensor
//! disambiguation.
//!
//! Module map (one stage per module):
//!
//! * [`config`]   — run configuration + builder, validation.
//! * [`planner`]  — memory planner: replica count bound `P ≥ (I−2)/(L−2)`,
//!   proxy/working-set byte accounting against a budget (§IV-D motivation).
//! * [`matching`] — anchor normalization + Hungarian alignment
//!   (Alg. 2 lines 5–7).
//! * [`recovery`] — stacked LSTSQ (Eq. 4), sampled-corner disambiguation
//!   (Alg. 2 lines 10–13), and the L1/ISTA second stage for the
//!   compressed-sensing variant (§IV-D).
//! * [`pipeline`] — the driver tying the stages together over a worker
//!   pool, with per-stage metrics.
//! * [`metrics`]  — stage timing/counters registry.

pub mod checkpoint;
pub mod config;
pub mod matching;
pub mod metrics;
pub mod pipeline;
pub mod planner;
pub mod recovery;
pub mod refine;

pub use config::{
    Backend, MapTierChoice, PipelineConfig, PipelineConfigBuilder, RecoverySolver,
    RecoverySolverKind, SensingConfig,
};
pub use metrics::{Metrics, StageStats};
pub use pipeline::{
    run_batch_group, Pipeline, PipelineResult, ProxyDecomposer, RustAlsDecomposer, ShardedGrid,
};
pub use planner::{MemoryPlan, MemoryPlanner};
