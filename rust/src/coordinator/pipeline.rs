//! The Exascale-Tensor pipeline driver — Alg. 2 end-to-end.
//!
//! ```text
//!           ┌─ maps (S anchors) ─┐
//! source ──▶ blocked compression ──▶ P proxies ──▶ parallel proxy ALS
//!   │           (Fig. 2, pool)                       (pool / XLA)
//!   │                                                     │
//!   └────────── corner sample                 anchor-normalize + Hungarian
//!                     │                                   │
//!               corner ALS ◀── stacked LSTSQ (Eq. 4) ◀────┘
//!                     │                │
//!                     └── match/rescale (Π,Σ) ──▶ (A, B, C)
//! ```
//!
//! The coordinator resolves one
//! [`ComputeBackend`](crate::linalg::ComputeBackend) handle from
//! [`super::config::Backend`] (serial / parallel CPU /
//! `runtime::XlaBackend`) and consults its stage hooks to pick the
//! compression and proxy-ALS engines; the stage-level traits
//! ([`ProxyDecomposer`], [`BlockCompressor`]) remain as override points —
//! the XLA backend exposes its fused AOT artifacts through exactly those
//! hooks.  On the CPU arms the streaming stages parallelize at block /
//! replica granularity over the worker pool and deliberately run the
//! serial kernel reference inside each job; the parallel kernels serve
//! top-level single contractions (`cp::als_decompose_with`, the apps, the
//! `gemm_mttkrp` bench).

use super::config::{Backend, PipelineConfig, RecoverySolverKind};
use super::metrics::Metrics;
use super::planner::{MemoryPlan, MemoryPlanner};
use super::recovery::{
    corner_disambiguate, entry_calibrate, normalize_and_align_min, sensing_recover_mode,
    stacked_recover_opts, RecoveryOptions,
};
use crate::compress::{
    compress_source, BlockCompressor, MapSource, MapTier, PrefetchConfig, ResumeState,
    RustCompressor, SparseSignMatrix, StreamOptions, DEFAULT_SHARD_PARTS,
};
use crate::cp::{als_batch, als_decompose_with, sampled_mse, AlsBatchItem, AlsOptions, CpModel};
use crate::linalg::backend::{cpu_backend, serial_backend, BackendHandle, SerialBackend};
use crate::linalg::ista::IstaOptions;
use crate::mixed::MixedPrecision;
use crate::tensor::{DenseTensor, TensorSource};
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Pluggable proxy-tensor CP decomposition backend.
/// Returns the model plus its final relative fit (`1 − ‖Y−Ŷ‖/‖Y‖`) so the
/// coordinator can restart/drop replicas stuck in bad local minima —
/// Alg. 2's "if [a replica] can't converge …, drop it (them) in time".
pub trait ProxyDecomposer: Sync {
    fn decompose(&self, proxy: &DenseTensor, rank: usize, seed: u64) -> Result<(CpModel, f64)>;
    fn name(&self) -> &'static str;
}

/// In-crate rust ALS backend.  Dispatches its MTTKRP/Gram kernels through
/// a [`ComputeBackend`](crate::linalg::ComputeBackend) handle — the serial
/// reference by default, since the coordinator already parallelizes across
/// replicas.
pub struct RustAlsDecomposer {
    pub iters: usize,
    pub tol: f64,
    backend: BackendHandle,
}

impl RustAlsDecomposer {
    pub fn new(iters: usize, tol: f64) -> Self {
        Self {
            iters,
            tol,
            backend: serial_backend(),
        }
    }

    /// Overrides the kernel backend (e.g. a parallel one when replicas are
    /// decomposed one at a time).
    pub fn with_backend(mut self, backend: BackendHandle) -> Self {
        self.backend = backend;
        self
    }
}

impl ProxyDecomposer for RustAlsDecomposer {
    fn decompose(&self, proxy: &DenseTensor, rank: usize, seed: u64) -> Result<(CpModel, f64)> {
        let (model, trace) = als_decompose_with(
            proxy,
            &AlsOptions {
                rank,
                max_iters: self.iters,
                tol: self.tol,
                seed,
                ..Default::default()
            },
            &*self.backend,
        )?;
        let fit = trace.fits.last().copied().unwrap_or(f64::NEG_INFINITY);
        Ok((model, fit))
    }

    fn name(&self) -> &'static str {
        "rust-als"
    }
}

/// Fit threshold policy: a replica is retried (new init seed) while its fit
/// is below `RETRY_FIT`, up to `MAX_ATTEMPTS`; after the sweep, replicas
/// more than `DROP_MARGIN` below the median fit are dropped.
const RETRY_FIT: f64 = 0.98;
const MAX_ATTEMPTS: usize = 3;
const DROP_MARGIN: f64 = 0.02;

/// Diagnostics attached to a pipeline run.
#[derive(Clone, Debug, Default)]
pub struct Diagnostics {
    /// Replicas dropped during alignment.
    pub dropped_replicas: usize,
    /// Sampled reconstruction MSE against the source.
    pub sampled_mse: f64,
    /// Sampled relative error.
    pub rel_error: f64,
    /// Worst factor error across modes vs. ground truth (set by callers
    /// that know the truth; NaN otherwise).
    pub max_factor_error: f64,
}

/// Result of a pipeline run.
pub struct PipelineResult {
    pub model: CpModel,
    pub plan: MemoryPlan,
    pub diagnostics: Diagnostics,
}

/// Stage-1 output of one job: the compressed proxies plus everything the
/// post-compression stages need.  Produced by `Pipeline::compress_stage`,
/// consumed by `Pipeline::finish_stage`; [`run_batch_group`] holds one per
/// job while a shared sweep decomposes every job's proxies together.
pub struct PreparedJob {
    plan: MemoryPlan,
    pool: ThreadPool,
    anchor: usize,
    maps: MapSource,
    proxies: Vec<DenseTensor>,
}

/// The Stage-1 grid facts a sharded run is built from: the deterministic
/// block grid and shard partition a solo run of this config would stream,
/// plus the map-generation parameters a remote worker needs to rebuild the
/// exact replica maps.  Self-contained on purpose — a worker process that
/// receives these fields (over the serve protocol's LEASE grant) can
/// recompute any shard range bit-for-bit without access to the
/// coordinator's planner or config machinery.
#[derive(Clone, Debug)]
pub struct ShardedGrid {
    pub dims: [usize; 3],
    pub reduced: [usize; 3],
    pub replicas: usize,
    pub anchor: usize,
    pub seed: u64,
    pub map_tier: MapTier,
    pub block: [usize; 3],
    pub blocks_total: usize,
    pub shard_parts: usize,
    /// Compression-path identity, same namespace as the checkpoint's
    /// `CompressionProgress::path`.  Only `"batched"` is shardable today.
    pub path: String,
}

/// The Exascale-Tensor coordinator.
pub struct Pipeline {
    cfg: PipelineConfig,
    pub metrics: Metrics,
    /// The compute backend every stage dispatches through; resolved from
    /// `cfg.backend` on the first run unless injected via
    /// [`Pipeline::with_compute`].
    compute: Option<BackendHandle>,
    /// Optional stage override (tests / custom engines); takes precedence
    /// over the compute backend's [`proxy_decomposer`]
    /// (crate::linalg::ComputeBackend::proxy_decomposer) hook.
    decomposer: Option<Box<dyn ProxyDecomposer>>,
    /// Optional stage override; takes precedence over the compute
    /// backend's `block_compressor` hook.
    compressor: Option<Box<dyn BlockCompressor>>,
    /// Optional artifact store + source fingerprint (serve plane): Stage 1
    /// is looked up here before it streams and published after it folds.
    store: Option<(Arc<crate::store::ArtifactStore>, u64)>,
}

/// The store address Stage 1 of a job resolves to.  One definition shared
/// by [`Pipeline::compress_stage`] (lookup + publish), the scheduler's
/// warm admission probe, and the sharded executor's artifact check — key
/// drift between them would silently kill reuse.  `path` is the
/// compression-path identity (`"batched"` / `"plain:<name>"`), same
/// namespace as the checkpoint's `CompressionProgress::path`.
pub fn proxy_key_for(
    cfg: &PipelineConfig,
    plan: &MemoryPlan,
    dims: [usize; 3],
    source_fp: u64,
    path: &str,
) -> crate::store::StageKey {
    crate::store::StageKey::proxies(
        source_fp,
        dims,
        cfg.reduced,
        plan.replicas,
        cfg.effective_anchor(),
        cfg.seed,
        cfg.mixed_precision,
        plan.block,
        path,
    )
}

/// The streaming schedule a [`MemoryPlan`] resolves to: prefetch policy
/// plus the deterministic shard partition.  One constructor for every
/// streaming stage, so checkpoints always record the schedule the engine
/// actually runs.
fn stream_opts_from_plan(plan: &MemoryPlan, pool: &ThreadPool) -> StreamOptions {
    StreamOptions {
        threads: pool.size(),
        prefetch: (plan.prefetch_depth > 0).then_some(PrefetchConfig {
            depth: plan.prefetch_depth,
            io_threads: plan.io_threads,
        }),
        shard_parts: DEFAULT_SHARD_PARTS,
    }
}

/// Surfaces one streaming pass's counters through the metrics registry.
fn record_stream_stats(metrics: &Metrics, stats: &crate::compress::StreamStats) {
    metrics.record("compress_io", stats.io_seconds);
    if stats.prefetched {
        metrics.record("compress_io_stall", stats.io_stall_seconds);
        metrics.record("compress_backpressure", stats.send_stall_seconds);
    }
    metrics.incr("blocks_streamed", stats.blocks_read);
}

impl Pipeline {
    pub fn new(cfg: PipelineConfig) -> Self {
        Self {
            cfg,
            metrics: Metrics::new(),
            compute: None,
            decomposer: None,
            compressor: None,
            store: None,
        }
    }

    /// Attaches the serve plane's artifact store plus this job's source
    /// fingerprint.  With it, `compress_stage` resolves the proxy stage
    /// key ([`proxy_key_for`]), fetches a resident artifact instead of
    /// streaming, and publishes freshly folded proxies for the next job.
    pub fn with_store(mut self, store: Arc<crate::store::ArtifactStore>, source_fp: u64) -> Self {
        self.store = Some((store, source_fp));
        self
    }

    /// Installs the compute backend explicitly.  The usual entry point for
    /// the XLA arm: `pipe.with_compute(Arc::new(XlaBackend::from_config(
    /// pipe.config())?))`.
    pub fn with_compute(mut self, backend: BackendHandle) -> Self {
        self.compute = Some(backend);
        self
    }

    /// Installs a custom proxy decomposer (stage-level override).
    pub fn with_decomposer(mut self, d: Box<dyn ProxyDecomposer>) -> Self {
        self.decomposer = Some(d);
        self
    }

    /// Installs a custom block compressor (stage-level override).
    pub fn with_compressor(mut self, c: Box<dyn BlockCompressor>) -> Self {
        self.compressor = Some(c);
        self
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Resolves the [`BackendHandle`] for this run: an injected handle
    /// wins; otherwise `cfg.backend` maps to its constructor —
    /// `RustSequential → SerialBackend`, `RustParallel →
    /// CpuParallelBackend`, `Xla → runtime::XlaBackend::from_config` (which
    /// needs AOT artifacts and fails loudly without them).  Legacy callers
    /// that injected *any* XLA stage via [`Pipeline::with_compressor`] /
    /// [`Pipeline::with_decomposer`] keep working: the CPU backend backs
    /// the kernels and the non-injected stage falls back to its rust
    /// default, exactly as before this layer existed.
    ///
    /// Note on the CPU arms: the streaming stages parallelize at *block /
    /// replica* granularity over the worker pool and deliberately run the
    /// serial kernel reference inside each job (see `compress::stream`,
    /// `refine`); the handle is what stage hooks and top-level single
    /// contractions dispatch through.
    fn resolve_compute(&mut self) -> Result<BackendHandle> {
        if let Some(b) = &self.compute {
            return Ok(b.clone());
        }
        let resolved: BackendHandle = match self.cfg.backend {
            Backend::RustSequential => serial_backend(),
            Backend::RustParallel => cpu_backend(self.cfg.threads),
            Backend::Xla => {
                if self.compressor.is_some() || self.decomposer.is_some() {
                    cpu_backend(self.cfg.threads)
                } else {
                    Arc::new(crate::runtime::XlaBackend::from_config(&self.cfg)?)
                }
            }
        };
        // Cache so repeated runs reuse one handle — for Backend::Xla this
        // avoids reloading the PJRT runtime (and recompiling every
        // artifact) on each run() call.
        self.compute = Some(resolved.clone());
        Ok(resolved)
    }

    fn pool(&self) -> ThreadPool {
        match self.cfg.backend {
            Backend::RustSequential => ThreadPool::new(1),
            _ => ThreadPool::new(self.cfg.threads),
        }
    }

    fn default_compressor(&self) -> RustCompressor {
        RustCompressor {
            precision: if self.cfg.mixed_precision {
                MixedPrecision::Bf16
            } else {
                MixedPrecision::Full
            },
        }
    }

    /// Runs Alg. 2 on `src`.
    pub fn run(&mut self, src: &dyn TensorSource) -> Result<PipelineResult> {
        self.cfg.validate()?;
        let compute = self.resolve_compute()?;
        let dims = src.dims();
        let plan = MemoryPlanner::plan(&self.cfg, dims)?;
        log::info!(
            "pipeline: dims={dims:?} reduced={:?} P={} block={:?} backend={:?} compute={} \
             (streaming stages: block-parallel over {} thread(s), serial kernels per job)",
            self.cfg.reduced,
            plan.replicas,
            plan.block,
            self.cfg.backend,
            compute.name(),
            self.pool().size()
        );

        if self.cfg.sensing.is_some() {
            return self.run_sensing(src, plan, &compute);
        }

        let prep = self.compress_stage(src, plan, &compute)?;

        // ── Stage 2: proxy decomposition (Alg. 2 lines 3–4) ──
        let models = self.metrics.time("decompose", || {
            self.decompose_proxies(&prep.proxies, &prep.pool, &compute)
        })?;

        self.finish_stage(src, prep, models)
    }

    /// Resolves the Stage-1 grid a sharded execution of this config would
    /// stream — the coordinator's half of the shard-lease seam.  Fails on
    /// configurations whose compression path sharded workers cannot
    /// reproduce bitwise: the sensing variant, mixed precision, and custom
    /// / backend-hook compressors all run the plain trait path, which is
    /// only exercised in-process.
    pub fn sharded_grid(&mut self, src: &dyn TensorSource) -> Result<ShardedGrid> {
        self.cfg.validate()?;
        if self.cfg.sensing.is_some() {
            bail!("sharded execution does not support the sensing variant");
        }
        let compute = self.resolve_compute()?;
        let use_batched = self.compressor.is_none()
            && compute.block_compressor().is_none()
            && !self.cfg.mixed_precision;
        if !use_batched {
            bail!(
                "sharded execution supports only the batched plain-f32 compression path \
                 (mixed precision / custom compressors must run single-process)"
            );
        }
        let dims = src.dims();
        let plan = MemoryPlanner::plan(&self.cfg, dims)?;
        let blocks_total = crate::tensor::BlockSpec3::new(dims, plan.block).num_blocks();
        Ok(ShardedGrid {
            dims,
            reduced: self.cfg.reduced,
            replicas: plan.replicas,
            anchor: self.cfg.effective_anchor(),
            seed: self.cfg.seed,
            map_tier: plan.map_tier,
            block: plan.block,
            blocks_total,
            shard_parts: DEFAULT_SHARD_PARTS,
            path: "batched".to_string(),
        })
    }

    /// Runs stages 2–6 on proxies produced elsewhere — the second half of
    /// the shard-lease seam.  The caller (the sharded executor) is
    /// responsible for having folded per-shard accumulators in the engine's
    /// deterministic shard order; from here on the run is exactly the solo
    /// path's post-compression tail, so factors and digest match a solo
    /// [`Pipeline::run`] bit for bit.
    pub fn run_with_proxies(
        &mut self,
        src: &dyn TensorSource,
        proxies: Vec<DenseTensor>,
    ) -> Result<PipelineResult> {
        self.cfg.validate()?;
        let compute = self.resolve_compute()?;
        let dims = src.dims();
        let plan = MemoryPlanner::plan(&self.cfg, dims)?;
        if proxies.len() != plan.replicas {
            bail!(
                "sharded fold delivered {} proxies but the plan expects {} replicas",
                proxies.len(),
                plan.replicas
            );
        }
        let pool = self.pool();
        let anchor = self.cfg.effective_anchor();
        let maps = MapSource::generate(
            dims,
            self.cfg.reduced,
            plan.replicas,
            anchor,
            self.cfg.seed,
            plan.map_tier,
        );
        self.metrics.incr("replicas", proxies.len() as u64);
        let prep = PreparedJob {
            plan,
            pool,
            anchor,
            maps,
            proxies,
        };
        let models = self.metrics.time("decompose", || {
            self.decompose_proxies(&prep.proxies, &prep.pool, &compute)
        })?;
        self.finish_stage(src, prep, models)
    }

    /// Stage 1 (Alg. 2 lines 1–2, Fig. 2): replica maps + blocked
    /// streaming compression, with the full checkpoint/resume machinery.
    /// Returns a [`PreparedJob`] carrying everything the post-compression
    /// stages need — the seam [`run_batch_group`] uses to run many jobs'
    /// proxy ALS through one coalesced sweep between this stage and
    /// [`Pipeline::finish_stage`].
    fn compress_stage(
        &self,
        src: &dyn TensorSource,
        plan: MemoryPlan,
        compute: &BackendHandle,
    ) -> Result<PreparedJob> {
        let dims = src.dims();
        let pool = self.pool();
        let anchor = self.cfg.effective_anchor();

        // ── Stage 1: compression (Alg. 2 lines 1–2, Fig. 2) ──
        // The maps exist in the tier the planner resolved: stored matrices
        // or generate-on-slice.  Every downstream consumer reads them
        // through panels, so the tier never changes a single result bit.
        log::info!("replica maps: {} tier", plan.map_tier.as_str());
        let maps = MapSource::generate(
            dims,
            self.cfg.reduced,
            plan.replicas,
            anchor,
            self.cfg.seed,
            plan.map_tier,
        );
        let default_comp;
        let compressor: &dyn BlockCompressor = match (&self.compressor, compute.block_compressor())
        {
            (Some(c), _) => c.as_ref(),
            (None, Some(c)) => c,
            (None, None) => {
                default_comp = self.default_compressor();
                &default_comp
            }
        };
        // Streaming schedule from the plan (incremental checkpoints are
        // only valid for one partition, so it is fixed here and recorded
        // there).
        let stream_opts = stream_opts_from_plan(&plan, &pool);
        if plan.out_of_core {
            log::info!(
                "out-of-core plan: tensor exceeds the {}-byte budget; streaming with \
                 prefetch depth {} × {} I/O thread(s)",
                self.cfg.memory_budget,
                plan.prefetch_depth,
                plan.io_threads
            );
        }
        // Fast path (§Perf): plain-f32 rust compression uses the
        // replica-batched, unfold-free chain; custom backends (XLA)
        // and mixed precision go through the trait.
        let use_batched = self.compressor.is_none()
            && compute.block_compressor().is_none()
            && !self.cfg.mixed_precision;
        let blocks_total = crate::tensor::BlockSpec3::new(dims, plan.block).num_blocks();
        let shards_total =
            ThreadPool::partition(blocks_total, stream_opts.shard_parts).len();
        let partition = super::checkpoint::CompressionProgress {
            block: plan.block,
            shard_parts: stream_opts.shard_parts,
            shards_total,
            shards_done: 0,
            blocks_done: 0,
            blocks_total,
            // The compressor's name is part of the identity: partials from
            // one kernel (e.g. the XLA artifact) must not silently blend
            // with a suffix computed by another.
            path: if use_batched {
                "batched".to_string()
            } else {
                format!("plain:{}", compressor.name())
            },
            generation: 0,
        };

        // Artifact-store lookup, ahead of even checkpoint resume: a
        // resident proxy set under this exact (source fingerprint,
        // compression config) key means Stage 1 never streams a block.
        // The blob layer verified the payload digest, so the fetched
        // proxies are bitwise the ones a cold run would fold.
        let store_key = self
            .store
            .as_ref()
            .map(|(_, fp)| proxy_key_for(&self.cfg, &plan, dims, *fp, &partition.path));
        if let (Some((store, _)), Some(key)) = (&self.store, &store_key) {
            if let Some(p) = store.get(key) {
                if p.len() == plan.replicas {
                    log::info!("stage 1 served from artifact store ({})", key.id());
                    self.metrics.incr("replicas", p.len() as u64);
                    return Ok(PreparedJob {
                        plan,
                        pool,
                        anchor,
                        maps,
                        proxies: p,
                    });
                }
                log::warn!(
                    "artifact {} holds {} proxies but the plan expects {}; recomputing",
                    key.id(),
                    p.len(),
                    plan.replicas
                );
            }
        }

        // Checkpoint resume: reuse persisted proxies from a matching run.
        let fp = super::checkpoint::default_fingerprint(&self.cfg, dims, plan.replicas);
        let resumed = match &self.cfg.checkpoint_dir {
            Some(dir) => super::checkpoint::load_proxies(dir, &fp)?,
            None => None,
        };
        let proxies = match resumed {
            Some(p) => {
                log::info!("resumed {} proxies from checkpoint", p.len());
                self.metrics.incr("checkpoint_resumed", 1);
                p
            }
            None => {
                // Mid-compression resume: a killed run's folded shard
                // prefix continues instead of restarting Stage 1 from
                // zero; the fixed reduction order makes the resumed result
                // bitwise identical to an uninterrupted pass.
                let partial = match &self.cfg.checkpoint_dir {
                    Some(dir) => {
                        let load = super::checkpoint::load_partial(dir, &fp, &partition)?;
                        if load.fallbacks > 0 {
                            self.metrics.incr("checkpoint_fallbacks", load.fallbacks);
                        }
                        load.state
                    }
                    None => None,
                };
                let (resume, start_gen) = match partial {
                    Some((pr, acc)) => {
                        log::info!(
                            "resuming compression mid-stream: {}/{} blocks already folded",
                            pr.blocks_done,
                            pr.blocks_total
                        );
                        self.metrics
                            .incr("checkpoint_partial_resumed_blocks", pr.blocks_done as u64);
                        let r = ResumeState {
                            shards_done: pr.shards_done,
                            blocks_done: pr.blocks_done,
                            acc,
                        };
                        (Some(r), pr.generation + 1)
                    }
                    None => (None, 0),
                };
                // Incremental sink: persist the folded prefix roughly
                // every eighth of the grid (bounded checkpoint traffic).
                // The engine invokes the sink while holding its fold lock,
                // so only a snapshot clone happens there; the disk write
                // runs on a dedicated background thread (one in-flight
                // snapshot — when the writer is behind, a checkpoint is
                // skipped rather than stalling fold advancement).
                use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
                let ckpt_interval = (blocks_total / 8).max(1);
                let last_saved = AtomicUsize::new(
                    resume.as_ref().map(|r| r.blocks_done).unwrap_or(0),
                );
                let generation = AtomicU64::new(start_gen);
                type Snapshot = (super::checkpoint::CompressionProgress, Vec<DenseTensor>);
                // Set by the sink on enqueue, cleared by the writer after
                // the save lands: lets the sink skip the (multi-MB,
                // under-the-fold-lock) snapshot clone entirely while a
                // write is still in flight.
                let writer_busy = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
                let (ckpt_tx, ckpt_writer) = match &self.cfg.checkpoint_dir {
                    Some(dir) => {
                        let (tx, rx) = std::sync::mpsc::sync_channel::<Snapshot>(1);
                        let dir = dir.clone();
                        let fp_w = fp.clone();
                        let busy = std::sync::Arc::clone(&writer_busy);
                        let handle = std::thread::spawn(move || {
                            while let Ok((pr, proxies)) = rx.recv() {
                                if let Err(e) =
                                    super::checkpoint::save_partial(&dir, &fp_w, &pr, &proxies)
                                {
                                    log::warn!("incremental checkpoint failed: {e:#}");
                                }
                                busy.store(false, Ordering::SeqCst);
                            }
                        });
                        (Some(tx), Some(handle))
                    }
                    None => (None, None),
                };
                let sink = |acc: &Vec<DenseTensor>, shards_done: usize, blocks_done: usize| {
                    if shards_done >= shards_total {
                        return true; // completion is the final checkpoint's job
                    }
                    if blocks_done < last_saved.load(Ordering::SeqCst) + ckpt_interval {
                        return true;
                    }
                    if let Some(tx) = &ckpt_tx {
                        if writer_busy.load(Ordering::SeqCst) {
                            return true; // try again at the next advance
                        }
                        let mut pr = partition.clone();
                        pr.shards_done = shards_done;
                        pr.blocks_done = blocks_done;
                        pr.generation = generation.load(Ordering::SeqCst);
                        // Sends happen under the engine's fold lock, so
                        // enqueue order == generation order.  `busy` flips
                        // on BEFORE the send (and back off on failure) so a
                        // fast writer can never clear it first and wedge it.
                        writer_busy.store(true, Ordering::SeqCst);
                        if tx.try_send((pr, acc.clone())).is_ok() {
                            generation.fetch_add(1, Ordering::SeqCst);
                            last_saved.store(blocks_done, Ordering::SeqCst);
                        } else {
                            writer_busy.store(false, Ordering::SeqCst);
                        }
                    }
                    true
                };
                let io_retries_before =
                    crate::tensor::io::IO_RETRIES.load(std::sync::atomic::Ordering::SeqCst);
                let io_gave_up_before =
                    crate::tensor::io::IO_GAVE_UP.load(std::sync::atomic::Ordering::SeqCst);
                let (p, stats) = self.metrics.time("compress", || {
                    let progress: Option<crate::compress::ProgressFn<'_, Vec<DenseTensor>>> =
                        if self.cfg.checkpoint_dir.is_some() { Some(&sink) } else { None };
                    if use_batched {
                        crate::compress::compress_source_batched_opts(
                            src, &maps, plan.block, &stream_opts, resume, progress,
                        )
                    } else {
                        crate::compress::compress_source_opts(
                            src, &maps, plan.block, compressor, &stream_opts, resume, progress,
                        )
                    }
                });
                // Retire the background writer before the final checkpoint
                // so no partial write races save_proxies/clear_partial.
                drop(ckpt_tx);
                if let Some(h) = ckpt_writer {
                    let _ = h.join();
                }
                record_stream_stats(&self.metrics, &stats);
                self.metrics.incr(
                    "io_retries",
                    crate::tensor::io::IO_RETRIES.load(std::sync::atomic::Ordering::SeqCst)
                        - io_retries_before,
                );
                self.metrics.incr(
                    "io_gave_up",
                    crate::tensor::io::IO_GAVE_UP.load(std::sync::atomic::Ordering::SeqCst)
                        - io_gave_up_before,
                );
                self.metrics
                    .set("compress_prefetch_depth", plan.prefetch_depth as u64);
                if let Some(msg) = &stats.failure {
                    // Checkpoint-then-fail: the engine stopped on an
                    // irrecoverable source read but handed back the intact
                    // folded shard prefix — persist it so a retried job
                    // resumes mid-stream instead of restarting Stage 1.
                    // The error message keeps the transient marker from the
                    // I/O layer, which is what the scheduler's retry policy
                    // classifies on.
                    if let Some(dir) = &self.cfg.checkpoint_dir {
                        if stats.shards_done > 0 {
                            let mut pr = partition.clone();
                            pr.shards_done = stats.shards_done;
                            pr.blocks_done = stats.blocks_done as usize;
                            pr.generation = generation.load(Ordering::SeqCst);
                            match super::checkpoint::save_partial(dir, &fp, &pr, &p) {
                                Ok(()) => log::warn!(
                                    "source failure after {}/{} shards; folded prefix \
                                     checkpointed before failing",
                                    pr.shards_done,
                                    pr.shards_total
                                ),
                                Err(e) => log::warn!(
                                    "source failure AND the failure checkpoint failed: {e:#}"
                                ),
                            }
                        }
                    }
                    bail!("compression failed: {msg}");
                }
                if let Some(dir) = &self.cfg.checkpoint_dir {
                    super::checkpoint::save_proxies(dir, &fp, &p)?;
                    super::checkpoint::clear_partial(dir)?;
                }
                p
            }
        };
        // Publish the folded proxies so the next job over this source +
        // compression config (e.g. the next rank of a sweep) skips Stage 1
        // entirely.  A publish failure only costs future reuse.
        if let (Some((store, _)), Some(key)) = (&self.store, &store_key) {
            if let Err(e) = store.publish(key, &proxies, &crate::util::json::Json::Null) {
                log::warn!("store: publishing proxies {} failed: {e:#}", key.id());
            }
        }
        self.metrics.incr("replicas", proxies.len() as u64);
        Ok(PreparedJob {
            plan,
            pool,
            anchor,
            maps,
            proxies,
        })
    }

    /// Stages 3–6 (Alg. 2 lines 5–13 + refinement): everything downstream
    /// of the proxy models.  Counterpart of [`Pipeline::compress_stage`].
    fn finish_stage(
        &self,
        src: &dyn TensorSource,
        prep: PreparedJob,
        models: Vec<(usize, CpModel)>,
    ) -> Result<PipelineResult> {
        let PreparedJob {
            plan,
            pool,
            anchor,
            maps,
            proxies: _,
        } = prep;
        let dims = src.dims();

        // ── Stage 3: anchor normalization + Hungarian alignment (5–7) ──
        // Keep at least the identifiability minimum even if anchor scores
        // are poor (approximately-low-rank inputs).
        let min_keep = MemoryPlanner::min_replicas_anchored(dims, self.cfg.reduced, anchor);
        let (aligned, kept) = self
            .metrics
            .time("align", || normalize_and_align_min(models, anchor, min_keep))?;
        let dropped = plan.replicas - kept.len();
        self.metrics.incr("dropped_replicas", dropped as u64);
        let maps_kept = maps.subset(&kept);

        // ── Stage 4: stacked least squares (Eq. 4, line 9) ──
        // The planner has already settled `Auto` into a concrete solver;
        // panel width is an execution knob, never part of the result.
        log::info!("recovery solver: {}", plan.recovery_solver.as_str());
        let ropts = RecoveryOptions {
            solver: plan.recovery_solver,
            panel_cols: self.cfg.recovery_panel_cols,
            ..RecoveryOptions::default()
        };
        let (tilde, rstats) = self.metrics.time("stacked_lstsq", || {
            stacked_recover_opts(&aligned, &maps_kept, &ropts)
        })?;
        self.record_recovery(plan.recovery_solver, &rstats);

        // ── Stage 5: sampled-subtensor disambiguation (lines 10–13), then
        // an entry-sampling scale polish. The subtensor is sampled at the
        // highest-energy rows of the recovered factors rather than the
        // literal leading corner, so sparse/structured tensors sample
        // signal instead of zeros. Falls back to calibration alone if the
        // corner ALS degenerates. ──
        let rows_i = super::recovery::select_energy_rows(&tilde.a, plan.corner);
        let rows_j = super::recovery::select_energy_rows(&tilde.b, plan.corner);
        let rows_k = super::recovery::select_energy_rows(&tilde.c, plan.corner);
        let corner = super::recovery::gather_subtensor(src, &rows_i, &rows_j, &rows_k);
        let model = self.metrics.time("disambiguate", || {
            let disamb = corner_disambiguate(
                &tilde,
                &corner,
                [&rows_i, &rows_j, &rows_k],
                &AlsOptions {
                    rank: self.cfg.rank,
                    max_iters: self.cfg.als_iters.max(200),
                    tol: self.cfg.als_tol,
                    seed: self.cfg.seed ^ 0xC02,
                    ..Default::default()
                },
            );
            let base = match disamb {
                Ok(m) => m,
                Err(e) => {
                    log::warn!("corner disambiguation failed ({e}); using entry calibration only");
                    tilde.clone()
                }
            };
            entry_calibrate(&base, src, 2 * self.cfg.rank, self.cfg.seed ^ 0xCA1)
                .unwrap_or(base)
        });

        // ── Stage 6 (extension): streaming direct refinement ──
        let model = self.refine_model(src, model, plan.block, &pool)?;

        let diagnostics = self.diagnose(src, &model, dropped);
        Ok(PipelineResult {
            model,
            plan,
            diagnostics,
        })
    }

    /// Runs the configured number of streaming refinement sweeps.
    fn refine_model(
        &self,
        src: &dyn TensorSource,
        model: CpModel,
        block: [usize; 3],
        pool: &ThreadPool,
    ) -> Result<CpModel> {
        if self.cfg.refine_sweeps == 0 {
            return Ok(model);
        }
        self.metrics.time("refine", || {
            super::refine::refine(src, model, block, self.cfg.refine_sweeps, pool)
        })
    }

    /// §IV-D compressed-sensing two-stage variant.
    fn run_sensing(
        &mut self,
        src: &dyn TensorSource,
        plan: MemoryPlan,
        compute: &BackendHandle,
    ) -> Result<PipelineResult> {
        let sc = self.cfg.sensing.unwrap();
        let dims = src.dims();
        let [al, bm, gn] = sc.expanded(self.cfg.reduced);
        let pool = self.pool();
        let anchor = self.cfg.effective_anchor();

        // Stage-1 sparse maps U (αL×I), V (βM×J), W (γN×K).
        let u1 = SparseSignMatrix::generate(al, dims[0], sc.nnz_per_col, self.cfg.seed ^ 0x51);
        let v1 = SparseSignMatrix::generate(bm, dims[1], sc.nnz_per_col, self.cfg.seed ^ 0x52);
        let w1 = SparseSignMatrix::generate(gn, dims[2], sc.nnz_per_col, self.cfg.seed ^ 0x53);

        // Stage-1: one streaming sparse compression into Z (αL×βM×γN),
        // on the plan's streaming schedule (prefetched when out-of-core —
        // this pass is the one that touches the huge source).
        let stream_opts = stream_opts_from_plan(&plan, &pool);
        let (z, stage1_stats) = self.metrics.time("sensing_stage1", || {
            crate::compress::compress_source_sparse_opts(
                src, &u1, &v1, &w1, plan.block, &stream_opts,
            )
        });
        record_stream_stats(&self.metrics, &stage1_stats);

        // Stage-2: plain Alg. 2 on the in-memory Z with dense maps
        // U'_p (L×αL) — reusing the whole standard pipeline.  The expanded
        // dims are small, but the tier still follows the plan so the two
        // tiers stay bitwise interchangeable end to end.
        let maps2 = MapSource::generate(
            [al, bm, gn],
            self.cfg.reduced,
            // P from the *expanded* dims: far smaller than from I.
            MemoryPlanner::default_replicas([al, bm, gn], self.cfg.reduced),
            anchor,
            self.cfg.seed ^ 0x54,
            plan.map_tier,
        );
        let default_comp = self.default_compressor();
        let z_src = crate::tensor::InMemorySource::new(z);
        let proxies = self.metrics.time("compress", || {
            compress_source(&z_src, &maps2, [al, bm, gn], &default_comp, &pool)
        });
        let models = self.metrics.time("decompose", || {
            self.decompose_proxies(&proxies, &pool, compute)
        })?;
        let min_keep =
            MemoryPlanner::min_replicas_anchored([al, bm, gn], self.cfg.reduced, anchor);
        let (aligned, kept) = self
            .metrics
            .time("align", || normalize_and_align_min(models, anchor, min_keep))?;
        let dropped = maps2.p_count() - kept.len();
        let ropts = RecoveryOptions {
            solver: plan.recovery_solver,
            panel_cols: self.cfg.recovery_panel_cols,
            ..RecoveryOptions::default()
        };
        let (tilde_z, rstats) = self.metrics.time("stacked_lstsq", || {
            stacked_recover_opts(&aligned, &maps2.subset(&kept), &ropts)
        })?;
        self.record_recovery(plan.recovery_solver, &rstats);

        // Second factorization stage: Z̃ = U·(AΠΣ) → AΠΣ via ISTA (§IV-D).
        let ista = IstaOptions {
            lambda: sc.lambda,
            max_iters: 2000,
            tol: 1e-9,
        };
        let tilde = self.metrics.time("sensing_l1", || CpModel::new(
            sensing_recover_mode(&u1, &tilde_z.a, &ista),
            sensing_recover_mode(&v1, &tilde_z.b, &ista),
            sensing_recover_mode(&w1, &tilde_z.c, &ista),
        ));

        // Sparse tensors' leading corner is typically all-zero, so the
        // corner decomposition (Alg. 2 lines 10–13) is degenerate here;
        // entry-sampling calibration replaces it (DESIGN.md substitution).
        let model = self
            .metrics
            .time("disambiguate", || {
                entry_calibrate(&tilde, src, 4 * self.cfg.rank, self.cfg.seed ^ 0xCA2)
            })?;
        let model = self.refine_model(src, model, plan.block, &pool)?;

        let diagnostics = self.diagnose(src, &model, dropped);
        Ok(PipelineResult {
            model,
            plan,
            diagnostics,
        })
    }

    /// Decomposes every proxy with restarts, then drops fit outliers.
    /// Returns `(original_index, model)` pairs for the survivors.
    fn decompose_proxies(
        &self,
        proxies: &[DenseTensor],
        pool: &ThreadPool,
        compute: &BackendHandle,
    ) -> Result<Vec<(usize, CpModel)>> {
        let rank = self.cfg.rank;
        let seed = self.cfg.seed;
        let default_dec;
        let decomposer: &dyn ProxyDecomposer =
            match (&self.decomposer, compute.proxy_decomposer()) {
                (Some(d), _) => d.as_ref(),
                (None, Some(d)) => d,
                (None, None) => {
                    // Replicas are decomposed in parallel across the pool,
                    // so each ALS normally runs on the serial kernel
                    // reference; with a single proxy the pool cannot help,
                    // so that lone ALS gets the resolved kernel backend
                    // (parallel on the RustParallel arm) instead.
                    let kernel: BackendHandle = if proxies.len() <= 1 {
                        compute.clone()
                    } else {
                        Arc::new(SerialBackend)
                    };
                    default_dec = RustAlsDecomposer::new(self.cfg.als_iters, self.cfg.als_tol)
                        .with_backend(kernel);
                    &default_dec
                }
            };
        let results = pool.map_indexed(proxies.len(), |p| {
            let mut best: Option<(CpModel, f64)> = None;
            for attempt in 0..MAX_ATTEMPTS {
                let s = attempt_seed(seed, p, attempt);
                match decomposer.decompose(&proxies[p], rank, s) {
                    Ok((m, fit)) => {
                        let improved = best.as_ref().map(|(_, bf)| fit > *bf).unwrap_or(true);
                        if improved {
                            best = Some((m, fit));
                        }
                        if best.as_ref().unwrap().1 >= RETRY_FIT {
                            break;
                        }
                    }
                    Err(e) => log::warn!("replica {p} attempt {attempt} failed: {e}"),
                }
            }
            best
        });
        select_surviving(results, &self.metrics)
    }

    /// Surfaces the stacked solve's counters as gauges (set, not
    /// accumulated — they describe this run's resolved configuration).
    fn record_recovery(
        &self,
        solver: RecoverySolverKind,
        stats: &super::recovery::RecoveryStats,
    ) {
        self.metrics.set("recovery_cg_iters", stats.cg_iterations);
        self.metrics.set(
            "recovery_solver_iterative",
            u64::from(solver == RecoverySolverKind::Iterative),
        );
    }

    fn diagnose(&self, src: &dyn TensorSource, model: &CpModel, dropped: usize) -> Diagnostics {
        let err = sampled_mse(src, model, 8, 16, self.cfg.seed ^ 0xD1A6);
        Diagnostics {
            dropped_replicas: dropped,
            sampled_mse: err.mse,
            rel_error: err.rel_error,
            max_factor_error: f64::NAN,
        }
    }
}

/// Deterministic per-(replica, attempt) init seed — the same value for the
/// solo attempt loop and the batched sweep, which is half of the batch
/// lane's bitwise-identity guarantee (the other half is [`als_batch`]'s
/// untouched per-item operation sequence).
fn attempt_seed(seed: u64, p: usize, attempt: usize) -> u64 {
    seed ^ (p as u64).wrapping_mul(0x9E37) ^ (attempt as u64).wrapping_mul(0x1234_5601)
}

/// Shared fit-outlier policy (solo and batched decomposition): median of
/// the surviving fits, drop anything more than `DROP_MARGIN` below it.
/// `results[p]` is replica `p`'s best `(model, fit)` across attempts
/// (`None` if every attempt failed).
fn select_surviving(
    results: Vec<Option<(CpModel, f64)>>,
    metrics: &Metrics,
) -> Result<Vec<(usize, CpModel)>> {
    let total = results.len();
    let mut fits: Vec<f64> = results.iter().flatten().map(|(_, f)| *f).collect();
    if fits.is_empty() {
        anyhow::bail!("every proxy decomposition failed");
    }
    fits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = fits[fits.len() / 2];
    let kept: Vec<(usize, CpModel)> = results
        .into_iter()
        .enumerate()
        .filter_map(|(p, r)| {
            let (m, fit) = r?;
            if fit >= median - DROP_MARGIN {
                Some((p, m))
            } else {
                log::warn!("dropping replica {p}: fit {fit:.4} ≪ median {median:.4}");
                None
            }
        })
        .collect();
    metrics.incr("replicas_fit_dropped", (total - kept.len()) as u64);
    Ok(kept)
}

/// Runs a group of compatible jobs with their proxy-ALS iterations
/// coalesced into shared [`als_batch`] sweeps — the batch lane's engine.
///
/// Per job: the ordinary Stage-1 compression runs as usual (checkpoints,
/// metrics, planner — all per job); then, instead of each job spinning up
/// its own pool residency for Stage 2, every job's `(replica, attempt)`
/// items join one coalesced sweep per retry wave; finally stages 3–6 run
/// per job on its own pipeline.  Attempt seeds, the improve/retry policy
/// (`RETRY_FIT`/`MAX_ATTEMPTS`), and the fit-outlier drop are exactly the
/// solo path's, and `als_batch` preserves each item's operation sequence
/// bit for bit — so every job's factors (and therefore its model digest)
/// are identical to a solo [`Pipeline::run`].
///
/// Jobs the sweep cannot serve identically fall back to solo `run()`
/// inline: the sensing variant, jobs with a custom or stage-hook proxy
/// decomposer, and single-proxy jobs (whose lone solo ALS runs on the
/// resolved kernel backend rather than the serial reference the
/// replica-parallel path — and the sweep — use).
///
/// Items are grouped by `(rank, als_iters, als_tol)` within each wave, so
/// mixed-config groups still work; the scheduler's lane feeds compatible
/// jobs to keep each wave a single sweep.
pub fn run_batch_group(
    pipes: &mut [Pipeline],
    sources: &[&dyn TensorSource],
) -> Vec<Result<PipelineResult>> {
    assert_eq!(pipes.len(), sources.len(), "run_batch_group: job/source mismatch");
    let n = pipes.len();
    let mut out: Vec<Option<Result<PipelineResult>>> = (0..n).map(|_| None).collect();
    let mut preps: Vec<Option<PreparedJob>> = (0..n).map(|_| None).collect();

    // Per-job prologue + Stage 1.
    for i in 0..n {
        let staged = (|| -> Result<Option<PreparedJob>> {
            pipes[i].cfg.validate()?;
            let compute = pipes[i].resolve_compute()?;
            let dims = sources[i].dims();
            let plan = MemoryPlanner::plan(&pipes[i].cfg, dims)?;
            let batchable = pipes[i].cfg.sensing.is_none()
                && pipes[i].decomposer.is_none()
                && compute.proxy_decomposer().is_none()
                && plan.replicas > 1;
            if !batchable {
                return Ok(None);
            }
            pipes[i].compress_stage(sources[i], plan, &compute).map(Some)
        })();
        match staged {
            Ok(Some(prep)) => preps[i] = Some(prep),
            Ok(None) => out[i] = Some(pipes[i].run(sources[i])),
            Err(e) => out[i] = Some(Err(e)),
        }
    }

    // Shared Stage 2: one coalesced sweep per retry wave over every
    // still-unconverged (job, replica) item.
    let mut best: Vec<Vec<Option<(CpModel, f64)>>> = (0..n)
        .map(|i| {
            let p = preps[i].as_ref().map(|pr| pr.proxies.len()).unwrap_or(0);
            (0..p).map(|_| None).collect()
        })
        .collect();
    // The sweep pool inherits the *aggregate* thread entitlement of its
    // members, capped at the host: a lone small job is stuck with its own
    // `cfg.threads`, but a coalesced wave has enough independent items to
    // fill the width the whole group was admitted with.  Width never
    // affects results — every item runs on a serial per-item kernel.
    let host = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let sweep_threads = (0..n)
        .filter(|&i| preps[i].is_some())
        .map(|i| pipes[i].cfg.threads.max(1))
        .sum::<usize>()
        .clamp(1, host);
    let sweep = cpu_backend(sweep_threads);
    let sweep_start = std::time::Instant::now();
    for attempt in 0..MAX_ATTEMPTS {
        // Items wanting this attempt, grouped by the (rank, iters, tol)
        // config one `als_batch` call shares.
        let mut groups: std::collections::BTreeMap<(usize, usize, u64), Vec<(usize, usize)>> =
            std::collections::BTreeMap::new();
        for i in 0..n {
            if preps[i].is_none() {
                continue;
            }
            for p in 0..best[i].len() {
                let wants = match &best[i][p] {
                    None => true,
                    Some((_, f)) => *f < RETRY_FIT,
                };
                if wants {
                    let cfg = &pipes[i].cfg;
                    groups
                        .entry((cfg.rank, cfg.als_iters, cfg.als_tol.to_bits()))
                        .or_default()
                        .push((i, p));
                }
            }
        }
        if groups.is_empty() {
            break;
        }
        for ((rank, iters, tol_bits), members) in groups {
            let items: Vec<AlsBatchItem<'_>> = members
                .iter()
                .map(|&(i, p)| AlsBatchItem {
                    tensor: &preps[i].as_ref().unwrap().proxies[p],
                    seed: attempt_seed(pipes[i].cfg.seed, p, attempt),
                })
                .collect();
            let opts = AlsOptions {
                rank,
                max_iters: iters,
                tol: f64::from_bits(tol_bits),
                ..Default::default()
            };
            let results = als_batch(&items, &opts, &*sweep);
            for (&(i, p), res) in members.iter().zip(results) {
                match res {
                    Ok((m, trace)) => {
                        let fit = trace.fits.last().copied().unwrap_or(f64::NEG_INFINITY);
                        let improved =
                            best[i][p].as_ref().map(|(_, bf)| fit > *bf).unwrap_or(true);
                        if improved {
                            best[i][p] = Some((m, fit));
                        }
                    }
                    Err(e) => log::warn!("replica {p} attempt {attempt} failed: {e}"),
                }
            }
        }
    }
    let sweep_secs = sweep_start.elapsed().as_secs_f64();

    // Per-job epilogue: fit-outlier policy + stages 3–6, each on its own
    // pipeline and metrics.  The sweep's wall time is recorded under every
    // participating job's "decompose" stage as-is (shared, not divided —
    // the lane's amortization is exactly that the jobs overlap in it).
    for i in 0..n {
        let Some(prep) = preps[i].take() else { continue };
        pipes[i].metrics.record("decompose", sweep_secs);
        let models = select_surviving(std::mem::take(&mut best[i]), &pipes[i].metrics);
        out[i] = Some(match models {
            Ok(models) => pipes[i].finish_stage(sources[i], prep, models),
            Err(e) => Err(e),
        });
    }

    out.into_iter()
        .map(|r| r.expect("every job settled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SensingConfig;
    use crate::tensor::LowRankGenerator;

    fn base_cfg() -> crate::coordinator::config::PipelineConfigBuilder {
        PipelineConfig::builder()
            .reduced_dims(10, 10, 10)
            .rank(3)
            .anchor_rows(5)
            .block([16, 16, 16])
            .corner(12)
            .als(150, 1e-11)
            .threads(4)
            .seed(7)
    }

    #[test]
    fn recovers_planted_factors_dense() {
        let gen = LowRankGenerator::new(40, 40, 40, 3, 1000);
        let cfg = base_cfg().build().unwrap();
        let mut pipe = Pipeline::new(cfg);
        let res = pipe.run(&gen).unwrap();
        assert!(
            res.diagnostics.rel_error < 1e-2,
            "rel error {} (mse {})",
            res.diagnostics.rel_error,
            res.diagnostics.sampled_mse
        );
        // Factor congruence with the planted truth.
        let (a, b, c) = gen.factors.clone();
        let truth = CpModel::new(a, b, c);
        let cong = crate::cp::model_congruence(&truth, &res.model);
        assert!(cong > 0.99, "congruence {cong}");
    }

    #[test]
    fn sequential_backend_matches_parallel() {
        let gen = LowRankGenerator::new(30, 30, 30, 2, 1001);
        let cfg_seq = base_cfg()
            .rank(2)
            .backend(Backend::RustSequential)
            .build()
            .unwrap();
        let cfg_par = base_cfg()
            .rank(2)
            .backend(Backend::RustParallel)
            .build()
            .unwrap();
        let r_seq = Pipeline::new(cfg_seq).run(&gen).unwrap();
        let r_par = Pipeline::new(cfg_par).run(&gen).unwrap();
        // Same seed → same maps → reconstruction should agree closely.
        let t_seq = r_seq.model.to_tensor();
        let t_par = r_par.model.to_tensor();
        assert!(t_seq.rel_error(&t_par) < 1e-3);
    }

    #[test]
    fn mixed_precision_small_extra_error() {
        let gen = LowRankGenerator::new(32, 32, 32, 2, 1002);
        let cfg = base_cfg().rank(2).mixed_precision(true).build().unwrap();
        let res = Pipeline::new(cfg).run(&gen).unwrap();
        assert!(
            res.diagnostics.rel_error < 5e-2,
            "mixed rel error {}",
            res.diagnostics.rel_error
        );
    }

    #[test]
    fn metrics_populated() {
        let gen = LowRankGenerator::new(24, 24, 24, 2, 1003);
        let cfg = base_cfg().rank(2).build().unwrap();
        let mut pipe = Pipeline::new(cfg);
        pipe.run(&gen).unwrap();
        for stage in ["compress", "decompose", "align", "stacked_lstsq", "disambiguate"] {
            assert!(pipe.metrics.stage(stage).is_some(), "missing stage {stage}");
        }
    }

    #[test]
    fn sensing_variant_runs() {
        let gen = crate::tensor::SparseLowRankGenerator::new(36, 36, 36, 2, 6, 1004);
        let cfg = base_cfg()
            .rank(2)
            .reduced_dims(12, 12, 12)
            .sensing(SensingConfig {
                alpha: 2.2,
                nnz_per_col: 10,
                lambda: 0.02,
            })
            .build()
            .unwrap();
        let res = Pipeline::new(cfg).run(&gen).unwrap();
        // The L1 second stage is approximate: allow a looser bound here.
        assert!(
            res.diagnostics.rel_error < 0.3,
            "sensing rel error {}",
            res.diagnostics.rel_error
        );
    }

    #[test]
    fn iterative_solver_matches_default_end_to_end() {
        use crate::coordinator::config::RecoverySolver;
        let gen = LowRankGenerator::new(30, 30, 30, 2, 1006);
        let cfg_chol = base_cfg().rank(2).build().unwrap();
        let cfg_iter = base_cfg()
            .rank(2)
            .recovery_solver(RecoverySolver::Iterative)
            .build()
            .unwrap();
        let r_chol = Pipeline::new(cfg_chol).run(&gen).unwrap();
        let mut pipe = Pipeline::new(cfg_iter);
        let r_iter = pipe.run(&gen).unwrap();
        assert_eq!(r_iter.plan.recovery_solver, RecoverySolverKind::Iterative);
        assert!(
            r_iter.diagnostics.rel_error < 1e-2,
            "iterative rel error {}",
            r_iter.diagnostics.rel_error
        );
        let t_chol = r_chol.model.to_tensor();
        let t_iter = r_iter.model.to_tensor();
        assert!(t_chol.rel_error(&t_iter) < 1e-2, "err {}", t_chol.rel_error(&t_iter));
        assert!(pipe.metrics.counter("recovery_cg_iters") > 0);
        assert_eq!(pipe.metrics.counter("recovery_solver_iterative"), 1);
    }

    #[test]
    fn batch_group_matches_solo_bitwise() {
        let gens: Vec<LowRankGenerator> = (0..3u64)
            .map(|i| LowRankGenerator::new(24, 24, 24, 2, 2000 + i))
            .collect();
        let solos: Vec<PipelineResult> = gens
            .iter()
            .map(|g| {
                Pipeline::new(base_cfg().rank(2).build().unwrap())
                    .run(g)
                    .unwrap()
            })
            .collect();
        let mut pipes: Vec<Pipeline> = (0..3)
            .map(|_| Pipeline::new(base_cfg().rank(2).build().unwrap()))
            .collect();
        let sources: Vec<&dyn TensorSource> =
            gens.iter().map(|g| g as &dyn TensorSource).collect();
        let results = run_batch_group(&mut pipes, &sources);
        for (i, (solo, batched)) in solos.iter().zip(results).enumerate() {
            let b = batched.unwrap();
            assert_eq!(
                b.model.a, solo.model.a,
                "job {i}: batched factor A must be bitwise solo"
            );
            assert_eq!(b.model.b, solo.model.b, "job {i}: factor B");
            assert_eq!(b.model.c, solo.model.c, "job {i}: factor C");
        }
        // The shared sweep's time lands under each job's decompose stage.
        for p in &pipes {
            assert!(p.metrics.stage("decompose").is_some());
        }
    }

    #[test]
    fn sharded_seam_matches_solo_bitwise() {
        use crate::compress::{compress_shard_batched, fold_shard_proxies, zero_shard_proxies};
        let gen = LowRankGenerator::new(30, 30, 30, 2, 1007);
        let cfg = base_cfg().rank(2).build().unwrap();
        let solo = Pipeline::new(cfg.clone()).run(&gen).unwrap();

        // Coordinator half: resolve the grid, simulate remote workers by
        // running each shard range independently, fold in shard order.
        let mut pipe = Pipeline::new(cfg);
        let grid = pipe.sharded_grid(&gen).unwrap();
        assert_eq!(grid.path, "batched");
        let maps = MapSource::generate(
            grid.dims,
            grid.reduced,
            grid.replicas,
            grid.anchor,
            grid.seed,
            grid.map_tier,
        );
        let shards = ThreadPool::partition(grid.blocks_total, grid.shard_parts);
        let mut folded = zero_shard_proxies(&maps);
        for (b0, b1) in shards {
            let acc = compress_shard_batched(&gen, &maps, grid.block, b0, b1);
            fold_shard_proxies(&mut folded, acc);
        }
        let res = pipe.run_with_proxies(&gen, folded).unwrap();
        assert_eq!(res.model.a, solo.model.a, "factor A must be bitwise solo");
        assert_eq!(res.model.b, solo.model.b, "factor B");
        assert_eq!(res.model.c, solo.model.c, "factor C");
    }

    #[test]
    fn artifact_store_reuse_is_bitwise_and_skips_streaming() {
        let dir = {
            let mut p = std::env::temp_dir();
            p.push(format!("exatensor_pipe_store_{}", std::process::id()));
            std::fs::remove_dir_all(&p).ok();
            p
        };
        let store = Arc::new(
            crate::store::ArtifactStore::open(
                dir.clone(),
                64 << 20,
                Arc::new(Metrics::new()),
            )
            .unwrap(),
        );
        let gen = LowRankGenerator::new(30, 30, 30, 2, 1008);
        // `anchor_rows` pinned in base_cfg ⇒ the proxy key is
        // rank-independent, so a rank sweep shares one Stage-1 artifact.
        let solo_r2 = Pipeline::new(base_cfg().rank(2).build().unwrap()).run(&gen).unwrap();
        let solo_r3 = Pipeline::new(base_cfg().rank(3).build().unwrap()).run(&gen).unwrap();

        let mut cold = Pipeline::new(base_cfg().rank(2).build().unwrap())
            .with_store(Arc::clone(&store), 0xFEED);
        let cold_res = cold.run(&gen).unwrap();
        assert!(cold.metrics.counter("blocks_streamed") > 0, "cold run streams");

        for (rank, solo) in [(2usize, &solo_r2), (3usize, &solo_r3)] {
            let mut warm = Pipeline::new(base_cfg().rank(rank).build().unwrap())
                .with_store(Arc::clone(&store), 0xFEED);
            let warm_res = warm.run(&gen).unwrap();
            assert_eq!(
                warm.metrics.counter("blocks_streamed"),
                0,
                "rank {rank}: warm run must not stream a single block"
            );
            assert_eq!(warm_res.model.a, solo.model.a, "rank {rank}: factor A bitwise");
            assert_eq!(warm_res.model.b, solo.model.b, "rank {rank}: factor B bitwise");
            assert_eq!(warm_res.model.c, solo.model.c, "rank {rank}: factor C bitwise");
        }
        // And the cold store-run itself matched the storeless solo.
        assert_eq!(cold_res.model.a, solo_r2.model.a);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn noisy_input_still_recovers() {
        let gen = LowRankGenerator::new(36, 36, 36, 2, 1005).with_noise(1e-3);
        let cfg = base_cfg().rank(2).build().unwrap();
        let res = Pipeline::new(cfg).run(&gen).unwrap();
        assert!(
            res.diagnostics.rel_error < 0.05,
            "noisy rel error {}",
            res.diagnostics.rel_error
        );
    }
}
