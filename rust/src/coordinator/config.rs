//! Pipeline configuration: every knob of Alg. 2 plus execution policy.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Which engine performs block compression and proxy decomposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Single-threaded pure rust — the paper's "Baseline".
    RustSequential,
    /// Multi-threaded pure rust — "Parallel on CPU" (the MPI arm).
    RustParallel,
    /// Worker pool + AOT XLA/Pallas artifacts — "Parallel on GPU"
    /// (tensor-core arm, adapted to the MXU; see DESIGN.md).
    Xla,
}

/// Replica-map storage-tier policy (see `compress::maps`): how the
/// Gaussian compression maps exist at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MapTierChoice {
    /// Planner decides: procedural when the materialized maps would eat a
    /// meaningful share (> 1/8) of the memory budget, materialized
    /// otherwise (and always, when no budget is set).
    #[default]
    Auto,
    /// Force dense stored maps (`P×(L·I+M·J+N·K)` floats).
    Materialized,
    /// Force generate-on-slice maps (`O(panel)` memory).
    Procedural,
}

impl MapTierChoice {
    pub fn as_str(&self) -> &'static str {
        match self {
            MapTierChoice::Auto => "auto",
            MapTierChoice::Materialized => "materialized",
            MapTierChoice::Procedural => "procedural",
        }
    }

    /// Parses the CLI/JSON spelling (`auto | materialized | procedural`,
    /// with `mat`/`proc` shorthands).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => MapTierChoice::Auto,
            "materialized" | "mat" => MapTierChoice::Materialized,
            "procedural" | "proc" => MapTierChoice::Procedural,
            other => bail!("map tier '{other}' (expected auto|materialized|procedural)"),
        })
    }
}

/// Default streamed-recovery panel width (columns per generated `L×w`
/// map panel) — the `recovery_panel_cols` knob's default.
pub const DEFAULT_RECOVERY_PANEL_COLS: usize = 256;

/// Stacked-recovery solver policy (see `coordinator::recovery`): how the
/// per-mode least-squares system of Eq. (4) is solved.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RecoverySolver {
    /// Planner decides: matrix-free iterative when the `dim×dim`
    /// normal-equation Gram would eat a meaningful share (> 1/8) of the
    /// memory budget, dense Cholesky otherwise (and always, when no
    /// budget is set).
    #[default]
    Auto,
    /// Force the dense path: accumulate the `dim×dim` Gram panel-wise,
    /// one Cholesky solve.  `O(dim²)` memory.
    Cholesky,
    /// Force matrix-free CGNR: matvecs stream map panels on demand, the
    /// Gram is never formed.  `O(panel + dim×R)` memory.
    Iterative,
    /// Force randomized sketch-and-solve: counter-rng Gaussian sketch of
    /// the stacked system, small dense solve, CG polish.  Memory is
    /// `O(sketch_rows×dim)` — larger than `Iterative`, so `Auto` never
    /// picks it; it exists as an explicitly-requested refine/experiment
    /// path.
    Sketch,
}

impl RecoverySolver {
    pub fn as_str(&self) -> &'static str {
        match self {
            RecoverySolver::Auto => "auto",
            RecoverySolver::Cholesky => "cholesky",
            RecoverySolver::Iterative => "iterative",
            RecoverySolver::Sketch => "sketch",
        }
    }

    /// Parses the CLI/JSON spelling (`auto | cholesky | iterative |
    /// sketch`, with `chol`/`cg`/`iter` shorthands).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => RecoverySolver::Auto,
            "cholesky" | "chol" => RecoverySolver::Cholesky,
            "iterative" | "iter" | "cg" => RecoverySolver::Iterative,
            "sketch" => RecoverySolver::Sketch,
            other => bail!("recovery solver '{other}' (expected auto|cholesky|iterative|sketch)"),
        })
    }
}

/// A *resolved* recovery solver — what actually runs after the planner
/// settles `Auto` (the analogue of `compress::maps::MapTier` for
/// `MapTierChoice`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoverySolverKind {
    Cholesky,
    Iterative,
    Sketch,
}

impl RecoverySolverKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            RecoverySolverKind::Cholesky => "cholesky",
            RecoverySolverKind::Iterative => "iterative",
            RecoverySolverKind::Sketch => "sketch",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "cholesky" => RecoverySolverKind::Cholesky,
            "iterative" => RecoverySolverKind::Iterative,
            "sketch" => RecoverySolverKind::Sketch,
            other => bail!("resolved recovery solver '{other}'"),
        })
    }
}

/// Compressed-sensing two-stage compression options (§IV-D).
#[derive(Clone, Copy, Debug)]
pub struct SensingConfig {
    /// Expansion factors α, β, γ (> 1): stage 1 compresses to
    /// `αL × βM × γN`.
    pub alpha: f32,
    /// Nonzeros per column of the sparse stage-1 maps.
    pub nnz_per_col: usize,
    /// L1 penalty for the ISTA second-stage recovery, *relative* to each
    /// column's `λ_max = ‖Uᵀy‖_∞` (scale-invariant).
    pub lambda: f32,
}

impl SensingConfig {
    /// Stage-1 expanded dims `[αL, βM, γN]` for the given reduced dims —
    /// the shape of the intermediate `Z` (and of each of the streaming
    /// engine's shard-local `Z` accumulators).  One definition shared by
    /// the pipeline and the memory planner.
    pub fn expanded(&self, reduced: [usize; 3]) -> [usize; 3] {
        let expand = |r: usize| ((r as f32 * self.alpha).ceil() as usize).max(r + 1);
        [expand(reduced[0]), expand(reduced[1]), expand(reduced[2])]
    }
}

impl Default for SensingConfig {
    fn default() -> Self {
        Self {
            alpha: 2.2,
            nnz_per_col: 8,
            lambda: 0.02,
        }
    }
}

/// Full pipeline configuration.  Build with [`PipelineConfig::builder`].
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Reduced (proxy) dims `[L, M, N]`.
    pub reduced: [usize; 3],
    /// Target CP rank `R` (the paper's `F`).
    pub rank: usize,
    /// Number of proxy replicas `P`; `None` → planner default
    /// `max((I−2)/(L−2), J/M, K/N) + 10` (§V-A).
    pub replicas: Option<usize>,
    /// Shared anchor rows `S`; must satisfy `S ≥ rank` for the trace
    /// matching to be well-posed. Default `rank + 2`.
    pub anchor_rows: Option<usize>,
    /// Compression block dims `d` (Fig. 2). Default `[500,500,500]`
    /// clamped to the tensor dims.
    pub block: Option<[usize; 3]>,
    /// Corner sample size `b` for the final disambiguation (Alg. 2 l. 10).
    pub corner: Option<usize>,
    /// ALS sweeps per proxy.
    pub als_iters: usize,
    /// ALS convergence tolerance.
    pub als_tol: f64,
    /// Execution backend.
    pub backend: Backend,
    /// Worker threads (ignored for `RustSequential`).
    pub threads: usize,
    /// Use mixed-precision (split bf16) block compression — §IV-B.
    pub mixed_precision: bool,
    /// Compressed-sensing two-stage mode — §IV-D. `None` = plain Alg. 2.
    pub sensing: Option<SensingConfig>,
    /// Memory budget in bytes for the planner (0 = unlimited).  When the
    /// budget is smaller than the tensor's byte size, the planner resolves
    /// an **out-of-core** plan: block dims, prefetch depth, and the
    /// streaming working set (queue + in-flight blocks + shard
    /// accumulators + checkpoint snapshots) are sized to fit the budget,
    /// and prefetching defaults on so file-backed reads overlap compute.
    /// (Known modeling gap: blocks parked out of order in the prefetched
    /// scheduler are bounded by the fold window but not individually
    /// budgeted — see ROADMAP.)
    pub memory_budget: usize,
    /// Prefetch queue depth in blocks.  `None` → auto (enabled at
    /// `2 × io_threads` for out-of-core plans, disabled otherwise);
    /// `Some(0)` → force synchronous reads; `Some(d)` → force depth `d`.
    pub prefetch_depth: Option<usize>,
    /// Dedicated I/O producer threads when prefetching.
    pub io_threads: usize,
    /// Streaming direct-refinement sweeps after recovery (one extra pass
    /// over the source per sweep; removes the stacked-solve noise
    /// amplification). 0 disables.
    pub refine_sweeps: usize,
    /// Checkpoint directory: when set, the post-compression state is
    /// persisted there and reused by matching re-runs (crash resume).
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Replica-map storage tier (`Auto` lets the planner pick).  Results
    /// are bitwise identical across tiers; only memory/speed differ, so
    /// this knob is excluded from cache fingerprints like the other
    /// execution-only knobs.
    pub map_tier: MapTierChoice,
    /// Stacked-recovery solver (`Auto` lets the planner pick).  All
    /// solvers target the same ridge-damped minimizer, so — like
    /// `map_tier` — this is an execution-only knob excluded from cache
    /// fingerprints (results agree to solver tolerance, not bitwise).
    pub recovery_solver: RecoverySolver,
    /// Column width of the streamed `L×w` map panels recovery reads
    /// (Gram accumulation for the dense path, matvec passes for the
    /// iterative path).  Larger panels amortize generation; smaller
    /// panels shrink the working set.  Execution-only.
    pub recovery_panel_cols: usize,
    pub seed: u64,
}

impl PipelineConfig {
    pub fn builder() -> PipelineConfigBuilder {
        PipelineConfigBuilder::default()
    }

    /// Effective anchor rows: `rank + 2` clamped to the smallest reduced
    /// dim (small modes may have every row anchored — the planner then
    /// treats them as uncompressed).
    pub fn effective_anchor(&self) -> usize {
        let min_red = self.reduced[0].min(self.reduced[1]).min(self.reduced[2]);
        self.anchor_rows.unwrap_or((self.rank + 2).min(min_red))
    }

    /// Validates internal consistency (dims-independent checks).
    pub fn validate(&self) -> Result<()> {
        if self.rank == 0 {
            bail!("rank must be ≥ 1");
        }
        let [l, m, n] = self.reduced;
        // Strict `reduced > rank` is only needed on modes that actually
        // compress — the planner enforces that per mode once dims are
        // known; here we require the weaker `reduced ≥ rank`.
        if l < self.rank || m < self.rank || n < self.rank {
            bail!(
                "reduced dims {:?} must be ≥ rank {} for proxy CP identifiability",
                self.reduced,
                self.rank
            );
        }
        let s = self.effective_anchor();
        if s < self.rank {
            bail!("anchor rows S={s} must be ≥ rank R={}", self.rank);
        }
        if s > l.min(m).min(n) {
            bail!("anchor rows S={s} exceed reduced dims {:?}", self.reduced);
        }
        if self.als_iters == 0 {
            bail!("als_iters must be ≥ 1");
        }
        if self.recovery_panel_cols == 0 {
            bail!("recovery_panel_cols must be ≥ 1");
        }
        if let Some(sc) = &self.sensing {
            if sc.alpha <= 1.0 {
                bail!("sensing alpha must be > 1, got {}", sc.alpha);
            }
            if sc.nnz_per_col == 0 {
                bail!("sensing nnz_per_col must be ≥ 1");
            }
        }
        Ok(())
    }
}

impl PipelineConfig {
    /// Serializes every knob to JSON — the `serve/` job spool persists one
    /// of these per job so a crashed daemon rebuilds the exact run.
    /// `u64` seeds round-trip exactly up to 2⁵³ (JSON numbers are f64).
    pub fn to_json(&self) -> Json {
        let opt_num = |v: Option<usize>| match v {
            Some(x) => Json::num(x as f64),
            None => Json::Null,
        };
        let mut pairs = vec![
            ("reduced", Json::arr_usize(&self.reduced)),
            ("rank", Json::num(self.rank as f64)),
            ("replicas", opt_num(self.replicas)),
            ("anchor_rows", opt_num(self.anchor_rows)),
            (
                "block",
                match self.block {
                    Some(b) => Json::arr_usize(&b),
                    None => Json::Null,
                },
            ),
            ("corner", opt_num(self.corner)),
            ("als_iters", Json::num(self.als_iters as f64)),
            ("als_tol", Json::num(self.als_tol)),
            (
                "backend",
                Json::str(match self.backend {
                    Backend::RustSequential => "seq",
                    Backend::RustParallel => "par",
                    Backend::Xla => "xla",
                }),
            ),
            ("threads", Json::num(self.threads as f64)),
            ("mixed_precision", Json::Bool(self.mixed_precision)),
            ("memory_budget", Json::num(self.memory_budget as f64)),
            ("prefetch_depth", opt_num(self.prefetch_depth)),
            ("io_threads", Json::num(self.io_threads as f64)),
            ("refine_sweeps", Json::num(self.refine_sweeps as f64)),
            ("map_tier", Json::str(self.map_tier.as_str())),
            ("recovery_solver", Json::str(self.recovery_solver.as_str())),
            ("recovery_panel_cols", Json::num(self.recovery_panel_cols as f64)),
            ("seed", Json::num(self.seed as f64)),
        ];
        if let Some(sc) = &self.sensing {
            pairs.push((
                "sensing",
                Json::obj(vec![
                    ("alpha", Json::num(sc.alpha as f64)),
                    ("nnz_per_col", Json::num(sc.nnz_per_col as f64)),
                    ("lambda", Json::num(sc.lambda as f64)),
                ]),
            ));
        }
        if let Some(dir) = &self.checkpoint_dir {
            pairs.push(("checkpoint_dir", Json::str(dir.display().to_string())));
        }
        Json::obj(pairs)
    }

    /// Inverse of [`PipelineConfig::to_json`]; validates the result.
    pub fn from_json(v: &Json) -> Result<PipelineConfig> {
        let num = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("config missing {key}"))
        };
        let opt_num = |key: &str| -> Result<Option<usize>> {
            match v.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(x) => Ok(Some(
                    x.as_usize().with_context(|| format!("config bad {key}"))?,
                )),
            }
        };
        let reduced = {
            let a = v
                .get("reduced")
                .and_then(|x| x.as_arr())
                .context("config missing reduced")?;
            if a.len() != 3 {
                bail!("config reduced: expected 3 dims");
            }
            [
                a[0].as_usize().context("reduced dim")?,
                a[1].as_usize().context("reduced dim")?,
                a[2].as_usize().context("reduced dim")?,
            ]
        };
        let block = match v.get("block") {
            None | Some(Json::Null) => None,
            Some(x) => {
                let a = x.as_arr().context("config bad block")?;
                if a.len() != 3 {
                    bail!("config block: expected 3 dims");
                }
                Some([
                    a[0].as_usize().context("block dim")?,
                    a[1].as_usize().context("block dim")?,
                    a[2].as_usize().context("block dim")?,
                ])
            }
        };
        let backend = match v.get("backend").and_then(|x| x.as_str()).unwrap_or("par") {
            "seq" => Backend::RustSequential,
            "xla" => Backend::Xla,
            "par" => Backend::RustParallel,
            other => bail!("config backend '{other}' (expected seq|par|xla)"),
        };
        let sensing = match v.get("sensing") {
            None | Some(Json::Null) => None,
            Some(s) => Some(SensingConfig {
                alpha: s
                    .get("alpha")
                    .and_then(|x| x.as_f64())
                    .context("sensing missing alpha")? as f32,
                nnz_per_col: s
                    .get("nnz_per_col")
                    .and_then(|x| x.as_usize())
                    .context("sensing missing nnz_per_col")?,
                lambda: s
                    .get("lambda")
                    .and_then(|x| x.as_f64())
                    .context("sensing missing lambda")? as f32,
            }),
        };
        let cfg = PipelineConfig {
            reduced,
            rank: num("rank")?,
            replicas: opt_num("replicas")?,
            anchor_rows: opt_num("anchor_rows")?,
            block,
            corner: opt_num("corner")?,
            als_iters: num("als_iters")?,
            als_tol: v
                .get("als_tol")
                .and_then(|x| x.as_f64())
                .context("config missing als_tol")?,
            backend,
            threads: num("threads")?.max(1),
            mixed_precision: v
                .get("mixed_precision")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            sensing,
            memory_budget: num("memory_budget")?,
            prefetch_depth: opt_num("prefetch_depth")?,
            io_threads: num("io_threads")?.max(1),
            refine_sweeps: num("refine_sweeps")?,
            checkpoint_dir: v
                .get("checkpoint_dir")
                .and_then(|x| x.as_str())
                .map(std::path::PathBuf::from),
            // Absent in pre-tier job records: default Auto.
            map_tier: match v.get("map_tier").and_then(|x| x.as_str()) {
                Some(s) => MapTierChoice::parse(s)?,
                None => MapTierChoice::Auto,
            },
            // Absent in pre-iterative job records: default Auto / 256.
            recovery_solver: match v.get("recovery_solver").and_then(|x| x.as_str()) {
                Some(s) => RecoverySolver::parse(s)?,
                None => RecoverySolver::Auto,
            },
            recovery_panel_cols: match v.get("recovery_panel_cols") {
                None | Some(Json::Null) => DEFAULT_RECOVERY_PANEL_COLS,
                Some(x) => x.as_usize().context("config bad recovery_panel_cols")?,
            },
            seed: num("seed")? as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Builder for [`PipelineConfig`].
#[derive(Clone, Debug)]
pub struct PipelineConfigBuilder {
    cfg: PipelineConfig,
}

impl Default for PipelineConfigBuilder {
    fn default() -> Self {
        Self {
            cfg: PipelineConfig {
                reduced: [50, 50, 50],
                rank: 5,
                replicas: None,
                anchor_rows: None,
                block: None,
                corner: None,
                als_iters: 60,
                als_tol: 1e-9,
                backend: Backend::RustParallel,
                threads: crate::util::default_threads(),
                mixed_precision: false,
                sensing: None,
                memory_budget: 0,
                prefetch_depth: None,
                io_threads: 2,
                refine_sweeps: 1,
                checkpoint_dir: None,
                map_tier: MapTierChoice::Auto,
                recovery_solver: RecoverySolver::Auto,
                recovery_panel_cols: DEFAULT_RECOVERY_PANEL_COLS,
                seed: 0,
            },
        }
    }
}

impl PipelineConfigBuilder {
    pub fn reduced_dims(mut self, l: usize, m: usize, n: usize) -> Self {
        self.cfg.reduced = [l, m, n];
        self
    }

    pub fn rank(mut self, r: usize) -> Self {
        self.cfg.rank = r;
        self
    }

    pub fn replicas(mut self, p: usize) -> Self {
        self.cfg.replicas = Some(p);
        self
    }

    pub fn anchor_rows(mut self, s: usize) -> Self {
        self.cfg.anchor_rows = Some(s);
        self
    }

    pub fn block(mut self, d: [usize; 3]) -> Self {
        self.cfg.block = Some(d);
        self
    }

    pub fn corner(mut self, b: usize) -> Self {
        self.cfg.corner = Some(b);
        self
    }

    pub fn als(mut self, iters: usize, tol: f64) -> Self {
        self.cfg.als_iters = iters;
        self.cfg.als_tol = tol;
        self
    }

    pub fn backend(mut self, b: Backend) -> Self {
        self.cfg.backend = b;
        self
    }

    pub fn threads(mut self, t: usize) -> Self {
        self.cfg.threads = t.max(1);
        self
    }

    pub fn mixed_precision(mut self, on: bool) -> Self {
        self.cfg.mixed_precision = on;
        self
    }

    pub fn sensing(mut self, s: SensingConfig) -> Self {
        self.cfg.sensing = Some(s);
        self
    }

    pub fn memory_budget(mut self, bytes: usize) -> Self {
        self.cfg.memory_budget = bytes;
        self
    }

    /// Forces the prefetch queue depth (`0` disables prefetching).
    pub fn prefetch_depth(mut self, depth: usize) -> Self {
        self.cfg.prefetch_depth = Some(depth);
        self
    }

    pub fn io_threads(mut self, n: usize) -> Self {
        self.cfg.io_threads = n.max(1);
        self
    }

    pub fn refine_sweeps(mut self, n: usize) -> Self {
        self.cfg.refine_sweeps = n;
        self
    }

    pub fn checkpoint_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.cfg.checkpoint_dir = Some(dir.into());
        self
    }

    /// Replica-map storage tier (`Auto` lets the planner pick).
    pub fn map_tier(mut self, tier: MapTierChoice) -> Self {
        self.cfg.map_tier = tier;
        self
    }

    /// Stacked-recovery solver (`Auto` lets the planner pick).
    pub fn recovery_solver(mut self, s: RecoverySolver) -> Self {
        self.cfg.recovery_solver = s;
        self
    }

    /// Streamed-recovery map-panel width (columns).
    pub fn recovery_panel_cols(mut self, w: usize) -> Self {
        self.cfg.recovery_panel_cols = w;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }

    pub fn build(self) -> Result<PipelineConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_builder_is_valid() {
        let cfg = PipelineConfig::builder().build().unwrap();
        assert_eq!(cfg.reduced, [50, 50, 50]);
        assert_eq!(cfg.effective_anchor(), 7);
    }

    #[test]
    fn rejects_rank_zero() {
        assert!(PipelineConfig::builder().rank(0).build().is_err());
    }

    #[test]
    fn rejects_reduced_below_rank() {
        assert!(PipelineConfig::builder()
            .rank(5)
            .reduced_dims(4, 10, 10)
            .build()
            .is_err());
        // reduced == rank is allowed (treated as an uncompressed mode when
        // it equals the tensor dim; the planner rejects it otherwise).
        assert!(PipelineConfig::builder()
            .rank(5)
            .reduced_dims(5, 10, 10)
            .anchor_rows(5)
            .build()
            .is_ok());
    }

    #[test]
    fn rejects_small_anchor() {
        assert!(PipelineConfig::builder()
            .rank(5)
            .anchor_rows(3)
            .build()
            .is_err());
    }

    #[test]
    fn rejects_anchor_exceeding_reduced() {
        assert!(PipelineConfig::builder()
            .rank(2)
            .reduced_dims(6, 6, 6)
            .anchor_rows(7)
            .build()
            .is_err());
    }

    #[test]
    fn rejects_bad_sensing() {
        assert!(PipelineConfig::builder()
            .sensing(SensingConfig {
                alpha: 0.5,
                ..Default::default()
            })
            .build()
            .is_err());
    }

    #[test]
    fn streaming_knobs_apply() {
        let cfg = PipelineConfig::builder()
            .prefetch_depth(8)
            .io_threads(0)
            .memory_budget(1 << 20)
            .build()
            .unwrap();
        assert_eq!(cfg.prefetch_depth, Some(8));
        assert_eq!(cfg.io_threads, 1, "clamped");
        assert_eq!(cfg.memory_budget, 1 << 20);
        let auto = PipelineConfig::builder().build().unwrap();
        assert_eq!(auto.prefetch_depth, None);
        assert_eq!(auto.io_threads, 2);
    }

    #[test]
    fn json_round_trip_preserves_every_knob() {
        let cfg = PipelineConfig::builder()
            .reduced_dims(20, 21, 22)
            .rank(3)
            .replicas(9)
            .anchor_rows(5)
            .block([100, 90, 80])
            .corner(15)
            .als(120, 1e-10)
            .backend(Backend::RustSequential)
            .threads(3)
            .mixed_precision(true)
            .memory_budget(1 << 24)
            .prefetch_depth(0)
            .io_threads(4)
            .refine_sweeps(2)
            .checkpoint_dir("/tmp/ckpt")
            .map_tier(MapTierChoice::Procedural)
            .recovery_solver(RecoverySolver::Iterative)
            .recovery_panel_cols(128)
            .seed(424242)
            .build()
            .unwrap();
        let text = cfg.to_json().to_string_pretty();
        let back = PipelineConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.map_tier, MapTierChoice::Procedural);
        assert_eq!(back.recovery_solver, RecoverySolver::Iterative);
        assert_eq!(back.recovery_panel_cols, 128);
        assert_eq!(back.reduced, cfg.reduced);
        assert_eq!(back.rank, cfg.rank);
        assert_eq!(back.replicas, cfg.replicas);
        assert_eq!(back.anchor_rows, cfg.anchor_rows);
        assert_eq!(back.block, cfg.block);
        assert_eq!(back.corner, cfg.corner);
        assert_eq!(back.als_iters, cfg.als_iters);
        assert_eq!(back.als_tol, cfg.als_tol);
        assert_eq!(back.backend, cfg.backend);
        assert_eq!(back.threads, cfg.threads);
        assert_eq!(back.mixed_precision, cfg.mixed_precision);
        assert_eq!(back.memory_budget, cfg.memory_budget);
        assert_eq!(back.prefetch_depth, Some(0), "Some(0) ≠ None must survive");
        assert_eq!(back.io_threads, cfg.io_threads);
        assert_eq!(back.refine_sweeps, cfg.refine_sweeps);
        assert_eq!(back.checkpoint_dir, cfg.checkpoint_dir);
        assert_eq!(back.seed, cfg.seed);

        // None-valued options round-trip as None (not 0).
        let auto = PipelineConfig::builder().build().unwrap();
        let back = PipelineConfig::from_json(&auto.to_json()).unwrap();
        assert_eq!(back.prefetch_depth, None);
        assert_eq!(back.replicas, None);
        assert_eq!(back.block, None);
        assert!(back.sensing.is_none());
        assert_eq!(back.map_tier, MapTierChoice::Auto);
        assert_eq!(back.recovery_solver, RecoverySolver::Auto);
        assert_eq!(back.recovery_panel_cols, DEFAULT_RECOVERY_PANEL_COLS);

        // Pre-tier / pre-iterative job records (keys absent) default to
        // Auto / Auto / 256.
        let mut legacy = auto.to_json();
        if let Json::Obj(m) = &mut legacy {
            m.remove("map_tier");
            m.remove("recovery_solver");
            m.remove("recovery_panel_cols");
        }
        let back = PipelineConfig::from_json(&legacy).unwrap();
        assert_eq!(back.map_tier, MapTierChoice::Auto);
        assert_eq!(back.recovery_solver, RecoverySolver::Auto);
        assert_eq!(back.recovery_panel_cols, DEFAULT_RECOVERY_PANEL_COLS);
        // Bad spellings are rejected.
        let mut bad_tier = auto.to_json();
        if let Json::Obj(m) = &mut bad_tier {
            m.insert("map_tier".into(), Json::str("dense"));
        }
        assert!(PipelineConfig::from_json(&bad_tier).is_err());
        let mut bad_solver = auto.to_json();
        if let Json::Obj(m) = &mut bad_solver {
            m.insert("recovery_solver".into(), Json::str("gmres"));
        }
        assert!(PipelineConfig::from_json(&bad_solver).is_err());

        // Sensing block round-trips.
        let sens = PipelineConfig::builder()
            .sensing(SensingConfig::default())
            .build()
            .unwrap();
        let back = PipelineConfig::from_json(&sens.to_json()).unwrap();
        let sc = back.sensing.unwrap();
        assert!((sc.alpha - 2.2).abs() < 1e-6);
        assert_eq!(sc.nnz_per_col, 8);

        // Invalid configs are rejected on the way in.
        let mut bad = cfg.to_json();
        if let Json::Obj(m) = &mut bad {
            m.insert("rank".into(), Json::num(0.0));
        }
        assert!(PipelineConfig::from_json(&bad).is_err());
    }

    #[test]
    fn recovery_solver_parses_all_spellings() {
        for (s, want) in [
            ("auto", RecoverySolver::Auto),
            ("cholesky", RecoverySolver::Cholesky),
            ("chol", RecoverySolver::Cholesky),
            ("iterative", RecoverySolver::Iterative),
            ("iter", RecoverySolver::Iterative),
            ("cg", RecoverySolver::Iterative),
            ("sketch", RecoverySolver::Sketch),
        ] {
            assert_eq!(RecoverySolver::parse(s).unwrap(), want);
        }
        assert!(RecoverySolver::parse("gmres").is_err());
        for kind in [
            RecoverySolverKind::Cholesky,
            RecoverySolverKind::Iterative,
            RecoverySolverKind::Sketch,
        ] {
            assert_eq!(RecoverySolverKind::parse(kind.as_str()).unwrap(), kind);
        }
    }

    #[test]
    fn rejects_zero_panel_cols() {
        assert!(PipelineConfig::builder().recovery_panel_cols(0).build().is_err());
    }

    #[test]
    fn builder_setters_apply() {
        let cfg = PipelineConfig::builder()
            .reduced_dims(20, 21, 22)
            .rank(3)
            .replicas(9)
            .block([100, 100, 100])
            .threads(0)
            .seed(42)
            .build()
            .unwrap();
        assert_eq!(cfg.reduced, [20, 21, 22]);
        assert_eq!(cfg.replicas, Some(9));
        assert_eq!(cfg.threads, 1); // clamped
        assert_eq!(cfg.seed, 42);
    }
}
