//! Run checkpointing: persist pipeline state during and after the
//! expensive stages so an interrupted run resumes without recompressing.
//!
//! The compression stage dominates wall-clock (`P` passes over a huge
//! tensor); a crash afterwards should not force a redo.  A checkpoint
//! directory holds a JSON header (config fingerprint, dims, seed, replica
//! count, stage) plus the proxy tensors in the crate's EXT1 binary format.
//! The maps themselves are *not* stored: they are regenerated
//! deterministically from the seed, which the header fingerprints — zero
//! map bytes on disk in either map tier.  The fingerprint deliberately
//! excludes the map tier: both tiers synthesize bitwise-identical maps
//! from the seed, so a checkpoint written under one tier resumes under
//! the other (asserted in `tests/map_tiers.rs`).
//!
//! Two checkpoint kinds coexist in one directory:
//!
//! * **Final** (`checkpoint.json` + `proxy_*.ext1`) — the fully compressed
//!   proxies, written once after Stage 1 completes (the pre-existing
//!   behavior).
//! * **Incremental** (`partial.json` + `partial_<gen>_proxy_*.ext1`) — the
//!   streaming engine's folded shard prefix, written every few shards
//!   mid-compression.  The header records the block-grid partition
//!   (block dims, shard parts, total blocks) plus a shard-progress bitmap,
//!   so a killed run resumes from the folded prefix instead of restarting
//!   Stage 1 from zero — and, because the engine's reduction order is
//!   fixed, the resumed result is bitwise identical to an uninterrupted
//!   run.  Writes are generation-numbered and committed by an atomic
//!   rename of `partial.json`, so a kill mid-write leaves the previous
//!   complete generation in force.

use crate::tensor::io::{load_tensor, save_tensor};
use crate::tensor::DenseTensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Checkpoint format version.  Bumped to **2** when the replica-map
/// generator changed from sequential xoshiro streams to the counter-based
/// hash (PR 5): the fingerprint fields are identical across that change,
/// but version-1 proxies were folded from differently-valued maps, so
/// resuming them against regenerated maps would be silently corrupt —
/// the version gate turns that into a loud "recompress" error instead.
const CHECKPOINT_VERSION: usize = 2;

/// Identifies a compression run; resuming requires an exact match.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint {
    pub dims: [usize; 3],
    pub reduced: [usize; 3],
    pub rank: usize,
    pub replicas: usize,
    pub anchor_rows: usize,
    pub seed: u64,
    pub mixed_precision: bool,
}

impl Fingerprint {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dims", Json::arr_usize(&self.dims)),
            ("reduced", Json::arr_usize(&self.reduced)),
            ("rank", Json::num(self.rank as f64)),
            ("replicas", Json::num(self.replicas as f64)),
            ("anchor_rows", Json::num(self.anchor_rows as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("mixed_precision", Json::Bool(self.mixed_precision)),
        ])
    }

    fn from_json(v: &Json) -> Result<Fingerprint> {
        let arr3 = |key: &str| -> Result<[usize; 3]> {
            let a = v
                .get(key)
                .and_then(|x| x.as_arr())
                .with_context(|| format!("checkpoint missing {key}"))?;
            if a.len() != 3 {
                bail!("checkpoint {key}: expected 3 dims");
            }
            Ok([
                a[0].as_usize().context("dim")?,
                a[1].as_usize().context("dim")?,
                a[2].as_usize().context("dim")?,
            ])
        };
        let num = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("checkpoint missing {key}"))
        };
        Ok(Fingerprint {
            dims: arr3("dims")?,
            reduced: arr3("reduced")?,
            rank: num("rank")?,
            replicas: num("replicas")?,
            anchor_rows: num("anchor_rows")?,
            seed: num("seed")? as u64,
            mixed_precision: v
                .get("mixed_precision")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
        })
    }
}

/// Writes a post-compression checkpoint: header + one EXT1 file per proxy.
pub fn save_proxies(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
    proxies: &[DenseTensor],
) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    for (p, y) in proxies.iter().enumerate() {
        save_tensor(y, dir.join(format!("proxy_{p:04}.ext1")))?;
    }
    let header = Json::obj(vec![
        ("version", Json::num(CHECKPOINT_VERSION as f64)),
        ("stage", Json::str("compressed")),
        ("fingerprint", fp.to_json()),
        ("proxy_count", Json::num(proxies.len() as f64)),
    ]);
    std::fs::write(dir.join("checkpoint.json"), header.to_string_pretty())?;
    Ok(())
}

/// Loads a checkpoint if it exists and matches `fp`; `Ok(None)` when absent,
/// `Err` on mismatch (resuming with different parameters would silently
/// corrupt results — fail loudly instead).
pub fn load_proxies(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
) -> Result<Option<Vec<DenseTensor>>> {
    let dir = dir.as_ref();
    let header_path = dir.join("checkpoint.json");
    if !header_path.exists() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&header_path)?;
    let v = Json::parse(&text).context("checkpoint.json parse")?;
    if v.get("version").and_then(|x| x.as_usize()) != Some(CHECKPOINT_VERSION) {
        bail!("unsupported checkpoint version");
    }
    let stored = Fingerprint::from_json(v.get("fingerprint").context("missing fingerprint")?)?;
    if &stored != fp {
        bail!(
            "checkpoint at {} was created with different parameters \
             (stored {stored:?}, requested {fp:?}); delete it to recompress",
            dir.display()
        );
    }
    let count = v
        .get("proxy_count")
        .and_then(|x| x.as_usize())
        .context("missing proxy_count")?;
    if count != fp.replicas {
        bail!(
            "checkpoint holds {count} proxies but the run expects {} replicas",
            fp.replicas
        );
    }
    let mut proxies = Vec::with_capacity(count);
    for p in 0..count {
        let path = dir.join(format!("proxy_{p:04}.ext1"));
        let t = load_tensor(&path).with_context(|| format!("loading {}", path.display()))?;
        if t.dims() != fp.reduced {
            bail!(
                "{}: proxy dims {:?} do not match reduced dims {:?}",
                path.display(),
                t.dims(),
                fp.reduced
            );
        }
        proxies.push(t);
    }
    Ok(Some(proxies))
}

/// The streaming position an incremental checkpoint captures, plus the
/// block-grid partition it is only valid for (resuming under a different
/// partition would fold blocks twice or skip them).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompressionProgress {
    /// Block dims the grid was built with.
    pub block: [usize; 3],
    /// Shard partition granularity (`StreamOptions::shard_parts`).
    pub shard_parts: usize,
    /// Total shards in the partition.
    pub shards_total: usize,
    /// Folded prefix: shards `0..shards_done` are in the partial proxies.
    pub shards_done: usize,
    /// Blocks covered by the folded prefix.
    pub blocks_done: usize,
    /// Total blocks in the grid.
    pub blocks_total: usize,
    /// Which compression path produced the partials (`"plain"`,
    /// `"batched"`) — paths differ in GEMM association, so partials are
    /// only resumable by the same path.
    pub path: String,
    /// Monotone write generation (for atomic replacement).
    pub generation: u64,
}

impl CompressionProgress {
    fn to_json(&self, bitmap_hex: &str) -> Json {
        Json::obj(vec![
            ("block", Json::arr_usize(&self.block)),
            ("shard_parts", Json::num(self.shard_parts as f64)),
            ("shards_total", Json::num(self.shards_total as f64)),
            ("shards_done", Json::num(self.shards_done as f64)),
            ("blocks_done", Json::num(self.blocks_done as f64)),
            ("blocks_total", Json::num(self.blocks_total as f64)),
            ("path", Json::str(self.path.clone())),
            ("generation", Json::num(self.generation as f64)),
            ("shard_bitmap", Json::str(bitmap_hex)),
        ])
    }

    fn from_json(v: &Json) -> Result<(CompressionProgress, String)> {
        let num = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("partial checkpoint missing {key}"))
        };
        let block = {
            let a = v
                .get("block")
                .and_then(|x| x.as_arr())
                .context("partial checkpoint missing block")?;
            if a.len() != 3 {
                bail!("partial checkpoint block: expected 3 dims");
            }
            [
                a[0].as_usize().context("block dim")?,
                a[1].as_usize().context("block dim")?,
                a[2].as_usize().context("block dim")?,
            ]
        };
        let bitmap = v
            .get("shard_bitmap")
            .and_then(|x| x.as_str())
            .context("partial checkpoint missing shard_bitmap")?
            .to_string();
        Ok((
            CompressionProgress {
                block,
                shard_parts: num("shard_parts")?,
                shards_total: num("shards_total")?,
                shards_done: num("shards_done")?,
                blocks_done: num("blocks_done")?,
                blocks_total: num("blocks_total")?,
                path: v
                    .get("path")
                    .and_then(|x| x.as_str())
                    .context("partial checkpoint missing path")?
                    .to_string(),
                generation: num("generation")? as u64,
            },
            bitmap,
        ))
    }
}

/// Little-endian-bit hex bitmap with bits `0..done` set out of `total` —
/// the block-grid progress record.  The current writer always persists a
/// prefix (the engine folds shards in order), but the format carries the
/// full bitmap so readers verify integrity rather than trusting a counter.
fn prefix_bitmap_hex(done: usize, total: usize) -> String {
    let nbytes = total.div_ceil(8).max(1);
    let mut bytes = vec![0u8; nbytes];
    for s in 0..done {
        bytes[s / 8] |= 1 << (s % 8);
    }
    let mut out = String::with_capacity(nbytes * 2);
    for b in bytes {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Parses a bitmap written by [`prefix_bitmap_hex`] and verifies it is the
/// prefix `0..done` of `total` shards.  Decodes byte-wise (never slicing
/// the untrusted string) so corrupt multi-byte content errors instead of
/// panicking mid-character.
fn check_prefix_bitmap(hex: &str, done: usize, total: usize) -> Result<()> {
    let nbytes = total.div_ceil(8).max(1);
    let raw = hex.as_bytes();
    if raw.len() != nbytes * 2 {
        bail!("shard bitmap length {} != {}", raw.len(), nbytes * 2);
    }
    let nibble = |b: u8| -> Result<u8> {
        match b {
            b'0'..=b'9' => Ok(b - b'0'),
            b'a'..=b'f' => Ok(b - b'a' + 10),
            b'A'..=b'F' => Ok(b - b'A' + 10),
            _ => bail!("shard bitmap is not hex (byte {b:#04x})"),
        }
    };
    let mut bytes = Vec::with_capacity(nbytes);
    for i in 0..nbytes {
        bytes.push((nibble(raw[2 * i])? << 4) | nibble(raw[2 * i + 1])?);
    }
    for s in 0..total {
        let set = bytes[s / 8] & (1 << (s % 8)) != 0;
        if set != (s < done) {
            bail!("shard bitmap is not the expected prefix of {done}/{total} (bit {s} = {set})");
        }
    }
    Ok(())
}

fn partial_proxy_name(generation: u64, p: usize) -> String {
    format!("partial_{generation:08}_proxy_{p:04}.ext1")
}

/// Writes an incremental (mid-compression) checkpoint: the folded-prefix
/// proxies under a fresh generation, then the `partial.json` header via an
/// atomic rename, then garbage-collects older generations.  A kill at any
/// point leaves a complete previous generation (or no partial at all).
pub fn save_partial(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
    progress: &CompressionProgress,
    proxies: &[DenseTensor],
) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let g = progress.generation;
    for (p, y) in proxies.iter().enumerate() {
        save_tensor(y, dir.join(partial_proxy_name(g, p)))?;
    }
    let header = Json::obj(vec![
        ("version", Json::num(CHECKPOINT_VERSION as f64)),
        ("stage", Json::str("compressing")),
        ("fingerprint", fp.to_json()),
        ("proxy_count", Json::num(proxies.len() as f64)),
        (
            "progress",
            progress.to_json(&prefix_bitmap_hex(progress.shards_done, progress.shards_total)),
        ),
    ]);
    let tmp = dir.join("partial.json.tmp");
    std::fs::write(&tmp, header.to_string_pretty())?;
    std::fs::rename(&tmp, dir.join("partial.json")).context("committing partial.json")?;
    // GC superseded generations (best-effort).
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if name.starts_with("partial_")
                && name.ends_with(".ext1")
                && !name.starts_with(&format!("partial_{g:08}_"))
            {
                std::fs::remove_file(e.path()).ok();
            }
        }
    }
    Ok(())
}

/// Loads an incremental checkpoint if present.  `Ok(None)` when absent;
/// `Err` when one exists but was written under a different fingerprint or
/// block-grid partition (resuming it would corrupt results — fail loudly,
/// mirroring [`load_proxies`]).  `expected` carries the partition of the
/// *current* run (its `shards_done`/`blocks_done`/`generation` are
/// ignored).
pub fn load_partial(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
    expected: &CompressionProgress,
) -> Result<Option<(CompressionProgress, Vec<DenseTensor>)>> {
    let dir = dir.as_ref();
    let header_path = dir.join("partial.json");
    if !header_path.exists() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&header_path)?;
    let v = Json::parse(&text).context("partial.json parse")?;
    if v.get("version").and_then(|x| x.as_usize()) != Some(CHECKPOINT_VERSION) {
        bail!("unsupported partial checkpoint version");
    }
    let stored_fp =
        Fingerprint::from_json(v.get("fingerprint").context("missing fingerprint")?)?;
    if &stored_fp != fp {
        bail!(
            "partial checkpoint at {} was created with different parameters \
             (stored {stored_fp:?}, requested {fp:?}); delete it to recompress",
            dir.display()
        );
    }
    let (progress, bitmap) =
        CompressionProgress::from_json(v.get("progress").context("missing progress")?)?;
    if progress.block != expected.block
        || progress.shard_parts != expected.shard_parts
        || progress.shards_total != expected.shards_total
        || progress.blocks_total != expected.blocks_total
        || progress.path != expected.path
    {
        bail!(
            "partial checkpoint at {} used a different block-grid partition or path \
             (stored {progress:?}, current {expected:?}); delete it to recompress",
            dir.display()
        );
    }
    // Progress bounds: a tampered/corrupt header must fail loudly here,
    // not panic later in the engine's resume assertions.
    if progress.shards_done > progress.shards_total {
        bail!(
            "partial checkpoint claims {} of {} shards done",
            progress.shards_done,
            progress.shards_total
        );
    }
    let parts =
        crate::util::threadpool::ThreadPool::partition(progress.blocks_total, progress.shard_parts);
    if parts.len() != progress.shards_total {
        bail!(
            "partial checkpoint shard partition is inconsistent ({} parts for {} declared)",
            parts.len(),
            progress.shards_total
        );
    }
    let prefix_blocks: usize = parts[..progress.shards_done].iter().map(|(a, b)| b - a).sum();
    if prefix_blocks != progress.blocks_done {
        bail!(
            "partial checkpoint blocks_done {} does not match its {}-shard prefix ({prefix_blocks})",
            progress.blocks_done,
            progress.shards_done
        );
    }
    check_prefix_bitmap(&bitmap, progress.shards_done, progress.shards_total)?;
    let count = v
        .get("proxy_count")
        .and_then(|x| x.as_usize())
        .context("missing proxy_count")?;
    // A truncated/corrupt partial must fail loudly here: resuming with the
    // wrong accumulator count would silently drop replicas in the merge.
    if count != fp.replicas {
        bail!(
            "partial checkpoint holds {count} proxies but the run expects {} replicas",
            fp.replicas
        );
    }
    let mut proxies = Vec::with_capacity(count);
    for p in 0..count {
        let path = dir.join(partial_proxy_name(progress.generation, p));
        let t = load_tensor(&path).with_context(|| format!("loading {}", path.display()))?;
        if t.dims() != fp.reduced {
            bail!(
                "{}: partial proxy dims {:?} do not match reduced dims {:?}",
                path.display(),
                t.dims(),
                fp.reduced
            );
        }
        proxies.push(t);
    }
    Ok(Some((progress, proxies)))
}

/// Removes only the incremental checkpoint (after the final one lands).
pub fn clear_partial(dir: impl AsRef<Path>) -> Result<()> {
    let dir = dir.as_ref();
    if !dir.exists() {
        return Ok(());
    }
    std::fs::remove_file(dir.join("partial.json")).ok();
    for e in std::fs::read_dir(dir)?.flatten() {
        let name = e.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("partial_") && name.ends_with(".ext1") {
            std::fs::remove_file(e.path()).ok();
        }
    }
    Ok(())
}

/// True when `dir` holds a committed incremental (mid-compression)
/// checkpoint — the `serve/` scheduler uses this to report which recovered
/// jobs will resume in-flight work rather than restart Stage 1.
pub fn partial_exists(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("partial.json").exists()
}

/// Removes a checkpoint directory (after a successful run).
pub fn clear(dir: impl AsRef<Path>) -> Result<()> {
    let dir = dir.as_ref();
    if dir.exists() {
        std::fs::remove_dir_all(dir)?;
    }
    Ok(())
}

#[doc(hidden)]
pub fn default_fingerprint(
    cfg: &super::config::PipelineConfig,
    dims: [usize; 3],
    replicas: usize,
) -> Fingerprint {
    Fingerprint {
        dims,
        reduced: cfg.reduced,
        rank: cfg.rank,
        replicas,
        anchor_rows: cfg.effective_anchor(),
        seed: cfg.seed,
        mixed_precision: cfg.mixed_precision,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;
    use std::path::PathBuf;

    fn fp() -> Fingerprint {
        Fingerprint {
            dims: [40, 40, 40],
            reduced: [10, 10, 10],
            rank: 3,
            replicas: 2,
            anchor_rows: 5,
            seed: 7,
            mixed_precision: false,
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_ckpt_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn round_trip() {
        let dir = tmpdir("rt");
        let mut rng = Xoshiro256::seed_from_u64(1);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_proxies(&dir, &fp(), &proxies).unwrap();
        let loaded = load_proxies(&dir, &fp()).unwrap().expect("checkpoint");
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0], proxies[0]);
        assert_eq!(loaded[1], proxies[1]);
        clear(&dir).unwrap();
        assert!(load_proxies(&dir, &fp()).unwrap().is_none());
    }

    #[test]
    fn mismatched_fingerprint_rejected() {
        let dir = tmpdir("mismatch");
        let mut rng = Xoshiro256::seed_from_u64(2);
        let proxies = vec![DenseTensor::random_normal([10, 10, 10], &mut rng)];
        let mut fp1 = fp();
        fp1.replicas = 1;
        save_proxies(&dir, &fp1, &proxies).unwrap();
        let mut other = fp1.clone();
        other.seed = 99;
        assert!(load_proxies(&dir, &other).is_err());
        clear(&dir).unwrap();
    }

    #[test]
    fn absent_checkpoint_is_none() {
        assert!(load_proxies("/nonexistent/ckpt", &fp()).unwrap().is_none());
    }

    fn progress(shards_done: usize, generation: u64) -> CompressionProgress {
        // Self-consistent partition: 120 blocks over 10 shards of 12.
        CompressionProgress {
            block: [8, 8, 8],
            shard_parts: 10,
            shards_total: 10,
            shards_done,
            blocks_done: shards_done * 12,
            blocks_total: 120,
            path: "batched".to_string(),
            generation,
        }
    }

    #[test]
    fn partial_progress_bounds_validated() {
        let dir = tmpdir("partial_bounds");
        let mut rng = Xoshiro256::seed_from_u64(8);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        // blocks_done inconsistent with the shard prefix → loud failure.
        let mut pr = progress(3, 0);
        pr.blocks_done = 35;
        save_partial(&dir, &fp(), &pr, &proxies).unwrap();
        assert!(load_partial(&dir, &fp(), &progress(0, 0)).is_err());
        clear(&dir).unwrap();
        // shards_done beyond shards_total → loud failure, not a panic.
        let mut pr = progress(10, 0);
        pr.shards_done = 12;
        pr.blocks_done = 144;
        save_partial(&dir, &fp(), &pr, &proxies).unwrap();
        assert!(load_partial(&dir, &fp(), &progress(0, 0)).is_err());
        clear(&dir).unwrap();
    }

    #[test]
    fn partial_round_trip_and_gc() {
        let dir = tmpdir("partial_rt");
        let mut rng = Xoshiro256::seed_from_u64(3);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_partial(&dir, &fp(), &progress(3, 0), &proxies).unwrap();
        let newer = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_partial(&dir, &fp(), &progress(6, 1), &newer).unwrap();
        let (pr, loaded) = load_partial(&dir, &fp(), &progress(0, 0)).unwrap().unwrap();
        assert_eq!(pr.shards_done, 6);
        assert_eq!(pr.blocks_done, 72);
        assert_eq!(loaded, newer, "latest generation wins");
        // Generation-0 files were garbage-collected.
        assert!(!dir.join(super::partial_proxy_name(0, 0)).exists());
        clear_partial(&dir).unwrap();
        assert!(load_partial(&dir, &fp(), &progress(0, 0)).unwrap().is_none());
        clear(&dir).unwrap();
    }

    #[test]
    fn partial_partition_mismatch_rejected() {
        let dir = tmpdir("partial_mismatch");
        let mut rng = Xoshiro256::seed_from_u64(4);
        let proxies = vec![DenseTensor::random_normal([10, 10, 10], &mut rng)];
        save_partial(&dir, &fp(), &progress(2, 0), &proxies).unwrap();
        let mut other_block = progress(0, 0);
        other_block.block = [4, 4, 4];
        assert!(load_partial(&dir, &fp(), &other_block).is_err());
        let mut other_path = progress(0, 0);
        other_path.path = "plain".to_string();
        assert!(load_partial(&dir, &fp(), &other_path).is_err());
        let mut other_fp = fp();
        other_fp.seed = 123;
        assert!(load_partial(&dir, &other_fp, &progress(0, 0)).is_err());
        clear(&dir).unwrap();
    }

    #[test]
    fn partial_absent_is_none_and_final_untouched() {
        let dir = tmpdir("partial_absent");
        let mut rng = Xoshiro256::seed_from_u64(5);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        // A final checkpoint alone yields no partial.
        save_proxies(&dir, &fp(), &proxies).unwrap();
        assert!(load_partial(&dir, &fp(), &progress(0, 0)).unwrap().is_none());
        // clear_partial must not disturb the final checkpoint.
        clear_partial(&dir).unwrap();
        assert!(load_proxies(&dir, &fp()).unwrap().is_some());
        clear(&dir).unwrap();
    }

    #[test]
    fn proxy_count_and_dims_validated_on_load() {
        let dir = tmpdir("count_dims");
        let mut rng = Xoshiro256::seed_from_u64(6);
        // One proxy where the fingerprint promises two → loud failure.
        let short = vec![DenseTensor::random_normal([10, 10, 10], &mut rng)];
        save_proxies(&dir, &fp(), &short).unwrap();
        assert!(load_proxies(&dir, &fp()).is_err());
        clear(&dir).unwrap();
        save_partial(&dir, &fp(), &progress(2, 0), &short).unwrap();
        assert!(load_partial(&dir, &fp(), &progress(0, 0)).is_err());
        clear(&dir).unwrap();
        // Right count, wrong dims → loud failure.
        let wrong_dims = vec![
            DenseTensor::random_normal([9, 10, 10], &mut rng),
            DenseTensor::random_normal([9, 10, 10], &mut rng),
        ];
        save_proxies(&dir, &fp(), &wrong_dims).unwrap();
        assert!(load_proxies(&dir, &fp()).is_err());
        clear(&dir).unwrap();
    }

    #[test]
    fn bitmap_prefix_integrity() {
        assert_eq!(super::prefix_bitmap_hex(0, 10), "0000");
        assert_eq!(super::prefix_bitmap_hex(3, 10), "0700");
        assert!(super::check_prefix_bitmap("0700", 3, 10).is_ok());
        assert!(super::check_prefix_bitmap("0f00", 3, 10).is_err(), "extra bit");
        assert!(super::check_prefix_bitmap("0300", 3, 10).is_err(), "missing bit");
        assert!(super::check_prefix_bitmap("07", 3, 10).is_err(), "short");
        assert!(super::check_prefix_bitmap("zz00", 3, 10).is_err(), "not hex");
        // Multi-byte UTF-8 of the right *byte* length must error, not panic.
        assert!(super::check_prefix_bitmap("aé0", 3, 10).is_err(), "non-ascii");
    }

    #[test]
    fn corrupt_header_rejected() {
        let dir = tmpdir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("checkpoint.json"), "{not json").unwrap();
        assert!(load_proxies(&dir, &fp()).is_err());
        clear(&dir).unwrap();
    }
}
