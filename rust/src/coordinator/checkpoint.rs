//! Run checkpointing: persist pipeline state after the expensive stages so
//! an interrupted run resumes without recompressing.
//!
//! The compression stage dominates wall-clock (`P` passes over a huge
//! tensor); a crash afterwards should not force a redo.  A checkpoint
//! directory holds a JSON header (config fingerprint, dims, seed, replica
//! count, stage) plus the proxy tensors in the crate's EXT1 binary format.
//! The maps themselves are *not* stored: they are regenerated
//! deterministically from the seed, which the header fingerprints.

use crate::tensor::io::{load_tensor, save_tensor};
use crate::tensor::DenseTensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Identifies a compression run; resuming requires an exact match.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint {
    pub dims: [usize; 3],
    pub reduced: [usize; 3],
    pub rank: usize,
    pub replicas: usize,
    pub anchor_rows: usize,
    pub seed: u64,
    pub mixed_precision: bool,
}

impl Fingerprint {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dims", Json::arr_usize(&self.dims)),
            ("reduced", Json::arr_usize(&self.reduced)),
            ("rank", Json::num(self.rank as f64)),
            ("replicas", Json::num(self.replicas as f64)),
            ("anchor_rows", Json::num(self.anchor_rows as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("mixed_precision", Json::Bool(self.mixed_precision)),
        ])
    }

    fn from_json(v: &Json) -> Result<Fingerprint> {
        let arr3 = |key: &str| -> Result<[usize; 3]> {
            let a = v
                .get(key)
                .and_then(|x| x.as_arr())
                .with_context(|| format!("checkpoint missing {key}"))?;
            if a.len() != 3 {
                bail!("checkpoint {key}: expected 3 dims");
            }
            Ok([
                a[0].as_usize().context("dim")?,
                a[1].as_usize().context("dim")?,
                a[2].as_usize().context("dim")?,
            ])
        };
        let num = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("checkpoint missing {key}"))
        };
        Ok(Fingerprint {
            dims: arr3("dims")?,
            reduced: arr3("reduced")?,
            rank: num("rank")?,
            replicas: num("replicas")?,
            anchor_rows: num("anchor_rows")?,
            seed: num("seed")? as u64,
            mixed_precision: v
                .get("mixed_precision")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
        })
    }
}

/// Writes a post-compression checkpoint: header + one EXT1 file per proxy.
pub fn save_proxies(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
    proxies: &[DenseTensor],
) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    for (p, y) in proxies.iter().enumerate() {
        save_tensor(y, dir.join(format!("proxy_{p:04}.ext1")))?;
    }
    let header = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("stage", Json::str("compressed")),
        ("fingerprint", fp.to_json()),
        ("proxy_count", Json::num(proxies.len() as f64)),
    ]);
    std::fs::write(dir.join("checkpoint.json"), header.to_string_pretty())?;
    Ok(())
}

/// Loads a checkpoint if it exists and matches `fp`; `Ok(None)` when absent,
/// `Err` on mismatch (resuming with different parameters would silently
/// corrupt results — fail loudly instead).
pub fn load_proxies(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
) -> Result<Option<Vec<DenseTensor>>> {
    let dir = dir.as_ref();
    let header_path = dir.join("checkpoint.json");
    if !header_path.exists() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&header_path)?;
    let v = Json::parse(&text).context("checkpoint.json parse")?;
    if v.get("version").and_then(|x| x.as_usize()) != Some(1) {
        bail!("unsupported checkpoint version");
    }
    let stored = Fingerprint::from_json(v.get("fingerprint").context("missing fingerprint")?)?;
    if &stored != fp {
        bail!(
            "checkpoint at {} was created with different parameters \
             (stored {stored:?}, requested {fp:?}); delete it to recompress",
            dir.display()
        );
    }
    let count = v
        .get("proxy_count")
        .and_then(|x| x.as_usize())
        .context("missing proxy_count")?;
    let mut proxies = Vec::with_capacity(count);
    for p in 0..count {
        let path = dir.join(format!("proxy_{p:04}.ext1"));
        proxies.push(load_tensor(&path).with_context(|| format!("loading {}", path.display()))?);
    }
    Ok(Some(proxies))
}

/// Removes a checkpoint directory (after a successful run).
pub fn clear(dir: impl AsRef<Path>) -> Result<()> {
    let dir = dir.as_ref();
    if dir.exists() {
        std::fs::remove_dir_all(dir)?;
    }
    Ok(())
}

#[doc(hidden)]
pub fn default_fingerprint(
    cfg: &super::config::PipelineConfig,
    dims: [usize; 3],
    replicas: usize,
) -> Fingerprint {
    Fingerprint {
        dims,
        reduced: cfg.reduced,
        rank: cfg.rank,
        replicas,
        anchor_rows: cfg.effective_anchor(),
        seed: cfg.seed,
        mixed_precision: cfg.mixed_precision,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;
    use std::path::PathBuf;

    fn fp() -> Fingerprint {
        Fingerprint {
            dims: [40, 40, 40],
            reduced: [10, 10, 10],
            rank: 3,
            replicas: 2,
            anchor_rows: 5,
            seed: 7,
            mixed_precision: false,
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_ckpt_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn round_trip() {
        let dir = tmpdir("rt");
        let mut rng = Xoshiro256::seed_from_u64(1);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_proxies(&dir, &fp(), &proxies).unwrap();
        let loaded = load_proxies(&dir, &fp()).unwrap().expect("checkpoint");
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0], proxies[0]);
        assert_eq!(loaded[1], proxies[1]);
        clear(&dir).unwrap();
        assert!(load_proxies(&dir, &fp()).unwrap().is_none());
    }

    #[test]
    fn mismatched_fingerprint_rejected() {
        let dir = tmpdir("mismatch");
        let mut rng = Xoshiro256::seed_from_u64(2);
        let proxies = vec![DenseTensor::random_normal([10, 10, 10], &mut rng)];
        let mut fp1 = fp();
        fp1.replicas = 1;
        save_proxies(&dir, &fp1, &proxies).unwrap();
        let mut other = fp1.clone();
        other.seed = 99;
        assert!(load_proxies(&dir, &other).is_err());
        clear(&dir).unwrap();
    }

    #[test]
    fn absent_checkpoint_is_none() {
        assert!(load_proxies("/nonexistent/ckpt", &fp()).unwrap().is_none());
    }

    #[test]
    fn corrupt_header_rejected() {
        let dir = tmpdir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("checkpoint.json"), "{not json").unwrap();
        assert!(load_proxies(&dir, &fp()).is_err());
        clear(&dir).unwrap();
    }
}
