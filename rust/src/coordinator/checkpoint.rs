//! Run checkpointing: persist pipeline state during and after the
//! expensive stages so an interrupted run resumes without recompressing.
//!
//! The compression stage dominates wall-clock (`P` passes over a huge
//! tensor); a crash afterwards should not force a redo.  A checkpoint
//! directory holds a JSON header (config fingerprint, dims, seed, replica
//! count, stage) plus the proxy tensors in the crate's EXT1 binary format.
//! The maps themselves are *not* stored: they are regenerated
//! deterministically from the seed, which the header fingerprints — zero
//! map bytes on disk in either map tier.  The fingerprint deliberately
//! excludes the map tier: both tiers synthesize bitwise-identical maps
//! from the seed, so a checkpoint written under one tier resumes under
//! the other (asserted in `tests/map_tiers.rs`).
//!
//! Two checkpoint kinds coexist in one directory:
//!
//! * **Final** (`checkpoint.json` + `proxy_*.ext1`) — the fully compressed
//!   proxies, written once after Stage 1 completes (the pre-existing
//!   behavior).
//! * **Incremental** (`partial.json` + `partial_<gen>_proxy_*.ext1`) — the
//!   streaming engine's folded shard prefix, written every few shards
//!   mid-compression.  The header records the block-grid partition
//!   (block dims, shard parts, total blocks) plus a shard-progress bitmap,
//!   so a killed run resumes from the folded prefix instead of restarting
//!   Stage 1 from zero — and, because the engine's reduction order is
//!   fixed, the resumed result is bitwise identical to an uninterrupted
//!   run.  Writes are generation-numbered and committed by an atomic
//!   rename of `partial.json`, so a kill mid-write leaves the previous
//!   complete generation in force.
//!
//! Incremental checkpoints are **integrity-checked**: `partial.json`
//! records an FNV-1a digest of every partial proxy payload and of the
//! shard bitmap, verified on load.  The previous generation's files (and
//! its header, as `partial_prev.json`) are retained until the next commit,
//! so a bit-rotted or torn newest generation falls back to the previous
//! intact one — and if none survives, [`load_partial`] degrades to a clean
//! cold start instead of resuming from corrupt state.  Only *corruption*
//! falls back; a fingerprint or partition mismatch stays a loud error
//! (those mean the caller asked for a different run, not that the disk
//! lied).

use crate::tensor::io::{load_tensor, save_tensor};
use crate::tensor::DenseTensor;
use crate::util::fault::{self, TRANSIENT_MARKER};
use crate::util::hash::{fnv1a64, Fnv};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Checkpoint format version.  Bumped to **2** when the replica-map
/// generator changed from sequential xoshiro streams to the counter-based
/// hash (PR 5): the fingerprint fields are identical across that change,
/// but version-1 proxies were folded from differently-valued maps, so
/// resuming them against regenerated maps would be silently corrupt —
/// the version gate turns that into a loud "recompress" error instead.
const CHECKPOINT_VERSION: usize = 2;

/// Identifies a compression run; resuming requires an exact match.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint {
    pub dims: [usize; 3],
    pub reduced: [usize; 3],
    pub rank: usize,
    pub replicas: usize,
    pub anchor_rows: usize,
    pub seed: u64,
    pub mixed_precision: bool,
}

impl Fingerprint {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dims", Json::arr_usize(&self.dims)),
            ("reduced", Json::arr_usize(&self.reduced)),
            ("rank", Json::num(self.rank as f64)),
            ("replicas", Json::num(self.replicas as f64)),
            ("anchor_rows", Json::num(self.anchor_rows as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("mixed_precision", Json::Bool(self.mixed_precision)),
        ])
    }

    fn from_json(v: &Json) -> Result<Fingerprint> {
        let arr3 = |key: &str| -> Result<[usize; 3]> {
            let a = v
                .get(key)
                .and_then(|x| x.as_arr())
                .with_context(|| format!("checkpoint missing {key}"))?;
            if a.len() != 3 {
                bail!("checkpoint {key}: expected 3 dims");
            }
            Ok([
                a[0].as_usize().context("dim")?,
                a[1].as_usize().context("dim")?,
                a[2].as_usize().context("dim")?,
            ])
        };
        let num = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("checkpoint missing {key}"))
        };
        Ok(Fingerprint {
            dims: arr3("dims")?,
            reduced: arr3("reduced")?,
            rank: num("rank")?,
            replicas: num("replicas")?,
            anchor_rows: num("anchor_rows")?,
            seed: num("seed")? as u64,
            mixed_precision: v
                .get("mixed_precision")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
        })
    }
}

/// Writes a post-compression checkpoint: header + one EXT1 file per proxy.
pub fn save_proxies(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
    proxies: &[DenseTensor],
) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    for (p, y) in proxies.iter().enumerate() {
        save_tensor(y, dir.join(format!("proxy_{p:04}.ext1")))?;
    }
    let header = Json::obj(vec![
        ("version", Json::num(CHECKPOINT_VERSION as f64)),
        ("stage", Json::str("compressed")),
        ("fingerprint", fp.to_json()),
        ("proxy_count", Json::num(proxies.len() as f64)),
    ]);
    std::fs::write(dir.join("checkpoint.json"), header.to_string_pretty())?;
    Ok(())
}

/// Loads a checkpoint if it exists and matches `fp`; `Ok(None)` when absent,
/// `Err` on mismatch (resuming with different parameters would silently
/// corrupt results — fail loudly instead).
pub fn load_proxies(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
) -> Result<Option<Vec<DenseTensor>>> {
    let dir = dir.as_ref();
    let header_path = dir.join("checkpoint.json");
    if !header_path.exists() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&header_path)?;
    let v = Json::parse(&text).context("checkpoint.json parse")?;
    if v.get("version").and_then(|x| x.as_usize()) != Some(CHECKPOINT_VERSION) {
        bail!("unsupported checkpoint version");
    }
    let stored = Fingerprint::from_json(v.get("fingerprint").context("missing fingerprint")?)?;
    if &stored != fp {
        bail!(
            "checkpoint at {} was created with different parameters \
             (stored {stored:?}, requested {fp:?}); delete it to recompress",
            dir.display()
        );
    }
    let count = v
        .get("proxy_count")
        .and_then(|x| x.as_usize())
        .context("missing proxy_count")?;
    if count != fp.replicas {
        bail!(
            "checkpoint holds {count} proxies but the run expects {} replicas",
            fp.replicas
        );
    }
    let mut proxies = Vec::with_capacity(count);
    for p in 0..count {
        let path = dir.join(format!("proxy_{p:04}.ext1"));
        let t = load_tensor(&path).with_context(|| format!("loading {}", path.display()))?;
        if t.dims() != fp.reduced {
            bail!(
                "{}: proxy dims {:?} do not match reduced dims {:?}",
                path.display(),
                t.dims(),
                fp.reduced
            );
        }
        proxies.push(t);
    }
    Ok(Some(proxies))
}

/// The streaming position an incremental checkpoint captures, plus the
/// block-grid partition it is only valid for (resuming under a different
/// partition would fold blocks twice or skip them).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompressionProgress {
    /// Block dims the grid was built with.
    pub block: [usize; 3],
    /// Shard partition granularity (`StreamOptions::shard_parts`).
    pub shard_parts: usize,
    /// Total shards in the partition.
    pub shards_total: usize,
    /// Folded prefix: shards `0..shards_done` are in the partial proxies.
    pub shards_done: usize,
    /// Blocks covered by the folded prefix.
    pub blocks_done: usize,
    /// Total blocks in the grid.
    pub blocks_total: usize,
    /// Which compression path produced the partials (`"plain"`,
    /// `"batched"`) — paths differ in GEMM association, so partials are
    /// only resumable by the same path.
    pub path: String,
    /// Monotone write generation (for atomic replacement).
    pub generation: u64,
}

impl CompressionProgress {
    fn to_json(&self, bitmap_hex: &str) -> Json {
        Json::obj(vec![
            ("block", Json::arr_usize(&self.block)),
            ("shard_parts", Json::num(self.shard_parts as f64)),
            ("shards_total", Json::num(self.shards_total as f64)),
            ("shards_done", Json::num(self.shards_done as f64)),
            ("blocks_done", Json::num(self.blocks_done as f64)),
            ("blocks_total", Json::num(self.blocks_total as f64)),
            ("path", Json::str(self.path.clone())),
            ("generation", Json::num(self.generation as f64)),
            ("shard_bitmap", Json::str(bitmap_hex)),
        ])
    }

    fn from_json(v: &Json) -> Result<(CompressionProgress, String)> {
        let num = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("partial checkpoint missing {key}"))
        };
        let block = {
            let a = v
                .get("block")
                .and_then(|x| x.as_arr())
                .context("partial checkpoint missing block")?;
            if a.len() != 3 {
                bail!("partial checkpoint block: expected 3 dims");
            }
            [
                a[0].as_usize().context("block dim")?,
                a[1].as_usize().context("block dim")?,
                a[2].as_usize().context("block dim")?,
            ]
        };
        let bitmap = v
            .get("shard_bitmap")
            .and_then(|x| x.as_str())
            .context("partial checkpoint missing shard_bitmap")?
            .to_string();
        Ok((
            CompressionProgress {
                block,
                shard_parts: num("shard_parts")?,
                shards_total: num("shards_total")?,
                shards_done: num("shards_done")?,
                blocks_done: num("blocks_done")?,
                blocks_total: num("blocks_total")?,
                path: v
                    .get("path")
                    .and_then(|x| x.as_str())
                    .context("partial checkpoint missing path")?
                    .to_string(),
                generation: num("generation")? as u64,
            },
            bitmap,
        ))
    }
}

/// Little-endian-bit hex bitmap with bits `0..done` set out of `total` —
/// the block-grid progress record.  The current writer always persists a
/// prefix (the engine folds shards in order), but the format carries the
/// full bitmap so readers verify integrity rather than trusting a counter.
fn prefix_bitmap_hex(done: usize, total: usize) -> String {
    let nbytes = total.div_ceil(8).max(1);
    let mut bytes = vec![0u8; nbytes];
    for s in 0..done {
        bytes[s / 8] |= 1 << (s % 8);
    }
    let mut out = String::with_capacity(nbytes * 2);
    for b in bytes {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Parses a bitmap written by [`prefix_bitmap_hex`] and verifies it is the
/// prefix `0..done` of `total` shards.  Decodes byte-wise (never slicing
/// the untrusted string) so corrupt multi-byte content errors instead of
/// panicking mid-character.
fn check_prefix_bitmap(hex: &str, done: usize, total: usize) -> Result<()> {
    let nbytes = total.div_ceil(8).max(1);
    let raw = hex.as_bytes();
    if raw.len() != nbytes * 2 {
        bail!("shard bitmap length {} != {}", raw.len(), nbytes * 2);
    }
    let nibble = |b: u8| -> Result<u8> {
        match b {
            b'0'..=b'9' => Ok(b - b'0'),
            b'a'..=b'f' => Ok(b - b'a' + 10),
            b'A'..=b'F' => Ok(b - b'A' + 10),
            _ => bail!("shard bitmap is not hex (byte {b:#04x})"),
        }
    };
    let mut bytes = Vec::with_capacity(nbytes);
    for i in 0..nbytes {
        bytes.push((nibble(raw[2 * i])? << 4) | nibble(raw[2 * i + 1])?);
    }
    for s in 0..total {
        let set = bytes[s / 8] & (1 << (s % 8)) != 0;
        if set != (s < done) {
            bail!("shard bitmap is not the expected prefix of {done}/{total} (bit {s} = {set})");
        }
    }
    Ok(())
}

fn partial_proxy_name(generation: u64, p: usize) -> String {
    format!("partial_{generation:08}_proxy_{p:04}.ext1")
}

/// Content digest of one tensor payload (dims + little-endian f32 bytes) —
/// what `partial.json` records per partial proxy and verifies on load.
fn tensor_digest(t: &DenseTensor) -> u64 {
    let mut h = Fnv::new();
    for d in t.dims() {
        h.write_u64(d as u64);
    }
    for &x in t.data() {
        h.write(&x.to_le_bytes());
    }
    h.finish()
}

/// Digests travel as 16-hex strings: JSON numbers are f64 and cannot hold
/// a u64 exactly.
fn digest_hex(d: u64) -> String {
    format!("{d:016x}")
}

fn parse_digest_hex(s: &str) -> Option<u64> {
    (s.len() == 16).then(|| u64::from_str_radix(s, 16).ok()).flatten()
}

/// The generation number a committed partial header points at, if the
/// header is readable — used by the GC to know which previous-generation
/// files are still referenced.
fn header_generation(path: &Path) -> Option<u64> {
    let text = std::fs::read_to_string(path).ok()?;
    let v = Json::parse(&text).ok()?;
    v.get("progress")?.get("generation")?.as_usize().map(|g| g as u64)
}

/// Writes an incremental (mid-compression) checkpoint: the folded-prefix
/// proxies under a fresh generation (each payload digested into the
/// header), preserves the outgoing header as `partial_prev.json`, commits
/// the new `partial.json` via an atomic rename, then garbage-collects
/// every generation older than the two the headers reference.  A kill at
/// any point leaves at least one complete generation (or no partial at
/// all), and a corrupted newest generation still has an intact fallback.
pub fn save_partial(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
    progress: &CompressionProgress,
    proxies: &[DenseTensor],
) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let g = progress.generation;
    let mut digests = Vec::with_capacity(proxies.len());
    for (p, y) in proxies.iter().enumerate() {
        save_tensor(y, dir.join(partial_proxy_name(g, p)))?;
        digests.push(Json::str(digest_hex(tensor_digest(y))));
    }
    let bitmap = prefix_bitmap_hex(progress.shards_done, progress.shards_total);
    let header = Json::obj(vec![
        ("version", Json::num(CHECKPOINT_VERSION as f64)),
        ("stage", Json::str("compressing")),
        ("fingerprint", fp.to_json()),
        ("proxy_count", Json::num(proxies.len() as f64)),
        ("proxy_digests", Json::Arr(digests)),
        ("bitmap_digest", Json::str(digest_hex(fnv1a64(bitmap.as_bytes())))),
        ("progress", progress.to_json(&bitmap)),
    ]);
    let tmp = dir.join("partial.json.tmp");
    std::fs::write(&tmp, header.to_string_pretty())?;
    // Keep the outgoing generation reachable: copy (not rename — the
    // current header must stay valid until the new one is committed) the
    // live header aside before replacing it.
    let current = dir.join("partial.json");
    if current.exists() {
        std::fs::copy(&current, dir.join("partial_prev.json"))
            .context("preserving previous partial header")?;
    }
    if fault::should_fault(fault::Site::CheckpointCommit) {
        std::fs::remove_file(&tmp).ok();
        bail!("injected checkpoint commit fault {TRANSIENT_MARKER}");
    }
    std::fs::rename(&tmp, &current).context("committing partial.json")?;
    // GC generations no longer referenced by either header (best-effort).
    // The prev header's generation is parsed rather than assumed to be
    // g−1: a failed commit consumes a generation number without updating
    // the headers.
    let prev_gen = header_generation(&dir.join("partial_prev.json"));
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if !name.starts_with("partial_") || !name.ends_with(".ext1") {
                continue;
            }
            let keep = name.starts_with(&format!("partial_{g:08}_"))
                || prev_gen
                    .map(|pg| name.starts_with(&format!("partial_{pg:08}_")))
                    .unwrap_or(true);
            if !keep {
                std::fs::remove_file(e.path()).ok();
            }
        }
    }
    Ok(())
}

/// Result of [`load_partial`]: the resumable state if any intact
/// generation exists, plus how many corrupt generations were skipped to
/// find it (surfaced by the pipeline as the `checkpoint_fallbacks`
/// metric).
#[derive(Debug)]
pub struct PartialLoad {
    pub state: Option<(CompressionProgress, Vec<DenseTensor>)>,
    pub fallbacks: u64,
}

/// One candidate header's verdict.  `Corrupt` means the disk lied (bad
/// JSON, failed digest, unloadable payload) — recoverable by falling back
/// a generation; genuine config mismatches are hard errors instead.
enum Candidate {
    Absent,
    Corrupt(String),
    Loaded(CompressionProgress, Vec<DenseTensor>),
}

/// Validates and loads the generation one header points at.  Every
/// integrity failure returns `Candidate::Corrupt`; fingerprint and
/// partition mismatches return `Err` (resuming under different parameters
/// would silently corrupt results — corruption fallback must not mask
/// that).
fn load_partial_candidate(
    dir: &Path,
    header_path: &Path,
    fp: &Fingerprint,
    expected: &CompressionProgress,
) -> Result<Candidate> {
    if !header_path.exists() {
        return Ok(Candidate::Absent);
    }
    let text = match std::fs::read_to_string(header_path) {
        Ok(t) => t,
        Err(e) => return Ok(Candidate::Corrupt(format!("unreadable header: {e}"))),
    };
    let v = match Json::parse(&text) {
        Ok(v) => v,
        Err(e) => return Ok(Candidate::Corrupt(format!("header parse: {e}"))),
    };
    if v.get("version").and_then(|x| x.as_usize()) != Some(CHECKPOINT_VERSION) {
        // Unlike `load_proxies`' loud version gate, a partial is
        // engine-managed state: an unsupported (or bit-rotted) version
        // degrades to recompressing, which is what the gate would demand
        // anyway.
        return Ok(Candidate::Corrupt("unsupported or damaged version".into()));
    }
    let stored_fp = match v
        .get("fingerprint")
        .context("missing fingerprint")
        .and_then(Fingerprint::from_json)
    {
        Ok(f) => f,
        Err(e) => return Ok(Candidate::Corrupt(format!("fingerprint: {e:#}"))),
    };
    if &stored_fp != fp {
        bail!(
            "partial checkpoint at {} was created with different parameters \
             (stored {stored_fp:?}, requested {fp:?}); delete it to recompress",
            dir.display()
        );
    }
    let (progress, bitmap) = match v
        .get("progress")
        .context("missing progress")
        .and_then(CompressionProgress::from_json)
    {
        Ok(p) => p,
        Err(e) => return Ok(Candidate::Corrupt(format!("progress: {e:#}"))),
    };
    if progress.block != expected.block
        || progress.shard_parts != expected.shard_parts
        || progress.shards_total != expected.shards_total
        || progress.blocks_total != expected.blocks_total
        || progress.path != expected.path
    {
        bail!(
            "partial checkpoint at {} used a different block-grid partition or path \
             (stored {progress:?}, current {expected:?}); delete it to recompress",
            dir.display()
        );
    }
    // Progress bounds: a tampered/corrupt header must be caught here, not
    // panic later in the engine's resume assertions.
    if progress.shards_done > progress.shards_total {
        return Ok(Candidate::Corrupt(format!(
            "claims {} of {} shards done",
            progress.shards_done, progress.shards_total
        )));
    }
    let parts =
        crate::util::threadpool::ThreadPool::partition(progress.blocks_total, progress.shard_parts);
    if parts.len() != progress.shards_total {
        return Ok(Candidate::Corrupt(format!(
            "inconsistent shard partition ({} parts for {} declared)",
            parts.len(),
            progress.shards_total
        )));
    }
    let prefix_blocks: usize = parts[..progress.shards_done].iter().map(|(a, b)| b - a).sum();
    if prefix_blocks != progress.blocks_done {
        return Ok(Candidate::Corrupt(format!(
            "blocks_done {} does not match its {}-shard prefix ({prefix_blocks})",
            progress.blocks_done, progress.shards_done
        )));
    }
    if let Err(e) = check_prefix_bitmap(&bitmap, progress.shards_done, progress.shards_total) {
        return Ok(Candidate::Corrupt(format!("{e:#}")));
    }
    match v.get("bitmap_digest").and_then(|x| x.as_str()).and_then(parse_digest_hex) {
        Some(d) if d == fnv1a64(bitmap.as_bytes()) => {}
        Some(_) => return Ok(Candidate::Corrupt("bitmap digest mismatch".into())),
        None => return Ok(Candidate::Corrupt("missing bitmap digest".into())),
    }
    let count = match v.get("proxy_count").and_then(|x| x.as_usize()) {
        Some(c) => c,
        None => return Ok(Candidate::Corrupt("missing proxy_count".into())),
    };
    // A truncated partial (wrong accumulator count) would silently drop
    // replicas in the merge — corruption, not a config mismatch.
    if count != fp.replicas {
        return Ok(Candidate::Corrupt(format!(
            "holds {count} proxies but the run expects {} replicas",
            fp.replicas
        )));
    }
    let digests: Vec<Option<u64>> = match v.get("proxy_digests").and_then(|x| x.as_arr()) {
        Some(a) if a.len() == count => {
            a.iter().map(|d| d.as_str().and_then(parse_digest_hex)).collect()
        }
        _ => return Ok(Candidate::Corrupt("missing or short proxy_digests".into())),
    };
    let mut proxies = Vec::with_capacity(count);
    for (p, want) in digests.iter().enumerate() {
        let path = dir.join(partial_proxy_name(progress.generation, p));
        let t = match load_tensor(&path) {
            Ok(t) => t,
            Err(e) => {
                return Ok(Candidate::Corrupt(format!("{}: {e:#}", path.display())));
            }
        };
        if t.dims() != fp.reduced {
            return Ok(Candidate::Corrupt(format!(
                "{}: proxy dims {:?} do not match reduced dims {:?}",
                path.display(),
                t.dims(),
                fp.reduced
            )));
        }
        if *want != Some(tensor_digest(&t)) {
            return Ok(Candidate::Corrupt(format!(
                "{}: payload digest mismatch",
                path.display()
            )));
        }
        proxies.push(t);
    }
    Ok(Candidate::Loaded(progress, proxies))
}

/// Loads the newest intact incremental checkpoint generation.
///
/// Tries `partial.json`, then `partial_prev.json`.  Corrupt candidates are
/// skipped (counted in [`PartialLoad::fallbacks`]); a fallback hit
/// promotes the previous header back to `partial.json` and deletes the
/// corrupt generation's files.  If no candidate survives, all partial
/// state is cleared and the run cold-starts.  `expected` carries the
/// partition of the *current* run (its `shards_done`/`blocks_done`/
/// `generation` are ignored); a fingerprint or partition mismatch is still
/// a hard `Err`, exactly as before.
pub fn load_partial(
    dir: impl AsRef<Path>,
    fp: &Fingerprint,
    expected: &CompressionProgress,
) -> Result<PartialLoad> {
    let dir = dir.as_ref();
    let primary = dir.join("partial.json");
    let prev = dir.join("partial_prev.json");
    let mut fallbacks = 0u64;
    for (is_prev, path) in [(false, &primary), (true, &prev)] {
        match load_partial_candidate(dir, path, fp, expected)? {
            Candidate::Absent => continue,
            Candidate::Corrupt(why) => {
                log::warn!(
                    "partial checkpoint {}: {why}; falling back a generation",
                    path.display()
                );
                fallbacks += 1;
            }
            Candidate::Loaded(pr, proxies) => {
                if is_prev {
                    // Promote the survivor so the directory invariant
                    // (partial.json = newest intact generation) is
                    // restored, and drop the corrupt newer files.
                    std::fs::rename(&prev, &primary).ok();
                    let keep = format!("partial_{:08}_", pr.generation);
                    if let Ok(entries) = std::fs::read_dir(dir) {
                        for e in entries.flatten() {
                            let name = e.file_name();
                            let name = name.to_string_lossy();
                            if name.starts_with("partial_")
                                && name.ends_with(".ext1")
                                && !name.starts_with(&keep)
                            {
                                std::fs::remove_file(e.path()).ok();
                            }
                        }
                    }
                }
                return Ok(PartialLoad { state: Some((pr, proxies)), fallbacks });
            }
        }
    }
    if fallbacks > 0 {
        // No generation survived: clear the wreckage so the cold start is
        // actually clean (and the next save doesn't resurrect it).
        log::warn!("no intact partial checkpoint generation; cold-starting compression");
        clear_partial(dir).ok();
    }
    Ok(PartialLoad { state: None, fallbacks })
}

/// Removes only the incremental checkpoint (after the final one lands).
pub fn clear_partial(dir: impl AsRef<Path>) -> Result<()> {
    let dir = dir.as_ref();
    if !dir.exists() {
        return Ok(());
    }
    std::fs::remove_file(dir.join("partial.json")).ok();
    std::fs::remove_file(dir.join("partial_prev.json")).ok();
    std::fs::remove_file(dir.join("partial.json.tmp")).ok();
    for e in std::fs::read_dir(dir)?.flatten() {
        let name = e.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("partial_") && name.ends_with(".ext1") {
            std::fs::remove_file(e.path()).ok();
        }
    }
    Ok(())
}

/// True when `dir` holds a committed incremental (mid-compression)
/// checkpoint — the `serve/` scheduler uses this to report which recovered
/// jobs will resume in-flight work rather than restart Stage 1.
pub fn partial_exists(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("partial.json").exists()
}

/// Removes a checkpoint directory (after a successful run).
pub fn clear(dir: impl AsRef<Path>) -> Result<()> {
    let dir = dir.as_ref();
    if dir.exists() {
        std::fs::remove_dir_all(dir)?;
    }
    Ok(())
}

#[doc(hidden)]
pub fn default_fingerprint(
    cfg: &super::config::PipelineConfig,
    dims: [usize; 3],
    replicas: usize,
) -> Fingerprint {
    Fingerprint {
        dims,
        reduced: cfg.reduced,
        rank: cfg.rank,
        replicas,
        anchor_rows: cfg.effective_anchor(),
        seed: cfg.seed,
        mixed_precision: cfg.mixed_precision,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;
    use std::path::PathBuf;

    fn fp() -> Fingerprint {
        Fingerprint {
            dims: [40, 40, 40],
            reduced: [10, 10, 10],
            rank: 3,
            replicas: 2,
            anchor_rows: 5,
            seed: 7,
            mixed_precision: false,
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_ckpt_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn round_trip() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("rt");
        let mut rng = Xoshiro256::seed_from_u64(1);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_proxies(&dir, &fp(), &proxies).unwrap();
        let loaded = load_proxies(&dir, &fp()).unwrap().expect("checkpoint");
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0], proxies[0]);
        assert_eq!(loaded[1], proxies[1]);
        clear(&dir).unwrap();
        assert!(load_proxies(&dir, &fp()).unwrap().is_none());
    }

    #[test]
    fn mismatched_fingerprint_rejected() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("mismatch");
        let mut rng = Xoshiro256::seed_from_u64(2);
        let proxies = vec![DenseTensor::random_normal([10, 10, 10], &mut rng)];
        let mut fp1 = fp();
        fp1.replicas = 1;
        save_proxies(&dir, &fp1, &proxies).unwrap();
        let mut other = fp1.clone();
        other.seed = 99;
        assert!(load_proxies(&dir, &other).is_err());
        clear(&dir).unwrap();
    }

    #[test]
    fn absent_checkpoint_is_none() {
        assert!(load_proxies("/nonexistent/ckpt", &fp()).unwrap().is_none());
    }

    fn progress(shards_done: usize, generation: u64) -> CompressionProgress {
        // Self-consistent partition: 120 blocks over 10 shards of 12.
        CompressionProgress {
            block: [8, 8, 8],
            shard_parts: 10,
            shards_total: 10,
            shards_done,
            blocks_done: shards_done * 12,
            blocks_total: 120,
            path: "batched".to_string(),
            generation,
        }
    }

    #[test]
    fn partial_progress_bounds_validated() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("partial_bounds");
        let mut rng = Xoshiro256::seed_from_u64(8);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        // blocks_done inconsistent with the shard prefix → corrupt header:
        // with no earlier generation to fall back to, the run cold-starts.
        let mut pr = progress(3, 0);
        pr.blocks_done = 35;
        save_partial(&dir, &fp(), &pr, &proxies).unwrap();
        let load = load_partial(&dir, &fp(), &progress(0, 0)).unwrap();
        assert!(load.state.is_none());
        assert_eq!(load.fallbacks, 1);
        clear(&dir).unwrap();
        // shards_done beyond shards_total → caught, never a panic.
        let mut pr = progress(10, 0);
        pr.shards_done = 12;
        pr.blocks_done = 144;
        save_partial(&dir, &fp(), &pr, &proxies).unwrap();
        let load = load_partial(&dir, &fp(), &progress(0, 0)).unwrap();
        assert!(load.state.is_none());
        assert_eq!(load.fallbacks, 1);
        clear(&dir).unwrap();
    }

    #[test]
    fn partial_round_trip_and_gc() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("partial_rt");
        let mut rng = Xoshiro256::seed_from_u64(3);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_partial(&dir, &fp(), &progress(3, 0), &proxies).unwrap();
        let newer = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_partial(&dir, &fp(), &progress(6, 1), &newer).unwrap();
        let load = load_partial(&dir, &fp(), &progress(0, 0)).unwrap();
        let (pr, loaded) = load.state.unwrap();
        assert_eq!(load.fallbacks, 0);
        assert_eq!(pr.shards_done, 6);
        assert_eq!(pr.blocks_done, 72);
        assert_eq!(loaded, newer, "latest generation wins");
        // Generation 0 is retained as the fallback generation…
        assert!(dir.join(super::partial_proxy_name(0, 0)).exists());
        assert!(dir.join("partial_prev.json").exists());
        // …until a third commit supersedes it.
        let newest = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_partial(&dir, &fp(), &progress(9, 2), &newest).unwrap();
        assert!(!dir.join(super::partial_proxy_name(0, 0)).exists(), "gen 0 GC'd");
        assert!(dir.join(super::partial_proxy_name(1, 0)).exists(), "gen 1 kept");
        clear_partial(&dir).unwrap();
        assert!(load_partial(&dir, &fp(), &progress(0, 0)).unwrap().state.is_none());
        assert!(!dir.join("partial_prev.json").exists());
        clear(&dir).unwrap();
    }

    #[test]
    fn corrupt_newest_generation_falls_back_to_previous() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("partial_fallback");
        let mut rng = Xoshiro256::seed_from_u64(9);
        let older = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_partial(&dir, &fp(), &progress(3, 0), &older).unwrap();
        let newer = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_partial(&dir, &fp(), &progress(6, 1), &newer).unwrap();
        // Bit-rot one byte of the newest generation's payload.
        let victim = dir.join(super::partial_proxy_name(1, 1));
        let mut bytes = std::fs::read(&victim).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        std::fs::write(&victim, &bytes).unwrap();
        let load = load_partial(&dir, &fp(), &progress(0, 0)).unwrap();
        assert_eq!(load.fallbacks, 1, "one corrupt generation skipped");
        let (pr, loaded) = load.state.expect("previous generation survives");
        assert_eq!(pr.shards_done, 3);
        assert_eq!(loaded, older, "fallback is bitwise the previous generation");
        // The survivor was promoted: a second load is clean.
        let again = load_partial(&dir, &fp(), &progress(0, 0)).unwrap();
        assert_eq!(again.fallbacks, 0);
        assert_eq!(again.state.unwrap().1, older);
        assert!(!victim.exists(), "corrupt generation's files deleted");
        clear(&dir).unwrap();
    }

    #[test]
    fn all_generations_corrupt_cold_starts_clean() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("partial_cold");
        let mut rng = Xoshiro256::seed_from_u64(10);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_partial(&dir, &fp(), &progress(3, 0), &proxies).unwrap();
        save_partial(&dir, &fp(), &progress(6, 1), &proxies).unwrap();
        std::fs::write(dir.join("partial.json"), "{torn").unwrap();
        std::fs::write(dir.join("partial_prev.json"), "also torn").unwrap();
        let load = load_partial(&dir, &fp(), &progress(0, 0)).unwrap();
        assert!(load.state.is_none());
        assert_eq!(load.fallbacks, 2, "both generations skipped");
        assert!(!partial_exists(&dir), "wreckage cleared for a clean cold start");
        assert!(!dir.join(super::partial_proxy_name(1, 0)).exists());
        clear(&dir).unwrap();
    }

    #[test]
    fn commit_fault_leaves_previous_generation_in_force() {
        use crate::util::fault::{arm_scoped, FaultPlan, Site, SiteSpec};
        let dir = tmpdir("partial_commit_fault");
        let mut rng = Xoshiro256::seed_from_u64(11);
        let older = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        save_partial(&dir, &fp(), &progress(3, 0), &older).unwrap();
        let newer = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        {
            let g = arm_scoped(FaultPlan::new(2).site(
                Site::CheckpointCommit,
                SiteSpec { max: 1, ..Default::default() },
            ));
            let e = save_partial(&dir, &fp(), &progress(6, 1), &newer)
                .expect_err("injected commit fault")
                .to_string();
            assert!(crate::util::fault::is_transient(&format!("{e:#}")));
            assert_eq!(g.fired(Site::CheckpointCommit), 1);
        }
        // The failed commit must not have replaced the live header.
        let load = load_partial(&dir, &fp(), &progress(0, 0)).unwrap();
        let (pr, loaded) = load.state.expect("previous generation in force");
        assert_eq!(pr.shards_done, 3);
        assert_eq!(loaded, older);
        // And a retried commit (disarmed) goes through.
        save_partial(&dir, &fp(), &progress(6, 1), &newer).unwrap();
        let (pr, loaded) =
            load_partial(&dir, &fp(), &progress(0, 0)).unwrap().state.unwrap();
        assert_eq!(pr.shards_done, 6);
        assert_eq!(loaded, newer);
        clear(&dir).unwrap();
    }

    #[test]
    fn partial_partition_mismatch_rejected() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("partial_mismatch");
        let mut rng = Xoshiro256::seed_from_u64(4);
        let proxies = vec![DenseTensor::random_normal([10, 10, 10], &mut rng)];
        save_partial(&dir, &fp(), &progress(2, 0), &proxies).unwrap();
        let mut other_block = progress(0, 0);
        other_block.block = [4, 4, 4];
        assert!(load_partial(&dir, &fp(), &other_block).is_err());
        let mut other_path = progress(0, 0);
        other_path.path = "plain".to_string();
        assert!(load_partial(&dir, &fp(), &other_path).is_err());
        let mut other_fp = fp();
        other_fp.seed = 123;
        assert!(load_partial(&dir, &other_fp, &progress(0, 0)).is_err());
        clear(&dir).unwrap();
    }

    #[test]
    fn partial_absent_is_none_and_final_untouched() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("partial_absent");
        let mut rng = Xoshiro256::seed_from_u64(5);
        let proxies = vec![
            DenseTensor::random_normal([10, 10, 10], &mut rng),
            DenseTensor::random_normal([10, 10, 10], &mut rng),
        ];
        // A final checkpoint alone yields no partial.
        save_proxies(&dir, &fp(), &proxies).unwrap();
        let load = load_partial(&dir, &fp(), &progress(0, 0)).unwrap();
        assert!(load.state.is_none());
        assert_eq!(load.fallbacks, 0, "absent is not corruption");
        // clear_partial must not disturb the final checkpoint.
        clear_partial(&dir).unwrap();
        assert!(load_proxies(&dir, &fp()).unwrap().is_some());
        clear(&dir).unwrap();
    }

    #[test]
    fn proxy_count_and_dims_validated_on_load() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("count_dims");
        let mut rng = Xoshiro256::seed_from_u64(6);
        // One proxy where the fingerprint promises two → loud failure.
        let short = vec![DenseTensor::random_normal([10, 10, 10], &mut rng)];
        save_proxies(&dir, &fp(), &short).unwrap();
        assert!(load_proxies(&dir, &fp()).is_err());
        clear(&dir).unwrap();
        // A partial with the wrong replica count is treated as corruption:
        // no intact generation remains, so the load cold-starts clean.
        save_partial(&dir, &fp(), &progress(2, 0), &short).unwrap();
        let load = load_partial(&dir, &fp(), &progress(0, 0)).unwrap();
        assert!(load.state.is_none());
        assert!(load.fallbacks >= 1, "count mismatch must count as a fallback");
        assert!(!partial_exists(&dir), "cold start clears the corrupt partial");
        clear(&dir).unwrap();
        // Right count, wrong dims → loud failure.
        let wrong_dims = vec![
            DenseTensor::random_normal([9, 10, 10], &mut rng),
            DenseTensor::random_normal([9, 10, 10], &mut rng),
        ];
        save_proxies(&dir, &fp(), &wrong_dims).unwrap();
        assert!(load_proxies(&dir, &fp()).is_err());
        clear(&dir).unwrap();
    }

    #[test]
    fn bitmap_prefix_integrity() {
        assert_eq!(super::prefix_bitmap_hex(0, 10), "0000");
        assert_eq!(super::prefix_bitmap_hex(3, 10), "0700");
        assert!(super::check_prefix_bitmap("0700", 3, 10).is_ok());
        assert!(super::check_prefix_bitmap("0f00", 3, 10).is_err(), "extra bit");
        assert!(super::check_prefix_bitmap("0300", 3, 10).is_err(), "missing bit");
        assert!(super::check_prefix_bitmap("07", 3, 10).is_err(), "short");
        assert!(super::check_prefix_bitmap("zz00", 3, 10).is_err(), "not hex");
        // Multi-byte UTF-8 of the right *byte* length must error, not panic.
        assert!(super::check_prefix_bitmap("aé0", 3, 10).is_err(), "non-ascii");
    }

    #[test]
    fn corrupt_header_rejected() {
        let _no_faults = crate::util::fault::exclude_faults();
        let dir = tmpdir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("checkpoint.json"), "{not json").unwrap();
        assert!(load_proxies(&dir, &fp()).is_err());
        clear(&dir).unwrap();
    }
}
