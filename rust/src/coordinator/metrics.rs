//! Per-stage metrics registry: wall-clock per pipeline stage plus counters.
//! The bench harness and EXPERIMENTS.md §Perf read these.

use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Timing/counter stats for one named stage.
#[derive(Clone, Debug, Default)]
pub struct StageStats {
    pub seconds: Summary,
    pub count: u64,
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    stages: Mutex<BTreeMap<String, StageStats>>,
    counters: Mutex<BTreeMap<String, u64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Times `f` under stage `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.record(name, start.elapsed().as_secs_f64());
        out
    }

    /// Records an externally measured duration.
    pub fn record(&self, name: &str, seconds: f64) {
        let mut stages = self.stages.lock().unwrap();
        let e = stages.entry(name.to_string()).or_default();
        e.seconds.push(seconds);
        e.count += 1;
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_insert(0) += by;
    }

    /// Sets a gauge-style counter to an absolute value (e.g. the resolved
    /// prefetch depth of the last run, where accumulation is meaningless).
    pub fn set(&self, name: &str, value: u64) {
        let mut c = self.counters.lock().unwrap();
        c.insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// One consistent export of every counter and gauge, sorted by key
    /// (the BTreeMap order).  This is the `METRICS` protocol verb's
    /// payload and the scheduler's per-job gauge surface (`jobs_queued`,
    /// `jobs_running`, `cache_hits`, `cache_evictions`,
    /// `admission_rejected_bytes`, …) — one snapshot call instead of
    /// ad-hoc field reads, so readers never observe a torn registry.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    pub fn stage(&self, name: &str) -> Option<StageStats> {
        self.stages.lock().unwrap().get(name).cloned()
    }

    /// Total seconds recorded under `name` (0 if absent).
    pub fn total_seconds(&self, name: &str) -> f64 {
        self.stage(name)
            .map(|s| s.seconds.mean() * s.count as f64)
            .unwrap_or(0.0)
    }

    /// Formats a stage report table.
    pub fn report(&self) -> String {
        let stages = self.stages.lock().unwrap();
        let counters = self.counters.lock().unwrap();
        let mut out = String::from(format!(
            "{:<28} {:>8} {:>12} {:>12}\n",
            "stage", "calls", "mean", "total"
        ));
        for (name, s) in stages.iter() {
            let total = s.seconds.mean() * s.count as f64;
            out.push_str(&format!(
                "{:<28} {:>8} {:>12} {:>12}\n",
                name,
                s.count,
                crate::util::stats::fmt_duration(s.seconds.mean()),
                crate::util::stats::fmt_duration(total),
            ));
        }
        for (name, v) in counters.iter() {
            out.push_str(&format!("{name:<28} {v:>8}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_records_stage() {
        let m = Metrics::new();
        let v = m.time("compress", || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        let s = m.stage("compress").unwrap();
        assert_eq!(s.count, 1);
        assert!(s.seconds.mean() >= 0.001);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("blocks", 3);
        m.incr("blocks", 4);
        assert_eq!(m.counter("blocks"), 7);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn set_overwrites_gauge() {
        let m = Metrics::new();
        m.set("depth", 4);
        m.set("depth", 2);
        assert_eq!(m.counter("depth"), 2);
    }

    #[test]
    fn report_mentions_everything() {
        let m = Metrics::new();
        m.record("decompose", 0.5);
        m.incr("replicas", 9);
        let r = m.report();
        assert!(r.contains("decompose"));
        assert!(r.contains("replicas"));
    }

    #[test]
    fn snapshot_exports_counters_and_gauges_sorted() {
        let m = Metrics::new();
        m.incr("jobs_queued", 3);
        m.set("admission_rejected_bytes", 1024);
        m.incr("cache_hits", 1);
        // The failure-hardening counters ride the same snapshot plumbing.
        m.incr("jobs_retried", 2);
        m.incr("jobs_quarantined", 1);
        m.incr("checkpoint_fallbacks", 1);
        m.incr("conn_timeouts", 4);
        m.incr("conn_rejected_over_capacity", 5);
        // Batch-lane counters and gauge ride it too.
        m.incr("batch_sweeps", 2);
        m.incr("batch_jobs_coalesced", 7);
        m.set("batch_lane_depth", 3);
        m.incr("tenant_quota_deferrals", 1);
        // Shard-lease counters (sharded multi-worker execution) too.
        m.incr("leases_granted", 6);
        m.incr("leases_relet", 1);
        m.incr("partials_folded", 8);
        m.incr("workers_connected", 2);
        // Artifact-store counters and gauges ride it too.
        m.incr("admission_warm_priced", 1);
        m.set("store_bytes", 4096);
        m.incr("store_corrupt", 1);
        m.set("store_entries", 5);
        m.incr("store_evictions", 2);
        m.incr("store_hits_compress", 4);
        m.incr("store_hits_factors", 3);
        m.incr("store_hits_shards", 9);
        m.incr("store_publishes", 6);
        let snap = m.snapshot();
        assert_eq!(
            snap,
            vec![
                ("admission_rejected_bytes".to_string(), 1024),
                ("admission_warm_priced".to_string(), 1),
                ("batch_jobs_coalesced".to_string(), 7),
                ("batch_lane_depth".to_string(), 3),
                ("batch_sweeps".to_string(), 2),
                ("cache_hits".to_string(), 1),
                ("checkpoint_fallbacks".to_string(), 1),
                ("conn_rejected_over_capacity".to_string(), 5),
                ("conn_timeouts".to_string(), 4),
                ("jobs_quarantined".to_string(), 1),
                ("jobs_queued".to_string(), 3),
                ("jobs_retried".to_string(), 2),
                ("leases_granted".to_string(), 6),
                ("leases_relet".to_string(), 1),
                ("partials_folded".to_string(), 8),
                ("store_bytes".to_string(), 4096),
                ("store_corrupt".to_string(), 1),
                ("store_entries".to_string(), 5),
                ("store_evictions".to_string(), 2),
                ("store_hits_compress".to_string(), 4),
                ("store_hits_factors".to_string(), 3),
                ("store_hits_shards".to_string(), 9),
                ("store_publishes".to_string(), 6),
                ("tenant_quota_deferrals".to_string(), 1),
                ("workers_connected".to_string(), 2),
            ]
        );
        let mut sorted = snap.clone();
        sorted.sort();
        assert_eq!(snap, sorted, "snapshot keys must come out sorted");
    }

    #[test]
    fn total_seconds_sums() {
        let m = Metrics::new();
        m.record("x", 1.0);
        m.record("x", 3.0);
        assert!((m.total_seconds("x") - 4.0).abs() < 1e-9);
    }
}
