//! Factor recovery — Alg. 2 lines 9–13 (+ §IV-D second stage).
//!
//! After alignment every replica satisfies `A_p ≈ U_p · (A Π Σ_A)` with a
//! *common* `Π Σ_A`, so stacking over replicas gives the overdetermined
//! system of Eq. (4); its least-squares solution is `Ã = A Π Σ_A` (and
//! likewise `B̃`, `C̃`).  The residual `Π Σ` ambiguity is removed by
//! CP-decomposing a small sampled corner of the original tensor directly
//! and matching its factors against the leading rows of the recovered ones
//! (lines 10–13).

use super::config::{RecoverySolverKind, DEFAULT_RECOVERY_PANEL_COLS};
use super::matching::anchor_normalize;
use super::planner::MemoryPlanner;
use crate::compress::{MapSource, MapTier, ReplicaMaps, SparseSignMatrix};
use crate::cp::{als_decompose, AlsOptions, CpModel};
use crate::linalg::ista::{ista_l1, IstaOptions};
use crate::linalg::iterative::{cg_normal_solve, CgOptions};
use crate::linalg::{cholesky_solve, hungarian_max, lstsq, matmul, matvec, Matrix, Trans};
use crate::tensor::DenseTensor;
use crate::util::rng::{counter_key, gaussian_from_key};
use anyhow::{bail, Context, Result};

/// Column-panel width of the streamed stacked solve (the historical
/// constant, now the default of the `recovery_panel_cols` knob): the only
/// map-shaped allocations recovery makes are `L×PANEL` scratch panels,
/// never the `P·L × dim` stack.  The memory planner budgets recovery with
/// the same knob.
pub const RECOVERY_PANEL_COLS: usize = DEFAULT_RECOVERY_PANEL_COLS;

/// How [`stacked_recover_opts`] solves each mode's stacked system.
#[derive(Clone, Copy, Debug)]
pub struct RecoveryOptions {
    /// Resolved solver (the planner settles `Auto` before recovery runs).
    pub solver: RecoverySolverKind,
    /// Streamed map-panel width in columns.
    pub panel_cols: usize,
    /// CG knobs for the iterative solver and the sketch path's polish.
    pub cg: CgOptions,
}

impl Default for RecoveryOptions {
    fn default() -> Self {
        Self {
            solver: RecoverySolverKind::Cholesky,
            panel_cols: DEFAULT_RECOVERY_PANEL_COLS,
            // f32 panel arithmetic stalls below ~1e-6 relative residual on
            // large systems; 1e-5 is comfortably inside the factors'
            // differential tolerance while always reachable.
            cg: CgOptions { tol: 1e-5, ..CgOptions::default() },
        }
    }
}

impl RecoveryOptions {
    pub fn with_solver(solver: RecoverySolverKind) -> Self {
        Self { solver, ..Self::default() }
    }
}

/// Per-run counters [`stacked_recover_opts`] reports (the
/// `recovery_cg_iters` metric).
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryStats {
    /// CG iterations summed over modes and right-hand-side columns
    /// (iterative solver and sketch polish; 0 for pure Cholesky).
    pub cg_iterations: u64,
}

/// Adds `b` into `m` at offset `(r0, c0)`.
fn add_block(m: &mut Matrix, r0: usize, c0: usize, b: &Matrix) {
    for c in 0..b.cols() {
        let dst = &mut m.col_mut(c0 + c)[r0..r0 + b.rows()];
        for (d, s) in dst.iter_mut().zip(b.col(c)) {
            *d += s;
        }
    }
}

/// Adds `bᵀ` into `m` at offset `(r0, c0)`.
fn add_block_transposed(m: &mut Matrix, r0: usize, c0: usize, b: &Matrix) {
    for r in 0..b.rows() {
        let dst = m.col_mut(c0 + r);
        for c in 0..b.cols() {
            dst[r0 + c] += b.get(r, c);
        }
    }
}

/// One mode of the stacked solve.  Validates identifiability, then
/// dispatches on the resolved solver:
///
/// * `Cholesky`  — accumulate the normal equations `Gram = Σ_p U_pᵀU_p`
///   (`dim×dim`) and `AᵀB = Σ_p U_pᵀA_p` (`dim×R`) from `L × ≤panel`
///   column panels, one Cholesky solve.  The dense oracle.
/// * `Iterative` — matrix-free CGNR: one panel pass for the Gram diagonal
///   + `AᵀB`, then every matvec streams panels again; the Gram never
///   exists and peak memory is `O(panel + dim×R)`.
/// * `Sketch`    — counter-rng Gaussian sketch of the stacked system,
///   small dense solve, CG polish from the sketched warm start.
///
/// In every path the accumulation order (`p` outer, panels inner,
/// single-threaded) is fixed, so the result is a pure function of the
/// panel *values* — which is what makes the two map tiers bitwise
/// interchangeable per solver.
fn recover_mode(
    aligned: &[CpModel],
    maps: &MapSource,
    mode: usize,
    factor: impl Fn(&CpModel) -> &Matrix,
    opts: &RecoveryOptions,
    stats: &mut RecoveryStats,
) -> Result<Matrix> {
    let dim = maps.dims()[mode];
    let l = maps.reduced()[mode];
    let rows = maps.p_count() * l;
    if rows < dim {
        bail!("stacked system underdetermined: {rows}×{dim} (need P·L ≥ dim)");
    }
    // Anchor rows repeat across replicas, so the stacked map's column rank
    // is at most S + P·(L−S), not P·L.  Reject rank deficiency up front:
    // the ridge-damped solvers below would otherwise return a finite ridge
    // solution instead of an error.  (Always ≥ L, so pass-through modes
    // with dim ≤ L are never rejected.)
    let s = maps.anchor_rows().min(l);
    let col_rank_bound = s + maps.p_count() * (l - s);
    if col_rank_bound < dim {
        bail!(
            "stacked map rank-deficient on mode {mode}: S + P·(L−S) = {col_rank_bound} < \
             dim {dim} (anchors repeat across replicas); add replicas or shrink S"
        );
    }
    let facs: Vec<&Matrix> = aligned.iter().map(|m| factor(m)).collect();
    for (p, fac) in facs.iter().enumerate() {
        assert_eq!(fac.rows(), l, "replica {p} factor rows ≠ reduced dim");
    }
    let w = opts.panel_cols.min(dim).max(1);
    match opts.solver {
        RecoverySolverKind::Cholesky => recover_mode_cholesky(&facs, maps, mode, dim, w),
        RecoverySolverKind::Iterative => {
            recover_mode_iterative(&facs, maps, mode, dim, w, opts, stats)
        }
        RecoverySolverKind::Sketch => {
            recover_mode_sketch(&facs, maps, mode, dim, w, opts, stats)
        }
    }
}

/// Dense QR on the materialized stack — the last-resort fallback every
/// solver shares when its result degenerates.  Procedural maps have no
/// stack to materialize; failing loudly there is the design.
fn dense_fallback(
    facs: &[&Matrix],
    maps: &MapSource,
    mode: usize,
    why: &str,
) -> Result<Matrix> {
    match maps.tier() {
        MapTier::Materialized => {
            let m = maps.materialized().expect("materialized tier");
            let stack = match mode {
                0 => m.stacked_u(),
                1 => m.stacked_v(),
                _ => m.stacked_w(),
            };
            let rhs = Matrix::vstack(facs);
            crate::linalg::qr_solve(&stack, &rhs)
                .context("stacked least squares (QR fallback)")
        }
        MapTier::Procedural => bail!(
            "{why} for mode {mode} and the procedural tier has no dense fallback; \
             rerun with map_tier=materialized or more replicas"
        ),
    }
}

/// The dense path: streamed Gram accumulation + one Cholesky solve.
/// Panel pairs cover the Gram's upper block triangle; the lower mirrors
/// by symmetry.
fn recover_mode_cholesky(
    facs: &[&Matrix],
    maps: &MapSource,
    mode: usize,
    dim: usize,
    w: usize,
) -> Result<Matrix> {
    let rank = facs[0].cols();
    let mut gram = Matrix::zeros(dim, dim);
    let mut atb = Matrix::zeros(dim, rank);
    let (mut buf_a, mut buf_b) = (Vec::new(), Vec::new());
    for (p, fac) in facs.iter().enumerate() {
        let mut a0 = 0;
        while a0 < dim {
            let a1 = (a0 + w).min(dim);
            let pan_a = maps.panel(p, mode, a0, a1, std::mem::take(&mut buf_a));
            add_block(&mut atb, a0, 0, &matmul(&pan_a, Trans::Yes, fac, Trans::No));
            add_block(&mut gram, a0, a0, &matmul(&pan_a, Trans::Yes, &pan_a, Trans::No));
            let mut b0 = a1;
            while b0 < dim {
                let b1 = (b0 + w).min(dim);
                let pan_b = maps.panel(p, mode, b0, b1, std::mem::take(&mut buf_b));
                let blk = matmul(&pan_a, Trans::Yes, &pan_b, Trans::No);
                add_block(&mut gram, a0, b0, &blk);
                add_block_transposed(&mut gram, b0, a0, &blk);
                buf_b = pan_b.into_vec();
                b0 = b1;
            }
            buf_a = pan_a.into_vec();
            a0 = a1;
        }
    }
    match cholesky_solve(&gram, &atb) {
        Ok(x) if x.data().iter().all(|v| v.is_finite()) => Ok(x),
        // The Gaussian stacked map is well-conditioned with overwhelming
        // probability, so this path is defensive.
        _ => dense_fallback(facs, maps, mode, "stacked Gram not positive definite"),
    }
}

/// One streamed pass accumulating what CGNR needs up front: the Gram
/// diagonal (per-column norms² of the stacked map) and the right-hand
/// side `AᵀB = Σ_p U_pᵀA_p`.
fn accumulate_diag_atb(
    facs: &[&Matrix],
    maps: &MapSource,
    mode: usize,
    dim: usize,
    w: usize,
) -> (Vec<f32>, Matrix) {
    let rank = facs[0].cols();
    let mut diag = vec![0.0f32; dim];
    let mut atb = Matrix::zeros(dim, rank);
    let mut buf = Vec::new();
    for (p, fac) in facs.iter().enumerate() {
        let mut a0 = 0;
        while a0 < dim {
            let a1 = (a0 + w).min(dim);
            let pan = maps.panel(p, mode, a0, a1, std::mem::take(&mut buf));
            add_block(&mut atb, a0, 0, &matmul(&pan, Trans::Yes, fac, Trans::No));
            for c in 0..pan.cols() {
                diag[a0 + c] += pan.col(c).iter().map(|&v| v * v).sum::<f32>();
            }
            buf = pan.into_vec();
            a0 = a1;
        }
    }
    (diag, atb)
}

/// The matrix-free path: CGNR whose operator `y ← AᵀA·x` is two streamed
/// panel passes per replica (`t_p = U_p·x` then `y += U_pᵀ·t_p`) — the
/// `dim×dim` Gram never exists.
fn recover_mode_iterative(
    facs: &[&Matrix],
    maps: &MapSource,
    mode: usize,
    dim: usize,
    w: usize,
    opts: &RecoveryOptions,
    stats: &mut RecoveryStats,
) -> Result<Matrix> {
    let l = maps.reduced()[mode];
    let (diag, atb) = accumulate_diag_atb(facs, maps, mode, dim, w);
    let p_count = maps.p_count();
    let mut buf = Vec::new();
    let mut t = vec![0.0f32; l];
    let mut apply = |x: &[f32], y: &mut [f32]| {
        y.fill(0.0);
        for p in 0..p_count {
            t.fill(0.0);
            let mut a0 = 0;
            while a0 < dim {
                let a1 = (a0 + w).min(dim);
                let pan = maps.panel(p, mode, a0, a1, std::mem::take(&mut buf));
                for (ti, v) in t.iter_mut().zip(matvec(&pan, Trans::No, &x[a0..a1])) {
                    *ti += v;
                }
                buf = pan.into_vec();
                a0 = a1;
            }
            let mut a0 = 0;
            while a0 < dim {
                let a1 = (a0 + w).min(dim);
                let pan = maps.panel(p, mode, a0, a1, std::mem::take(&mut buf));
                for (yi, v) in y[a0..a1].iter_mut().zip(matvec(&pan, Trans::Yes, &t)) {
                    *yi += v;
                }
                buf = pan.into_vec();
                a0 = a1;
            }
        }
    };
    let out = cg_normal_solve(&mut apply, &diag, &atb, None, &opts.cg)?;
    stats.cg_iterations += out.iterations;
    if out.x.data().iter().all(|v| v.is_finite()) {
        Ok(out.x)
    } else {
        dense_fallback(facs, maps, mode, "CGNR produced non-finite iterates")
    }
}

/// Dedicated keying domain for the recovery sketch (disjoint from the
/// replica-map keys, which hash `(map seed, replica, mode, row, col)`).
const SKETCH_SEED: u64 = 0x5ca1_ab1e_0f0e_7c31;

/// The randomized path: sketch the stacked system with a counter-rng
/// Gaussian `S (s × P·L)`, `s = dim + 4·rank + 16`, solve the small dense
/// `min ‖(SA)·x − (SB)‖`, then polish with warm-started CG against the
/// *unsketched* operator.  Peak memory is `O(s·dim)` — same order as the
/// Gram, which is why `Auto` never resolves here (this is the refine /
/// experimentation path, per Erichson et al.).
fn recover_mode_sketch(
    facs: &[&Matrix],
    maps: &MapSource,
    mode: usize,
    dim: usize,
    w: usize,
    opts: &RecoveryOptions,
    stats: &mut RecoveryStats,
) -> Result<Matrix> {
    let l = maps.reduced()[mode];
    let rank = facs[0].cols();
    let s_rows = MemoryPlanner::sketch_rows(dim, rank);
    let scale = 1.0 / (s_rows as f32).sqrt();
    let mut sa = Matrix::zeros(s_rows, dim);
    let mut sb = Matrix::zeros(s_rows, rank);
    let mut buf = Vec::new();
    for (p, fac) in facs.iter().enumerate() {
        // This replica's s×L sketch block, generated on demand and dropped
        // after use — entry (i, row) keys on (replica, sketch row, map
        // row, mode) so every tier and panel width sees the same sketch.
        let s_blk = Matrix::from_fn(s_rows, l, |i, row| {
            scale
                * gaussian_from_key(counter_key(
                    SKETCH_SEED,
                    p as u64,
                    i as u64,
                    row as u64,
                    mode as u64,
                ))
        });
        add_block(&mut sb, 0, 0, &matmul(&s_blk, Trans::No, fac, Trans::No));
        let mut a0 = 0;
        while a0 < dim {
            let a1 = (a0 + w).min(dim);
            let pan = maps.panel(p, mode, a0, a1, std::mem::take(&mut buf));
            add_block(&mut sa, 0, a0, &matmul(&s_blk, Trans::No, &pan, Trans::No));
            buf = pan.into_vec();
            a0 = a1;
        }
    }
    let sketched = match lstsq(&sa, &sb) {
        Ok(x) if x.data().iter().all(|v| v.is_finite()) => x,
        _ => return dense_fallback(facs, maps, mode, "sketched solve degenerated"),
    };
    // Polish against the true operator: the sketch solution is within
    // O(ε_sketch) of the minimizer, so warm-started CG needs few
    // iterations to reach solver tolerance.
    drop(sa);
    let (diag, atb) = accumulate_diag_atb(facs, maps, mode, dim, w);
    let p_count = maps.p_count();
    let mut t = vec![0.0f32; l];
    let mut apply = |x: &[f32], y: &mut [f32]| {
        y.fill(0.0);
        for p in 0..p_count {
            t.fill(0.0);
            let mut a0 = 0;
            while a0 < dim {
                let a1 = (a0 + w).min(dim);
                let pan = maps.panel(p, mode, a0, a1, std::mem::take(&mut buf));
                for (ti, v) in t.iter_mut().zip(matvec(&pan, Trans::No, &x[a0..a1])) {
                    *ti += v;
                }
                buf = pan.into_vec();
                a0 = a1;
            }
            let mut a0 = 0;
            while a0 < dim {
                let a1 = (a0 + w).min(dim);
                let pan = maps.panel(p, mode, a0, a1, std::mem::take(&mut buf));
                for (yi, v) in y[a0..a1].iter_mut().zip(matvec(&pan, Trans::Yes, &t)) {
                    *yi += v;
                }
                buf = pan.into_vec();
                a0 = a1;
            }
        }
    };
    let out = cg_normal_solve(&mut apply, &diag, &atb, Some(&sketched), &opts.cg)?;
    stats.cg_iterations += out.iterations;
    if out.x.data().iter().all(|v| v.is_finite()) {
        Ok(out.x)
    } else {
        dense_fallback(facs, maps, mode, "sketch polish produced non-finite iterates")
    }
}

/// Solves the stacked least squares (Eq. 4) for all three modes by
/// **streaming column panels** of the stacked maps — no `P·L × I` matrix is
/// ever materialized, so recovery works unchanged for both map tiers.  The
/// per-mode solver and panel width come from `opts`; returns the model plus
/// per-run [`RecoveryStats`].
///
/// `aligned` are the anchor-normalized, permutation-aligned replica models,
/// one per kept replica of `maps` (same order).
pub fn stacked_recover_opts(
    aligned: &[CpModel],
    maps: &MapSource,
    opts: &RecoveryOptions,
) -> Result<(CpModel, RecoveryStats)> {
    if aligned.is_empty() {
        bail!("no aligned replicas to recover from");
    }
    if aligned.len() != maps.p_count() {
        bail!(
            "{} aligned replicas but {} kept maps — subset the maps to match",
            aligned.len(),
            maps.p_count()
        );
    }
    let mut stats = RecoveryStats::default();
    let a = recover_mode(aligned, maps, 0, |m| &m.a, opts, &mut stats)?;
    let b = recover_mode(aligned, maps, 1, |m| &m.b, opts, &mut stats)?;
    let c = recover_mode(aligned, maps, 2, |m| &m.c, opts, &mut stats)?;
    Ok((CpModel::new(a, b, c), stats))
}

/// [`stacked_recover_opts`] with the default (Cholesky) options — the
/// historical entry point, kept so existing callers and the differential
/// tests stay byte-for-byte unchanged.
pub fn stacked_recover(aligned: &[CpModel], maps: &MapSource) -> Result<CpModel> {
    stacked_recover_opts(aligned, maps, &RecoveryOptions::default()).map(|(m, _)| m)
}

/// The retired materializing solve — `vstack` the maps and factors, then
/// one dense [`lstsq`] per mode.  Kept **only** as the differential oracle
/// for the panel-streamed [`stacked_recover`] (its peak memory is the
/// `P·L × I` stack this refactor eliminates).
#[doc(hidden)]
pub fn stacked_recover_vstack(aligned: &[CpModel], maps: &ReplicaMaps) -> Result<CpModel> {
    if aligned.is_empty() {
        bail!("no aligned replicas to recover from");
    }
    let per_mode = |stack_map: Matrix, factors: Vec<&Matrix>| -> Result<Matrix> {
        let stacked = Matrix::vstack(&factors);
        if stack_map.rows() < stack_map.cols() {
            bail!(
                "stacked system underdetermined: {}×{} (need P·L ≥ dim)",
                stack_map.rows(),
                stack_map.cols()
            );
        }
        lstsq(&stack_map, &stacked).context("stacked least squares")
    };
    let a = per_mode(maps.stacked_u(), aligned.iter().map(|m| &m.a).collect())?;
    let b = per_mode(maps.stacked_v(), aligned.iter().map(|m| &m.b).collect())?;
    let c = per_mode(maps.stacked_w(), aligned.iter().map(|m| &m.c).collect())?;
    Ok(CpModel::new(a, b, c))
}

/// Top-`b` row indices of a factor matrix by row energy (L2), sorted —
/// the rows where the sampled disambiguation subtensor actually carries
/// signal (the *leading* corner of a sparse/gene tensor is often ~zero).
pub fn select_energy_rows(m: &Matrix, b: usize) -> Vec<usize> {
    let mut scored: Vec<(f64, usize)> = (0..m.rows())
        .map(|row| {
            let e: f64 = (0..m.cols())
                .map(|c| {
                    let v = m.get(row, c) as f64;
                    v * v
                })
                .sum();
            (e, row)
        })
        .collect();
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut rows: Vec<usize> = scored.into_iter().take(b.min(m.rows())).map(|(_, r)| r).collect();
    rows.sort_unstable();
    rows
}

/// Gathers the subtensor `X[rows_i × rows_j × rows_k]` from a source via
/// singleton block reads (the index sets are small: b ≈ 4·R).
pub fn gather_subtensor(
    src: &dyn crate::tensor::TensorSource,
    rows_i: &[usize],
    rows_j: &[usize],
    rows_k: &[usize],
) -> DenseTensor {
    use crate::tensor::BlockRange;
    let mut t = DenseTensor::zeros(rows_i.len(), rows_j.len(), rows_k.len());
    for (kk, &k) in rows_k.iter().enumerate() {
        for (jj, &j) in rows_j.iter().enumerate() {
            // one mode-1 run per (j,k) if rows_i were contiguous; general
            // case: singleton reads.
            for (ii, &i) in rows_i.iter().enumerate() {
                let blk = src.block(&BlockRange {
                    i0: i,
                    i1: i + 1,
                    j0: j,
                    j1: j + 1,
                    k0: k,
                    k1: k + 1,
                    index: 0,
                });
                t.set(ii, jj, kk, blk.get(0, 0, 0));
            }
        }
    }
    t
}

/// Joint column matching between the corner decomposition and the
/// *sampled rows* of the recovered factors: similarity is the product of
/// per-mode absolute cosines (consistent across modes by construction).
fn joint_match(tilde: &CpModel, hat: &CpModel, rows: [&[usize]; 3]) -> Vec<usize> {
    let r = tilde.rank();
    let cos = |t: &Matrix, h: &Matrix, idx: &[usize], i: usize, j: usize| -> f64 {
        let (mut dot, mut nt, mut nh) = (0.0f64, 0.0f64, 0.0f64);
        for (hrow, &trow) in idx.iter().enumerate() {
            let x = t.get(trow, j) as f64;
            let y = h.get(hrow, i) as f64;
            dot += x * y;
            nt += x * x;
            nh += y * y;
        }
        if nt == 0.0 || nh == 0.0 {
            0.0
        } else {
            (dot / (nt.sqrt() * nh.sqrt())).abs()
        }
    };
    let sim = Matrix::from_fn(r, r, |i, j| {
        (cos(&tilde.a, &hat.a, rows[0], i, j)
            * cos(&tilde.b, &hat.b, rows[1], i, j)
            * cos(&tilde.c, &hat.c, rows[2], i, j)) as f32
    });
    // rows = hat columns, cols = tilde columns: perm[hat_col] = tilde_col.
    hungarian_max(&sim).col_of_row
}

/// Per-column signed scale `s` minimizing `‖t_lead − s·h‖`:
/// `s = ⟨h, t_lead⟩ / ⟨h, h⟩`.
fn lead_scale(tilde_col: &[f32], hat_col: &[f32]) -> f32 {
    let n = hat_col.len().min(tilde_col.len());
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for row in 0..n {
        num += hat_col[row] as f64 * tilde_col[row] as f64;
        den += hat_col[row] as f64 * hat_col[row] as f64;
    }
    if den == 0.0 {
        1.0
    } else {
        (num / den) as f32
    }
}

/// Removes the final `Π Σ` ambiguity (Alg. 2 lines 10–13): decomposes the
/// sampled subtensor `corner = X[rows_i × rows_j × rows_k]` directly,
/// matches columns jointly across modes, and rescales each recovered
/// column so its sampled rows agree with the corner factors.  Returns the
/// fully disambiguated model (columns in the corner decomposition's
/// order).  Pass `rows = [0..b)` per mode for the paper-literal leading
/// corner; the pipeline passes energy-selected rows so sparse tensors
/// sample signal rather than zeros.
pub fn corner_disambiguate(
    tilde: &CpModel,
    corner: &DenseTensor,
    rows: [&[usize]; 3],
    als: &AlsOptions,
) -> Result<CpModel> {
    let r = tilde.rank();
    assert_eq!(corner.dims()[0], rows[0].len());
    assert_eq!(corner.dims()[1], rows[1].len());
    assert_eq!(corner.dims()[2], rows[2].len());
    let (hat, trace) = als_decompose(corner, als).context("corner ALS")?;
    let fit = trace.fits.last().copied().unwrap_or(0.0);
    if fit < 0.5 {
        bail!("corner decomposition failed to converge (fit {fit:.3}); enlarge the corner");
    }
    let perm = joint_match(tilde, &hat, rows);

    let rescale = |t: &Matrix, h: &Matrix, idx: &[usize]| -> Matrix {
        let mut out = Matrix::zeros(t.rows(), r);
        for hat_col in 0..r {
            let t_col = perm[hat_col];
            let lead: Vec<f32> = idx.iter().map(|&row| t.get(row, t_col)).collect();
            let hvec: Vec<f32> = (0..idx.len()).map(|row| h.get(row, hat_col)).collect();
            let s = lead_scale(&lead, &hvec);
            let inv = if s.abs() < 1e-20 { 0.0 } else { 1.0 / s };
            for row in 0..t.rows() {
                out.set(row, hat_col, t.get(row, t_col) * inv);
            }
        }
        out
    };
    Ok(CpModel::new(
        rescale(&tilde.a, &hat.a, rows[0]),
        rescale(&tilde.b, &hat.b, rows[1]),
        rescale(&tilde.c, &hat.c, rows[2]),
    ))
}

/// Entry-sampling scale calibration.
///
/// After alignment + stacking, `tilde` has a *consistent* column
/// correspondence across modes but unknown per-mode diagonal scalings; for
/// reconstruction only the per-component scale product matters.  We pick,
/// per component, the largest-|·| rows of each mode factor (plus random
/// extras for conditioning), read those entries of `X` from the source
/// (1×1×1 block reads), and solve the linear least squares
/// `X(i,j,k) ≈ Σ_r λ_r ã_ir b̃_jr c̃_kr` for `λ`, absorbing `λ` into mode 1.
///
/// This replaces the corner decomposition (Alg. 2 lines 10–13) when the
/// sampled corner is degenerate — e.g. *sparse* tensors, whose leading
/// corner is usually all-zero (documented substitution, DESIGN.md) — and
/// serves as a cheap polish after it otherwise.
pub fn entry_calibrate(
    tilde: &CpModel,
    src: &dyn crate::tensor::TensorSource,
    extra_samples: usize,
    seed: u64,
) -> Result<CpModel> {
    use crate::tensor::BlockRange;
    use crate::util::rng::Xoshiro256;
    let r = tilde.rank();
    let [i_dim, j_dim, k_dim] = src.dims();
    let mut rng = Xoshiro256::seed_from_u64(seed);

    // Candidate rows per mode: per-component argmax + random extras.
    let top_rows = |m: &Matrix, dim: usize, rng: &mut Xoshiro256| -> Vec<usize> {
        let mut rows: Vec<usize> = (0..r)
            .map(|c| {
                let mut best = (0usize, 0.0f32);
                for row in 0..m.rows().min(dim) {
                    let v = m.get(row, c).abs();
                    if v > best.1 {
                        best = (row, v);
                    }
                }
                best.0
            })
            .collect();
        for _ in 0..extra_samples {
            rows.push(rng.next_below(dim as u64) as usize);
        }
        rows.sort_unstable();
        rows.dedup();
        rows
    };
    let ri = top_rows(&tilde.a, i_dim, &mut rng);
    let rj = top_rows(&tilde.b, j_dim, &mut rng);
    let rk = top_rows(&tilde.c, k_dim, &mut rng);

    // Assemble the system: one equation per sampled entry.
    let n_eq = ri.len() * rj.len() * rk.len();
    let mut design = Matrix::zeros(n_eq, r);
    let mut rhs = Matrix::zeros(n_eq, 1);
    let mut e = 0usize;
    for &i in &ri {
        for &j in &rj {
            for &k in &rk {
                let blk = src.block(&BlockRange {
                    i0: i,
                    i1: i + 1,
                    j0: j,
                    j1: j + 1,
                    k0: k,
                    k1: k + 1,
                    index: e,
                });
                rhs.set(e, 0, blk.get(0, 0, 0));
                for c in 0..r {
                    design.set(e, c, tilde.a.get(i, c) * tilde.b.get(j, c) * tilde.c.get(k, c));
                }
                e += 1;
            }
        }
    }
    if n_eq < r {
        bail!("entry calibration: {n_eq} samples < rank {r}");
    }
    let lambda = lstsq(&design, &rhs).context("entry calibration lstsq")?;
    let scales: Vec<f32> = (0..r).map(|c| lambda.get(c, 0)).collect();
    Ok(CpModel::new(
        tilde.a.scale_cols(&scales),
        tilde.b.clone(),
        tilde.c.clone(),
    ))
}

/// §IV-D second stage: given `Ỹ = U·(AΠΣ)` recovered from the stacked solve
/// over the *sensing-expanded* space (`U (αL×I)` sparse, so the system per
/// column is underdetermined), recover `AΠΣ` column-wise with L1-penalized
/// least squares (ISTA) — the factor columns of sparse tensors are
/// compressible, which is what makes this well-posed.
pub fn sensing_recover_mode(
    u_sparse: &SparseSignMatrix,
    tilde_compressed: &Matrix,
    opts: &IstaOptions,
) -> Matrix {
    let u_dense = u_sparse.to_dense(); // αL × I
    let i_dim = u_dense.cols();
    let mut out = Matrix::zeros(i_dim, tilde_compressed.cols());
    // Per column: λ is *relative* — `opts.lambda · ‖Uᵀy‖_∞` (λ_max scaling),
    // so recovery is invariant to the column's unknown Σ scale.
    for col in 0..tilde_compressed.cols() {
        let rhs = Matrix::from_fn(tilde_compressed.rows(), 1, |r, _| tilde_compressed.get(r, col));
        let atb = {
            use crate::linalg::backend::{ComputeBackend, SerialBackend};
            use crate::linalg::Trans;
            SerialBackend.matmul(&u_dense, Trans::Yes, &rhs, Trans::No)
        };
        let lam_max = atb.max_abs();
        if lam_max == 0.0 {
            continue;
        }
        let col_opts = IstaOptions {
            lambda: opts.lambda * lam_max,
            ..opts.clone()
        };
        let (x, _iters) = ista_l1(&u_dense, &rhs, &col_opts);
        // Hard-threshold relative to the column max, then debias with an
        // unregularized least squares on the support (LASSO debiasing).
        let xmax = x.max_abs();
        let support: Vec<usize> = (0..i_dim)
            .filter(|&i| x.get(i, 0).abs() > 0.02 * xmax)
            .collect();
        if support.is_empty() || support.len() > u_dense.rows() {
            for i in 0..i_dim {
                out.set(i, col, x.get(i, 0));
            }
            continue;
        }
        let sub = Matrix::from_fn(u_dense.rows(), support.len(), |r, c| {
            u_dense.get(r, support[c])
        });
        match lstsq(&sub, &rhs) {
            Ok(coef) => {
                for (c, &i) in support.iter().enumerate() {
                    out.set(i, col, coef.get(c, 0));
                }
            }
            Err(_) => {
                for i in 0..i_dim {
                    out.set(i, col, x.get(i, 0));
                }
            }
        }
    }
    out
}

/// Convenience used by the pipeline: normalize + align in one pass,
/// dropping replicas whose anchor blocks degenerate (paper pads `P` by +10
/// precisely to tolerate such drops).  Returns the aligned models and the
/// **kept replica indices** (same order), so callers can subset the
/// compression maps to match before the stacked solve.
pub fn normalize_and_align(
    models: Vec<(usize, CpModel)>,
    anchor_rows: usize,
) -> Result<(Vec<CpModel>, Vec<usize>)> {
    normalize_and_align_min(models, anchor_rows, 0)
}

/// As [`normalize_and_align`], but guarantees at least `min_keep` replicas
/// survive (best-scoring first) even when anchor matches are poor — on
/// tensors that are only *approximately* low rank every replica matches
/// imperfectly, and dropping below the identifiability bound would kill
/// the stacked solve entirely.
pub fn normalize_and_align_min(
    models: Vec<(usize, CpModel)>,
    anchor_rows: usize,
    min_keep: usize,
) -> Result<(Vec<CpModel>, Vec<usize>)> {
    use super::matching::align_to_reference;
    // Normalize all; mark failures.
    let mut normalized: Vec<(usize, CpModel)> = Vec::with_capacity(models.len());
    for (idx, mut m) in models {
        if anchor_normalize(&mut m, anchor_rows).is_ok() {
            normalized.push((idx, m));
        }
    }
    let reference = normalized
        .first()
        .map(|(_, m)| m.clone())
        .context("every replica failed anchor normalization")?;
    // Score every replica; a poor anchor match means its components don't
    // correspond to the reference's (e.g. ALS merged two components).
    let mut scored: Vec<(f64, usize, CpModel)> = Vec::new();
    for (idx, m) in normalized {
        if let Ok((am, report)) = align_to_reference(&reference, &m, anchor_rows) {
            scored.push((report.match_score, idx, am));
        }
    }
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut aligned = Vec::new();
    let mut kept = Vec::new();
    for (rank_pos, (score, idx, am)) in scored.into_iter().enumerate() {
        if score > 0.97 || rank_pos < min_keep {
            aligned.push(am);
            kept.push(idx);
        }
    }
    Ok((aligned, kept))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    /// Builds the exact compressed models `A_p = U_p A` (no ALS noise) to
    /// test the algebra of recovery in isolation.  Works for either tier —
    /// maps are read through whole-map panels.
    fn exact_replica_models(truth: &CpModel, maps: &MapSource) -> Vec<CpModel> {
        let [i, j, k] = maps.dims();
        (0..maps.p_count())
            .map(|p| {
                let u = maps.panel(p, 0, 0, i, Vec::new());
                let v = maps.panel(p, 1, 0, j, Vec::new());
                let w = maps.panel(p, 2, 0, k, Vec::new());
                CpModel::new(
                    matmul(&u, Trans::No, &truth.a, Trans::No),
                    matmul(&v, Trans::No, &truth.b, Trans::No),
                    matmul(&w, Trans::No, &truth.c, Trans::No),
                )
            })
            .collect()
    }

    fn truth_model(dims: [usize; 3], rank: usize, seed: u64) -> CpModel {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        CpModel::new(
            Matrix::random_normal(dims[0], rank, &mut rng),
            Matrix::random_normal(dims[1], rank, &mut rng),
            Matrix::random_normal(dims[2], rank, &mut rng),
        )
    }

    #[test]
    fn stacked_recovery_inverts_exact_compression() {
        // Rank of the stacked map is S + P(L−S) = 4 + 8·4 = 36 ≥ 30.
        let dims = [30, 28, 26];
        let truth = truth_model(dims, 3, 300);
        let maps = MapSource::generate(dims, [8, 8, 8], 8, 4, 301, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        // With exact (unpermuted, unscaled) replicas, stacked recovery must
        // reproduce the factors exactly.
        let rec = stacked_recover(&models, &maps).unwrap();
        assert!(rec.a.rel_error(&truth.a) < 1e-3, "A err {}", rec.a.rel_error(&truth.a));
        assert!(rec.b.rel_error(&truth.b) < 1e-3);
        assert!(rec.c.rel_error(&truth.c) < 1e-3);
    }

    #[test]
    fn stacked_recovery_rejects_underdetermined() {
        let dims = [100, 10, 10];
        let truth = truth_model(dims, 2, 302);
        // 2·5 < 100: the stacked system cannot determine mode 1.
        let maps = MapSource::generate(dims, [5, 5, 5], 2, 3, 303, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        assert!(stacked_recover(&models, &maps).is_err());
    }

    #[test]
    fn streamed_recovery_matches_vstack_oracle() {
        // The panel-streamed normal-equation solve vs the retired
        // materializing lstsq: same system, so the minimizers agree to
        // numerical precision — without the P·L×I stack ever existing.
        // dim 300 > RECOVERY_PANEL_COLS=256 exercises the multi-panel
        // (off-diagonal Gram block) path.
        // Stacked-map column rank is S + P(L−S) = 4 + 40·8 = 324 ≥ 300.
        let dims = [300, 40, 30];
        let truth = truth_model(dims, 3, 320);
        let maps = MapSource::generate(dims, [12, 10, 9], 40, 4, 321, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        let streamed = stacked_recover(&models, &maps).unwrap();
        let oracle =
            stacked_recover_vstack(&models, maps.materialized().unwrap()).unwrap();
        let a_err = streamed.a.rel_error(&oracle.a);
        assert!(a_err < 1e-3, "A err {a_err}");
        assert!(streamed.b.rel_error(&oracle.b) < 1e-3);
        assert!(streamed.c.rel_error(&oracle.c) < 1e-3);
    }

    #[test]
    fn streamed_recovery_is_tier_bitwise_invariant() {
        // Stacked-map column rank is S + P(L−S) = 3 + 12·6 = 75 ≥ 60.
        let dims = [60, 50, 40];
        let truth = truth_model(dims, 2, 322);
        let mat = MapSource::generate(dims, [9, 9, 9], 12, 3, 323, MapTier::Materialized);
        let proc_ = MapSource::generate(dims, [9, 9, 9], 12, 3, 323, MapTier::Procedural);
        let models = exact_replica_models(&truth, &mat);
        let a = stacked_recover(&models, &mat).unwrap();
        let b = stacked_recover(&models, &proc_).unwrap();
        assert_eq!(a.a.data(), b.a.data());
        assert_eq!(a.b.data(), b.b.data());
        assert_eq!(a.c.data(), b.c.data());
    }

    #[test]
    fn iterative_recovery_matches_cholesky_and_oracle() {
        // dim 300 > default panel 256 exercises multi-panel streaming in
        // the CG matvec; exact replicas make the stacked system consistent,
        // so CGNR and the dense solvers agree to solver tolerance.
        let dims = [300, 40, 30];
        let truth = truth_model(dims, 3, 320);
        let maps = MapSource::generate(dims, [12, 10, 9], 40, 4, 321, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        let opts = RecoveryOptions::with_solver(RecoverySolverKind::Iterative);
        let (iter, stats) = stacked_recover_opts(&models, &maps, &opts).unwrap();
        assert!(stats.cg_iterations > 0);
        let chol = stacked_recover(&models, &maps).unwrap();
        let oracle =
            stacked_recover_vstack(&models, maps.materialized().unwrap()).unwrap();
        for (got, want) in [(&iter.a, &chol.a), (&iter.b, &chol.b), (&iter.c, &chol.c)] {
            let err = got.rel_error(want);
            assert!(err < 1e-3, "iterative vs cholesky err {err}");
        }
        assert!(iter.a.rel_error(&oracle.a) < 1e-3);
        assert!(iter.b.rel_error(&oracle.b) < 1e-3);
        assert!(iter.c.rel_error(&oracle.c) < 1e-3);
    }

    #[test]
    fn iterative_recovery_is_tier_bitwise_invariant() {
        // Panels are bitwise identical across tiers, the accumulation order
        // is fixed, and CG is deterministic — so the iterative path inherits
        // the tier-interchangeability guarantee bit for bit.
        let dims = [60, 50, 40];
        let truth = truth_model(dims, 2, 322);
        let mat = MapSource::generate(dims, [9, 9, 9], 12, 3, 323, MapTier::Materialized);
        let proc_ = MapSource::generate(dims, [9, 9, 9], 12, 3, 323, MapTier::Procedural);
        let models = exact_replica_models(&truth, &mat);
        let opts = RecoveryOptions::with_solver(RecoverySolverKind::Iterative);
        let (a, _) = stacked_recover_opts(&models, &mat, &opts).unwrap();
        let (b, _) = stacked_recover_opts(&models, &proc_, &opts).unwrap();
        assert_eq!(a.a.data(), b.a.data());
        assert_eq!(a.b.data(), b.b.data());
        assert_eq!(a.c.data(), b.c.data());
    }

    #[test]
    fn iterative_recovery_is_panel_width_insensitive() {
        // Different panel widths change the matvec accumulation splits (and
        // so the f32 rounding), but the minimizer is the same.
        let dims = [60, 50, 40];
        let truth = truth_model(dims, 2, 322);
        let maps = MapSource::generate(dims, [9, 9, 9], 12, 3, 323, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        let narrow = RecoveryOptions {
            panel_cols: 7,
            ..RecoveryOptions::with_solver(RecoverySolverKind::Iterative)
        };
        let (a, _) = stacked_recover_opts(&models, &maps, &narrow).unwrap();
        let (b, _) = stacked_recover_opts(
            &models,
            &maps,
            &RecoveryOptions::with_solver(RecoverySolverKind::Iterative),
        )
        .unwrap();
        assert!(a.a.rel_error(&b.a) < 1e-4, "A err {}", a.a.rel_error(&b.a));
        assert!(a.b.rel_error(&b.b) < 1e-4);
        assert!(a.c.rel_error(&b.c) < 1e-4);
    }

    #[test]
    fn sketch_recovery_matches_cholesky() {
        let dims = [80, 40, 30];
        let truth = truth_model(dims, 3, 330);
        let maps = MapSource::generate(dims, [12, 10, 9], 12, 4, 331, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        let opts = RecoveryOptions::with_solver(RecoverySolverKind::Sketch);
        let (sk, _) = stacked_recover_opts(&models, &maps, &opts).unwrap();
        let chol = stacked_recover(&models, &maps).unwrap();
        // The CG polish runs after the sketch, so agreement is at solver
        // tolerance, not just sketch tolerance.
        assert!(sk.a.rel_error(&chol.a) < 1e-3, "A err {}", sk.a.rel_error(&chol.a));
        assert!(sk.b.rel_error(&chol.b) < 1e-3);
        assert!(sk.c.rel_error(&chol.c) < 1e-3);
    }

    #[test]
    fn near_square_recovery_agrees_across_solvers() {
        // col_rank_bound = S + P(L−S) = 4 + 8·4 = 36 vs dim 34: barely
        // overdetermined, the worst-conditioned regime the identifiability
        // check admits.  All three solvers (and the vstack oracle) must
        // still agree — the consistent system keeps CGNR's residual honest
        // even when the Gram is nearly singular.
        let dims = [34, 20, 20];
        let truth = truth_model(dims, 2, 340);
        let maps = MapSource::generate(dims, [8, 8, 8], 8, 4, 341, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        let chol = stacked_recover(&models, &maps).unwrap();
        let (iter, _) = stacked_recover_opts(
            &models,
            &maps,
            &RecoveryOptions::with_solver(RecoverySolverKind::Iterative),
        )
        .unwrap();
        let (sk, _) = stacked_recover_opts(
            &models,
            &maps,
            &RecoveryOptions::with_solver(RecoverySolverKind::Sketch),
        )
        .unwrap();
        let oracle =
            stacked_recover_vstack(&models, maps.materialized().unwrap()).unwrap();
        for m in [&chol, &iter, &sk] {
            assert!(m.a.rel_error(&oracle.a) < 5e-2, "A err {}", m.a.rel_error(&oracle.a));
            assert!(m.b.rel_error(&oracle.b) < 5e-2);
            assert!(m.c.rel_error(&oracle.c) < 5e-2);
        }
    }

    #[test]
    fn recovery_stats_flag_solver_work() {
        let dims = [30, 28, 26];
        let truth = truth_model(dims, 3, 300);
        let maps = MapSource::generate(dims, [8, 8, 8], 8, 4, 301, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        let (_, chol_stats) =
            stacked_recover_opts(&models, &maps, &RecoveryOptions::default()).unwrap();
        assert_eq!(chol_stats.cg_iterations, 0);
        let (_, iter_stats) = stacked_recover_opts(
            &models,
            &maps,
            &RecoveryOptions::with_solver(RecoverySolverKind::Iterative),
        )
        .unwrap();
        assert!(iter_stats.cg_iterations > 0);
    }

    #[test]
    fn iterative_recovery_rejects_rank_deficiency_up_front() {
        // The identifiability checks run before solver dispatch, so the
        // ridge-damped CG can never paper over an underdetermined system.
        let dims = [100, 10, 10];
        let truth = truth_model(dims, 2, 302);
        let maps = MapSource::generate(dims, [5, 5, 5], 2, 3, 303, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        let opts = RecoveryOptions::with_solver(RecoverySolverKind::Iterative);
        assert!(stacked_recover_opts(&models, &maps, &opts).is_err());
    }

    #[test]
    fn recovery_rejects_mismatched_replica_count() {
        let dims = [20, 20, 20];
        let truth = truth_model(dims, 2, 324);
        let maps = MapSource::generate(dims, [8, 8, 8], 4, 3, 325, MapTier::Materialized);
        let models = exact_replica_models(&truth, &maps);
        // Dropping a model without subsetting the maps must fail loudly.
        assert!(stacked_recover(&models[..3], &maps).is_err());
    }

    #[test]
    fn normalize_and_align_with_planted_perms() {
        let dims = [24, 24, 24];
        let truth = truth_model(dims, 3, 304);
        let maps = MapSource::generate(dims, [8, 8, 8], 5, 4, 305, MapTier::Materialized);
        let mut models = exact_replica_models(&truth, &maps);
        // Scramble replicas 1.. with per-replica permutation and scales.
        let perms = [[1usize, 2, 0], [2, 0, 1], [0, 2, 1], [1, 0, 2]];
        for (idx, m) in models.iter_mut().enumerate().skip(1) {
            let perm = &perms[(idx - 1) % perms.len()];
            let scales = [1.7f32, -0.6, 2.3];
            m.a = m.a.permute_cols(perm).scale_cols(&scales);
            m.b = m.b.permute_cols(perm).scale_cols(&scales);
            m.c = m.c.permute_cols(perm).scale_cols(&scales);
        }
        let (aligned, kept) =
            normalize_and_align(models.into_iter().enumerate().collect(), 4).unwrap();
        // kept is score-ordered; all five replicas must survive.
        let mut sorted = kept.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
        assert_eq!(aligned.len(), 5);
        // aligned[i] pairs with maps.subset(&kept)[i].
        let rec = stacked_recover(&aligned, &maps.subset(&kept)).unwrap();
        // rec = A Π Σ for a common ΠΣ: congruence with truth must be ~1.
        let c = crate::cp::factor_congruence(&truth.a, &rec.a);
        assert!(c > 0.999, "congruence {c}");
    }

    #[test]
    fn corner_disambiguation_recovers_truth_exactly_scaled() {
        let dims = [20, 18, 16];
        let truth = truth_model(dims, 2, 306);
        // tilde = truth with a hidden permutation+scaling.
        let tilde = truth.permute_and_scale(&[1, 0], &[2.5, -1.25]);
        let corner_b = 8;
        let corner = DenseTensor::from_cp_factors(
            &truth.a.slice_rows(0, corner_b),
            &truth.b.slice_rows(0, corner_b),
            &truth.c.slice_rows(0, corner_b),
        );
        let rows: Vec<usize> = (0..corner_b).collect();
        let rec = corner_disambiguate(
            &tilde,
            &corner,
            [&rows, &rows, &rows],
            &AlsOptions {
                rank: 2,
                max_iters: 300,
                tol: 1e-13,
                seed: 7,
                ..Default::default()
            },
        )
        .unwrap();
        // Reconstruction must match the truth tensor.
        let t_truth = truth.to_tensor();
        let t_rec = rec.to_tensor();
        assert!(
            t_rec.rel_error(&t_truth) < 1e-2,
            "err {}",
            t_rec.rel_error(&t_truth)
        );
    }

    #[test]
    fn sensing_recovery_recovers_sparse_columns() {
        // Sparse factor column, sensed through a sparse JL map.
        let mut rng = Xoshiro256::seed_from_u64(307);
        let i_dim = 60;
        let u = SparseSignMatrix::generate(30, i_dim, 4, 308);
        let mut a = Matrix::zeros(i_dim, 2);
        for (col, rows) in [(0usize, [3usize, 20, 41]), (1usize, [7, 33, 55])].iter() {
            for &row in rows {
                a.set(row, *col, 1.0 + rng.next_gaussian().abs() as f32);
            }
        }
        let ua = u.mul_dense(&a);
        let rec = sensing_recover_mode(
            &u,
            &ua,
            &IstaOptions {
                lambda: 1e-3,
                max_iters: 3000,
                tol: 1e-10,
            },
        );
        assert!(rec.rel_error(&a) < 0.05, "err {}", rec.rel_error(&a));
    }

    #[test]
    fn full_pipeline_algebra_end_to_end() {
        // Exact algebra (no ALS on proxies): compress → scramble → align →
        // stack → corner-disambiguate must reproduce the planted tensor.
        let dims = [26, 26, 26];
        let truth = truth_model(dims, 2, 309);
        let maps = MapSource::generate(dims, [9, 9, 9], 4, 3, 310, MapTier::Materialized);
        let mut models = exact_replica_models(&truth, &maps);
        for (idx, m) in models.iter_mut().enumerate() {
            let perm = if idx % 2 == 0 { [1usize, 0] } else { [0usize, 1] };
            let scales = [1.0 + idx as f32, -(1.0 + idx as f32 / 2.0)];
            m.a = m.a.permute_cols(&perm).scale_cols(&scales);
            m.b = m.b.permute_cols(&perm).scale_cols(&scales);
            m.c = m.c.permute_cols(&perm).scale_cols(&scales);
        }
        let (aligned, kept) =
            normalize_and_align(models.into_iter().enumerate().collect(), 3).unwrap();
        let tilde = stacked_recover(&aligned, &maps.subset(&kept)).unwrap();
        let corner = DenseTensor::from_cp_factors(
            &truth.a.slice_rows(0, 8),
            &truth.b.slice_rows(0, 8),
            &truth.c.slice_rows(0, 8),
        );
        let rows: Vec<usize> = (0..8).collect();
        let rec = corner_disambiguate(
            &tilde,
            &corner,
            [&rows, &rows, &rows],
            &AlsOptions {
                rank: 2,
                max_iters: 300,
                tol: 1e-13,
                seed: 11,
                ..Default::default()
            },
        )
        .unwrap();
        let err = rec.to_tensor().rel_error(&truth.to_tensor());
        assert!(err < 1e-2, "end-to-end algebra err {err}");
    }
}
