//! Memory planner — §IV-D's motivation made executable.
//!
//! The recovery least squares needs `P ≥ (I−2)/(L−2)` replicas for
//! identifiability ([5] as cited by the paper), and the working set of the
//! pipeline is `P·L·M·N` proxy floats plus one block (and its stacked
//! mode-1 intermediate) per worker, the prefetch queue, and the stacked
//! LSTSQ operands.  The planner computes the replica count, checks the
//! total against a byte budget, and — if the budget is tight — shrinks the
//! block size, then the prefetch depth, before giving up.
//!
//! When the budget is smaller than the tensor's own byte size the plan is
//! **out-of-core**: the input can never be materialized, the streaming
//! stages must page blocks (a [`crate::tensor::FileTensorSource`] or
//! implicit generator), and prefetching defaults on so block reads overlap
//! the per-block TTM chains.

use super::config::{MapTierChoice, PipelineConfig, RecoverySolver, RecoverySolverKind};
use crate::compress::MapTier;
use anyhow::{bail, Result};

/// The resolved execution plan.
#[derive(Clone, Debug, PartialEq)]
pub struct MemoryPlan {
    pub replicas: usize,
    pub block: [usize; 3],
    pub corner: usize,
    /// Estimated peak bytes (proxies + replica maps in their tier +
    /// per-worker blocks/panels + batched intermediates + prefetch queue +
    /// streamed recovery).
    pub estimated_bytes: usize,
    /// Prefetch queue depth in blocks (0 = synchronous reads).
    pub prefetch_depth: usize,
    /// I/O producer threads when `prefetch_depth > 0`.
    pub io_threads: usize,
    /// The budget is below the tensor's byte size: the input must stay on
    /// disk / implicit and stream through the block pipeline.
    pub out_of_core: bool,
    /// Resolved replica-map storage tier.  `Auto` configs resolve to
    /// procedural when the materialized maps would eat > 1/8 of the
    /// budget; results are bitwise identical either way.
    pub map_tier: MapTier,
    /// Resolved stacked-recovery solver.  `Auto` configs resolve to
    /// iterative when the largest per-mode `dim×dim` Gram would eat
    /// > 1/8 of the budget; all solvers agree to solver tolerance.
    pub recovery_solver: RecoverySolverKind,
}

/// Plans replica count / block size / corner size for a concrete tensor.
pub struct MemoryPlanner;

impl MemoryPlanner {
    /// Paper §V-A replica rule: `max((I−2)/(L−2), J/M, K/N) + 10`.
    pub fn default_replicas(dims: [usize; 3], reduced: [usize; 3]) -> usize {
        let [i, j, k] = dims;
        let [l, m, n] = reduced;
        let r1 = (i.saturating_sub(2)).div_ceil(l.saturating_sub(2).max(1));
        let r2 = j.div_ceil(m.max(1));
        let r3 = k.div_ceil(n.max(1));
        r1.max(r2).max(r3) + 10
    }

    /// Identifiability lower bound: with `S` anchor rows shared across
    /// replicas, the stacked map `[U_1; …; U_P]` has rank at most
    /// `S + P·(L−S)`, so solvability of Eq. (4) needs
    /// `P ≥ (I−S)/(L−S)` per mode — the paper's `(I−2)/(L−2)` is the
    /// `S = 2` case.
    pub fn min_replicas_anchored(
        dims: [usize; 3],
        reduced: [usize; 3],
        anchor_rows: usize,
    ) -> usize {
        let per_mode = |d: usize, r: usize| {
            let s = anchor_rows.min(r);
            if d <= r {
                1 // no compression on this mode: one replica spans it
            } else if r == s {
                // every row anchored ⇒ replicas add no information
                usize::MAX / 4
            } else {
                (d - s).div_ceil(r - s)
            }
        };
        per_mode(dims[0], reduced[0])
            .max(per_mode(dims[1], reduced[1]))
            .max(per_mode(dims[2], reduced[2]))
    }

    /// Paper-literal bound (`S = 2`), kept for the replica-count ablation.
    pub fn min_replicas(dims: [usize; 3], reduced: [usize; 3]) -> usize {
        Self::min_replicas_anchored(dims, reduced, 2)
    }

    /// Bytes the replica maps themselves pin for the whole run, by tier:
    /// the dense `P × (L·I + M·J + N·K)` floats when materialized, **zero**
    /// when procedural — generate-on-slice maps exist only as per-worker
    /// panel scratch, which the workers term below counts.  This is the
    /// term that made exascale `I` unplannable before the tiered source.
    pub fn replica_map_bytes(
        dims: [usize; 3],
        reduced: [usize; 3],
        replicas: usize,
        tier: MapTier,
    ) -> usize {
        match tier {
            MapTier::Materialized => {
                let [l, m, n] = reduced;
                replicas * (l * dims[0] + m * dims[1] + n * dims[2])
                    * std::mem::size_of::<f32>()
            }
            MapTier::Procedural => 0,
        }
    }

    /// Largest per-mode `dim×dim` normal-equation Gram in bytes — the
    /// structure the dense recovery solver materializes and the iterative
    /// one doesn't.  Drives the `Auto` solver resolution the same way the
    /// materialized-map bytes drive the `Auto` tier.
    pub fn recovery_gram_bytes(dims: [usize; 3]) -> usize {
        dims.iter()
            .map(|&d| d.saturating_mul(d).saturating_mul(std::mem::size_of::<f32>()))
            .max()
            .unwrap_or(0)
    }

    /// Sketch rows the sketch-and-solve recovery path uses for a mode of
    /// size `dim`: enough oversampling for a well-conditioned small dense
    /// solve.  One definition shared by the solver and the byte model.
    pub fn sketch_rows(dim: usize, rank: usize) -> usize {
        dim + 4 * rank + 16
    }

    /// Peak bytes of the stacked-recovery solve for one mode (Eq. 4),
    /// per resolved solver.  All solvers share the `P·L×R` stacked factor
    /// RHS, the `dim×R` solution/right-hand accumulator, and two streamed
    /// `L×w` map panels; they differ in the solver state on top:
    ///
    /// * `Cholesky`  — the `dim×dim` Gram (the `O(I²)` term);
    /// * `Iterative` — six `dim`-length CG vectors (diag, x-col, r, z, p,
    ///   q), the Gram never exists;
    /// * `Sketch`    — the `s×dim` sketched operand plus its `s×R` RHS,
    ///   `s = sketch_rows(dim, rank)`.
    pub fn recovery_mode_bytes(
        dim: usize,
        reduced: usize,
        replicas: usize,
        rank: usize,
        panel_cols: usize,
        solver: RecoverySolverKind,
    ) -> usize {
        let f = std::mem::size_of::<f32>();
        let w = panel_cols.min(dim).max(1);
        let shared = dim * rank + replicas * reduced * rank + 2 * reduced * w;
        let solver_state = match solver {
            RecoverySolverKind::Cholesky => dim * dim,
            RecoverySolverKind::Iterative => 6 * dim,
            RecoverySolverKind::Sketch => {
                let s = Self::sketch_rows(dim, rank);
                s * (dim + rank)
            }
        };
        (shared + solver_state) * f
    }

    /// Byte estimate for a candidate plan.
    ///
    /// When prefetching, raw blocks live in the queue (`prefetch_depth`),
    /// in producer reads (`io_threads`), and in consumers' hands
    /// (`threads`) — all budgeted.  (Blocks parked out-of-order at the
    /// engine's in-position-order send stage are bounded by that same
    /// `depth + io + threads` window — a producer only admits a new block
    /// once the in-order prefix advances — so the queue term covers them;
    /// no separate parked-block term exists.)  `batched = true`
    /// models the replica-batched f32 chain, whose mode-1 intermediate
    /// stacks all `P` replicas (`P·L × dj·dk` per worker) — the term that
    /// actually dominates tight out-of-core budgets.  `tier` picks the
    /// replica-map model: dense storage (materialized) or panel-scratch
    /// only (procedural); `panel_cols`/`solver` pick the recovery model
    /// (see [`MemoryPlanner::recovery_mode_bytes`]).
    #[allow(clippy::too_many_arguments)]
    pub fn estimate_bytes(
        dims: [usize; 3],
        reduced: [usize; 3],
        replicas: usize,
        block: [usize; 3],
        threads: usize,
        rank: usize,
        prefetch_depth: usize,
        io_threads: usize,
        batched: bool,
        tier: MapTier,
        panel_cols: usize,
        solver: RecoverySolverKind,
    ) -> usize {
        let f = std::mem::size_of::<f32>();
        let [l, m, n] = reduced;
        let proxies = replicas * l * m * n * f;
        let maps = Self::replica_map_bytes(dims, reduced, replicas, tier);
        // Each in-flight worker holds one materialized block, the mode-1
        // intermediate of its TTM chain — (L × dj·dk) per replica on the
        // trait path, (P·L × dj·dk) stacked on the batched f32 path — and
        // the per-block map panels its scratch carries in *both* tiers
        // (stacked `P·L × di` U-panel when batched, per-replica otherwise,
        // plus one `M × dj` and one `N × dk` panel).
        let blk = block[0] * block[1] * block[2];
        let interm = if batched { replicas * l } else { l } * block[1] * block[2];
        let panels = if batched { replicas * l } else { l } * block[0]
            + m * block[1]
            + n * block[2];
        let workers = threads.max(1) * (blk + interm + panels) * f;
        // Shard-local accumulator sets: the engine's fold-prefix window
        // caps live sets at `threads.max(2)` plus the folder's own.
        let shard_accs = (threads.max(2) + 1) * l * m * n * replicas * f;
        let queue = if prefetch_depth > 0 {
            (prefetch_depth + io_threads.max(1) + threads.max(1)) * blk * f
        } else {
            0
        };
        // Streamed recovery (modes solved sequentially → max over modes);
        // the `P·L × dim` stack of the retired vstack solve is gone in
        // every tier/solver combination.
        let recovery = (0..3)
            .map(|mode| {
                Self::recovery_mode_bytes(
                    dims[mode],
                    reduced[mode],
                    replicas,
                    rank,
                    panel_cols,
                    solver,
                )
            })
            .max()
            .unwrap_or(0);
        proxies + maps + workers + shard_accs + queue + recovery
    }

    /// Peak bytes one shard-lease **worker process** pins while serving a
    /// lease (see `serve/worker.rs`): the replica maps in their tier, one
    /// in-flight block with its batched mode-1 intermediate and map
    /// panels (`compress_shard_batched` runs the shard serially, so
    /// exactly one block is live), one raw shard-accumulator set
    /// (`P·L·M·N` floats — shards ship before the next begins, so the
    /// count does not scale with `lease_shards`), and the base64 wire
    /// buffer for the replica currently streaming back (4 encoded bytes
    /// per 3 payload bytes).
    pub fn worker_residency(
        dims: [usize; 3],
        reduced: [usize; 3],
        replicas: usize,
        block: [usize; 3],
        tier: MapTier,
    ) -> usize {
        let f = std::mem::size_of::<f32>();
        let [l, m, n] = reduced;
        let maps = Self::replica_map_bytes(dims, reduced, replicas, tier);
        let blk = block[0] * block[1] * block[2];
        let interm = replicas * l * block[1] * block[2];
        let panels = replicas * l * block[0] + m * block[1] + n * block[2];
        let acc = replicas * l * m * n * f;
        let wire = (l * m * n * f).div_ceil(3) * 4;
        maps + (blk + interm + panels) * f + acc + wire
    }

    /// Byte estimate for a job admitted **warm**: its Stage-1 proxy
    /// artifact is resident in the artifact store, so no source block
    /// ever streams — the per-worker block/intermediate/panel terms, the
    /// prefetch queue, the shard-accumulator window, and the checkpoint
    /// snapshots all vanish.  What remains is the proxy set itself, the
    /// replica maps in their tier (recovery still slices them), and the
    /// streamed recovery solve.  This is the price the scheduler charges
    /// when admission finds the proxies already in the store.
    pub fn warm_estimate(
        dims: [usize; 3],
        reduced: [usize; 3],
        replicas: usize,
        rank: usize,
        tier: MapTier,
        panel_cols: usize,
        solver: RecoverySolverKind,
    ) -> usize {
        let f = std::mem::size_of::<f32>();
        let [l, m, n] = reduced;
        let proxies = replicas * l * m * n * f;
        let maps = Self::replica_map_bytes(dims, reduced, replicas, tier);
        let recovery = (0..3)
            .map(|mode| {
                Self::recovery_mode_bytes(
                    dims[mode],
                    reduced[mode],
                    replicas,
                    rank,
                    panel_cols,
                    solver,
                )
            })
            .max()
            .unwrap_or(0);
        proxies + maps + recovery
    }

    /// Resolves the plan for `dims` under `cfg`, shrinking blocks to satisfy
    /// the budget when necessary.
    pub fn plan(cfg: &PipelineConfig, dims: [usize; 3]) -> Result<MemoryPlan> {
        let reduced = cfg.reduced;
        for (d, r) in dims.iter().zip(&reduced) {
            if r > d {
                bail!("reduced dim {r} exceeds tensor dim {d}");
            }
            // A mode that actually compresses (r < d) needs r > rank for
            // proxy CP identifiability; r == d is a pass-through mode.
            if r < d && *r <= cfg.rank {
                bail!(
                    "reduced dim {r} must exceed rank {} on compressed modes (dim {d})",
                    cfg.rank
                );
            }
        }
        let min_p = Self::min_replicas_anchored(dims, reduced, cfg.effective_anchor());
        if min_p > 100_000 {
            bail!(
                "infeasible: anchor rows S={} leave no informative rows on some \
                 compressed mode (reduced {reduced:?}); lower S or raise L/M/N",
                cfg.effective_anchor()
            );
        }
        let replicas = match cfg.replicas {
            Some(p) => {
                if p < min_p {
                    bail!(
                        "replicas P={p} below identifiability bound {min_p} \
                         (P ≥ (I−S)/(L−S) per mode with S={} anchors)",
                        cfg.effective_anchor()
                    );
                }
                p
            }
            None => Self::default_replicas(dims, reduced).max(min_p + 2),
        };

        let default_block = [
            500.min(dims[0]).max(1),
            500.min(dims[1]).max(1),
            500.min(dims[2]).max(1),
        ];
        let mut block = cfg.block.unwrap_or(default_block);
        for (b, d) in block.iter_mut().zip(&dims) {
            *b = (*b).min(*d).max(1);
        }

        // Corner must be large enough to CP-decompose at rank R but stay
        // cheap: default 4·R clamped to dims.
        let corner = cfg
            .corner
            .unwrap_or(4 * cfg.rank)
            .min(dims[0])
            .min(dims[1])
            .min(dims[2])
            .max(cfg.rank + 1);

        // Out-of-core decision: a budget below the tensor's own byte size
        // means the input can never be materialized — the streaming stages
        // must page blocks, and prefetching defaults on to hide the reads.
        let tensor_bytes = dims[0]
            .checked_mul(dims[1])
            .and_then(|x| x.checked_mul(dims[2]))
            .and_then(|x| x.checked_mul(std::mem::size_of::<f32>()))
            .unwrap_or(usize::MAX);
        let out_of_core = cfg.memory_budget > 0 && tensor_bytes > cfg.memory_budget;
        let io_threads = cfg.io_threads.max(1);
        let mut prefetch_depth = match cfg.prefetch_depth {
            Some(d) => d,
            None if out_of_core => 2 * io_threads,
            None => 0,
        };
        // The replica-batched f32 chain (pipeline's default fast path)
        // stacks all P replicas in its mode-1 intermediate; budget for it
        // unless mixed precision forces the trait path.
        let batched = !cfg.mixed_precision;

        // Resolve the replica-map tier.  Auto: go procedural as soon as
        // storing the maps would eat a meaningful share (> 1/8) of the
        // budget — the maps are the `O(P·I)` term the rest of the plan
        // cannot shrink away, and the procedural tier trades them for
        // per-worker panel scratch at a small generation cost.  With no
        // budget (0 = unlimited) stay materialized: panels then cost one
        // memcpy instead of re-hashing.
        let mat_map_bytes =
            Self::replica_map_bytes(dims, reduced, replicas, MapTier::Materialized);
        let map_tier = match cfg.map_tier {
            MapTierChoice::Materialized => MapTier::Materialized,
            MapTierChoice::Procedural => MapTier::Procedural,
            MapTierChoice::Auto => {
                if cfg.memory_budget > 0 && mat_map_bytes > cfg.memory_budget / 8 {
                    MapTier::Procedural
                } else {
                    MapTier::Materialized
                }
            }
        };

        // Resolve the recovery solver by the same budget-share rule: the
        // dense path's `dim×dim` Gram is the one recovery term no amount
        // of block-shrinking can reduce, so go matrix-free as soon as it
        // would eat > 1/8 of the budget.  With no budget stay Cholesky
        // (one factorization beats ~rank·dim CG panel passes when memory
        // is free).  `Sketch` is never auto-picked: its `s×dim` sketched
        // operand is the same order as the Gram it replaces — it exists
        // for explicit experimentation, not memory relief.
        let recovery_solver = match cfg.recovery_solver {
            RecoverySolver::Cholesky => RecoverySolverKind::Cholesky,
            RecoverySolver::Iterative => RecoverySolverKind::Iterative,
            RecoverySolver::Sketch => RecoverySolverKind::Sketch,
            RecoverySolver::Auto => {
                if cfg.memory_budget > 0
                    && Self::recovery_gram_bytes(dims) > cfg.memory_budget / 8
                {
                    RecoverySolverKind::Iterative
                } else {
                    RecoverySolverKind::Cholesky
                }
            }
        };

        // Incremental checkpointing snapshots the folded proxies: up to two
        // extra P·L·M·N sets live at once (one queued for the background
        // writer + one mid-save).
        let snapshot_bytes = if cfg.checkpoint_dir.is_some() {
            2 * replicas * reduced[0] * reduced[1] * reduced[2] * std::mem::size_of::<f32>()
        } else {
            0
        };
        // Sensing stage-1 streams into shard-local copies of the expanded
        // Z (αL×βM×γN) — up to the same window+1 live sets as the plain
        // path's proxy accumulators, but at the expanded shape.
        let sensing_acc_bytes = match &cfg.sensing {
            Some(sc) => {
                let [al, bm, gn] = sc.expanded(reduced);
                (cfg.threads.max(2) + 1) * al * bm * gn * std::mem::size_of::<f32>()
            }
            None => 0,
        };
        let est = |block: [usize; 3], depth: usize| {
            snapshot_bytes
                + sensing_acc_bytes
                + Self::estimate_bytes(
                    dims,
                    reduced,
                    replicas,
                    block,
                    cfg.threads,
                    cfg.rank,
                    depth,
                    io_threads,
                    batched,
                    map_tier,
                    cfg.recovery_panel_cols,
                    recovery_solver,
                )
        };
        let mut estimated = est(block, prefetch_depth);
        if cfg.memory_budget > 0 {
            // Halve block dims until we fit (blocks and their stacked
            // intermediates dominate for big d)…
            while estimated > cfg.memory_budget && block.iter().any(|&b| b > 8) {
                for b in block.iter_mut() {
                    *b = (*b / 2).max(8);
                }
                estimated = est(block, prefetch_depth);
            }
            // …then trade prefetch headroom for footprint, all the way
            // down to synchronous streaming (depth 0 zeroes the queue and
            // in-flight block terms) before giving up.
            while estimated > cfg.memory_budget && prefetch_depth > 0 {
                prefetch_depth /= 2;
                estimated = est(block, prefetch_depth);
            }
            if estimated > cfg.memory_budget {
                bail!(
                    "cannot satisfy memory budget {} bytes: minimum plan needs {estimated}",
                    cfg.memory_budget
                );
            }
        }

        Ok(MemoryPlan {
            replicas,
            block,
            corner,
            estimated_bytes: estimated,
            prefetch_depth,
            io_threads,
            out_of_core,
            map_tier,
            recovery_solver,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::PipelineConfig;

    fn cfg() -> PipelineConfig {
        PipelineConfig::builder()
            .reduced_dims(50, 50, 50)
            .rank(5)
            // Pinned: the estimate scales with workers, and tests must not
            // depend on the machine's core count.
            .threads(4)
            .build()
            .unwrap()
    }

    #[test]
    fn paper_replica_rule() {
        // I=J=K=1000, L=M=N=50 → (998/48)=20.79→21, J/M=20, K/N=20 → 21+10.
        let p = MemoryPlanner::default_replicas([1000, 1000, 1000], [50, 50, 50]);
        assert_eq!(p, 31);
    }

    #[test]
    fn plan_defaults() {
        let plan = MemoryPlanner::plan(&cfg(), [1000, 1000, 1000]).unwrap();
        assert_eq!(plan.replicas, 31);
        assert_eq!(plan.block, [500, 500, 500]);
        assert_eq!(plan.corner, 20);
        assert!(plan.estimated_bytes > 0);
        assert!(!plan.out_of_core, "no budget → in-core");
        assert_eq!(plan.prefetch_depth, 0, "prefetch off without out-of-core");
        assert_eq!(plan.map_tier, MapTier::Materialized, "no budget → stored maps");
        assert_eq!(
            plan.recovery_solver,
            RecoverySolverKind::Cholesky,
            "no budget → dense recovery solve"
        );
    }

    #[test]
    fn out_of_core_plan_selected_below_tensor_bytes() {
        let mut c = cfg();
        // 2000³ f32 = 32 GB ≫ 1 GB budget.
        c.memory_budget = 1 << 30;
        let plan = MemoryPlanner::plan(&c, [2000, 2000, 2000]).unwrap();
        assert!(plan.out_of_core);
        assert!(plan.prefetch_depth >= 1, "out-of-core defaults prefetch on");
        assert_eq!(plan.io_threads, 2);
        assert!(plan.estimated_bytes <= c.memory_budget);
    }

    #[test]
    fn explicit_prefetch_depth_honored_and_zero_disables() {
        let mut c = cfg();
        c.memory_budget = 1 << 30;
        c.prefetch_depth = Some(16);
        let plan = MemoryPlanner::plan(&c, [2000, 2000, 2000]).unwrap();
        assert!(plan.prefetch_depth <= 16 && plan.prefetch_depth >= 1);
        c.prefetch_depth = Some(0);
        let plan = MemoryPlanner::plan(&c, [2000, 2000, 2000]).unwrap();
        assert_eq!(plan.prefetch_depth, 0);
    }

    #[test]
    fn estimate_monotone_in_depth_and_batching() {
        let chol = RecoverySolverKind::Cholesky;
        let base = MemoryPlanner::estimate_bytes(
            [1000; 3], [50; 3], 31, [100; 3], 4, 5, 0, 2, false, MapTier::Materialized, 256,
            chol,
        );
        let deeper = MemoryPlanner::estimate_bytes(
            [1000; 3], [50; 3], 31, [100; 3], 4, 5, 8, 2, false, MapTier::Materialized, 256,
            chol,
        );
        let batched = MemoryPlanner::estimate_bytes(
            [1000; 3], [50; 3], 31, [100; 3], 4, 5, 0, 2, true, MapTier::Materialized, 256,
            chol,
        );
        assert!(deeper > base, "queue + in-flight blocks must be budgeted");
        assert!(batched > base, "stacked P·L intermediate must be budgeted");
    }

    #[test]
    fn estimate_tier_aware_hand_computed() {
        // dims [100,80,60], reduced [10,10,10], P=3, block [20,20,20],
        // threads 2, rank 4, no prefetch, unbatched.  By hand:
        //   proxies    = 3·10·10·10·4                        =  12 000
        //   maps (mat) = 3·(10·100 + 10·80 + 10·60)·4        =  28 800
        //   workers    = 2·(20³ + 10·20·20 + 3·10·20)·4      = 100 800
        //                (block + mode-1 interm + u/v/w panels 200 each)
        //   shard_accs = (2+1)·10³·3·4                       =  36 000
        //   queue      = 0
        //   recovery   = max over modes; mode 1 (dim 100):
        //                (100² + 100·4 + 3·10·4 + 2·10·100)·4 = 50 080
        //   total (materialized)                             = 227 680
        //   total (procedural)  = same − 28 800              = 198 880
        let args = ([100, 80, 60], [10, 10, 10], 3, [20, 20, 20], 2, 4, 0, 1, false);
        let est = |tier| {
            MemoryPlanner::estimate_bytes(
                args.0,
                args.1,
                args.2,
                args.3,
                args.4,
                args.5,
                args.6,
                args.7,
                args.8,
                tier,
                256,
                RecoverySolverKind::Cholesky,
            )
        };
        assert_eq!(est(MapTier::Materialized), 227_680);
        assert_eq!(est(MapTier::Procedural), 198_880);
        assert_eq!(
            est(MapTier::Materialized) - est(MapTier::Procedural),
            MemoryPlanner::replica_map_bytes(
                [100, 80, 60], [10, 10, 10], 3, MapTier::Materialized
            ),
            "the tiers may differ only by the stored-map term"
        );
    }

    #[test]
    fn procedural_map_term_is_flat_in_i() {
        // ΔI-flatness: growing I 10× adds map bytes only in the
        // materialized tier (P·L·ΔI·4 = 3·10·900·4 = 108 000); the
        // procedural map term stays zero, so the tier gap at any I equals
        // the materialized map bytes at that I.
        for (dims, gap) in [([100, 80, 60], 28_800usize), ([1000, 80, 60], 136_800)] {
            assert_eq!(
                MemoryPlanner::replica_map_bytes(dims, [10; 3], 3, MapTier::Procedural),
                0
            );
            let mat = MemoryPlanner::estimate_bytes(
                dims,
                [10; 3],
                3,
                [20; 3],
                2,
                4,
                0,
                1,
                false,
                MapTier::Materialized,
                256,
                RecoverySolverKind::Cholesky,
            );
            let proc_ = MemoryPlanner::estimate_bytes(
                dims,
                [10; 3],
                3,
                [20; 3],
                2,
                4,
                0,
                1,
                false,
                MapTier::Procedural,
                256,
                RecoverySolverKind::Cholesky,
            );
            assert_eq!(mat - proc_, gap, "dims {dims:?}");
        }
        // And the gap is exactly the maps' I-linear growth: 136 800 −
        // 28 800 = P·L·ΔI·4 = 108 000.  What remains I-dependent in the
        // procedural estimate is the solve itself (Gram dim² + dim·R +
        // panel clamp), not any map storage.
        let small = MemoryPlanner::estimate_bytes(
            [100, 80, 60],
            [10; 3],
            3,
            [20; 3],
            2,
            4,
            0,
            1,
            false,
            MapTier::Procedural,
            256,
            RecoverySolverKind::Cholesky,
        );
        let big = MemoryPlanner::estimate_bytes(
            [1000, 80, 60],
            [10; 3],
            3,
            [20; 3],
            2,
            4,
            0,
            1,
            false,
            MapTier::Procedural,
            256,
            RecoverySolverKind::Cholesky,
        );
        // mode-0 recovery: (10⁶ + 4000 + 120 + 2·10·256)·4 = 4 036 960 vs
        // (10⁴ + 400 + 120 + 2·10·100)·4 = 50 080.
        assert_eq!(big - small, 4_036_960 - 50_080);
    }

    #[test]
    fn estimate_solver_aware_hand_computed() {
        // Same shapes as the tier test (dims [100,80,60], reduced 10³,
        // P=3, rank 4, w = min(256, dim)).  Mode 0 dominates every solver:
        //   shared     = 100·4 + 3·10·4 + 2·10·100        = 2 520 floats
        //   cholesky   = + 100²                            → 50 080 bytes
        //   iterative  = + 6·100                           → 12 480 bytes
        //   sketch     = + (100+4·4+16)·(100+4) = 132·104  → 64 992 bytes
        let mode = |solver| MemoryPlanner::recovery_mode_bytes(100, 10, 3, 4, 256, solver);
        assert_eq!(mode(RecoverySolverKind::Cholesky), 50_080);
        assert_eq!(mode(RecoverySolverKind::Iterative), 12_480);
        assert_eq!(mode(RecoverySolverKind::Sketch), 64_992);
        // Threaded through the full estimate, solvers differ only by the
        // dominant mode's recovery term.
        let est = |solver| {
            MemoryPlanner::estimate_bytes(
                [100, 80, 60],
                [10; 3],
                3,
                [20; 3],
                2,
                4,
                0,
                1,
                false,
                MapTier::Materialized,
                256,
                solver,
            )
        };
        assert_eq!(est(RecoverySolverKind::Cholesky), 227_680);
        assert_eq!(est(RecoverySolverKind::Iterative), 227_680 - 50_080 + 12_480);
    }

    #[test]
    fn iterative_recovery_estimate_is_linear_in_i() {
        // Growing I 10× (procedural maps, iterative solver) moves only the
        // O(I) recovery terms: mode-0 goes from
        // (6·100 + 100·4 + 120 + 2·10·100)·4 = 12 480 (w clamped to 100)
        // to (6·1000 + 1000·4 + 120 + 2·10·256)·4 = 60 960 — no I² term
        // anywhere, versus the Cholesky gap of 3 986 880.
        let est = |dims| {
            MemoryPlanner::estimate_bytes(
                dims,
                [10; 3],
                3,
                [20; 3],
                2,
                4,
                0,
                1,
                false,
                MapTier::Procedural,
                256,
                RecoverySolverKind::Iterative,
            )
        };
        assert_eq!(est([1000, 80, 60]) - est([100, 80, 60]), 60_960 - 12_480);
    }

    #[test]
    fn auto_solver_selection_follows_budget_share() {
        let base = PipelineConfig::builder()
            .reduced_dims(10, 10, 10)
            .rank(4)
            .threads(2)
            .build()
            .unwrap();
        let dims = [3000, 40, 40];
        // No budget → dense Cholesky (memory is free, one factorization
        // beats the CG panel passes).
        let plan = MemoryPlanner::plan(&base, dims).unwrap();
        assert_eq!(plan.recovery_solver, RecoverySolverKind::Cholesky);
        // Gram = 3000²·4 = 36 MB.  1 GiB budget: 36 MB < budget/8 =
        // 128 MiB → stay Cholesky.
        let mut c = base.clone();
        c.memory_budget = 1 << 30;
        let plan = MemoryPlanner::plan(&c, dims).unwrap();
        assert_eq!(plan.recovery_solver, RecoverySolverKind::Cholesky);
        // 200 MiB budget: 36 MB > budget/8 = 25 MiB → iterative.
        c.memory_budget = 200 << 20;
        let plan = MemoryPlanner::plan(&c, dims).unwrap();
        assert_eq!(plan.recovery_solver, RecoverySolverKind::Iterative);
        assert!(plan.estimated_bytes <= c.memory_budget);
        // Explicit choices are always honored, including against the
        // budget rule's preference.
        c.recovery_solver = RecoverySolver::Cholesky;
        let plan = MemoryPlanner::plan(&c, dims).unwrap();
        assert_eq!(plan.recovery_solver, RecoverySolverKind::Cholesky);
        let mut free = base.clone();
        free.recovery_solver = RecoverySolver::Iterative;
        let plan = MemoryPlanner::plan(&free, dims).unwrap();
        assert_eq!(plan.recovery_solver, RecoverySolverKind::Iterative);
        free.recovery_solver = RecoverySolver::Sketch;
        let plan = MemoryPlanner::plan(&free, dims).unwrap();
        assert_eq!(plan.recovery_solver, RecoverySolverKind::Sketch);
    }

    #[test]
    fn auto_tier_selection_follows_budget_share() {
        // No budget → materialized.
        let plan = MemoryPlanner::plan(&cfg(), [2000, 2000, 2000]).unwrap();
        assert_eq!(plan.map_tier, MapTier::Materialized);
        // P=52 at these shapes → materialized maps = 52·(50·2000·3)·4 ≈
        // 62.4 MB.  1 GiB budget: 62.4 MB < budget/8 → stay materialized.
        let mut c = cfg();
        c.memory_budget = 1 << 30;
        let plan = MemoryPlanner::plan(&c, [2000, 2000, 2000]).unwrap();
        assert_eq!(plan.map_tier, MapTier::Materialized);
        // 256 MiB budget: 62.4 MB > budget/8 = 32 MiB → procedural.
        c.memory_budget = 256 << 20;
        let plan = MemoryPlanner::plan(&c, [2000, 2000, 2000]).unwrap();
        assert_eq!(plan.map_tier, MapTier::Procedural);
        assert!(plan.estimated_bytes <= c.memory_budget);
        // Explicit choices are always honored.
        c.map_tier = MapTierChoice::Materialized;
        c.memory_budget = 1 << 30;
        let plan = MemoryPlanner::plan(&c, [2000, 2000, 2000]).unwrap();
        assert_eq!(plan.map_tier, MapTier::Materialized);
        c.map_tier = MapTierChoice::Procedural;
        c.memory_budget = 0;
        let plan = MemoryPlanner::plan(&c, [2000, 2000, 2000]).unwrap();
        assert_eq!(plan.map_tier, MapTier::Procedural);
    }

    #[test]
    fn worker_residency_hand_computed() {
        // Same shapes as the tier test: dims [100,80,60], reduced 10³,
        // P=3, block [20,20,20].  By hand:
        //   maps (mat) = 3·(10·100 + 10·80 + 10·60)·4    = 28 800
        //   block path = (20³ + 3·10·20·20
        //                 + (3·10·20 + 10·20 + 10·20))·4 = 84 000
        //   accumulator= 3·10³·4                         = 12 000
        //   wire (b64) = ⌈10³·4 / 3⌉·4 = 1 334·4         =  5 336
        //   total (materialized)                         = 130 136
        //   total (procedural) = same − 28 800           = 101 336
        let res = |tier| {
            MemoryPlanner::worker_residency([100, 80, 60], [10; 3], 3, [20; 3], tier)
        };
        assert_eq!(res(MapTier::Materialized), 130_136);
        assert_eq!(res(MapTier::Procedural), 101_336);
        // A worker is strictly cheaper than the coordinator's own full
        // estimate at the same shapes — the point of sharding out.
        let full = MemoryPlanner::estimate_bytes(
            [100, 80, 60],
            [10; 3],
            3,
            [20; 3],
            2,
            4,
            0,
            1,
            true,
            MapTier::Materialized,
            256,
            RecoverySolverKind::Cholesky,
        );
        assert!(res(MapTier::Materialized) < full);
    }

    #[test]
    fn warm_estimate_hand_computed_and_cheaper_than_cold() {
        // Same shapes as the tier test: dims [100,80,60], reduced 10³,
        // P=3, rank 4, Cholesky.  By hand:
        //   proxies    = 3·10³·4       = 12 000
        //   maps (mat) = 28 800
        //   recovery   = 50 080 (mode 0, as above)
        //   total      = 90 880
        let warm = MemoryPlanner::warm_estimate(
            [100, 80, 60],
            [10; 3],
            3,
            4,
            MapTier::Materialized,
            256,
            RecoverySolverKind::Cholesky,
        );
        assert_eq!(warm, 90_880);
        // Warm admission must always price below the cold estimate at the
        // same shapes — that headroom is what lets more warm jobs coexist.
        let cold = MemoryPlanner::estimate_bytes(
            [100, 80, 60],
            [10; 3],
            3,
            [20; 3],
            2,
            4,
            0,
            1,
            false,
            MapTier::Materialized,
            256,
            RecoverySolverKind::Cholesky,
        );
        assert!(warm < cold);
    }

    #[test]
    fn explicit_replicas_below_bound_rejected() {
        let mut c = cfg();
        c.replicas = Some(2);
        assert!(MemoryPlanner::plan(&c, [1000, 1000, 1000]).is_err());
    }

    #[test]
    fn reduced_larger_than_dims_rejected() {
        assert!(MemoryPlanner::plan(&cfg(), [40, 1000, 1000]).is_err());
    }

    #[test]
    fn budget_shrinks_blocks() {
        let mut c = cfg();
        // 300 MiB: the auto tier goes procedural (62.4 MiB of materialized
        // maps > budget/8), leaving a fixed floor of proxies 26 MiB +
        // shard accumulators 130 MiB + streamed-recovery Gram ~16 MiB ≈
        // 172 MiB for P=52 at these shapes — below the budget, while the
        // unbounded estimate exceeds it, so the block-shrinking loop must
        // engage and converge.
        c.memory_budget = 300 * 1024 * 1024;
        let plan_unbounded = MemoryPlanner::plan(&cfg(), [2000, 2000, 2000]).unwrap();
        let plan_bounded = MemoryPlanner::plan(&c, [2000, 2000, 2000]).unwrap();
        assert!(plan_bounded.block[0] < plan_unbounded.block[0]);
        assert!(plan_bounded.estimated_bytes <= 300 * 1024 * 1024);
    }

    #[test]
    fn impossible_budget_rejected() {
        let mut c = cfg();
        c.memory_budget = 1024; // 1 KB — absurd
        assert!(MemoryPlanner::plan(&c, [1000, 1000, 1000]).is_err());
    }

    #[test]
    fn block_clamped_to_dims() {
        let mut c = cfg();
        c.block = Some([999, 999, 999]);
        let plan = MemoryPlanner::plan(&c, [100, 80, 60]).unwrap();
        assert_eq!(plan.block, [100, 80, 60]);
    }

    #[test]
    fn corner_respects_dims_and_rank() {
        let plan = MemoryPlanner::plan(&cfg(), [60, 60, 60]).unwrap();
        assert!(plan.corner >= 6);
        assert!(plan.corner <= 60);
    }
}
