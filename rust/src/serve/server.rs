//! The daemon: a `TcpListener` accept loop in front of the scheduler.
//!
//! One thread per connection, newline-delimited JSON request/response
//! pairs (see [`super::protocol`]).  `SHUTDOWN` answers, then starts the
//! graceful drain: the acceptor stops taking connections, running jobs
//! complete, queued jobs stay spooled for the next start.  Worker-plane
//! verbs (`WORKER_HELLO`/`LEASE`/`PARTIAL`/`RENEW`) stay live through the
//! drain so a running sharded job can finish folding its partials while
//! `LEASE` tells the worker fleet to shut down.  A hard kill
//! (SIGKILL / power loss) is also safe: job records are committed by
//! atomic rename and running jobs leave incremental pipeline checkpoints,
//! so the next `bind` + `run` recovers the queue and resumes mid-
//! compression work bitwise-identically.
//!
//! **Connection hardening** (multi-tenant daemons meet hostile peers):
//! every connection must deliver a complete request line within
//! [`ServerConfig::conn_timeout_ms`] — slow-loris peers (one byte per
//! window) and half-open peers (connect, send nothing) are reaped on the
//! same deadline (`conn_timeouts` counts them) — and at most
//! [`ServerConfig::max_conns`] connections are served concurrently;
//! excess peers get a polite `{"ok":false}` line and are dropped
//! (`conn_rejected_over_capacity`).

use super::job::Spool;
use super::protocol::{self, Request};
use super::scheduler::{Scheduler, SchedulerConfig};
use crate::coordinator::Metrics;
use crate::util::fault;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Daemon construction knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`Server::local_addr`]).
    pub addr: String,
    /// Spool directory (job records, results, per-job checkpoints).
    pub spool_dir: PathBuf,
    pub scheduler: SchedulerConfig,
    /// Per-request deadline in milliseconds: a connection that has not
    /// delivered a complete request line within this window is closed
    /// (covers idle, half-open, and slow-loris peers alike; blank
    /// keep-alive lines do not extend it).  0 disables the deadline.
    pub conn_timeout_ms: u64,
    /// Concurrent-connection bound; peers over the cap receive a polite
    /// error line and are dropped.  0 = unbounded.
    pub max_conns: usize,
}

/// Default per-request connection deadline (30 s).
pub const DEFAULT_CONN_TIMEOUT_MS: u64 = 30_000;
/// Default concurrent-connection bound.
pub const DEFAULT_MAX_CONNS: usize = 256;

struct Shared {
    scheduler: Scheduler,
    metrics: Arc<Metrics>,
    shutting_down: AtomicBool,
    addr: SocketAddr,
    conn_timeout_ms: u64,
    conn_active: AtomicUsize,
}

/// Decrements the live-connection count (and gauge) when a handler exits,
/// however it exits.
struct ConnGuard {
    shared: Arc<Shared>,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        let n = self.shared.conn_active.fetch_sub(1, Ordering::SeqCst) - 1;
        self.shared.metrics.set("conn_active", n as u64);
    }
}

/// A bound (but not yet serving) daemon.
pub struct Server {
    listener: TcpListener,
    max_conns: usize,
    shared: Arc<Shared>,
}

impl Server {
    /// Opens the spool (recovering persisted jobs), starts the scheduler's
    /// worker pool, and binds the listener.
    pub fn bind(cfg: &ServerConfig) -> Result<Server> {
        let metrics = Arc::new(Metrics::new());
        let spool = Spool::open(&cfg.spool_dir)?;
        let scheduler = Scheduler::new(spool, cfg.scheduler.clone(), Arc::clone(&metrics))?;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("local_addr")?;
        Ok(Server {
            listener,
            max_conns: cfg.max_conns,
            shared: Arc::new(Shared {
                scheduler,
                metrics,
                shutting_down: AtomicBool::new(false),
                addr,
                conn_timeout_ms: cfg.conn_timeout_ms,
                conn_active: AtomicUsize::new(0),
            }),
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Serves until a `SHUTDOWN` request, then drains gracefully.
    pub fn run(self) -> Result<()> {
        log::info!("serve: listening on {}", self.shared.addr);
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for stream in self.listener.incoming() {
            if self.shared.shutting_down.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    // Over-capacity: answer politely on the acceptor (with
                    // a write timeout so an unreading peer cannot wedge the
                    // accept loop) and drop the socket.
                    let active = self.shared.conn_active.load(Ordering::SeqCst);
                    if self.max_conns > 0 && active >= self.max_conns {
                        self.shared.metrics.incr("conn_rejected_over_capacity", 1);
                        let mut w = s;
                        let _ = w.set_write_timeout(Some(Duration::from_secs(1)));
                        let _ = protocol::write_line(
                            &mut w,
                            &protocol::err(
                                "server at connection capacity, retry later",
                            ),
                        );
                        continue;
                    }
                    let n = self.shared.conn_active.fetch_add(1, Ordering::SeqCst) + 1;
                    self.shared.metrics.set("conn_active", n as u64);
                    let shared = Arc::clone(&self.shared);
                    handles.push(std::thread::spawn(move || handle_conn(shared, s)));
                    // Reap (join) finished handlers so a long-lived daemon
                    // does not accumulate one dead JoinHandle per past
                    // connection.
                    let mut live = Vec::with_capacity(handles.len());
                    for h in handles {
                        if h.is_finished() {
                            let _ = h.join();
                        } else {
                            live.push(h);
                        }
                    }
                    handles = live;
                }
                Err(e) => log::warn!("serve: accept: {e}"),
            }
        }
        log::info!("serve: draining ({} running)", self.shared.scheduler.running_count());
        // Stop admissions FIRST — an open connection must not keep feeding
        // the queue (scheduler.submit also rejects once this flag is set),
        // and the drain must not wait on idle keep-alive connections.
        self.shared.scheduler.shutdown();
        self.shared.scheduler.join();
        // Reap finished handlers; an idle connection blocked in read does
        // not hold the drain hostage — handle_conn closes it on its next
        // request (it checks the flag), or it dies with the process.
        for h in handles {
            if h.is_finished() {
                let _ = h.join();
            }
        }
        log::info!("serve: drained, bye");
        Ok(())
    }
}

/// Answers requests on one connection until EOF, `SHUTDOWN`, or a
/// deadline expiry (idle/half-open/slow-loris reap).
fn handle_conn(shared: Arc<Shared>, stream: TcpStream) {
    let _active = ConnGuard { shared: Arc::clone(&shared) };
    let timeout = Duration::from_millis(shared.conn_timeout_ms);
    if shared.conn_timeout_ms > 0 {
        // Short per-read tick + absolute deadline in the reader: the tick
        // alone cannot stop a peer trickling one byte per window.
        let tick = (timeout / 8)
            .max(Duration::from_millis(10))
            .min(Duration::from_secs(1));
        let _ = stream.set_read_timeout(Some(tick));
        let _ = stream.set_write_timeout(Some(timeout));
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            log::warn!("serve: cloning stream: {e}");
            return;
        }
    };
    let mut reader = BufReader::new(stream);
    loop {
        // Fault site `conn_stall`: act exactly as if this connection's
        // request deadline expired (the reap path, minus the wait).
        let read = if fault::should_fault(fault::Site::ConnStall) {
            Err(anyhow::anyhow!("{}", protocol::TIMEOUT_MSG))
        } else if shared.conn_timeout_ms > 0 {
            protocol::read_line_json_deadline(&mut reader, Instant::now() + timeout)
        } else {
            protocol::read_line_json(&mut reader)
        };
        let msg = match read {
            Ok(Some(v)) => v,
            Ok(None) => return,
            Err(e) if protocol::is_timeout_error(&e) => {
                shared.metrics.incr("conn_timeouts", 1);
                let _ = protocol::write_line(
                    &mut writer,
                    &protocol::err("request timed out, closing connection"),
                );
                return;
            }
            Err(e) => {
                let _ = protocol::write_line(&mut writer, &protocol::err(format!("{e:#}")));
                return;
            }
        };
        let req = match Request::from_json(&msg) {
            Ok(req) => req,
            Err(e) => {
                if protocol::write_line(&mut writer, &protocol::err(format!("{e:#}"))).is_err() {
                    return;
                }
                continue;
            }
        };
        // During the drain, answer with an error and close: open
        // connections must not keep the daemon serving.  The worker plane
        // is exempt — a draining daemon's *running* sharded job still
        // needs its partials folded, and `LEASE` is precisely how workers
        // learn to shut down (the shard registry answers `shutdown`).
        if shared.shutting_down.load(Ordering::SeqCst) && !is_worker_plane(&req) {
            let _ = protocol::write_line(&mut writer, &protocol::err("daemon is draining"));
            return;
        }
        let (resp, shutdown) = dispatch(&shared, req);
        if protocol::write_line(&mut writer, &resp).is_err() {
            return;
        }
        if shutdown {
            trigger_shutdown(&shared);
            return;
        }
    }
}

/// Flags the drain and pokes the blocking acceptor with a self-connection.
fn trigger_shutdown(shared: &Shared) {
    if !shared.shutting_down.swap(true, Ordering::SeqCst) {
        // Normalize a wildcard bind (0.0.0.0 / ::) to loopback: connecting
        // to the unspecified address is not valid on every platform, and a
        // failed poke would leave the acceptor blocked forever.
        let mut target = shared.addr;
        if target.ip().is_unspecified() {
            let ip: std::net::IpAddr = match target {
                SocketAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                SocketAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            };
            target.set_ip(ip);
        }
        let _ = TcpStream::connect(target);
    }
}

/// Worker-plane verbs keep working during the drain (see `handle_conn`).
fn is_worker_plane(req: &Request) -> bool {
    matches!(
        req,
        Request::WorkerHello { .. }
            | Request::Lease { .. }
            | Request::Partial(_)
            | Request::Renew { .. }
    )
}

fn dispatch(shared: &Shared, req: Request) -> (Json, bool) {
    match req {
        Request::Submit(spec) => match shared.scheduler.submit(spec) {
            Ok(rec) => (protocol::ok(vec![("job", rec.to_json())]), false),
            Err(e) => (protocol::err(format!("{e:#}")), false),
        },
        Request::Status(id) => match shared.scheduler.status(&id) {
            Some(rec) => (protocol::ok(vec![("job", rec.to_json())]), false),
            None => (protocol::err(format!("no such job {id}")), false),
        },
        Request::Result(id) => match shared.scheduler.status(&id) {
            Some(rec) => match rec.state {
                super::job::JobState::Done => {
                    let mut fields = vec![("job", rec.to_json())];
                    let rdir = shared.scheduler.result_dir(&id);
                    if rdir.exists() {
                        fields.push(("result_dir", Json::str(rdir.display().to_string())));
                    }
                    (protocol::ok(fields), false)
                }
                super::job::JobState::Failed => (
                    protocol::err(format!(
                        "job {id} failed: {}",
                        rec.error.as_deref().unwrap_or("unknown")
                    )),
                    false,
                ),
                other => (
                    protocol::err(format!("job {id} not finished (state {})", other.as_str())),
                    false,
                ),
            },
            None => (protocol::err(format!("no such job {id}")), false),
        },
        Request::Cancel(id) => match shared.scheduler.cancel(&id) {
            Ok(rec) => (protocol::ok(vec![("job", rec.to_json())]), false),
            Err(e) => (protocol::err(format!("{e:#}")), false),
        },
        Request::List => {
            // One summary line per job (submission order) — deliberately
            // not the full record: a fleet dashboard polling LIST must not
            // drag every job's spec/config over the wire.
            let jobs: Vec<Json> = shared
                .scheduler
                .jobs()
                .into_iter()
                .map(|rec| {
                    let workers: Vec<Json> = shared
                        .scheduler
                        .workers_for(&rec.id)
                        .into_iter()
                        .map(Json::str)
                        .collect();
                    Json::obj(vec![
                        ("id", Json::str(rec.id.clone())),
                        ("state", Json::str(rec.state.as_str())),
                        ("tenant", Json::str(rec.spec.tenant.clone())),
                        ("priority", Json::num(rec.spec.priority as f64)),
                        ("workers", Json::Arr(workers)),
                    ])
                })
                .collect();
            (protocol::ok(vec![("jobs", Json::Arr(jobs))]), false)
        }
        Request::Metrics => {
            let snap: BTreeMap<String, Json> = shared
                .metrics
                .snapshot()
                .into_iter()
                .map(|(k, v)| (k, Json::num(v as f64)))
                .collect();
            (protocol::ok(vec![("metrics", Json::Obj(snap))]), false)
        }
        Request::WorkerHello { worker } => (shared.scheduler.worker_hello(&worker), false),
        Request::Lease { worker } => (shared.scheduler.lease(&worker), false),
        Request::Partial(msg) => (shared.scheduler.partial(&msg), false),
        Request::Renew { worker, job, lease } => {
            (shared.scheduler.renew(&worker, &job, lease), false)
        }
        Request::Shutdown => (protocol::ok(vec![("draining", Json::Bool(true))]), true),
    }
}
