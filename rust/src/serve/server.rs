//! The daemon: a `TcpListener` accept loop in front of the scheduler.
//!
//! One thread per connection, newline-delimited JSON request/response
//! pairs (see [`super::protocol`]).  `SHUTDOWN` answers, then starts the
//! graceful drain: the acceptor stops taking connections, running jobs
//! complete, queued jobs stay spooled for the next start.  A hard kill
//! (SIGKILL / power loss) is also safe: job records are committed by
//! atomic rename and running jobs leave incremental pipeline checkpoints,
//! so the next `bind` + `run` recovers the queue and resumes mid-
//! compression work bitwise-identically.

use super::job::Spool;
use super::protocol::{self, Request};
use super::scheduler::{Scheduler, SchedulerConfig};
use crate::coordinator::Metrics;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Daemon construction knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`Server::local_addr`]).
    pub addr: String,
    /// Spool directory (job records, results, per-job checkpoints).
    pub spool_dir: PathBuf,
    pub scheduler: SchedulerConfig,
}

struct Shared {
    scheduler: Scheduler,
    metrics: Arc<Metrics>,
    shutting_down: AtomicBool,
    addr: SocketAddr,
}

/// A bound (but not yet serving) daemon.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    /// Opens the spool (recovering persisted jobs), starts the scheduler's
    /// worker pool, and binds the listener.
    pub fn bind(cfg: &ServerConfig) -> Result<Server> {
        let metrics = Arc::new(Metrics::new());
        let spool = Spool::open(&cfg.spool_dir)?;
        let scheduler = Scheduler::new(spool, cfg.scheduler.clone(), Arc::clone(&metrics))?;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("local_addr")?;
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                scheduler,
                metrics,
                shutting_down: AtomicBool::new(false),
                addr,
            }),
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Serves until a `SHUTDOWN` request, then drains gracefully.
    pub fn run(self) -> Result<()> {
        log::info!("serve: listening on {}", self.shared.addr);
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for stream in self.listener.incoming() {
            if self.shared.shutting_down.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let shared = Arc::clone(&self.shared);
                    handles.push(std::thread::spawn(move || handle_conn(shared, s)));
                    handles.retain(|h| !h.is_finished());
                }
                Err(e) => log::warn!("serve: accept: {e}"),
            }
        }
        log::info!("serve: draining ({} running)", self.shared.scheduler.running_count());
        // Stop admissions FIRST — an open connection must not keep feeding
        // the queue (scheduler.submit also rejects once this flag is set),
        // and the drain must not wait on idle keep-alive connections.
        self.shared.scheduler.shutdown();
        self.shared.scheduler.join();
        // Reap finished handlers; an idle connection blocked in read does
        // not hold the drain hostage — handle_conn closes it on its next
        // request (it checks the flag), or it dies with the process.
        for h in handles {
            if h.is_finished() {
                let _ = h.join();
            }
        }
        log::info!("serve: drained, bye");
        Ok(())
    }
}

/// Answers requests on one connection until EOF (or `SHUTDOWN`).
fn handle_conn(shared: Arc<Shared>, stream: TcpStream) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            log::warn!("serve: cloning stream: {e}");
            return;
        }
    };
    let mut reader = BufReader::new(stream);
    loop {
        let msg = match protocol::read_line_json(&mut reader) {
            Ok(Some(v)) => v,
            Ok(None) => return,
            Err(e) => {
                let _ = protocol::write_line(&mut writer, &protocol::err(format!("{e:#}")));
                return;
            }
        };
        // During the drain, answer with an error and close: open
        // connections must not keep the daemon serving.
        if shared.shutting_down.load(Ordering::SeqCst) {
            let _ = protocol::write_line(&mut writer, &protocol::err("daemon is draining"));
            return;
        }
        let (resp, shutdown) = match Request::from_json(&msg) {
            Ok(req) => dispatch(&shared, req),
            Err(e) => (protocol::err(format!("{e:#}")), false),
        };
        if protocol::write_line(&mut writer, &resp).is_err() {
            return;
        }
        if shutdown {
            trigger_shutdown(&shared);
            return;
        }
    }
}

/// Flags the drain and pokes the blocking acceptor with a self-connection.
fn trigger_shutdown(shared: &Shared) {
    if !shared.shutting_down.swap(true, Ordering::SeqCst) {
        // Normalize a wildcard bind (0.0.0.0 / ::) to loopback: connecting
        // to the unspecified address is not valid on every platform, and a
        // failed poke would leave the acceptor blocked forever.
        let mut target = shared.addr;
        if target.ip().is_unspecified() {
            let ip: std::net::IpAddr = match target {
                SocketAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                SocketAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            };
            target.set_ip(ip);
        }
        let _ = TcpStream::connect(target);
    }
}

fn dispatch(shared: &Shared, req: Request) -> (Json, bool) {
    match req {
        Request::Submit(spec) => match shared.scheduler.submit(spec) {
            Ok(rec) => (protocol::ok(vec![("job", rec.to_json())]), false),
            Err(e) => (protocol::err(format!("{e:#}")), false),
        },
        Request::Status(id) => match shared.scheduler.status(&id) {
            Some(rec) => (protocol::ok(vec![("job", rec.to_json())]), false),
            None => (protocol::err(format!("no such job {id}")), false),
        },
        Request::Result(id) => match shared.scheduler.status(&id) {
            Some(rec) => match rec.state {
                super::job::JobState::Done => {
                    let mut fields = vec![("job", rec.to_json())];
                    let rdir = shared.scheduler.result_dir(&id);
                    if rdir.exists() {
                        fields.push(("result_dir", Json::str(rdir.display().to_string())));
                    }
                    (protocol::ok(fields), false)
                }
                super::job::JobState::Failed => (
                    protocol::err(format!(
                        "job {id} failed: {}",
                        rec.error.as_deref().unwrap_or("unknown")
                    )),
                    false,
                ),
                other => (
                    protocol::err(format!("job {id} not finished (state {})", other.as_str())),
                    false,
                ),
            },
            None => (protocol::err(format!("no such job {id}")), false),
        },
        Request::Cancel(id) => match shared.scheduler.cancel(&id) {
            Ok(rec) => (protocol::ok(vec![("job", rec.to_json())]), false),
            Err(e) => (protocol::err(format!("{e:#}")), false),
        },
        Request::Metrics => {
            let snap: BTreeMap<String, Json> = shared
                .metrics
                .snapshot()
                .into_iter()
                .map(|(k, v)| (k, Json::num(v as f64)))
                .collect();
            (protocol::ok(vec![("metrics", Json::Obj(snap))]), false)
        }
        Request::Shutdown => (protocol::ok(vec![("draining", Json::Bool(true))]), true),
    }
}
