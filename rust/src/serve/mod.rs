//! `serve/` — the multi-tenant decomposition service (PR 4).
//!
//! Turns the one-shot [`Pipeline`](crate::coordinator::Pipeline) into a
//! long-lived daemon: tenants `SUBMIT` jobs over a line-delimited JSON TCP
//! protocol, a scheduler admits as many as fit a global memory budget
//! (priced per job by [`MemoryPlanner`](crate::coordinator::MemoryPlanner)),
//! repeated inputs are served from an LRU result cache keyed by tensor
//! fingerprint, and every job record is spooled so a killed daemon
//! recovers its queue — running jobs resume mid-compression from their
//! incremental checkpoints, bitwise-identically.
//!
//! Module map:
//!
//! * [`job`]       — job model + lifecycle + the crash-safe JSON spool.
//! * [`scheduler`] — priority/FIFO queue, admission control, worker pool,
//!   and the batch lane that coalesces compatible small jobs into one
//!   shared ALS sweep.
//! * [`batch`]     — batch-lane policy: eligibility threshold, sweep
//!   compatibility key, deficit-round-robin tenant fair share.
//! * [`cache`]     — tensor fingerprinting + LRU byte-budget result cache.
//! * [`protocol`]  — the wire format (`SUBMIT`/`STATUS`/`RESULT`/`CANCEL`/
//!   `LIST`/`METRICS`/`SHUTDOWN`, plus the worker plane `WORKER_HELLO`/
//!   `LEASE`/`PARTIAL`/`RENEW`) and the one-shot client.
//! * [`server`]    — the TCP accept loop + graceful drain.
//! * [`shard`]     — the coordinator's lease ledger for sharded jobs:
//!   shard slots, deadlines, digest-checked partial ingestion, and the
//!   in-shard-order fold that keeps results bitwise identical.
//! * [`worker`]    — the thin worker-process loop that joins a
//!   coordinator and executes leased shard ranges.

pub mod batch;
pub mod cache;
pub mod job;
pub mod protocol;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod worker;

pub use batch::{compat_key, lane_eligible, DrrState};
pub use cache::{cache_key, file_fingerprint, model_digest, CachedResult, ResultCache};
pub use job::{JobId, JobOutcome, JobRecord, JobSource, JobSpec, JobState, Spool};
pub use protocol::Request;
pub use scheduler::{Scheduler, SchedulerConfig};
pub use server::{Server, ServerConfig, DEFAULT_CONN_TIMEOUT_MS, DEFAULT_MAX_CONNS};
pub use shard::{LeaseGrant, ShardConfig, ShardRegistry};
pub use worker::{run_worker, WorkerConfig, WorkerReport};
