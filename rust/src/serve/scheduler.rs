//! Priority/FIFO job scheduler with **memory-budget admission control**.
//!
//! Every submitted job is priced up front by
//! [`MemoryPlanner`](crate::coordinator::MemoryPlanner): the resolved
//! plan's `estimated_bytes` (which, since PR 4, includes the replica maps
//! — the exascale-dominant term) is the job's admission cost.  Workers
//! admit jobs in priority-then-FIFO order, **backfilling** past any job
//! that does not currently fit the global budget: small jobs run alongside
//! one big out-of-core job instead of head-of-line blocking behind it.
//! Backfill is **bounded by an anti-starvation reservation**
//! ([`SchedulerConfig::starvation_rounds`]): once the head job has been
//! passed over that many times, no new jobs are admitted until running
//! work drains enough for the head to fit — a continuous stream of small
//! jobs can delay a large one by at most `starvation_rounds` backfills
//! plus one drain.  Deferrals are observable: `admission_rejected_bytes`
//! counts each job's bytes once at its first deferral,
//! `admission_deferred_bytes` carries the bytes currently blocked ahead of
//! the last admission, `admission_head_deferrals` gauges the current
//! head's consumed rounds, and `admission_reservation_holds` counts picks
//! the reservation refused.
//!
//! Jobs run on a bounded pool of worker threads (one job per worker; the
//! pipeline's own `threads` knob governs intra-job parallelism).  Each
//! running job writes the pipeline's incremental checkpoints under its
//! spool directory, so a killed daemon requeues `running` jobs on restart
//! and they resume mid-compression bitwise-identically.
//!
//! Shutdown is a graceful drain: no new admissions, running jobs complete,
//! queued jobs stay spooled for the next start.

use super::cache::{cache_key, model_digest, source_fingerprint, CachedResult, ResultCache};
use super::job::{JobId, JobOutcome, JobRecord, JobSpec, JobState, Spool};
use super::protocol::PartialMsg;
use super::shard::{ShardConfig, ShardRegistry};
use crate::coordinator::{checkpoint, MemoryPlanner, Metrics, Pipeline, PipelineResult};
use crate::cp::CpModel;
use crate::store::{ArtifactStore, PinGuard, StageKey};
use crate::tensor::TensorSource;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Scheduler construction knobs.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Global admission budget in bytes (0 = unlimited: admit up to the
    /// worker count).  Per-job planner budgets are clamped to this, so a
    /// job either resolves a plan that fits or fails at submission.
    pub memory_budget: usize,
    /// Concurrent jobs (worker threads).
    pub workers: usize,
    /// Result-cache toggle, kept as a byte count for CLI compatibility:
    /// 0 disables caching of final factor sets; any other value enables
    /// it.  Cached factors live in the artifact store and are bounded by
    /// `store_bytes`, not by this knob.
    pub cache_bytes: usize,
    /// Artifact-store byte budget (proxies + shard accumulators + cached
    /// factors, LRU-evicted together).  0 disables the store entirely:
    /// no stage reuse, no warm admission, no result cache persistence.
    pub store_bytes: usize,
    /// **Anti-starvation reservation**: how many backfill admissions the
    /// head-of-queue job tolerates while it does not fit the budget.
    /// Once a blocked head has been passed over this many times, no
    /// further jobs are admitted until running work drains enough for the
    /// head to fit (it always does: a lone job's plan is clamped to the
    /// budget at submission).  Bounds head-of-line delay to
    /// `starvation_rounds` backfill jobs plus the drain, at the cost of
    /// briefly idling workers.  0 disables backfill entirely (strict
    /// priority/FIFO).
    pub starvation_rounds: u64,
    /// **Retry policy**: how many times a job that failed *transiently*
    /// (its error carries the I/O layer's transient marker — an exhausted
    /// read-retry budget, an injected fault) is requeued before it is
    /// finally `failed`.  Retried jobs re-enter the queue after an
    /// exponential backoff and resume from their incremental checkpoint,
    /// so a retry re-streams only the unfolded suffix.
    pub max_retries: u32,
    /// **Poison policy**: a job whose run *panics* this many times is
    /// moved to the terminal `quarantined` state instead of being retried
    /// again — one poison job must not eat the worker pool forever.  A
    /// daemon crash while a job runs counts as one panic (recovery cannot
    /// tell them apart).
    pub poison_threshold: u32,
    /// Base retry backoff in milliseconds (doubled per prior attempt,
    /// capped at 5 s).
    pub retry_backoff_ms: u64,
    /// **Batch lane** threshold in bytes: when an admitted job's plan
    /// costs at most this, compatible queued jobs (same
    /// [`compat_key`](super::batch::compat_key)) coalesce with it into one
    /// shared ALS sweep occupying a single worker.  0 disables the lane
    /// (the default): every job keeps the per-job path.
    pub batch_threshold_bytes: usize,
    /// Max jobs per coalesced sweep (values below 2 disable coalescing).
    pub batch_max_jobs: usize,
    /// Per-tenant cap on concurrently running jobs enforced by the lane
    /// extension (0 = unlimited).  Candidates deferred by the cap stay
    /// queued and are counted in `tenant_quota_deferrals`.
    pub tenant_quota: usize,
    /// **Shard leases**: a sharded job's lease with no PARTIAL/RENEW
    /// activity for this long is abandoned and its shards re-leased.
    pub lease_timeout_ms: u64,
    /// Max contiguous shards granted per lease to one worker.
    pub lease_shards: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            memory_budget: 0,
            workers: 2,
            cache_bytes: 64 << 20,
            store_bytes: 256 << 20,
            starvation_rounds: 8,
            max_retries: 2,
            poison_threshold: 2,
            retry_backoff_ms: 50,
            batch_threshold_bytes: 0,
            batch_max_jobs: 32,
            tenant_quota: 0,
            lease_timeout_ms: 5_000,
            lease_shards: 4,
        }
    }
}

struct State {
    records: BTreeMap<JobId, JobRecord>,
    /// Queued ids, sorted (priority desc, seq asc).
    queue: Vec<JobId>,
    /// Running ids → admission bytes.
    running: BTreeMap<JobId, usize>,
    used_bytes: usize,
    used_bytes_peak: usize,
    running_peak: usize,
    cancel_requested: BTreeSet<JobId>,
    /// Queued jobs whose bytes were already counted into the monotone
    /// `admission_rejected_bytes` counter (count once per deferral, not
    /// once per worker wakeup).
    deferred_seen: BTreeSet<JobId>,
    /// Anti-starvation bookkeeping: the currently blocked head-of-queue
    /// job and how many backfill jobs have been admitted past it.  Reset
    /// whenever the head changes or is admitted.
    head_block: Option<(JobId, u64)>,
    /// Retry backoff: requeued jobs are not admissible before this
    /// instant (in-memory only — a restart retries immediately, which is
    /// correct: the daemon restart IS the backoff).
    not_before: BTreeMap<JobId, Instant>,
    /// Batch-lane fair share across tenants (in-memory: fairness restarts
    /// clean with the daemon, which is fine — deficits only age within a
    /// contention episode).
    drr: super::batch::DrrState,
    next_seq: u64,
    shutting_down: bool,
}

struct Inner {
    spool: Spool,
    /// Content-addressed artifact store under the spool dir: compressed
    /// proxy sets, sharded-run accumulators, and cached factor sets.
    store: Arc<ArtifactStore>,
    cache: ResultCache,
    /// Store pins held for admitted warm jobs: a job priced with
    /// [`MemoryPlanner::warm_estimate`] must find its proxy artifact
    /// still resident when it runs, so the artifact is pinned from
    /// admission until the job settles ([`Inner::finalize`]).
    warm_pins: Mutex<BTreeMap<JobId, PinGuard>>,
    metrics: Arc<Metrics>,
    budget: usize,
    starvation_rounds: u64,
    max_retries: u32,
    poison_threshold: u32,
    retry_backoff_ms: u64,
    batch_threshold_bytes: usize,
    batch_max_jobs: usize,
    tenant_quota: usize,
    /// Lease ledger for sharded jobs (worker-plane verbs route here).
    shards: ShardRegistry,
    state: Mutex<State>,
    cv: Condvar,
}

/// The multi-tenant job scheduler.  All methods are `&self`; clone the
/// wrapping `Arc` to share it with the server's connection handlers.
pub struct Scheduler {
    inner: Arc<Inner>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Scheduler {
    /// Opens the spool, recovers persisted jobs (crashed `running` jobs are
    /// requeued and will resume from their checkpoints), and starts the
    /// worker pool.
    pub fn new(spool: Spool, cfg: SchedulerConfig, metrics: Arc<Metrics>) -> Result<Scheduler> {
        let recovered = spool.load_all()?;
        let mut state = State {
            records: BTreeMap::new(),
            queue: Vec::new(),
            running: BTreeMap::new(),
            used_bytes: 0,
            used_bytes_peak: 0,
            running_peak: 0,
            cancel_requested: BTreeSet::new(),
            deferred_seen: BTreeSet::new(),
            head_block: None,
            not_before: BTreeMap::new(),
            drr: super::batch::DrrState::new(),
            next_seq: 1,
            shutting_down: false,
        };
        let (mut requeued, mut resumable, mut quarantined) = (0u64, 0u64, 0u64);
        for mut rec in recovered {
            state.next_seq = state.next_seq.max(rec.seq + 1);
            match rec.state {
                JobState::Running | JobState::Submitted | JobState::Queued
                    if rec.cancel_requested =>
                {
                    // An acknowledged cancellation must survive the crash:
                    // honor it instead of requeueing.
                    rec.state = JobState::Cancelled;
                    spool.save(&rec)?;
                    checkpoint::clear(spool.checkpoint_dir(&rec.id)).ok();
                }
                JobState::Running | JobState::Submitted | JobState::Queued => {
                    if rec.state == JobState::Running {
                        // The daemon died while this job ran.  Recovery
                        // cannot tell an unlucky crash from a job that
                        // *causes* crashes, so it charges one panic — a
                        // record repeatedly found `running` at startup
                        // crosses the poison threshold and is quarantined
                        // instead of crash-looping the daemon.
                        rec.panics += 1;
                        if rec.panics >= cfg.poison_threshold.max(1) {
                            rec.state = JobState::Quarantined;
                            rec.error = Some(format!(
                                "quarantined: daemon died {} times while this job ran",
                                rec.panics
                            ));
                            spool.save(&rec)?;
                            quarantined += 1;
                            state.records.insert(rec.id.clone(), rec);
                            continue;
                        }
                    }
                    if checkpoint::partial_exists(spool.checkpoint_dir(&rec.id)) {
                        resumable += 1;
                    }
                    if rec.state != JobState::Queued || rec.panics > 0 {
                        rec.state = JobState::Queued;
                        spool.save(&rec)?;
                    }
                    requeued += 1;
                    state.queue.push(rec.id.clone());
                }
                _ => {} // terminal states are kept for STATUS/RESULT only
            }
            state.records.insert(rec.id.clone(), rec);
        }
        sort_queue(&mut state.queue, &state.records);
        metrics.set("jobs_recovered", requeued);
        metrics.set("jobs_resumable", resumable);
        if quarantined > 0 {
            metrics.incr("jobs_quarantined", quarantined);
        }
        let store = Arc::new(
            ArtifactStore::open(spool.store_dir(), cfg.store_bytes, Arc::clone(&metrics))
                .context("opening the artifact store")?,
        );
        let inner = Arc::new(Inner {
            spool,
            cache: ResultCache::over(Arc::clone(&store), cfg.cache_bytes > 0),
            shards: ShardRegistry::new(
                ShardConfig {
                    lease_timeout_ms: cfg.lease_timeout_ms,
                    lease_shards: cfg.lease_shards,
                    ..ShardConfig::default()
                },
                Arc::clone(&metrics),
            )
            .with_store(Arc::clone(&store)),
            store,
            warm_pins: Mutex::new(BTreeMap::new()),
            metrics,
            budget: cfg.memory_budget,
            starvation_rounds: cfg.starvation_rounds,
            max_retries: cfg.max_retries,
            poison_threshold: cfg.poison_threshold.max(1),
            retry_backoff_ms: cfg.retry_backoff_ms,
            batch_threshold_bytes: cfg.batch_threshold_bytes,
            batch_max_jobs: cfg.batch_max_jobs,
            tenant_quota: cfg.tenant_quota,
            state: Mutex::new(state),
            cv: Condvar::new(),
        });
        {
            let st = inner.state.lock().unwrap();
            inner.sync_gauges(&st);
        }
        let workers = (0..cfg.workers.max(1))
            .map(|w| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("serve-worker-{w}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawning scheduler worker")
            })
            .collect();
        Ok(Scheduler {
            inner,
            workers: Mutex::new(workers),
        })
    }

    /// Submits a job: prices it with the planner, checks the result cache
    /// (a hit completes the job instantly), otherwise enqueues it.
    /// Errors (unreadable input file, infeasible plan) reach the submitter
    /// directly — no job record is created.
    pub fn submit(&self, spec: JobSpec) -> Result<JobRecord> {
        let key = cache_key(&spec)?;
        let source_fp = source_fingerprint(&spec.source)?;
        let dims = spec.source.dims()?;
        let mut cfg = spec.config.clone();
        // The daemon's global budget caps every per-job plan: a job either
        // resolves (possibly out-of-core) under it or is rejected here,
        // so one admitted job can never exceed the whole budget.
        if self.inner.budget > 0
            && (cfg.memory_budget == 0 || cfg.memory_budget > self.inner.budget)
        {
            cfg.memory_budget = self.inner.budget;
        }
        // Price with checkpointing on (every daemon job checkpoints): the
        // planner counts the incremental-snapshot sets only when a
        // checkpoint dir is present, and the real path is assigned below
        // once the id exists — only `is_some` affects the estimate.
        cfg.checkpoint_dir = Some(self.inner.spool.checkpoint_dir("pending"));
        let plan = MemoryPlanner::plan(&cfg, dims)
            .context("admission: resolving the job's memory plan")?;

        // Warm pricing: when the job's Stage-1 proxy artifact is already
        // resident, the run will never stream a block, so admission
        // charges only the remaining stages (proxies + maps + recovery).
        // The artifact is pinned so LRU eviction cannot invalidate the
        // discount between admission and the run; the pin is released
        // when the job settles.  `refine_sweeps > 0` re-streams the
        // input, and mixed precision takes the non-"batched" partition,
        // so both keep the cold price.
        let mut plan_bytes = plan.estimated_bytes;
        let mut warm_pin: Option<PinGuard> = None;
        if !spec.no_cache && cfg.refine_sweeps == 0 && !cfg.mixed_precision {
            let pkey = StageKey::proxies(
                source_fp,
                dims,
                cfg.reduced,
                plan.replicas,
                cfg.effective_anchor(),
                cfg.seed,
                cfg.mixed_precision,
                plan.block,
                "batched",
            );
            if let Some(pin) = self.inner.store.pin(&pkey) {
                let warm = MemoryPlanner::warm_estimate(
                    dims,
                    cfg.reduced,
                    plan.replicas,
                    cfg.rank,
                    plan.map_tier,
                    cfg.recovery_panel_cols,
                    plan.recovery_solver,
                );
                if warm < plan_bytes {
                    plan_bytes = warm;
                    warm_pin = Some(pin);
                    self.inner.metrics.incr("admission_warm_priced", 1);
                }
            }
        }

        // Phase 1 (locked): allocate the id and publish the record in
        // `submitted` state — visible to STATUS, not yet runnable.
        let mut rec = {
            let mut st = self.inner.state.lock().unwrap();
            if st.shutting_down {
                bail!("daemon is shutting down, not accepting jobs");
            }
            let seq = st.next_seq;
            st.next_seq += 1;
            let id = format!("job-{seq:06}");
            cfg.checkpoint_dir = Some(self.inner.spool.checkpoint_dir(&id));
            let rec = JobRecord {
                id: id.clone(),
                seq,
                spec: JobSpec {
                    source: spec.source,
                    config: cfg,
                    priority: spec.priority,
                    tenant: spec.tenant,
                    sharded: spec.sharded,
                    no_cache: spec.no_cache,
                },
                state: JobState::Submitted,
                plan_bytes,
                cache_key: key,
                cancel_requested: false,
                resolved_solver: Some(plan.recovery_solver),
                attempts: 0,
                panics: 0,
                error: None,
                outcome: None,
            };
            st.records.insert(id, rec.clone());
            rec
        };
        if let Some(pin) = warm_pin {
            self.inner.warm_pins.lock().unwrap().insert(rec.id.clone(), pin);
        }

        // Cache fast path: completes instantly, no queue involvement.
        // `no_cache` jobs bypass it — they exist to measure cold runs.
        let mut hit_model = None;
        let cached = if rec.spec.no_cache {
            None
        } else {
            self.inner.cache.get(&rec.cache_key)
        };
        if let Some(hit) = cached {
            rec.state = JobState::Done;
            rec.outcome = Some(JobOutcome {
                rel_error: hit.rel_error,
                sampled_mse: hit.sampled_mse,
                dropped_replicas: hit.dropped_replicas,
                model_digest: hit.model_digest,
                from_cache: true,
            });
            hit_model = Some(hit.model);
        } else {
            rec.state = JobState::Queued;
        }

        // Phase 2 (off-lock): persist before the job becomes runnable — a
        // job a crash would silently lose must not exist, and spool disk
        // writes must not stall protocol reads or worker admissions.
        if let Err(e) = self.inner.spool.save(&rec) {
            let mut st = self.inner.state.lock().unwrap();
            st.records.remove(&rec.id);
            self.inner.sync_gauges(&st);
            drop(st);
            self.inner.warm_pins.lock().unwrap().remove(&rec.id);
            return Err(e);
        }

        // Phase 3 (locked): make it runnable (or terminal for a cache
        // hit) — unless a racing CANCEL transitioned it meanwhile.
        let rec_out = {
            let mut guard = self.inner.state.lock().unwrap();
            let st = &mut *guard;
            let current = match st.records.get(&rec.id) {
                Some(r) => r.state,
                None => bail!("job {} vanished during submission", rec.id),
            };
            if current == JobState::Submitted {
                {
                    let r = st.records.get_mut(&rec.id).unwrap();
                    r.state = rec.state;
                    r.outcome = rec.outcome.clone();
                }
                let out = st.records[&rec.id].clone();
                if out.state == JobState::Queued {
                    st.queue.push(out.id.clone());
                    sort_queue(&mut st.queue, &st.records);
                } else {
                    self.inner.metrics.incr("jobs_done", 1);
                }
                self.inner.sync_gauges(st);
                out
            } else {
                // A racing CANCEL transitioned it while we persisted; its
                // spool write may have been overwritten by phase 2 —
                // restore the current truth on disk.
                let out = st.records[&rec.id].clone();
                self.inner.sync_gauges(st);
                drop(guard);
                if let Err(e) = self.inner.spool.save(&out) {
                    log::warn!("spool: restoring {}: {e:#}", out.id);
                }
                return Ok(out);
            }
        };
        self.inner.cv.notify_all();
        // Cache-hit jobs still get their factor files (RESULT promises
        // them for every done job); written off-lock, it's small.
        if let Some(model) = hit_model {
            if let Err(e) = save_model(&self.inner.spool.result_dir(&rec_out.id), &model) {
                log::warn!("persisting cached factors for {}: {e:#}", rec_out.id);
            }
        }
        Ok(rec_out)
    }

    pub fn status(&self, id: &str) -> Option<JobRecord> {
        self.inner.state.lock().unwrap().records.get(id).cloned()
    }

    /// All records, submission order.
    pub fn jobs(&self) -> Vec<JobRecord> {
        let st = self.inner.state.lock().unwrap();
        let mut v: Vec<JobRecord> = st.records.values().cloned().collect();
        v.sort_by_key(|r| r.seq);
        v
    }

    /// Cancels a job.  Queued jobs cancel immediately; running jobs are
    /// flagged and transition to `cancelled` when their pipeline pass
    /// finishes (the streaming stages have no preemption point that would
    /// preserve checkpoint consistency).  Terminal jobs are left as-is.
    pub fn cancel(&self, id: &str) -> Result<JobRecord> {
        let mut st = self.inner.state.lock().unwrap();
        let rec = st.records.get(id).context("no such job")?.clone();
        match rec.state {
            JobState::Submitted | JobState::Queued => {
                st.queue.retain(|q| q.as_str() != id);
                st.deferred_seen.remove(id);
                st.not_before.remove(id);
                let snapshot = {
                    let r = st.records.get_mut(id).unwrap();
                    r.state = JobState::Cancelled;
                    r.clone()
                };
                self.inner.metrics.incr("jobs_cancelled", 1);
                self.inner.sync_gauges(&st);
                drop(st);
                self.inner.warm_pins.lock().unwrap().remove(id);
                if let Err(e) = self.inner.spool.save(&snapshot) {
                    log::warn!("spool: persisting cancel for {id}: {e:#}");
                }
                Ok(snapshot)
            }
            JobState::Running => {
                st.cancel_requested.insert(id.to_string());
                // Persist the flag so the acknowledged cancellation
                // survives a daemon crash mid-run (saved off-lock).
                let snapshot = {
                    let r = st.records.get_mut(id).unwrap();
                    r.cancel_requested = true;
                    r.clone()
                };
                drop(st);
                if let Err(e) = self.inner.spool.save(&snapshot) {
                    log::warn!("spool: persisting cancel flag for {id}: {e:#}");
                }
                Ok(snapshot)
            }
            _ => Ok(rec),
        }
    }

    /// Begins the graceful drain: stop admitting, let running jobs finish.
    /// Workers pulling LEASE are told to shut down; a running sharded job
    /// still completes — the registry's self-drain finishes any shards
    /// its departing workers abandoned.
    pub fn shutdown(&self) {
        let mut st = self.inner.state.lock().unwrap();
        st.shutting_down = true;
        drop(st);
        self.inner.shards.shutdown();
        self.inner.cv.notify_all();
    }

    /// Waits for every worker to exit (call after [`Scheduler::shutdown`]).
    pub fn join(&self) {
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.state.lock().unwrap().queue.len()
    }

    pub fn running_count(&self) -> usize {
        self.inner.state.lock().unwrap().running.len()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    /// Worker plane: `WORKER_HELLO` — registers a shard worker.
    pub fn worker_hello(&self, worker: &str) -> Json {
        self.inner.shards.hello(worker)
    }

    /// Worker plane: `LEASE` — grants a shard range or answers
    /// idle/shutdown.
    pub fn lease(&self, worker: &str) -> Json {
        self.inner.shards.lease(worker)
    }

    /// Worker plane: `PARTIAL` — ingests one replica of one shard
    /// accumulator.
    pub fn partial(&self, msg: &PartialMsg) -> Json {
        self.inner.shards.partial(msg)
    }

    /// Worker plane: `RENEW` — extends a live lease's deadline.
    pub fn renew(&self, worker: &str, job: &str, lease: u64) -> Json {
        self.inner.shards.renew(worker, job, lease)
    }

    /// Workers currently holding leases on `job` (`LIST`'s per-job
    /// assignment column).
    pub fn workers_for(&self, job: &str) -> Vec<String> {
        self.inner.shards.workers_for(job)
    }

    /// Where a finished job's factor files land in the spool.
    pub fn result_dir(&self, id: &str) -> std::path::PathBuf {
        self.inner.spool.result_dir(id)
    }

    /// Blocks until `id` reaches a terminal state (test/CLI convenience);
    /// errors after `timeout`.
    pub fn wait(&self, id: &str, timeout: std::time::Duration) -> Result<JobRecord> {
        let start = Instant::now();
        loop {
            match self.status(id) {
                Some(rec) if rec.state.is_terminal() => return Ok(rec),
                Some(_) => {}
                None => bail!("no such job {id}"),
            }
            if start.elapsed() > timeout {
                bail!("timed out waiting for {id}");
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
    }
}

/// Splits a `catch_unwind` result into the run's own outcome plus a
/// did-it-panic flag, rendering the panic payload into the job error.
fn unwrap_panic<T>(
    r: std::thread::Result<Result<T>>,
) -> (Result<T>, bool) {
    match r {
        Ok(r) => (r, false),
        Err(p) => {
            let what = if let Some(s) = p.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = p.downcast_ref::<String>() {
                s.clone()
            } else {
                "see daemon log".to_string()
            };
            (Err(anyhow::anyhow!("job panicked: {what}")), true)
        }
    }
}

/// Priority desc, then FIFO by sequence.
fn sort_queue(queue: &mut [JobId], records: &BTreeMap<JobId, JobRecord>) {
    queue.sort_by_key(|id| {
        let r = &records[id];
        (std::cmp::Reverse(r.spec.priority), r.seq)
    });
}

/// What one worker wakeup admitted: a single job, or a coalesced batch of
/// compatible small jobs that will share one ALS sweep on this worker.
enum Picked {
    Solo(JobId, JobRecord),
    Batch(Vec<(JobId, JobRecord)>),
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let picked = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutting_down {
                    return;
                }
                if let Some(picked) = inner.pick_admissible(&mut st) {
                    break picked;
                }
                // Sleep until woken — or until the earliest retry backoff
                // expires, so requeued jobs don't wait for unrelated
                // activity to re-trigger admission.
                let now = Instant::now();
                let timeout = st
                    .not_before
                    .values()
                    .min()
                    .map(|t| t.saturating_duration_since(now))
                    .unwrap_or(Duration::from_secs(3600))
                    .max(Duration::from_millis(1));
                st = inner.cv.wait_timeout(st, timeout).unwrap().0;
            }
        };
        // Persist the queued→running transitions off the state lock (the
        // in-memory record is authoritative; spool writes must not stall
        // protocol reads or peer admissions).
        match picked {
            Picked::Solo(id, snapshot) => {
                if let Err(e) = inner.spool.save(&snapshot) {
                    log::warn!("spool: persisting {id} running: {e:#}");
                }
                inner.run_job(&id);
            }
            Picked::Batch(members) => {
                for (id, snapshot) in &members {
                    if let Err(e) = inner.spool.save(snapshot) {
                        log::warn!("spool: persisting {id} running: {e:#}");
                    }
                }
                inner.run_batch(&members);
            }
        }
        // A completion frees budget: wake peers blocked on admission.
        inner.cv.notify_all();
    }
}

impl Inner {
    /// First queued job that fits the remaining budget, in priority/FIFO
    /// order.  Jobs scanned past are deferred, not rejected: each job's
    /// bytes feed the monotone `admission_rejected_bytes` counter once (at
    /// its first deferral), and the bytes currently blocked ahead of the
    /// admitted job are exported as the `admission_deferred_bytes` gauge —
    /// so queueing under memory pressure is observable via `METRICS`
    /// without the magnitude depending on worker wakeup frequency.
    ///
    /// **Anti-starvation reservation**: backfill past a blocked head job is
    /// capped at `starvation_rounds` admissions.  Past the cap, nothing is
    /// admitted until running work drains enough for the head to fit — a
    /// continuous stream of small jobs can no longer starve a large one
    /// (the documented PR 4 trade-off, now bounded).  Safe from deadlock:
    /// submission clamps every plan to the global budget, so the head
    /// always fits an empty budget, which the drain reaches.
    /// **Batch lane**: when the anchor pick is lane-eligible (see
    /// [`super::batch::lane_eligible`]) and no blocked head holds an
    /// anti-starvation reservation, compatible queued jobs are coalesced
    /// with it — budget-checked, per-tenant-quota-checked, ordered by
    /// deficit-round-robin fair share — into one [`Picked::Batch`] that a
    /// single worker runs as one shared sweep.  Big jobs and backfill
    /// admissions keep the per-job path untouched.
    ///
    /// Returns the picked id(s) plus record snapshots for the caller to
    /// persist off-lock.
    fn pick_admissible(&self, st: &mut State) -> Option<Picked> {
        let mut chosen = None;
        let mut deferred_bytes = 0u64;
        let mut reservation_hold = false;
        let now = Instant::now();
        for (pos, id) in st.queue.iter().enumerate() {
            if st.not_before.get(id).map_or(false, |t| *t > now) {
                // Retry backoff pending: not eligible yet, and not a
                // memory-pressure deferral either.
                continue;
            }
            let pb = st.records[id].plan_bytes;
            if self.budget == 0 || st.used_bytes + pb <= self.budget {
                chosen = Some(pos);
                break;
            }
            deferred_bytes += pb as u64;
            if st.deferred_seen.insert(id.clone()) {
                self.metrics.incr("admission_rejected_bytes", pb as u64);
            }
            if pos == 0 {
                // The head is blocked: consult (and maybe start) its
                // deferral count.  A changed head resets the count.
                let rounds = match &st.head_block {
                    Some((hid, n)) if hid == id => *n,
                    _ => {
                        st.head_block = Some((id.clone(), 0));
                        0
                    }
                };
                self.metrics.set("admission_head_deferrals", rounds);
                if rounds >= self.starvation_rounds {
                    reservation_hold = true;
                    break;
                }
            }
        }
        if reservation_hold {
            self.metrics.incr("admission_reservation_holds", 1);
            self.metrics.set("admission_deferred_bytes", deferred_bytes);
            return None;
        }
        self.metrics.set("admission_deferred_bytes", deferred_bytes);
        let pos = chosen?;
        // Admitting past a blocked head consumes one of its tolerance
        // rounds; admitting the head itself clears the bookkeeping.
        if pos > 0 {
            if let (Some((hid, n)), Some(head)) = (&mut st.head_block, st.queue.first()) {
                if hid == head {
                    *n += 1;
                    self.metrics.set("admission_head_deferrals", *n);
                }
            }
        } else {
            st.head_block = None;
            self.metrics.set("admission_head_deferrals", 0);
        }
        let id = st.queue.remove(pos);
        st.deferred_seen.remove(&id);
        st.not_before.remove(&id);
        let pb = st.records[&id].plan_bytes;
        st.used_bytes += pb;
        st.used_bytes_peak = st.used_bytes_peak.max(st.used_bytes);
        st.running.insert(id.clone(), pb);
        st.running_peak = st.running_peak.max(st.running.len());
        let rec = st.records.get_mut(&id).unwrap();
        rec.state = JobState::Running;
        let snapshot = rec.clone();
        let members = self.extend_batch(st, &id, &snapshot, now);
        self.sync_gauges(st);
        match members {
            Some(members) => Some(Picked::Batch(members)),
            None => Some(Picked::Solo(id, snapshot)),
        }
    }

    /// Tries to grow the freshly admitted anchor job into a coalesced
    /// batch.  Returns `Some(members)` (anchor first, all already marked
    /// running and budget-charged) when at least one compatible job
    /// joined, `None` to run the anchor solo.
    ///
    /// Constraints honored per extension member:
    /// * lane on, anchor and member lane-eligible, identical `compat_key`;
    /// * no anti-starvation reservation in progress (`head_block` empty:
    ///   extending past a blocked head would spend backfill rounds the
    ///   reservation accounting never sees);
    /// * member's plan fits the remaining admission budget;
    /// * member's tenant below the in-flight quota (deferrals counted in
    ///   `tenant_quota_deferrals`);
    /// * member not waiting out a retry backoff;
    /// * candidate order decided by deficit-round-robin fair share, so a
    ///   tenant flooding small jobs shares the lane with everyone else.
    fn extend_batch(
        &self,
        st: &mut State,
        anchor_id: &JobId,
        anchor: &JobRecord,
        now: Instant,
    ) -> Option<Vec<(JobId, JobRecord)>> {
        if self.batch_threshold_bytes == 0
            || self.batch_max_jobs < 2
            || st.head_block.is_some()
            || !super::batch::lane_eligible(anchor, self.batch_threshold_bytes)
        {
            return None;
        }
        let key = super::batch::compat_key(anchor);
        // Per-tenant in-flight counts (the anchor is already in `running`).
        let mut in_flight: BTreeMap<String, usize> = BTreeMap::new();
        for rid in st.running.keys() {
            *in_flight
                .entry(st.records[rid].spec.tenant.clone())
                .or_insert(0) += 1;
        }
        // The compatible candidate pool, in queue (priority/FIFO) order.
        let mut pool: Vec<JobId> = st
            .queue
            .iter()
            .filter(|qid| {
                !st.not_before.get(*qid).map_or(false, |t| *t > now)
                    && super::batch::lane_eligible(&st.records[*qid], self.batch_threshold_bytes)
                    && super::batch::compat_key(&st.records[*qid]) == key
            })
            .cloned()
            .collect();
        let mut members = vec![(anchor_id.clone(), anchor.clone())];
        while members.len() < self.batch_max_jobs && !pool.is_empty() {
            // Tenants at their in-flight quota sit the sweep out; each
            // deferred candidate is counted once (it stays queued and will
            // anchor or join a later sweep).
            if self.tenant_quota > 0 {
                let before = pool.len();
                pool.retain(|qid| {
                    in_flight
                        .get(&st.records[qid].spec.tenant)
                        .map_or(true, |n| *n < self.tenant_quota)
                });
                let deferred = before - pool.len();
                if deferred > 0 {
                    self.metrics.incr("tenant_quota_deferrals", deferred as u64);
                }
            }
            if self.budget > 0 {
                pool.retain(|qid| st.used_bytes + st.records[qid].plan_bytes <= self.budget);
            }
            if pool.is_empty() {
                break;
            }
            let tenants: Vec<&str> = pool
                .iter()
                .map(|qid| st.records[qid].spec.tenant.as_str())
                .collect();
            let Some(k) = st.drr.pick(&tenants) else { break };
            let qid = pool.remove(k);
            st.queue.retain(|x| x != &qid);
            st.deferred_seen.remove(&qid);
            st.not_before.remove(&qid);
            let pb = st.records[&qid].plan_bytes;
            st.used_bytes += pb;
            st.used_bytes_peak = st.used_bytes_peak.max(st.used_bytes);
            st.running.insert(qid.clone(), pb);
            st.running_peak = st.running_peak.max(st.running.len());
            let rec = st.records.get_mut(&qid).unwrap();
            rec.state = JobState::Running;
            *in_flight.entry(rec.spec.tenant.clone()).or_insert(0) += 1;
            members.push((qid.clone(), rec.clone()));
        }
        if members.len() > 1 {
            Some(members)
        } else {
            None
        }
    }

    fn run_job(&self, id: &str) {
        let (rec, cancelled) = {
            let st = self.state.lock().unwrap();
            (
                st.records.get(id).cloned().expect("running job has a record"),
                st.cancel_requested.contains(id),
            )
        };
        if cancelled {
            self.finalize(id, JobState::Cancelled, None, None);
            return;
        }
        // A twin job may have finished while this one sat queued.
        if !rec.spec.no_cache {
            if let Some(hit) = self.cache.get(&rec.cache_key) {
                let outcome = JobOutcome {
                    rel_error: hit.rel_error,
                    sampled_mse: hit.sampled_mse,
                    dropped_replicas: hit.dropped_replicas,
                    model_digest: hit.model_digest,
                    from_cache: true,
                };
                if let Err(e) = save_model(&self.spool.result_dir(id), &hit.model) {
                    log::warn!("persisting cached factors for {id}: {e:#}");
                }
                self.finalize(id, JobState::Done, Some(outcome), None);
                return;
            }
        }

        let started = Instant::now();
        let run = catch_unwind(AssertUnwindSafe(|| -> Result<(CpModel, JobOutcome)> {
            // Fault site `worker_panic`, keyed by the job's sequence so a
            // chaos plan can poison ONE job while its neighbors run clean.
            if crate::util::fault::should_fault_keyed(
                crate::util::fault::Site::WorkerPanic,
                rec.seq,
            ) {
                panic!("injected worker panic (job {})", rec.id);
            }
            let src = rec.spec.source.open()?;
            let mut pipe = Pipeline::new(rec.spec.config.clone());
            if !rec.spec.no_cache {
                // Wire the artifact store through the pipeline's stage
                // seams: Stage 1 is looked up before any block streams
                // and published after the fold.
                match source_fingerprint(&rec.spec.source) {
                    Ok(fp) => pipe = pipe.with_store(Arc::clone(&self.store), fp),
                    Err(e) => log::warn!("source fingerprint for {}: {e:#}", rec.id),
                }
            }
            let res = if rec.spec.sharded {
                self.run_sharded(&rec, &mut pipe, src.as_ref())?
            } else {
                pipe.run(src.as_ref())?
            };
            self.fold_pipeline_metrics(&pipe);
            let digest = model_digest(&res.model);
            Ok((
                res.model,
                JobOutcome {
                    rel_error: res.diagnostics.rel_error,
                    sampled_mse: res.diagnostics.sampled_mse,
                    dropped_replicas: res.diagnostics.dropped_replicas,
                    model_digest: digest,
                    from_cache: false,
                },
            ))
        }));
        self.metrics.record("job_run", started.elapsed().as_secs_f64());
        let (run, panicked) = unwrap_panic(run);
        self.settle(id, &rec.cache_key, run, panicked);
    }

    /// Runs a sharded job: the compression stage executes on leased
    /// workers through the [`ShardRegistry`] (or the registry's own
    /// self-drain when none are live), and the decomposition/recovery
    /// stages run locally on the folded proxies.  The fold order makes
    /// the result bitwise identical to `pipe.run(src)`.
    ///
    /// Completed compressions are promoted to a full proxy checkpoint and
    /// the partial is cleared — the same handoff the solo compress stage
    /// performs — so a crash after compression resumes without re-leasing
    /// anything, and a transient-failure retry re-enters here and picks
    /// the proxies straight up.
    fn run_sharded(
        &self,
        rec: &JobRecord,
        pipe: &mut Pipeline,
        src: &dyn TensorSource,
    ) -> Result<PipelineResult> {
        let grid = pipe.sharded_grid(src)?;
        let dir = rec
            .spec
            .config
            .checkpoint_dir
            .clone()
            .context("sharded job has no checkpoint dir")?;
        let fp = checkpoint::default_fingerprint(&rec.spec.config, grid.dims, grid.replicas);
        // The sharded grid carries the same (block, replicas, anchor)
        // the solo planner resolves, so this key matches the artifact a
        // solo run of the same spec would publish — and vice versa.
        let proxy_key = if rec.spec.no_cache {
            None
        } else {
            source_fingerprint(&rec.spec.source).ok().map(|sfp| {
                StageKey::proxies(
                    sfp,
                    grid.dims,
                    grid.reduced,
                    grid.replicas,
                    grid.anchor,
                    grid.seed,
                    rec.spec.config.mixed_precision,
                    grid.block,
                    &grid.path,
                )
            })
        };
        let proxies = match checkpoint::load_proxies(&dir, &fp)? {
            Some(p) => p,
            None => {
                // Whole-set store hit: an earlier run of this grid left
                // its folded proxies — skip the lease protocol entirely.
                let resident = proxy_key
                    .as_ref()
                    .and_then(|k| self.store.get(k))
                    .filter(|p| p.len() == grid.replicas);
                let p = match resident {
                    Some(p) => p,
                    None => {
                        let p = self.shards.run_sharded(
                            &rec.id,
                            rec.spec.source.clone(),
                            grid,
                            &dir,
                            fp.clone(),
                            proxy_key.clone(),
                        )?;
                        if let Some(k) = &proxy_key {
                            if let Err(e) = self.store.publish(k, &p, &Json::Null) {
                                log::warn!("proxy publish {} failed: {e:#}", k.id());
                            }
                        }
                        p
                    }
                };
                checkpoint::save_proxies(&dir, &fp, &p)?;
                checkpoint::clear_partial(&dir)?;
                p
            }
        };
        pipe.run_with_proxies(src, proxies)
    }

    /// Runs a coalesced batch of admitted jobs as one shared ALS sweep on
    /// this worker thread.  Every member settles through the same paths a
    /// solo run uses (cancel, cache twin, retry/poison policy), so results
    /// — factors and `model_digest` — are bitwise identical to running each
    /// job alone; only the wall-clock cost is shared.
    ///
    /// If the shared sweep *panics*, the panic cannot be attributed to one
    /// member, so the whole batch falls back to solo runs: the genuinely
    /// poisonous job is charged its panic there (and quarantined at the
    /// threshold) while its peers complete normally.
    fn run_batch(&self, members: &[(JobId, JobRecord)]) {
        // Per-job prologue identical to run_job: cancelled jobs and
        // cache-twin hits settle immediately and drop out of the sweep.
        let mut live: Vec<(JobId, JobRecord)> = Vec::new();
        for (id, _) in members {
            let (rec, cancelled) = {
                let st = self.state.lock().unwrap();
                (
                    st.records.get(id).cloned().expect("running job has a record"),
                    st.cancel_requested.contains(id),
                )
            };
            if cancelled {
                self.finalize(id, JobState::Cancelled, None, None);
                continue;
            }
            let hit = if rec.spec.no_cache {
                None
            } else {
                self.cache.get(&rec.cache_key)
            };
            if let Some(hit) = hit {
                let outcome = JobOutcome {
                    rel_error: hit.rel_error,
                    sampled_mse: hit.sampled_mse,
                    dropped_replicas: hit.dropped_replicas,
                    model_digest: hit.model_digest,
                    from_cache: true,
                };
                if let Err(e) = save_model(&self.spool.result_dir(id), &hit.model) {
                    log::warn!("persisting cached factors for {id}: {e:#}");
                }
                self.finalize(id, JobState::Done, Some(outcome), None);
                continue;
            }
            live.push((id.clone(), rec));
        }
        match live.len() {
            0 => return,
            1 => return self.run_job(&live[0].0), // degenerate batch
            _ => {}
        }
        self.metrics.incr("batch_sweeps", 1);
        self.metrics.incr("batch_jobs_coalesced", live.len() as u64);
        let started = Instant::now();
        type PerJob = Vec<Result<(CpModel, JobOutcome)>>;
        let run = catch_unwind(AssertUnwindSafe(|| -> PerJob {
            // Per-job fault probes, same site/key as the solo path, so a
            // chaos plan can poison ONE member while its peers run clean
            // (via the solo fallback below).
            for (id, rec) in &live {
                if crate::util::fault::should_fault_keyed(
                    crate::util::fault::Site::WorkerPanic,
                    rec.seq,
                ) {
                    panic!("injected worker panic (job {id})");
                }
            }
            // Open every input; a job whose source fails to open settles
            // through its own error without failing its batch peers.
            let mut out: Vec<Option<Result<(CpModel, JobOutcome)>>> =
                live.iter().map(|_| None).collect();
            let mut pipes: Vec<Pipeline> = Vec::new();
            let mut srcs = Vec::new();
            let mut swept: Vec<usize> = Vec::new();
            for (i, (_, rec)) in live.iter().enumerate() {
                match rec.spec.source.open() {
                    Ok(s) => {
                        let mut pipe = Pipeline::new(rec.spec.config.clone());
                        if !rec.spec.no_cache {
                            match source_fingerprint(&rec.spec.source) {
                                Ok(fp) => {
                                    pipe = pipe.with_store(Arc::clone(&self.store), fp);
                                }
                                Err(e) => {
                                    log::warn!("source fingerprint for {}: {e:#}", rec.id);
                                }
                            }
                        }
                        pipes.push(pipe);
                        srcs.push(s);
                        swept.push(i);
                    }
                    Err(e) => out[i] = Some(Err(e)),
                }
            }
            let src_refs: Vec<&dyn crate::tensor::TensorSource> =
                srcs.iter().map(|b| b.as_ref()).collect();
            let results = crate::coordinator::run_batch_group(&mut pipes, &src_refs);
            for ((i, pipe), res) in swept.iter().zip(&pipes).zip(results) {
                out[*i] = Some(res.map(|res| {
                    self.fold_pipeline_metrics(pipe);
                    let digest = model_digest(&res.model);
                    (
                        res.model,
                        JobOutcome {
                            rel_error: res.diagnostics.rel_error,
                            sampled_mse: res.diagnostics.sampled_mse,
                            dropped_replicas: res.diagnostics.dropped_replicas,
                            model_digest: digest,
                            from_cache: false,
                        },
                    )
                }));
            }
            out.into_iter().map(|o| o.expect("every member settled")).collect()
        }));
        self.metrics.record("job_run", started.elapsed().as_secs_f64());
        match run {
            Ok(per_job) => {
                for ((id, rec), res) in live.iter().zip(per_job) {
                    self.settle(id, &rec.cache_key, res, false);
                }
            }
            Err(p) => {
                let what = if let Some(s) = p.downcast_ref::<&str>() {
                    (*s).to_string()
                } else if let Some(s) = p.downcast_ref::<String>() {
                    s.clone()
                } else {
                    "see daemon log".to_string()
                };
                log::warn!(
                    "batch sweep of {} jobs panicked ({what}); falling back to solo runs",
                    live.len()
                );
                self.metrics.incr("batch_sweep_panics", 1);
                for (id, _) in &live {
                    self.run_job(id);
                }
            }
        }
    }

    /// Folds one finished pipeline's metrics into the daemon registry
    /// (aggregate traffic: blocks_streamed, checkpoint resumes, …).
    /// Gauge-style values must not be summed — last run wins.
    fn fold_pipeline_metrics(&self, pipe: &Pipeline) {
        const GAUGES: [&str; 4] = [
            "compress_prefetch_depth",
            "recovery_cg_iters",
            "recovery_solver_iterative",
            "batch_lane_depth",
        ];
        for (k, v) in pipe.metrics.snapshot() {
            if GAUGES.contains(&k.as_str()) {
                self.metrics.set(&k, v);
            } else {
                self.metrics.incr(&k, v);
            }
        }
    }

    /// Transitions a finished run — solo or one member of a batch — into
    /// its terminal (or retry) state: the cancel/cache/retry/poison policy
    /// shared by both execution paths.
    fn settle(
        &self,
        id: &str,
        cache_key: &str,
        run: Result<(CpModel, JobOutcome)>,
        panicked: bool,
    ) {
        match run {
            Ok((model, outcome)) => {
                let (cancelled, no_cache) = {
                    let st = self.state.lock().unwrap();
                    (
                        st.cancel_requested.contains(id),
                        st.records.get(id).is_some_and(|r| r.spec.no_cache),
                    )
                };
                if cancelled {
                    checkpoint::clear(self.spool.checkpoint_dir(id)).ok();
                    self.finalize(id, JobState::Cancelled, None, None);
                    return;
                }
                if let Err(e) = save_model(&self.spool.result_dir(id), &model) {
                    log::warn!("persisting result factors for {id}: {e:#}");
                }
                if !no_cache {
                    self.cache.insert(
                        cache_key.to_string(),
                        CachedResult {
                            model: Arc::new(model),
                            rel_error: outcome.rel_error,
                            sampled_mse: outcome.sampled_mse,
                            dropped_replicas: outcome.dropped_replicas,
                            model_digest: outcome.model_digest,
                        },
                    );
                }
                // The job is complete: its pipeline checkpoints are dead
                // weight (the spooled factors are the durable artifact).
                checkpoint::clear(self.spool.checkpoint_dir(id)).ok();
                self.finalize(id, JobState::Done, Some(outcome), None);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                let (cancelled, counters) = {
                    let st = self.state.lock().unwrap();
                    let r = st.records.get(id);
                    (
                        st.cancel_requested.contains(id),
                        r.map(|r| (r.attempts, r.panics)).unwrap_or((0, 0)),
                    )
                };
                if cancelled {
                    checkpoint::clear(self.spool.checkpoint_dir(id)).ok();
                    self.finalize(id, JobState::Cancelled, None, None);
                } else if panicked {
                    // Poison policy: charge one panic; quarantine at the
                    // threshold, otherwise retry with backoff (the panic
                    // may have been environmental).
                    let panics = counters.1 + 1;
                    if panics >= self.poison_threshold {
                        self.bump_counters(id, None, Some(panics));
                        self.finalize(id, JobState::Quarantined, None, Some(msg));
                    } else {
                        self.bump_counters(id, None, Some(panics));
                        self.requeue_with_backoff(id, msg);
                    }
                } else if crate::util::fault::is_transient(&msg) {
                    // Transient failure (exhausted I/O retries — the error
                    // carries the marker, and checkpoint-then-fail already
                    // persisted the folded prefix): requeue up to the
                    // retry budget; the retry resumes mid-stream.
                    let attempts = counters.0 + 1;
                    self.bump_counters(id, Some(attempts), None);
                    if attempts <= self.max_retries {
                        self.requeue_with_backoff(id, msg);
                    } else {
                        self.finalize(
                            id,
                            JobState::Failed,
                            None,
                            Some(format!("{msg} ({} retries exhausted)", self.max_retries)),
                        );
                    }
                } else {
                    self.finalize(id, JobState::Failed, None, Some(msg));
                }
            }
        }
    }

    /// Writes updated retry counters into the in-memory record (persisted
    /// by the follow-up requeue/finalize save).
    fn bump_counters(&self, id: &str, attempts: Option<u32>, panics: Option<u32>) {
        let mut st = self.state.lock().unwrap();
        if let Some(rec) = st.records.get_mut(id) {
            if let Some(a) = attempts {
                rec.attempts = a;
            }
            if let Some(p) = panics {
                rec.panics = p;
            }
        }
    }

    /// Puts a failed-but-retryable job back in the queue behind an
    /// exponential backoff, releasing its admission budget.
    fn requeue_with_backoff(&self, id: &str, error: String) {
        let snapshot = {
            let mut st = self.state.lock().unwrap();
            if let Some(pb) = st.running.remove(id) {
                st.used_bytes -= pb;
            }
            let Some(rec) = st.records.get_mut(id) else { return };
            rec.state = JobState::Queued;
            // Keep the failure visible in STATUS while the retry waits.
            rec.error = Some(error);
            let tries = (rec.attempts + rec.panics).max(1).min(7);
            let snap = rec.clone();
            st.queue.push(id.to_string());
            sort_queue(&mut st.queue, &st.records);
            let delay =
                Duration::from_millis((self.retry_backoff_ms << (tries - 1)).min(5_000));
            st.not_before.insert(id.to_string(), Instant::now() + delay);
            self.metrics.incr("jobs_retried", 1);
            log::warn!(
                "job {id} retrying in {} ms (attempts={}, panics={}): {}",
                delay.as_millis(),
                snap.attempts,
                snap.panics,
                snap.error.as_deref().unwrap_or("")
            );
            self.sync_gauges(&st);
            snap
        };
        if let Err(e) = self.spool.save(&snapshot) {
            log::warn!("spool: persisting {id} retry: {e:#}");
        }
        self.cv.notify_all();
    }

    fn finalize(
        &self,
        id: &str,
        state: JobState,
        outcome: Option<JobOutcome>,
        error: Option<String>,
    ) {
        let snapshot = {
            let mut st = self.state.lock().unwrap();
            if let Some(pb) = st.running.remove(id) {
                st.used_bytes -= pb;
            }
            st.cancel_requested.remove(id);
            st.not_before.remove(id);
            let snap = st.records.get_mut(id).map(|rec| {
                rec.state = state;
                rec.outcome = outcome;
                rec.error = error;
                rec.clone()
            });
            let counter = match state {
                JobState::Done => "jobs_done",
                JobState::Failed => "jobs_failed",
                JobState::Quarantined => "jobs_quarantined",
                _ => "jobs_cancelled",
            };
            self.metrics.incr(counter, 1);
            self.sync_gauges(&st);
            snap
        };
        // A warm-priced job's proxy pin is released once the job settles
        // (whatever the terminal state): the artifact returns to plain
        // LRU standing.
        self.warm_pins.lock().unwrap().remove(id);
        // Off-lock persistence: the in-memory record is authoritative.  A
        // crash between the transition and this write re-runs the job on
        // restart — idempotent, and usually a cache hit.
        if let Some(rec) = snapshot {
            if let Err(e) = self.spool.save(&rec) {
                log::warn!("spool: persisting {id} {}: {e:#}", state.as_str());
            }
        }
    }

    /// Mirrors queue/running/cache state into the metrics registry — the
    /// single source the `METRICS` verb snapshots.
    fn sync_gauges(&self, st: &State) {
        self.metrics.set("jobs_queued", st.queue.len() as u64);
        self.metrics.set("jobs_running", st.running.len() as u64);
        // Lane depth: queued jobs currently eligible to coalesce (0 both
        // when the queue drains and when the lane is off).
        let lane_depth = st
            .queue
            .iter()
            .filter(|id| super::batch::lane_eligible(&st.records[*id], self.batch_threshold_bytes))
            .count();
        self.metrics.set("batch_lane_depth", lane_depth as u64);
        self.metrics.set("jobs_running_peak", st.running_peak as u64);
        self.metrics.set("admission_used_bytes", st.used_bytes as u64);
        self.metrics
            .set("admission_used_bytes_peak", st.used_bytes_peak as u64);
        let cs = self.cache.stats();
        self.metrics.set("cache_hits", cs.hits);
        self.metrics.set("cache_misses", cs.misses);
        self.metrics.set("cache_evictions", cs.evictions);
        self.metrics.set("cache_bytes", cs.used_bytes as u64);
        self.metrics.set("cache_entries", cs.entries as u64);
    }
}

/// Persists the factor matrices as EXT1 files under `dir`.
fn save_model(dir: &std::path::Path, model: &CpModel) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    crate::tensor::io::save_matrix(&model.a, dir.join("a.ext1"))?;
    crate::tensor::io::save_matrix(&model.b, dir.join("b.ext1"))?;
    crate::tensor::io::save_matrix(&model.c, dir.join("c.ext1"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PipelineConfig;
    use crate::serve::job::JobSource;
    use std::time::Duration;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_sched_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    fn small_spec(seed: u64, priority: i64) -> JobSpec {
        JobSpec {
            source: JobSource::Synthetic { size: 24, rank: 2, noise: 0.0, seed },
            config: PipelineConfig::builder()
                .reduced_dims(8, 8, 8)
                .rank(2)
                .anchor_rows(4)
                .block([8, 8, 8])
                .als(120, 1e-10)
                .threads(2)
                .seed(seed)
                .build()
                .unwrap(),
            priority,
            tenant: String::new(),
            sharded: false,
            no_cache: false,
        }
    }

    fn big_spec(seed: u64, priority: i64) -> JobSpec {
        JobSpec {
            source: JobSource::Synthetic { size: 48, rank: 2, noise: 0.0, seed },
            config: PipelineConfig::builder()
                .reduced_dims(12, 12, 12)
                .rank(2)
                .anchor_rows(4)
                .block([12, 12, 12])
                .als(120, 1e-10)
                .threads(2)
                .seed(seed)
                .build()
                .unwrap(),
            priority,
            tenant: String::new(),
            sharded: false,
            no_cache: false,
        }
    }

    fn sched(dir: &std::path::Path, cfg: SchedulerConfig) -> Scheduler {
        Scheduler::new(Spool::open(dir).unwrap(), cfg, Arc::new(Metrics::new())).unwrap()
    }

    #[test]
    fn submit_runs_to_done_and_repeat_hits_cache() {
        let dir = tmpdir("basic");
        let s = sched(&dir, SchedulerConfig { workers: 1, ..Default::default() });
        let rec = s.submit(small_spec(11, 0)).unwrap();
        assert_eq!(rec.state, JobState::Queued);
        assert!(rec.plan_bytes > 0, "planner must price the job");
        assert!(rec.resolved_solver.is_some(), "admission records the resolved solver");
        let done = s.wait(&rec.id, Duration::from_secs(120)).unwrap();
        assert_eq!(done.state, JobState::Done, "err: {:?}", done.error);
        let o1 = done.outcome.unwrap();
        assert!(!o1.from_cache);
        assert!(o1.rel_error < 0.05, "rel {}", o1.rel_error);
        // Identical resubmission: served from cache at submit time, same
        // digest, no second pipeline run.
        let rec2 = s.submit(small_spec(11, 0)).unwrap();
        assert_eq!(rec2.state, JobState::Done);
        let o2 = rec2.outcome.unwrap();
        assert!(o2.from_cache);
        assert_eq!(o2.model_digest, o1.model_digest);
        assert_eq!(s.metrics().counter("cache_hits"), 1);
        // Result factors persisted for the real run.
        assert!(dir.join("results").join(&rec.id).join("a.ext1").exists());
        s.shutdown();
        s.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_source_reaches_submitter_not_a_record() {
        let dir = tmpdir("badsubmit");
        let s = sched(&dir, SchedulerConfig::default());
        let spec = JobSpec {
            source: JobSource::File { path: "/nonexistent/t.ext1".into() },
            ..small_spec(1, 0)
        };
        assert!(s.submit(spec).is_err());
        assert_eq!(s.jobs().len(), 0);
        s.shutdown();
        s.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reservation_unblocks_starved_head() {
        let dir = tmpdir("starve");
        // Price the jobs exactly as submit() will (checkpoint dir present,
        // per-job budget clamped to the global one).
        let price = |spec: &JobSpec, budget: usize| {
            let mut cfg = spec.config.clone();
            if budget > 0 {
                cfg.memory_budget = budget;
            }
            cfg.checkpoint_dir = Some(dir.join("probe"));
            MemoryPlanner::plan(&cfg, spec.source.dims().unwrap())
                .unwrap()
                .estimated_bytes
        };
        let v_s = price(&small_spec(30, 0), 0);
        let v_b = price(&big_spec(22, 5), 0);
        // Shape invariants this scenario needs: two smalls coexist, the
        // big job never coexists with a small, the big job fits alone.
        assert!(v_b >= 2 * v_s, "big plan {v_b} must cost ≥ 2 smalls ({v_s})");
        let budget = v_b + v_s / 2;
        assert_eq!(v_s, price(&small_spec(30, 0), budget), "budget must not reshape smalls");
        assert_eq!(v_b, price(&big_spec(22, 5), budget), "budget must not reshape the big job");

        let s = sched(
            &dir,
            SchedulerConfig {
                memory_budget: budget,
                workers: 2,
                starvation_rounds: 2,
                ..Default::default()
            },
        );
        // One small occupies part of the budget, then the high-priority
        // big job becomes the blocked head while more smalls stream in —
        // the PR 4 starvation scenario.  Wait for the first small to be
        // *running* before submitting the big job, so the head is
        // deterministically blocked (not admitted into an empty budget).
        let first = s.submit(small_spec(30, 0)).unwrap();
        let t0 = Instant::now();
        while s.status(&first.id).unwrap().state == JobState::Queued {
            assert!(t0.elapsed() < Duration::from_secs(60), "first job never started");
            std::thread::sleep(Duration::from_millis(2));
        }
        let big = s.submit(big_spec(22, 5)).unwrap();
        let smalls: Vec<_> =
            (0..5).map(|i| s.submit(small_spec(40 + i, 0)).unwrap()).collect();

        let done_big = s.wait(&big.id, Duration::from_secs(300)).unwrap();
        assert_eq!(done_big.state, JobState::Done, "err: {:?}", done_big.error);
        // The reservation must have engaged at least once…
        assert!(
            s.metrics().counter("admission_reservation_holds") > 0,
            "blocked head never triggered the reservation"
        );
        // …and bounded backfill: without it all 5 trailing smalls would
        // finish first; with starvation_rounds = 2 at most 2 may (3 with
        // scheduling slack).
        let done_smalls = smalls
            .iter()
            .filter(|r| s.status(&r.id).unwrap().state == JobState::Done)
            .count();
        assert!(done_smalls <= 3, "head was starved: {done_smalls}/5 smalls finished first");

        for r in smalls.iter().chain([&first]) {
            let rec = s.wait(&r.id, Duration::from_secs(300)).unwrap();
            assert_eq!(rec.state, JobState::Done, "err: {:?}", rec.error);
        }
        s.shutdown();
        s.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crashed_running_job_charges_a_panic_and_quarantines_at_threshold() {
        let dir = tmpdir("quarantine");
        let spool = Spool::open(&dir).unwrap();
        // A record the previous daemon died holding in `running`, already
        // carrying one persisted panic: recovery charges a second, which
        // hits the default poison threshold (2) → terminal quarantine
        // instead of another crash-loop iteration.
        let rec = JobRecord {
            id: "job-000001".into(),
            seq: 1,
            spec: small_spec(77, 0),
            state: JobState::Running,
            plan_bytes: 1_000,
            cache_key: "qk".into(),
            cancel_requested: false,
            resolved_solver: None,
            attempts: 0,
            panics: 1,
            error: None,
            outcome: None,
        };
        spool.save(&rec).unwrap();
        let s = sched(&dir, SchedulerConfig { workers: 1, ..Default::default() });
        let st = s.status("job-000001").unwrap();
        assert_eq!(st.state, JobState::Quarantined);
        assert_eq!(st.panics, 2);
        assert!(st.error.unwrap().contains("quarantined"));
        assert_eq!(s.metrics().counter("jobs_quarantined"), 1);
        // The quarantine is durable: a second daemon leaves it terminal.
        s.shutdown();
        s.join();
        let s2 = sched(&dir, SchedulerConfig { workers: 1, ..Default::default() });
        assert_eq!(s2.status("job-000001").unwrap().state, JobState::Quarantined);
        assert_eq!(
            s2.metrics().counter("jobs_quarantined"),
            0,
            "terminal records are not re-quarantined"
        );
        s2.shutdown();
        s2.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn queued_job_cancels_immediately() {
        let dir = tmpdir("cancel");
        // Zero workers is clamped to 1, so block admission with a
        // ridiculous budget floor instead: budget smaller than any plan
        // keeps everything queued.
        let s = sched(
            &dir,
            SchedulerConfig { memory_budget: 1, workers: 1, ..Default::default() },
        );
        // Submission must fail the planner (cannot fit 1 byte)…
        assert!(s.submit(small_spec(5, 0)).is_err());
        s.shutdown();
        s.join();
        std::fs::remove_dir_all(&dir).ok();

        // …so exercise cancel on an admissible-but-unstarted job instead:
        // single worker, first job occupies it, second job sits queued.
        let dir = tmpdir("cancel2");
        let s = sched(&dir, SchedulerConfig { workers: 1, ..Default::default() });
        let a = s.submit(small_spec(6, 5)).unwrap();
        let b = s.submit(small_spec(7, 0)).unwrap();
        let c = s.cancel(&b.id).unwrap();
        assert!(
            c.state == JobState::Cancelled || c.state == JobState::Running,
            "cancel observed {:?}",
            c.state
        );
        let fb = s.wait(&b.id, Duration::from_secs(120)).unwrap();
        assert!(matches!(fb.state, JobState::Cancelled | JobState::Done));
        s.wait(&a.id, Duration::from_secs(120)).unwrap();
        s.shutdown();
        s.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The tentpole guarantee at daemon level: jobs run through a
    /// coalesced batch sweep produce bitwise the same `model_digest` as
    /// the same specs run solo, and the lane actually coalesces.
    #[test]
    fn batch_lane_matches_solo_digests_and_coalesces() {
        let specs: Vec<JobSpec> = (0..4)
            .map(|i| {
                let mut sp = small_spec(60 + i, 0);
                sp.tenant = if i % 2 == 0 { "even".into() } else { "odd".into() };
                sp
            })
            .collect();

        // Arm 1: lane off — the per-job path prices and runs each alone.
        let dir = tmpdir("lane_off");
        let s = sched(&dir, SchedulerConfig { workers: 1, ..Default::default() });
        let mut solo_digests = Vec::new();
        for sp in &specs {
            let rec = s.submit(sp.clone()).unwrap();
            let done = s.wait(&rec.id, Duration::from_secs(120)).unwrap();
            assert_eq!(done.state, JobState::Done, "err: {:?}", done.error);
            solo_digests.push(done.outcome.unwrap().model_digest);
        }
        assert_eq!(s.metrics().counter("batch_sweeps"), 0, "lane off must not sweep");
        s.shutdown();
        s.join();
        std::fs::remove_dir_all(&dir).ok();

        // Arm 2: lane on, single worker.  A higher-priority blocker
        // occupies the worker while the small jobs queue up, so when it
        // finishes the whole flood is visible to one admission tick and
        // coalesces deterministically.
        let dir = tmpdir("lane_on");
        let s = sched(
            &dir,
            SchedulerConfig {
                workers: 1,
                batch_threshold_bytes: usize::MAX,
                ..Default::default()
            },
        );
        let blocker = s.submit(big_spec(90, 10)).unwrap();
        let t0 = Instant::now();
        while s.status(&blocker.id).unwrap().state == JobState::Queued {
            assert!(t0.elapsed() < Duration::from_secs(60), "blocker never started");
            std::thread::sleep(Duration::from_millis(2));
        }
        let ids: Vec<_> = specs.iter().map(|sp| s.submit(sp.clone()).unwrap().id).collect();
        assert!(
            s.metrics().counter("batch_lane_depth") >= specs.len() as u64,
            "queued smalls must show up as lane depth"
        );
        for (i, id) in ids.iter().enumerate() {
            let done = s.wait(id, Duration::from_secs(120)).unwrap();
            assert_eq!(done.state, JobState::Done, "err: {:?}", done.error);
            let o = done.outcome.unwrap();
            assert!(!o.from_cache, "distinct specs must not alias in the cache");
            assert_eq!(
                o.model_digest, solo_digests[i],
                "job {i}: batched digest differs from solo"
            );
        }
        assert!(s.metrics().counter("batch_sweeps") >= 1, "no sweep coalesced");
        assert!(
            s.metrics().counter("batch_jobs_coalesced") >= 2,
            "coalesced {} jobs",
            s.metrics().counter("batch_jobs_coalesced")
        );
        s.shutdown();
        s.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// With a per-tenant in-flight quota of 1, a single tenant's flood
    /// cannot coalesce with itself: extension candidates are deferred (and
    /// counted), every job still completes through the solo path.
    #[test]
    fn tenant_quota_defers_lane_extension() {
        let dir = tmpdir("quota");
        let s = sched(
            &dir,
            SchedulerConfig {
                workers: 1,
                batch_threshold_bytes: usize::MAX,
                tenant_quota: 1,
                ..Default::default()
            },
        );
        let blocker = s.submit(big_spec(91, 10)).unwrap();
        let t0 = Instant::now();
        while s.status(&blocker.id).unwrap().state == JobState::Queued {
            assert!(t0.elapsed() < Duration::from_secs(60), "blocker never started");
            std::thread::sleep(Duration::from_millis(2));
        }
        let ids: Vec<_> = (0..3)
            .map(|i| {
                let mut sp = small_spec(70 + i, 0);
                sp.tenant = "flood".into();
                s.submit(sp).unwrap().id
            })
            .collect();
        for id in &ids {
            let done = s.wait(id, Duration::from_secs(120)).unwrap();
            assert_eq!(done.state, JobState::Done, "err: {:?}", done.error);
        }
        assert_eq!(
            s.metrics().counter("batch_sweeps"),
            0,
            "quota 1 must keep a single tenant's jobs from coalescing"
        );
        assert!(
            s.metrics().counter("tenant_quota_deferrals") >= 2,
            "deferrals: {}",
            s.metrics().counter("tenant_quota_deferrals")
        );
        s.shutdown();
        s.join();
        std::fs::remove_dir_all(&dir).ok();
    }
}
