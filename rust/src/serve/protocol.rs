//! Wire protocol: newline-delimited JSON over TCP, dependency-free.
//!
//! One request per line, one response per line; a connection may carry any
//! number of request/response pairs.  Requests are objects with a `cmd`
//! field (`SUBMIT`, `STATUS`, `RESULT`, `CANCEL`, `LIST`, `METRICS`,
//! `SHUTDOWN`, plus the worker-plane verbs `WORKER_HELLO`, `LEASE`,
//! `PARTIAL`, `RENEW` used by shard workers — see `serve/shard.rs`);
//! responses always carry `"ok": true|false` and, on failure, `"error"`.
//! `LIST` returns a one-line summary per known job —
//! id/state/tenant/priority (+ active shard workers) — for fleet
//! dashboards that must not pull every record's full spec.
//!
//! ```text
//! → {"cmd":"SUBMIT","spec":{"source":{...},"config":{...},"priority":0}}
//! ← {"ok":true,"job":{"id":"job-000001","state":"queued",...}}
//! → {"cmd":"METRICS"}
//! ← {"ok":true,"metrics":{"jobs_queued":1,"jobs_running":1,...}}
//! ```

use super::job::{JobId, JobSpec};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// One replica of one shard-local accumulator, streamed back from a
/// worker (`PARTIAL`).  Replicas are sent one per message so every line
/// stays under [`MAX_LINE_BYTES`] for serve-sized grids; `data` is the
/// base64-encoded little-endian `f32` bytes and `digest` their FNV-1a
/// hash, verified by the coordinator before the payload enters the fold.
#[derive(Clone, Debug)]
pub struct PartialMsg {
    pub worker: String,
    pub job: JobId,
    /// The lease this payload was computed under; a stale id (the range
    /// was re-leased after a timeout) is answered with `abandoned`.
    pub lease: u64,
    /// Global shard index in the deterministic partition.
    pub shard: usize,
    /// Replica index within the shard accumulator (`0..replicas`).
    pub replica: usize,
    pub data: String,
    pub digest: u64,
}

impl PartialMsg {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cmd", Json::str("PARTIAL")),
            ("worker", Json::str(self.worker.clone())),
            ("job", Json::str(self.job.clone())),
            ("lease", Json::num(self.lease as f64)),
            ("shard", Json::num(self.shard as f64)),
            ("replica", Json::num(self.replica as f64)),
            ("data", Json::str(self.data.clone())),
            ("digest", Json::str(format!("{:016x}", self.digest))),
        ])
    }

    fn from_json(v: &Json) -> Result<PartialMsg> {
        let field = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("PARTIAL missing {k}"))
        };
        Ok(PartialMsg {
            worker: v
                .get("worker")
                .and_then(|x| x.as_str())
                .context("PARTIAL missing worker")?
                .to_string(),
            job: v
                .get("job")
                .and_then(|x| x.as_str())
                .context("PARTIAL missing job")?
                .to_string(),
            lease: field("lease")? as u64,
            shard: field("shard")?,
            replica: field("replica")?,
            data: v
                .get("data")
                .and_then(|x| x.as_str())
                .context("PARTIAL missing data")?
                .to_string(),
            digest: u64::from_str_radix(
                v.get("digest")
                    .and_then(|x| x.as_str())
                    .context("PARTIAL missing digest")?,
                16,
            )
            .context("bad PARTIAL digest")?,
        })
    }
}

/// A parsed client request.
#[derive(Debug)]
pub enum Request {
    Submit(JobSpec),
    Status(JobId),
    Result(JobId),
    Cancel(JobId),
    /// Summaries of every known job (id, state, tenant, priority).
    List,
    Metrics,
    Shutdown,
    /// A shard worker announcing itself to the coordinator.
    WorkerHello { worker: String },
    /// A worker pulling its next lease; the response is a grant, an idle
    /// backoff hint, or a shutdown signal.
    Lease { worker: String },
    /// One replica of one shard accumulator computed under a lease.
    Partial(PartialMsg),
    /// Heartbeat extending a lease's deadline mid-computation.
    Renew { worker: String, job: JobId, lease: u64 },
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Submit(spec) => Json::obj(vec![
                ("cmd", Json::str("SUBMIT")),
                ("spec", spec.to_json()),
            ]),
            Request::Status(id) => {
                Json::obj(vec![("cmd", Json::str("STATUS")), ("id", Json::str(id.clone()))])
            }
            Request::Result(id) => {
                Json::obj(vec![("cmd", Json::str("RESULT")), ("id", Json::str(id.clone()))])
            }
            Request::Cancel(id) => {
                Json::obj(vec![("cmd", Json::str("CANCEL")), ("id", Json::str(id.clone()))])
            }
            Request::List => Json::obj(vec![("cmd", Json::str("LIST"))]),
            Request::Metrics => Json::obj(vec![("cmd", Json::str("METRICS"))]),
            Request::Shutdown => Json::obj(vec![("cmd", Json::str("SHUTDOWN"))]),
            Request::WorkerHello { worker } => Json::obj(vec![
                ("cmd", Json::str("WORKER_HELLO")),
                ("worker", Json::str(worker.clone())),
            ]),
            Request::Lease { worker } => Json::obj(vec![
                ("cmd", Json::str("LEASE")),
                ("worker", Json::str(worker.clone())),
            ]),
            Request::Partial(msg) => msg.to_json(),
            Request::Renew { worker, job, lease } => Json::obj(vec![
                ("cmd", Json::str("RENEW")),
                ("worker", Json::str(worker.clone())),
                ("job", Json::str(job.clone())),
                ("lease", Json::num(*lease as f64)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request> {
        let id = || -> Result<JobId> {
            Ok(v.get("id")
                .and_then(|x| x.as_str())
                .context("request missing id")?
                .to_string())
        };
        match v.get("cmd").and_then(|x| x.as_str()) {
            Some("SUBMIT") => Ok(Request::Submit(JobSpec::from_json(
                v.get("spec").context("SUBMIT missing spec")?,
            )?)),
            Some("STATUS") => Ok(Request::Status(id()?)),
            Some("RESULT") => Ok(Request::Result(id()?)),
            Some("CANCEL") => Ok(Request::Cancel(id()?)),
            Some("LIST") => Ok(Request::List),
            Some("METRICS") => Ok(Request::Metrics),
            Some("SHUTDOWN") => Ok(Request::Shutdown),
            Some("WORKER_HELLO") => Ok(Request::WorkerHello {
                worker: v
                    .get("worker")
                    .and_then(|x| x.as_str())
                    .context("WORKER_HELLO missing worker")?
                    .to_string(),
            }),
            Some("LEASE") => Ok(Request::Lease {
                worker: v
                    .get("worker")
                    .and_then(|x| x.as_str())
                    .context("LEASE missing worker")?
                    .to_string(),
            }),
            Some("PARTIAL") => Ok(Request::Partial(PartialMsg::from_json(v)?)),
            Some("RENEW") => Ok(Request::Renew {
                worker: v
                    .get("worker")
                    .and_then(|x| x.as_str())
                    .context("RENEW missing worker")?
                    .to_string(),
                job: v
                    .get("job")
                    .and_then(|x| x.as_str())
                    .context("RENEW missing job")?
                    .to_string(),
                lease: v
                    .get("lease")
                    .and_then(|x| x.as_usize())
                    .context("RENEW missing lease")? as u64,
            }),
            other => bail!("unknown cmd {other:?}"),
        }
    }
}

/// `{"ok":true, ...fields}`.
pub fn ok(fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.extend(fields);
    Json::obj(pairs)
}

/// `{"ok":false,"error":msg}`.
pub fn err(msg: impl std::fmt::Display) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg.to_string())),
    ])
}

/// Writes one message line (compact JSON + `\n`) and flushes.
pub fn write_line(w: &mut impl Write, v: &Json) -> std::io::Result<()> {
    w.write_all(v.to_string_compact().as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// Largest accepted message line.  A multi-tenant daemon must not let one
/// connection grow an unbounded `String`: a peer streaming bytes with no
/// newline is cut off here instead of OOMing everyone else's jobs.
pub const MAX_LINE_BYTES: u64 = 4 << 20;

/// Reads the next non-blank message line (blank lines are tolerated as
/// keep-alives and skipped); `Ok(None)` on clean EOF.  Lines longer than
/// [`MAX_LINE_BYTES`] are an error.
pub fn read_line_json(r: &mut impl BufRead) -> Result<Option<Json>> {
    loop {
        let mut line = String::new();
        let n = r
            .by_ref()
            .take(MAX_LINE_BYTES)
            .read_line(&mut line)
            .context("reading message line")?;
        if n == 0 {
            return Ok(None);
        }
        if n as u64 >= MAX_LINE_BYTES && !line.ends_with('\n') {
            bail!("message line exceeds {MAX_LINE_BYTES} bytes");
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        return Ok(Some(Json::parse(trimmed).context("parsing message")?));
    }
}

/// Error text for a request-read deadline expiry — the server matches on
/// this to count `conn_timeouts` (the vendored error type has no downcast).
pub const TIMEOUT_MSG: &str = "timed out waiting for a complete request line";

/// True if an error chain is the deadline expiry from
/// [`read_line_json_deadline`].
pub fn is_timeout_error(e: &anyhow::Error) -> bool {
    format!("{e:#}").contains(TIMEOUT_MSG)
}

/// Deadline-based server-side variant of [`read_line_json`]: a complete
/// request line must arrive before `deadline` no matter how slowly bytes
/// trickle in.  A per-read socket timeout alone cannot stop a slow-loris
/// peer that sends one byte per window — the caller sets a short socket
/// read timeout (so reads surface as `WouldBlock`/`TimedOut` here) and
/// this loop enforces the absolute deadline across them.  Blank keep-alive
/// lines are skipped but do NOT extend the deadline: an idle or half-open
/// connection is reaped once the deadline passes.
pub fn read_line_json_deadline(
    r: &mut impl BufRead,
    deadline: std::time::Instant,
) -> Result<Option<Json>> {
    let mut line = String::new();
    loop {
        if line.len() as u64 >= MAX_LINE_BYTES {
            bail!("message line exceeds {MAX_LINE_BYTES} bytes");
        }
        match r.by_ref().take(MAX_LINE_BYTES).read_line(&mut line) {
            // EOF: parse a final unterminated line, else clean close.
            Ok(0) => {
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    return Ok(None);
                }
                return Ok(Some(Json::parse(trimmed).context("parsing message")?));
            }
            Ok(_) => {
                let complete = line.ends_with('\n');
                if !complete && line.len() as u64 >= MAX_LINE_BYTES {
                    bail!("message line exceeds {MAX_LINE_BYTES} bytes");
                }
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    if !complete {
                        return Ok(None); // EOF after blank keep-alives
                    }
                    line.clear();
                    continue;
                }
                return Ok(Some(Json::parse(trimmed).context("parsing message")?));
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if std::time::Instant::now() >= deadline {
                    bail!("{TIMEOUT_MSG}");
                }
                // Partial bytes stay accumulated in `line`; keep waiting.
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("reading message line"),
        }
    }
}

/// One-shot client call: connect, send, read the single response.
pub fn call(addr: &str, req: &Request) -> Result<Json> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let mut w = stream.try_clone().context("cloning stream")?;
    write_line(&mut w, &req.to_json()).context("sending request")?;
    let mut r = BufReader::new(stream);
    read_line_json(&mut r)?.context("server closed the connection without replying")
}

/// `call` + ok-check: returns the response object or the server's error.
pub fn call_ok(addr: &str, req: &Request) -> Result<Json> {
    let resp = call(addr, req)?;
    if resp.get("ok").and_then(|x| x.as_bool()) != Some(true) {
        bail!(
            "server error: {}",
            resp.get("error").and_then(|x| x.as_str()).unwrap_or("unknown")
        );
    }
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PipelineConfig;
    use crate::serve::job::JobSource;

    #[test]
    fn request_round_trips() {
        let spec = JobSpec {
            source: JobSource::Synthetic { size: 16, rank: 2, noise: 0.0, seed: 1 },
            config: PipelineConfig::builder()
                .reduced_dims(8, 8, 8)
                .rank(2)
                .anchor_rows(4)
                .build()
                .unwrap(),
            priority: 1,
            tenant: "acme".into(),
            sharded: true,
            no_cache: false,
        };
        for req in [
            Request::Submit(spec),
            Request::Status("job-000001".into()),
            Request::Result("job-000002".into()),
            Request::Cancel("job-000003".into()),
            Request::List,
            Request::Metrics,
            Request::Shutdown,
            Request::WorkerHello { worker: "w0-123".into() },
            Request::Lease { worker: "w0-123".into() },
            Request::Partial(PartialMsg {
                worker: "w0-123".into(),
                job: "job-000004".into(),
                lease: 9,
                shard: 5,
                replica: 2,
                data: "0000803f".into(),
                digest: 0x1234_5678_9abc_def0,
            }),
            Request::Renew { worker: "w0-123".into(), job: "job-000004".into(), lease: 9 },
        ] {
            let v = Json::parse(&req.to_json().to_string_compact()).unwrap();
            let back = Request::from_json(&v).unwrap();
            assert_eq!(
                back.to_json().to_string_compact(),
                req.to_json().to_string_compact()
            );
        }
        assert!(Request::from_json(&Json::parse(r#"{"cmd":"NOPE"}"#).unwrap()).is_err());
        assert!(Request::from_json(&Json::parse(r#"{"cmd":"STATUS"}"#).unwrap()).is_err());
    }

    #[test]
    fn oversized_line_rejected_not_buffered() {
        let big = vec![b'x'; MAX_LINE_BYTES as usize + 16];
        let mut r = std::io::BufReader::new(&big[..]);
        assert!(read_line_json(&mut r).is_err(), "no-newline flood must error");
    }

    /// Mock stream: yields its chunks one `read` at a time; an empty chunk
    /// models a socket read timeout (`WouldBlock`), like a slow-loris peer
    /// pausing between bytes.
    struct Trickle {
        chunks: Vec<Vec<u8>>,
        i: usize,
    }
    impl Read for Trickle {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            let Some(c) = self.chunks.get(self.i) else { return Ok(0) };
            self.i += 1;
            if c.is_empty() {
                return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "tick"));
            }
            let n = c.len().min(out.len());
            out[..n].copy_from_slice(&c[..n]);
            Ok(n)
        }
    }

    #[test]
    fn deadline_reader_rides_out_timeouts_within_the_deadline() {
        let r = Trickle {
            chunks: vec![
                b"{\"ok\"".to_vec(),
                vec![], // timeout mid-line
                vec![],
                b":true}\n".to_vec(),
            ],
            i: 0,
        };
        let mut r = BufReader::new(r);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        let v = read_line_json_deadline(&mut r, deadline).unwrap().unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn deadline_reader_reaps_slow_loris_and_half_open_peers() {
        // Half-open: nothing but timeouts, deadline already passed.
        let r = Trickle { chunks: vec![vec![], vec![], vec![]], i: 0 };
        let mut r = BufReader::new(r);
        let deadline = std::time::Instant::now() - std::time::Duration::from_millis(1);
        let e = read_line_json_deadline(&mut r, deadline).unwrap_err();
        assert!(is_timeout_error(&e), "got: {e:#}");
        // Slow-loris: a byte per window never completes the line either.
        let r = Trickle {
            chunks: vec![b"{".to_vec(), vec![], b"\"".to_vec(), vec![]],
            i: 0,
        };
        let mut r = BufReader::new(r);
        let e = read_line_json_deadline(&mut r, deadline).unwrap_err();
        assert!(is_timeout_error(&e), "got: {e:#}");
    }

    #[test]
    fn line_io_round_trips_and_eof_is_none() {
        let msg = ok(vec![("x", Json::num(1.0))]);
        let mut buf = Vec::new();
        write_line(&mut buf, &msg).unwrap();
        buf.extend_from_slice(b"\n  \n"); // stray keep-alive blanks
        write_line(&mut buf, &err("boom")).unwrap();
        let mut r = std::io::BufReader::new(&buf[..]);
        let a = read_line_json(&mut r).unwrap().unwrap();
        assert_eq!(a.get("ok").unwrap(), &Json::Bool(true));
        let b = read_line_json(&mut r).unwrap().unwrap();
        assert_eq!(b.get("error").and_then(|x| x.as_str()), Some("boom"));
        assert!(read_line_json(&mut r).unwrap().is_none(), "EOF → None");
    }
}
