//! Shard-lease worker: the process that joins a coordinator and executes
//! leased shard ranges (PR 9).
//!
//! A worker is deliberately thin: it owns no queue, no checkpoint, and no
//! job state.  It polls `LEASE`, and each grant is **self-contained** —
//! the [`LeaseGrant`] carries the source descriptor and the full
//! [`ShardedGrid`](crate::coordinator::ShardedGrid), so the worker
//! rebuilds the replica maps and the fixed block partition locally and
//! runs [`compress_shard_batched`] over its range, one shard at a time.
//! Every replica of every finished shard streams back as a
//! digest-checked `PARTIAL`; the coordinator owns ordering, folding, and
//! retry.  If the coordinator answers `abandoned` (the lease deadline
//! passed and the range was re-leased), the worker simply drops the rest
//! of the range and pulls a fresh lease — at-least-once delivery is safe
//! because the registry ignores shards it has already completed.
//!
//! Worker death is injectable for chaos tests: a
//! [`FaultPlan`](crate::util::fault::FaultPlan) `worker_panic` schedule,
//! keyed by [`WorkerConfig::fault_key`], makes the worker die between
//! shards, which is exactly the failure the lease deadline exists to
//! absorb.

use super::protocol::{self, PartialMsg, Request};
use super::shard::{encode_f32_b64, payload_digest, LeaseGrant};
use crate::compress::{compress_shard_batched, MapSource};
use crate::util::fault::{should_fault_keyed, Site};
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Context, Result};
use std::time::Duration;

/// How a worker process joins a coordinator.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Coordinator address (`host:port`).
    pub addr: String,
    /// Worker name reported in `WORKER_HELLO` and shown by `LIST`.
    pub name: String,
    /// Idle backoff when the coordinator does not hint one.
    pub backoff_ms: u64,
    /// Key matched by `worker_panic:…,key=K` fault schedules, so a plan
    /// can kill exactly one worker of a fleet.
    pub fault_key: u64,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            addr: String::new(),
            name: "worker".to_string(),
            backoff_ms: 50,
            fault_key: 0,
        }
    }
}

/// What a worker did before the coordinator told it to stop.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerReport {
    pub leases: u64,
    pub shards: u64,
}

/// Joins the coordinator at `cfg.addr` and serves leases until it
/// answers `shutdown`.  Returns the tally for the CLI to print.
pub fn run_worker(cfg: &WorkerConfig) -> Result<WorkerReport> {
    protocol::call_ok(
        &cfg.addr,
        &Request::WorkerHello {
            worker: cfg.name.clone(),
        },
    )
    .with_context(|| format!("joining coordinator at {}", cfg.addr))?;
    let mut report = WorkerReport::default();
    loop {
        let resp = protocol::call_ok(
            &cfg.addr,
            &Request::Lease {
                worker: cfg.name.clone(),
            },
        )?;
        if resp.get("shutdown").and_then(|x| x.as_bool()) == Some(true) {
            return Ok(report);
        }
        if let Some(g) = resp.get("grant") {
            let grant = LeaseGrant::from_json(g).context("parsing lease grant")?;
            report.leases += 1;
            report.shards += serve_lease(cfg, &grant)?;
            continue;
        }
        let backoff = resp
            .get("backoff_ms")
            .and_then(|x| x.as_usize())
            .map_or(cfg.backoff_ms, |b| b as u64);
        std::thread::sleep(Duration::from_millis(backoff.max(1)));
    }
}

/// Executes one granted range shard by shard.  Returns how many shards
/// were fully delivered; stops early (without error) when the
/// coordinator reports the lease abandoned.
fn serve_lease(cfg: &WorkerConfig, grant: &LeaseGrant) -> Result<u64> {
    let g = &grant.grid;
    let src = grant.source.open().context("opening job source")?;
    let maps = MapSource::generate(g.dims, g.reduced, g.replicas, g.anchor, g.seed, g.map_tier);
    let shards = ThreadPool::partition(g.blocks_total, g.shard_parts);
    let mut served = 0u64;
    for s in grant.shard0..grant.shard1 {
        // Injected death, keyed so a FaultPlan targets one worker of a
        // fleet.  Dying *between* shards models the common crash window:
        // work lost mid-lease, nothing half-delivered.
        if should_fault_keyed(Site::WorkerPanic, cfg.fault_key) {
            bail!("injected worker death before shard {s} (transient)");
        }
        let &(b0, b1) = shards
            .get(s)
            .with_context(|| format!("granted shard {s} outside the {} partition", shards.len()))?;
        let acc = compress_shard_batched(src.as_ref(), &maps, g.block, b0, b1);
        for (replica, t) in acc.iter().enumerate() {
            let msg = PartialMsg {
                worker: cfg.name.clone(),
                job: grant.job.clone(),
                lease: grant.lease,
                shard: s,
                replica,
                data: encode_f32_b64(t.data()),
                digest: payload_digest(t.data()),
            };
            let resp = protocol::call_ok(&cfg.addr, &Request::Partial(msg))?;
            if resp.get("abandoned").and_then(|x| x.as_bool()) == Some(true) {
                return Ok(served);
            }
        }
        served += 1;
        // Heartbeat between shards so a long range outlives its deadline.
        if s + 1 < grant.shard1 {
            let resp = protocol::call_ok(
                &cfg.addr,
                &Request::Renew {
                    worker: cfg.name.clone(),
                    job: grant.job.clone(),
                    lease: grant.lease,
                },
            )?;
            if resp.get("abandoned").and_then(|x| x.as_bool()) == Some(true) {
                return Ok(served);
            }
        }
    }
    Ok(served)
}
