//! Shard-lease execution: the coordinator side of sharded multi-worker
//! runs (PR 9).
//!
//! A job submitted with `"sharded": true` does not stream its blocks in
//! the daemon process.  Instead the scheduler registers the job's
//! deterministic shard grid (the same fixed
//! [`ThreadPool::partition`] over the block grid the single-process
//! engine uses) with the [`ShardRegistry`], and worker processes pull
//! **leases** — contiguous shard ranges — over the serve protocol:
//!
//! ```text
//!   worker                    coordinator
//!   WORKER_HELLO  ─────────▶  register worker
//!   LEASE         ─────────▶  grant {job, lease, shard0..shard1, grid}
//!   (runs the engine on its range, one shard at a time)
//!   PARTIAL ×P    ─────────▶  verify digest, assemble replicas
//!   RENEW         ─────────▶  extend the lease deadline
//! ```
//!
//! Each completed shard arrives as `P` raw shard-local accumulators —
//! **never** a worker-side fold across shards, because float addition is
//! not associative and the single-process engine folds shard
//! accumulators into the proxies in strict shard-index order.  The
//! registry parks complete shards that arrive out of order and folds the
//! contiguous prefix with [`fold_shard_proxies`], so the final proxies —
//! and therefore the factors and `model_digest` downstream — are bitwise
//! identical to the unsharded run.
//!
//! The folded prefix doubles as the job's incremental checkpoint: it is
//! persisted with [`checkpoint::save_partial`] under the job's
//! checkpoint dir, so a restarted coordinator resumes from `shards_done`
//! exactly like the solo engine resumes mid-compression.  Leases that
//! miss their deadline (worker death, stalled connection) return their
//! unfinished shards to the pending set (`leases_relet`); and when no
//! live worker is pulling — none ever connected, all died, or the daemon
//! is draining — the coordinator drains pending shards itself with
//! [`compress_shard_batched`], so a sharded job always terminates with
//! the same bits, workers or not.
//!
//! When the registry is attached to an [`ArtifactStore`]
//! (`with_store`), every digest-verified shard accumulator is also
//! published as a content-addressed `ShardAccum` blob keyed by the
//! job's proxy [`StageKey`] plus `(shard, replica)`.  A restarted or
//! re-submitted job prefills its pending shards from resident blobs at
//! registration — the store is a second recovery tier that, unlike the
//! fold-prefix checkpoint, survives out-of-order arrival and is shared
//! across job ids.

use super::job::{JobId, JobSource};
use super::protocol::{self, PartialMsg};
use crate::compress::{compress_shard_batched, fold_shard_proxies, MapSource, MapTier};
use crate::coordinator::checkpoint::{self, CompressionProgress, Fingerprint};
use crate::coordinator::{Metrics, ShardedGrid};
use crate::store::{ArtifactStore, StageKey};
use crate::tensor::{DenseTensor, TensorSource};
use crate::util::hash::fnv1a64;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Registry knobs, lifted from the scheduler config.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// A lease with no PARTIAL/RENEW activity for this long is abandoned
    /// and its unfinished shards re-leased.
    pub lease_timeout_ms: u64,
    /// Max contiguous shards granted per lease.
    pub lease_shards: usize,
    /// Idle-poll backoff hint returned to workers when no work is ready.
    pub backoff_ms: u64,
    /// Persist the folded prefix every this many newly folded shards.
    pub checkpoint_every: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self {
            lease_timeout_ms: 5_000,
            lease_shards: 4,
            backoff_ms: 50,
            checkpoint_every: 8,
        }
    }
}

/// Base64-encodes `data` as little-endian `f32` bytes — the PARTIAL
/// payload encoding.  Base64 costs 4 wire bytes per 3 payload bytes
/// (the hex codec it replaced cost 2 per 1 — a 1.5× saving on every
/// accumulator crossing the protocol) while keeping the wire format
/// line-delimited JSON like every other verb; a shard accumulator is
/// `L·M·N` floats, far under [`protocol::MAX_LINE_BYTES`].
pub fn encode_f32_b64(data: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    crate::util::b64::encode(&bytes)
}

/// Inverse of [`encode_f32_b64`].
pub fn decode_f32_b64(s: &str) -> Result<Vec<f32>> {
    let bytes = crate::util::b64::decode(s)?;
    if bytes.len() % 4 != 0 {
        bail!("payload has {} bytes, not a whole number of f32s", bytes.len());
    }
    let mut out = Vec::with_capacity(bytes.len() / 4);
    for ch in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
    }
    Ok(out)
}

/// FNV-1a over the little-endian bytes of one accumulator payload — the
/// PARTIAL integrity check (same hash family as the checkpoint digests).
pub fn payload_digest(data: &[f32]) -> u64 {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fnv1a64(&bytes)
}

/// One granted lease, as carried in the LEASE response.  Self-contained:
/// a worker rebuilds the maps and the block grid from these fields alone
/// and produces bit-identical shard accumulators.
#[derive(Clone, Debug, PartialEq)]
pub struct LeaseGrant {
    pub job: JobId,
    pub lease: u64,
    /// Granted shard range `[shard0, shard1)` in the fixed partition.
    pub shard0: usize,
    pub shard1: usize,
    /// Lease deadline budget; a worker should RENEW well inside it.
    pub deadline_ms: u64,
    pub source: JobSource,
    pub grid: ShardedGrid,
}

fn grid_to_json(g: &ShardedGrid) -> Json {
    Json::obj(vec![
        ("dims", Json::arr_usize(&g.dims)),
        ("reduced", Json::arr_usize(&g.reduced)),
        ("replicas", Json::num(g.replicas as f64)),
        ("anchor", Json::num(g.anchor as f64)),
        ("seed", Json::num(g.seed as f64)),
        ("map_tier", Json::str(g.map_tier.as_str())),
        ("block", Json::arr_usize(&g.block)),
        ("blocks_total", Json::num(g.blocks_total as f64)),
        ("shard_parts", Json::num(g.shard_parts as f64)),
        ("path", Json::str(g.path.clone())),
    ])
}

fn usize3(v: &Json, key: &str) -> Result<[usize; 3]> {
    let arr = v
        .get(key)
        .and_then(|x| x.as_arr())
        .with_context(|| format!("grant missing {key}"))?;
    if arr.len() != 3 {
        bail!("grant {key} must have 3 entries");
    }
    let mut out = [0usize; 3];
    for (o, x) in out.iter_mut().zip(arr) {
        *o = x.as_usize().with_context(|| format!("bad {key} entry"))?;
    }
    Ok(out)
}

fn field_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(|x| x.as_usize())
        .with_context(|| format!("grant missing {key}"))
}

fn grid_from_json(v: &Json) -> Result<ShardedGrid> {
    let tier = match v.get("map_tier").and_then(|x| x.as_str()) {
        Some("materialized") => MapTier::Materialized,
        Some("procedural") => MapTier::Procedural,
        other => bail!("grant has unknown map_tier {other:?}"),
    };
    Ok(ShardedGrid {
        dims: usize3(v, "dims")?,
        reduced: usize3(v, "reduced")?,
        replicas: field_usize(v, "replicas")?,
        anchor: field_usize(v, "anchor")?,
        seed: field_usize(v, "seed")? as u64,
        map_tier: tier,
        block: usize3(v, "block")?,
        blocks_total: field_usize(v, "blocks_total")?,
        shard_parts: field_usize(v, "shard_parts")?,
        path: v
            .get("path")
            .and_then(|x| x.as_str())
            .context("grant missing path")?
            .to_string(),
    })
}

impl LeaseGrant {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::str(self.job.clone())),
            ("lease", Json::num(self.lease as f64)),
            ("shard0", Json::num(self.shard0 as f64)),
            ("shard1", Json::num(self.shard1 as f64)),
            ("deadline_ms", Json::num(self.deadline_ms as f64)),
            ("source", self.source.to_json()),
            ("grid", grid_to_json(&self.grid)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<LeaseGrant> {
        Ok(LeaseGrant {
            job: v
                .get("job")
                .and_then(|x| x.as_str())
                .context("grant missing job")?
                .to_string(),
            lease: field_usize(v, "lease")? as u64,
            shard0: field_usize(v, "shard0")?,
            shard1: field_usize(v, "shard1")?,
            deadline_ms: field_usize(v, "deadline_ms")? as u64,
            source: JobSource::from_json(v.get("source").context("grant missing source")?)?,
            grid: grid_from_json(v.get("grid").context("grant missing grid")?)?,
        })
    }
}

/// Lifecycle of one shard in the fixed partition.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Slot {
    Pending,
    Leased(u64),
    Done,
}

struct Lease {
    worker: String,
    shard0: usize,
    shard1: usize,
    deadline: Instant,
}

/// Worker name the registry uses for its own self-drain "leases" — never
/// granted over the wire, exempt from the deadline sweep by construction
/// (the deadline is set far in the future; the compute happens inline).
const LOCAL_WORKER: &str = "coordinator";

struct ShardJob {
    grid: ShardedGrid,
    source: JobSource,
    /// Per-shard block ranges `[b0, b1)` of the fixed partition.
    shards: Vec<(usize, usize)>,
    slots: Vec<Slot>,
    /// Replica assembly for shards mid-delivery: one slot per replica.
    assembling: BTreeMap<usize, Vec<Option<DenseTensor>>>,
    /// Complete shards waiting for their fold turn (arrived out of order).
    parked: BTreeMap<usize, Vec<DenseTensor>>,
    /// Folded prefix — shards `0..next_fold` — over the zero (or resumed)
    /// base, in strict shard order.
    folded: Vec<DenseTensor>,
    next_fold: usize,
    blocks_done: usize,
    leases: BTreeMap<u64, Lease>,
    ckpt_dir: PathBuf,
    fp: Fingerprint,
    /// Next `save_partial` generation (monotone across coordinator
    /// restarts: resumes start one past the loaded generation).
    generation: u64,
    /// `next_fold` at the last persisted checkpoint.
    last_saved: usize,
    /// Proxy-set key of this job in the artifact store; shard
    /// accumulators are published under `shard_accum(proxy, s, r)`.
    proxy_key: Option<StageKey>,
}

impl ShardJob {
    fn progress(&self) -> CompressionProgress {
        CompressionProgress {
            block: self.grid.block,
            shard_parts: self.grid.shard_parts,
            shards_total: self.shards.len(),
            shards_done: self.next_fold,
            blocks_done: self.blocks_done,
            blocks_total: self.grid.blocks_total,
            path: self.grid.path.clone(),
            generation: self.generation,
        }
    }

    fn done(&self) -> bool {
        self.next_fold == self.shards.len()
    }
}

struct RegState {
    jobs: BTreeMap<JobId, ShardJob>,
    workers: BTreeSet<String>,
    next_lease: u64,
    /// Last time any worker pulled a LEASE (or said hello; seeded with
    /// the registry's creation time) — the liveness signal the
    /// self-drain gate watches.
    last_pull: Instant,
    shutdown: bool,
}

/// The coordinator's lease ledger: shard slots, active leases, replica
/// assembly, the in-order fold, and the partial-checkpoint writer.
///
/// All methods that answer protocol verbs return the response [`Json`]
/// directly — the server's dispatch forwards them verbatim.
pub struct ShardRegistry {
    state: Mutex<RegState>,
    cv: Condvar,
    metrics: Arc<Metrics>,
    cfg: ShardConfig,
    store: Option<Arc<ArtifactStore>>,
}

impl ShardRegistry {
    pub fn new(cfg: ShardConfig, metrics: Arc<Metrics>) -> Self {
        Self {
            state: Mutex::new(RegState {
                jobs: BTreeMap::new(),
                workers: BTreeSet::new(),
                next_lease: 1,
                last_pull: Instant::now(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            metrics,
            cfg,
            store: None,
        }
    }

    /// Attaches the artifact store: verified shard accumulators are
    /// published as `ShardAccum` blobs, and jobs registered with a
    /// proxy key prefill pending shards from resident blobs.
    pub fn with_store(mut self, store: Arc<ArtifactStore>) -> Self {
        self.store = Some(store);
        self
    }

    fn timeout(&self) -> Duration {
        Duration::from_millis(self.cfg.lease_timeout_ms.max(1))
    }

    fn note_worker(&self, st: &mut RegState, worker: &str) {
        if st.workers.insert(worker.to_string()) {
            self.metrics.incr("workers_connected", 1);
        }
    }

    /// WORKER_HELLO: registers the worker name.
    pub fn hello(&self, worker: &str) -> Json {
        let mut st = self.state.lock().unwrap();
        self.note_worker(&mut st, worker);
        st.last_pull = Instant::now(); // a hello'd worker is about to pull
        protocol::ok(vec![("workers", Json::num(st.workers.len() as f64))])
    }

    /// Returns every expired lease's unfinished shards to the pending
    /// set.  Counts one `leases_relet` per abandoned lease.
    fn sweep_expired(&self, st: &mut RegState, now: Instant) {
        let mut relet = 0u64;
        for job in st.jobs.values_mut() {
            let expired: Vec<u64> = job
                .leases
                .iter()
                .filter(|(_, l)| l.deadline <= now)
                .map(|(id, _)| *id)
                .collect();
            for id in expired {
                let l = job.leases.remove(&id).unwrap();
                for s in l.shard0..l.shard1 {
                    if job.slots[s] == Slot::Leased(id) {
                        job.slots[s] = Slot::Pending;
                        job.assembling.remove(&s);
                    }
                }
                relet += 1;
            }
        }
        if relet > 0 {
            self.metrics.incr("leases_relet", relet);
            self.cv.notify_all();
        }
    }

    /// LEASE: grants the lowest contiguous run of pending shards (first
    /// job in submission order with work), or an idle/shutdown reply.
    pub fn lease(&self, worker: &str) -> Json {
        let now = Instant::now();
        let mut st = self.state.lock().unwrap();
        self.note_worker(&mut st, worker);
        st.last_pull = now;
        if st.shutdown {
            return protocol::ok(vec![("shutdown", Json::Bool(true))]);
        }
        self.sweep_expired(&mut st, now);
        let deadline = now + self.timeout();
        let lease_id = st.next_lease;
        let mut grant: Option<LeaseGrant> = None;
        for (id, job) in st.jobs.iter_mut() {
            let Some(s0) = job.slots.iter().position(|s| *s == Slot::Pending) else {
                continue;
            };
            let mut s1 = s0;
            while s1 < job.slots.len()
                && job.slots[s1] == Slot::Pending
                && s1 - s0 < self.cfg.lease_shards.max(1)
            {
                job.slots[s1] = Slot::Leased(lease_id);
                s1 += 1;
            }
            job.leases.insert(
                lease_id,
                Lease {
                    worker: worker.to_string(),
                    shard0: s0,
                    shard1: s1,
                    deadline,
                },
            );
            grant = Some(LeaseGrant {
                job: id.clone(),
                lease: lease_id,
                shard0: s0,
                shard1: s1,
                deadline_ms: self.cfg.lease_timeout_ms,
                source: job.source.clone(),
                grid: job.grid.clone(),
            });
            break;
        }
        match grant {
            Some(g) => {
                st.next_lease += 1;
                self.metrics.incr("leases_granted", 1);
                protocol::ok(vec![("grant", g.to_json())])
            }
            None => protocol::ok(vec![
                ("idle", Json::Bool(true)),
                ("backoff_ms", Json::num(self.cfg.backoff_ms as f64)),
            ]),
        }
    }

    /// RENEW: extends the lease deadline if it is still live.
    pub fn renew(&self, worker: &str, job: &str, lease: u64) -> Json {
        let now = Instant::now();
        let mut st = self.state.lock().unwrap();
        let timeout = self.timeout();
        let live = st
            .jobs
            .get_mut(job)
            .and_then(|j| j.leases.get_mut(&lease))
            .filter(|l| l.worker == worker && l.deadline > now);
        match live {
            Some(l) => {
                l.deadline = now + timeout;
                protocol::ok(vec![("extended", Json::Bool(true))])
            }
            None => protocol::ok(vec![("abandoned", Json::Bool(true))]),
        }
    }

    /// PARTIAL: verifies and ingests one replica of one shard
    /// accumulator.  A stale lease (expired, re-leased, job finished)
    /// gets `abandoned` — the worker drops the rest of its lease and
    /// pulls a new one; malformed payloads are protocol errors.
    pub fn partial(&self, msg: &PartialMsg) -> Json {
        let data = match decode_f32_b64(&msg.data) {
            Ok(d) => d,
            Err(e) => return protocol::err(format!("partial payload: {e}")),
        };
        if payload_digest(&data) != msg.digest {
            return protocol::err("partial digest mismatch");
        }
        let now = Instant::now();
        let timeout = self.timeout();
        let mut st = self.state.lock().unwrap();
        let Some(job) = st.jobs.get_mut(&msg.job) else {
            return protocol::ok(vec![("abandoned", Json::Bool(true))]);
        };
        let stale = match job.leases.get_mut(&msg.lease) {
            Some(l)
                if l.worker == msg.worker
                    && l.deadline > now
                    && (l.shard0..l.shard1).contains(&msg.shard)
                    && job.slots[msg.shard] == Slot::Leased(msg.lease) =>
            {
                l.deadline = now + timeout; // delivery is liveness
                false
            }
            _ => true,
        };
        if stale {
            return protocol::ok(vec![("abandoned", Json::Bool(true))]);
        }
        let [l, m, n] = job.grid.reduced;
        if msg.replica >= job.grid.replicas {
            return protocol::err(format!(
                "replica {} out of range (P={})",
                msg.replica, job.grid.replicas
            ));
        }
        if data.len() != l * m * n {
            return protocol::err(format!(
                "payload has {} floats, shard accumulator needs {}",
                data.len(),
                l * m * n
            ));
        }
        let replicas = job.grid.replicas;
        let slots = job
            .assembling
            .entry(msg.shard)
            .or_insert_with(|| vec![None; replicas]);
        slots[msg.replica] = Some(DenseTensor::from_vec([l, m, n], data));
        let mut publish: Vec<(StageKey, DenseTensor)> = Vec::new();
        let ckpt = if slots.iter().all(|s| s.is_some()) {
            let acc: Vec<DenseTensor> = job
                .assembling
                .remove(&msg.shard)
                .unwrap()
                .into_iter()
                .map(|s| s.unwrap())
                .collect();
            if self.store.is_some() {
                if let Some(proxy) = &job.proxy_key {
                    for (r, t) in acc.iter().enumerate() {
                        publish.push((StageKey::shard_accum(proxy, msg.shard, r), t.clone()));
                    }
                }
            }
            self.complete_shard(job, msg.shard, acc)
        } else {
            None
        };
        drop(st);
        self.publish_accumulators(&publish);
        self.write_checkpoint(&msg.job, ckpt);
        protocol::ok(vec![("accepted", Json::Bool(true))])
    }

    /// Best-effort store publish of digest-verified shard accumulators,
    /// performed outside the registry lock so lease traffic never
    /// queues behind blob I/O.
    fn publish_accumulators(&self, items: &[(StageKey, DenseTensor)]) {
        let Some(store) = &self.store else { return };
        for (key, t) in items {
            if let Err(e) = store.publish(key, std::slice::from_ref(t), &Json::Null) {
                log::warn!("shard accumulator publish {} failed: {e:#}", key.id());
            }
        }
    }

    /// Marks `shard` done, parks its accumulator, folds the contiguous
    /// prefix in shard order, and retires leases with no outstanding
    /// shards.  Returns a checkpoint snapshot when the fold advance hits
    /// the persistence cadence — the caller writes it *after* releasing
    /// the registry lock, so lease traffic never queues behind file I/O.
    fn complete_shard(
        &self,
        job: &mut ShardJob,
        shard: usize,
        acc: Vec<DenseTensor>,
    ) -> Option<CkptSnapshot> {
        job.slots[shard] = Slot::Done;
        job.parked.insert(shard, acc);
        let mut folded_now = 0u64;
        while let Some(next) = job.parked.remove(&job.next_fold) {
            fold_shard_proxies(&mut job.folded, next);
            let (b0, b1) = job.shards[job.next_fold];
            job.blocks_done += b1 - b0;
            job.next_fold += 1;
            folded_now += 1;
        }
        if folded_now > 0 {
            self.metrics.incr("partials_folded", folded_now);
        }
        let slots = &job.slots;
        job.leases
            .retain(|lid, l| (l.shard0..l.shard1).any(|s| slots[s] == Slot::Leased(*lid)));
        if job.done() {
            self.cv.notify_all();
        }
        let due = job.next_fold - job.last_saved >= self.cfg.checkpoint_every.max(1);
        if folded_now > 0 && (due || job.done()) {
            // Claim the save under the lock (bump the generation and the
            // saved watermark) so concurrent completions never race for
            // the same generation.
            job.last_saved = job.next_fold;
            job.generation += 1;
            return Some(CkptSnapshot {
                dir: job.ckpt_dir.clone(),
                fp: job.fp.clone(),
                progress: job.progress(),
                proxies: job.folded.clone(),
            });
        }
        None
    }

    /// Best-effort partial-checkpoint write (outside the registry lock).
    fn write_checkpoint(&self, id: &str, ckpt: Option<CkptSnapshot>) {
        if let Some(c) = ckpt {
            if let Err(e) = checkpoint::save_partial(&c.dir, &c.fp, &c.progress, &c.proxies) {
                eprintln!("exatensor serve: shard checkpoint for {id} failed: {e:#}");
            }
        }
    }

    /// Workers holding live leases on `job` — the `LIST` verb's
    /// per-job assignment column.
    pub fn workers_for(&self, job: &str) -> Vec<String> {
        let st = self.state.lock().unwrap();
        let Some(j) = st.jobs.get(job) else {
            return Vec::new();
        };
        let names: BTreeSet<String> = j.leases.values().map(|l| l.worker.clone()).collect();
        names.into_iter().collect()
    }

    /// Drain: LEASE now answers `shutdown` so workers exit, and the
    /// self-drain gate opens so running sharded jobs still finish with
    /// identical bits.
    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.cv.notify_all();
    }

    /// Runs `id` to completion under the lease protocol and returns the
    /// folded proxies — bitwise identical to the single-process engine's.
    ///
    /// Called from the scheduler's job runner thread; blocks until every
    /// shard is folded.  Resumes the folded prefix from a prior partial
    /// checkpoint in `ckpt_dir` if one matches.  While no live worker is
    /// pulling leases, the runner drains pending shards itself, one at a
    /// time, with [`compress_shard_batched`] — the no-worker daemon and a
    /// fully worker-served run produce the same bits.
    ///
    /// `proxy_key` is the job's proxy-set [`StageKey`]; with a store
    /// attached it namespaces the published shard accumulators and
    /// drives the prefill of pending shards from resident blobs.
    pub fn run_sharded(
        &self,
        id: &JobId,
        source: JobSource,
        grid: ShardedGrid,
        ckpt_dir: &Path,
        fp: Fingerprint,
        proxy_key: Option<StageKey>,
    ) -> Result<Vec<DenseTensor>> {
        let shards = ThreadPool::partition(grid.blocks_total, grid.shard_parts);
        let [l, m, n] = grid.reduced;
        // Zero fold base — the same `+0.0` start as the engine's
        // zero-initialized proxies.
        let mut folded: Vec<DenseTensor> =
            (0..grid.replicas).map(|_| DenseTensor::zeros(l, m, n)).collect();
        let mut next_fold = 0usize;
        let mut blocks_done = 0usize;
        let mut generation = 0u64;
        let template = CompressionProgress {
            block: grid.block,
            shard_parts: grid.shard_parts,
            shards_total: shards.len(),
            shards_done: 0,
            blocks_done: 0,
            blocks_total: grid.blocks_total,
            path: grid.path.clone(),
            generation: 0,
        };
        let loaded = checkpoint::load_partial(ckpt_dir, &fp, &template)
            .context("loading sharded partial checkpoint")?;
        if loaded.fallbacks > 0 {
            self.metrics.incr("checkpoint_fallbacks", loaded.fallbacks);
        }
        if let Some((progress, proxies)) = loaded.state {
            next_fold = progress.shards_done;
            blocks_done = progress.blocks_done;
            generation = progress.generation + 1;
            folded = proxies;
        }
        // Prefill: a shard whose full replica set is already resident in
        // the artifact store (published by an earlier run of this grid)
        // is completed from the store instead of re-leased or drained.
        // `contains` first so a partial replica set never counts hits.
        let mut prefilled: BTreeMap<usize, Vec<DenseTensor>> = BTreeMap::new();
        if let (Some(store), Some(proxy)) = (&self.store, &proxy_key) {
            for shard in next_fold..shards.len() {
                let keys: Vec<StageKey> = (0..grid.replicas)
                    .map(|r| StageKey::shard_accum(proxy, shard, r))
                    .collect();
                if !keys.iter().all(|k| store.contains(k)) {
                    continue;
                }
                let mut acc: Vec<DenseTensor> = Vec::with_capacity(grid.replicas);
                for key in &keys {
                    match store.get(key) {
                        Some(ts) if ts.len() == 1 && ts[0].dims() == grid.reduced => {
                            acc.extend(ts);
                        }
                        _ => break, // evicted or corrupt under us: recompute
                    }
                }
                if acc.len() == grid.replicas {
                    prefilled.insert(shard, acc);
                }
            }
        }
        {
            let mut st = self.state.lock().unwrap();
            let mut slots = vec![Slot::Pending; shards.len()];
            for s in slots.iter_mut().take(next_fold) {
                *s = Slot::Done;
            }
            st.jobs.insert(
                id.clone(),
                ShardJob {
                    grid: grid.clone(),
                    source: source.clone(),
                    shards,
                    slots,
                    assembling: BTreeMap::new(),
                    parked: BTreeMap::new(),
                    folded,
                    next_fold,
                    blocks_done,
                    leases: BTreeMap::new(),
                    ckpt_dir: ckpt_dir.to_path_buf(),
                    fp,
                    generation,
                    last_saved: next_fold,
                    proxy_key: proxy_key.clone(),
                },
            );
            if !prefilled.is_empty() {
                let job = st.jobs.get_mut(id).unwrap();
                let mut ckpts = Vec::new();
                for (shard, acc) in prefilled {
                    if let Some(c) = self.complete_shard(job, shard, acc) {
                        ckpts.push(c);
                    }
                }
                drop(st);
                for c in ckpts {
                    self.write_checkpoint(id, Some(c));
                }
                st = self.state.lock().unwrap();
            }
            self.cv.notify_all();
        }
        // Lazy local engine for the self-drain path.
        let mut local: Option<(Box<dyn TensorSource>, MapSource)> = None;
        let tick = Duration::from_millis((self.cfg.lease_timeout_ms / 4).clamp(10, 250));
        let mut st = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            self.sweep_expired(&mut st, now);
            if st.jobs.get(id).map(|j| j.done()) != Some(false) {
                break;
            }
            // Destructure once: field-precise borrows don't reach
            // through the guard's DerefMut.
            let inner = &mut *st;
            let workers_quiet = inner.shutdown
                || inner.workers.is_empty()
                || now.duration_since(inner.last_pull) > self.timeout();
            let job = inner.jobs.get_mut(id).unwrap();
            let drain = if workers_quiet && job.leases.is_empty() {
                job.slots.iter().position(|s| *s == Slot::Pending)
            } else {
                None
            };
            let Some(shard) = drain else {
                st = self.cv.wait_timeout(st, tick).unwrap().0;
                continue;
            };
            // Reserve the shard with a far-future local lease so the
            // sweep and concurrent grants leave it alone, then compute
            // it inline with the lock released.
            let lease_id = inner.next_lease;
            inner.next_lease += 1;
            job.slots[shard] = Slot::Leased(lease_id);
            job.leases.insert(
                lease_id,
                Lease {
                    worker: LOCAL_WORKER.to_string(),
                    shard0: shard,
                    shard1: shard + 1,
                    deadline: now + Duration::from_secs(24 * 3600),
                },
            );
            let (b0, b1) = job.shards[shard];
            drop(st);
            if local.is_none() {
                let src = source.open().context("opening source for self-drain")?;
                let maps = MapSource::generate(
                    grid.dims,
                    grid.reduced,
                    grid.replicas,
                    grid.anchor,
                    grid.seed,
                    grid.map_tier,
                );
                local = Some((src, maps));
            }
            let (src, maps) = local.as_ref().unwrap();
            let acc = compress_shard_batched(src.as_ref(), maps, grid.block, b0, b1);
            if self.store.is_some() {
                if let Some(proxy) = &proxy_key {
                    let items: Vec<(StageKey, DenseTensor)> = acc
                        .iter()
                        .enumerate()
                        .map(|(r, t)| (StageKey::shard_accum(proxy, shard, r), t.clone()))
                        .collect();
                    self.publish_accumulators(&items);
                }
            }
            st = self.state.lock().unwrap();
            let ckpt = match st.jobs.get_mut(id) {
                Some(job) => {
                    job.leases.remove(&lease_id);
                    self.complete_shard(job, shard, acc)
                }
                None => None,
            };
            drop(st);
            self.write_checkpoint(id, ckpt);
            st = self.state.lock().unwrap();
        }
        let job = st.jobs.remove(id).context("sharded job vanished mid-run")?;
        Ok(job.folded)
    }
}

/// A claimed partial-checkpoint write, performed outside the lock.
struct CkptSnapshot {
    dir: PathBuf,
    fp: Fingerprint,
    progress: CompressionProgress,
    proxies: Vec<DenseTensor>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::checkpoint::partial_exists;

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_shard_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    fn test_grid() -> (JobSource, ShardedGrid) {
        let source = JobSource::Synthetic {
            size: 12,
            rank: 2,
            noise: 0.0,
            seed: 77,
        };
        let dims = [12, 12, 12];
        let block = [5, 5, 5];
        let blocks_total = crate::tensor::BlockSpec3::new(dims, block).num_blocks();
        let grid = ShardedGrid {
            dims,
            reduced: [4, 4, 4],
            replicas: 3,
            anchor: 2,
            seed: 9,
            map_tier: MapTier::Materialized,
            block,
            blocks_total,
            shard_parts: 8,
            path: "batched".to_string(),
        };
        (source, grid)
    }

    /// The single-process reduction: zero base, shard accumulators
    /// folded in strict shard order.
    fn solo_fold(source: &JobSource, grid: &ShardedGrid) -> Vec<DenseTensor> {
        let src = source.open().unwrap();
        let maps = MapSource::generate(
            grid.dims,
            grid.reduced,
            grid.replicas,
            grid.anchor,
            grid.seed,
            grid.map_tier,
        );
        let [l, m, n] = grid.reduced;
        let mut folded: Vec<DenseTensor> =
            (0..grid.replicas).map(|_| DenseTensor::zeros(l, m, n)).collect();
        for &(b0, b1) in &ThreadPool::partition(grid.blocks_total, grid.shard_parts) {
            let acc = compress_shard_batched(src.as_ref(), &maps, grid.block, b0, b1);
            fold_shard_proxies(&mut folded, acc);
        }
        folded
    }

    /// Plays one worker: computes the granted range and delivers every
    /// replica of every shard as PARTIAL messages.
    fn serve_grant(reg: &ShardRegistry, worker: &str, grant: &LeaseGrant) {
        let src = grant.source.open().unwrap();
        let g = &grant.grid;
        let maps = MapSource::generate(g.dims, g.reduced, g.replicas, g.anchor, g.seed, g.map_tier);
        let shards = ThreadPool::partition(g.blocks_total, g.shard_parts);
        for s in grant.shard0..grant.shard1 {
            let (b0, b1) = shards[s];
            let acc = compress_shard_batched(src.as_ref(), &maps, g.block, b0, b1);
            for (r, t) in acc.iter().enumerate() {
                let msg = PartialMsg {
                    worker: worker.to_string(),
                    job: grant.job.clone(),
                    lease: grant.lease,
                    shard: s,
                    replica: r,
                    data: encode_f32_b64(t.data()),
                    digest: payload_digest(t.data()),
                };
                let resp = reg.partial(&msg);
                if resp.get("abandoned").is_some() {
                    return; // lease expired under us; pull a fresh one
                }
                assert_eq!(
                    resp.get("accepted").and_then(|x| x.as_bool()),
                    Some(true),
                    "partial rejected: {resp:?}"
                );
            }
        }
    }

    /// Pulls and serves leases until the registry reports idle/shutdown.
    fn serve_until_idle(reg: &ShardRegistry, worker: &str) {
        loop {
            let resp = reg.lease(worker);
            if resp.get("shutdown").is_some() || resp.get("idle").is_some() {
                return;
            }
            let grant = LeaseGrant::from_json(resp.get("grant").unwrap()).unwrap();
            serve_grant(reg, worker, &grant);
        }
    }

    #[test]
    fn b64_payload_round_trips_bitwise() {
        let data = vec![0.0f32, -0.0, 1.5, -2.25e-3, f32::MIN_POSITIVE, 1e30];
        let wire = encode_f32_b64(&data);
        let back = decode_f32_b64(&wire).unwrap();
        assert_eq!(data.len(), back.len());
        for (a, b) in data.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(payload_digest(&data), payload_digest(&back));
        // 4 wire bytes per 3 payload bytes (plus padding), down from
        // hex's 8 per 4.
        assert_eq!(wire.len(), (data.len() * 4).div_ceil(3) * 4);
        assert!(decode_f32_b64("AAA").is_err(), "truncated payload must fail");
        assert!(decode_f32_b64("!!!!").is_err(), "non-alphabet must fail");
        assert!(
            decode_f32_b64("AAAAAAA=").is_err(),
            "whole bytes but a fractional f32 must fail"
        );
    }

    #[test]
    fn lease_grant_round_trips_json() {
        let (source, grid) = test_grid();
        let grant = LeaseGrant {
            job: "job-000007".to_string(),
            lease: 42,
            shard0: 3,
            shard1: 6,
            deadline_ms: 5_000,
            source,
            grid,
        };
        let wire = grant.to_json().to_string_compact();
        let back = LeaseGrant::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.job, grant.job);
        assert_eq!(back.lease, grant.lease);
        assert_eq!((back.shard0, back.shard1), (3, 6));
        assert_eq!(back.source, grant.source);
        assert_eq!(back.grid.dims, grant.grid.dims);
        assert_eq!(back.grid.map_tier, grant.grid.map_tier);
        assert_eq!(back.grid.blocks_total, grant.grid.blocks_total);
        assert_eq!(back.grid.path, "batched");
    }

    #[test]
    fn worker_served_run_folds_bitwise_identical() {
        let _no_faults = crate::util::fault::exclude_faults();
        let (source, grid) = test_grid();
        let expected = solo_fold(&source, &grid);
        let dir = tmpdir("worker_served");
        let metrics = Arc::new(Metrics::new());
        let reg = Arc::new(ShardRegistry::new(
            ShardConfig {
                checkpoint_every: 2,
                ..ShardConfig::default()
            },
            metrics.clone(),
        ));
        let fp = Fingerprint {
            dims: grid.dims,
            reduced: grid.reduced,
            rank: 2,
            replicas: grid.replicas,
            anchor_rows: grid.anchor,
            seed: grid.seed,
            mixed_precision: false,
        };
        reg.hello("w1");
        let runner = {
            let reg = reg.clone();
            let (source, grid, dir, fp) = (source.clone(), grid.clone(), dir.clone(), fp);
            std::thread::spawn(move || {
                reg.run_sharded(&"job-000001".to_string(), source, grid, &dir, fp, None)
            })
        };
        // Poll until the job is registered, then serve every lease.
        loop {
            let resp = reg.lease("w1");
            if let Some(g) = resp.get("grant") {
                let grant = LeaseGrant::from_json(g).unwrap();
                serve_grant(&reg, "w1", &grant);
                serve_until_idle(&reg, "w1");
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let folded = runner.join().unwrap().unwrap();
        assert_eq!(folded, expected, "sharded fold must be bitwise identical");
        assert!(metrics.counter("leases_granted") >= 1);
        assert_eq!(
            metrics.counter("partials_folded"),
            ThreadPool::partition(grid.blocks_total, grid.shard_parts).len() as u64
        );
        assert_eq!(metrics.counter("workers_connected"), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn expired_lease_is_relet_and_still_bitwise() {
        let _no_faults = crate::util::fault::exclude_faults();
        let (source, grid) = test_grid();
        let expected = solo_fold(&source, &grid);
        let dir = tmpdir("relet");
        let metrics = Arc::new(Metrics::new());
        let reg = Arc::new(ShardRegistry::new(
            ShardConfig {
                lease_timeout_ms: 60,
                lease_shards: 2,
                backoff_ms: 5,
                checkpoint_every: 100,
            },
            metrics.clone(),
        ));
        let fp = checkpoint_fingerprint(&grid);
        reg.hello("flaky");
        let runner = {
            let reg = reg.clone();
            let (source, grid, dir, fp) = (source.clone(), grid.clone(), dir.clone(), fp);
            std::thread::spawn(move || {
                reg.run_sharded(&"job-000002".to_string(), source, grid, &dir, fp, None)
            })
        };
        // Take the first lease and abandon it (simulated worker death):
        // never deliver, let the deadline pass.
        loop {
            let resp = reg.lease("flaky");
            if resp.get("grant").is_some() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        std::thread::sleep(Duration::from_millis(120));
        // An honest worker picks up the re-leased range and finishes.
        loop {
            let resp = reg.lease("honest");
            if let Some(g) = resp.get("grant") {
                let grant = LeaseGrant::from_json(g).unwrap();
                serve_grant(&reg, "honest", &grant);
            } else {
                if runner.is_finished() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        let folded = runner.join().unwrap().unwrap();
        assert_eq!(folded, expected, "relet run must stay bitwise identical");
        assert!(
            metrics.counter("leases_relet") >= 1,
            "abandoned lease must be re-leased"
        );
        assert_eq!(metrics.counter("workers_connected"), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn no_worker_run_self_drains_bitwise_and_checkpoints() {
        let _no_faults = crate::util::fault::exclude_faults();
        let (source, grid) = test_grid();
        let expected = solo_fold(&source, &grid);
        let dir = tmpdir("selfdrain");
        let metrics = Arc::new(Metrics::new());
        let reg = ShardRegistry::new(
            ShardConfig {
                lease_timeout_ms: 20,
                checkpoint_every: 2,
                ..ShardConfig::default()
            },
            metrics.clone(),
        );
        let fp = checkpoint_fingerprint(&grid);
        let folded = reg
            .run_sharded(&"job-000003".to_string(), source, grid, &dir, fp, None)
            .unwrap();
        assert_eq!(folded, expected, "self-drain must be bitwise identical");
        assert_eq!(metrics.counter("leases_granted"), 0, "no worker ever leased");
        assert!(
            partial_exists(&dir),
            "self-drain must leave a resumable partial checkpoint"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restart_resumes_fold_from_partial_checkpoint() {
        let _no_faults = crate::util::fault::exclude_faults();
        let (source, grid) = test_grid();
        let expected = solo_fold(&source, &grid);
        let dir = tmpdir("resume");
        let fp = checkpoint_fingerprint(&grid);
        // First "coordinator": fold a three-shard prefix by hand and
        // persist it the way the registry would.
        {
            let src = source.open().unwrap();
            let maps = MapSource::generate(
                grid.dims,
                grid.reduced,
                grid.replicas,
                grid.anchor,
                grid.seed,
                grid.map_tier,
            );
            let [l, m, n] = grid.reduced;
            let mut folded: Vec<DenseTensor> =
                (0..grid.replicas).map(|_| DenseTensor::zeros(l, m, n)).collect();
            let shards = ThreadPool::partition(grid.blocks_total, grid.shard_parts);
            let mut blocks_done = 0;
            for &(b0, b1) in shards.iter().take(3) {
                let acc = compress_shard_batched(src.as_ref(), &maps, grid.block, b0, b1);
                fold_shard_proxies(&mut folded, acc);
                blocks_done += b1 - b0;
            }
            let progress = CompressionProgress {
                block: grid.block,
                shard_parts: grid.shard_parts,
                shards_total: shards.len(),
                shards_done: 3,
                blocks_done,
                blocks_total: grid.blocks_total,
                path: grid.path.clone(),
                generation: 1,
            };
            checkpoint::save_partial(&dir, &fp, &progress, &folded).unwrap();
        }
        // Restarted coordinator: resumes the folded prefix and drains
        // the remaining shards itself.
        let metrics = Arc::new(Metrics::new());
        let reg = ShardRegistry::new(
            ShardConfig {
                lease_timeout_ms: 20,
                ..ShardConfig::default()
            },
            metrics.clone(),
        );
        let folded = reg
            .run_sharded(&"job-000004".to_string(), source, grid, &dir, fp, None)
            .unwrap();
        assert_eq!(folded, expected, "resumed fold must be bitwise identical");
        assert_eq!(
            metrics.counter("partials_folded"),
            5,
            "only the five unfolded shards are recomputed"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resubmitted_sharded_job_refetches_accumulators_from_store() {
        let _no_faults = crate::util::fault::exclude_faults();
        let (source, grid) = test_grid();
        let expected = solo_fold(&source, &grid);
        let root = tmpdir("store_refetch");
        let store_dir = root.join("store");
        let total = ThreadPool::partition(grid.blocks_total, grid.shard_parts).len();
        let proxy = StageKey::proxies(
            0xC0FFEE,
            grid.dims,
            grid.reduced,
            grid.replicas,
            grid.anchor,
            grid.seed,
            false,
            grid.block,
            &grid.path,
        );
        let quick = ShardConfig {
            lease_timeout_ms: 20,
            ..ShardConfig::default()
        };
        // First daemon: self-drains and publishes every accumulator.
        {
            let metrics = Arc::new(Metrics::new());
            let store =
                Arc::new(ArtifactStore::open(&store_dir, 64 << 20, metrics.clone()).unwrap());
            let reg = ShardRegistry::new(quick.clone(), metrics.clone()).with_store(store);
            let folded = reg
                .run_sharded(
                    &"job-000005".to_string(),
                    source.clone(),
                    grid.clone(),
                    &root.join("ckpt_a"),
                    checkpoint_fingerprint(&grid),
                    Some(proxy.clone()),
                )
                .unwrap();
            assert_eq!(folded, expected);
            assert_eq!(
                metrics.counter("store_publishes"),
                (total * grid.replicas) as u64
            );
        }
        // Second daemon — fresh registry, fresh checkpoint dir, same
        // store: every shard prefills from resident blobs, so the fold
        // is bitwise identical without recomputing or leasing anything.
        let metrics = Arc::new(Metrics::new());
        let store = Arc::new(ArtifactStore::open(&store_dir, 64 << 20, metrics.clone()).unwrap());
        let reg = ShardRegistry::new(quick, metrics.clone()).with_store(store);
        let folded = reg
            .run_sharded(
                &"job-000006".to_string(),
                source,
                grid.clone(),
                &root.join("ckpt_b"),
                checkpoint_fingerprint(&grid),
                Some(proxy),
            )
            .unwrap();
        assert_eq!(folded, expected, "prefilled fold must be bitwise identical");
        assert_eq!(
            metrics.counter("store_hits_shards"),
            (total * grid.replicas) as u64
        );
        assert_eq!(
            metrics.counter("store_publishes"),
            0,
            "prefilled shards are not republished"
        );
        assert_eq!(metrics.counter("leases_granted"), 0);
        std::fs::remove_dir_all(&root).ok();
    }

    fn checkpoint_fingerprint(grid: &ShardedGrid) -> Fingerprint {
        Fingerprint {
            dims: grid.dims,
            reduced: grid.reduced,
            rank: 2,
            replicas: grid.replicas,
            anchor_rows: grid.anchor,
            seed: grid.seed,
            mixed_precision: false,
        }
    }
}
