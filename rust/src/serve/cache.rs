//! Result cache: repeated decompositions of the same input are served
//! from the artifact store instead of re-running the pipeline.
//!
//! The key is a **tensor fingerprint**: an FNV-1a digest over the input's
//! identity (for `EXT1` files, the header bytes + file length + mtime; for
//! synthetic sources, the generator parameters), the tensor dims, the CP
//! rank, the seed, and a hash of the result-relevant pipeline config.
//! Execution-only knobs (`threads`, `io_threads`, `prefetch_depth`,
//! `checkpoint_dir`) are excluded — the streaming engine is bitwise
//! deterministic across them, so runs that differ only there produce
//! identical factors and must share a cache line.
//!
//! Since the artifact store landed, [`ResultCache`] is a thin view over
//! its `factors` class: each entry is one store blob (three factor
//! tensors + a summary header), so factor sets share the store's global
//! byte budget, LRU policy, pinning, digest verification, and crash
//! persistence — a restarted daemon reopens its store and every factor
//! set cached before the restart still hits.

use super::job::JobSpec;
use crate::cp::CpModel;
use crate::linalg::Matrix;
use crate::store::{ArtifactClass, ArtifactStore, StageKey};
use crate::tensor::DenseTensor;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// FNV-1a lives in `util/hash.rs` since the checkpoint layer adopted it
/// for payload digests; re-exported here because the cache is where it
/// grew up and the serve code keys off this path.
pub use crate::util::hash::{fnv1a64, Fnv};

/// Digest of a CP model's factor bytes — the protocol's cheap bitwise-
/// identity witness (resume-after-kill must reproduce it exactly).
pub fn model_digest(model: &CpModel) -> u64 {
    let mut h = Fnv::new();
    for m in [&model.a, &model.b, &model.c] {
        h.write_u64(m.rows() as u64);
        h.write_u64(m.cols() as u64);
        for &x in m.data() {
            h.write(&x.to_le_bytes());
        }
    }
    h.finish()
}

/// Digest of an `EXT1` file's identity: header bytes (magic + ndim + dims),
/// total file length, and the modification time.  Never reads the payload,
/// so fingerprinting a multi-TB tensor costs one small read — the mtime is
/// what catches a payload rewritten in place with the same shape.
pub fn file_fingerprint(path: &str) -> Result<u64> {
    use std::io::Read;
    let mut f = std::fs::File::open(path).with_context(|| format!("fingerprinting {path}"))?;
    let meta = f.metadata().context("stat")?;
    let len = meta.len();
    // Magic (4) + ndim (4) + up to 8 dims (64): the EXT1 header never
    // exceeds 72 bytes.
    let mut header = [0u8; 72];
    let mut read = 0;
    while read < header.len() {
        match f.read(&mut header[read..]) {
            Ok(0) => break,
            Ok(n) => read += n,
            Err(e) => return Err(e).context("reading header"),
        }
    }
    let mut h = Fnv::new();
    h.write(&header[..read]);
    h.write_u64(len);
    if let Ok(mtime) = meta.modified() {
        if let Ok(d) = mtime.duration_since(std::time::UNIX_EPOCH) {
            h.write_u64(d.as_secs());
            h.write_u64(d.subsec_nanos() as u64);
        }
    }
    Ok(h.finish())
}

/// Fingerprint of a job's *source* alone, no config: the input-digest half
/// of the proxy stage key ([`crate::coordinator::proxy_key_for`]).  Two
/// jobs over the same bytes share this even when their ranks differ —
/// which is exactly what lets a rank sweep share one Stage-1 artifact.
pub fn source_fingerprint(source: &super::job::JobSource) -> Result<u64> {
    let mut h = Fnv::new();
    match source {
        super::job::JobSource::Synthetic { size, rank, noise, seed } => {
            h.write(b"synthetic");
            h.write_u64(*size as u64);
            h.write_u64(*rank as u64);
            h.write_u64(noise.to_bits());
            h.write_u64(*seed);
        }
        super::job::JobSource::File { path } => {
            h.write(b"file");
            h.write_u64(file_fingerprint(path)?);
        }
    }
    Ok(h.finish())
}

/// The full result-cache key for a job spec.  Errors if a file input
/// cannot be read (the submitter gets the failure immediately).
pub fn cache_key(spec: &JobSpec) -> Result<String> {
    let mut h = Fnv::new();
    h.write_u64(source_fingerprint(&spec.source)?);
    let dims = spec.source.dims()?;
    for d in dims {
        h.write_u64(d as u64);
    }
    h.write_u64(spec.config.rank as u64);
    h.write_u64(spec.config.seed);
    // Config hash over the canonical JSON minus execution-only knobs.
    let mut cfg = spec.config.to_json();
    if let Json::Obj(m) = &mut cfg {
        // `map_tier` is execution-only too: both tiers produce bitwise
        // identical factors by construction (tests/map_tiers.rs), so a
        // procedural resubmission of a materialized job is a cache hit.
        // `recovery_solver`/`recovery_panel_cols` follow the same policy:
        // every solver converges to the same minimizer within the
        // pipeline's own tolerance (tests in coordinator::recovery), so
        // how the stacked solve executes must not split cache lines.
        for k in [
            "threads",
            "io_threads",
            "prefetch_depth",
            "checkpoint_dir",
            "map_tier",
            "recovery_solver",
            "recovery_panel_cols",
        ] {
            m.remove(k);
        }
    }
    h.write(cfg.to_string_compact().as_bytes());
    Ok(format!("{:016x}", h.finish()))
}

/// A cached decomposition: the model plus the summary the protocol returns.
#[derive(Clone)]
pub struct CachedResult {
    pub model: Arc<CpModel>,
    pub rel_error: f64,
    pub sampled_mse: f64,
    pub dropped_replicas: usize,
    pub model_digest: u64,
}

/// Monotone counters a scheduler mirrors into its metrics registry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub used_bytes: usize,
    pub entries: usize,
}

/// Thread-safe result cache: a view over the artifact store's `factors`
/// class.  `enabled = false` (`--cache-mb 0`) turns the view off without
/// touching the store — proxy/shard reuse keeps working underneath.
pub struct ResultCache {
    store: Arc<ArtifactStore>,
    enabled: bool,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ResultCache {
    pub fn over(store: Arc<ArtifactStore>, enabled: bool) -> Self {
        Self {
            store,
            enabled,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn get(&self, key: &str) -> Option<CachedResult> {
        if !self.enabled {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let fetched = self
            .store
            .get_with_meta(&StageKey::factors(key))
            .and_then(|(tensors, meta)| decode_factors(&tensors, &meta));
        match fetched {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: String, result: CachedResult) {
        if !self.enabled {
            return;
        }
        let m = &result.model;
        let tensors: Vec<DenseTensor> = [&m.a, &m.b, &m.c]
            .into_iter()
            .map(|f| DenseTensor::from_vec([f.rows(), f.cols(), 1], f.data().to_vec()))
            .collect();
        let meta = Json::obj(vec![
            ("rel_error", Json::num(result.rel_error)),
            ("sampled_mse", Json::num(result.sampled_mse)),
            ("dropped_replicas", Json::num(result.dropped_replicas as f64)),
            // A string: u64 digests don't survive the f64 round-trip.
            ("model_digest", Json::str(format!("{:016x}", result.model_digest))),
        ]);
        if let Err(e) = self.store.publish(&StageKey::factors(&key), &tensors, &meta) {
            log::warn!("cache: publishing factors {key} failed: {e:#}");
        }
    }

    pub fn stats(&self) -> CacheStats {
        let s = self.store.class_stats(ArtifactClass::Factors);
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: s.evictions,
            used_bytes: s.used_bytes,
            entries: s.entries,
        }
    }
}

/// Rebuilds a [`CachedResult`] from its store blob.  `None` on structural
/// mismatch (the payload digest already passed, so this only guards
/// against a blob written by some other code path).
fn decode_factors(tensors: &[DenseTensor], meta: &Json) -> Option<CachedResult> {
    let [a, b, c] = tensors else { return None };
    let to_matrix = |t: &DenseTensor| {
        let [rows, cols, one] = t.dims();
        (one == 1).then(|| Matrix::from_vec(rows, cols, t.data().to_vec()))
    };
    let model = CpModel::new(to_matrix(a)?, to_matrix(b)?, to_matrix(c)?);
    let digest = meta
        .get("model_digest")
        .and_then(|x| x.as_str())
        .and_then(|s| u64::from_str_radix(s, 16).ok())?;
    Some(CachedResult {
        model: Arc::new(model),
        rel_error: meta.get("rel_error").and_then(|x| x.as_f64())?,
        sampled_mse: meta.get("sampled_mse").and_then(|x| x.as_f64())?,
        dropped_replicas: meta.get("dropped_replicas").and_then(|x| x.as_usize())?,
        model_digest: digest,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Metrics, PipelineConfig};
    use crate::serve::job::JobSource;
    use std::path::PathBuf;

    fn model(rows: usize, rank: usize, fill: f32) -> CachedResult {
        let m = |r| Matrix::from_vec(r, rank, vec![fill; r * rank]);
        let model = CpModel::new(m(rows), m(rows), m(rows));
        let digest = model_digest(&model);
        CachedResult {
            model: Arc::new(model),
            rel_error: 0.125,
            sampled_mse: 0.25,
            dropped_replicas: 1,
            model_digest: digest,
        }
    }

    fn tmproot(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_cache_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    fn cache_at(root: &PathBuf, budget: usize) -> ResultCache {
        let store =
            Arc::new(ArtifactStore::open(root.clone(), budget, Arc::new(Metrics::new())).unwrap());
        ResultCache::over(store, budget > 0)
    }

    fn spec(seed: u64, threads: usize) -> JobSpec {
        JobSpec {
            source: JobSource::Synthetic { size: 16, rank: 2, noise: 0.0, seed: 9 },
            config: PipelineConfig::builder()
                .reduced_dims(8, 8, 8)
                .rank(2)
                .anchor_rows(4)
                .threads(threads)
                .seed(seed)
                .build()
                .unwrap(),
            priority: 0,
            tenant: String::new(),
            sharded: false,
            no_cache: false,
        }
    }

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
        let mut h = Fnv::new();
        h.write(b"ab");
        let mut h2 = Fnv::new();
        h2.write(b"a");
        h2.write(b"b");
        assert_eq!(h.finish(), h2.finish(), "incremental == one-shot");
    }

    #[test]
    fn cache_key_ignores_execution_knobs_but_not_seed() {
        let k1 = cache_key(&spec(1, 2)).unwrap();
        let k2 = cache_key(&spec(1, 8)).unwrap();
        assert_eq!(k1, k2, "thread count must not split cache lines");
        let k3 = cache_key(&spec(2, 2)).unwrap();
        assert_ne!(k1, k3, "seed changes the result, must change the key");
        // Map tier is bitwise-invisible to results: same cache line.
        let mut tiered = spec(1, 2);
        tiered.config.map_tier = crate::coordinator::config::MapTierChoice::Procedural;
        assert_eq!(k1, cache_key(&tiered).unwrap(), "map tier must not split cache lines");
        // Recovery solver + panel width are execution knobs too.
        let mut solved = spec(1, 2);
        solved.config.recovery_solver = crate::coordinator::config::RecoverySolver::Iterative;
        solved.config.recovery_panel_cols = 64;
        assert_eq!(
            k1,
            cache_key(&solved).unwrap(),
            "recovery solver/panel must not split cache lines"
        );
        // `no_cache` is a policy flag, not part of the result's identity.
        let mut bypass = spec(1, 2);
        bypass.no_cache = true;
        assert_eq!(k1, cache_key(&bypass).unwrap(), "no_cache must not split cache lines");
    }

    #[test]
    fn model_digest_detects_single_bit_changes() {
        let a = model(8, 2, 1.0);
        let mut m = (*a.model).clone();
        *m.a.data_mut().first_mut().unwrap() += 1e-7;
        assert_ne!(model_digest(&m), a.model_digest);
    }

    #[test]
    fn round_trips_model_and_summary_through_the_store() {
        let root = tmproot("roundtrip");
        let cache = cache_at(&root, 1 << 20);
        let r = model(8, 2, 1.5);
        assert!(cache.get("k").is_none());
        cache.insert("k".into(), r.clone());
        let back = cache.get("k").expect("cached entry hits");
        assert_eq!(back.model.a, r.model.a, "factor A must round-trip bitwise");
        assert_eq!(back.model.b, r.model.b);
        assert_eq!(back.model.c, r.model.c);
        assert_eq!(back.model_digest, r.model_digest);
        assert_eq!(back.rel_error, r.rel_error);
        assert_eq!(back.sampled_mse, r.sampled_mse);
        assert_eq!(back.dropped_replicas, r.dropped_replicas);
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let root = tmproot("lru");
        // Measure one entry's blob cost, then budget for two.
        let probe = cache_at(&root, 1 << 20);
        probe.insert("probe".into(), model(8, 2, 0.0));
        let one = probe.stats().used_bytes;
        assert!(one > 0);
        drop(probe);
        std::fs::remove_dir_all(&root).ok();

        let cache = cache_at(&root, one * 2 + one / 2);
        cache.insert("a".into(), model(8, 2, 1.0));
        cache.insert("b".into(), model(8, 2, 2.0));
        assert_eq!(cache.stats().entries, 2);
        // Touch "a" so "b" is LRU, then insert "c": "b" must be evicted.
        assert!(cache.get("a").is_some());
        cache.insert("c".into(), model(8, 2, 3.0));
        let st = cache.stats();
        assert_eq!(st.entries, 2);
        assert_eq!(st.evictions, 1);
        assert!(st.used_bytes <= one * 2 + one / 2);
        assert!(cache.get("b").is_none(), "LRU entry must be gone");
        assert!(cache.get("a").is_some() && cache.get("c").is_some());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn oversized_entry_and_disabled_cache_are_not_cached() {
        let root = tmproot("oversized");
        let cache = cache_at(&root, 100);
        // Enabled, but the blob exceeds the whole store budget.
        cache.insert("big".into(), model(64, 4, 1.0));
        assert_eq!(cache.stats().entries, 0);
        drop(cache);
        std::fs::remove_dir_all(&root).ok();

        let off = cache_at(&root, 0);
        off.insert("x".into(), model(8, 2, 1.0));
        assert!(off.get("x").is_none());
        assert_eq!(off.stats().misses, 1);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn survives_a_cache_restart() {
        let root = tmproot("restart");
        let r = model(8, 2, 4.0);
        {
            let cache = cache_at(&root, 1 << 20);
            cache.insert("k".into(), r.clone());
        }
        // A fresh view over the same store root (daemon restart) still
        // hits: factor sets persist as store blobs.
        let cache = cache_at(&root, 1 << 20);
        let back = cache.get("k").expect("restarted cache must hit");
        assert_eq!(back.model_digest, r.model_digest);
        assert_eq!(back.model.a, r.model.a);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn file_fingerprint_tracks_rewrites_and_shape() {
        let p = std::env::temp_dir()
            .join(format!("exatensor_fp_{}.ext1", std::process::id()));
        let path = p.to_str().unwrap();
        let t = crate::tensor::DenseTensor::from_vec([2, 2, 2], vec![1.0; 8]);
        crate::tensor::io::save_tensor(&t, &p).unwrap();
        let f1 = file_fingerprint(path).unwrap();
        assert_eq!(f1, file_fingerprint(path).unwrap(), "stable across reads");
        // The source fingerprint nests the file fingerprint.
        let src = JobSource::File { path: path.to_string() };
        assert_eq!(
            source_fingerprint(&src).unwrap(),
            source_fingerprint(&src).unwrap()
        );
        // Rewriting the payload in place with the same shape must change
        // the fingerprint (via mtime): a stale cached decomposition of the
        // old payload would otherwise be served silently.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let t2 = crate::tensor::DenseTensor::from_vec([2, 2, 2], vec![2.0; 8]);
        crate::tensor::io::save_tensor(&t2, &p).unwrap();
        let f2 = file_fingerprint(path).unwrap();
        assert_ne!(f1, f2, "same-shape rewrite must change the fingerprint");
        assert_ne!(
            source_fingerprint(&src).unwrap(),
            {
                // recompute against the old value by hashing f1 directly
                let mut h = Fnv::new();
                h.write(b"file");
                h.write_u64(f1);
                h.finish()
            },
            "source fingerprint must track the file fingerprint"
        );
        // A different shape changes it regardless of timing.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let t3 = crate::tensor::DenseTensor::from_vec([4, 2, 1], vec![1.0; 8]);
        crate::tensor::io::save_tensor(&t3, &p).unwrap();
        assert_ne!(f2, file_fingerprint(path).unwrap());
        std::fs::remove_file(&p).ok();
    }
}
