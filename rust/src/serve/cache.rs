//! Result cache: repeated decompositions of the same input are served
//! from memory instead of re-running the pipeline.
//!
//! The key is a **tensor fingerprint**: an FNV-1a digest over the input's
//! identity (for `EXT1` files, the header bytes + file length + mtime; for
//! synthetic sources, the generator parameters), the tensor dims, the CP
//! rank, the seed, and a hash of the result-relevant pipeline config.
//! Execution-only knobs (`threads`, `io_threads`, `prefetch_depth`,
//! `checkpoint_dir`) are excluded — the streaming engine is bitwise
//! deterministic across them, so runs that differ only there produce
//! identical factors and must share a cache line.
//!
//! Eviction is LRU under a byte budget: each entry is priced at its factor
//! bytes, and inserts evict least-recently-used entries until the cache
//! fits.  An entry larger than the whole budget is simply not cached.

use super::job::JobSpec;
use crate::cp::CpModel;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::Read;
use std::sync::{Arc, Mutex};

/// FNV-1a lives in `util/hash.rs` since the checkpoint layer adopted it
/// for payload digests; re-exported here because the cache is where it
/// grew up and the serve code keys off this path.
pub use crate::util::hash::{fnv1a64, Fnv};

/// Digest of a CP model's factor bytes — the protocol's cheap bitwise-
/// identity witness (resume-after-kill must reproduce it exactly).
pub fn model_digest(model: &CpModel) -> u64 {
    let mut h = Fnv::new();
    for m in [&model.a, &model.b, &model.c] {
        h.write_u64(m.rows() as u64);
        h.write_u64(m.cols() as u64);
        for &x in m.data() {
            h.write(&x.to_le_bytes());
        }
    }
    h.finish()
}

/// Digest of an `EXT1` file's identity: header bytes (magic + ndim + dims),
/// total file length, and the modification time.  Never reads the payload,
/// so fingerprinting a multi-TB tensor costs one small read — the mtime is
/// what catches a payload rewritten in place with the same shape.
pub fn file_fingerprint(path: &str) -> Result<u64> {
    let mut f = std::fs::File::open(path).with_context(|| format!("fingerprinting {path}"))?;
    let meta = f.metadata().context("stat")?;
    let len = meta.len();
    // Magic (4) + ndim (4) + up to 8 dims (64): the EXT1 header never
    // exceeds 72 bytes.
    let mut header = [0u8; 72];
    let mut read = 0;
    while read < header.len() {
        match f.read(&mut header[read..]) {
            Ok(0) => break,
            Ok(n) => read += n,
            Err(e) => return Err(e).context("reading header"),
        }
    }
    let mut h = Fnv::new();
    h.write(&header[..read]);
    h.write_u64(len);
    if let Ok(mtime) = meta.modified() {
        if let Ok(d) = mtime.duration_since(std::time::UNIX_EPOCH) {
            h.write_u64(d.as_secs());
            h.write_u64(d.subsec_nanos() as u64);
        }
    }
    Ok(h.finish())
}

/// The full result-cache key for a job spec.  Errors if a file input
/// cannot be read (the submitter gets the failure immediately).
pub fn cache_key(spec: &JobSpec) -> Result<String> {
    let mut h = Fnv::new();
    match &spec.source {
        super::job::JobSource::Synthetic { size, rank, noise, seed } => {
            h.write(b"synthetic");
            h.write_u64(*size as u64);
            h.write_u64(*rank as u64);
            h.write_u64(noise.to_bits());
            h.write_u64(*seed);
        }
        super::job::JobSource::File { path } => {
            h.write(b"file");
            h.write_u64(file_fingerprint(path)?);
        }
    }
    let dims = spec.source.dims()?;
    for d in dims {
        h.write_u64(d as u64);
    }
    h.write_u64(spec.config.rank as u64);
    h.write_u64(spec.config.seed);
    // Config hash over the canonical JSON minus execution-only knobs.
    let mut cfg = spec.config.to_json();
    if let Json::Obj(m) = &mut cfg {
        // `map_tier` is execution-only too: both tiers produce bitwise
        // identical factors by construction (tests/map_tiers.rs), so a
        // procedural resubmission of a materialized job is a cache hit.
        // `recovery_solver`/`recovery_panel_cols` follow the same policy:
        // every solver converges to the same minimizer within the
        // pipeline's own tolerance (tests in coordinator::recovery), so
        // how the stacked solve executes must not split cache lines.
        for k in [
            "threads",
            "io_threads",
            "prefetch_depth",
            "checkpoint_dir",
            "map_tier",
            "recovery_solver",
            "recovery_panel_cols",
        ] {
            m.remove(k);
        }
    }
    h.write(cfg.to_string_compact().as_bytes());
    Ok(format!("{:016x}", h.finish()))
}

/// A cached decomposition: the model plus the summary the protocol returns.
#[derive(Clone)]
pub struct CachedResult {
    pub model: Arc<CpModel>,
    pub rel_error: f64,
    pub sampled_mse: f64,
    pub dropped_replicas: usize,
    pub model_digest: u64,
}

impl CachedResult {
    /// Bytes this entry charges against the cache budget (factor data).
    fn cost(&self) -> usize {
        let m = &self.model;
        (m.a.rows() + m.b.rows() + m.c.rows()) * m.rank() * std::mem::size_of::<f32>() + 64
    }
}

/// Monotone counters a scheduler mirrors into its metrics registry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub used_bytes: usize,
    pub entries: usize,
}

struct Entry {
    result: CachedResult,
    bytes: usize,
    last_used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    used: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Thread-safe LRU result cache with a byte budget.
pub struct ResultCache {
    budget: usize,
    inner: Mutex<Inner>,
}

impl ResultCache {
    /// `budget` = 0 disables caching entirely (every get misses, inserts
    /// are dropped).
    pub fn new(budget: usize) -> Self {
        Self {
            budget,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                used: 0,
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    pub fn get(&self, key: &str) -> Option<CachedResult> {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        match g.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                let r = e.result.clone();
                g.hits += 1;
                Some(r)
            }
            None => {
                g.misses += 1;
                None
            }
        }
    }

    pub fn insert(&self, key: String, result: CachedResult) {
        let bytes = result.cost();
        if bytes > self.budget {
            log::debug!("cache: {key} costs {bytes} B > budget {} B, not cached", self.budget);
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(old) = g.map.remove(&key) {
            g.used -= old.bytes;
        }
        // Evict LRU entries until the new entry fits the budget.
        while g.used + bytes > self.budget {
            let victim = g
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    let e = g.map.remove(&k).unwrap();
                    g.used -= e.bytes;
                    g.evictions += 1;
                }
                None => break,
            }
        }
        g.used += bytes;
        g.map.insert(key, Entry { result, bytes, last_used: tick });
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            used_bytes: g.used,
            entries: g.map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PipelineConfig;
    use crate::linalg::Matrix;
    use crate::serve::job::JobSource;

    fn model(rows: usize, rank: usize, fill: f32) -> CachedResult {
        let m = |r| Matrix::from_vec(r, rank, vec![fill; r * rank]);
        let model = CpModel::new(m(rows), m(rows), m(rows));
        let digest = model_digest(&model);
        CachedResult {
            model: Arc::new(model),
            rel_error: 0.0,
            sampled_mse: 0.0,
            dropped_replicas: 0,
            model_digest: digest,
        }
    }

    fn spec(seed: u64, threads: usize) -> JobSpec {
        JobSpec {
            source: JobSource::Synthetic { size: 16, rank: 2, noise: 0.0, seed: 9 },
            config: PipelineConfig::builder()
                .reduced_dims(8, 8, 8)
                .rank(2)
                .anchor_rows(4)
                .threads(threads)
                .seed(seed)
                .build()
                .unwrap(),
            priority: 0,
            tenant: String::new(),
            sharded: false,
        }
    }

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
        let mut h = Fnv::new();
        h.write(b"ab");
        let mut h2 = Fnv::new();
        h2.write(b"a");
        h2.write(b"b");
        assert_eq!(h.finish(), h2.finish(), "incremental == one-shot");
    }

    #[test]
    fn cache_key_ignores_execution_knobs_but_not_seed() {
        let k1 = cache_key(&spec(1, 2)).unwrap();
        let k2 = cache_key(&spec(1, 8)).unwrap();
        assert_eq!(k1, k2, "thread count must not split cache lines");
        let k3 = cache_key(&spec(2, 2)).unwrap();
        assert_ne!(k1, k3, "seed changes the result, must change the key");
        // Map tier is bitwise-invisible to results: same cache line.
        let mut tiered = spec(1, 2);
        tiered.config.map_tier = crate::coordinator::config::MapTierChoice::Procedural;
        assert_eq!(k1, cache_key(&tiered).unwrap(), "map tier must not split cache lines");
        // Recovery solver + panel width are execution knobs too.
        let mut solved = spec(1, 2);
        solved.config.recovery_solver = crate::coordinator::config::RecoverySolver::Iterative;
        solved.config.recovery_panel_cols = 64;
        assert_eq!(
            k1,
            cache_key(&solved).unwrap(),
            "recovery solver/panel must not split cache lines"
        );
    }

    #[test]
    fn model_digest_detects_single_bit_changes() {
        let a = model(8, 2, 1.0);
        let mut m = (*a.model).clone();
        *m.a.data_mut().first_mut().unwrap() += 1e-7;
        assert_ne!(model_digest(&m), a.model_digest);
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        // Each 8×2×3-factor entry costs 8·2·4·3 + 64 = 256 bytes; budget
        // holds exactly two.
        let cache = ResultCache::new(512);
        cache.insert("a".into(), model(8, 2, 1.0));
        cache.insert("b".into(), model(8, 2, 2.0));
        assert_eq!(cache.stats().entries, 2);
        // Touch "a" so "b" is LRU, then insert "c": "b" must be evicted.
        assert!(cache.get("a").is_some());
        cache.insert("c".into(), model(8, 2, 3.0));
        let st = cache.stats();
        assert_eq!(st.entries, 2);
        assert_eq!(st.evictions, 1);
        assert!(st.used_bytes <= 512);
        assert!(cache.get("b").is_none(), "LRU entry must be gone");
        assert!(cache.get("a").is_some() && cache.get("c").is_some());
    }

    #[test]
    fn oversized_entry_and_zero_budget_are_not_cached() {
        let cache = ResultCache::new(100);
        cache.insert("big".into(), model(64, 4, 1.0));
        assert_eq!(cache.stats().entries, 0);
        let off = ResultCache::new(0);
        off.insert("x".into(), model(8, 2, 1.0));
        assert!(off.get("x").is_none());
        assert_eq!(off.stats().misses, 1);
    }

    #[test]
    fn file_fingerprint_tracks_rewrites_and_shape() {
        let p = std::env::temp_dir()
            .join(format!("exatensor_fp_{}.ext1", std::process::id()));
        let path = p.to_str().unwrap();
        let t = crate::tensor::DenseTensor::from_vec([2, 2, 2], vec![1.0; 8]);
        crate::tensor::io::save_tensor(&t, &p).unwrap();
        let f1 = file_fingerprint(path).unwrap();
        assert_eq!(f1, file_fingerprint(path).unwrap(), "stable across reads");
        // Rewriting the payload in place with the same shape must change
        // the fingerprint (via mtime): a stale cached decomposition of the
        // old payload would otherwise be served silently.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let t2 = crate::tensor::DenseTensor::from_vec([2, 2, 2], vec![2.0; 8]);
        crate::tensor::io::save_tensor(&t2, &p).unwrap();
        let f2 = file_fingerprint(path).unwrap();
        assert_ne!(f1, f2, "same-shape rewrite must change the fingerprint");
        // A different shape changes it regardless of timing.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let t3 = crate::tensor::DenseTensor::from_vec([4, 2, 1], vec![1.0; 8]);
        crate::tensor::io::save_tensor(&t3, &p).unwrap();
        assert_ne!(f2, file_fingerprint(path).unwrap());
        std::fs::remove_file(&p).ok();
    }
}
