//! Batch-lane policy: which jobs may coalesce, what "compatible" means,
//! and the multi-tenant fair-share state that keeps one flooding tenant
//! from monopolizing the lane.
//!
//! The lane itself lives in [`super::scheduler`]: per admission tick it
//! gathers compatible small jobs into one shared sweep
//! ([`crate::coordinator::run_batch_group`]), which runs their ALS
//! iterations through a single coalesced `als_batch` dispatch.  This
//! module holds the pure policy pieces so they can be property-tested
//! without a daemon:
//!
//! * [`lane_eligible`] — the threshold rule: a job rides the lane iff the
//!   lane is on (`--batch-threshold-mb > 0`), its planner-priced
//!   `plan_bytes` fits under the cutoff, and it runs the plain dense
//!   pipeline (no sensing variant, no XLA stage hooks).  Everything else
//!   keeps the existing per-job admission path untouched.
//! * [`compat_key`] — jobs coalesce only when their ALS sweeps are
//!   config-identical: same rank, same iteration budget, same tolerance
//!   (bit pattern, so `compat_key` equality is exact).
//! * [`DrrState`] — deficit-round-robin fair share across tenants with
//!   capped aging, so a tenant flooding thousands of small jobs shares the
//!   lane ~evenly with every other tenant that has work waiting, and a
//!   briefly-absent tenant re-enters within [`DRR_DEFICIT_CAP`] slots.

use super::job::JobRecord;
use crate::coordinator::config::Backend;
use std::collections::BTreeMap;

/// Compatibility key for coalescing: two jobs may share one sweep iff
/// their `(rank, als_iters, als_tol)` agree exactly (tolerance compared by
/// bit pattern).  Tensor dims may differ — each item keeps its own
/// unfoldings — only the sweep-shaping config must match.
pub fn compat_key(rec: &JobRecord) -> (usize, usize, u64) {
    let c = &rec.spec.config;
    (c.rank, c.als_iters, c.als_tol.to_bits())
}

/// The threshold rule: may this job ride the batch lane at all?
///
/// `threshold_bytes == 0` means the lane is off (the default), so every
/// job keeps the existing per-job path.  Jobs above the cutoff, sensing
/// jobs, and XLA-backend jobs (whose proxy ALS goes through the backend's
/// stage hook, not the in-crate sweep) are likewise solo.
pub fn lane_eligible(rec: &JobRecord, threshold_bytes: usize) -> bool {
    threshold_bytes > 0
        && rec.plan_bytes <= threshold_bytes
        && rec.spec.config.sensing.is_none()
        && !matches!(rec.spec.config.backend, Backend::Xla)
}

/// Credit a tenant earns per admission slot in which it has work waiting.
pub const DRR_QUANTUM: u64 = 1;

/// Deficit cap — the aging bound.  A tenant's banked credit never exceeds
/// this, so (a) no tenant can hoard unbounded priority, and (b) any tenant
/// with work waiting is served within `DRR_DEFICIT_CAP` slots of the
/// fair-share schedule no matter how large a competitor's flood is.
pub const DRR_DEFICIT_CAP: u64 = 8;

/// Deficit-round-robin state across tenants (classic DRR with unit-cost
/// jobs): every tenant with waiting work earns [`DRR_QUANTUM`] per slot
/// (capped at [`DRR_DEFICIT_CAP`]), the largest deficit is served and
/// charged one unit, and — as in textbook DRR — a tenant's deficit resets
/// when it has nothing queued.
///
/// Deficit ties are broken **least-recently-served first**, not by queue
/// order.  The distinction is load-bearing: two tenants both pinned at
/// the deficit cap tie on *every* slot (the winner's one-unit charge is
/// re-credited next slot), so a queue-order tie-break would hand every
/// saturated slot to whichever tenant holds the queue front — the
/// flooding tenant — and starve the rest.  LRS ties make saturated
/// tenants strictly alternate.
#[derive(Debug, Default)]
pub struct DrrState {
    deficits: BTreeMap<String, u64>,
    /// Virtual timestamp of each tenant's last admission (0 = never):
    /// the tie-break rank.  Pruned with `deficits` when a tenant has
    /// nothing waiting, so a returning tenant re-enters as "never
    /// served" and wins its first saturated tie immediately.
    last_served: BTreeMap<String, u64>,
    clock: u64,
}

impl DrrState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Picks which of `tenants` (the queued candidates' tenants, in queue
    /// order) to admit next, returning the winning candidate's index.
    ///
    /// Every distinct tenant present is credited one quantum first; the
    /// largest deficit wins, ties going to the least recently served
    /// tenant (never-served first, then queue order).  The winner is
    /// charged one unit, and tenants with nothing waiting are forgotten —
    /// their deficit restarts from zero when they return.
    pub fn pick(&mut self, tenants: &[&str]) -> Option<usize> {
        if tenants.is_empty() {
            return None;
        }
        let mut distinct: Vec<&str> = Vec::new();
        for &t in tenants {
            if !distinct.contains(&t) {
                distinct.push(t);
            }
        }
        self.deficits.retain(|t, _| distinct.contains(&t.as_str()));
        self.last_served.retain(|t, _| distinct.contains(&t.as_str()));
        for &t in &distinct {
            let d = self.deficits.entry(t.to_string()).or_insert(0);
            *d = (*d + DRR_QUANTUM).min(DRR_DEFICIT_CAP);
        }
        let mut winner = distinct[0];
        for &t in &distinct[1..] {
            let (d, ls) = (self.deficits[t], self.last_served.get(t).copied().unwrap_or(0));
            let (bd, bls) = (
                self.deficits[winner],
                self.last_served.get(winner).copied().unwrap_or(0),
            );
            if d > bd || (d == bd && ls < bls) {
                winner = t;
            }
        }
        self.clock += 1;
        self.last_served.insert(winner.to_string(), self.clock);
        let d = self.deficits.get_mut(winner).unwrap();
        *d = d.saturating_sub(1);
        tenants.iter().position(|&t| t == winner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PipelineConfig;
    use crate::serve::job::{JobSource, JobSpec, JobState};

    fn rec(plan_bytes: usize, rank: usize) -> JobRecord {
        JobRecord {
            id: "job-000001".into(),
            seq: 1,
            spec: JobSpec {
                source: JobSource::Synthetic { size: 16, rank: 2, noise: 0.0, seed: 1 },
                config: PipelineConfig::builder()
                    .reduced_dims(8, 8, 8)
                    .rank(rank)
                    .anchor_rows(4)
                    .build()
                    .unwrap(),
                priority: 0,
                tenant: String::new(),
                sharded: false,
                no_cache: false,
            },
            state: JobState::Queued,
            plan_bytes,
            cache_key: String::new(),
            cancel_requested: false,
            resolved_solver: None,
            attempts: 0,
            panics: 0,
            error: None,
            outcome: None,
        }
    }

    #[test]
    fn eligibility_follows_threshold_rule() {
        let r = rec(1 << 20, 2);
        assert!(!lane_eligible(&r, 0), "lane off by default");
        assert!(lane_eligible(&r, 2 << 20));
        assert!(!lane_eligible(&r, 1 << 19), "over the cutoff");
        let mut sensing = rec(1 << 20, 2);
        sensing.spec.config.sensing = Some(crate::coordinator::SensingConfig {
            alpha: 2.0,
            nnz_per_col: 8,
            lambda: 0.01,
        });
        assert!(!lane_eligible(&sensing, 2 << 20), "sensing jobs stay solo");
        let mut xla = rec(1 << 20, 2);
        xla.spec.config.backend = Backend::Xla;
        assert!(!lane_eligible(&xla, 2 << 20), "XLA jobs stay solo");
    }

    #[test]
    fn compat_key_separates_sweep_configs() {
        assert_eq!(compat_key(&rec(1, 2)), compat_key(&rec(999, 2)));
        assert_ne!(compat_key(&rec(1, 2)), compat_key(&rec(1, 3)));
        let mut other_tol = rec(1, 2);
        other_tol.spec.config.als_tol *= 2.0;
        assert_ne!(compat_key(&rec(1, 2)), compat_key(&other_tol));
    }

    #[test]
    fn lone_tenant_always_served() {
        let mut drr = DrrState::new();
        for _ in 0..100 {
            assert_eq!(drr.pick(&["solo", "solo", "solo"]), Some(0));
        }
        assert_eq!(drr.pick(&[]), None);
    }

    /// The satellite property test: a 1000-job flood from tenant A cannot
    /// starve tenant B beyond the aging bound — B's i-th admission happens
    /// within `2·i + DRR_DEFICIT_CAP` slots of B having work queued.
    #[test]
    fn flood_cannot_starve_minority_tenant_beyond_aging_bound() {
        let mut drr = DrrState::new();
        // Both tenants keep work queued for all 200 measured slots (a
        // drained tenant rightly stops competing), so the even-share
        // assertion below is about fairness, not queue exhaustion.
        let mut queue: Vec<&str> = vec!["A"; 1000];
        queue.extend(std::iter::repeat("B").take(200));
        let mut b_admitted = 0usize;
        for slot in 1..=200usize {
            let idx = drr.pick(&queue).unwrap();
            let picked = queue.remove(idx);
            if picked == "B" {
                b_admitted += 1;
                assert!(
                    slot <= 2 * b_admitted + DRR_DEFICIT_CAP as usize,
                    "B admission #{b_admitted} only came at slot {slot}"
                );
            }
        }
        // Two tenants with work waiting share the lane ~evenly.
        assert!(
            (90..=110).contains(&(200 - b_admitted)),
            "A got {} of 200 slots",
            200 - b_admitted
        );
        // Aging is capped: a tenant absent for ages re-enters with at most
        // DRR_DEFICIT_CAP banked credit, not one per missed slot.
        let mut drr = DrrState::new();
        for _ in 0..100 {
            drr.pick(&["A", "C"]); // C waits un-served only if A out-deficits it
        }
        let banked = drr.deficits.get("C").copied().unwrap_or(0);
        assert!(banked <= DRR_DEFICIT_CAP, "banked {banked}");
    }
}
