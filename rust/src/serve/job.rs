//! Job model for the multi-tenant decomposition service: what a tenant
//! submits, the lifecycle state machine, and the crash-safe spool that
//! persists every record so a killed daemon recovers its queue.
//!
//! Lifecycle:
//!
//! ```text
//! submitted ──▶ queued ──▶ running ──▶ done
//!     │            ▲           ├─────▶ failed       (permanent error, or
//!     │            │           │                     transient retries spent)
//!     │            └── retry ──┤
//!     │            │           ├─────▶ quarantined  (panicked too often)
//!     └────────────┴───────────┴─────▶ cancelled
//! ```
//!
//! `submitted` covers the brief planning window between `SUBMIT` arriving
//! and the scheduler pricing the job with [`MemoryPlanner`]
//! (crate::coordinator::MemoryPlanner); a cache hit jumps straight from
//! `submitted` to `done`.  Records are JSON files under
//! `<spool>/jobs/<id>.json`, committed by atomic rename, so the spool is
//! never observed half-written.

use crate::coordinator::config::{PipelineConfig, RecoverySolverKind};
use crate::tensor::{FileTensorSource, LowRankGenerator, TensorSource};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Unique job identifier (`job-<seq>`; the sequence survives restarts).
pub type JobId = String;

/// Where the input tensor comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum JobSource {
    /// Implicit low-rank generator (never materialized).
    Synthetic {
        size: usize,
        rank: usize,
        noise: f64,
        seed: u64,
    },
    /// An `EXT1` file streamed out-of-core through `FileTensorSource`.
    File { path: String },
}

impl JobSource {
    pub fn to_json(&self) -> Json {
        match self {
            JobSource::Synthetic {
                size,
                rank,
                noise,
                seed,
            } => Json::obj(vec![
                ("kind", Json::str("synthetic")),
                ("size", Json::num(*size as f64)),
                ("rank", Json::num(*rank as f64)),
                ("noise", Json::num(*noise)),
                ("seed", Json::num(*seed as f64)),
            ]),
            JobSource::File { path } => Json::obj(vec![
                ("kind", Json::str("file")),
                ("path", Json::str(path.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<JobSource> {
        match v.get("kind").and_then(|x| x.as_str()) {
            Some("synthetic") => Ok(JobSource::Synthetic {
                size: v
                    .get("size")
                    .and_then(|x| x.as_usize())
                    .context("source missing size")?,
                rank: v
                    .get("rank")
                    .and_then(|x| x.as_usize())
                    .context("source missing rank")?,
                noise: v.get("noise").and_then(|x| x.as_f64()).unwrap_or(0.0),
                seed: v
                    .get("seed")
                    .and_then(|x| x.as_usize())
                    .context("source missing seed")? as u64,
            }),
            Some("file") => Ok(JobSource::File {
                path: v
                    .get("path")
                    .and_then(|x| x.as_str())
                    .context("source missing path")?
                    .to_string(),
            }),
            other => bail!("unknown source kind {other:?}"),
        }
    }

    /// Tensor dims without materializing anything (file inputs read only
    /// the header) — what the planner prices admission with.
    pub fn dims(&self) -> Result<[usize; 3]> {
        match self {
            JobSource::Synthetic { size, .. } => Ok([*size, *size, *size]),
            JobSource::File { path } => Ok(FileTensorSource::open(path)?.dims()),
        }
    }

    /// Opens the streaming source for a run.
    pub fn open(&self) -> Result<Box<dyn TensorSource>> {
        match self {
            JobSource::Synthetic {
                size,
                rank,
                noise,
                seed,
            } => {
                let mut g = LowRankGenerator::new(*size, *size, *size, *rank, *seed);
                if *noise > 0.0 {
                    g = g.with_noise(*noise as f32);
                }
                Ok(Box::new(g))
            }
            JobSource::File { path } => Ok(Box::new(FileTensorSource::open(path)?)),
        }
    }
}

/// Everything a tenant submits: input + full pipeline config + priority.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub source: JobSource,
    /// Per-job pipeline configuration.  `checkpoint_dir` is daemon-owned
    /// (one directory per job under the spool) and ignored if set here.
    pub config: PipelineConfig,
    /// Higher runs first; ties break FIFO by submission order.
    pub priority: i64,
    /// Owning tenant for fair-share accounting (batch-lane quotas and
    /// deficit-round-robin aging).  Empty means the anonymous default
    /// tenant.  Like `priority`, this is scheduling metadata: it is NOT
    /// part of the result-cache key, so identical work from different
    /// tenants still shares cache entries.
    pub tenant: String,
    /// Run the compression stage in sharded mode: the daemon partitions
    /// the deterministic shard grid into lease ranges and farms them out
    /// to `worker` processes over the serve protocol, folding the
    /// returned shard accumulators in shard order so the result is
    /// bitwise identical to a solo run.  Like `tenant`/`priority` this is
    /// execution metadata and is NOT part of the result-cache key.
    pub sharded: bool,
    /// Bypass the artifact store entirely for this job: no result-cache
    /// fast path, no Stage-1 proxy reuse, and nothing published for later
    /// jobs.  The control knob for cold-baseline runs (benchmarks, the
    /// CI control sweep) on a warm daemon.  NOT part of the cache key —
    /// it changes policy, never the result.
    pub no_cache: bool,
}

impl JobSpec {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("source", self.source.to_json()),
            ("config", self.config.to_json()),
            ("priority", Json::num(self.priority as f64)),
        ];
        if !self.tenant.is_empty() {
            pairs.push(("tenant", Json::str(self.tenant.clone())));
        }
        if self.sharded {
            pairs.push(("sharded", Json::Bool(true)));
        }
        if self.no_cache {
            pairs.push(("no_cache", Json::Bool(true)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<JobSpec> {
        Ok(JobSpec {
            source: JobSource::from_json(v.get("source").context("spec missing source")?)?,
            config: PipelineConfig::from_json(v.get("config").context("spec missing config")?)?,
            priority: v.get("priority").and_then(|x| x.as_f64()).unwrap_or(0.0) as i64,
            tenant: v
                .get("tenant")
                .and_then(|x| x.as_str())
                .unwrap_or("")
                .to_string(),
            sharded: v.get("sharded").and_then(|x| x.as_bool()).unwrap_or(false),
            no_cache: v.get("no_cache").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }
}

/// Lifecycle states.  `is_terminal` states never transition again.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Submitted,
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
    /// The job's run panicked `poison_threshold` times: it is parked
    /// terminally instead of being retried again, so one poison job cannot
    /// eat the worker pool forever.
    Quarantined,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Submitted => "submitted",
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Quarantined => "quarantined",
        }
    }

    pub fn parse(s: &str) -> Result<JobState> {
        Ok(match s {
            "submitted" => JobState::Submitted,
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            "quarantined" => JobState::Quarantined,
            other => bail!("unknown job state '{other}'"),
        })
    }

    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Done | JobState::Failed | JobState::Cancelled | JobState::Quarantined
        )
    }
}

/// What a finished job produced (also the cache payload's summary).
#[derive(Clone, Debug, PartialEq)]
pub struct JobOutcome {
    pub rel_error: f64,
    pub sampled_mse: f64,
    pub dropped_replicas: usize,
    /// FNV-1a digest of the factor bytes — the cheap bitwise-identity
    /// witness the protocol exposes (kill/restart resume must reproduce
    /// an uninterrupted run's digest exactly).
    pub model_digest: u64,
    /// Served from the result cache instead of a fresh run.
    pub from_cache: bool,
}

impl JobOutcome {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rel_error", Json::num(self.rel_error)),
            ("sampled_mse", Json::num(self.sampled_mse)),
            ("dropped_replicas", Json::num(self.dropped_replicas as f64)),
            ("model_digest", Json::str(format!("{:016x}", self.model_digest))),
            ("from_cache", Json::Bool(self.from_cache)),
        ])
    }

    fn from_json(v: &Json) -> Result<JobOutcome> {
        let digest = v
            .get("model_digest")
            .and_then(|x| x.as_str())
            .context("outcome missing model_digest")?;
        Ok(JobOutcome {
            rel_error: v
                .get("rel_error")
                .and_then(|x| x.as_f64())
                .context("outcome missing rel_error")?,
            sampled_mse: v.get("sampled_mse").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
            dropped_replicas: v
                .get("dropped_replicas")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            model_digest: u64::from_str_radix(digest, 16).context("bad model_digest")?,
            from_cache: v.get("from_cache").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }
}

/// One job's persisted record — the unit the spool stores and the
/// `STATUS` verb returns.
#[derive(Clone, Debug)]
pub struct JobRecord {
    pub id: JobId,
    /// Monotone submission sequence (FIFO tiebreak; survives restarts).
    pub seq: u64,
    pub spec: JobSpec,
    pub state: JobState,
    /// Admission price: the resolved plan's estimated bytes.
    pub plan_bytes: usize,
    /// Result-cache key (tensor fingerprint + config hash).
    pub cache_key: String,
    /// A `CANCEL` arrived while the job was running.  Persisted so an
    /// acknowledged cancellation survives a daemon crash: recovery turns
    /// a flagged non-terminal record into `cancelled` instead of
    /// requeueing it.
    pub cancel_requested: bool,
    /// The planner-resolved recovery solver (settled at admission, so
    /// `STATUS` reports what will actually run even while the job queues).
    /// `None` in records written before the field existed.
    pub resolved_solver: Option<RecoverySolverKind>,
    /// Transient-failure retries consumed so far (persisted so the retry
    /// budget survives a daemon restart; 0 in legacy records).
    pub attempts: u32,
    /// Runs of this job that ended in a panic — including runs cut short
    /// by a daemon crash, which recovery counts as one panic because it
    /// cannot tell them apart.  At the scheduler's `poison_threshold` the
    /// job is quarantined.  0 in legacy records.
    pub panics: u32,
    pub error: Option<String>,
    pub outcome: Option<JobOutcome>,
}

impl JobRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("version", Json::num(1.0)),
            ("id", Json::str(self.id.clone())),
            ("seq", Json::num(self.seq as f64)),
            ("spec", self.spec.to_json()),
            ("state", Json::str(self.state.as_str())),
            ("plan_bytes", Json::num(self.plan_bytes as f64)),
            ("cache_key", Json::str(self.cache_key.clone())),
        ];
        if self.cancel_requested {
            pairs.push(("cancel_requested", Json::Bool(true)));
        }
        if let Some(s) = self.resolved_solver {
            pairs.push(("resolved_solver", Json::str(s.as_str())));
        }
        if self.attempts > 0 {
            pairs.push(("attempts", Json::num(self.attempts as f64)));
        }
        if self.panics > 0 {
            pairs.push(("panics", Json::num(self.panics as f64)));
        }
        if let Some(e) = &self.error {
            pairs.push(("error", Json::str(e.clone())));
        }
        if let Some(o) = &self.outcome {
            pairs.push(("outcome", o.to_json()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<JobRecord> {
        if v.get("version").and_then(|x| x.as_usize()) != Some(1) {
            bail!("unsupported job record version");
        }
        Ok(JobRecord {
            id: v
                .get("id")
                .and_then(|x| x.as_str())
                .context("record missing id")?
                .to_string(),
            seq: v
                .get("seq")
                .and_then(|x| x.as_usize())
                .context("record missing seq")? as u64,
            spec: JobSpec::from_json(v.get("spec").context("record missing spec")?)?,
            state: JobState::parse(
                v.get("state")
                    .and_then(|x| x.as_str())
                    .context("record missing state")?,
            )?,
            plan_bytes: v.get("plan_bytes").and_then(|x| x.as_usize()).unwrap_or(0),
            cache_key: v
                .get("cache_key")
                .and_then(|x| x.as_str())
                .unwrap_or("")
                .to_string(),
            cancel_requested: v
                .get("cancel_requested")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            resolved_solver: match v.get("resolved_solver").and_then(|x| x.as_str()) {
                Some(s) => Some(RecoverySolverKind::parse(s)?),
                None => None,
            },
            attempts: v.get("attempts").and_then(|x| x.as_usize()).unwrap_or(0) as u32,
            panics: v.get("panics").and_then(|x| x.as_usize()).unwrap_or(0) as u32,
            error: v.get("error").and_then(|x| x.as_str()).map(str::to_string),
            outcome: match v.get("outcome") {
                None | Some(Json::Null) => None,
                Some(o) => Some(JobOutcome::from_json(o)?),
            },
        })
    }
}

/// The on-disk spool: `jobs/` (records), `results/` (factor files),
/// `checkpoints/<id>/` (per-job incremental + final pipeline checkpoints).
pub struct Spool {
    dir: PathBuf,
}

impl Spool {
    pub fn open(dir: impl AsRef<Path>) -> Result<Spool> {
        let dir = dir.as_ref().to_path_buf();
        for sub in ["jobs", "results", "checkpoints"] {
            std::fs::create_dir_all(dir.join(sub))
                .with_context(|| format!("creating spool {}/{sub}", dir.display()))?;
        }
        Ok(Spool { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Artifact-store root: content-addressed blobs (proxy sets, shard
    /// accumulators, cached factors) shared across jobs and daemons.
    pub fn store_dir(&self) -> PathBuf {
        self.dir.join("store")
    }

    /// Per-job pipeline checkpoint directory — a killed daemon's running
    /// jobs resume mid-compression from here on restart.
    pub fn checkpoint_dir(&self, id: &str) -> PathBuf {
        self.dir.join("checkpoints").join(id)
    }

    /// Per-job result directory (factor matrices as EXT1 files).
    pub fn result_dir(&self, id: &str) -> PathBuf {
        self.dir.join("results").join(id)
    }

    fn record_path(&self, id: &str) -> PathBuf {
        self.dir.join("jobs").join(format!("{id}.json"))
    }

    /// Persists one record via write-to-temp + atomic rename: a kill mid-
    /// save leaves the previous complete record in force.
    pub fn save(&self, rec: &JobRecord) -> Result<()> {
        let path = self.record_path(&rec.id);
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, rec.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path).context("committing job record")?;
        Ok(())
    }

    /// Loads every record, sorted by sequence.  Unparseable files are
    /// skipped with a warning (one corrupt record must not wedge the whole
    /// daemon on restart).
    pub fn load_all(&self) -> Result<Vec<JobRecord>> {
        let mut out = Vec::new();
        for e in std::fs::read_dir(self.dir.join("jobs"))?.flatten() {
            let path = e.path();
            if path.extension().and_then(|x| x.to_str()) != Some("json") {
                continue;
            }
            let parsed = std::fs::read_to_string(&path)
                .map_err(anyhow::Error::from)
                .and_then(|t| Ok(Json::parse(&t)?))
                .and_then(|v| JobRecord::from_json(&v));
            match parsed {
                Ok(rec) => out.push(rec),
                Err(err) => log::warn!("spool: skipping {}: {err:#}", path.display()),
            }
        }
        out.sort_by_key(|r| r.seq);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_spool_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    fn spec() -> JobSpec {
        JobSpec {
            source: JobSource::Synthetic {
                size: 32,
                rank: 2,
                noise: 0.0,
                seed: 7,
            },
            config: PipelineConfig::builder()
                .reduced_dims(8, 8, 8)
                .rank(2)
                .anchor_rows(4)
                .build()
                .unwrap(),
            priority: 3,
            tenant: "acme".into(),
            sharded: false,
            no_cache: false,
        }
    }

    fn record(id: &str, seq: u64, state: JobState) -> JobRecord {
        JobRecord {
            id: id.to_string(),
            seq,
            spec: spec(),
            state,
            plan_bytes: 123_456,
            cache_key: "deadbeef".into(),
            cancel_requested: false,
            resolved_solver: Some(RecoverySolverKind::Cholesky),
            attempts: 0,
            panics: 0,
            error: None,
            outcome: Some(JobOutcome {
                rel_error: 1e-3,
                sampled_mse: 1e-6,
                dropped_replicas: 1,
                model_digest: 0xfeed_beef_dead_cafe,
                from_cache: false,
            }),
        }
    }

    #[test]
    fn record_json_round_trip() {
        let rec = record("job-000007", 7, JobState::Running);
        let v = Json::parse(&rec.to_json().to_string_pretty()).unwrap();
        let back = JobRecord::from_json(&v).unwrap();
        assert_eq!(back.id, rec.id);
        assert_eq!(back.seq, rec.seq);
        assert_eq!(back.state, JobState::Running);
        assert_eq!(back.plan_bytes, rec.plan_bytes);
        assert_eq!(back.cache_key, rec.cache_key);
        assert!(!back.cancel_requested, "defaults false");
        let mut flagged = rec.clone();
        flagged.cancel_requested = true;
        let back = JobRecord::from_json(&flagged.to_json()).unwrap();
        assert!(back.cancel_requested, "cancel flag survives the round trip");
        assert_eq!(back.outcome, rec.outcome);
        assert_eq!(back.spec.priority, 3);
        assert_eq!(back.spec.tenant, "acme", "tenant survives the round trip");
        assert_eq!(back.spec.source, rec.spec.source);
        assert_eq!(back.spec.config.reduced, [8, 8, 8]);
        // Legacy specs (no tenant key) default to the anonymous tenant, and
        // the anonymous tenant is not emitted at all.
        let mut anon = rec.spec.clone();
        anon.tenant = String::new();
        let spec_json = anon.to_json();
        assert!(spec_json.get("tenant").is_none(), "empty tenant stays implicit");
        assert_eq!(JobSpec::from_json(&spec_json).unwrap().tenant, "");
        // Like the tenant, `sharded` is implicit when off and survives the
        // round trip when on (legacy specs default to unsharded).
        assert!(spec_json.get("sharded").is_none(), "unsharded stays implicit");
        assert!(!JobSpec::from_json(&spec_json).unwrap().sharded);
        let mut shd = rec.spec.clone();
        shd.sharded = true;
        assert!(JobSpec::from_json(&shd.to_json()).unwrap().sharded);
        // `no_cache` follows the same implicit-default pattern.
        assert!(spec_json.get("no_cache").is_none(), "cached stays implicit");
        assert!(!JobSpec::from_json(&spec_json).unwrap().no_cache);
        let mut bypass = rec.spec.clone();
        bypass.no_cache = true;
        assert!(JobSpec::from_json(&bypass.to_json()).unwrap().no_cache);
        assert_eq!(back.resolved_solver, Some(RecoverySolverKind::Cholesky));
        // Legacy records (no resolved_solver key) default to None.
        let mut legacy = rec.to_json();
        if let Json::Obj(m) = &mut legacy {
            m.remove("resolved_solver");
        }
        let back = JobRecord::from_json(&legacy).unwrap();
        assert_eq!(back.resolved_solver, None);
        // Legacy records also predate the retry counters.
        assert_eq!((back.attempts, back.panics), (0, 0));
        // Non-zero retry counters survive the round trip.
        let mut retried = rec.clone();
        retried.attempts = 2;
        retried.panics = 1;
        let back = JobRecord::from_json(&retried.to_json()).unwrap();
        assert_eq!((back.attempts, back.panics), (2, 1));
    }

    #[test]
    fn file_source_round_trip_and_state_strings() {
        let s = JobSource::File { path: "/tmp/x.ext1".into() };
        assert_eq!(JobSource::from_json(&s.to_json()).unwrap(), s);
        for st in [
            JobState::Submitted,
            JobState::Queued,
            JobState::Running,
            JobState::Done,
            JobState::Failed,
            JobState::Cancelled,
            JobState::Quarantined,
        ] {
            assert_eq!(JobState::parse(st.as_str()).unwrap(), st);
            assert_eq!(
                st.is_terminal(),
                matches!(
                    st,
                    JobState::Done
                        | JobState::Failed
                        | JobState::Cancelled
                        | JobState::Quarantined
                )
            );
        }
        assert!(JobState::parse("bogus").is_err());
    }

    #[test]
    fn spool_persists_and_recovers_sorted() {
        let dir = tmpdir("persist");
        let spool = Spool::open(&dir).unwrap();
        spool.save(&record("job-000002", 2, JobState::Queued)).unwrap();
        spool.save(&record("job-000001", 1, JobState::Done)).unwrap();
        // Overwrite in place: the newer state wins.
        spool.save(&record("job-000002", 2, JobState::Running)).unwrap();
        // A corrupt record is skipped, not fatal.
        std::fs::write(dir.join("jobs").join("junk.json"), "{nope").unwrap();
        let all = spool.load_all().unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].id, "job-000001");
        assert_eq!(all[1].state, JobState::Running);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthetic_source_dims_and_open() {
        let s = JobSource::Synthetic { size: 12, rank: 2, noise: 0.0, seed: 3 };
        assert_eq!(s.dims().unwrap(), [12, 12, 12]);
        assert_eq!(s.open().unwrap().dims(), [12, 12, 12]);
        let missing = JobSource::File { path: "/nonexistent/x.ext1".into() };
        assert!(missing.dims().is_err());
    }
}
