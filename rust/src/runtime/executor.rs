//! Executor thread pool owning PJRT clients + compiled artifacts.
//!
//! Why threads-with-channels instead of sharing: the `xla` crate's client
//! and executable types are `Rc`-based (`!Send`), so each executor thread
//! builds its *own* client and compiles its own copy of every artifact, and
//! callers (any thread) submit [`Request`]s over an mpsc channel, blocking
//! on a per-request reply channel.  Compilation happens once per thread at
//! startup — never on the request path.

use super::host::HostTensor;
use super::manifest::Manifest;
use anyhow::{anyhow, bail, Context, Result};
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

// Without the `xla` feature the only readers of these fields
// (`executor_main`'s serve loop, `run_one`) are compiled out; the stub
// executor still receives the struct, so keep the shape and silence the
// resulting dead_code lint.
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
struct Request {
    artifact: String,
    inputs: Vec<HostTensor>,
    reply: mpsc::Sender<Result<Vec<HostTensor>>>,
}

/// Handle to the executor pool.  Cheap to clone; dropping the last handle
/// shuts the executor threads down.
#[derive(Clone)]
pub struct XlaRuntime {
    inner: Arc<Inner>,
}

struct Inner {
    tx: Mutex<mpsc::Sender<Request>>,
    manifest: Manifest,
    threads: Vec<JoinHandle<()>>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        // Replace the sender to close the channel, then join.
        let (dummy_tx, _) = mpsc::channel();
        *self.tx.lock().unwrap() = dummy_tx;
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl XlaRuntime {
    /// Loads the manifest from `dir` and spins up `threads` executor
    /// threads, each compiling every artifact on its own PJRT CPU client.
    pub fn load(dir: impl AsRef<std::path::Path>, threads: usize) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        Self::with_manifest(manifest, threads)
    }

    /// As [`load`], with an already-parsed manifest.
    pub fn with_manifest(manifest: Manifest, threads: usize) -> Result<XlaRuntime> {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));

        // Each thread reports readiness (or a startup error) once.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::new();
        for tid in 0..threads {
            let rx = Arc::clone(&rx);
            let ready = ready_tx.clone();
            let manifest = manifest.clone();
            handles.push(std::thread::spawn(move || {
                executor_main(tid, manifest, rx, ready);
            }));
        }
        drop(ready_tx);
        for _ in 0..threads {
            ready_rx
                .recv()
                .context("executor thread died during startup")??;
        }
        log::info!(
            "xla runtime ready: {} artifacts × {threads} executor threads",
            manifest.artifacts.len()
        );
        Ok(XlaRuntime {
            inner: Arc::new(Inner {
                tx: Mutex::new(tx),
                manifest,
                threads: handles,
            }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    /// Executes `artifact` with `inputs`, blocking for the outputs.
    /// Validates shapes against the manifest before dispatch.
    pub fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let spec = self.inner.manifest.get(artifact)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {artifact}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (idx, (got, want)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if &got.dims != want {
                bail!(
                    "artifact {artifact}: input {idx} shape {:?} != expected {:?}",
                    got.dims,
                    want
                );
            }
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.inner
            .tx
            .lock()
            .unwrap()
            .send(Request {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("executor threads are gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("executor dropped request"))?
    }
}

/// Executor thread body when the crate is built **without** the `xla`
/// feature: report a clean startup error so `XlaRuntime::load` fails with
/// an actionable message and every artifact-dependent caller self-skips.
#[cfg(not(feature = "xla"))]
fn executor_main(
    _tid: usize,
    _manifest: Manifest,
    _rx: Arc<Mutex<mpsc::Receiver<Request>>>,
    ready: mpsc::Sender<Result<()>>,
) {
    let _ = ready.send(Err(anyhow!(
        "exascale_tensor was built without the `xla` feature; \
         rebuild with `cargo build --features xla` (and a real xla-rs in \
         rust/vendor/xla) to execute AOT artifacts"
    )));
}

/// Executor thread body: build client, compile all artifacts, serve.
#[cfg(feature = "xla")]
fn executor_main(
    tid: usize,
    manifest: Manifest,
    rx: Arc<Mutex<mpsc::Receiver<Request>>>,
    ready: mpsc::Sender<Result<()>>,
) {
    let setup = || -> Result<(xla::PjRtClient, HashMap<String, xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        let mut exes = HashMap::new();
        for (name, spec) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .with_context(|| format!("loading {}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok((client, exes))
    };
    let (client, exes) = match setup() {
        Ok(pair) => {
            let _ = ready.send(Ok(()));
            pair
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = &client; // keep alive for executables' lifetime
    log::debug!("executor {tid}: serving");

    loop {
        let req = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let req = match req {
            Ok(r) => r,
            Err(_) => break, // channel closed → shutdown
        };
        let result = run_one(&exes, &manifest, &req);
        let _ = req.reply.send(result);
    }
}

#[cfg(feature = "xla")]
fn run_one(
    exes: &HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: &Manifest,
    req: &Request,
) -> Result<Vec<HostTensor>> {
    let exe = exes
        .get(&req.artifact)
        .with_context(|| format!("artifact {} not compiled", req.artifact))?;
    let spec = manifest.get(&req.artifact)?;

    // Build literals (f32, row-major — jax's default layout).
    let mut literals = Vec::with_capacity(req.inputs.len());
    for input in &req.inputs {
        let dims: Vec<i64> = input.dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&input.data)
            .reshape(&dims)
            .with_context(|| format!("reshaping input to {dims:?}"))?;
        literals.push(lit);
    }

    let result = exe
        .execute::<xla::Literal>(&literals)
        .with_context(|| format!("executing {}", req.artifact))?;
    // gen-side lowers with return_tuple=True: result[0][0] is a tuple of
    // spec.outputs.len() elements.
    let mut tuple = result[0][0]
        .to_literal_sync()
        .context("fetching result literal")?;
    let parts = tuple.decompose_tuple().context("decomposing result tuple")?;
    if parts.len() != spec.outputs.len() {
        bail!(
            "artifact {}: expected {} outputs, got {}",
            req.artifact,
            spec.outputs.len(),
            parts.len()
        );
    }
    let mut outputs = Vec::with_capacity(parts.len());
    for (part, dims) in parts.into_iter().zip(&spec.outputs) {
        let data = part
            .to_vec::<f32>()
            .context("converting output literal to f32")?;
        outputs.push(HostTensor::new(dims.clone(), data));
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run *and* the `xla`
    /// feature; they self-skip (with a loud message) otherwise so
    /// `cargo test` works in a fresh checkout.
    fn runtime() -> Option<XlaRuntime> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
            return None;
        }
        match XlaRuntime::load(dir, 2) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("SKIP: xla runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn executes_identity_artifact_if_present() {
        let Some(rt) = runtime() else { return };
        // The aot.py manifest always includes a tiny smoke artifact.
        let Ok(spec) = rt.manifest().get("smoke_add") else {
            eprintln!("SKIP: smoke_add not in manifest");
            return;
        };
        let x = HostTensor::new(spec.inputs[0].clone(), vec![1.0; spec.inputs[0].iter().product()]);
        let y = HostTensor::new(spec.inputs[1].clone(), vec![2.0; spec.inputs[1].iter().product()]);
        let out = rt.execute("smoke_add", vec![x, y]).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].data.iter().all(|&v| (v - 3.0).abs() < 1e-6));
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let Some(rt) = runtime() else { return };
        let err = rt.execute("smoke_add", vec![]).unwrap_err().to_string();
        assert!(err.contains("expected"), "got: {err}");
    }

    #[test]
    fn unknown_artifact_rejected() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("no_such_artifact", vec![]).is_err());
    }
}
