//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas artifacts
//! from the rust request path (Python is never invoked here).
//!
//! The `xla` crate's `PjRtClient`/`PjRtLoadedExecutable` are `!Send`
//! (`Rc`-based), so the runtime follows the device-owner pattern: each
//! executor thread owns its *own* PJRT CPU client plus compiled copies of
//! every artifact, and callers submit work through channels
//! ([`executor::XlaRuntime::execute`] blocks on a per-request reply
//! channel).  This mirrors how a CUDA-stream owner thread is used in the
//! systems the paper builds on.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (written by
//!   `python/compile/aot.py`) describing each HLO-text artifact's inputs
//!   and outputs.
//! * [`host`]     — `HostTensor`, the `Send` host-side value crossing the
//!   channel boundary.
//! * [`executor`] — the executor thread pool.
//! * [`backends`] — [`XlaBackend`], the artifact-backed
//!   [`crate::linalg::ComputeBackend`] ("GPU tensor cores" arm of the
//!   benchmarks), built from the [`crate::compress::BlockCompressor`] and
//!   [`crate::coordinator::ProxyDecomposer`] artifact adapters.

pub mod backends;
pub mod executor;
pub mod host;
pub mod manifest;

pub use backends::{XlaAlsDecomposer, XlaBackend, XlaCompressor};
pub use executor::XlaRuntime;
pub use host::HostTensor;
pub use manifest::{ArtifactSpec, Manifest};

/// Default artifacts directory, overridable via `EXATENSOR_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("EXATENSOR_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
