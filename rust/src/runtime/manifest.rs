//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! (writer) and the rust runtime (reader).
//!
//! ```json
//! {
//!   "version": 1,
//!   "artifacts": {
//!     "compress_block_l16m16n16_d32": {
//!       "file": "compress_block_l16m16n16_d32.hlo.txt",
//!       "inputs":  [[32,32,32],[16,32],[16,32],[16,32]],
//!       "outputs": [[16,16,16]],
//!       "kind": "compress_block",
//!       "params": {"l":16,"m":16,"n":16,"d":32}
//!     }, …
//!   }
//! }
//! ```

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One artifact's metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
    pub kind: String,
    pub params: BTreeMap<String, usize>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

fn parse_shapes(v: &Json, what: &str) -> Result<Vec<Vec<usize>>> {
    let arr = v
        .as_arr()
        .with_context(|| format!("{what}: expected array of shapes"))?;
    arr.iter()
        .map(|shape| {
            shape
                .as_arr()
                .with_context(|| format!("{what}: expected shape array"))?
                .iter()
                .map(|d| d.as_usize().with_context(|| format!("{what}: bad dim")))
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Loads `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parses manifest text (dir used to resolve artifact files).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest.json parse")?;
        let version = root.get("version").and_then(|v| v.as_usize()).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let arts = root
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .context("manifest missing 'artifacts' object")?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in arts {
            let file = spec
                .get("file")
                .and_then(|f| f.as_str())
                .with_context(|| format!("artifact {name}: missing file"))?;
            let inputs = parse_shapes(
                spec.get("inputs").with_context(|| format!("artifact {name}: inputs"))?,
                name,
            )?;
            let outputs = parse_shapes(
                spec.get("outputs")
                    .with_context(|| format!("artifact {name}: outputs"))?,
                name,
            )?;
            let kind = spec
                .get("kind")
                .and_then(|k| k.as_str())
                .unwrap_or("generic")
                .to_string();
            let mut params = BTreeMap::new();
            if let Some(pobj) = spec.get("params").and_then(|p| p.as_obj()) {
                for (k, v) in pobj {
                    if let Some(n) = v.as_usize() {
                        params.insert(k.clone(), n);
                    }
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs,
                    outputs,
                    kind,
                    params,
                },
            );
        }
        Ok(Manifest { artifacts, dir })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }

    /// Finds the first artifact of `kind` whose params match all `want`
    /// pairs.
    pub fn find(&self, kind: &str, want: &[(&str, usize)]) -> Option<&ArtifactSpec> {
        self.artifacts.values().find(|a| {
            a.kind == kind
                && want
                    .iter()
                    .all(|(k, v)| a.params.get(*k).copied() == Some(*v))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": {
        "compress_block_l4_d8": {
          "file": "cb.hlo.txt",
          "inputs": [[8,8,8],[4,8],[4,8],[4,8]],
          "outputs": [[4,4,4]],
          "kind": "compress_block",
          "params": {"l": 4, "m": 4, "n": 4, "d": 8}
        },
        "als_sweep_l4_r2": {
          "file": "als.hlo.txt",
          "inputs": [[4,4,4],[4,2],[4,2],[4,2]],
          "outputs": [[4,2],[4,2],[4,2]],
          "kind": "als_sweep",
          "params": {"l": 4, "r": 2}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let cb = m.get("compress_block_l4_d8").unwrap();
        assert_eq!(cb.inputs.len(), 4);
        assert_eq!(cb.outputs[0], vec![4, 4, 4]);
        assert_eq!(cb.file, PathBuf::from("/tmp/a/cb.hlo.txt"));
        assert_eq!(cb.params["d"], 8);
    }

    #[test]
    fn find_by_kind_and_params() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.find("als_sweep", &[("r", 2)]).is_some());
        assert!(m.find("als_sweep", &[("r", 3)]).is_none());
        assert!(m.find("compress_block", &[("l", 4), ("d", 8)]).is_some());
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("nope"));
    }

    #[test]
    fn bad_version_rejected() {
        let bad = r#"{"version": 2, "artifacts": {}}"#;
        assert!(Manifest::parse(bad, PathBuf::from(".")).is_err());
    }

    #[test]
    fn malformed_rejected() {
        assert!(Manifest::parse("{", PathBuf::from(".")).is_err());
        assert!(Manifest::parse(r#"{"version":1}"#, PathBuf::from(".")).is_err());
    }
}
