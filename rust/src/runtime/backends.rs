//! Pipeline backends wired to the AOT artifacts — the "GPU tensor cores"
//! arm of the benchmarks.
//!
//! * [`XlaCompressor`] implements [`BlockCompressor`] with the Pallas
//!   `ttm_chain` kernel artifact (fixed block shape; ragged edge blocks are
//!   zero-padded — exact, since the op is linear and padding contributes 0).
//! * [`XlaAlsDecomposer`] implements [`ProxyDecomposer`] with the fused
//!   `als_sweep` artifact: one call = one full ALS sweep (all three mode
//!   updates) on the device; rust loops sweeps and checks convergence.

use super::executor::XlaRuntime;
use super::host::HostTensor;
use crate::compress::BlockCompressor;
use crate::coordinator::config::PipelineConfig;
use crate::coordinator::ProxyDecomposer;
use crate::cp::CpModel;
use crate::linalg::backend::{ComputeBackend, CpuParallelBackend};
use crate::linalg::{Matrix, Trans};
use crate::tensor::DenseTensor;
use crate::util::rng::Xoshiro256;
use anyhow::{bail, Context, Result};

/// Block compression via the `compress_block` artifact.
pub struct XlaCompressor {
    runtime: XlaRuntime,
    artifact: String,
    block_d: [usize; 3],
    reduced: [usize; 3],
}

impl XlaCompressor {
    /// Picks the `compress_block` artifact matching `reduced = [L,M,N]` and
    /// block size `d` from the manifest.
    pub fn new(runtime: XlaRuntime, reduced: [usize; 3], d: usize) -> Result<Self> {
        let spec = runtime
            .manifest()
            .find(
                "compress_block",
                &[
                    ("l", reduced[0]),
                    ("m", reduced[1]),
                    ("n", reduced[2]),
                    ("d", d),
                ],
            )
            .with_context(|| {
                format!("no compress_block artifact for reduced={reduced:?} d={d} (run `make artifacts`)")
            })?
            .clone();
        Ok(Self {
            runtime,
            artifact: spec.name,
            block_d: [d, d, d],
            reduced,
        })
    }

    pub fn block_dims(&self) -> [usize; 3] {
        self.block_d
    }
}

impl BlockCompressor for XlaCompressor {
    fn compress_block(
        &self,
        t: &DenseTensor,
        u_blk: &Matrix,
        v_blk: &Matrix,
        w_blk: &Matrix,
    ) -> DenseTensor {
        let [l, m, n] = self.reduced;
        let [d0, d1, d2] = self.block_d;
        // Zero-copy layout trick (§Perf): the column-major rust buffer of a
        // `(di, dj, dk)` tensor IS the row-major buffer of the reversed
        // `(dk, dj, di)` tensor, and `Comp` over reversed dims is the same
        // contraction with U and W swapped:
        //   Comp(T_rev, W, V, U) = Comp(T, U, V, W) reversed.
        // The output then memcpy-reinterprets back to column-major.  This
        // removes the two O(d³)/O(LMN) scalar transposes per dispatch that
        // dominated the request path (requires the symmetric artifact
        // shapes we compile: d0=d1=d2, l=m=n).
        debug_assert!(d0 == d1 && d1 == d2 && l == m && m == n);
        let [di, dj, dk] = t.dims();
        let th = HostTensor::new(vec![dk, dj, di], t.data().to_vec()).pad_to(&[d2, d1, d0]);
        let uh = HostTensor::from_matrix(u_blk).pad_to(&[l, d0]);
        let vh = HostTensor::from_matrix(v_blk).pad_to(&[m, d1]);
        let wh = HostTensor::from_matrix(w_blk).pad_to(&[n, d2]);
        let out = self
            .runtime
            .execute(&self.artifact, vec![th, wh, vh, uh])
            .expect("compress_block artifact execution failed");
        // Row-major (n, m, l) == column-major (l, m, n): reinterpret.
        DenseTensor::from_vec([l, m, n], out[0].data.clone())
    }

    fn name(&self) -> &'static str {
        "xla-pallas-ttm"
    }
}

/// Proxy ALS via the fused `als_sweep` artifact.
pub struct XlaAlsDecomposer {
    runtime: XlaRuntime,
    artifact: String,
    reduced: [usize; 3],
    rank: usize,
    pub sweeps: usize,
    pub tol: f64,
}

impl XlaAlsDecomposer {
    pub fn new(
        runtime: XlaRuntime,
        reduced: [usize; 3],
        rank: usize,
        sweeps: usize,
        tol: f64,
    ) -> Result<Self> {
        let spec = runtime
            .manifest()
            .find(
                "als_sweep",
                &[
                    ("l", reduced[0]),
                    ("m", reduced[1]),
                    ("n", reduced[2]),
                    ("r", rank),
                ],
            )
            .with_context(|| {
                format!("no als_sweep artifact for reduced={reduced:?} rank={rank} (run `make artifacts`)")
            })?
            .clone();
        Ok(Self {
            runtime,
            artifact: spec.name,
            reduced,
            rank,
            sweeps,
            tol,
        })
    }
}

impl ProxyDecomposer for XlaAlsDecomposer {
    fn decompose(&self, proxy: &DenseTensor, rank: usize, seed: u64) -> Result<(CpModel, f64)> {
        assert_eq!(rank, self.rank, "decomposer compiled for rank {}", self.rank);
        assert_eq!(proxy.dims(), self.reduced, "proxy dims mismatch");
        let mut rng = Xoshiro256::seed_from_u64(seed);
        // The artifact takes (Y, B, C): A is recomputed first inside the
        // sweep, so it is not an input (see model.als_sweep).
        let mut a = HostTensor::zeros(vec![self.reduced[0], rank]);
        let mut b = HostTensor::from_matrix(&Matrix::random_normal(
            self.reduced[1],
            rank,
            &mut rng,
        ));
        let mut c = HostTensor::from_matrix(&Matrix::random_normal(
            self.reduced[2],
            rank,
            &mut rng,
        ));
        let y = HostTensor::from_tensor(proxy);
        let norm_y = proxy.frobenius_norm();

        let mut prev_fit = f64::NEG_INFINITY;
        for sweep in 0..self.sweeps {
            let out = self
                .runtime
                .execute(&self.artifact, vec![y.clone(), b, c])
                .with_context(|| format!("als_sweep sweep {sweep}"))?;
            let mut it = out.into_iter();
            a = it.next().context("missing A output")?;
            b = it.next().context("missing B output")?;
            c = it.next().context("missing C output")?;
            // Convergence check on the host every few sweeps (cheap at L≤50).
            if sweep % 4 == 3 || sweep + 1 == self.sweeps {
                let model = CpModel::new(a.to_matrix(), b.to_matrix(), c.to_matrix());
                let resid = residual_norm(proxy, &model);
                let fit = 1.0 - resid / norm_y.max(1e-300);
                if (fit - prev_fit).abs() < self.tol {
                    return Ok((model, fit));
                }
                prev_fit = fit;
            }
        }
        let model = CpModel::new(a.to_matrix(), b.to_matrix(), c.to_matrix());
        let fit = 1.0 - residual_norm(proxy, &model) / norm_y.max(1e-300);
        Ok((model, fit))
    }

    fn name(&self) -> &'static str {
        "xla-als-sweep"
    }
}

/// The "GPU tensor cores" arm as a single [`ComputeBackend`]: the fused
/// AOT Pallas artifacts (`ttm_chain` block compression, `als_sweep` proxy
/// ALS) are exposed through the trait's stage hooks, while the host-side
/// dense kernels delegate to a [`CpuParallelBackend`] — small Gram/LSTSQ
/// work stays on the CPU exactly as in the paper's system, where the
/// device executes the two fused hot kernels.
///
/// Construction is a single call keyed off the pipeline configuration:
/// `coordinator/config.rs::Backend::Xla` resolves to
/// [`XlaBackend::from_config`].
pub struct XlaBackend {
    cpu: CpuParallelBackend,
    compressor: XlaCompressor,
    decomposer: XlaAlsDecomposer,
}

impl XlaBackend {
    /// Wires both artifact adapters on one runtime handle.
    pub fn new(
        runtime: XlaRuntime,
        reduced: [usize; 3],
        block_d: usize,
        rank: usize,
        sweeps: usize,
        tol: f64,
        threads: usize,
    ) -> Result<Self> {
        Ok(Self {
            cpu: CpuParallelBackend::new(threads),
            compressor: XlaCompressor::new(runtime.clone(), reduced, block_d)?,
            decomposer: XlaAlsDecomposer::new(runtime, reduced, rank, sweeps, tol)?,
        })
    }

    /// The single constructor behind `Backend::Xla`: loads the AOT
    /// artifacts from [`crate::runtime::artifacts_dir`] and picks the
    /// specs matching the run configuration.  Needs explicit cubic block
    /// dims (the compiled `compress_block` artifacts are cubic).
    pub fn from_config(cfg: &PipelineConfig) -> Result<Self> {
        let block = cfg
            .block
            .context("Backend::Xla needs explicit block dims (PipelineConfig::block)")?;
        if block[0] != block[1] || block[1] != block[2] {
            bail!("Backend::Xla needs cubic block dims, got {block:?}");
        }
        let runtime = XlaRuntime::load(crate::runtime::artifacts_dir(), 2)
            .context("loading the AOT artifact runtime for Backend::Xla")?;
        Self::new(
            runtime,
            cfg.reduced,
            block[0],
            cfg.rank,
            cfg.als_iters,
            cfg.als_tol,
            cfg.threads,
        )
    }
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-pallas"
    }

    fn gemm(
        &self,
        alpha: f32,
        a: &Matrix,
        op_a: Trans,
        b: &Matrix,
        op_b: Trans,
        beta: f32,
        c: &mut Matrix,
    ) {
        self.cpu.gemm(alpha, a, op_a, b, op_b, beta, c);
    }

    fn gemm_batch(
        &self,
        alpha: f32,
        a_list: &[Matrix],
        op_a: Trans,
        b: &Matrix,
        op_b: Trans,
        beta: f32,
        c_list: &mut [Matrix],
    ) {
        self.cpu.gemm_batch(alpha, a_list, op_a, b, op_b, beta, c_list);
    }

    fn mttkrp(&self, mode: usize, x_mode: &Matrix, slow: &Matrix, fast: &Matrix) -> Matrix {
        // Delegate so host-side MTTKRPs get the parallel fused panel/row
        // split, not the trait's serial default.
        self.cpu.mttkrp(mode, x_mode, slow, fast)
    }

    fn block_compressor(&self) -> Option<&dyn BlockCompressor> {
        Some(&self.compressor)
    }

    fn proxy_decomposer(&self) -> Option<&dyn ProxyDecomposer> {
        Some(&self.decomposer)
    }
}

fn residual_norm(y: &DenseTensor, model: &CpModel) -> f64 {
    use crate::linalg::backend::SerialBackend;
    let x1 = crate::tensor::unfold::unfold_1(y);
    let x1kr = SerialBackend.mttkrp(1, &x1, &model.c, &model.b);
    let mut inner = 0.0f64;
    for r in 0..model.rank() {
        for i in 0..model.a.rows() {
            inner += model.a.get(i, r) as f64 * x1kr.get(i, r) as f64;
        }
    }
    let ns = y.frobenius_norm();
    ((ns * ns - 2.0 * inner + model.norm_sq()).max(0.0)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::comp_dense;
    use crate::mixed::MixedPrecision;

    fn runtime() -> Option<XlaRuntime> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            return None;
        }
        match XlaRuntime::load(dir, 1) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("SKIP: xla runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn xla_compress_matches_rust() {
        let Some(rt) = runtime() else { return };
        let Ok(comp) = XlaCompressor::new(rt, [16, 16, 16], 32) else {
            eprintln!("SKIP: no compress_block l16 d32 artifact");
            return;
        };
        let mut rng = Xoshiro256::seed_from_u64(500);
        let t = DenseTensor::random_normal([32, 32, 32], &mut rng);
        let u = Matrix::random_normal(16, 32, &mut rng);
        let v = Matrix::random_normal(16, 32, &mut rng);
        let w = Matrix::random_normal(16, 32, &mut rng);
        let got = comp.compress_block(&t, &u, &v, &w);
        let want = comp_dense(&t, &u, &v, &w, MixedPrecision::Full);
        let err = got.rel_error(&want);
        assert!(err < 1e-3, "xla vs rust err {err}");
    }

    #[test]
    fn xla_compress_ragged_block_padding_exact() {
        let Some(rt) = runtime() else { return };
        let Ok(comp) = XlaCompressor::new(rt, [16, 16, 16], 32) else { return };
        let mut rng = Xoshiro256::seed_from_u64(501);
        // Edge block smaller than compiled shape.
        let t = DenseTensor::random_normal([20, 32, 7], &mut rng);
        let u = Matrix::random_normal(16, 20, &mut rng);
        let v = Matrix::random_normal(16, 32, &mut rng);
        let w = Matrix::random_normal(16, 7, &mut rng);
        let got = comp.compress_block(&t, &u, &v, &w);
        let want = comp_dense(&t, &u, &v, &w, MixedPrecision::Full);
        assert!(got.rel_error(&want) < 1e-3);
    }

    #[test]
    fn xla_als_decomposes_planted_proxy() {
        let Some(rt) = runtime() else { return };
        let Ok(dec) = XlaAlsDecomposer::new(rt, [16, 16, 16], 4, 120, 1e-10) else {
            eprintln!("SKIP: no als_sweep l16 r4 artifact");
            return;
        };
        let mut rng = Xoshiro256::seed_from_u64(502);
        let a = Matrix::random_normal(16, 4, &mut rng);
        let b = Matrix::random_normal(16, 4, &mut rng);
        let c = Matrix::random_normal(16, 4, &mut rng);
        let y = DenseTensor::from_cp_factors(&a, &b, &c);
        let (model, _fit) = dec.decompose(&y, 4, 77).unwrap();
        let err = model.to_tensor().rel_error(&y);
        assert!(err < 1e-2, "xla als err {err}");
    }
}
