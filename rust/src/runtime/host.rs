//! `HostTensor` — the `Send` host-side tensor value that crosses the
//! channel boundary into the executor threads (raw f32 buffer + dims).

use crate::linalg::Matrix;
use crate::tensor::DenseTensor;

/// A host-side row-of-floats with logical dims.  Layout convention matches
/// the artifacts: **row-major** (C order), because jax lowers with default
/// row-major layouts; conversion helpers below re-order from/to the crate's
/// column-major types.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "dims/data mismatch");
        Self { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Self {
            dims,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// From a column-major matrix → row-major host buffer.
    pub fn from_matrix(m: &Matrix) -> Self {
        let (r, c) = (m.rows(), m.cols());
        let mut data = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                data[i * c + j] = m.get(i, j);
            }
        }
        Self {
            dims: vec![r, c],
            data,
        }
    }

    /// Into a column-major matrix (dims must be 2-D).
    pub fn to_matrix(&self) -> Matrix {
        assert_eq!(self.dims.len(), 2, "to_matrix on {}-D tensor", self.dims.len());
        let (r, c) = (self.dims[0], self.dims[1]);
        Matrix::from_fn(r, c, |i, j| self.data[i * c + j])
    }

    /// From a column-major dense tensor → row-major host buffer.
    pub fn from_tensor(t: &DenseTensor) -> Self {
        let [i_dim, j_dim, k_dim] = t.dims();
        let mut data = vec![0.0f32; i_dim * j_dim * k_dim];
        for i in 0..i_dim {
            for j in 0..j_dim {
                for k in 0..k_dim {
                    data[(i * j_dim + j) * k_dim + k] = t.get(i, j, k);
                }
            }
        }
        Self {
            dims: vec![i_dim, j_dim, k_dim],
            data,
        }
    }

    /// Into a column-major dense tensor (dims must be 3-D).
    pub fn to_tensor(&self) -> DenseTensor {
        assert_eq!(self.dims.len(), 3, "to_tensor on {}-D tensor", self.dims.len());
        let [i_dim, j_dim, k_dim] = [self.dims[0], self.dims[1], self.dims[2]];
        DenseTensor::from_fn([i_dim, j_dim, k_dim], |i, j, k| {
            self.data[(i * j_dim + j) * k_dim + k]
        })
    }

    /// Zero-pads to `target` dims (each ≥ current) — used to feed
    /// fixed-shape artifacts with ragged edge blocks; zero padding is exact
    /// for the linear ops we compile.
    pub fn pad_to(&self, target: &[usize]) -> HostTensor {
        assert_eq!(target.len(), self.dims.len());
        for (t, d) in target.iter().zip(&self.dims) {
            assert!(t >= d, "pad_to: target {target:?} smaller than {:?}", self.dims);
        }
        if target == self.dims.as_slice() {
            return self.clone();
        }
        let mut out = HostTensor::zeros(target.to_vec());
        // Generic n-D copy via odometer.
        let nd = self.dims.len();
        let mut idx = vec![0usize; nd];
        let in_strides = row_major_strides(&self.dims);
        let out_strides = row_major_strides(target);
        'outer: loop {
            let src: usize = idx.iter().zip(&in_strides).map(|(i, s)| i * s).sum();
            let dst: usize = idx.iter().zip(&out_strides).map(|(i, s)| i * s).sum();
            out.data[dst] = self.data[src];
            // increment odometer (last dim fastest)
            for d in (0..nd).rev() {
                idx[d] += 1;
                if idx[d] < self.dims[d] {
                    continue 'outer;
                }
                idx[d] = 0;
            }
            break;
        }
        out
    }

    /// Crops to `target` dims (each ≤ current) — inverse of [`pad_to`].
    pub fn crop_to(&self, target: &[usize]) -> HostTensor {
        assert_eq!(target.len(), self.dims.len());
        for (t, d) in target.iter().zip(&self.dims) {
            assert!(t <= d, "crop_to: target {target:?} larger than {:?}", self.dims);
        }
        if target == self.dims.as_slice() {
            return self.clone();
        }
        let mut out = HostTensor::zeros(target.to_vec());
        let nd = target.len();
        let mut idx = vec![0usize; nd];
        let in_strides = row_major_strides(&self.dims);
        let out_strides = row_major_strides(target);
        'outer: loop {
            let src: usize = idx.iter().zip(&in_strides).map(|(i, s)| i * s).sum();
            let dst: usize = idx.iter().zip(&out_strides).map(|(i, s)| i * s).sum();
            out.data[dst] = self.data[src];
            for d in (0..nd).rev() {
                idx[d] += 1;
                if idx[d] < target[d] {
                    continue 'outer;
                }
                idx[d] = 0;
            }
            break;
        }
        out
    }
}

fn row_major_strides(dims: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; dims.len()];
    for d in (0..dims.len().saturating_sub(1)).rev() {
        strides[d] = strides[d + 1] * dims[d + 1];
    }
    strides
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn matrix_round_trip() {
        let mut rng = Xoshiro256::seed_from_u64(400);
        let m = Matrix::random_normal(5, 7, &mut rng);
        let h = HostTensor::from_matrix(&m);
        assert_eq!(h.dims, vec![5, 7]);
        // row-major check
        assert_eq!(h.data[1], m.get(0, 1));
        assert_eq!(h.to_matrix(), m);
    }

    #[test]
    fn tensor_round_trip() {
        let mut rng = Xoshiro256::seed_from_u64(401);
        let t = DenseTensor::random_normal([3, 4, 5], &mut rng);
        let h = HostTensor::from_tensor(&t);
        assert_eq!(h.dims, vec![3, 4, 5]);
        assert_eq!(h.data[1], t.get(0, 0, 1)); // last dim fastest
        assert_eq!(h.to_tensor(), t);
    }

    #[test]
    fn pad_then_crop_identity() {
        let mut rng = Xoshiro256::seed_from_u64(402);
        let t = DenseTensor::random_normal([2, 3, 4], &mut rng);
        let h = HostTensor::from_tensor(&t);
        let padded = h.pad_to(&[5, 5, 5]);
        assert_eq!(padded.dims, vec![5, 5, 5]);
        // padding area is zero
        assert_eq!(padded.data[(4 * 5 + 4) * 5 + 4], 0.0);
        let back = padded.crop_to(&[2, 3, 4]);
        assert_eq!(back, h);
    }

    #[test]
    fn pad_preserves_values() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let h = HostTensor::from_matrix(&m).pad_to(&[3, 4]);
        assert_eq!(h.data[0], 1.0);
        assert_eq!(h.data[1], 2.0);
        assert_eq!(h.data[4], 3.0); // row 1 starts at 4 in 3×4
        assert_eq!(h.data[5], 4.0);
        assert_eq!(h.data[11], 0.0);
    }

    #[test]
    #[should_panic(expected = "dims/data mismatch")]
    fn bad_dims_rejected() {
        let _ = HostTensor::new(vec![2, 2], vec![0.0; 3]);
    }
}
