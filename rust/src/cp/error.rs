//! Error metrics for CP models against (possibly huge) tensor sources.
//!
//! Full-tensor MSE is only possible for in-memory tensors; against a
//! [`TensorSource`] we stream sampled blocks — the estimator the paper's
//! MSE figures (4, 6, 8) are built from on the large scales.

use super::model::CpModel;
use crate::linalg::{hungarian_max, Matrix};
use crate::tensor::{BlockRange, TensorSource};
use crate::util::rng::Xoshiro256;

/// Result of a sampled error evaluation.
#[derive(Clone, Debug)]
pub struct SampledError {
    pub mse: f64,
    pub rel_error: f64,
    pub samples: usize,
}

/// Streams `num_blocks` random `d³` blocks from the source and accumulates
/// MSE / relative error of the model against them.
pub fn sampled_mse(
    src: &dyn TensorSource,
    model: &CpModel,
    d: usize,
    num_blocks: usize,
    seed: u64,
) -> SampledError {
    let [i_dim, j_dim, k_dim] = src.dims();
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut sq_err = 0.0f64;
    let mut sq_ref = 0.0f64;
    let mut n = 0usize;
    for idx in 0..num_blocks {
        let di = d.min(i_dim);
        let dj = d.min(j_dim);
        let dk = d.min(k_dim);
        let i0 = rng.next_below((i_dim - di + 1) as u64) as usize;
        let j0 = rng.next_below((j_dim - dj + 1) as u64) as usize;
        let k0 = rng.next_below((k_dim - dk + 1) as u64) as usize;
        let r = BlockRange {
            i0,
            i1: i0 + di,
            j0,
            j1: j0 + dj,
            k0,
            k1: k0 + dk,
            index: idx,
        };
        let blk = src.block(&r);
        for k in 0..dk {
            for j in 0..dj {
                for i in 0..di {
                    let x = blk.get(i, j, k) as f64;
                    let xh = model.value_at(i0 + i, j0 + j, k0 + k) as f64;
                    sq_err += (x - xh) * (x - xh);
                    sq_ref += x * x;
                    n += 1;
                }
            }
        }
    }
    SampledError {
        mse: sq_err / n.max(1) as f64,
        rel_error: if sq_ref > 0.0 {
            (sq_err / sq_ref).sqrt()
        } else {
            sq_err.sqrt()
        },
        samples: n,
    }
}

/// Factor congruence: how well `est` matches `truth` up to column
/// permutation and sign/scale.  Returns the mean absolute cosine of the
/// best column matching (1.0 = perfect recovery) — the standard CP factor
/// match score (FMS) restricted to one mode.
pub fn factor_congruence(truth: &Matrix, est: &Matrix) -> f64 {
    assert_eq!(truth.rows(), est.rows(), "congruence: row mismatch");
    assert_eq!(truth.cols(), est.cols(), "congruence: rank mismatch");
    let r = truth.cols();
    if r == 0 {
        return 1.0;
    }
    let mut t = truth.clone();
    let mut e = est.clone();
    t.normalize_cols();
    e.normalize_cols();
    // |cos| similarity matrix, matched by Hungarian.
    let sim = Matrix::from_fn(r, r, |i, j| {
        let dot: f32 = t.col(i).iter().zip(e.col(j)).map(|(a, b)| a * b).sum();
        dot.abs()
    });
    let asn = hungarian_max(&sim);
    asn.total / r as f64
}

/// Full three-mode factor match score: min over modes of the per-mode
/// congruence under a *single shared* column matching (columns must align
/// consistently across modes).
pub fn model_congruence(truth: &CpModel, est: &CpModel) -> f64 {
    let r = truth.rank();
    assert_eq!(est.rank(), r);
    let norm = |m: &Matrix| {
        let mut c = m.clone();
        c.normalize_cols();
        c
    };
    let (ta, tb, tc) = (norm(&truth.a), norm(&truth.b), norm(&truth.c));
    let (ea, eb, ec) = (norm(&est.a), norm(&est.b), norm(&est.c));
    // Shared matching maximizing the product-of-cosines triple.
    let sim = Matrix::from_fn(r, r, |i, j| {
        let da: f32 = ta.col(i).iter().zip(ea.col(j)).map(|(x, y)| x * y).sum();
        let db: f32 = tb.col(i).iter().zip(eb.col(j)).map(|(x, y)| x * y).sum();
        let dc: f32 = tc.col(i).iter().zip(ec.col(j)).map(|(x, y)| x * y).sum();
        da.abs() * db.abs() * dc.abs()
    });
    let asn = hungarian_max(&sim);
    // Mean of per-column triple products; 1.0 = all three modes perfect.
    asn.total / r as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{InMemorySource, LowRankGenerator};

    #[test]
    fn congruence_perfect_for_permuted_scaled_copy() {
        let mut rng = Xoshiro256::seed_from_u64(110);
        let m = Matrix::random_normal(10, 3, &mut rng);
        let permuted = m.permute_cols(&[2, 0, 1]).scale_cols(&[-2.0, 0.5, 3.0]);
        let c = factor_congruence(&m, &permuted);
        assert!(c > 0.9999, "congruence {c}");
    }

    #[test]
    fn congruence_low_for_random() {
        let mut rng = Xoshiro256::seed_from_u64(111);
        let m1 = Matrix::random_normal(50, 3, &mut rng);
        let m2 = Matrix::random_normal(50, 3, &mut rng);
        assert!(factor_congruence(&m1, &m2) < 0.6);
    }

    #[test]
    fn model_congruence_tracks_all_modes() {
        let gen = LowRankGenerator::new(8, 8, 8, 2, 112);
        let (a, b, c) = gen.factors.clone();
        let truth = CpModel::new(a, b, c);
        let same = model_congruence(&truth, &truth.permute_and_scale(&[1, 0], &[2.0, -1.0]));
        assert!(same > 0.999, "got {same}");
    }

    #[test]
    fn sampled_mse_zero_for_exact_model() {
        let gen = LowRankGenerator::new(20, 20, 20, 3, 113);
        let (a, b, c) = gen.factors.clone();
        let model = CpModel::new(a, b, c);
        let err = sampled_mse(&gen, &model, 5, 8, 1);
        assert!(err.mse < 1e-10, "mse {}", err.mse);
        assert_eq!(err.samples, 8 * 125);
    }

    #[test]
    fn sampled_mse_detects_wrong_model() {
        let gen = LowRankGenerator::new(15, 15, 15, 2, 114);
        let wrong = CpModel::new(
            Matrix::zeros(15, 2),
            Matrix::zeros(15, 2),
            Matrix::zeros(15, 2),
        );
        let err = sampled_mse(&gen, &wrong, 4, 4, 2);
        assert!(err.mse > 0.1);
        assert!((err.rel_error - 1.0).abs() < 1e-9); // zero model ⇒ rel err 1
    }

    #[test]
    fn sampled_mse_block_larger_than_tensor() {
        let t = crate::tensor::DenseTensor::from_fn([3, 3, 3], |_, _, _| 1.0);
        let src = InMemorySource::new(t);
        let model = CpModel::new(
            Matrix::from_fn(3, 1, |_, _| 1.0),
            Matrix::from_fn(3, 1, |_, _| 1.0),
            Matrix::from_fn(3, 1, |_, _| 1.0),
        );
        let err = sampled_mse(&src, &model, 10, 2, 3);
        assert!(err.mse < 1e-12);
    }
}
