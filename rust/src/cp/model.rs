//! The CP model `[[A, B, C]]` and factor-level operations shared by the
//! direct and compressed paths.

use crate::linalg::backend::{ComputeBackend, SerialBackend};
use crate::linalg::products::{hadamard, khatri_rao};
use crate::linalg::{Matrix, Trans};
use crate::tensor::DenseTensor;

/// A rank-R CP model of a third-order tensor: `X ≈ Σ_r a_r ∘ b_r ∘ c_r`.
#[derive(Clone, Debug)]
pub struct CpModel {
    pub a: Matrix,
    pub b: Matrix,
    pub c: Matrix,
}

impl CpModel {
    pub fn new(a: Matrix, b: Matrix, c: Matrix) -> Self {
        assert_eq!(a.cols(), b.cols(), "rank mismatch A/B");
        assert_eq!(b.cols(), c.cols(), "rank mismatch B/C");
        Self { a, b, c }
    }

    pub fn rank(&self) -> usize {
        self.a.cols()
    }

    pub fn dims(&self) -> [usize; 3] {
        [self.a.rows(), self.b.rows(), self.c.rows()]
    }

    /// Materializes the full tensor (small models only).
    pub fn to_tensor(&self) -> DenseTensor {
        DenseTensor::from_cp_factors(&self.a, &self.b, &self.c)
    }

    /// Model value at one index — used for streamed/sampled error.
    #[inline]
    pub fn value_at(&self, i: usize, j: usize, k: usize) -> f32 {
        let mut s = 0.0;
        for r in 0..self.rank() {
            s += self.a.get(i, r) * self.b.get(j, r) * self.c.get(k, r);
        }
        s
    }

    /// `‖[[A,B,C]]‖_F²` via the Gram-Hadamard identity (O(R²·dims) not
    /// O(IJK)).
    pub fn norm_sq(&self) -> f64 {
        let g = hadamard(
            &hadamard(&SerialBackend.gram(&self.a), &SerialBackend.gram(&self.b)),
            &SerialBackend.gram(&self.c),
        );
        g.data().iter().map(|&x| x as f64).sum()
    }

    /// Normalizes all factor columns to unit norm, pushing magnitudes into
    /// per-component weights (returned).  Standard CP normal form.
    pub fn normalize(&mut self) -> Vec<f32> {
        let na = self.a.normalize_cols();
        let nb = self.b.normalize_cols();
        let nc = self.c.normalize_cols();
        na.iter()
            .zip(&nb)
            .zip(&nc)
            .map(|((&x, &y), &z)| x * y * z)
            .collect()
    }

    /// Applies weights back into the first factor (inverse of a
    /// `normalize` round-trip when B, C stay unit-norm).
    pub fn absorb_weights(&mut self, weights: &[f32]) {
        self.a = self.a.scale_cols(weights);
    }

    /// Applies a column permutation + per-column scale to all factors:
    /// the `(Π, Σ)` disambiguation of Alg. 2 (scale applied to A only —
    /// the convention used throughout the recovery stage).
    pub fn permute_and_scale(&self, perm: &[usize], scale_a: &[f32]) -> CpModel {
        CpModel {
            a: self.a.permute_cols(perm).scale_cols(scale_a),
            b: self.b.permute_cols(perm),
            c: self.c.permute_cols(perm),
        }
    }

    /// Mode-1 reconstruction `A (C ⊙ B)ᵀ` (for validation on small sizes).
    pub fn unfold1(&self) -> Matrix {
        SerialBackend.matmul(&self.a, Trans::No, &khatri_rao(&self.c, &self.b), Trans::Yes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_model(seed: u64) -> CpModel {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        CpModel::new(
            Matrix::random_normal(5, 3, &mut rng),
            Matrix::random_normal(6, 3, &mut rng),
            Matrix::random_normal(7, 3, &mut rng),
        )
    }

    #[test]
    fn norm_sq_matches_dense() {
        let m = random_model(80);
        let dense = m.to_tensor();
        let direct = dense.frobenius_norm().powi(2);
        assert!((m.norm_sq() - direct).abs() / direct < 1e-4);
    }

    #[test]
    fn value_at_matches_dense() {
        let m = random_model(81);
        let dense = m.to_tensor();
        for (i, j, k) in [(0, 0, 0), (4, 5, 6), (2, 3, 1)] {
            assert!((m.value_at(i, j, k) - dense.get(i, j, k)).abs() < 1e-5);
        }
    }

    #[test]
    fn normalize_preserves_tensor() {
        let mut m = random_model(82);
        let before = m.to_tensor();
        let w = m.normalize();
        // Unit columns now.
        for j in 0..3 {
            let n: f32 = m.a.col(j).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
        m.absorb_weights(&w);
        let after = m.to_tensor();
        assert!(after.rel_error(&before) < 1e-5);
    }

    #[test]
    fn permute_and_scale_preserves_up_to_reorder() {
        let m = random_model(83);
        let perm = [2usize, 0, 1];
        let scale = [1.0f32, 1.0, 1.0];
        let p = m.permute_and_scale(&perm, &scale);
        // Same tensor (permutation of rank-1 terms is a no-op on the sum).
        assert!(p.to_tensor().rel_error(&m.to_tensor()) < 1e-5);
    }

    #[test]
    fn unfold1_matches_tensor_unfolding() {
        let m = random_model(84);
        let x1 = crate::tensor::unfold::unfold_1(&m.to_tensor());
        assert!(m.unfold1().rel_error(&x1) < 1e-5);
    }

    #[test]
    #[should_panic(expected = "rank mismatch")]
    fn rank_mismatch_rejected() {
        let _ = CpModel::new(Matrix::zeros(2, 2), Matrix::zeros(2, 3), Matrix::zeros(2, 3));
    }
}
