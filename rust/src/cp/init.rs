//! Factor initialization for ALS.
//!
//! Two strategies, matching the Table-I baselines (DESIGN.md
//! "Substitutions"): random normal (TensorLy's default) and HOSVD-style
//! leading eigenvectors of the unfolding Grams (the Matlab Tensor Toolbox
//! `'nvecs'` option).

use crate::linalg::backend::{ComputeBackend, SerialBackend};
use crate::linalg::eig::leading_eigvecs;
use crate::linalg::{Matrix, Trans};
use crate::tensor::unfold::{unfold_1, unfold_2, unfold_3};
use crate::tensor::DenseTensor;
use crate::util::rng::Xoshiro256;

/// Initialization strategy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitMethod {
    Random,
    Hosvd,
}

/// Random normal factors.
pub fn random_init(dims: [usize; 3], rank: usize, rng: &mut Xoshiro256) -> (Matrix, Matrix, Matrix) {
    (
        Matrix::random_normal(dims[0], rank, rng),
        Matrix::random_normal(dims[1], rank, rng),
        Matrix::random_normal(dims[2], rank, rng),
    )
}

/// HOSVD init: leading `rank` eigenvectors of `X_(n) X_(n)ᵀ` per mode.
/// If `rank > dim_n` the remaining columns are filled with random normals
/// (Tensor Toolbox behaviour).
pub fn hosvd_init(t: &DenseTensor, rank: usize, rng: &mut Xoshiro256) -> (Matrix, Matrix, Matrix) {
    let per_mode = |x: &Matrix, dim: usize, rng: &mut Xoshiro256| -> Matrix {
        let gram = SerialBackend.matmul(x, Trans::No, x, Trans::Yes);
        let v = leading_eigvecs(&gram, rank.min(dim));
        if v.cols() == rank {
            v
        } else {
            let extra = Matrix::random_normal(dim, rank - v.cols(), rng);
            let mut out = Matrix::zeros(dim, rank);
            out.set_block(0, 0, &v);
            out.set_block(0, v.cols(), &extra);
            out
        }
    };
    let [i, j, k] = t.dims();
    let a = per_mode(&unfold_1(t), i, rng);
    let b = per_mode(&unfold_2(t), j, rng);
    let c = per_mode(&unfold_3(t), k, rng);
    (a, b, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;

    #[test]
    fn random_init_shapes() {
        let mut rng = Xoshiro256::seed_from_u64(90);
        let (a, b, c) = random_init([4, 5, 6], 3, &mut rng);
        assert_eq!((a.rows(), a.cols()), (4, 3));
        assert_eq!((b.rows(), b.cols()), (5, 3));
        assert_eq!((c.rows(), c.cols()), (6, 3));
    }

    #[test]
    fn hosvd_init_spans_signal_subspace() {
        // For an exactly rank-2 tensor, the HOSVD factors must span the true
        // column space of each unfolding.
        let mut rng = Xoshiro256::seed_from_u64(91);
        let a_true = Matrix::random_normal(6, 2, &mut rng);
        let b_true = Matrix::random_normal(7, 2, &mut rng);
        let c_true = Matrix::random_normal(8, 2, &mut rng);
        let t = DenseTensor::from_cp_factors(&a_true, &b_true, &c_true);
        let (a0, _, _) = hosvd_init(&t, 2, &mut rng);
        // Project a_true onto span(a0): residual should vanish.
        let proj = matmul(&a0, Trans::No, &matmul(&a0, Trans::Yes, &a_true, Trans::No), Trans::No);
        assert!(proj.rel_error(&a_true) < 1e-3, "err={}", proj.rel_error(&a_true));
    }

    #[test]
    fn hosvd_init_pads_when_rank_exceeds_dim() {
        let mut rng = Xoshiro256::seed_from_u64(92);
        let t = DenseTensor::random_normal([2, 8, 8], &mut rng);
        let (a, _, _) = hosvd_init(&t, 5, &mut rng);
        assert_eq!((a.rows(), a.cols()), (2, 5));
        assert!(a.slice_cols(2, 5).max_abs() > 0.0); // padded columns nonzero
    }
}
