//! Alternating Least Squares CP decomposition — Algorithm 1 of the paper.
//!
//! This is the routine the compressed pipeline calls on each (small) proxy
//! tensor, and — run directly on the full tensor — the "Baseline (CPU)"
//! variant of every benchmark figure.
//!
//! Per sweep, for each mode:
//! `A ← X_(1)(C ⊙ B) · (CᵀC * BᵀB)⁻¹` (and cyclically for B, C), where the
//! MTTKRP `X_(1)(C ⊙ B)` is the hot spot and the Gram solve is a tiny `R×R`
//! ridge-damped Cholesky.

use super::init::{hosvd_init, random_init, InitMethod};
use super::model::CpModel;
use crate::linalg::backend::{ComputeBackend, SerialBackend};
use crate::linalg::{ridge_solve, Matrix};
use crate::tensor::unfold::{unfold_1, unfold_2, unfold_3};
use crate::tensor::{DenseTensor, SparseTensor};
use crate::util::rng::Xoshiro256;
use anyhow::Result;

/// ALS configuration.
#[derive(Clone, Debug)]
pub struct AlsOptions {
    pub rank: usize,
    pub max_iters: usize,
    /// Stop when the relative fit change between sweeps drops below this.
    pub tol: f64,
    pub init: InitMethod,
    pub seed: u64,
    /// Ridge damping for the Gram solves (0 disables).
    pub ridge: f32,
}

impl Default for AlsOptions {
    fn default() -> Self {
        Self {
            rank: 5,
            max_iters: 100,
            tol: 1e-8,
            init: InitMethod::Random,
            seed: 0,
            ridge: 1e-8,
        }
    }
}

/// Convergence trace: relative fit per sweep
/// (`fit = 1 − ‖X − X̂‖/‖X‖`, the Tensor-Toolbox convention).
#[derive(Clone, Debug, Default)]
pub struct AlsTrace {
    pub fits: Vec<f64>,
    pub iters: usize,
    pub converged: bool,
}

/// Dense direct ALS (Alg. 1) on the serial reference backend.
/// Returns the model and its trace.
pub fn als_decompose(t: &DenseTensor, opts: &AlsOptions) -> Result<(CpModel, AlsTrace)> {
    als_decompose_with(t, opts, &SerialBackend)
}

/// Dense direct ALS dispatching every MTTKRP/Gram through `backend` —
/// pass a [`crate::linalg::CpuParallelBackend`] to run the paper's
/// "Parallel on CPU" baseline arm.
pub fn als_decompose_with(
    t: &DenseTensor,
    opts: &AlsOptions,
    backend: &dyn ComputeBackend,
) -> Result<(CpModel, AlsTrace)> {
    let mut rng = Xoshiro256::seed_from_u64(opts.seed);
    let (a0, b0, c0) = match opts.init {
        InitMethod::Random => random_init(t.dims(), opts.rank, &mut rng),
        InitMethod::Hosvd => hosvd_init(t, opts.rank, &mut rng),
    };
    let x1 = unfold_1(t);
    let x2 = unfold_2(t);
    let x3 = unfold_3(t);
    let norm_x = t.frobenius_norm();

    let mut model = CpModel::new(a0, b0, c0);
    let mut trace = AlsTrace::default();
    let mut prev_fit = f64::NEG_INFINITY;

    for it in 0..opts.max_iters {
        // Mode 1: A ← X₁ (C⊙B) (CᵀC * BᵀB)⁻¹
        model.a = mode_update(&x1, 1, &model.c, &model.b, opts.ridge, backend)?;
        // Mode 2: B ← X₂ (C⊙A) (CᵀC * AᵀA)⁻¹
        model.b = mode_update(&x2, 2, &model.c, &model.a, opts.ridge, backend)?;
        // Mode 3: C ← X₃ (B⊙A) (BᵀB * AᵀA)⁻¹
        model.c = mode_update(&x3, 3, &model.b, &model.a, opts.ridge, backend)?;

        let fit = fit_dense(norm_x, &x1, &model, backend);
        trace.fits.push(fit);
        trace.iters = it + 1;
        if (fit - prev_fit).abs() < opts.tol && it > 0 {
            trace.converged = true;
            break;
        }
        prev_fit = fit;
    }
    Ok((model, trace))
}

/// One ALS mode update given the mode unfolding and the other two factors
/// (`slow ⊙ fast` ordering must match the unfolding convention).  The
/// MTTKRP — the sweep's hot spot, fused so the Khatri-Rao product is never
/// formed — and the Gram (`kr_gram`, Hadamard-of-Grams) both dispatch
/// through the backend: a whole normal equation without a `(J·K)×R` buffer.
fn mode_update(
    x_n: &Matrix,
    mode: usize,
    slow: &Matrix,
    fast: &Matrix,
    ridge: f32,
    backend: &dyn ComputeBackend,
) -> Result<Matrix> {
    let mttkrp = backend.mttkrp(mode, x_n, slow, fast);
    let gram = backend.kr_gram(slow, fast);
    // Solve gram · Fᵀ = mttkrpᵀ  ⇒  F = mttkrp · gram⁻¹ (gram symmetric).
    let sol = ridge_solve(&gram, &mttkrp.transpose(), ridge)?;
    Ok(sol.transpose())
}

/// Relative fit `1 − ‖X − X̂‖/‖X‖` computed without forming `X̂`:
/// `‖X − X̂‖² = ‖X‖² − 2⟨X₁, Â(C⊙B)ᵀ⟩ + ‖X̂‖²`, with the inner product as a
/// trace of small matrices.
fn fit_dense(norm_x: f64, x1: &Matrix, model: &CpModel, backend: &dyn ComputeBackend) -> f64 {
    // ⟨X₁, A·KRᵀ⟩ = Tr(Aᵀ·X₁·KR) — the X₁·KR product is itself an MTTKRP.
    let x1kr = backend.mttkrp(1, x1, &model.c, &model.b); // I×R
    let mut inner = 0.0f64;
    for r in 0..model.rank() {
        for i in 0..model.a.rows() {
            inner += model.a.get(i, r) as f64 * x1kr.get(i, r) as f64;
        }
    }
    let resid_sq = (norm_x * norm_x - 2.0 * inner + model.norm_sq()).max(0.0);
    1.0 - resid_sq.sqrt() / norm_x.max(1e-300)
}

/// One item of a coalesced ALS sweep: the (small) tensor plus its init
/// seed.  Rank / iteration budget / tolerance are shared across the batch
/// (the `opts` argument of [`als_batch`]) — that is the batch lane's
/// compatibility contract; only the seed varies per item.
pub struct AlsBatchItem<'a> {
    pub tensor: &'a DenseTensor,
    pub seed: u64,
}

/// ALS iterations per lockstep round of a batched sweep.  Coarse on
/// purpose: each round costs one backend fan-out (one pool-scope thread
/// residency for the *whole batch*), so a handful of iterations per round
/// amortizes the wake-up while the per-item convergence mask still retires
/// early-converged items within a round of their convergence sweep.
const BATCH_ROUND_ITERS: usize = 8;

/// Coalesced dense ALS over many small tensors — the batch lane's driver.
///
/// Every item runs **exactly** the solo sequence of
/// [`als_decompose_with`]`(t, {seed: item.seed, ..opts}, &SerialBackend)`:
/// same init draws, same per-sweep kernel calls in the same order, same
/// convergence test.  The batching is purely *where* the items run — the
/// `sweep` backend's [`ComputeBackend::for_each_item`] fans the
/// independent items across one shared pool residency per round
/// (`gemm_batch`-style dispatch, with each worker's thread-local
/// `PackArena` reused across every item it picks up) instead of each job
/// paying its own thread-pool wake-up and cold pack buffers.  Because the
/// per-item operation sequence is untouched, each returned model and trace
/// is bitwise identical to the solo run.
///
/// Items carry a per-item convergence mask: an item that converges (or
/// errors) drops out of subsequent rounds without stalling the rest of the
/// sweep.  Per-item errors come back as that item's `Err`; they do not
/// poison the batch.
pub fn als_batch(
    items: &[AlsBatchItem<'_>],
    opts: &AlsOptions,
    sweep: &dyn ComputeBackend,
) -> Vec<Result<(CpModel, AlsTrace)>> {
    use std::sync::Mutex;
    struct ItemState {
        x1: Matrix,
        x2: Matrix,
        x3: Matrix,
        norm_x: f64,
        model: CpModel,
        trace: AlsTrace,
        prev_fit: f64,
        done: bool,
        error: Option<anyhow::Error>,
    }
    // One slot per item; each fan-out closure touches only its own slot,
    // so the mutexes are uncontended — they exist to carry `&mut` state
    // through the `Fn(usize)` fan-out surface.
    let states: Vec<Mutex<Option<ItemState>>> =
        items.iter().map(|_| Mutex::new(None)).collect();

    // Init round: seeds, factor draws, unfoldings, norms — identical to
    // the solo prologue, fanned out like everything else.
    sweep.for_each_item(items.len(), &|i| {
        let item = &items[i];
        let mut rng = Xoshiro256::seed_from_u64(item.seed);
        let (a0, b0, c0) = match opts.init {
            InitMethod::Random => random_init(item.tensor.dims(), opts.rank, &mut rng),
            InitMethod::Hosvd => hosvd_init(item.tensor, opts.rank, &mut rng),
        };
        *states[i].lock().unwrap() = Some(ItemState {
            x1: unfold_1(item.tensor),
            x2: unfold_2(item.tensor),
            x3: unfold_3(item.tensor),
            norm_x: item.tensor.frobenius_norm(),
            model: CpModel::new(a0, b0, c0),
            trace: AlsTrace::default(),
            prev_fit: f64::NEG_INFINITY,
            done: false,
            error: None,
        });
    });

    // Lockstep rounds over the still-active mask.
    loop {
        let active: Vec<usize> = states
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.lock().unwrap().as_ref().unwrap().done)
            .map(|(i, _)| i)
            .collect();
        if active.is_empty() {
            break;
        }
        sweep.for_each_item(active.len(), &|k| {
            let mut guard = states[active[k]].lock().unwrap();
            let st = guard.as_mut().unwrap();
            for _ in 0..BATCH_ROUND_ITERS {
                let it = st.trace.fits.len();
                if it >= opts.max_iters {
                    st.done = true;
                    break;
                }
                // Item kernels stay on the serial reference — the exact
                // engine the solo path runs each small decomposition on —
                // so batching changes no operand, order, or rounding.
                let step = (|| -> Result<()> {
                    st.model.a =
                        mode_update(&st.x1, 1, &st.model.c, &st.model.b, opts.ridge, &SerialBackend)?;
                    st.model.b =
                        mode_update(&st.x2, 2, &st.model.c, &st.model.a, opts.ridge, &SerialBackend)?;
                    st.model.c =
                        mode_update(&st.x3, 3, &st.model.b, &st.model.a, opts.ridge, &SerialBackend)?;
                    Ok(())
                })();
                if let Err(e) = step {
                    st.error = Some(e);
                    st.done = true;
                    break;
                }
                let fit = fit_dense(st.norm_x, &st.x1, &st.model, &SerialBackend);
                st.trace.fits.push(fit);
                st.trace.iters = it + 1;
                if (fit - st.prev_fit).abs() < opts.tol && it > 0 {
                    st.trace.converged = true;
                    st.done = true;
                    break;
                }
                st.prev_fit = fit;
            }
        });
    }

    states
        .into_iter()
        .map(|m| {
            let st = m.into_inner().unwrap().unwrap();
            match st.error {
                Some(e) => Err(e),
                None => Ok((st.model, st.trace)),
            }
        })
        .collect()
}

/// Sparse direct ALS on the serial reference backend.
pub fn als_decompose_sparse(t: &SparseTensor, opts: &AlsOptions) -> Result<(CpModel, AlsTrace)> {
    als_decompose_sparse_with(t, opts, &SerialBackend)
}

/// Sparse direct ALS: same sweep structure with sparse MTTKRP (an
/// `O(nnz·R)` scatter that stays outside [`ComputeBackend`]); the Gram
/// solves dispatch through `backend`.
pub fn als_decompose_sparse_with(
    t: &SparseTensor,
    opts: &AlsOptions,
    backend: &dyn ComputeBackend,
) -> Result<(CpModel, AlsTrace)> {
    let mut rng = Xoshiro256::seed_from_u64(opts.seed);
    let (a0, b0, c0) = random_init(t.dims(), opts.rank, &mut rng);
    let norm_x = t.frobenius_norm();

    let mut model = CpModel::new(a0, b0, c0);
    let mut trace = AlsTrace::default();
    let mut prev_fit = f64::NEG_INFINITY;

    for it in 0..opts.max_iters {
        let m1 = t.mttkrp(1, &model.b, &model.c);
        model.a = gram_solve(&m1, &model.c, &model.b, opts.ridge, backend)?;
        let m2 = t.mttkrp(2, &model.a, &model.c);
        model.b = gram_solve(&m2, &model.c, &model.a, opts.ridge, backend)?;
        let m3 = t.mttkrp(3, &model.a, &model.b);
        model.c = gram_solve(&m3, &model.b, &model.a, opts.ridge, backend)?;

        let resid_sq = t.residual_sq(&model.a, &model.b, &model.c);
        let fit = 1.0 - resid_sq.sqrt() / norm_x.max(1e-300);
        trace.fits.push(fit);
        trace.iters = it + 1;
        if (fit - prev_fit).abs() < opts.tol && it > 0 {
            trace.converged = true;
            break;
        }
        prev_fit = fit;
    }
    Ok((model, trace))
}

fn gram_solve(
    mttkrp: &Matrix,
    g1: &Matrix,
    g2: &Matrix,
    ridge: f32,
    backend: &dyn ComputeBackend,
) -> Result<Matrix> {
    let gram = backend.kr_gram(g1, g2);
    let sol = ridge_solve(&gram, &mttkrp.transpose(), ridge)?;
    Ok(sol.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planted(dims: [usize; 3], rank: usize, seed: u64) -> (DenseTensor, CpModel) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let m = CpModel::new(
            Matrix::random_normal(dims[0], rank, &mut rng),
            Matrix::random_normal(dims[1], rank, &mut rng),
            Matrix::random_normal(dims[2], rank, &mut rng),
        );
        (m.to_tensor(), m)
    }

    #[test]
    fn recovers_exact_low_rank() {
        let (t, _) = planted([12, 11, 10], 3, 100);
        let (model, trace) = als_decompose(
            &t,
            &AlsOptions {
                rank: 3,
                max_iters: 200,
                tol: 1e-12,
                ..Default::default()
            },
        )
        .unwrap();
        let rec = model.to_tensor();
        let err = rec.rel_error(&t);
        assert!(err < 1e-3, "rel error {err}, fits {:?}", trace.fits.last());
        assert!(trace.fits.last().unwrap() > &0.999);
    }

    #[test]
    fn fit_is_monotone_ish() {
        let (t, _) = planted([10, 10, 10], 2, 101);
        let (_, trace) = als_decompose(
            &t,
            &AlsOptions {
                rank: 2,
                max_iters: 30,
                tol: 0.0,
                ..Default::default()
            },
        )
        .unwrap();
        // ALS is monotone in exact arithmetic; the fit *estimator* has an
        // f32 cancellation noise floor (~3e-4 near fit=1), so allow that.
        for w in trace.fits.windows(2) {
            assert!(w[1] > w[0] - 1e-3, "fit decreased: {:?}", trace.fits);
        }
    }

    #[test]
    fn hosvd_init_converges_faster_or_equal() {
        let (t, _) = planted([14, 14, 14], 3, 102);
        let opts_r = AlsOptions {
            rank: 3,
            max_iters: 50,
            tol: 1e-10,
            init: InitMethod::Random,
            ..Default::default()
        };
        let opts_h = AlsOptions {
            init: InitMethod::Hosvd,
            ..opts_r.clone()
        };
        let (_, tr) = als_decompose(&t, &opts_r).unwrap();
        let (_, th) = als_decompose(&t, &opts_h).unwrap();
        // HOSVD should reach convergence in no more sweeps (usually fewer).
        assert!(th.iters <= tr.iters + 2, "hosvd {} vs random {}", th.iters, tr.iters);
    }

    #[test]
    fn noisy_tensor_fit_reasonable() {
        let mut rng = Xoshiro256::seed_from_u64(103);
        let (clean, _) = planted([10, 10, 10], 2, 104);
        let mut noisy = clean.clone();
        for x in noisy.data_mut() {
            *x += 0.01 * rng.next_gaussian() as f32;
        }
        let (model, _) = als_decompose(
            &noisy,
            &AlsOptions {
                rank: 2,
                max_iters: 60,
                ..Default::default()
            },
        )
        .unwrap();
        // Should denoise towards the clean tensor.
        assert!(model.to_tensor().rel_error(&clean) < 0.02);
    }

    #[test]
    fn sparse_als_recovers_sparse_planted() {
        // Sparse factors (few nonzeros per column) → sparse tensor.
        let gen = crate::tensor::SparseLowRankGenerator::new(20, 20, 20, 2, 4, 105);
        let (a, b, c) = gen.factors();
        let dense = DenseTensor::from_cp_factors(a, b, c);
        let sparse = SparseTensor::from_dense(&dense, 0.0);
        let (model, trace) = als_decompose_sparse(
            &sparse,
            &AlsOptions {
                rank: 2,
                max_iters: 200,
                tol: 1e-12,
                seed: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let err = model.to_tensor().rel_error(&dense);
        assert!(err < 1e-2, "err {err}, fit {:?}", trace.fits.last());
    }

    #[test]
    fn parallel_backend_reaches_same_solution() {
        use crate::linalg::CpuParallelBackend;
        let (t, _) = planted([14, 12, 10], 2, 120);
        let opts = AlsOptions {
            rank: 2,
            max_iters: 120,
            tol: 1e-12,
            ..Default::default()
        };
        let (m_ser, _) = als_decompose(&t, &opts).unwrap();
        let be = CpuParallelBackend::new(4).with_min_par_flops(0);
        let (m_par, _) = als_decompose_with(&t, &opts, &be).unwrap();
        assert!(m_ser.to_tensor().rel_error(&t) < 1e-3);
        assert!(m_par.to_tensor().rel_error(&t) < 1e-3);
    }

    #[test]
    fn batch_matches_solo_bitwise_across_sizes() {
        use crate::linalg::CpuParallelBackend;
        // Mixed difficulty on purpose: even items are exact low-rank
        // (converge early), odd items carry noise (run longer) — the
        // convergence mask must retire the early finishers without
        // perturbing anyone else's floats.
        for &n in &[1usize, 3, 16] {
            let tensors: Vec<DenseTensor> = (0..n)
                .map(|i| {
                    let (mut t, _) = planted([8, 7, 6], 2, 200 + i as u64);
                    if i % 2 == 1 {
                        let mut rng = Xoshiro256::seed_from_u64(300 + i as u64);
                        for x in t.data_mut() {
                            *x += 0.05 * rng.next_gaussian() as f32;
                        }
                    }
                    t
                })
                .collect();
            let opts = AlsOptions {
                rank: 2,
                max_iters: 40,
                tol: 1e-9,
                ..Default::default()
            };
            let items: Vec<AlsBatchItem<'_>> = tensors
                .iter()
                .enumerate()
                .map(|(i, t)| AlsBatchItem { tensor: t, seed: 77 + i as u64 })
                .collect();
            let pool_sweep = CpuParallelBackend::new(4).with_min_par_flops(0);
            let batched = als_batch(&items, &opts, &pool_sweep);
            let serial_sweep = als_batch(&items, &opts, &SerialBackend);
            for (i, t) in tensors.iter().enumerate() {
                let (solo_m, solo_tr) = als_decompose_with(
                    t,
                    &AlsOptions { seed: 77 + i as u64, ..opts.clone() },
                    &SerialBackend,
                )
                .unwrap();
                for arm in [&batched[i], &serial_sweep[i]] {
                    let (m, tr) = arm.as_ref().unwrap();
                    assert_eq!(m.a, solo_m.a, "n={n} item {i}: factor A must be bitwise solo");
                    assert_eq!(m.b, solo_m.b, "n={n} item {i}: factor B must be bitwise solo");
                    assert_eq!(m.c, solo_m.c, "n={n} item {i}: factor C must be bitwise solo");
                    assert_eq!(tr.iters, solo_tr.iters, "n={n} item {i}");
                    assert_eq!(tr.converged, solo_tr.converged, "n={n} item {i}");
                    assert_eq!(tr.fits, solo_tr.fits, "n={n} item {i}");
                }
            }
            // The mix really does finish at different sweeps (the mask ran).
            if n >= 3 {
                let iters: Vec<usize> = batched
                    .iter()
                    .map(|r| r.as_ref().unwrap().1.iters)
                    .collect();
                assert!(
                    iters.iter().min() < iters.iter().max(),
                    "expected mixed convergence, got {iters:?}"
                );
            }
        }
    }

    #[test]
    fn rank_one_trivial() {
        let (t, _) = planted([5, 5, 5], 1, 106);
        let (model, _) = als_decompose(
            &t,
            &AlsOptions {
                rank: 1,
                max_iters: 50,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(model.to_tensor().rel_error(&t) < 1e-3);
    }

    #[test]
    fn overparameterized_rank_still_fits() {
        let (t, _) = planted([8, 8, 8], 2, 107);
        let (model, _) = als_decompose(
            &t,
            &AlsOptions {
                rank: 4, // more than true rank
                max_iters: 80,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(model.to_tensor().rel_error(&t) < 1e-2);
    }
}
