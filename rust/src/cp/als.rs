//! Alternating Least Squares CP decomposition — Algorithm 1 of the paper.
//!
//! This is the routine the compressed pipeline calls on each (small) proxy
//! tensor, and — run directly on the full tensor — the "Baseline (CPU)"
//! variant of every benchmark figure.
//!
//! Per sweep, for each mode:
//! `A ← X_(1)(C ⊙ B) · (CᵀC * BᵀB)⁻¹` (and cyclically for B, C), where the
//! MTTKRP `X_(1)(C ⊙ B)` is the hot spot and the Gram solve is a tiny `R×R`
//! ridge-damped Cholesky.

use super::init::{hosvd_init, random_init, InitMethod};
use super::model::CpModel;
use crate::linalg::backend::{ComputeBackend, SerialBackend};
use crate::linalg::{ridge_solve, Matrix};
use crate::tensor::unfold::{unfold_1, unfold_2, unfold_3};
use crate::tensor::{DenseTensor, SparseTensor};
use crate::util::rng::Xoshiro256;
use anyhow::Result;

/// ALS configuration.
#[derive(Clone, Debug)]
pub struct AlsOptions {
    pub rank: usize,
    pub max_iters: usize,
    /// Stop when the relative fit change between sweeps drops below this.
    pub tol: f64,
    pub init: InitMethod,
    pub seed: u64,
    /// Ridge damping for the Gram solves (0 disables).
    pub ridge: f32,
}

impl Default for AlsOptions {
    fn default() -> Self {
        Self {
            rank: 5,
            max_iters: 100,
            tol: 1e-8,
            init: InitMethod::Random,
            seed: 0,
            ridge: 1e-8,
        }
    }
}

/// Convergence trace: relative fit per sweep
/// (`fit = 1 − ‖X − X̂‖/‖X‖`, the Tensor-Toolbox convention).
#[derive(Clone, Debug, Default)]
pub struct AlsTrace {
    pub fits: Vec<f64>,
    pub iters: usize,
    pub converged: bool,
}

/// Dense direct ALS (Alg. 1) on the serial reference backend.
/// Returns the model and its trace.
pub fn als_decompose(t: &DenseTensor, opts: &AlsOptions) -> Result<(CpModel, AlsTrace)> {
    als_decompose_with(t, opts, &SerialBackend)
}

/// Dense direct ALS dispatching every MTTKRP/Gram through `backend` —
/// pass a [`crate::linalg::CpuParallelBackend`] to run the paper's
/// "Parallel on CPU" baseline arm.
pub fn als_decompose_with(
    t: &DenseTensor,
    opts: &AlsOptions,
    backend: &dyn ComputeBackend,
) -> Result<(CpModel, AlsTrace)> {
    let mut rng = Xoshiro256::seed_from_u64(opts.seed);
    let (a0, b0, c0) = match opts.init {
        InitMethod::Random => random_init(t.dims(), opts.rank, &mut rng),
        InitMethod::Hosvd => hosvd_init(t, opts.rank, &mut rng),
    };
    let x1 = unfold_1(t);
    let x2 = unfold_2(t);
    let x3 = unfold_3(t);
    let norm_x = t.frobenius_norm();

    let mut model = CpModel::new(a0, b0, c0);
    let mut trace = AlsTrace::default();
    let mut prev_fit = f64::NEG_INFINITY;

    for it in 0..opts.max_iters {
        // Mode 1: A ← X₁ (C⊙B) (CᵀC * BᵀB)⁻¹
        model.a = mode_update(&x1, 1, &model.c, &model.b, opts.ridge, backend)?;
        // Mode 2: B ← X₂ (C⊙A) (CᵀC * AᵀA)⁻¹
        model.b = mode_update(&x2, 2, &model.c, &model.a, opts.ridge, backend)?;
        // Mode 3: C ← X₃ (B⊙A) (BᵀB * AᵀA)⁻¹
        model.c = mode_update(&x3, 3, &model.b, &model.a, opts.ridge, backend)?;

        let fit = fit_dense(norm_x, &x1, &model, backend);
        trace.fits.push(fit);
        trace.iters = it + 1;
        if (fit - prev_fit).abs() < opts.tol && it > 0 {
            trace.converged = true;
            break;
        }
        prev_fit = fit;
    }
    Ok((model, trace))
}

/// One ALS mode update given the mode unfolding and the other two factors
/// (`slow ⊙ fast` ordering must match the unfolding convention).  The
/// MTTKRP — the sweep's hot spot, fused so the Khatri-Rao product is never
/// formed — and the Gram (`kr_gram`, Hadamard-of-Grams) both dispatch
/// through the backend: a whole normal equation without a `(J·K)×R` buffer.
fn mode_update(
    x_n: &Matrix,
    mode: usize,
    slow: &Matrix,
    fast: &Matrix,
    ridge: f32,
    backend: &dyn ComputeBackend,
) -> Result<Matrix> {
    let mttkrp = backend.mttkrp(mode, x_n, slow, fast);
    let gram = backend.kr_gram(slow, fast);
    // Solve gram · Fᵀ = mttkrpᵀ  ⇒  F = mttkrp · gram⁻¹ (gram symmetric).
    let sol = ridge_solve(&gram, &mttkrp.transpose(), ridge)?;
    Ok(sol.transpose())
}

/// Relative fit `1 − ‖X − X̂‖/‖X‖` computed without forming `X̂`:
/// `‖X − X̂‖² = ‖X‖² − 2⟨X₁, Â(C⊙B)ᵀ⟩ + ‖X̂‖²`, with the inner product as a
/// trace of small matrices.
fn fit_dense(norm_x: f64, x1: &Matrix, model: &CpModel, backend: &dyn ComputeBackend) -> f64 {
    // ⟨X₁, A·KRᵀ⟩ = Tr(Aᵀ·X₁·KR) — the X₁·KR product is itself an MTTKRP.
    let x1kr = backend.mttkrp(1, x1, &model.c, &model.b); // I×R
    let mut inner = 0.0f64;
    for r in 0..model.rank() {
        for i in 0..model.a.rows() {
            inner += model.a.get(i, r) as f64 * x1kr.get(i, r) as f64;
        }
    }
    let resid_sq = (norm_x * norm_x - 2.0 * inner + model.norm_sq()).max(0.0);
    1.0 - resid_sq.sqrt() / norm_x.max(1e-300)
}

/// Sparse direct ALS on the serial reference backend.
pub fn als_decompose_sparse(t: &SparseTensor, opts: &AlsOptions) -> Result<(CpModel, AlsTrace)> {
    als_decompose_sparse_with(t, opts, &SerialBackend)
}

/// Sparse direct ALS: same sweep structure with sparse MTTKRP (an
/// `O(nnz·R)` scatter that stays outside [`ComputeBackend`]); the Gram
/// solves dispatch through `backend`.
pub fn als_decompose_sparse_with(
    t: &SparseTensor,
    opts: &AlsOptions,
    backend: &dyn ComputeBackend,
) -> Result<(CpModel, AlsTrace)> {
    let mut rng = Xoshiro256::seed_from_u64(opts.seed);
    let (a0, b0, c0) = random_init(t.dims(), opts.rank, &mut rng);
    let norm_x = t.frobenius_norm();

    let mut model = CpModel::new(a0, b0, c0);
    let mut trace = AlsTrace::default();
    let mut prev_fit = f64::NEG_INFINITY;

    for it in 0..opts.max_iters {
        let m1 = t.mttkrp(1, &model.b, &model.c);
        model.a = gram_solve(&m1, &model.c, &model.b, opts.ridge, backend)?;
        let m2 = t.mttkrp(2, &model.a, &model.c);
        model.b = gram_solve(&m2, &model.c, &model.a, opts.ridge, backend)?;
        let m3 = t.mttkrp(3, &model.a, &model.b);
        model.c = gram_solve(&m3, &model.b, &model.a, opts.ridge, backend)?;

        let resid_sq = t.residual_sq(&model.a, &model.b, &model.c);
        let fit = 1.0 - resid_sq.sqrt() / norm_x.max(1e-300);
        trace.fits.push(fit);
        trace.iters = it + 1;
        if (fit - prev_fit).abs() < opts.tol && it > 0 {
            trace.converged = true;
            break;
        }
        prev_fit = fit;
    }
    Ok((model, trace))
}

fn gram_solve(
    mttkrp: &Matrix,
    g1: &Matrix,
    g2: &Matrix,
    ridge: f32,
    backend: &dyn ComputeBackend,
) -> Result<Matrix> {
    let gram = backend.kr_gram(g1, g2);
    let sol = ridge_solve(&gram, &mttkrp.transpose(), ridge)?;
    Ok(sol.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planted(dims: [usize; 3], rank: usize, seed: u64) -> (DenseTensor, CpModel) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let m = CpModel::new(
            Matrix::random_normal(dims[0], rank, &mut rng),
            Matrix::random_normal(dims[1], rank, &mut rng),
            Matrix::random_normal(dims[2], rank, &mut rng),
        );
        (m.to_tensor(), m)
    }

    #[test]
    fn recovers_exact_low_rank() {
        let (t, _) = planted([12, 11, 10], 3, 100);
        let (model, trace) = als_decompose(
            &t,
            &AlsOptions {
                rank: 3,
                max_iters: 200,
                tol: 1e-12,
                ..Default::default()
            },
        )
        .unwrap();
        let rec = model.to_tensor();
        let err = rec.rel_error(&t);
        assert!(err < 1e-3, "rel error {err}, fits {:?}", trace.fits.last());
        assert!(trace.fits.last().unwrap() > &0.999);
    }

    #[test]
    fn fit_is_monotone_ish() {
        let (t, _) = planted([10, 10, 10], 2, 101);
        let (_, trace) = als_decompose(
            &t,
            &AlsOptions {
                rank: 2,
                max_iters: 30,
                tol: 0.0,
                ..Default::default()
            },
        )
        .unwrap();
        // ALS is monotone in exact arithmetic; the fit *estimator* has an
        // f32 cancellation noise floor (~3e-4 near fit=1), so allow that.
        for w in trace.fits.windows(2) {
            assert!(w[1] > w[0] - 1e-3, "fit decreased: {:?}", trace.fits);
        }
    }

    #[test]
    fn hosvd_init_converges_faster_or_equal() {
        let (t, _) = planted([14, 14, 14], 3, 102);
        let opts_r = AlsOptions {
            rank: 3,
            max_iters: 50,
            tol: 1e-10,
            init: InitMethod::Random,
            ..Default::default()
        };
        let opts_h = AlsOptions {
            init: InitMethod::Hosvd,
            ..opts_r.clone()
        };
        let (_, tr) = als_decompose(&t, &opts_r).unwrap();
        let (_, th) = als_decompose(&t, &opts_h).unwrap();
        // HOSVD should reach convergence in no more sweeps (usually fewer).
        assert!(th.iters <= tr.iters + 2, "hosvd {} vs random {}", th.iters, tr.iters);
    }

    #[test]
    fn noisy_tensor_fit_reasonable() {
        let mut rng = Xoshiro256::seed_from_u64(103);
        let (clean, _) = planted([10, 10, 10], 2, 104);
        let mut noisy = clean.clone();
        for x in noisy.data_mut() {
            *x += 0.01 * rng.next_gaussian() as f32;
        }
        let (model, _) = als_decompose(
            &noisy,
            &AlsOptions {
                rank: 2,
                max_iters: 60,
                ..Default::default()
            },
        )
        .unwrap();
        // Should denoise towards the clean tensor.
        assert!(model.to_tensor().rel_error(&clean) < 0.02);
    }

    #[test]
    fn sparse_als_recovers_sparse_planted() {
        // Sparse factors (few nonzeros per column) → sparse tensor.
        let gen = crate::tensor::SparseLowRankGenerator::new(20, 20, 20, 2, 4, 105);
        let (a, b, c) = gen.factors();
        let dense = DenseTensor::from_cp_factors(a, b, c);
        let sparse = SparseTensor::from_dense(&dense, 0.0);
        let (model, trace) = als_decompose_sparse(
            &sparse,
            &AlsOptions {
                rank: 2,
                max_iters: 200,
                tol: 1e-12,
                seed: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let err = model.to_tensor().rel_error(&dense);
        assert!(err < 1e-2, "err {err}, fit {:?}", trace.fits.last());
    }

    #[test]
    fn parallel_backend_reaches_same_solution() {
        use crate::linalg::CpuParallelBackend;
        let (t, _) = planted([14, 12, 10], 2, 120);
        let opts = AlsOptions {
            rank: 2,
            max_iters: 120,
            tol: 1e-12,
            ..Default::default()
        };
        let (m_ser, _) = als_decompose(&t, &opts).unwrap();
        let be = CpuParallelBackend::new(4).with_min_par_flops(0);
        let (m_par, _) = als_decompose_with(&t, &opts, &be).unwrap();
        assert!(m_ser.to_tensor().rel_error(&t) < 1e-3);
        assert!(m_par.to_tensor().rel_error(&t) < 1e-3);
    }

    #[test]
    fn rank_one_trivial() {
        let (t, _) = planted([5, 5, 5], 1, 106);
        let (model, _) = als_decompose(
            &t,
            &AlsOptions {
                rank: 1,
                max_iters: 50,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(model.to_tensor().rel_error(&t) < 1e-3);
    }

    #[test]
    fn overparameterized_rank_still_fits() {
        let (t, _) = planted([8, 8, 8], 2, 107);
        let (model, _) = als_decompose(
            &t,
            &AlsOptions {
                rank: 4, // more than true rank
                max_iters: 80,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(model.to_tensor().rel_error(&t) < 1e-2);
    }
}
