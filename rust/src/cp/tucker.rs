//! Tucker decomposition (HOSVD + HOOI) — comparison baseline.
//!
//! The paper positions CP against compression alternatives; Tucker is the
//! natural orthogonal-compression baseline (PARACOMP itself builds on the
//! idea that random maps stand in for Tucker bases).  We implement
//! truncated HOSVD with optional HOOI refinement, used by the ablation
//! bench to compare reconstruction-per-parameter against CP.

use crate::compress::comp_dense;
use crate::linalg::svd::leading_singular_vectors;
use crate::mixed::MixedPrecision;
use crate::tensor::unfold::{unfold_1, unfold_2, unfold_3};
use crate::tensor::DenseTensor;
use anyhow::Result;

/// A Tucker model: core `G (r1×r2×r3)` and orthonormal factors
/// `U1 (I×r1)`, `U2 (J×r2)`, `U3 (K×r3)`.
#[derive(Clone, Debug)]
pub struct TuckerModel {
    pub core: DenseTensor,
    pub u1: crate::linalg::Matrix,
    pub u2: crate::linalg::Matrix,
    pub u3: crate::linalg::Matrix,
}

impl TuckerModel {
    /// Reconstructs the full tensor `G ×₁U1 ×₂U2 ×₃U3`.
    pub fn to_tensor(&self) -> DenseTensor {
        // comp_dense computes X ×ₙ Mₙ with Mₙ (rows×cols) contracting the
        // tensor's mode-n dim against Mₙ's columns, so pass the factors
        // directly (I×r → need r columns: use transpose convention).
        comp_dense(
            &self.core,
            &self.u1,
            &self.u2,
            &self.u3,
            MixedPrecision::Full,
        )
    }

    /// Parameter count (core + factors).
    pub fn params(&self) -> usize {
        let [r1, r2, r3] = self.core.dims();
        r1 * r2 * r3
            + self.u1.rows() * r1
            + self.u2.rows() * r2
            + self.u3.rows() * r3
    }
}

/// Truncated HOSVD: factors = leading singular vectors of each unfolding;
/// core = `X ×₁U1ᵀ ×₂U2ᵀ ×₃U3ᵀ`.
pub fn hosvd(t: &DenseTensor, ranks: [usize; 3]) -> TuckerModel {
    let u1 = leading_singular_vectors(&unfold_1(t), ranks[0]);
    let u2 = leading_singular_vectors(&unfold_2(t), ranks[1]);
    let u3 = leading_singular_vectors(&unfold_3(t), ranks[2]);
    let core = comp_dense(
        t,
        &u1.transpose(),
        &u2.transpose(),
        &u3.transpose(),
        MixedPrecision::Full,
    );
    TuckerModel { core, u1, u2, u3 }
}

/// HOOI refinement: alternating re-estimation of each factor from the
/// partially projected tensor.  A few iterations suffice.
pub fn hooi(t: &DenseTensor, ranks: [usize; 3], iters: usize) -> Result<TuckerModel> {
    let mut model = hosvd(t, ranks);
    for _ in 0..iters {
        // U1 from X ×₂U2ᵀ ×₃U3ᵀ.
        let y1 = comp_dense(
            t,
            &crate::linalg::Matrix::identity(t.dims()[0]),
            &model.u2.transpose(),
            &model.u3.transpose(),
            MixedPrecision::Full,
        );
        model.u1 = leading_singular_vectors(&unfold_1(&y1), ranks[0]);
        let y2 = comp_dense(
            t,
            &model.u1.transpose(),
            &crate::linalg::Matrix::identity(t.dims()[1]),
            &model.u3.transpose(),
            MixedPrecision::Full,
        );
        model.u2 = leading_singular_vectors(&unfold_2(&y2), ranks[1]);
        let y3 = comp_dense(
            t,
            &model.u1.transpose(),
            &model.u2.transpose(),
            &crate::linalg::Matrix::identity(t.dims()[2]),
            MixedPrecision::Full,
        );
        model.u3 = leading_singular_vectors(&unfold_3(&y3), ranks[2]);
    }
    model.core = comp_dense(
        t,
        &model.u1.transpose(),
        &model.u2.transpose(),
        &model.u3.transpose(),
        MixedPrecision::Full,
    );
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Xoshiro256;

    fn low_tucker_tensor(seed: u64) -> DenseTensor {
        // Random core 2×3×2 expanded to 10×9×8: exactly Tucker-(2,3,2).
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let core = DenseTensor::random_normal([2, 3, 2], &mut rng);
        let u1 = Matrix::random_normal(10, 2, &mut rng);
        let u2 = Matrix::random_normal(9, 3, &mut rng);
        let u3 = Matrix::random_normal(8, 2, &mut rng);
        comp_dense(&core, &u1, &u2, &u3, MixedPrecision::Full)
    }

    #[test]
    fn hosvd_exact_for_exact_rank() {
        let t = low_tucker_tensor(710);
        let model = hosvd(&t, [2, 3, 2]);
        let rec = model.to_tensor();
        assert!(rec.rel_error(&t) < 1e-4, "err {}", rec.rel_error(&t));
        assert_eq!(model.core.dims(), [2, 3, 2]);
    }

    #[test]
    fn hooi_improves_or_matches_hosvd_truncated() {
        // Full-rank random tensor, aggressive truncation: HOOI ≤ HOSVD err.
        let mut rng = Xoshiro256::seed_from_u64(711);
        let t = DenseTensor::random_normal([10, 10, 10], &mut rng);
        let h = hosvd(&t, [4, 4, 4]);
        let err_hosvd = h.to_tensor().rel_error(&t);
        let ho = hooi(&t, [4, 4, 4], 3).unwrap();
        let err_hooi = ho.to_tensor().rel_error(&t);
        assert!(err_hooi <= err_hosvd + 1e-4, "hooi {err_hooi} vs hosvd {err_hosvd}");
    }

    #[test]
    fn factors_orthonormal() {
        let t = low_tucker_tensor(712);
        let model = hosvd(&t, [2, 3, 2]);
        use crate::linalg::{matmul, Trans};
        for (u, r) in [(&model.u1, 2), (&model.u2, 3), (&model.u3, 2)] {
            let g = matmul(u, Trans::Yes, u, Trans::No);
            assert!(g.rel_error(&Matrix::identity(r)) < 1e-4);
        }
    }

    #[test]
    fn params_counting() {
        let t = low_tucker_tensor(713);
        let model = hosvd(&t, [2, 3, 2]);
        assert_eq!(model.params(), 12 + 20 + 27 + 16);
    }
}
