//! CP decomposition core: the direct ALS algorithm (Alg. 1 of the paper,
//! the "Baseline (CPU)" of every benchmark), factor initialization, model
//! types, and error/congruence diagnostics.

pub mod als;
pub mod error;
pub mod init;
pub mod model;
pub mod tucker;

pub use als::{
    als_batch, als_decompose, als_decompose_sparse, als_decompose_sparse_with,
    als_decompose_with, AlsBatchItem, AlsOptions, AlsTrace,
};
pub use error::{factor_congruence, model_congruence, sampled_mse, SampledError};
pub use init::{hosvd_init, random_init, InitMethod};
pub use model::CpModel;
pub use tucker::{hooi, hosvd, TuckerModel};
