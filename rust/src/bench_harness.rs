//! Micro/macro benchmark harness (offline substitute for `criterion`).
//!
//! Each `rust/benches/*.rs` binary (built with `harness = false`) uses this
//! to run warmups + timed iterations, print a markdown table matching the
//! corresponding paper figure, and append machine-readable JSON rows to
//! `bench_results/` for EXPERIMENTS.md.

use crate::util::json::Json;
use crate::util::stats::{fmt_duration, Samples, Timer};
use std::time::Duration;

/// One benchmark case measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub iters: usize,
    /// Extra columns (e.g. MSE, speedup) keyed by label.
    pub extra: Vec<(String, f64)>,
}

/// Runs `f` with warmup and returns timing stats.
///
/// `min_iters`/`max_seconds` bound total runtime: at least `min_iters`
/// iterations, stopping early once the budget is exhausted.
pub fn bench<T>(name: &str, min_iters: usize, max_seconds: f64, mut f: impl FnMut() -> T) -> Measurement {
    // Warmup: one run (populates caches, JIT-free in rust but warms allocs).
    let _ = f();
    let mut samples = Samples::default();
    let budget = Timer::start();
    let mut iters = 0;
    while iters < min_iters || (budget.elapsed_s() < max_seconds && iters < 1000) {
        let t = Timer::start();
        let _ = f();
        samples.push(t.elapsed_s());
        iters += 1;
        if budget.elapsed_s() >= max_seconds && iters >= min_iters {
            break;
        }
    }
    Measurement {
        name: name.to_string(),
        mean_s: samples.mean(),
        p50_s: samples.median(),
        p95_s: samples.percentile(0.95),
        iters,
        extra: Vec::new(),
    }
}

/// Times a single run of `f` (for expensive end-to-end cases where one
/// iteration is the honest protocol, like the paper's hour-scale runs).
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (Measurement, T) {
    let t = Timer::start();
    let out = f();
    let s = t.elapsed_s();
    (
        Measurement {
            name: name.to_string(),
            mean_s: s,
            p50_s: s,
            p95_s: s,
            iters: 1,
            extra: Vec::new(),
        },
        out,
    )
}

impl Measurement {
    pub fn with_extra(mut self, key: &str, value: f64) -> Self {
        self.extra.push((key.to_string(), value));
        self
    }

    /// Tags the worker-thread count — the sweep axis of the
    /// `BENCH_gemm_mttkrp` kernel-throughput report.
    pub fn with_threads(self, threads: usize) -> Self {
        self.with_extra("threads", threads as f64)
    }
}

/// Throughput in GFLOP/s for `flops` floating-point operations done in
/// `seconds` (the standard `2·m·n·k` GEMM convention is the caller's job).
pub fn gflops(flops: f64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        f64::INFINITY
    } else {
        flops / seconds / 1e9
    }
}

/// A table of measurements that prints like the paper's figures and
/// persists to `bench_results/<id>.json`.
pub struct Report {
    pub id: String,
    pub title: String,
    pub rows: Vec<Measurement>,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Self {
        Self {
            id: id.to_string(),
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, m: Measurement) {
        self.rows.push(m);
    }

    /// Markdown table: name, mean, p50, p95, plus any extra columns.
    pub fn to_markdown(&self) -> String {
        let mut extra_keys: Vec<String> = Vec::new();
        for r in &self.rows {
            for (k, _) in &r.extra {
                if !extra_keys.contains(k) {
                    extra_keys.push(k.clone());
                }
            }
        }
        let mut s = format!("\n## {} — {}\n\n", self.id, self.title);
        s.push_str("| case | mean | p50 | p95 | iters |");
        for k in &extra_keys {
            s.push_str(&format!(" {k} |"));
        }
        s.push('\n');
        s.push_str("|---|---|---|---|---|");
        for _ in &extra_keys {
            s.push_str("---|");
        }
        s.push('\n');
        for r in &self.rows {
            s.push_str(&format!(
                "| {} | {} | {} | {} | {} |",
                r.name,
                fmt_duration(r.mean_s),
                fmt_duration(r.p50_s),
                fmt_duration(r.p95_s),
                r.iters
            ));
            for k in &extra_keys {
                match r.extra.iter().find(|(key, _)| key == k) {
                    Some((_, v)) => s.push_str(&format!(" {v:.3e} |")),
                    None => s.push_str(" — |"),
                }
            }
            s.push('\n');
        }
        s
    }

    /// Writes JSON rows under `bench_results/<id>.json`.
    pub fn save(&self) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all("bench_results")?;
        let path = std::path::PathBuf::from(format!("bench_results/{}.json", self.id));
        self.save_as(&path)?;
        Ok(path)
    }

    /// Writes the JSON document to an explicit path (e.g. the tracked
    /// `BENCH_gemm_mttkrp.json` throughput trajectory).
    pub fn save_as(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut pairs = vec![
                    ("name", Json::str(r.name.clone())),
                    ("mean_s", Json::num(r.mean_s)),
                    ("p50_s", Json::num(r.p50_s)),
                    ("p95_s", Json::num(r.p95_s)),
                    ("iters", Json::num(r.iters as f64)),
                ];
                for (k, v) in &r.extra {
                    pairs.push((k.as_str(), Json::num(*v)));
                }
                Json::Obj(
                    pairs
                        .into_iter()
                        .map(|(k, v)| (k.to_string(), v))
                        .collect(),
                )
            })
            .collect();
        let doc = Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("title", Json::str(self.title.clone())),
            ("rows", Json::Arr(rows)),
        ]);
        std::fs::write(path, doc.to_string_pretty())
    }

    /// Print + save, the standard bench-main tail.
    pub fn finish(&self) {
        println!("{}", self.to_markdown());
        match self.save() {
            Ok(p) => println!("(saved {})", p.display()),
            Err(e) => eprintln!("warning: could not save report: {e}"),
        }
    }
}

/// Computes the "speedup" column the paper reports:
/// `baseline_time / optimized_time`.
pub fn speedup(baseline_s: f64, optimized_s: f64) -> f64 {
    if optimized_s <= 0.0 {
        f64::INFINITY
    } else {
        baseline_s / optimized_s
    }
}

/// Sleep-free busy-wait used by harness self-tests.
#[doc(hidden)]
pub fn spin_for(d: Duration) {
    let t = Timer::start();
    while t.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_respects_min_iters() {
        let m = bench("spin", 3, 0.0, || spin_for(Duration::from_micros(100)));
        assert!(m.iters >= 3);
        assert!(m.mean_s >= 50e-6);
    }

    #[test]
    fn bench_once_single_iter() {
        let (m, out) = bench_once("one", || 42);
        assert_eq!(m.iters, 1);
        assert_eq!(out, 42);
    }

    #[test]
    fn report_markdown_contains_rows_and_extras() {
        let mut rep = Report::new("figX", "test");
        rep.push(
            bench("a", 1, 0.0, || ()).with_extra("mse", 1.5e-7),
        );
        rep.push(bench("b", 1, 0.0, || ()));
        let md = rep.to_markdown();
        assert!(md.contains("| a |"));
        assert!(md.contains("mse"));
        assert!(md.contains("1.500e-7") || md.contains("1.5e-7") || md.contains("1.500e-07"));
    }

    #[test]
    fn speedup_math() {
        assert_eq!(speedup(10.0, 2.0), 5.0);
        assert!(speedup(1.0, 0.0).is_infinite());
    }

    #[test]
    fn save_writes_parseable_json() {
        let mut rep = Report::new("selftest_harness", "self test");
        rep.push(bench("x", 1, 0.0, || ()));
        let path = rep.save().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(parsed.get("id").unwrap().as_str(), Some("selftest_harness"));
        std::fs::remove_file(path).ok();
    }
}
