//! Split-precision matrices and the compensated matmul of Eq. (5).

use crate::linalg::backend::{ComputeBackend, SerialBackend};
use crate::linalg::{Matrix, Trans};
use crate::util::f16::{quantize_bf16_slice, quantize_f16_slice};

/// Which 16-bit format the emulation rounds through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixedPrecision {
    /// IEEE binary16 — GPU tensor-core semantics (the paper's hardware).
    F16,
    /// bfloat16 — TPU MXU semantics (our adapted target).
    Bf16,
    /// No rounding: plain f32 (the "off" ablation arm).
    Full,
}

/// A matrix split into `hi` (16-bit-representable values stored widened to
/// f32) and `lo = original − hi` residual.
#[derive(Clone, Debug)]
pub struct SplitMatrix {
    pub hi: Matrix,
    pub lo: Matrix,
}

/// Splits `m` into 16-bit high part + residual (`hi + lo == m` exactly,
/// by Sterbenz' lemma, for finite values).
pub fn split_matrix(m: &Matrix, precision: MixedPrecision) -> SplitMatrix {
    let hi_data = match precision {
        MixedPrecision::F16 => quantize_f16_slice(m.data()),
        MixedPrecision::Bf16 => quantize_bf16_slice(m.data()),
        MixedPrecision::Full => m.data().to_vec(),
    };
    let lo_data: Vec<f32> = m
        .data()
        .iter()
        .zip(&hi_data)
        .map(|(&orig, &hi)| if hi.is_finite() { orig - hi } else { 0.0 })
        .collect();
    SplitMatrix {
        hi: Matrix::from_vec(m.rows(), m.cols(), hi_data),
        lo: Matrix::from_vec(m.rows(), m.cols(), lo_data),
    }
}

/// First-order compensated mixed-precision matmul (Eq. 5 restricted to two
/// operands):
/// `A·B ≈ hi(A)·hi(B) + hi(A)·lo(B) + lo(A)·hi(B)`
/// where each product term is computed with 16-bit operands accumulated in
/// f32 (the emulation quantizes the operands; accumulation here is f32 as
/// on the MXU/tensor cores).
pub fn matmul_mixed(a: &Matrix, b: &Matrix, precision: MixedPrecision) -> Matrix {
    matmul_mixed_with(a, b, precision, &SerialBackend)
}

/// [`matmul_mixed`] dispatching its three GEMM terms through `backend`.
pub fn matmul_mixed_with(
    a: &Matrix,
    b: &Matrix,
    precision: MixedPrecision,
    backend: &dyn ComputeBackend,
) -> Matrix {
    if precision == MixedPrecision::Full {
        return backend.matmul(a, Trans::No, b, Trans::No);
    }
    let sa = split_matrix(a, precision);
    let sb = split_matrix(b, precision);
    // The residuals lo(A), lo(B) are themselves quantized before the MMA —
    // hardware feeds them through the same 16-bit port. Splitting already
    // leaves lo within 2^-10 (2^-7 for bf16) of hi's magnitude, and one more
    // rounding is how the real kernel behaves.
    let lo_a = split_matrix(&sa.lo, precision).hi;
    let lo_b = split_matrix(&sb.lo, precision).hi;

    let mut out = Matrix::zeros(a.rows(), b.cols());
    backend.gemm(1.0, &sa.hi, Trans::No, &sb.hi, Trans::No, 0.0, &mut out);
    backend.gemm(1.0, &sa.hi, Trans::No, &lo_b, Trans::No, 1.0, &mut out);
    backend.gemm(1.0, &lo_a, Trans::No, &sb.hi, Trans::No, 1.0, &mut out);
    out
}

/// Uncompensated 16-bit matmul (`hi·hi` only) — what naive tensor-core use
/// gives you; the ablation baseline for Eq. (5).
pub fn matmul_mixed_naive(a: &Matrix, b: &Matrix, precision: MixedPrecision) -> Matrix {
    if precision == MixedPrecision::Full {
        return SerialBackend.matmul(a, Trans::No, b, Trans::No);
    }
    let sa = split_matrix(a, precision);
    let sb = split_matrix(b, precision);
    SerialBackend.matmul(&sa.hi, Trans::No, &sb.hi, Trans::No)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::util::rng::Xoshiro256;

    fn rel_err(approx: &Matrix, exact: &Matrix) -> f64 {
        approx.rel_error(exact)
    }

    #[test]
    fn split_reconstructs_exactly() {
        let mut rng = Xoshiro256::seed_from_u64(120);
        let m = Matrix::random_normal(20, 20, &mut rng);
        for p in [MixedPrecision::F16, MixedPrecision::Bf16] {
            let s = split_matrix(&m, p);
            let rec = s.hi.add(&s.lo);
            assert_eq!(rec, m, "{p:?} split not exact");
        }
    }

    #[test]
    fn compensation_beats_naive_f16() {
        let mut rng = Xoshiro256::seed_from_u64(121);
        let a = Matrix::random_normal(64, 64, &mut rng);
        let b = Matrix::random_normal(64, 64, &mut rng);
        let exact = matmul(&a, Trans::No, &b, Trans::No);
        let naive = rel_err(&matmul_mixed_naive(&a, &b, MixedPrecision::F16), &exact);
        let comp = rel_err(&matmul_mixed(&a, &b, MixedPrecision::F16), &exact);
        assert!(
            comp < naive / 10.0,
            "compensated {comp:.2e} should be ≫ better than naive {naive:.2e}"
        );
    }

    #[test]
    fn compensation_beats_naive_bf16() {
        let mut rng = Xoshiro256::seed_from_u64(122);
        let a = Matrix::random_normal(48, 48, &mut rng);
        let b = Matrix::random_normal(48, 48, &mut rng);
        let exact = matmul(&a, Trans::No, &b, Trans::No);
        let naive = rel_err(&matmul_mixed_naive(&a, &b, MixedPrecision::Bf16), &exact);
        let comp = rel_err(&matmul_mixed(&a, &b, MixedPrecision::Bf16), &exact);
        assert!(comp < naive / 5.0, "comp {comp:.2e} vs naive {naive:.2e}");
    }

    #[test]
    fn full_precision_is_exact_passthrough() {
        let mut rng = Xoshiro256::seed_from_u64(123);
        let a = Matrix::random_normal(10, 12, &mut rng);
        let b = Matrix::random_normal(12, 9, &mut rng);
        let exact = matmul(&a, Trans::No, &b, Trans::No);
        assert_eq!(matmul_mixed(&a, &b, MixedPrecision::Full), exact);
        assert_eq!(matmul_mixed_naive(&a, &b, MixedPrecision::Full), exact);
    }

    #[test]
    fn error_bound_first_order() {
        // Compensated error should be O(u²)·cond-ish: for unit-scale
        // operands and f16 (u ≈ 2^-11), expect ≲ 1e-5 relative error.
        let mut rng = Xoshiro256::seed_from_u64(124);
        let a = Matrix::random_normal(32, 32, &mut rng);
        let b = Matrix::random_normal(32, 32, &mut rng);
        let exact = matmul(&a, Trans::No, &b, Trans::No);
        let comp = rel_err(&matmul_mixed(&a, &b, MixedPrecision::F16), &exact);
        assert!(comp < 5e-5, "comp err {comp:.2e}");
    }
}
