//! Mixed-precision emulation — §IV-B of the paper.
//!
//! GPU tensor cores compute `FP16×FP16 + FP32 → FP32`; the TPU MXU computes
//! `bf16×bf16 → f32` (DESIGN.md §Hardware-Adaptation).  Either way the
//! operands are lossy 16-bit, and the paper's fix is a first-order residual
//! expansion (Eq. 5): split every operand `x = hi(x) + lo(x)` with `hi` the
//! 16-bit rounding and `lo` the exactly-representable residual, then keep
//! the four first-order product terms
//! `hi·hi + lo·hi + hi·lo` (and the `lo` of the *tensor* side) while
//! dropping the quadratic `lo·lo` terms.
//!
//! This module provides the bit-faithful **CPU emulation** used by the
//! rust-only benchmark variants and by tests that validate the Pallas
//! kernel's numerics; the L1 Pallas kernel (`python/compile/kernels/
//! mixed_matmul.py`) implements the same scheme on the MXU path.

pub mod split;

pub use split::{
    matmul_mixed, matmul_mixed_naive, matmul_mixed_with, split_matrix, MixedPrecision,
    SplitMatrix,
};
