//! Thin SVD via one-sided Jacobi (Hestenes) rotations.
//!
//! Used by the Tucker/HOSVD comparison baseline (`cp::tucker`) and
//! available to the HOSVD init.  One-sided Jacobi orthogonalizes the
//! columns of `A`; the column norms become singular values, `U` the
//! normalized columns, and `V` accumulates the rotations.  Robust and
//! simple at the few-hundred-column scale we need.

use super::matrix::Matrix;

/// Thin SVD `A (m×n) = U (m×n) · diag(s) · Vᵀ (n×n)` with singular values
/// sorted descending. Requires `m ≥ n` (transpose first otherwise).
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub v: Matrix,
}

/// Computes the thin SVD by one-sided Jacobi sweeps.
pub fn svd_thin(a: &Matrix) -> Svd {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "svd_thin: need m ≥ n (got {m}×{n}); transpose first");
    // Work in f64 for the rotations.
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let idx = |i: usize, j: usize| i + j * m;
    let mut v = vec![0.0f64; n * n];
    for j in 0..n {
        v[j + j * n] = 1.0;
    }

    let max_sweeps = 60;
    let eps = 1e-12;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries of columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let xp = w[idx(i, p)];
                    let xq = w[idx(i, q)];
                    app += xp * xp;
                    aqq += xq * xq;
                    apq += xp * xq;
                }
                off += apq * apq;
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let xp = w[idx(i, p)];
                    let xq = w[idx(i, q)];
                    w[idx(i, p)] = c * xp - s * xq;
                    w[idx(i, q)] = s * xp + c * xq;
                }
                for j in 0..n {
                    let vp = v[j + p * n];
                    let vq = v[j + q * n];
                    v[j + p * n] = c * vp - s * vq;
                    v[j + q * n] = s * vp + c * vq;
                }
            }
        }
        if off.sqrt() < 1e-14 {
            break;
        }
    }

    // Column norms → singular values; normalize U columns.
    let mut order: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 = (0..m).map(|i| w[idx(i, j)] * w[idx(i, j)]).sum();
            (norm.sqrt(), j)
        })
        .collect();
    order.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut vm = Matrix::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (out_col, &(norm, src)) in order.iter().enumerate() {
        s.push(norm as f32);
        if norm > 1e-300 {
            for i in 0..m {
                u.set(i, out_col, (w[idx(i, src)] / norm) as f32);
            }
        } else if out_col < m {
            u.set(out_col, out_col, 1.0); // arbitrary orthogonal completion
        }
        for j in 0..n {
            vm.set(j, out_col, v[j + src * n] as f32);
        }
    }
    Svd { u, s, v: vm }
}

/// Leading `k` left singular vectors of `A` (works for any aspect ratio).
pub fn leading_singular_vectors(a: &Matrix, k: usize) -> Matrix {
    if a.rows() >= a.cols() {
        let svd = svd_thin(a);
        svd.u.slice_cols(0, k.min(svd.u.cols()))
    } else {
        // A = U S Vᵀ ⇔ Aᵀ = V S Uᵀ: take V of the transpose.
        let svd = svd_thin(&a.transpose());
        svd.v.slice_cols(0, k.min(svd.v.cols()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, Trans};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn reconstructs_random_matrix() {
        let mut rng = Xoshiro256::seed_from_u64(700);
        let a = Matrix::random_normal(15, 8, &mut rng);
        let svd = svd_thin(&a);
        // A = U diag(s) Vᵀ
        let us = svd.u.scale_cols(&svd.s);
        let rec = matmul(&us, Trans::No, &svd.v, Trans::Yes);
        assert!(rec.rel_error(&a) < 1e-5, "err {}", rec.rel_error(&a));
    }

    #[test]
    fn u_and_v_orthonormal() {
        let mut rng = Xoshiro256::seed_from_u64(701);
        let a = Matrix::random_normal(12, 6, &mut rng);
        let svd = svd_thin(&a);
        let utu = matmul(&svd.u, Trans::Yes, &svd.u, Trans::No);
        assert!(utu.rel_error(&Matrix::identity(6)) < 1e-5);
        let vtv = matmul(&svd.v, Trans::Yes, &svd.v, Trans::No);
        assert!(vtv.rel_error(&Matrix::identity(6)) < 1e-5);
    }

    #[test]
    fn singular_values_sorted_and_match_norm() {
        let mut rng = Xoshiro256::seed_from_u64(702);
        let a = Matrix::random_normal(20, 5, &mut rng);
        let svd = svd_thin(&a);
        for wpair in svd.s.windows(2) {
            assert!(wpair[0] >= wpair[1] - 1e-6);
        }
        let frob_sq: f64 = svd.s.iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert!((frob_sq.sqrt() - a.frobenius_norm()).abs() < 1e-4);
    }

    #[test]
    fn low_rank_detected() {
        let mut rng = Xoshiro256::seed_from_u64(703);
        let b = Matrix::random_normal(10, 2, &mut rng);
        let c = Matrix::random_normal(2, 6, &mut rng);
        let a = matmul(&b, Trans::No, &c, Trans::No); // rank ≤ 2
        let svd = svd_thin(&a);
        assert!(svd.s[2] < 1e-4 * svd.s[0], "s = {:?}", svd.s);
    }

    #[test]
    fn wide_matrix_leading_vectors() {
        let mut rng = Xoshiro256::seed_from_u64(704);
        let a = Matrix::random_normal(4, 10, &mut rng);
        let u = leading_singular_vectors(&a, 3);
        assert_eq!((u.rows(), u.cols()), (4, 3));
        let utu = matmul(&u, Trans::Yes, &u, Trans::No);
        assert!(utu.rel_error(&Matrix::identity(3)) < 1e-4);
    }
}
