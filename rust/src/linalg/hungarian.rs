//! Hungarian algorithm (Kuhn–Munkres) for the assignment problem.
//!
//! The paper uses it twice (Alg. 2 lines 6 and 11): to find the column
//! permutation `Π_p` maximizing `Tr(A_1(1:S,:)ᵀ A_p(1:S,:) Π)` and to match
//! the sampled-subtensor factors against the recovered `AΠΣ`.  We implement
//! the O(n³) potentials/augmenting-path formulation for **minimum** cost and
//! expose a maximization wrapper.

use super::matrix::Matrix;

/// Result of an assignment: `col_of_row[i] = j` means row `i` is matched to
/// column `j`; `total` is the summed weight of the matching.
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub col_of_row: Vec<usize>,
    pub total: f64,
}

/// Minimum-cost perfect matching on a square cost matrix (O(n³)).
///
/// Classic shortest-augmenting-path formulation with row/column potentials
/// (equivalent to the Jonker-Volgenant variant).
pub fn hungarian_min(cost: &Matrix) -> Assignment {
    let n = cost.rows();
    assert_eq!(n, cost.cols(), "hungarian: square matrix required");
    if n == 0 {
        return Assignment {
            col_of_row: vec![],
            total: 0.0,
        };
    }
    // 1-indexed internals (0 is a sentinel), following the standard e-maxx
    // formulation.
    let inf = f64::INFINITY;
    let mut u = vec![0.0f64; n + 1]; // row potentials
    let mut v = vec![0.0f64; n + 1]; // col potentials
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to column j
    let mut way = vec![0usize; n + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost.get(i0 - 1, j - 1) as f64 - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // Augment along the alternating path.
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut col_of_row = vec![0usize; n];
    let mut total = 0.0;
    for j in 1..=n {
        if p[j] > 0 {
            col_of_row[p[j] - 1] = j - 1;
            total += cost.get(p[j] - 1, j - 1) as f64;
        }
    }
    Assignment { col_of_row, total }
}

/// Maximum-weight perfect matching: negates the weights and calls
/// [`hungarian_min`].  This is the trace-maximization step of Alg. 2.
pub fn hungarian_max(weight: &Matrix) -> Assignment {
    let n = weight.rows();
    let neg = Matrix::from_fn(n, n, |i, j| -weight.get(i, j));
    let a = hungarian_min(&neg);
    let total = (0..n)
        .map(|i| weight.get(i, a.col_of_row[i]) as f64)
        .sum();
    Assignment {
        col_of_row: a.col_of_row,
        total,
    }
}

/// Converts an assignment to the permutation `perm` such that applying
/// `permute_cols(perm)` to the *candidate* matrix aligns its columns with
/// the reference: `perm[r] = c` where candidate column `c` matches
/// reference column `r`.
pub fn assignment_to_perm(a: &Assignment) -> Vec<usize> {
    // a.col_of_row[ref_col] = cand_col (rows index the reference side).
    a.col_of_row.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn identity_cost_picks_diagonal() {
        // Cost 0 on the diagonal, 1 elsewhere → diagonal matching.
        let c = Matrix::from_fn(4, 4, |i, j| if i == j { 0.0 } else { 1.0 });
        let a = hungarian_min(&c);
        assert_eq!(a.col_of_row, vec![0, 1, 2, 3]);
        assert_eq!(a.total, 0.0);
    }

    #[test]
    fn known_3x3() {
        // Classic example: optimal = 5 (1+3+1? verify by brute force below).
        let c = Matrix::from_rows(&[&[4.0, 1.0, 3.0], &[2.0, 0.0, 5.0], &[3.0, 2.0, 2.0]]);
        let a = hungarian_min(&c);
        assert_eq!(a.total, brute_force_min(&c).1);
    }

    #[test]
    fn max_variant_recovers_planted_permutation() {
        // Weight matrix: big on a planted permutation.
        let perm = [2usize, 0, 3, 1];
        let w = Matrix::from_fn(4, 4, |i, j| if perm[i] == j { 10.0 } else { 1.0 });
        let a = hungarian_max(&w);
        assert_eq!(a.col_of_row, perm.to_vec());
        assert_eq!(a.total, 40.0);
    }

    fn brute_force_min(c: &Matrix) -> (Vec<usize>, f64) {
        let n = c.rows();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut best = (perm.clone(), f64::INFINITY);
        permute(&mut perm, 0, &mut |p| {
            let cost: f64 = (0..n).map(|i| c.get(i, p[i]) as f64).sum();
            if cost < best.1 {
                best = (p.to_vec(), cost);
            }
        });
        best
    }

    fn permute(xs: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
        if k == xs.len() {
            f(xs);
            return;
        }
        for i in k..xs.len() {
            xs.swap(k, i);
            permute(xs, k + 1, f);
            xs.swap(k, i);
        }
    }

    #[test]
    fn property_optimal_vs_brute_force() {
        prop::check("hungarian-optimal", 40, |g| {
            let n = g.int(1, 6);
            let c = Matrix::from_fn(n, n, |_, _| 0.0);
            let mut c = c;
            for j in 0..n {
                for i in 0..n {
                    c.set(i, j, g.f32(-5.0, 5.0));
                }
            }
            let fast = hungarian_min(&c);
            let (_, best) = brute_force_min(&c);
            assert!(
                (fast.total - best).abs() < 1e-4,
                "hungarian {} vs brute {best}",
                fast.total
            );
            // output is a permutation
            let mut seen = vec![false; n];
            for &j in &fast.col_of_row {
                assert!(!seen[j], "duplicate column {j}");
                seen[j] = true;
            }
        });
    }

    #[test]
    fn empty_matrix() {
        let a = hungarian_min(&Matrix::zeros(0, 0));
        assert!(a.col_of_row.is_empty());
    }

    #[test]
    fn single_element() {
        let a = hungarian_min(&Matrix::from_rows(&[&[7.0]]));
        assert_eq!(a.col_of_row, vec![0]);
        assert_eq!(a.total, 7.0);
    }
}
